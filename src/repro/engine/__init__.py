"""Unified public API: ``Dataset`` + ``Engine`` over pluggable backends.

    from repro.engine import Dataset

    ds = Dataset.watdiv(scale=0.5, threshold=0.25)
    eng = ds.engine("jit")                  # or "eager" / "distributed"
    res = eng.query("SELECT * WHERE { ?u wsdbm:follows ?v . "
                    "?v wsdbm:likes ?p }")
    res.to_terms()                          # dictionary-decoded rows

Templated queries (same shape, different constants) hit the plan cache:
parsing and compilation happen once per template, constants re-bind as
runtime values (see :mod:`repro.engine.template`).
"""

from repro.engine.backends import (
    ExecutionBackend, ExecutionContext, PreparedQuery, available_backends,
    create_backend, register_backend,
)
from repro.engine.dataset import Dataset
from repro.engine.engine import Engine, PlanCache, ServerMetrics
from repro.engine.result import Result
from repro.engine.template import (
    ConstantBinding, QueryTemplate, template_signature,
)

__all__ = [
    "Dataset", "Engine", "Result",
    "ExecutionBackend", "ExecutionContext", "PreparedQuery",
    "register_backend", "create_backend", "available_backends",
    "QueryTemplate", "ConstantBinding", "template_signature",
    "ServerMetrics", "PlanCache",
]
