"""Unified public API: ``Dataset`` + ``Engine`` over pluggable backends.

    from repro.engine import Dataset

    # τ=0.25 is the paper's recommended production SF-threshold (§7.4):
    # it keeps most query-relevant ExtVP reductions at a fraction of
    # the τ=1.0 storage.
    ds = Dataset.watdiv(scale=0.5, seed=0, threshold=0.25)
    eng = ds.engine("jit")                  # or "eager" / "distributed"
    res = eng.query("SELECT * WHERE { ?u wsdbm:follows ?v . "
                    "?v wsdbm:likes ?p }")
    res.to_terms()                          # dictionary-decoded rows

    # batched: same-template requests share ONE compiled-program launch
    results = eng.query_batch([
        "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }",
        "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v . ?v sorg:email ?e }",
    ])

Templated queries (same shape, different constants) hit the plan cache:
parsing and compilation happen once per template, constants re-bind as
runtime values (see :mod:`repro.engine.template`).  ``query_batch``
stacks the constants into a leading batch axis instead (one XLA launch
for the whole batch, see docs/serving.md).
"""

from repro.engine.backends import (
    ExecutionBackend, ExecutionContext, PreparedQuery, available_backends,
    create_backend, register_backend,
)
from repro.engine.dataset import Dataset
from repro.engine.engine import Engine, PlanCache, ServerMetrics
from repro.engine.result import Result
from repro.engine.template import (
    ConstantBinding, QueryTemplate, template_signature,
)
from repro.runtime import BackendRouter, BatchTuner, RuntimeConfig

__all__ = [
    "Dataset", "Engine", "Result",
    "ExecutionBackend", "ExecutionContext", "PreparedQuery",
    "register_backend", "create_backend", "available_backends",
    "QueryTemplate", "ConstantBinding", "template_signature",
    "ServerMetrics", "PlanCache",
    "RuntimeConfig", "BackendRouter", "BatchTuner",
]
