"""Pluggable execution backends behind one protocol.

S2RDF's design point is that one relational layer (ExtVP + Algorithm-1/4
compilation) serves any query shape on any execution substrate; this
module is where the substrates plug in.  A backend turns a
:class:`~repro.engine.template.QueryTemplate` into a
:class:`PreparedQuery` — the expensive, template-level artifact (parsed
tree, compiled plan, jitted XLA program, sharded storage) — and a
prepared query runs any constant instantiation via a
:class:`~repro.engine.template.ConstantBinding` without re-parsing or
re-compiling.

Built-in backends:

* ``eager``        — host numpy reference engine (exact dynamic shapes).
* ``jit``          — static-shape XLA program (:mod:`repro.core.jexec`);
                     bound constants are runtime arguments, so one
                     compiled program serves every instantiation.
* ``distributed``  — shard_map over a device mesh
                     (:mod:`repro.core.distributed`); requires ``mesh``.

New backends (Pallas probe paths, cached/sharded layouts, remote
engines) register with :func:`register_backend` and become addressable by
name everywhere a backend string is accepted — no call-site changes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.algebra import BGP, Query
from repro.core.compiler import Plan, compile_bgp, compile_core
from repro.core.executor import (
    Bindings, apply_spine_host, execute, execute_plan,
)
from repro.core.modifiers import peel_spine, substitute_spine
from repro.core.stats import Catalog
from repro.engine.result import Result
from repro.engine.template import (
    ConstantBinding, QueryTemplate, node_vars, rebind_plan, substitute_query,
)

__all__ = [
    "ExecutionContext", "PreparedQuery", "ExecutionBackend",
    "register_backend", "create_backend", "available_backends",
]

_NO_BINDING = ConstantBinding(mapping={}, missing=False)


@dataclass
class ExecutionContext:
    """Everything a backend needs to prepare and run queries."""

    catalog: Catalog
    dictionary: object = None            # Optional[repro.rdf.Dictionary]
    layout: str = "extvp"
    mesh: object = None                  # Optional[jax.sharding.Mesh]
    #: join-order planner compiled plans use ("greedy" | "estimate");
    #: the Engine refreshes this from its RuntimeConfig before every
    #: prepare, and keys its plan cache on it
    planner: str = "greedy"


class PreparedQuery:
    """A template compiled for one backend; run any instantiation of it.

    ``run(binding)`` evaluates the prepared program under a constant
    binding (``None`` for slot-free queries).  Subclasses hold whatever
    per-template state their engine needs.
    """

    backend: str = "?"
    #: True when run_batch executes the whole batch in one program launch
    #: (padding to a static shape is then worthwhile); the base loop runs
    #: padding slots as real queries, so callers must not pad for it.
    vectorized_batch: bool = False
    #: True when a device backend could not compile the template and fell
    #: back to the eager host engine — Engine counts these per request
    #: (``device_fallbacks``), so silent eager execution is observable.
    fallback: bool = False

    def __init__(self, template: QueryTemplate, ctx: ExecutionContext):
        self.template = template
        self.ctx = ctx
        self.query: Query = template.query

    # -- interface -------------------------------------------------------------
    def run(self, binding: Optional[ConstantBinding] = None,
            trace=None) -> Result:
        """``trace`` is the sampled request's
        :class:`~repro.obs.tracer.TraceContext` (or ``None``, the
        default and the fast path) — implementations emit their
        launch/decode spans onto it."""
        raise NotImplementedError

    def run_batch(self, bindings: List[Optional[ConstantBinding]],
                  trace=None) -> List[Result]:
        """Evaluate B constant-bindings of this template; one Result per
        binding, in order.  The base implementation is the sequential
        loop — the parity oracle every vectorized override is tested
        against.  Device backends override it to execute the whole batch
        in a single program launch (the bindings stack into a leading
        batch axis of the ``bounds`` input).  ``trace`` is the chunk's
        lead trace context; the sequential loop attributes it to the
        first binding."""
        return [self.run(b, trace=trace if i == 0 else None)
                for i, b in enumerate(bindings)]

    # -- shared helpers --------------------------------------------------------
    @property
    def out_cols(self) -> Tuple[str, ...]:
        if self.query.select is not None:
            return tuple(self.query.select)
        return node_vars(self.query.root)

    def _empty(self) -> Result:
        return Result.empty(self.out_cols, self.ctx.dictionary)


class _EmptyPrepared(PreparedQuery):
    """Statistics-proven empty template: answered without touching data."""

    def __init__(self, template, ctx, backend: str):
        super().__init__(template, ctx)
        self.backend = backend
        self.plan = Plan(empty=True, vars=self.out_cols)

    def run(self, binding: Optional[ConstantBinding] = None,
            trace=None) -> Result:
        if trace is not None:
            trace.event("short_circuit", why="statistics-empty plan")
        return self._empty()


class _EagerPrepared(PreparedQuery):
    """Host numpy engine.  Queries whose modifier spine sits on a BGP
    core cache the compiled plan + spine and re-bind scan/filter
    constants by id substitution; other operator trees
    (OPTIONAL/UNION/...) cache the parsed tree and re-bind through
    ``substitute_query``."""

    backend = "eager"

    def __init__(self, template, ctx, fallback: bool = False):
        super().__init__(template, ctx)
        self.fallback = fallback
        self.plan: Optional[Plan] = None
        self.spine = None
        core, spine = peel_spine(self.query)
        if isinstance(core, BGP) and ctx.layout != "pt":
            self.plan = compile_bgp(core, ctx.catalog, ctx.layout,
                                    ctx.planner)
            self.spine = spine

    def run(self, binding: Optional[ConstantBinding] = None,
            trace=None) -> Result:
        binding = binding or _NO_BINDING
        if binding.missing:
            return self._empty()
        sid = trace.start("host.execute", backend="eager") \
            if trace is not None else None
        if self.plan is not None:
            if self.plan.empty:
                if trace is not None:
                    trace.end(sid, rows=0, short_circuit=True)
                return self._empty()
            plan = rebind_plan(self.plan, binding.mapping)
            spine = substitute_spine(self.spine, binding.mapping)
            b = apply_spine_host(execute_plan(plan, self.ctx.catalog), spine,
                                 self.ctx.catalog)
            res = Result(b, self.ctx.dictionary)
        else:
            query = substitute_query(self.query, binding.mapping)
            res = Result(execute(query, self.ctx.catalog,
                                 layout=self.ctx.layout),
                         self.ctx.dictionary)
        if trace is not None:
            trace.end(sid, rows=len(res))
        return res


class _VectorizedPrepared(PreparedQuery):
    """Shared device path (jit/distributed): the executor owns a compiled
    static program whose ``bounds`` input carries the bound constants.
    ``run`` feeds one bounds vector; ``run_batch`` stacks B of them into
    a leading batch axis and executes the whole micro-batch in a single
    launch.  Missing-constant bindings (S2RDF's statistics-only empty
    answer) are answered on the host and never occupy a batch slot."""

    vectorized_batch = True

    def __init__(self, template, ctx, executor):
        super().__init__(template, ctx)
        self.executor = executor
        self.plan: Plan = executor.plan

    def _wrap(self, data: np.ndarray, cols: Tuple[str, ...]) -> Result:
        # the executor's compiled spine already applied FILTER, the
        # projection, DISTINCT, ORDER BY and the slice on device — the
        # host must not re-project or re-dedup (that would destroy the
        # device-established row order)
        return Result(Bindings(cols, data), self.ctx.dictionary)

    def run(self, binding: Optional[ConstantBinding] = None,
            trace=None) -> Result:
        binding = binding or _NO_BINDING
        if binding.missing:
            if trace is not None:
                trace.event("short_circuit", why="constant missing "
                            "from the dictionary")
            return self._empty()
        plan = rebind_plan(self.plan, binding.mapping)
        data, cols = self.executor.run(
            bounds=self.executor.bounds_from_plan(plan),
            fconsts=self.executor.fconsts_from_mapping(binding.mapping),
            trace=trace)
        if trace is None:
            return self._wrap(data, cols)
        sid = trace.start("decode")
        res = self._wrap(data, cols)
        trace.end(sid, rows=len(res))
        return res

    def run_batch(self, bindings: List[Optional[ConstantBinding]],
                  trace=None) -> List[Result]:
        bindings = [b or _NO_BINDING for b in bindings]
        results: List[Optional[Result]] = [None] * len(bindings)
        live: List[int] = []
        bounds: List[np.ndarray] = []
        fconsts: List[np.ndarray] = []
        for i, b in enumerate(bindings):
            if b.missing:
                results[i] = self._empty()
            else:
                live.append(i)
                bounds.append(self.executor.bounds_from_plan(
                    rebind_plan(self.plan, b.mapping)))
                fconsts.append(self.executor.fconsts_from_mapping(b.mapping))
        if live:
            # pad back to the caller's (static-bucket) batch size: missing
            # bindings must not shrink B, or each distinct live-count would
            # compile its own program
            while len(bounds) < len(bindings):
                bounds.append(bounds[-1])
                fconsts.append(fconsts[-1])
            outs = self.executor.run_batch(bounds, fconsts, trace=trace)
            sid = trace.start("demux", batch=len(bindings),
                              live=len(live)) if trace is not None else None
            for i, (data, cols) in zip(live, outs):
                results[i] = self._wrap(data, cols)
            if trace is not None:
                trace.end(sid)
        return results

    def lower(self, caps=None):
        return self.executor.lower(caps)


class _JitPrepared(_VectorizedPrepared):
    """Static-shape XLA program, compiled once per template.  Bound
    constants are runtime scalars, so re-binding never re-traces; a
    batch of bindings re-traces once per batch shape, never per request."""

    backend = "jit"


class _DistributedPrepared(_VectorizedPrepared):
    """shard_map engine over a mesh; table shards and the per-shard
    program are template-level state, constants are runtime scalars.
    Batches vmap the bounds stack inside shard_map, so every device
    serves the whole batch over its own table shard in one launch."""

    backend = "distributed"


# ---------------------------------------------------------------------------
# Backends
# ---------------------------------------------------------------------------

class ExecutionBackend:
    """Protocol: ``prepare(template, ctx) -> PreparedQuery``."""

    name: str = "?"

    def prepare(self, template: QueryTemplate,
                ctx: ExecutionContext) -> PreparedQuery:
        raise NotImplementedError


class EagerBackend(ExecutionBackend):
    name = "eager"

    def prepare(self, template, ctx):
        return _EagerPrepared(template, ctx)


class JitBackend(ExecutionBackend):
    """The full graph-pattern fragment — BGP/FILTER/OPTIONAL/UNION cores
    plus unbound-predicate (triples-table) scans, under any modifier
    spine (see :func:`repro.core.modifiers.peel_spine`) — compiles
    end-to-end into the static-shape device program via
    :func:`repro.core.compiler.compile_core`.  The remaining eager
    fallbacks (flagged so the Engine can count them) are the host-only
    ``pt`` storage layout and dictionaries whose numeric keys defeat the
    double-single encoding — both surface as NotImplementedError during
    prepare, never as silent divergence at run time."""

    name = "jit"

    def prepare(self, template, ctx):
        if ctx.layout == "pt":
            return _EagerPrepared(template, ctx, fallback=True)
        core, spine = peel_spine(template.query)
        from repro.core.jexec import PlanExecutor
        try:
            cp = compile_core(core, ctx.catalog, ctx.layout, ctx.planner)
            if cp.empty:
                return _EmptyPrepared(template, ctx, self.name)
            ex = PlanExecutor(cp, ctx.catalog, spine=spine)
        except NotImplementedError:
            return _EagerPrepared(template, ctx, fallback=True)
        return _JitPrepared(template, ctx, ex)


class DistributedBackend(ExecutionBackend):
    name = "distributed"

    def __init__(self, dual_partition: bool = False):
        self.dual_partition = dual_partition

    def prepare(self, template, ctx):
        if ctx.mesh is None:
            raise ValueError("distributed backend needs a mesh")
        if ctx.layout == "pt":
            return _EagerPrepared(template, ctx, fallback=True)
        core, spine = peel_spine(template.query)
        from repro.core.distributed import DistributedExecutor
        try:
            cp = compile_core(core, ctx.catalog, ctx.layout, ctx.planner)
            if cp.empty:
                return _EmptyPrepared(template, ctx, self.name)
            ex = DistributedExecutor(cp, ctx.catalog, ctx.mesh,
                                     dual_partition=self.dual_partition,
                                     spine=spine)
        except NotImplementedError:
            return _EagerPrepared(template, ctx, fallback=True)
        return _DistributedPrepared(template, ctx, ex)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ExecutionBackend]] = {}


def register_backend(name: str,
                     factory: Callable[[], ExecutionBackend]) -> None:
    """Register (or replace) a backend under a string key."""
    _REGISTRY[name] = factory


def create_backend(name: str) -> ExecutionBackend:
    try:
        return _REGISTRY[name]()
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {available_backends()}"
        ) from None


def available_backends() -> List[str]:
    return sorted(_REGISTRY)


register_backend("eager", EagerBackend)
register_backend("jit", JitBackend)
register_backend("distributed", DistributedBackend)
