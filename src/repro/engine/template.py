"""Query templates: the unit of plan caching and constant re-binding.

A served SPARQL workload repeats a small set of *templates* with varying
entity constants (WatDiv's ``%x%`` placeholders, S2RDF §7).  Everything
expensive about a query — parsing, Algorithm-1 table selection,
Algorithm-4 join ordering, XLA compilation of the static-shape program —
depends only on the template: bound entity constants influence nothing but
the scan selection *values*.  This module makes that observation
executable:

* ``template_signature`` normalizes entity constants out of the query
  text (schema terms — predicates, class names — stay, because they
  determine table selection and therefore plan identity).
* ``QueryTemplate`` parses the query ONCE with each constant replaced by
  a unique placeholder id, so the algebra tree / compiled plan can be
  re-bound to new constants by a pure id substitution — no re-parse, no
  re-compile.
* ``ConstantBinding`` maps placeholder ids to real dictionary ids for one
  instantiation; a constant absent from the dictionary marks the binding
  ``missing`` (the statistics-only empty answer, S2RDF §6).

Placeholders get ids in a reserved negative band so they can never
collide with dictionary ids (dense ``[0, n)``), ``UNBOUND`` (-1) or
``MISSING_TERM`` (-2).
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple

from repro.core.algebra import (
    BGP, Distinct, Filter, JoinPair, LeftJoin, Node, OrderBy, Project,
    Query, Slice, TriplePattern, UnionOp, is_var, tp_vars,
)
from repro.core.compiler import Plan, ScanStep
from repro.core.modifiers import substitute_filter, substitute_term
from repro.core.sparql import MISSING_TERM, _Parser

__all__ = [
    "template_signature", "extract_constants", "ConstantBinding",
    "QueryTemplate", "substitute_query", "rebind_plan", "node_vars",
    "PLACEHOLDER_BASE",
]

# Entity constants: IRIs, literals, and prefixed names whose local part
# contains a digit (instance ids like wsdbm:User3).  Schema terms —
# predicates, class names without instance suffixes — are left intact:
# they determine table selection, so they are part of the plan identity.
# The pname alternative must consume the WHOLE token (trailing chars after
# the digit included), otherwise slot substitution would split a name like
# wsdbm:User3a mid-token and corrupt the template text.
_CONST_RE = re.compile(
    r"(?:<[^>]*>|\"(?:[^\"\\]|\\.)*\""
    r"|(?<![?\w])[A-Za-z_][\w\-]*:[\w\-\.]*\d[\w\-\.]*)")

# PREFIX declarations carry IRIs that are namespace bindings, not entity
# constants: they must survive both signatures and template substitution.
_PROLOGUE_RE = re.compile(
    r"^(?:\s*PREFIX\s+[A-Za-z_][\w\-]*:\s*<[^>]*>)*\s*", re.IGNORECASE)

# Reserved id band for template placeholders: slot i gets id BASE - i.
PLACEHOLDER_BASE = -1000


def _normalize(qtext: str) -> str:
    return " ".join(qtext.split())


def _split_prologue(norm: str) -> Tuple[str, str]:
    m = _PROLOGUE_RE.match(norm)
    return norm[: m.end()], norm[m.end():]


def template_signature(qtext: str) -> str:
    """Normalize bound entity terms so template instantiations share a
    plan slot.  The prologue is kept verbatim (two queries binding the
    same prefix to different IRIs must not share a template)."""
    prologue, body = _split_prologue(_normalize(qtext))
    return prologue + _CONST_RE.sub("¤", body)


def extract_constants(qtext: str) -> List[str]:
    """Entity constants of one instantiation, in textual order — the
    positional counterpart of the ¤ slots in the signature."""
    _, body = _split_prologue(_normalize(qtext))
    return _CONST_RE.findall(body)


class _TemplateDictionary:
    """Dictionary view that resolves ``¤<i>`` tokens to placeholder ids."""

    def __init__(self, base) -> None:
        self._base = base

    def id_of(self, term: str) -> Optional[int]:
        if term.startswith("¤"):
            try:
                return PLACEHOLDER_BASE - int(term[1:])
            except ValueError:
                pass
        return self._base.id_of(term)


def _resolve_name(term: str, dictionary, prefixes: Dict[str, str]) -> Optional[int]:
    """Resolve a surface term exactly the way the parser would."""
    tid = dictionary.id_of(term)
    if tid is not None:
        return tid
    if ":" in term and not term.startswith('"'):
        pfx, local = term.split(":", 1)
        if pfx in prefixes:
            return dictionary.id_of(prefixes[pfx] + local)
    return None


def resolve_constant(text: str, dictionary,
                     prefixes: Dict[str, str]) -> Optional[int]:
    if text.startswith("<") and text.endswith(">"):
        return _resolve_name(text[1:-1], dictionary, prefixes)
    return _resolve_name(text, dictionary, prefixes)


@dataclass(frozen=True)
class ConstantBinding:
    """Placeholder-id → dictionary-id mapping for one instantiation."""

    mapping: Dict[int, int]
    missing: bool = False   # some constant absent from the dictionary

    @property
    def empty(self) -> bool:
        return not self.mapping


_EMPTY_BINDING = ConstantBinding(mapping={}, missing=False)


class QueryTemplate:
    """A parsed query with entity constants lifted into rebindable slots.

    ``query`` holds placeholder ids (negative band) wherever the source
    text had an entity constant; ``binding_for(qtext)`` produces the
    substitution for a concrete instantiation of the same signature.
    """

    def __init__(self, qtext: str, dictionary) -> None:
        norm = _normalize(qtext)
        prologue, body = _split_prologue(norm)
        self.signature = prologue + _CONST_RE.sub("¤", body)
        self.dictionary = dictionary

        n = 0

        def _slot(m: re.Match) -> str:
            nonlocal n
            token = f"<¤{n}>"
            n += 1
            return token

        template_text = prologue + _CONST_RE.sub(_slot, body)
        parser = _Parser(template_text, _TemplateDictionary(dictionary))
        self.query: Query = parser.parse_query()
        self.prefixes: Dict[str, str] = parser.prefixes
        self.slot_ids: Tuple[int, ...] = tuple(
            PLACEHOLDER_BASE - i for i in range(n))
        # A placeholder in predicate position would poison table selection
        # (predicates are plan identity); such templates are not reusable.
        slot_set = set(self.slot_ids)
        self.rebindable = not any(
            (not is_var(tp.p)) and int(tp.p) in slot_set
            for tp in iter_patterns(self.query.root))

    @classmethod
    def concrete(cls, qtext: str, dictionary) -> "QueryTemplate":
        """A degenerate, slot-free template: the query parsed as-is.
        Used for queries whose template form is not rebindable."""
        self = cls.__new__(cls)
        self.signature = template_signature(qtext)
        self.dictionary = dictionary
        parser = _Parser(_normalize(qtext), dictionary)
        self.query = parser.parse_query()
        self.prefixes = parser.prefixes
        self.slot_ids = ()
        self.rebindable = False
        return self

    @property
    def n_slots(self) -> int:
        return len(self.slot_ids)

    def binding_for(self, qtext: str) -> ConstantBinding:
        if not self.slot_ids:
            return _EMPTY_BINDING
        consts = extract_constants(qtext)
        if len(consts) != len(self.slot_ids):
            raise ValueError(
                f"query does not match template: {len(consts)} constants "
                f"vs {len(self.slot_ids)} slots")
        mapping: Dict[int, int] = {}
        missing = False
        for slot, text in zip(self.slot_ids, consts):
            tid = resolve_constant(text, self.dictionary, self.prefixes)
            if tid is None:
                tid = MISSING_TERM
                missing = True
            mapping[slot] = tid
        return ConstantBinding(mapping=mapping, missing=missing)


# ---------------------------------------------------------------------------
# Substitution: pure id rewrites over trees and plans
# ---------------------------------------------------------------------------

# The id-rewrite primitives live in repro.core.modifiers (engine/ may
# import core/, not vice versa); these are the historical local names.
_sub_term = substitute_term
_sub_expr = substitute_filter


def _sub_tp(tp: TriplePattern, mapping: Dict[int, int]) -> TriplePattern:
    return TriplePattern(_sub_term(tp.s, mapping), _sub_term(tp.p, mapping),
                         _sub_term(tp.o, mapping))


def _sub_node(node: Node, mapping: Dict[int, int]) -> Node:
    if isinstance(node, BGP):
        return BGP([_sub_tp(tp, mapping) for tp in node.patterns])
    if isinstance(node, JoinPair):
        return JoinPair(_sub_node(node.left, mapping),
                        _sub_node(node.right, mapping))
    if isinstance(node, Filter):
        return Filter(_sub_expr(node.expr, mapping),
                      _sub_node(node.child, mapping))
    if isinstance(node, LeftJoin):
        return LeftJoin(_sub_node(node.left, mapping),
                        _sub_node(node.right, mapping),
                        None if node.expr is None else
                        _sub_expr(node.expr, mapping))
    if isinstance(node, UnionOp):
        return UnionOp(_sub_node(node.left, mapping),
                       _sub_node(node.right, mapping))
    if isinstance(node, Distinct):
        return Distinct(_sub_node(node.child, mapping))
    if isinstance(node, OrderBy):
        return OrderBy(_sub_node(node.child, mapping), node.keys)
    if isinstance(node, Slice):
        return Slice(_sub_node(node.child, mapping), node.offset, node.limit)
    if isinstance(node, Project):
        return Project(_sub_node(node.child, mapping), node.vars)
    raise TypeError(f"unknown node {type(node)}")


def substitute_query(query: Query, mapping: Dict[int, int]) -> Query:
    """Clone ``query`` with every constant id rewritten through ``mapping``."""
    if not mapping:
        return query
    return Query(root=_sub_node(query.root, mapping), select=query.select,
                 distinct=query.distinct)


def rebind_plan(plan: Plan, mapping: Dict[int, int]) -> Plan:
    """Re-bind scan constants of a compiled plan.  Table selection, join
    order and statistics are template-invariant, so only the triple
    patterns change."""
    if not mapping or plan.empty:
        return plan
    steps = [ScanStep(_sub_tp(s.tp, mapping), s.kind, s.p2, s.sf, s.size,
                      s.uses_tt) for s in plan.steps]
    return Plan(steps=steps, empty=plan.empty, vars=plan.vars,
                planner=plan.planner)


def iter_patterns(node: Node) -> Iterator[TriplePattern]:
    if isinstance(node, BGP):
        yield from node.patterns
    elif isinstance(node, (JoinPair, LeftJoin, UnionOp)):
        yield from iter_patterns(node.left)
        yield from iter_patterns(node.right)
    elif isinstance(node, (Filter, Distinct, OrderBy, Slice, Project)):
        yield from iter_patterns(node.child)


def node_vars(node: Node) -> Tuple[str, ...]:
    """Variables produced by a pattern tree, in first-seen order."""
    seen: List[str] = []
    for tp in iter_patterns(node):
        for v in tp_vars(tp):
            if v not in seen:
                seen.append(v)
    return tuple(seen)
