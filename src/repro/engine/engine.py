"""The unified query engine: template LRU cache + backend dispatch.

``Engine`` is the one public execution surface.  It owns

* a real LRU plan cache keyed on the template signature — each entry
  holds the parsed :class:`~repro.engine.template.QueryTemplate` AND the
  backend's :class:`~repro.engine.backends.PreparedQuery`, so a repeated
  templated query is served with zero parsing and zero compilation (the
  constants re-bind as runtime values);
* the statistics short-circuit (provably-empty plans answered without
  touching data, the ST-8 behaviour, visible per request);
* the **adaptive runtime** (``backend="auto"``): a per-template
  :class:`~repro.runtime.router.BackendRouter` that measures eager /
  jit / distributed latency and routes each signature to its observed
  winner, and a :class:`~repro.runtime.tuner.BatchTuner` that adapts
  the micro-batch shape menu from observed launch latencies (see
  docs/serving.md, "Adaptive runtime");
* operator metrics: latency percentiles, plan-cache hit rate,
  empty-answer count, rows served, per-backend routing counts.

S2RDF notes that repeated Virtuoso queries benefit from caching while its
own runtimes are stable: here we cache *compilation*, never results.
"""

from __future__ import annotations

from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.verifier import verify_prepared
from repro.engine.backends import (
    ExecutionBackend, ExecutionContext, PreparedQuery, create_backend,
)
from repro.engine.result import Result
from repro.engine.template import QueryTemplate, _normalize, template_signature
from repro.obs import LogHistogram, Tracer
from repro.obs.tracer import TraceContext
from repro.runtime import BackendRouter, BatchTuner, RouteDecision, \
    RuntimeConfig
from repro.runtime.config import runtime_config as _global_runtime_config

__all__ = ["Engine", "ServerMetrics", "PlanCache"]


# Compat sample windows (``latencies_ms`` / ``queue_ms`` below) keep only
# the newest slice — the histograms are the real percentile source now
# and never truncate.
_MAX_SAMPLES = 8192

# cardinality-drift reports cached per (prepared, binding): a hot
# template's repeated traces must not re-run the host joins every time
_DRIFT_CACHE_SIZE = 1024


@dataclass
class ServerMetrics:
    served: int = 0
    rows: int = 0
    empties: int = 0          # zero-row answers, however produced
    short_circuits: int = 0   # answered from statistics alone (no data touched)
    # requests served through an eager fallback on a device backend (the
    # prepared query's ``fallback`` flag): silent eager execution was the
    # failure mode that hid the device path's BGP-only coverage
    device_fallbacks: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    # micro-batching: one "batch" is one device launch serving B requests
    batches: int = 0          # batched launches executed
    batched_requests: int = 0 # requests served through a batched launch
    padding_slots: int = 0    # slots wasted padding up to a static shape
    # adaptive runtime: requests per backend actually executed on (on a
    # static engine this is all one key; under "auto" it shows the mix)
    routed: Dict[str, int] = field(default_factory=dict)

    # Snapshot provider attached by the owning Engine — lets anything
    # holding the metrics object (SparqlServer, dashboards) pull the full
    # router/tuner state without a reference to the engine itself.
    runtime_report_fn = None
    # Attached by the owning Engine: lets the Prometheus renderer expose
    # per-stage span histograms without a reference to the engine.
    tracer: Optional[Tracer] = None

    def __post_init__(self) -> None:
        # Histograms are the primary store: O(1) memory, O(1) record,
        # exact counts, mergeable.  The bounded deques only back the
        # legacy ``latencies_ms`` / ``queue_ms`` list views (compat shim
        # until callers migrate) — a deque's maxlen trims in O(1) where
        # the old lists materialized ``[ms] * count`` and re-sliced.
        self.latency_hist = LogHistogram()
        self.queue_hist = LogHistogram()
        self._lat_samples: "deque" = deque(maxlen=_MAX_SAMPLES)
        self._queue_samples: "deque" = deque(maxlen=_MAX_SAMPLES)

    # -- compat shims (deprecated list views; see docs/observability.md) ------
    @property
    def latencies_ms(self) -> List[float]:
        """Newest latency samples as a list (bounded window).  Deprecated
        read-only view — percentiles come from ``latency_hist`` now."""
        return list(self._lat_samples)

    @property
    def queue_ms(self) -> List[float]:
        """Newest queue-wait samples as a list (bounded window).
        Deprecated read-only view — use ``queue_hist``."""
        return list(self._queue_samples)

    def record_route(self, backend: str, count: int = 1) -> None:
        self.routed[backend] = self.routed.get(backend, 0) + count

    def record_latency(self, ms: float, count: int = 1) -> None:
        self.latency_hist.record(ms, count)
        # the compat window never needs more than maxlen copies
        self._lat_samples.extend([ms] * min(count, _MAX_SAMPLES))

    def record_queue(self, ms: float) -> None:
        self.queue_hist.record(ms)
        self._queue_samples.append(ms)

    def runtime_report(self) -> Dict[str, object]:
        """The owning engine's router/tuner snapshot (empty when the
        metrics object is not attached to an engine)."""
        fn = self.runtime_report_fn
        return fn() if fn is not None else {}

    def summary(self) -> Dict[str, object]:
        """Operator summary.  Percentiles are ``None`` (not a fabricated
        0.0) until at least one sample exists, so a dashboard can tell
        "idle" from "fast"."""
        slots = self.batched_requests + self.padding_slots
        lat, qms = self.latency_hist, self.queue_hist
        return {
            "served": self.served,
            "rows": self.rows,
            "empties": self.empties,
            "short_circuits": self.short_circuits,
            "device_fallbacks": self.device_fallbacks,
            "plan_hit_rate": self.plan_hits / max(self.plan_hits
                                                  + self.plan_misses, 1),
            "p50_ms": lat.percentile(50),
            "p90_ms": lat.percentile(90),
            "p99_ms": lat.percentile(99),
            "batches": self.batches,
            "batched_requests": self.batched_requests,
            # fraction of launched batch slots carrying real requests
            "batch_occupancy": self.batched_requests / max(slots, 1),
            "padding_waste": self.padding_slots / max(slots, 1),
            "queue_p50_ms": qms.percentile(50),
            "queue_p99_ms": qms.percentile(99),
            "routed": dict(self.routed),
        }

    def prometheus(self) -> str:
        """This metrics object in the Prometheus text exposition format
        (counters, latency/queue/per-stage histograms, router and tuner
        gauges) — see :mod:`repro.obs.prometheus` and
        docs/observability.md for the metric catalog."""
        from repro.obs.prometheus import render
        return render(self)


class PlanCache:
    """Bounded LRU: signature -> PreparedQuery.  Replaces the old
    per-signature "presence" dict (which re-parsed unconditionally) and
    the unbounded executor cache."""

    def __init__(self, capacity: int = 512):
        self.capacity = max(1, int(capacity))
        self._data: "OrderedDict[str, PreparedQuery]" = OrderedDict()
        self.evictions = 0

    def get(self, sig: str) -> Optional[PreparedQuery]:
        hit = self._data.get(sig)
        if hit is not None:
            self._data.move_to_end(sig)
        return hit

    def put(self, sig: str, prepared: PreparedQuery) -> None:
        self._data[sig] = prepared
        self._data.move_to_end(sig)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, sig: str) -> bool:
        return sig in self._data

    def keys(self):
        return self._data.keys()


class Engine:
    """Execute SPARQL text over a Dataset through one pluggable backend —
    or through the adaptive runtime.

    Created via :meth:`repro.engine.dataset.Dataset.engine` (or directly
    from a catalog-bearing dataset).  ``backend`` is a registry key —
    ``"eager"``, ``"jit"``, ``"distributed"``, or anything registered via
    :func:`repro.engine.backends.register_backend` — or the special key
    ``"auto"``: the engine then prepares templates on every candidate
    backend (eager + jit, plus distributed when a mesh is given) and a
    :class:`~repro.runtime.BackendRouter` routes each template signature
    to its measured-latency winner (warmup → exploit → periodic probe;
    knobs on :class:`~repro.runtime.RuntimeConfig` / ``runtime=``).
    """

    #: Static batch shapes a micro-batch is padded up to.  A small fixed
    #: menu bounds the number of compiled programs per template at
    #: ``len(BATCH_SHAPES)`` while keeping padding waste < 50%.  The
    #: live menu belongs to :class:`~repro.runtime.BatchTuner`, which
    #: retires shapes that measure slower than smaller ones.
    BATCH_SHAPES: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)

    def __init__(self, dataset, backend: str = "eager",
                 layout: str = "extvp", mesh=None,
                 plan_cache_size: int = 512,
                 batch_shapes: Optional[Sequence[int]] = None,
                 runtime: Optional[RuntimeConfig] = None):
        # alpa global_config idiom: engines without an explicit runtime=
        # share the process-wide default instance
        self.config = runtime if runtime is not None else \
            _global_runtime_config
        if isinstance(backend, ExecutionBackend):
            self._backends: Dict[str, ExecutionBackend] = \
                {backend.name: backend}
        elif backend == "auto":
            names = ["eager", "jit"] + \
                (["distributed"] if mesh is not None else [])
            self._backends = {n: create_backend(n) for n in names}
        else:
            b = create_backend(backend)
            self._backends = {b.name: b}
        self.auto = len(self._backends) > 1 or backend == "auto"
        if "distributed" in self._backends and mesh is None:
            raise ValueError(
                "distributed backend needs a mesh: pass mesh=jax.make_mesh("
                "(n_devices,), ('data',)) (see docs/serving.md)")
        self.dataset = dataset
        self.layout = layout
        self.ctx = ExecutionContext(catalog=dataset.catalog,
                                    dictionary=dataset.dictionary,
                                    layout=layout, mesh=mesh,
                                    planner=self._planner)
        self.cache = PlanCache(plan_cache_size)
        self.metrics = ServerMetrics()
        self.metrics.runtime_report_fn = self.runtime_report
        #: span tracing (repro.obs) — inert until the config's
        #: ``trace_sample_rate`` knob is > 0 (the hot path's only cost is
        #: the ``tracer.active`` guard)
        self.tracer = Tracer(self.config)
        self.metrics.tracer = self.tracer
        self._drift_cache: "OrderedDict" = OrderedDict()
        if batch_shapes is None:
            shapes = self.config.batch_shapes
        else:
            shapes = tuple(batch_shapes)
        if not shapes or min(shapes) < 1:
            raise ValueError("batch_shapes must be positive ints")
        self.batch_shapes: Tuple[int, ...] = tuple(sorted(shapes))
        self.router = BackendRouter(tuple(self._backends), self.config)
        self.tuner = BatchTuner(self.batch_shapes, self.config)

    @property
    def backend(self) -> str:
        if self.auto:
            return "auto"
        return next(iter(self._backends))

    @property
    def _backend(self) -> ExecutionBackend:
        """The sole backend of a static engine (back-compat accessor)."""
        return next(iter(self._backends.values()))

    @property
    def _planner(self) -> str:
        """The live planner knob — read from the RuntimeConfig on every
        use so flipping ``config.planner`` mid-session takes effect (the
        plan-cache key includes it, so stale orders cannot be served)."""
        return getattr(self.config, "planner", "greedy")

    # -- compilation ----------------------------------------------------------
    def _cache_key(self, bname: str, sig: str) -> str:
        # static engines keep the bare signature as the key (the public,
        # documented cache shape); auto engines hold one prepared query
        # per (backend, signature).  A non-default planner prefixes the
        # key: plans compiled under different join-order planners are
        # different artifacts and must never shadow each other.
        key = sig if not self.auto else f"{bname}::{sig}"
        planner = self._planner
        return key if planner == "greedy" else f"planner={planner}::{key}"

    def _lookup(self, bname: str, qtext: str, sig: str
                ) -> Optional[PreparedQuery]:
        prepared = self.cache.get(self._cache_key(bname, sig))
        if prepared is not None:
            return prepared
        # Non-rebindable templates (e.g. a constant in predicate position)
        # are cached under the exact normalized text instead, so identical
        # repeats still skip parsing and compilation.
        return self.cache.get(self._cache_key(bname, "=" + _normalize(qtext)))

    def _build(self, bname: str, qtext: str, sig: str,
               trace: Optional[TraceContext] = None) -> PreparedQuery:
        self.ctx.planner = self._planner
        sid = trace.start("parse") if trace is not None else None
        try:
            template = QueryTemplate(qtext, self.ctx.dictionary)
        except ValueError:
            # Template substitution produced unparseable text (constants the
            # slot regex cannot lift cleanly); fall back to the concrete
            # query.  A genuinely malformed query raises from .concrete.
            template = None
        if template is None or not template.rebindable:
            template = QueryTemplate.concrete(qtext, self.ctx.dictionary)
        if trace is not None:
            trace.end(sid, rebindable=template.rebindable)
            sid = trace.start("plan", backend=bname,
                              planner=self._planner)
        prepared = self._backends[bname].prepare(template, self.ctx)
        if trace is not None:
            trace.end(sid, fallback=getattr(prepared, "fallback", False))
        if getattr(self.config, "verify_plans", False):
            sid = trace.start("verify") if trace is not None else None
            verify_prepared(prepared, self.ctx.catalog).raise_if_failed()
            if trace is not None:
                trace.end(sid)
        key = sig if template.rebindable else "=" + _normalize(qtext)
        self.cache.put(self._cache_key(bname, key), prepared)
        return prepared

    def _prepared_for(self, bname: str, qtext: str, sig: str,
                      counted: bool = False,
                      trace: Optional[TraceContext] = None
                      ) -> PreparedQuery:
        prepared = self._lookup(bname, qtext, sig)
        if prepared is not None:
            if counted:
                self.metrics.plan_hits += 1
            if trace is not None:
                trace.event("plan_cache", outcome="hit", backend=bname)
            return prepared
        if counted:
            self.metrics.plan_misses += 1
        if trace is not None:
            trace.event("plan_cache", outcome="miss", backend=bname)
        return self._build(bname, qtext, sig, trace=trace)

    def prepare(self, qtext: str) -> PreparedQuery:
        """Prepared form of ``qtext``'s template, from cache if present,
        on the backend the router currently favors.  Cache-hit
        bookkeeping happens in :meth:`query`; ``prepare`` is the silent
        path for callers managing their own loop."""
        sig = template_signature(qtext)
        _, prepared = self._route(qtext, sig, counted=False, peek=True)
        return prepared

    # -- routing ---------------------------------------------------------------
    def _route(self, qtext: str, sig: str, counted: bool = True,
               peek: bool = False,
               use: Optional[RouteDecision] = None,
               trace: Optional[TraceContext] = None
               ) -> Tuple[RouteDecision, PreparedQuery]:
        """Decide a backend for this request and return its prepared
        query.  A backend whose ``prepare`` raises (auto mode only) is
        excluded for the signature and the router re-decides; a prepared
        query that silently fell back to the eager host path is likewise
        excluded — the router must never attribute eager latencies to a
        device backend.  ``use`` short-circuits the first decision (a
        micro-batch group decides once via :meth:`BackendRouter.decide`
        and shares it); the exclusion/re-route machinery still applies."""
        while True:
            if use is not None:
                decision, use = use, None
            else:
                decision = self.router.peek(sig) if peek \
                    else self.router.decide(sig)
            bname = decision.backend
            if trace is not None:
                # the routing decision IS a trace event, losing EWMAs
                # attached — trace_inspect answers "why eager?" from this
                trace.event("router.decide", backend=bname,
                            reason=decision.reason,
                            ewma_ms=self.router.estimates(sig))
            try:
                prepared = self._prepared_for(bname, qtext, sig, counted,
                                              trace=trace)
            except Exception:
                if self.auto and bname != "eager":
                    self.router.mark_failed(sig, bname)
                    if trace is not None:
                        trace.event("router.exclude", backend=bname,
                                    why="prepare failed")
                    counted = False    # one request, one hit/miss count
                    continue
                raise
            if self.auto and bname != "eager" and prepared.fallback:
                self.router.mark_fallback(sig, bname)
                if trace is not None:
                    trace.event("router.exclude", backend=bname,
                                why="eager fallback")
                counted = False
                continue
            return decision, prepared

    def explain(self, qtext: str) -> str:
        """The compiled plan of ``qtext``'s template plus (for flat BGP
        cores) per-step estimated vs. actual intermediate cardinalities,
        which join-order planner produced the plan, and the routing
        decision the request would get right now and why (``forced`` on a
        static engine, ``warmup``/``measured``/``probe`` under ``auto``)
        — diagnostics, consumes no routing budget (the actual column does
        execute the pipeline's joins on the host)."""
        sig = template_signature(qtext)
        decision, prepared = self._route(qtext, sig, counted=False,
                                         peek=True)
        plan = getattr(prepared, "plan", None)
        lines = [plan.describe() if plan is not None else "(operator tree)"]
        lines.extend(self._explain_cardinalities(prepared, qtext, plan))
        st = self.router.report()["signatures"].get(sig, {})
        ewma = st.get("ewma_ms", {})
        detail = ", ".join(f"{b}={ewma[b]:.3f}ms" for b in sorted(ewma))
        lines.append(f"backend: {decision.backend} ({decision.reason}"
                     + (f"; measured {detail}" if detail else "") + ")")
        if getattr(prepared, "fallback", False):
            lines.append("note: prepared as an eager fallback "
                         "(device path cannot express this template)")
        # static-verifier verdict — always reported here (explain is the
        # diagnostic surface), regardless of the verify_plans gate
        lines.append(verify_prepared(prepared, self.ctx.catalog).describe())
        return "\n".join(lines)

    def _explain_cardinalities(self, prepared: PreparedQuery, qtext: str,
                               plan) -> List[str]:
        """Estimated-vs-actual per-step cardinality lines for flat BGP
        pipelines (sequentially joining the flat steps of an
        OPTIONAL/UNION tree would misstate its semantics, so those only
        report the winning planner)."""
        from repro.core.algebra import BGP
        from repro.core.modifiers import peel_spine
        from repro.engine.template import rebind_plan

        if plan is None:
            return []
        requested = self._planner
        out = [f"planner: {plan.planner} (requested {requested})"
               if plan.planner != requested else f"planner: {plan.planner}"]
        if plan.empty or not plan.steps:
            return out
        core, _ = peel_spine(prepared.template.query)
        if not isinstance(core, BGP):
            return out
        concrete = plan
        if prepared.template.rebindable:
            binding = prepared.template.binding_for(qtext)
            if binding.missing:
                out.append("cardinalities: skipped (constant absent from "
                           "the dictionary; answered from statistics)")
                return out
            concrete = rebind_plan(plan, binding.mapping)

        from repro.core import estimate as _estimate
        ests = _estimate.estimate_order(concrete.steps, self.ctx.catalog)
        actuals = _estimate.actual_cardinalities(concrete.steps,
                                                 self.ctx.catalog)
        if ests is None:
            out.append("cardinalities: estimates unavailable (catalog has "
                       "no distinct-count statistics)")
            ests = [None] * len(concrete.steps)
        for i, (step, est, act) in enumerate(
                zip(concrete.steps, ests, actuals)):
            shown = "?" if est is None else f"{est.rows:.1f}"
            out.append(f"  step {i}: {step.describe()} "
                       f"est={shown} actual={act}")
        return out

    # -- execution ------------------------------------------------------------
    def _record(self, prepared: PreparedQuery, binding, res: Result) -> None:
        """Per-request result accounting shared by the single-query and
        batched paths."""
        self.metrics.served += 1
        self.metrics.rows += len(res)
        if len(res) == 0:
            self.metrics.empties += 1
        if getattr(prepared, "fallback", False):
            self.metrics.device_fallbacks += 1
        plan = getattr(prepared, "plan", None)
        if (plan is not None and plan.empty) or \
                (binding is not None and binding.missing):
            self.metrics.short_circuits += 1

    def query(self, qtext: str) -> Result:
        clock = self.config.clock
        t0 = clock()
        # guard-first fast path: with tracing off this costs one
        # attribute load and one float compare (gated <=1% overhead by
        # benchmarks/trace_overhead.py)
        tr = self.tracer
        trace = tr.begin(qtext) if tr is not None and tr.active else None
        sig = template_signature(qtext)
        if trace is not None:
            trace.annotate(sig=sig)
        decision, prepared = self._route(qtext, sig, trace=trace)
        binding = prepared.template.binding_for(qtext) \
            if prepared.template.rebindable else None
        t_run = clock()
        if trace is not None:
            sid = trace.start("execute", backend=decision.backend)
            res = prepared.run(binding, trace=trace)
            trace.end(sid, rows=len(res))
        else:
            res = prepared.run(binding)
        self.router.observe(sig, decision.backend,
                            (clock() - t_run) * 1e3, reason=decision.reason)
        self.metrics.record_latency((clock() - t0) * 1e3)
        self.metrics.record_route(decision.backend)
        self._record(prepared, binding, res)
        if trace is not None:
            self._trace_finish(trace, prepared, binding, decision)
        return res

    # -- batched execution -----------------------------------------------------
    def bucket_shape(self, n: int) -> int:
        """Smallest *active* static batch shape holding ``n`` requests
        (``n`` larger than the biggest shape is chunked by the caller).
        The menu starts as ``batch_shapes`` and shrinks as the tuner
        retires shapes that measure slower than smaller ones."""
        return self.tuner.bucket_for(n)

    def max_active_batch(self) -> int:
        """Largest currently-active batch shape (the micro-batcher's
        effective bucket bound)."""
        return self.tuner.max_shape()

    def _run_group(self, sig: str, decision: RouteDecision,
                   prepared: PreparedQuery,
                   bindings: List[Optional[object]],
                   traces: Optional[List[Optional[TraceContext]]] = None
                   ) -> List[Result]:
        """Execute same-template bindings through ``run_batch``, chunked
        at the largest active static shape and padded up to the bucket
        shape (the pad repeats a real binding; padded results are
        dropped).  Backends whose ``run_batch`` is the sequential loop
        are not padded — padding only buys something when the batch is
        one static-shape program launch.

        ``traces`` (parallel to ``bindings``) carries the sampled
        requests' trace contexts.  A chunk shares ONE device launch, so
        the fenced ``device.launch`` span lands on the chunk's first
        traced context (the *lead*); every other traced request of the
        chunk gets its own ``execute`` span flagged
        ``shared_launch=True``."""
        out: List[Result] = []
        clock = self.config.clock
        max_shape = self.max_active_batch()
        pad = getattr(prepared, "vectorized_batch", False)
        if traces is None:
            traces = [None] * len(bindings)
        for start in range(0, len(bindings), max_shape):
            chunk = bindings[start: start + max_shape]
            traced = [(j, t) for j, t in
                      enumerate(traces[start: start + max_shape])
                      if t is not None]
            lead = traced[0][1] if traced else None
            shape = self.bucket_shape(len(chunk)) if pad else len(chunk)
            padded = chunk + [chunk[-1]] * (shape - len(chunk))
            open_sids = [
                (t, t.start("execute", backend=decision.backend,
                            batch=len(chunk), shape=shape,
                            shared_launch=t is not lead))
                for _, t in traced]
            if lead is not None and shape != len(chunk):
                lead.event("batch.pad", shape=shape, live=len(chunk),
                           padding=shape - len(chunk))
            t0 = clock()
            res = prepared.run_batch(padded, trace=lead) \
                if lead is not None else prepared.run_batch(padded)
            dt_ms = (clock() - t0) * 1e3
            self.metrics.batches += 1
            self.metrics.batched_requests += len(chunk)
            self.metrics.padding_slots += shape - len(chunk)
            # every request in the batch observed the batch's wall time
            self.metrics.record_latency(dt_ms, count=len(chunk))
            self.metrics.record_route(decision.backend, count=len(chunk))
            # the router compares per-request service time across
            # backends; the tuner compares per-slot time across shapes
            self.router.observe(sig, decision.backend, dt_ms / len(chunk),
                                reason=decision.reason, weight=len(chunk))
            if pad:
                before = self.tuner.active_shapes() \
                    if lead is not None else None
                self.tuner.observe(shape, len(chunk), dt_ms)
                if lead is not None:
                    after = self.tuner.active_shapes()
                    if after != before:
                        lead.event("tuner.retire", retired=[
                            s for s in before if s not in after])
            kept = res[: len(chunk)]
            for (j, t), (_, sid) in zip(traced, open_sids):
                t.end(sid, rows=len(kept[j]))
            out.extend(kept)
        return out

    def query_batch(self, qtexts: List[str],
                    traces: Optional[List[Optional[TraceContext]]] = None
                    ) -> List[Result]:
        """Execute a list of queries, amortizing device launches: requests
        sharing a prepared template are stacked into one batched program
        execution (see :meth:`PreparedQuery.run_batch`); results come back
        in submission order.  This is the synchronous core the serving
        layer's micro-batcher drains into.  ``traces`` lets the batcher
        hand over trace contexts begun at submit time (so the queue span
        is part of the trace); called directly, the engine samples its
        own."""
        tr = self.tracer
        if traces is None:
            traces = [tr.begin(q) for q in qtexts] \
                if tr is not None and tr.active else [None] * len(qtexts)
        results: List[Optional[Result]] = [None] * len(qtexts)
        sig_groups: "OrderedDict[str, List[int]]" = OrderedDict()
        for i, qtext in enumerate(qtexts):
            sig_groups.setdefault(template_signature(qtext), []).append(i)
        for sig, idxs in sig_groups.items():
            # ONE routing decision per signature group: the whole group
            # lands on one backend (so a probe measures the loser on a
            # realistic batched launch) and the router costs one decision
            # per launch group, not one per request
            shared = self.router.decide(sig, n=len(idxs))
            groups: "OrderedDict[int, Tuple[RouteDecision, PreparedQuery, List[int]]]" = \
                OrderedDict()
            for i in idxs:
                if traces[i] is not None:
                    traces[i].annotate(sig=sig)
                # per-request _route keeps the failure/fallback re-route
                # machinery; on the cached fast path it is one dict get
                decision, prepared = self._route(qtexts[i], sig,
                                                 use=shared,
                                                 trace=traces[i])
                groups.setdefault(id(prepared),
                                  (decision, prepared, []))[2].append(i)
            for decision, prepared, sub in groups.values():
                bindings = [prepared.template.binding_for(qtexts[i])
                            if prepared.template.rebindable else None
                            for i in sub]
                group_results = self._run_group(sig, decision, prepared,
                                                bindings,
                                                [traces[i] for i in sub])
                for i, binding, res in zip(sub, bindings, group_results):
                    results[i] = res
                    self._record(prepared, binding, res)
                    if traces[i] is not None:
                        self._trace_finish(traces[i], prepared, binding,
                                           decision)
        return results  # type: ignore[return-value]

    # -- trace support ---------------------------------------------------------
    def _trace_finish(self, trace: TraceContext, prepared: PreparedQuery,
                      binding, decision: RouteDecision) -> None:
        """Join the cardinality-drift report onto the trace's launch
        spans and hand the finished trace to the flight recorder."""
        if getattr(self.config, "trace_cardinality", True):
            drift = self._cardinality_drift(prepared, binding)
            if drift is not None:
                if trace.annotate_named("device.launch",
                                        cardinalities=drift) == 0:
                    trace.annotate_named("host.execute",
                                         cardinalities=drift)
                trace.annotate(cardinalities=drift)
        trace.finish(backend=decision.backend)

    def _cardinality_drift(self, prepared: PreparedQuery, binding
                           ) -> Optional[List[Dict[str, object]]]:
        """Estimated vs. actual per-step cardinalities of a flat BGP
        pipeline — ``explain()``'s drift report as a per-trace artifact.
        The actual column joins the steps on the host, so reports are
        cached per (prepared, binding): a hot template's traces pay the
        joins once, not per request."""
        from repro.core.algebra import BGP
        from repro.core.modifiers import peel_spine
        from repro.engine.template import rebind_plan

        plan = getattr(prepared, "plan", None)
        if plan is None or plan.empty or not plan.steps:
            return None
        if binding is not None and binding.missing:
            return None
        key = (id(prepared),
               tuple(sorted(binding.mapping.items()))
               if binding is not None else ())
        hit = self._drift_cache.get(key)
        if hit is not None:
            self._drift_cache.move_to_end(key)
            return hit
        core, _ = peel_spine(prepared.template.query)
        if not isinstance(core, BGP):
            return None
        concrete = plan if binding is None \
            else rebind_plan(plan, binding.mapping)
        from repro.core import estimate as _estimate
        ests = _estimate.estimate_order(concrete.steps, self.ctx.catalog)
        actuals = _estimate.actual_cardinalities(concrete.steps,
                                                 self.ctx.catalog)
        if actuals is None:
            return None
        if ests is None:
            ests = [None] * len(concrete.steps)
        drift = [{"step": i, "op": step.describe(),
                  "est": None if est is None else round(est.rows, 1),
                  "actual": int(act)}
                 for i, (step, est, act)
                 in enumerate(zip(concrete.steps, ests, actuals))]
        self._drift_cache[key] = drift
        while len(self._drift_cache) > _DRIFT_CACHE_SIZE:
            self._drift_cache.popitem(last=False)
        return drift

    # -- observability ---------------------------------------------------------
    def runtime_report(self) -> Dict[str, object]:
        """One JSON-friendly snapshot of every adaptive-runtime decision:
        per-signature backend choices with their latency estimates, the
        decision log tail, the live batch-shape menu with per-bucket
        stats, the active knob values, and the serving metrics.  Field
        definitions live in docs/serving.md."""
        return {
            "backend": self.backend,
            "auto": self.auto,
            "planner": self._planner,
            "router": self.router.report(),
            "tuner": self.tuner.report(),
            "config": self.config.snapshot(),
            "metrics": self.metrics.summary(),
        }
