"""The unified query engine: template LRU cache + backend dispatch.

``Engine`` is the one public execution surface.  It owns

* a real LRU plan cache keyed on the template signature — each entry
  holds the parsed :class:`~repro.engine.template.QueryTemplate` AND the
  backend's :class:`~repro.engine.backends.PreparedQuery`, so a repeated
  templated query is served with zero parsing and zero compilation (the
  constants re-bind as runtime values);
* the statistics short-circuit (provably-empty plans answered without
  touching data, the ST-8 behaviour, visible per request);
* operator metrics: latency percentiles, plan-cache hit rate,
  empty-answer count, rows served.

S2RDF notes that repeated Virtuoso queries benefit from caching while its
own runtimes are stable: here we cache *compilation*, never results.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.engine.backends import (
    ExecutionBackend, ExecutionContext, PreparedQuery, create_backend,
)
from repro.engine.result import Result
from repro.engine.template import QueryTemplate, _normalize, template_signature

__all__ = ["Engine", "ServerMetrics", "PlanCache"]


@dataclass
class ServerMetrics:
    served: int = 0
    rows: int = 0
    empties: int = 0          # zero-row answers, however produced
    short_circuits: int = 0   # answered from statistics alone (no data touched)
    plan_hits: int = 0
    plan_misses: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        return {
            "served": self.served,
            "rows": self.rows,
            "empties": self.empties,
            "short_circuits": self.short_circuits,
            "plan_hit_rate": self.plan_hits / max(self.plan_hits
                                                  + self.plan_misses, 1),
            "p50_ms": float(np.percentile(lat, 50)),
            "p90_ms": float(np.percentile(lat, 90)),
            "p99_ms": float(np.percentile(lat, 99)),
        }


class PlanCache:
    """Bounded LRU: signature -> PreparedQuery.  Replaces the old
    per-signature "presence" dict (which re-parsed unconditionally) and
    the unbounded executor cache."""

    def __init__(self, capacity: int = 512):
        self.capacity = max(1, int(capacity))
        self._data: "OrderedDict[str, PreparedQuery]" = OrderedDict()
        self.evictions = 0

    def get(self, sig: str) -> Optional[PreparedQuery]:
        hit = self._data.get(sig)
        if hit is not None:
            self._data.move_to_end(sig)
        return hit

    def put(self, sig: str, prepared: PreparedQuery) -> None:
        self._data[sig] = prepared
        self._data.move_to_end(sig)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, sig: str) -> bool:
        return sig in self._data

    def keys(self):
        return self._data.keys()


class Engine:
    """Execute SPARQL text over a Dataset through one pluggable backend.

    Created via :meth:`repro.engine.dataset.Dataset.engine` (or directly
    from a catalog-bearing dataset).  ``backend`` is a registry key —
    ``"eager"``, ``"jit"``, ``"distributed"``, or anything registered via
    :func:`repro.engine.backends.register_backend`.
    """

    def __init__(self, dataset, backend: str = "eager",
                 layout: str = "extvp", mesh=None,
                 plan_cache_size: int = 512):
        if isinstance(backend, ExecutionBackend):
            self._backend = backend
        else:
            self._backend = create_backend(backend)
        if self._backend.name == "distributed" and mesh is None:
            raise ValueError("distributed backend needs a mesh")
        self.dataset = dataset
        self.layout = layout
        self.ctx = ExecutionContext(catalog=dataset.catalog,
                                    dictionary=dataset.dictionary,
                                    layout=layout, mesh=mesh)
        self.cache = PlanCache(plan_cache_size)
        self.metrics = ServerMetrics()

    @property
    def backend(self) -> str:
        return self._backend.name

    # -- compilation ----------------------------------------------------------
    def _lookup(self, qtext: str, sig: str) -> Optional[PreparedQuery]:
        prepared = self.cache.get(sig)
        if prepared is not None:
            return prepared
        # Non-rebindable templates (e.g. a constant in predicate position)
        # are cached under the exact normalized text instead, so identical
        # repeats still skip parsing and compilation.
        return self.cache.get("=" + _normalize(qtext))

    def _build(self, qtext: str, sig: str) -> PreparedQuery:
        try:
            template = QueryTemplate(qtext, self.ctx.dictionary)
        except ValueError:
            # Template substitution produced unparseable text (constants the
            # slot regex cannot lift cleanly); fall back to the concrete
            # query.  A genuinely malformed query raises from .concrete.
            template = None
        if template is None or not template.rebindable:
            template = QueryTemplate.concrete(qtext, self.ctx.dictionary)
        prepared = self._backend.prepare(template, self.ctx)
        self.cache.put(sig if template.rebindable else "=" + _normalize(qtext),
                       prepared)
        return prepared

    def prepare(self, qtext: str) -> PreparedQuery:
        """Prepared form of ``qtext``'s template, from cache if present.
        Cache-hit bookkeeping happens in :meth:`query`; ``prepare`` is the
        silent path for callers managing their own loop."""
        sig = template_signature(qtext)
        prepared = self._lookup(qtext, sig)
        if prepared is not None:
            return prepared
        return self._build(qtext, sig)

    def explain(self, qtext: str) -> str:
        """The compiled plan of ``qtext``'s template (diagnostics)."""
        prepared = self.prepare(qtext)
        plan = getattr(prepared, "plan", None)
        return plan.describe() if plan is not None else "(operator tree)"

    # -- execution ------------------------------------------------------------
    def query(self, qtext: str) -> Result:
        t0 = time.perf_counter()
        sig = template_signature(qtext)
        prepared = self._lookup(qtext, sig)
        if prepared is not None:
            self.metrics.plan_hits += 1
        else:
            self.metrics.plan_misses += 1
            prepared = self._build(qtext, sig)
        binding = prepared.template.binding_for(qtext) \
            if prepared.template.rebindable else None
        res = prepared.run(binding)
        self.metrics.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        self.metrics.served += 1
        self.metrics.rows += len(res)
        if len(res) == 0:
            self.metrics.empties += 1
        plan = getattr(prepared, "plan", None)
        if (plan is not None and plan.empty) or \
                (binding is not None and binding.missing):
            self.metrics.short_circuits += 1
        return res

    def query_batch(self, qtexts: List[str]) -> List[Result]:
        return [self.query(q) for q in qtexts]
