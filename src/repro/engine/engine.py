"""The unified query engine: template LRU cache + backend dispatch.

``Engine`` is the one public execution surface.  It owns

* a real LRU plan cache keyed on the template signature — each entry
  holds the parsed :class:`~repro.engine.template.QueryTemplate` AND the
  backend's :class:`~repro.engine.backends.PreparedQuery`, so a repeated
  templated query is served with zero parsing and zero compilation (the
  constants re-bind as runtime values);
* the statistics short-circuit (provably-empty plans answered without
  touching data, the ST-8 behaviour, visible per request);
* operator metrics: latency percentiles, plan-cache hit rate,
  empty-answer count, rows served.

S2RDF notes that repeated Virtuoso queries benefit from caching while its
own runtimes are stable: here we cache *compilation*, never results.
"""

from __future__ import annotations

import time
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.engine.backends import (
    ExecutionBackend, ExecutionContext, PreparedQuery, create_backend,
)
from repro.engine.result import Result
from repro.engine.template import QueryTemplate, _normalize, template_signature

__all__ = ["Engine", "ServerMetrics", "PlanCache"]


# Latency/queue sample lists keep only the newest window: a long-lived
# server must not grow per-request state without bound, and recent
# samples are what an operator's percentiles should reflect anyway.
_MAX_SAMPLES = 8192


@dataclass
class ServerMetrics:
    served: int = 0
    rows: int = 0
    empties: int = 0          # zero-row answers, however produced
    short_circuits: int = 0   # answered from statistics alone (no data touched)
    # requests served through an eager fallback on a device backend (the
    # prepared query's ``fallback`` flag): silent eager execution was the
    # failure mode that hid the device path's BGP-only coverage
    device_fallbacks: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    latencies_ms: List[float] = field(default_factory=list)
    # micro-batching: one "batch" is one device launch serving B requests
    batches: int = 0          # batched launches executed
    batched_requests: int = 0 # requests served through a batched launch
    padding_slots: int = 0    # slots wasted padding up to a static shape
    queue_ms: List[float] = field(default_factory=list)  # submit -> result

    def record_latency(self, ms: float, count: int = 1) -> None:
        self.latencies_ms.extend([ms] * count)
        if len(self.latencies_ms) > _MAX_SAMPLES:
            del self.latencies_ms[: -_MAX_SAMPLES]

    def record_queue(self, ms: float) -> None:
        self.queue_ms.append(ms)
        if len(self.queue_ms) > _MAX_SAMPLES:
            del self.queue_ms[: -_MAX_SAMPLES]

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        qms = np.asarray(self.queue_ms) if self.queue_ms else np.zeros(1)
        slots = self.batched_requests + self.padding_slots
        return {
            "served": self.served,
            "rows": self.rows,
            "empties": self.empties,
            "short_circuits": self.short_circuits,
            "device_fallbacks": self.device_fallbacks,
            "plan_hit_rate": self.plan_hits / max(self.plan_hits
                                                  + self.plan_misses, 1),
            "p50_ms": float(np.percentile(lat, 50)),
            "p90_ms": float(np.percentile(lat, 90)),
            "p99_ms": float(np.percentile(lat, 99)),
            "batches": self.batches,
            "batched_requests": self.batched_requests,
            # fraction of launched batch slots carrying real requests
            "batch_occupancy": self.batched_requests / max(slots, 1),
            "padding_waste": self.padding_slots / max(slots, 1),
            "queue_p50_ms": float(np.percentile(qms, 50)),
            "queue_p99_ms": float(np.percentile(qms, 99)),
        }


class PlanCache:
    """Bounded LRU: signature -> PreparedQuery.  Replaces the old
    per-signature "presence" dict (which re-parsed unconditionally) and
    the unbounded executor cache."""

    def __init__(self, capacity: int = 512):
        self.capacity = max(1, int(capacity))
        self._data: "OrderedDict[str, PreparedQuery]" = OrderedDict()
        self.evictions = 0

    def get(self, sig: str) -> Optional[PreparedQuery]:
        hit = self._data.get(sig)
        if hit is not None:
            self._data.move_to_end(sig)
        return hit

    def put(self, sig: str, prepared: PreparedQuery) -> None:
        self._data[sig] = prepared
        self._data.move_to_end(sig)
        while len(self._data) > self.capacity:
            self._data.popitem(last=False)
            self.evictions += 1

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, sig: str) -> bool:
        return sig in self._data

    def keys(self):
        return self._data.keys()


class Engine:
    """Execute SPARQL text over a Dataset through one pluggable backend.

    Created via :meth:`repro.engine.dataset.Dataset.engine` (or directly
    from a catalog-bearing dataset).  ``backend`` is a registry key —
    ``"eager"``, ``"jit"``, ``"distributed"``, or anything registered via
    :func:`repro.engine.backends.register_backend`.
    """

    #: Static batch shapes a micro-batch is padded up to.  A small fixed
    #: menu bounds the number of compiled programs per template at
    #: ``len(BATCH_SHAPES)`` while keeping padding waste < 50%.
    BATCH_SHAPES: Tuple[int, ...] = (1, 2, 4, 8, 16, 32)

    def __init__(self, dataset, backend: str = "eager",
                 layout: str = "extvp", mesh=None,
                 plan_cache_size: int = 512,
                 batch_shapes: Optional[Sequence[int]] = None):
        if isinstance(backend, ExecutionBackend):
            self._backend = backend
        else:
            self._backend = create_backend(backend)
        if self._backend.name == "distributed" and mesh is None:
            raise ValueError(
                "distributed backend needs a mesh: pass mesh=jax.make_mesh("
                "(n_devices,), ('data',)) (see docs/serving.md)")
        self.dataset = dataset
        self.layout = layout
        self.ctx = ExecutionContext(catalog=dataset.catalog,
                                    dictionary=dataset.dictionary,
                                    layout=layout, mesh=mesh)
        self.cache = PlanCache(plan_cache_size)
        self.metrics = ServerMetrics()
        shapes = self.BATCH_SHAPES if batch_shapes is None \
            else tuple(batch_shapes)
        if not shapes or min(shapes) < 1:
            raise ValueError("batch_shapes must be positive ints")
        self.batch_shapes: Tuple[int, ...] = tuple(sorted(shapes))

    @property
    def backend(self) -> str:
        return self._backend.name

    # -- compilation ----------------------------------------------------------
    def _lookup(self, qtext: str, sig: str) -> Optional[PreparedQuery]:
        prepared = self.cache.get(sig)
        if prepared is not None:
            return prepared
        # Non-rebindable templates (e.g. a constant in predicate position)
        # are cached under the exact normalized text instead, so identical
        # repeats still skip parsing and compilation.
        return self.cache.get("=" + _normalize(qtext))

    def _build(self, qtext: str, sig: str) -> PreparedQuery:
        try:
            template = QueryTemplate(qtext, self.ctx.dictionary)
        except ValueError:
            # Template substitution produced unparseable text (constants the
            # slot regex cannot lift cleanly); fall back to the concrete
            # query.  A genuinely malformed query raises from .concrete.
            template = None
        if template is None or not template.rebindable:
            template = QueryTemplate.concrete(qtext, self.ctx.dictionary)
        prepared = self._backend.prepare(template, self.ctx)
        self.cache.put(sig if template.rebindable else "=" + _normalize(qtext),
                       prepared)
        return prepared

    def prepare(self, qtext: str) -> PreparedQuery:
        """Prepared form of ``qtext``'s template, from cache if present.
        Cache-hit bookkeeping happens in :meth:`query`; ``prepare`` is the
        silent path for callers managing their own loop."""
        sig = template_signature(qtext)
        prepared = self._lookup(qtext, sig)
        if prepared is not None:
            return prepared
        return self._build(qtext, sig)

    def explain(self, qtext: str) -> str:
        """The compiled plan of ``qtext``'s template (diagnostics)."""
        prepared = self.prepare(qtext)
        plan = getattr(prepared, "plan", None)
        return plan.describe() if plan is not None else "(operator tree)"

    # -- execution ------------------------------------------------------------
    def _lookup_counted(self, qtext: str) -> PreparedQuery:
        sig = template_signature(qtext)
        prepared = self._lookup(qtext, sig)
        if prepared is not None:
            self.metrics.plan_hits += 1
            return prepared
        self.metrics.plan_misses += 1
        return self._build(qtext, sig)

    def _record(self, prepared: PreparedQuery, binding, res: Result) -> None:
        """Per-request result accounting shared by the single-query and
        batched paths."""
        self.metrics.served += 1
        self.metrics.rows += len(res)
        if len(res) == 0:
            self.metrics.empties += 1
        if getattr(prepared, "fallback", False):
            self.metrics.device_fallbacks += 1
        plan = getattr(prepared, "plan", None)
        if (plan is not None and plan.empty) or \
                (binding is not None and binding.missing):
            self.metrics.short_circuits += 1

    def query(self, qtext: str) -> Result:
        t0 = time.perf_counter()
        prepared = self._lookup_counted(qtext)
        binding = prepared.template.binding_for(qtext) \
            if prepared.template.rebindable else None
        res = prepared.run(binding)
        self.metrics.record_latency((time.perf_counter() - t0) * 1e3)
        self._record(prepared, binding, res)
        return res

    # -- batched execution -----------------------------------------------------
    def bucket_shape(self, n: int) -> int:
        """Smallest configured static batch shape holding ``n`` requests
        (``n`` larger than the biggest shape is chunked by the caller)."""
        for s in self.batch_shapes:
            if s >= n:
                return s
        return self.batch_shapes[-1]

    def _run_group(self, prepared: PreparedQuery,
                   bindings: List[Optional[object]]) -> List[Result]:
        """Execute same-template bindings through ``run_batch``, chunked
        at the largest static shape and padded up to the bucket shape (the
        pad repeats a real binding; padded results are dropped).  Backends
        whose ``run_batch`` is the sequential loop are not padded —
        padding only buys something when the batch is one static-shape
        program launch."""
        out: List[Result] = []
        max_shape = self.batch_shapes[-1]
        pad = getattr(prepared, "vectorized_batch", False)
        for start in range(0, len(bindings), max_shape):
            chunk = bindings[start: start + max_shape]
            shape = self.bucket_shape(len(chunk)) if pad else len(chunk)
            padded = chunk + [chunk[-1]] * (shape - len(chunk))
            t0 = time.perf_counter()
            res = prepared.run_batch(padded)
            dt_ms = (time.perf_counter() - t0) * 1e3
            self.metrics.batches += 1
            self.metrics.batched_requests += len(chunk)
            self.metrics.padding_slots += shape - len(chunk)
            # every request in the batch observed the batch's wall time
            self.metrics.record_latency(dt_ms, count=len(chunk))
            out.extend(res[: len(chunk)])
        return out

    def query_batch(self, qtexts: List[str]) -> List[Result]:
        """Execute a list of queries, amortizing device launches: requests
        sharing a prepared template are stacked into one batched program
        execution (see :meth:`PreparedQuery.run_batch`); results come back
        in submission order.  This is the synchronous core the serving
        layer's micro-batcher drains into."""
        results: List[Optional[Result]] = [None] * len(qtexts)
        groups: "OrderedDict[int, Tuple[PreparedQuery, List[int]]]" = \
            OrderedDict()
        for i, qtext in enumerate(qtexts):
            prepared = self._lookup_counted(qtext)
            groups.setdefault(id(prepared), (prepared, []))[1].append(i)
        for prepared, idxs in groups.values():
            bindings = [prepared.template.binding_for(qtexts[i])
                        if prepared.template.rebindable else None
                        for i in idxs]
            group_results = self._run_group(prepared, bindings)
            for i, binding, res in zip(idxs, bindings, group_results):
                results[i] = res
                self._record(prepared, binding, res)
        return results  # type: ignore[return-value]
