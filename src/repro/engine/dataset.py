"""The ``Dataset`` facade: one object owning the whole storage stack.

S2RDF's data layer is a pipeline — dictionary-encode the triples, build
VP tables, semi-join-reduce them into ExtVP with selectivity statistics
(paper §5) — that every entry point used to hand-wire.  ``Dataset`` owns
that pipeline end to end and hands out :class:`~repro.engine.engine.Engine`
instances bound to any registered execution backend.

    # threshold is the paper's SF-threshold τ; 0.25 is the recommended
    # production trade-off (§7.4), 1.0 materializes every reduction.
    ds = Dataset.watdiv(scale=1.0, seed=0, threshold=0.25)
    eng = ds.engine("jit")
    res = eng.query("SELECT * WHERE { ?u wsdbm:follows ?v }")
    res.to_terms()

    # micro-batched: B same-template requests, one program launch
    batch = eng.query_batch([
        "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v }",
        "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v }",
    ])
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.stats import Catalog, build_catalog
from repro.core.vp import KINDS
from repro.engine.engine import Engine
from repro.engine.result import Result

__all__ = ["Dataset"]


@dataclass
class Dataset:
    """A loaded RDF graph: dictionary + TT + VP + ExtVP(τ) + statistics."""

    catalog: Catalog
    dictionary: object = None          # repro.rdf.Dictionary
    schema: object = None              # Optional[WatDivSchema]
    _engines: Dict[tuple, Engine] = field(default_factory=dict, repr=False)

    def __post_init__(self) -> None:
        if self.dictionary is None:
            self.dictionary = self.catalog.dictionary

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_triples(cls, triples: Iterable[Tuple[str, str, str]],
                     threshold: float = 1.0,
                     kinds: Tuple[str, ...] = KINDS,
                     with_extvp: bool = True) -> "Dataset":
        """Build the full store from (s, p, o) string triples."""
        from repro.rdf.dictionary import Dictionary
        d = Dictionary()
        tt = d.encode_triples(triples)
        cat = build_catalog(tt, d, threshold=threshold, kinds=kinds,
                            with_extvp=with_extvp)
        return cls(catalog=cat, dictionary=d)

    @classmethod
    def watdiv(cls, scale: float = 1.0, seed: int = 0,
               threshold: float = 1.0,
               kinds: Tuple[str, ...] = KINDS,
               with_extvp: bool = True) -> "Dataset":
        """Generate a WatDiv-like graph (paper §7) and build its store."""
        from repro.rdf.generator import WatDivConfig, generate_watdiv
        tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=scale,
                                                  seed=seed))
        cat = build_catalog(tt, d, threshold=threshold, kinds=kinds,
                            with_extvp=with_extvp)
        return cls(catalog=cat, dictionary=d, schema=sch)

    @classmethod
    def from_ntriples(cls, path: str, threshold: float = 1.0,
                      kinds: Tuple[str, ...] = KINDS,
                      with_extvp: bool = True) -> "Dataset":
        """Load an N-Triples file (the paper's input format)."""
        from repro.rdf.ntriples import parse_ntriples
        with open(path) as f:
            triples = parse_ntriples(f.read())
        return cls.from_triples(triples, threshold=threshold, kinds=kinds,
                                with_extvp=with_extvp)

    # -- engines --------------------------------------------------------------
    def engine(self, backend: str = "eager", layout: str = "extvp",
               mesh=None, plan_cache_size: int = 512,
               batch_shapes=None) -> Engine:
        """An :class:`Engine` over this dataset.  Engines are cached per
        configuration so repeated calls share plan caches."""
        key = (backend, layout, id(mesh), plan_cache_size,
               None if batch_shapes is None else tuple(batch_shapes))
        eng = self._engines.get(key)
        if eng is None:
            eng = Engine(self, backend=backend, layout=layout, mesh=mesh,
                         plan_cache_size=plan_cache_size,
                         batch_shapes=batch_shapes)
            self._engines[key] = eng
        return eng

    def query(self, qtext: str, backend: str = "eager",
              layout: str = "extvp", mesh=None) -> Result:
        """One-shot convenience: ``ds.engine(backend).query(qtext)``."""
        return self.engine(backend, layout, mesh).query(qtext)

    # -- storage --------------------------------------------------------------
    @property
    def n_triples(self) -> int:
        return self.catalog.n_triples

    def storage_report(self) -> Dict[str, float]:
        return self.catalog.storage_report()
