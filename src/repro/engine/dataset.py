"""The ``Dataset`` facade: one object owning the whole storage stack.

S2RDF's data layer is a pipeline — dictionary-encode the triples, build
VP tables, semi-join-reduce them into ExtVP with selectivity statistics
(paper §5) — that every entry point used to hand-wire.  ``Dataset`` owns
that pipeline end to end and hands out :class:`~repro.engine.engine.Engine`
instances bound to any registered execution backend.

    # threshold is the paper's SF-threshold τ; 0.25 is the recommended
    # production trade-off (§7.4), 1.0 materializes every reduction.
    ds = Dataset.watdiv(scale=1.0, seed=0, threshold=0.25)
    eng = ds.engine("jit")
    res = eng.query("SELECT * WHERE { ?u wsdbm:follows ?v }")
    res.to_terms()

    # micro-batched: B same-template requests, one program launch
    batch = eng.query_batch([
        "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v }",
        "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v }",
    ])

    # persist once, boot forever (repro.store): save() writes the
    # on-disk columnar store, load() memory-maps it lazily — no rebuild
    ds.save("watdiv.store")
    ds = Dataset.load("watdiv.store")
"""

from __future__ import annotations

import os
import time
from dataclasses import dataclass, field
from typing import Dict, Iterable, Optional, Tuple

import numpy as np

from repro.core.stats import Catalog, build_catalog
from repro.core.table import Table
from repro.core.vp import KINDS
from repro.engine.engine import Engine
from repro.engine.result import Result

__all__ = ["Dataset"]


@dataclass
class Dataset:
    """A loaded RDF graph: dictionary + TT + VP + ExtVP(τ) + statistics.

    ``build_backend`` selects the ExtVP construction substrate — the
    ``"numpy"`` host loop, the ``"jax"`` pair-batched device pipeline, or
    the ``"distributed"`` shard_map pair grid (see
    :mod:`repro.core.extvp_build`); all three build byte-identical
    catalogs, and the choice also seeds :meth:`append_triples`.
    """

    catalog: Catalog
    dictionary: object = None          # repro.rdf.Dictionary
    schema: object = None              # Optional[WatDivSchema]
    build_backend: str = "numpy"
    #: directory of the on-disk store this dataset is attached to (set by
    #: :meth:`load` / :meth:`save`); appends journal delta segments there
    store_path: Optional[str] = field(default=None, repr=False)
    _engines: Dict[tuple, Engine] = field(default_factory=dict, repr=False)
    #: accounting of the last append_triples call (pairs reused vs rebuilt)
    last_append_report: Optional[Dict[str, int]] = field(default=None,
                                                         repr=False)

    def __post_init__(self) -> None:
        if self.dictionary is None:
            self.dictionary = self.catalog.dictionary

    # -- constructors ---------------------------------------------------------
    @classmethod
    def from_triples(cls, triples: Iterable[Tuple[str, str, str]],
                     threshold: float = 1.0,
                     kinds: Tuple[str, ...] = KINDS,
                     with_extvp: bool = True,
                     build_backend: str = "numpy",
                     mesh=None) -> "Dataset":
        """Build the full store from (s, p, o) string triples."""
        from repro.rdf.dictionary import Dictionary
        d = Dictionary()
        tt = d.encode_triples(list(triples))
        cat = build_catalog(tt, d, threshold=threshold, kinds=kinds,
                            with_extvp=with_extvp,
                            build_backend=build_backend, mesh=mesh)
        return cls(catalog=cat, dictionary=d, build_backend=build_backend)

    @classmethod
    def watdiv(cls, scale: float = 1.0, seed: int = 0,
               threshold: float = 1.0,
               kinds: Tuple[str, ...] = KINDS,
               with_extvp: bool = True,
               build_backend: str = "numpy",
               mesh=None) -> "Dataset":
        """Generate a WatDiv-like graph (paper §7) and build its store."""
        from repro.rdf.generator import WatDivConfig, generate_watdiv
        tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=scale,
                                                  seed=seed))
        cat = build_catalog(tt, d, threshold=threshold, kinds=kinds,
                            with_extvp=with_extvp,
                            build_backend=build_backend, mesh=mesh)
        return cls(catalog=cat, dictionary=d, schema=sch,
                   build_backend=build_backend)

    @classmethod
    def from_ntriples(cls, path: str, threshold: float = 1.0,
                      kinds: Tuple[str, ...] = KINDS,
                      with_extvp: bool = True,
                      build_backend: str = "numpy",
                      mesh=None) -> "Dataset":
        """Load an N-Triples file (the paper's input format)."""
        from repro.rdf.ntriples import parse_ntriples
        with open(path) as f:
            triples = parse_ntriples(f.read())
        return cls.from_triples(triples, threshold=threshold, kinds=kinds,
                                with_extvp=with_extvp,
                                build_backend=build_backend, mesh=mesh)

    # -- incremental load ------------------------------------------------------
    def append_triples(self, triples: Iterable[Tuple[str, str, str]],
                       build_backend: Optional[str] = None,
                       mesh=None, journal: bool = True) -> Dict[str, int]:
        """Append (s, p, o) string triples and incrementally refresh the
        store: only the VP tables of predicates that received rows are
        rebuilt, and only the ExtVP pairs those predicates touch — or
        whose probe-side entity range the new build keys intersect — are
        re-semi-joined (:func:`repro.core.extvp_build.incremental_pairs`).
        The resulting catalog is equivalent to a from-scratch build over
        the concatenated triples.

        Cached engines are invalidated (their prepared plans scan the old
        tables); re-fetch them via :meth:`engine` afterwards.  Returns the
        pair-accounting report, also kept as ``last_append_report``.

        When the dataset is attached to an on-disk store (``store_path``
        set by :meth:`load` / :meth:`save`), the appended triples are
        additionally journaled as a delta segment so the next
        :meth:`load` replays them through this same incremental path;
        ``journal=False`` suppresses that (used by replay itself).
        ``compact()`` folds accumulated segments back into the base.
        """
        triples = list(triples)
        backend = build_backend or self.build_backend
        cat = self.catalog
        if not triples:
            report = {"pairs": len(cat.extvp.sf), "reused": len(cat.extvp.sf),
                      "range_skipped": 0, "recomputed": 0, "evaluated": 0}
            self.last_append_report = report
            return report
        from repro.core.extvp_build import incremental_pairs
        new_tt = self.dictionary.encode_triples(triples)
        tt = np.concatenate([cat.tt, new_tt])
        touched = {int(p) for p in np.unique(new_tt[:, 1])}

        t0 = time.perf_counter()
        vp = dict(cat.vp)
        for p in sorted(touched):
            rows = new_tt[new_tt[:, 1] == p][:, [0, 2]]
            if p in vp:
                rows = np.concatenate([vp[p].rows, rows])
            vp[p] = Table.from_unsorted(rows)
        # distinct-count statistics: recompute only the touched predicates
        # (their tables are materialized above anyway); catalogs without
        # the stats (version-1 stores) stay without them — back-filling
        # would force-load every lazy table
        distinct_s = distinct_o = m2_s = m2_o = None
        if cat.distinct_s is not None and cat.distinct_o is not None:
            distinct_s, distinct_o = dict(cat.distinct_s), dict(cat.distinct_o)
            for p in touched:
                distinct_s[p] = int(len(vp[p].unique_s))
                distinct_o[p] = int(len(vp[p].unique_o))
        if cat.m2_s is not None and cat.m2_o is not None:
            from repro.core.stats import _m2
            m2_s, m2_o = dict(cat.m2_s), dict(cat.m2_o)
            for p in touched:
                m2_s[p] = _m2(vp[p].rows[:, 0])
                m2_o[p] = _m2(vp[p].rows[:, 1])
        vp_secs = cat.vp_build_seconds + (time.perf_counter() - t0)

        # A store built with with_extvp=False has no pair statistics to
        # extend — keep it ExtVP-less instead of back-filling the schema.
        t0 = time.perf_counter()
        if cat.with_extvp:
            ext, report = incremental_pairs(
                cat.extvp, cat.vp, vp, touched,
                threshold=cat.extvp.threshold, kinds=tuple(cat.extvp.kinds),
                backend=backend, mesh=mesh)
        else:
            from repro.core.vp import ExtVPBuild
            ext = ExtVPBuild(threshold=cat.extvp.threshold,
                             kinds=tuple(cat.extvp.kinds), backend=backend)
            report = {"pairs": 0, "reused": 0, "range_skipped": 0,
                      "recomputed": 0, "evaluated": 0}
        ext.build_seconds = time.perf_counter() - t0
        self.catalog = Catalog(tt=tt, vp=vp, extvp=ext,
                               dictionary=self.dictionary,
                               vp_build_seconds=vp_secs,
                               with_extvp=cat.with_extvp,
                               store=cat.store,
                               distinct_s=distinct_s, distinct_o=distinct_o,
                               m2_s=m2_s, m2_o=m2_o)
        self._engines.clear()
        self.last_append_report = report
        if journal and self.store_path is not None:
            from repro.store import append_segment, delta_stats
            append_segment(self.store_path, triples)
            if self.catalog.store is not None:
                n, nbytes = delta_stats(self.store_path)
                self.catalog.store.delta_segments = n
                self.catalog.store.bytes_by_section["delta"] = nbytes
        return report

    # -- persistence (repro.store) ---------------------------------------------
    def save(self, path: Optional[str] = None) -> str:
        """Persist the catalog as an on-disk columnar store at ``path``
        (defaults to the attached ``store_path``).

        Writes the versioned manifest, the dictionary, and raw
        little-endian column files for TT / every VP table / every
        materialized ExtVP table via the streaming writer
        (:func:`repro.store.write_store`), then clears any delta journal
        at the target — the rewritten base supersedes it.  The dataset
        becomes attached to ``path``: later :meth:`append_triples` calls
        journal there and :meth:`load` restores this exact state.
        """
        path = os.fspath(path) if path is not None else self.store_path
        if path is None:
            raise ValueError("no path: pass save(path) or load the dataset "
                             "from a store first")
        from repro.store import (StoreInfo, clear_segments, section_bytes,
                                 write_store)
        manifest = write_store(self.catalog, self.dictionary, path,
                               build_backend=self.build_backend)
        clear_segments(path)
        self.catalog.store = StoreInfo(
            path=path, bytes_by_section=section_bytes(manifest, path),
            delta_segments=0)
        self.store_path = path
        return path

    @classmethod
    def load(cls, path: str, eager: bool = False, verify: bool = False,
             build_backend: str = "numpy", mesh=None) -> "Dataset":
        """Boot a dataset from an on-disk store — no build pipeline runs.

        The base catalog comes up **lazy and zero-copy** by default:
        only the manifest (statistics + dictionary) is parsed, and each
        table ``np.memmap``-s its column file on first touch.
        ``eager=True`` materializes everything now (benchmarking / tail-
        latency mode); ``verify=True`` CRC-checks each file when it is
        first read.  Any journaled delta segments are then replayed
        through :meth:`append_triples` (the incremental semi-join path),
        so the result is equivalent to the pre-restart catalog.
        """
        from repro.store import load_catalog, read_segments
        path = os.fspath(path)
        cat, dictionary = load_catalog(path, eager=eager, verify=verify)
        ds = cls(catalog=cat, dictionary=dictionary,
                 build_backend=build_backend, store_path=path)
        for seg in read_segments(path):
            ds.append_triples(seg.triples, build_backend=build_backend,
                              mesh=mesh, journal=False)
        return ds

    def compact(self) -> str:
        """Fold the delta journal into the base store: rewrite the full
        columnar base from the current (already replayed/appended)
        catalog and drop the segments.  Restores O(manifest) cold-start
        after a burst of appends."""
        if self.store_path is None:
            raise ValueError("dataset is not attached to a store; "
                             "call save(path) first")
        return self.save(self.store_path)

    # -- engines --------------------------------------------------------------
    def engine(self, backend: str = "eager", layout: str = "extvp",
               mesh=None, plan_cache_size: int = 512,
               batch_shapes=None, runtime=None) -> Engine:
        """An :class:`Engine` over this dataset.  Engines are cached per
        configuration so repeated calls share plan caches.

        ``backend="auto"`` enables the adaptive runtime: the engine
        measures each template on every candidate backend and routes to
        the observed winner (knobs via ``runtime=RuntimeConfig(...)``;
        see docs/serving.md, "Adaptive runtime")."""
        key = (backend, layout, id(mesh), plan_cache_size,
               None if batch_shapes is None else tuple(batch_shapes),
               id(runtime))
        eng = self._engines.get(key)
        if eng is None:
            eng = Engine(self, backend=backend, layout=layout, mesh=mesh,
                         plan_cache_size=plan_cache_size,
                         batch_shapes=batch_shapes, runtime=runtime)
            self._engines[key] = eng
        return eng

    def query(self, qtext: str, backend: str = "eager",
              layout: str = "extvp", mesh=None) -> Result:
        """One-shot convenience: ``ds.engine(backend).query(qtext)``."""
        return self.engine(backend, layout, mesh).query(qtext)

    # -- storage --------------------------------------------------------------
    @property
    def n_triples(self) -> int:
        return self.catalog.n_triples

    def storage_report(self) -> Dict[str, float]:
        return self.catalog.storage_report()
