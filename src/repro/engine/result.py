"""Uniform query-result type for every execution backend.

All engines produce the same logical object — a bag of solution mappings
over id-encoded columns — but historically returned it in three shapes
(``Bindings``, ``(np.ndarray, cols)``, sharded arrays).  ``Result`` wraps
the canonical :class:`~repro.core.executor.Bindings` plus the dictionary
so callers can decode ids back to RDF terms and compare results across
backends under SPARQL bag semantics (column order is presentation, not
identity).
"""

from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.executor import Bindings
from repro.rdf.dictionary import UNBOUND

__all__ = ["Result"]


@dataclass
class Result:
    """A relation over query variables, with optional term decoding."""

    bindings: Bindings
    dictionary: Optional[object] = None   # repro.rdf.Dictionary

    # -- shape ----------------------------------------------------------------
    @property
    def cols(self) -> Tuple[str, ...]:
        return self.bindings.cols

    @property
    def data(self) -> np.ndarray:
        return self.bindings.data

    def __len__(self) -> int:
        return len(self.bindings)

    @staticmethod
    def empty(cols: Sequence[str], dictionary=None) -> "Result":
        return Result(Bindings.empty(tuple(cols)), dictionary)

    # -- views ---------------------------------------------------------------
    def to_numpy(self) -> np.ndarray:
        """The (n, n_vars) int32 id matrix."""
        return self.bindings.data

    def to_terms(self) -> List[Dict[str, str]]:
        """Dictionary-decoded rows: one ``{var: term}`` mapping per
        solution (unbound OPTIONAL slots are omitted)."""
        if self.dictionary is None:
            raise ValueError("Result has no dictionary to decode with")
        out: List[Dict[str, str]] = []
        for row in self.bindings.data.tolist():
            out.append({c: self.dictionary.term_of(int(v))
                        for c, v in zip(self.cols, row) if v != UNBOUND})
        return out

    # -- comparison (SPARQL bag semantics) -----------------------------------
    def as_multiset(self, cols: Optional[Sequence[str]] = None) -> Counter:
        """Bag of solution tuples over ``cols`` (default: sorted columns,
        making the bag independent of backend column order).  Columns in
        ``cols`` the relation does not carry are UNBOUND-filled — a
        variable a backend dropped entirely and one it materialized as
        all-UNBOUND encode the same solution mapping, so both
        canonicalize to the same tuples."""
        order = sorted(self.cols) if cols is None else list(cols)
        n = len(self)
        if not order:
            return Counter({(): n}) if n else Counter()
        arrs = [self.bindings.data[:, self.cols.index(c)] if c in self.cols
                else np.full(n, UNBOUND, dtype=np.int32) for c in order]
        return Counter(map(tuple, np.stack(arrs, axis=1).tolist()))

    def same_as(self, other: "Result") -> bool:
        """Multiset equality under SPARQL bag semantics.  Both sides are
        canonicalized over the UNION of their column sets (missing
        columns are UNBOUND-filled), so rows differing only in
        UNBOUND-vs-missing columns compare equal — previously a result
        binding strictly more (all-UNBOUND) columns was never equal to
        one omitting them, which let left-join tests pass vacuously."""
        cols = sorted(set(self.cols) | set(other.cols))
        return self.as_multiset(cols) == other.as_multiset(cols)
