"""S2RDF reproduction: ExtVP storage + SPARQL engines over JAX.

The public API is the :mod:`repro.engine` facade:

    from repro import Dataset

    ds = Dataset.watdiv(scale=0.5, threshold=0.25)
    res = ds.engine("jit").query("SELECT * WHERE { ?u wsdbm:follows ?v }")

Lower layers (``repro.core``, ``repro.rdf``, ``repro.serve``) remain
importable directly; heavyweight submodules (models, kernels, launch) are
not imported here.
"""

from repro.engine import (
    ConstantBinding, Dataset, Engine, ExecutionBackend, ExecutionContext,
    PreparedQuery, QueryTemplate, Result, ServerMetrics, available_backends,
    create_backend, register_backend, template_signature,
)

__all__ = [
    "Dataset", "Engine", "Result",
    "ExecutionBackend", "ExecutionContext", "PreparedQuery",
    "register_backend", "create_backend", "available_backends",
    "QueryTemplate", "ConstantBinding", "template_signature",
    "ServerMetrics",
]
