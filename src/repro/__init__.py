"""S2RDF reproduction: ExtVP storage + SPARQL engines over JAX.

The public API is the :mod:`repro.engine` facade:

    from repro import Dataset

    ds = Dataset.watdiv(scale=0.5, threshold=0.25)
    res = ds.engine("jit").query("SELECT * WHERE { ?u wsdbm:follows ?v }")

Lower layers (``repro.core``, ``repro.rdf``, ``repro.serve``) remain
importable directly; heavyweight submodules (models, kernels, launch) are
not imported here.
"""

import os as _os

# The XLA CPU "thunk" runtime shipped around jaxlib 0.4.3x miscompiles
# sort→gather chains in the relational programs (a row gather through a
# lexsort permutation returns PAD rows downstream of a cross join;
# verified: results are correct under --xla_cpu_use_thunk_runtime=false
# or --xla_backend_optimization_level=0, wrong otherwise).  Pin the
# legacy CPU runtime before the first backend initialization.  Appending
# respects any user-provided XLA_FLAGS; device backends other than CPU
# ignore this flag.
_flags = _os.environ.get("XLA_FLAGS", "")
if "xla_cpu_use_thunk_runtime" not in _flags:
    _os.environ["XLA_FLAGS"] = \
        (_flags + " --xla_cpu_use_thunk_runtime=false").strip()

from repro.engine import (
    ConstantBinding, Dataset, Engine, ExecutionBackend, ExecutionContext,
    PreparedQuery, QueryTemplate, Result, RuntimeConfig, ServerMetrics,
    available_backends, create_backend, register_backend, template_signature,
)

__all__ = [
    "Dataset", "Engine", "Result",
    "ExecutionBackend", "ExecutionContext", "PreparedQuery",
    "register_backend", "create_backend", "available_backends",
    "QueryTemplate", "ConstantBinding", "template_signature",
    "ServerMetrics", "RuntimeConfig",
]
