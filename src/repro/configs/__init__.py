"""Architecture registry: ``--arch <id>`` resolves here.

``repro.configs.get(name)`` returns the full :class:`ArchConfig`;
``get_reduced(name)`` the CPU-smoke-test-sized variant of the same
family.  ``s2rdf`` is the paper's own engine configuration (not an LM).
"""

from __future__ import annotations

from typing import Dict, List

from repro.models.config import ArchConfig

from repro.configs.qwen1_5_0_5b import CONFIG as _qwen
from repro.configs.gemma3_12b import CONFIG as _gemma3
from repro.configs.mistral_nemo_12b import CONFIG as _nemo
from repro.configs.granite_3_2b import CONFIG as _granite
from repro.configs.granite_moe_1b import CONFIG as _granite_moe
from repro.configs.deepseek_moe_16b import CONFIG as _deepseek
from repro.configs.jamba_1_5_large import CONFIG as _jamba
from repro.configs.whisper_small import CONFIG as _whisper
from repro.configs.llava_next_34b import CONFIG as _llava
from repro.configs.mamba2_370m import CONFIG as _mamba2

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        _qwen, _gemma3, _nemo, _granite, _granite_moe,
        _deepseek, _jamba, _whisper, _llava, _mamba2,
    ]
}


def names() -> List[str]:
    return list(ARCHS)


def get(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return ARCHS[name]


def get_reduced(name: str, **overrides) -> ArchConfig:
    return get(name).reduced(**overrides)
