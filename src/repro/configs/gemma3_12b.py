"""gemma3-12b [dense] — 48L d3840 16H (GQA kv=8) ff15360 vocab 262144,
5:1 local:global interleave (sliding window 1024), 128k context.
[hf:google/gemma-3-12b-pt; unverified]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv=8, d_ff=15360,
    vocab=262144, rope_theta=1e6, sliding_window=1024,
    # period-6 group: 5 sliding-window layers then 1 global layer
    group_pattern=(
        ("attn_local", "dense"), ("attn_local", "dense"),
        ("attn_local", "dense"), ("attn_local", "dense"),
        ("attn_local", "dense"), ("attn", "dense"),
    ),
    tie_embeddings=True,
)
