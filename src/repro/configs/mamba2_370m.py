"""mamba2-370m [ssm] — 48L d1024, attention-free SSD blocks
(d_state 128, headdim 64, expand 2 → d_inner 2048, 32 ssm heads),
vocab 50280.  [arXiv:2405.21060]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=16, n_kv=16, d_ff=0,
    vocab=50280,
    group_pattern=(("mamba", "none"),),
    ssm_expand=2, ssm_state=128, ssm_headdim=64, ssm_chunk=256,
    tie_embeddings=True,
    subquadratic=True,
    # §Perf: 370M params replicate comfortably; DP-only decode removes the
    # per-token model-axis collectives entirely (3.4x latency bound,
    # EXPERIMENTS.md §Perf) — measured harmful for qwen-0.5b (fp32 param
    # re-reads dominate), so set per-arch, not globally.
    dp_only_decode=True,
)
