"""llava-next-34b [vlm] — 60L d7168 56H (GQA kv=8) ff20480 vocab 64000
text backbone; anyres vision tiling is a STUB (input_specs provides
precomputed patch embeddings, 576 base patches).
[hf:llava-hf/llava-v1.6-34b-hf; unverified]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv=8, d_ff=20480,
    vocab=64000, head_dim=128, rope_theta=5e6,
    group_pattern=(("attn", "dense"),),
    vlm=True, n_patches=576,
    tie_embeddings=False,
)
