"""whisper-small [audio] — enc-dec 12+12L d768 12H (kv=12) ff3072
vocab 51865; conv/mel frontend is a STUB (input_specs provides
precomputed frame embeddings, n_frames=1500).  [arXiv:2212.04356]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="audio",
    n_layers=12, d_model=768, n_heads=12, n_kv=12, d_ff=3072,
    vocab=51865, qkv_bias=True, rope_theta=1e4,
    group_pattern=(("attn", "dense"),),
    enc_dec=True, n_enc_layers=12, n_frames=1500,
    tie_embeddings=True,
)
