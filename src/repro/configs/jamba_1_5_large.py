"""jamba-1.5-large-398b [hybrid] — 72L d8192 64H (GQA kv=8) ff24576,
Mamba:attention 7:1 interleave, MoE 16 experts top-2 on every other
layer, vocab 65536.  [arXiv:2403.19887]

Period-8 group: attention at in-block index 4, MoE at odd indices —
9 groups × 8 layers = 72.  Mamba settings follow the Jamba paper
(d_state 16, headdim 64, expand 2 → d_inner 16384, 256 ssm heads)."""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv=8, d_ff=24576,
    vocab=65536, head_dim=128, rope_theta=1e4,
    group_pattern=(
        ("mamba", "dense"), ("mamba", "moe"),
        ("mamba", "dense"), ("mamba", "moe"),
        ("attn", "dense"), ("mamba", "moe"),
        ("mamba", "dense"), ("mamba", "moe"),
    ),
    moe=MoEConfig(n_experts=16, top_k=2, d_ff_expert=24576),
    ssm_expand=2, ssm_state=16, ssm_headdim=64, ssm_chunk=256,
    tie_embeddings=False,
    subquadratic=True,
)
