"""granite-3-2b [dense] — 40L d2048 32H (GQA kv=8) ff8192 vocab 49155.
[hf:ibm-granite/granite-3.0-2b-base]"""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv=8, d_ff=8192,
    vocab=49155, rope_theta=1e4,
    group_pattern=(("attn", "dense"),),
    tie_embeddings=True,
)
