"""deepseek-moe-16b [moe] — 28L d2048 16H (kv=16), fine-grained MoE:
64 routed experts top-6 + 2 shared experts (ff 1408 each), dense first
layer (ff 10944), vocab 102400.  [arXiv:2401.06066]"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=10944,
    vocab=102400, rope_theta=1e4,
    group_pattern=(("attn", "moe"),),
    first_layer_override=("attn", "dense"),   # DeepSeekMoE keeps layer 0 dense
    moe=MoEConfig(n_experts=64, top_k=6, d_ff_expert=1408,
                  n_shared=2, d_ff_shared=1408),
    tie_embeddings=False,
)
