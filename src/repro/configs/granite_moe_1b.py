"""granite-moe-1b-a400m [moe] — 24L d1024 16H (GQA kv=8), MoE 32 experts
top-8, expert ff 512, vocab 49155.  [hf:ibm-granite/granite-3.0-1b-a400m-base]"""

from repro.models.config import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv=8, d_ff=512,
    vocab=49155, rope_theta=1e4,
    group_pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
    tie_embeddings=True,
)
