"""Pallas TPU kernel: shuffle-bucket histogram.

The distributed engine's repartition step (core/distributed.py) needs
per-destination counts (``dest = key % n_shards``) to size its all_to_all
buckets and detect overflow.  As with the join kernels, the TPU-natural
shape is a tiled broadcast-compare: each program takes a (1, TILE) key
block and produces the (1, NB) partial histogram via a (TILE, NB)
equality compare summed over lanes, accumulated across the key grid into
the single output block.

Padding: invalid keys are PROBE_PAD (2^31 - 1); ``PAD % n_buckets`` would
alias a real bucket, so the kernel masks pads explicitly before counting.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["bucket_count_kernel", "bucket_count_pallas", "TILE"]

TILE = 1024
PAD = np.int32(2**31 - 1)


def bucket_count_kernel(keys_ref, out_ref, *, n_buckets: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    keys = keys_ref[...]                          # (1, TILE)
    valid = keys != PAD
    dest = jnp.where(valid, keys % n_buckets, n_buckets)
    buckets = jnp.arange(n_buckets, dtype=jnp.int32)
    hits = (dest[0, :, None] == buckets[None, :]).astype(jnp.int32)
    out_ref[...] = out_ref[...] + jnp.sum(hits, axis=0)[None, :]


@functools.partial(jax.jit, static_argnames=("n_buckets", "interpret"))
def bucket_count_pallas(keys: jax.Array, n_buckets: int,
                        interpret: bool = True) -> jax.Array:
    """Histogram of keys % n_buckets over non-PAD keys; len(keys) must be
    a TILE multiple (callers pad with PAD)."""
    n = keys.shape[0]
    assert n % TILE == 0, n
    grid = (n // TILE,)
    out = pl.pallas_call(
        functools.partial(bucket_count_kernel, n_buckets=n_buckets),
        grid=grid,
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, n_buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, n_buckets), jnp.int32),
        interpret=interpret,
    )(keys.reshape(n // TILE, TILE))
    return out[0]
