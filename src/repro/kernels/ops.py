"""Public jit'd wrappers for the Pallas kernels.

Handles the padding / sentinel conventions so callers pass ragged int32
key arrays:

* probe side padded with ``PROBE_PAD = 2^31 - 1``
* build side padded with ``BUILD_PAD = 2^31 - 2``

The two sentinels differ, so padded lanes never produce false matches,
and ``BUILD_PAD`` sorts above every valid id, so a padded build side stays
ascending.  On CPU the kernels run in ``interpret=True`` mode (Python
execution of the kernel body — correct but slow); on TPU they compile.
Set ``use_pallas(False)`` to route everything through the pure-jnp refs
(the default on CPU for speed; tests exercise both paths explicitly).
The ``REPRO_USE_PALLAS=1`` environment variable flips the default at
import time — CI's ``tests-pallas`` job uses it to run the kernel and
build suites end-to-end on the Pallas interpret path.
"""

from __future__ import annotations

import functools
import os
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import bucketcount, mergejoin, ref, semijoin

__all__ = ["semijoin_mask", "join_probe", "bucket_count", "use_pallas",
           "pallas_enabled", "PROBE_PAD", "BUILD_PAD"]

PROBE_PAD = np.int32(2**31 - 1)
BUILD_PAD = np.int32(2**31 - 2)

# CPU default: jnp reference path (REPRO_USE_PALLAS=1 opts in to Pallas)
_STATE = {"use_pallas": os.environ.get("REPRO_USE_PALLAS", "0") == "1"}


def use_pallas(enabled: bool) -> None:
    _STATE["use_pallas"] = enabled


def pallas_enabled() -> bool:
    return _STATE["use_pallas"]


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, mult: int, fill) -> jax.Array:
    n = x.shape[0]
    rem = (-n) % mult
    if rem == 0 and n > 0:
        return x
    return jnp.concatenate([x, jnp.full((max(rem, mult if n == 0 else rem),),
                                        fill, x.dtype)])


@functools.partial(jax.jit, static_argnames=("force_pallas",))
def semijoin_mask(probe: jax.Array, build_sorted: jax.Array,
                  force_pallas: bool = False) -> jax.Array:
    """mask[i] = probe[i] ∈ build_sorted (int32 0/1), any lengths ≥ 0."""
    if not (force_pallas or _STATE["use_pallas"]):
        return ref.semijoin_membership_ref(probe, build_sorted)
    n = probe.shape[0]
    a = _pad_to(probe.astype(jnp.int32), semijoin.TILE_A, PROBE_PAD)
    b = _pad_to(build_sorted.astype(jnp.int32), semijoin.TILE_B, BUILD_PAD)
    out = semijoin.semijoin_membership_pallas(a, b, interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("force_pallas",))
def join_probe(probe: jax.Array, build_sorted: jax.Array,
               force_pallas: bool = False) -> Tuple[jax.Array, jax.Array]:
    """(lo, cnt) per probe key against the ascending build side."""
    if not (force_pallas or _STATE["use_pallas"]):
        return ref.join_probe_ref(probe, build_sorted)
    n = probe.shape[0]
    n_b = build_sorted.shape[0]
    a = _pad_to(probe.astype(jnp.int32), mergejoin.TILE_A, PROBE_PAD)
    b = _pad_to(build_sorted.astype(jnp.int32), mergejoin.TILE_B, BUILD_PAD)
    lo, cnt = mergejoin.join_probe_pallas(a, b, interpret=_interpret())
    # padded build rows sort above all valid keys; they can inflate lo only
    # for probe keys >= BUILD_PAD (i.e. probe pads), which callers discard.
    return jnp.minimum(lo[:n], n_b), cnt[:n]


@functools.partial(jax.jit, static_argnames=("n_buckets", "force_pallas"))
def bucket_count(keys: jax.Array, valid: jax.Array, n_buckets: int,
                 force_pallas: bool = False) -> jax.Array:
    """Histogram of keys % n_buckets over valid rows (shuffle planning)."""
    if not (force_pallas or _STATE["use_pallas"]):
        return ref.bucket_count_ref(keys, valid, n_buckets)
    masked = jnp.where(valid, keys.astype(jnp.int32), PROBE_PAD)
    padded = _pad_to(masked, bucketcount.TILE, PROBE_PAD)
    return bucketcount.bucket_count_pallas(padded, n_buckets,
                                           interpret=_interpret())
