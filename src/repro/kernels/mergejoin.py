"""Pallas TPU kernel: sort-merge join probe (lower-bound + match count).

The join expansion in :mod:`repro.core.jexec` needs, per probe key,
``lo[i] = #{b < a_i}`` (the lower-bound rank into the sorted build side)
and ``cnt[i] = #{b == a_i}``.  XLA lowers ``jnp.searchsorted`` to a
33-step while-loop of dynamic-slices per key — serial, gather-bound and
hostile to the VPU.  This kernel instead computes both quantities as
*tiled compare-and-reduce sums*:

    lo[i]  = Σ_tiles Σ_j (b_j <  a_i)
    cnt[i] = Σ_tiles Σ_j (b_j == a_i)

over the same (A_tiles × B_tiles) grid as the semi-join kernel, with the
same sorted-tile short-cuts: a build tile entirely below the probe tile
contributes the scalar TB to every lo[i] (no vector compare); a build
tile entirely above contributes nothing; only diagonal-band tiles do the
(TA, TB) VPU compare.  Effective vector work is O(diag · TA · TB), i.e.
linear in the input for sorted inputs, while staying branch-free inside
each program.

Padding: probe pads are 2^31-1, build pads 2^31-2, so pad counts never
contaminate valid lanes (build pads are never < or == a valid probe key,
and probe-pad lanes are discarded by the caller).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["join_probe_kernel", "join_probe_pallas", "TILE_A", "TILE_B"]

TILE_A = 1024
TILE_B = 512


def join_probe_kernel(a_ref, b_ref, lo_ref, cnt_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        lo_ref[...] = jnp.zeros_like(lo_ref)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    a = a_ref[...]            # (1, TA) (any order)
    b = b_ref[...]            # (1, TB) ascending
    a_lo, a_hi = jnp.min(a), jnp.max(a)   # probe tile need not be sorted
    b_lo, b_hi = b[0, 0], b[0, -1]        # build side is globally ascending

    below = b_hi < a_lo       # whole build tile strictly below probe tile

    @pl.when(below)
    def _all_below():
        lo_ref[...] = lo_ref[...] + jnp.int32(b.shape[1])

    overlap = jnp.logical_and(jnp.logical_not(below), b_lo <= a_hi)

    @pl.when(overlap)
    def _compare():
        av = a[0, :, None]                     # (TA, 1)
        bv = b[0, None, :]                     # (1, TB)
        lt = (bv < av).astype(jnp.int32)       # (TA, TB)
        eq = (bv == av).astype(jnp.int32)
        lo_ref[...] = lo_ref[...] + jnp.sum(lt, axis=1)[None, :]
        cnt_ref[...] = cnt_ref[...] + jnp.sum(eq, axis=1)[None, :]
    # (b_lo > a_hi): contributes nothing — fall through


@functools.partial(jax.jit, static_argnames=("interpret",))
def join_probe_pallas(probe: jax.Array, build: jax.Array,
                      interpret: bool = True):
    """Returns (lo, cnt) int32 arrays, shapes == probe.  Build ascending,
    probe any order, tile-aligned (ops.py pads)."""
    n_a, n_b = probe.shape[0], build.shape[0]
    assert n_a % TILE_A == 0 and n_b % TILE_B == 0, (n_a, n_b)
    grid = (n_a // TILE_A, n_b // TILE_B)

    lo, cnt = pl.pallas_call(
        join_probe_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_A), lambda i, j: (i, 0)),
            pl.BlockSpec((1, TILE_B), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE_A), lambda i, j: (i, 0)),
            pl.BlockSpec((1, TILE_A), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_a // TILE_A, TILE_A), jnp.int32),
            jax.ShapeDtypeStruct((n_a // TILE_A, TILE_A), jnp.int32),
        ],
        interpret=interpret,
    )(probe.reshape(n_a // TILE_A, TILE_A),
      build.reshape(n_b // TILE_B, TILE_B))
    return lo.reshape(n_a), cnt.reshape(n_a)
