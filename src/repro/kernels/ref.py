"""Pure-jnp oracles for the Pallas kernels.

These define the exact semantics the kernels must match (asserted with
``assert_allclose`` across shape/dtype sweeps in tests/test_kernels.py).
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = ["semijoin_membership_ref", "join_probe_ref", "bucket_count_ref"]


def semijoin_membership_ref(probe: jnp.ndarray, build_sorted: jnp.ndarray) -> jnp.ndarray:
    """mask[i] = probe[i] ∈ build_sorted  (int32 0/1).

    ``build_sorted`` must be ascending.  Padding convention: PAD values on
    either side never match because the two sides use distinct pad
    sentinels (2^31-1 for probe, 2^31-2 for build).
    """
    lo = jnp.searchsorted(build_sorted, probe, side="left")
    hi = jnp.searchsorted(build_sorted, probe, side="right")
    return (hi > lo).astype(jnp.int32)


def join_probe_ref(probe: jnp.ndarray, build_sorted: jnp.ndarray):
    """(lo, cnt): lower-bound index and match count of each probe key in the
    sorted build side — the two arrays the sort-merge join expansion needs."""
    lo = jnp.searchsorted(build_sorted, probe, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(build_sorted, probe, side="right").astype(jnp.int32)
    return lo, hi - lo


def bucket_count_ref(keys: jnp.ndarray, valid: jnp.ndarray, n_buckets: int):
    """Histogram of keys % n_buckets over valid rows (shuffle planning)."""
    dest = jnp.where(valid, keys.astype(jnp.uint32) % n_buckets, n_buckets)
    return jnp.bincount(dest, length=n_buckets + 1)[:n_buckets].astype(jnp.int32)
