"""Pallas TPU kernel: sorted semi-join membership.

The ExtVP builder and the on-the-fly semi-join reducer both need
``mask[i] = probe[i] ∈ build`` over sorted int32 key columns.  On GPU one
would hash-probe; on TPU the VPU (8×128 vector unit) makes *tiled
broadcast-compare* the natural shape:

  grid = (A_tiles, B_tiles); each program compares one probe tile
  (TA keys, held in VMEM as an (8, TA/8)-packed block) against one build
  tile (TB keys) with a (TA, TB) vectorized equality, reducing along TB
  with a logical-any into the output block (revisited across the B grid
  dimension — first iteration initializes, later ones OR-accumulate).

Both sides are ascending, so a (min, max)-disjoint tile pair contributes
nothing; the kernel still *loads* the block (BlockSpec pipelining is
unconditional) but skips the O(TA·TB) compare via ``pl.when`` — on real
hardware that removes ~all vector work for the off-diagonal of the grid,
making effective cost O(A·TB + B·TA) instead of O(A·B).

VMEM budget per program: TA·4 + TB·4 + TA·TB/8 (bool) bytes
≈ 4 KiB + 2 KiB + 64 KiB for TA=1024, TB=512 — comfortably inside the
~16 MiB/core VMEM of TPU v5e.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["semijoin_membership_kernel", "semijoin_membership_pallas",
           "TILE_A", "TILE_B"]

TILE_A = 1024
TILE_B = 512


def semijoin_membership_kernel(a_ref, b_ref, out_ref):
    """One (probe-tile, build-tile) cell of the sweep."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    a = a_ref[...]            # (1, TA) int32 (any order)
    b = b_ref[...]            # (1, TB) int32, ascending

    a_lo, a_hi = jnp.min(a), jnp.max(a)   # probe tile need not be sorted
    b_lo, b_hi = b[0, 0], b[0, -1]        # build side is globally ascending
    overlap = jnp.logical_and(b_lo <= a_hi, a_lo <= b_hi)

    @pl.when(overlap)
    def _compare():
        eq = a[0, :, None] == b[0, None, :]          # (TA, TB) VPU compare
        hit = jnp.any(eq, axis=1).astype(jnp.int32)  # (TA,)
        out_ref[...] = jnp.maximum(out_ref[...], hit[None, :])


@functools.partial(jax.jit, static_argnames=("interpret",))
def semijoin_membership_pallas(probe: jax.Array, build: jax.Array,
                               interpret: bool = True) -> jax.Array:
    """mask[i] = probe[i] ∈ build.  Build ascending; probe any order;
    lengths multiples of the tile sizes (ops.py pads).  int32 in/out."""
    n_a, n_b = probe.shape[0], build.shape[0]
    assert n_a % TILE_A == 0 and n_b % TILE_B == 0, (n_a, n_b)
    grid = (n_a // TILE_A, n_b // TILE_B)

    return pl.pallas_call(
        semijoin_membership_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TILE_A), lambda i, j: (i, 0)),
            pl.BlockSpec((1, TILE_B), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, TILE_A), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_a // TILE_A, TILE_A), jnp.int32),
        interpret=interpret,
    )(probe.reshape(n_a // TILE_A, TILE_A),
      build.reshape(n_b // TILE_B, TILE_B)).reshape(n_a)
