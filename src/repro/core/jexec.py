"""Static-shape jitted executor — the device (TPU) path of the engine.

XLA requires static shapes, so every relation is a fixed-capacity buffer
``(data[cap, k], n)`` with PAD rows past ``n``; every operator returns an
overflow flag when a capacity would have been exceeded and the host re-runs
the plan with doubled capacities (the standard static-buffer serving
pattern).  Capacities are seeded from the catalog's ExtVP statistics — the
same statistics the paper uses for join ordering — so overflows are rare.

Join algorithm: sort-merge.  The probe side is key-sorted (XLA sort), the
build side binary-searched (``jnp.searchsorted``), match counts expanded
into output slots by a rank-search over the exclusive prefix sum.  All
steps are O(n log n) vectorized primitives that map to TPU-friendly sort /
gather / compare units — this is where the Pallas kernels of
:mod:`repro.kernels` plug in for the probe phase.

Join keys are single int32 columns (the first shared variable); any
further shared variables are post-filtered after expansion — BGP joins
share one variable in the overwhelming majority of cases (star/chain
joins), and this keeps the engine int32-only (x64 mode stays off for the
LM substrate).  Sentinels keep padded/NULL rows unmatched: probe-side pads
→ ``A_SENT``, build-side pads → ``B_SENT`` (distinct, sort-max), UNBOUND
values → per-side negative sentinels.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algebra import BoolOp, Bound, Cmp, FilterExpr, NotExpr, is_var
from repro.core.compiler import Plan, ScanStep
from repro.core.modifiers import (
    ModifierSpine, filter_const_slots, filter_variables,
)
from repro.core.stats import Catalog
from repro.core.table import round_up_pow2
from repro.rdf.dictionary import PAD, UNBOUND

__all__ = ["JBindings", "PlanExecutor", "device_join", "device_scan",
           "device_scan_windowed", "build_key", "bounds_from_plan",
           "trace_count", "device_filter", "device_project",
           "device_distinct", "device_order", "device_slice"]

A_SENT = np.int32(2**31 - 1)   # probe-side padded-row key (== PAD)
B_SENT = np.int32(2**31 - 2)   # build-side padded-row key (sort-max, != A_SENT)
A_NULL = np.int32(-3)          # probe-side UNBOUND key
B_NULL = np.int32(-5)          # build-side UNBOUND key


@dataclass
class JBindings:
    """Static-shape relation: cols are trace-time metadata."""

    cols: Tuple[str, ...]
    data: jax.Array          # (cap, k) int32
    n: jax.Array             # () int32
    overflow: jax.Array      # () bool — sticky across operators

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


def _valid_mask(cap: int, n: jax.Array) -> jax.Array:
    return jnp.arange(cap, dtype=jnp.int32) < n


def _compact(data: jax.Array, keep: jax.Array, out_cap: int,
             fill: int = PAD) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Move keep-rows to the front (stable); returns (data, n, overflow)."""
    cap = data.shape[0]
    n_keep = jnp.sum(keep, dtype=jnp.int32)
    order = jnp.argsort(~keep, stable=True)           # keeps first
    gathered = data[order]
    if out_cap < cap:
        gathered = gathered[:out_cap]
    elif out_cap > cap:
        padrows = jnp.full((out_cap - cap, data.shape[1]), fill, jnp.int32)
        gathered = jnp.concatenate([gathered, padrows], axis=0)
    mask = _valid_mask(out_cap, n_keep)
    gathered = jnp.where(mask[:, None], gathered, fill)
    return gathered, jnp.minimum(n_keep, out_cap), n_keep > out_cap


def device_scan(rows: jax.Array, n: jax.Array, s_bound,
                o_bound, same_var: bool,
                out_cols: Sequence[int], out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Select + project one (s, o) table (Algorithm 2, device form).

    ``s_bound``/``o_bound`` are ``None`` (statically unbound) or an int32
    scalar — python int or traced value.  Passing bound constants as
    traced runtime values is what lets one compiled program serve every
    instantiation of a query template (constant re-binding)."""
    cap = rows.shape[0]
    keep = _valid_mask(cap, n)
    if s_bound is not None:
        keep &= rows[:, 0] == s_bound
    if o_bound is not None:
        keep &= rows[:, 1] == o_bound
    if same_var:
        keep &= rows[:, 0] == rows[:, 1]
    projected = rows[:, list(out_cols)] if out_cols else rows[:, :0]
    return _compact(projected, keep, out_cap)


def build_key(b: JBindings, key_col: int) -> jax.Array:
    """The build-side join-key column with NULL/pad sentinels applied —
    the input of the build-side sort.  Exposed so a batched program can
    presort a *shared* (bounds-independent) build relation once and reuse
    it for every batch element (see ``device_join``'s ``b_presorted``)."""
    kb = b.data[:, key_col]
    kb = jnp.where(kb == UNBOUND, B_NULL, kb)
    return jnp.where(_valid_mask(b.capacity, b.n), kb, B_SENT)


def device_scan_windowed(rows: jax.Array, n: jax.Array, s_bound,
                         out_cols: Sequence[int],
                         out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bound-subject scan over a subject-sorted table: the matching rows
    are one contiguous window found by binary search and need no compact
    sort, so the cost is O(log T + out_cap) instead of the full-table
    mask-and-compact of :func:`device_scan` — the difference between a
    per-request scan and a per-batch-element scan being effectively free.
    PAD rows sort after every valid id, so the search never needs the
    valid count.  Only usable without an object post-filter: overflow is
    the raw window width vs ``out_cap``, which for a filtered scan would
    be conservative (a hub subject with a selective object filter would
    permanently inflate the step's capacity — callers route that case to
    :func:`device_scan`, which counts true matches)."""
    cap = rows.shape[0]
    col = rows[:, 0]
    sb = jnp.asarray(s_bound, dtype=jnp.int32)
    lo = jnp.searchsorted(col, sb, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(col, sb, side="right").astype(jnp.int32)
    idx = lo + jnp.arange(out_cap, dtype=jnp.int32)
    keep = idx < hi
    g = rows[jnp.clip(idx, 0, cap - 1)]
    projected = g[:, list(out_cols)] if out_cols else g[:, :0]
    data = jnp.where(keep[:, None], projected, PAD)
    return data, jnp.minimum(hi - lo, out_cap), hi - lo > out_cap


def device_join(a: JBindings, b: JBindings, out_cap: int,
                b_presorted: Optional[Tuple[jax.Array, jax.Array]] = None
                ) -> JBindings:
    """Natural join of two static relations (sort-merge, rank expansion).

    ``b_presorted`` is an optional ``(order_b, kb_sorted)`` pair from
    :func:`build_key` + sort, letting callers hoist the O(n log n)
    build-side sort out of a vmapped batch when ``b`` does not depend on
    the bound constants."""
    shared = [c for c in a.cols if c in b.cols]
    b_only = [c for c in b.cols if c not in a.cols]
    out_cols = a.cols + tuple(b_only)

    cap_a, cap_b = a.capacity, b.capacity
    if not shared:  # cross join (rare; bounded by caps)
        ii = jnp.arange(out_cap, dtype=jnp.int32)
        a_idx = ii // jnp.maximum(b.n, 1)
        b_idx = ii % jnp.maximum(b.n, 1)
        total = a.n * b.n
        valid = ii < total
        data = jnp.concatenate(
            [a.data[jnp.clip(a_idx, 0, cap_a - 1)],
             b.data[jnp.clip(b_idx, 0, cap_b - 1)]], axis=1)
        data = jnp.where(valid[:, None], data, PAD)
        return JBindings(out_cols, data, jnp.minimum(total, out_cap).astype(jnp.int32),
                         a.overflow | b.overflow | (total > out_cap))

    ka = a.data[:, a.cols.index(shared[0])]
    ka = jnp.where(ka == UNBOUND, A_NULL, ka)
    ka = jnp.where(_valid_mask(cap_a, a.n), ka, A_SENT)
    if b_presorted is None:
        kb = build_key(b, b.cols.index(shared[0]))
        order_b = jnp.argsort(kb).astype(jnp.int32)
        kb_sorted = kb[order_b]
    else:
        order_b, kb_sorted = b_presorted
    lo = jnp.searchsorted(kb_sorted, ka, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(kb_sorted, ka, side="right").astype(jnp.int32)
    cnt = hi - lo
    prefix = jnp.cumsum(cnt) - cnt               # exclusive prefix
    total = prefix[-1] + cnt[-1]

    j = jnp.arange(out_cap, dtype=jnp.int32)
    # rank search: which probe row produced output slot j
    a_idx = jnp.searchsorted(prefix + cnt, j, side="right").astype(jnp.int32)
    a_idx = jnp.clip(a_idx, 0, cap_a - 1)
    off = j - prefix[a_idx]
    b_pos = jnp.clip(lo[a_idx] + off, 0, cap_b - 1).astype(jnp.int32)
    b_idx = order_b[b_pos]
    valid = j < total

    left = a.data[a_idx]
    right = b.data[b_idx]

    # post-filter shared columns beyond the key (SQL NULL semantics)
    for c in shared[1:]:
        va = left[:, a.cols.index(c)]
        vb = right[:, b.cols.index(c)]
        valid &= (va == vb) & (va != UNBOUND)

    pieces = [left]
    if b_only:
        pieces.append(right[:, [b.cols.index(c) for c in b_only]])
    data = jnp.concatenate(pieces, axis=1)
    if shared[1:]:
        data, n, ovf = _compact(data, valid, out_cap)
    else:
        # single shared variable (the overwhelmingly common star/chain
        # case): rank expansion emits matches contiguously at j < total,
        # so masking replaces the O(out_cap log out_cap) compact sort
        data = jnp.where(valid[:, None], data, PAD)
        n = jnp.minimum(total, out_cap).astype(jnp.int32)
        ovf = jnp.asarray(False)
    return JBindings(out_cols, data, n,
                     a.overflow | b.overflow | ovf | (total > out_cap))


# ---------------------------------------------------------------------------
# Solution modifiers on device (the spine of repro.core.modifiers)
#
# All five operators keep the JBindings invariant — valid rows occupy
# [0, n) contiguously with PAD rows behind — and none can overflow (a
# modifier never grows the relation), so the per-step overflow/retry
# protocol of the scan/join pipeline is untouched.
# ---------------------------------------------------------------------------

def _filter_operand(b: JBindings, values: jax.Array, term, numeric: bool,
                    fconsts: jax.Array, ctr: List[int]):
    """(ids, numeric values) for one comparison operand.  Constant ids
    are *runtime* scalars read from ``fconsts`` (slot order fixed by
    :func:`repro.core.modifiers.filter_const_slots`), so re-binding a
    template constant never re-traces; float literals are trace-time
    constants (they are part of the template text)."""
    cap = b.capacity
    nv = values.shape[0]
    if isinstance(term, str):            # variable
        ids = b.data[:, b.cols.index(term)]
        if not numeric:
            return ids, None
        if nv:
            safe = jnp.clip(ids, 0, nv - 1)
            val = jnp.where(ids >= 0, values[safe], jnp.nan)
        else:
            val = jnp.full((cap,), jnp.nan, values.dtype)
        return ids, val
    if isinstance(term, float):          # numeric literal
        return None, jnp.full((cap,), term, values.dtype)
    tid = fconsts[ctr[0]]                # constant id -> runtime slot
    ctr[0] += 1
    ids = jnp.full((cap,), tid, jnp.int32)
    if not numeric:
        return ids, None
    if nv:
        ok = (tid >= 0) & (tid < nv)
        v = jnp.where(ok, values[jnp.clip(tid, 0, nv - 1)], jnp.nan)
    else:
        v = jnp.asarray(jnp.nan, values.dtype)
    return ids, jnp.full((cap,), v, values.dtype)


def _filter_mask(expr: FilterExpr, b: JBindings, values: jax.Array,
                 fconsts: jax.Array, ctr: List[int]) -> jax.Array:
    """Boolean keep-mask over the relation's rows; mirrors the eager
    :func:`repro.core.executor.eval_filter` semantics exactly (identity
    comparison on ids, numeric comparison through the dictionary value
    table, UNBOUND/type-error rows dropped)."""
    if isinstance(expr, BoolOp):
        masks = [_filter_mask(e, b, values, fconsts, ctr) for e in expr.args]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if expr.op == "&&" else (out | m)
        return out
    if isinstance(expr, NotExpr):
        return ~_filter_mask(expr.arg, b, values, fconsts, ctr)
    if isinstance(expr, Bound):
        return b.data[:, b.cols.index(expr.var)] != UNBOUND
    assert isinstance(expr, Cmp)
    numeric = expr.op in ("<", "<=", ">", ">=") or \
        isinstance(expr.lhs, float) or isinstance(expr.rhs, float)
    lid, lval = _filter_operand(b, values, expr.lhs, numeric, fconsts, ctr)
    rid, rval = _filter_operand(b, values, expr.rhs, numeric, fconsts, ctr)
    if numeric:
        if expr.op == "=":
            return lval == rval
        if expr.op == "!=":
            return (lval != rval) & ~jnp.isnan(lval) & ~jnp.isnan(rval)
        if expr.op == "<":
            return lval < rval
        if expr.op == "<=":
            return lval <= rval
        if expr.op == ">":
            return lval > rval
        return lval >= rval
    ok = (lid != UNBOUND) & (rid != UNBOUND)
    return ((lid == rid) if expr.op == "=" else (lid != rid)) & ok


def device_filter(b: JBindings, expr: FilterExpr, values: jax.Array,
                  fconsts: jax.Array, ctr: List[int]) -> JBindings:
    """FILTER: mask + stable compact (kept rows stay in order)."""
    keep = _filter_mask(expr, b, values, fconsts, ctr) & \
        _valid_mask(b.capacity, b.n)
    data, n, _ = _compact(b.data, keep, b.capacity)
    return JBindings(b.cols, data, n, b.overflow)


def device_project(b: JBindings, out_vars: Sequence[str]) -> JBindings:
    """Projection: gather the selected columns (UNBOUND-fill variables
    the pipeline does not produce), re-PAD invalid rows."""
    cap = b.capacity
    if not out_vars:
        return JBindings((), b.data[:, :0], b.n, b.overflow)
    cols = [b.data[:, b.cols.index(v)] if v in b.cols
            else jnp.full((cap,), UNBOUND, jnp.int32) for v in out_vars]
    data = jnp.stack(cols, axis=1)
    data = jnp.where(_valid_mask(cap, b.n)[:, None], data, PAD)
    return JBindings(tuple(out_vars), data, b.n, b.overflow)


def device_resize(b: JBindings, out_cap: int
                  ) -> Tuple[JBindings, jax.Array]:
    """Re-buffer the relation to ``out_cap`` rows — a pure static
    truncation (valid rows are contiguous at the front by the pipeline
    invariant, so no sort/gather is needed).  Returns the relation and
    an overflow flag for the retry protocol: DISTINCT/ORDER BY sort this
    buffer, so right-sizing it is what keeps modifier queries from
    paying an O(join_cap log join_cap) sort over mostly-PAD rows."""
    cap, k = b.data.shape
    if out_cap < cap:
        data = b.data[:out_cap]
    elif out_cap > cap:
        data = jnp.concatenate(
            [b.data, jnp.full((out_cap - cap, k), PAD, b.data.dtype)], axis=0)
    else:
        data = b.data
    ovf = b.n > out_cap
    return JBindings(b.cols, data, jnp.minimum(b.n, out_cap),
                     b.overflow), ovf


def device_distinct(b: JBindings) -> JBindings:
    """DISTINCT: lexsort + adjacent-unique to find duplicates, then a
    stable compact of the FIRST occurrence of each distinct row in the
    original order — exactly the eager engine's first-occurrence-stable
    dedup, so an order established before (or after) it survives."""
    cap, k = b.data.shape
    if k == 0:   # zero-column relation: dedup of n empty mappings is one
        return JBindings(b.cols, b.data, jnp.minimum(b.n, 1), b.overflow)
    valid = _valid_mask(cap, b.n)
    keys = [b.data[:, j] for j in range(k - 1, -1, -1)]
    keys.append((~valid).astype(jnp.int32))        # valid rows first
    order = jnp.lexsort(keys)
    sdata = b.data[order]
    svalid = valid[order]
    same_prev = jnp.concatenate([
        jnp.zeros((1,), bool),
        jnp.all(sdata[1:] == sdata[:-1], axis=1)])
    keep_sorted = svalid & ~same_prev
    keep = jnp.zeros(cap, bool).at[order].set(keep_sorted)
    data, n, _ = _compact(b.data, keep, cap)
    return JBindings(b.cols, data, n, b.overflow)


def device_order(b: JBindings, keys: Sequence[Tuple[str, bool]],
                 values: jax.Array) -> JBindings:
    """ORDER BY: stable lexsort over the dictionary's numeric value
    table (numeric literals by value, other terms by id — the eager
    ``order_rows`` semantics); PAD rows keep sorting last."""
    cap = b.capacity
    valid = _valid_mask(cap, b.n)
    nv = values.shape[0]
    ks = []
    for var, asc in reversed(tuple(keys)):
        if var not in b.cols:
            continue                      # unbound key: constant, no-op
        ids = b.data[:, b.cols.index(var)]
        if nv:
            safe = jnp.clip(ids, 0, nv - 1)
            v = jnp.where(ids >= 0, values[safe], jnp.nan)
        else:
            v = jnp.full((cap,), jnp.nan, values.dtype)
        v = jnp.where(jnp.isnan(v), ids.astype(values.dtype), v)
        ks.append(v if asc else -v)
    if not ks:
        return b
    ks.append((~valid).astype(jnp.int32))          # valid rows first
    order = jnp.lexsort(ks)
    return JBindings(b.cols, b.data[order], b.n, b.overflow)


def device_slice(b: JBindings, offset: int, limit: Optional[int]) -> JBindings:
    """OFFSET/LIMIT: static row-window over the compacted relation.  A
    LIMIT below the buffer capacity also *trims the buffer*, so only the
    final ≤ limit rows ever transfer back to the host."""
    cap, k = b.data.shape
    data, n = b.data, b.n
    if offset:
        shift = min(int(offset), cap)
        data = jnp.concatenate(
            [data[shift:], jnp.full((shift, k), PAD, data.dtype)], axis=0)
        n = jnp.maximum(n - offset, 0)
    if limit is not None:
        n = jnp.minimum(n, limit)
        if limit < cap:
            data = data[:max(int(limit), 0)]
    return JBindings(b.cols, data, n, b.overflow)


# ---------------------------------------------------------------------------
# Plan executor
# ---------------------------------------------------------------------------

def _step_meta(step: ScanStep) -> Tuple[Optional[int], Optional[int], bool,
                                        Tuple[int, ...], Tuple[str, ...]]:
    tp = step.tp
    s_bound = None if is_var(tp.s) else int(tp.s)
    o_bound = None if is_var(tp.o) else int(tp.o)
    same = is_var(tp.s) and is_var(tp.o) and tp.s == tp.o
    cols: List[str] = []
    take: List[int] = []
    if is_var(tp.s):
        cols.append(tp.s)
        take.append(0)
    if is_var(tp.o) and tp.o not in cols:
        cols.append(tp.o)
        take.append(1)
    return s_bound, o_bound, same, tuple(take), tuple(cols)


_TRACE_COUNT = 0   # program traces (== XLA compiles); test probe


def trace_count() -> int:
    """Number of static programs traced so far in this process.  A served
    template workload should increase this once per (template, caps), not
    once per request — the observable for "no recompilation on re-bind"."""
    return _TRACE_COUNT


def bounds_from_plan(plan: Plan) -> np.ndarray:
    """Per-step (s, o) bound-constant values, UNBOUND where the slot is a
    variable — the runtime argument vector of the compiled program."""
    out = np.full((len(plan.steps), 2), UNBOUND, dtype=np.int32)
    for i, step in enumerate(plan.steps):
        if not is_var(step.tp.s):
            out[i, 0] = int(step.tp.s)
        if not is_var(step.tp.o):
            out[i, 1] = int(step.tp.o)
    return out


def _pipeline_cols(plan: Plan) -> Tuple[str, ...]:
    """Variables the scan/join pipeline produces, first-seen order."""
    cols: List[str] = []
    for step in plan.steps:
        for v in _step_meta(step)[4]:
            if v not in cols:
                cols.append(v)
    return tuple(cols)


def _mod_cap_seed(spine: ModifierSpine, pipeline_cap: int) -> int:
    """Initial capacity of the modifier resize slot: generous around the
    slice window when there is one, a modest constant otherwise; never
    beyond the pipeline buffer (more rows cannot exist) and never below
    1/32 of it, so the overflow-retry loop reaches any true result size
    within its doubling budget."""
    if spine.limit is not None:
        est = max(64, 4 * (spine.offset + spine.limit))
    else:
        est = 4096
    est = max(est, pipeline_cap // 32)
    return min(round_up_pow2(est, 64), round_up_pow2(pipeline_cap, 64))


def double_caps(caps: Tuple[int, ...], ovf, n_steps: int) -> Tuple[int, ...]:
    """One overflow-retry step: double every overflowing capacity.  The
    modifier resize slot (index ``n_steps``, when present) additionally
    keeps pace with the pipeline caps — its overflow flag only fires
    once the pipeline actually delivers more rows, so without the floor
    the two growth phases would run in series and could exhaust the
    retry budget on explosive joins."""
    new = [c * 2 if ovf[i] else c for i, c in enumerate(caps)]
    if len(new) > n_steps and n_steps:
        pipe_max = max(new[:n_steps])
        new[n_steps] = min(max(new[n_steps], pipe_max // 4),
                           round_up_pow2(pipe_max, 64))
    return tuple(new)


def _spine_uses_values(spine: ModifierSpine) -> bool:
    """True when the compiled spine reads the numeric value table:
    ORDER BY keys, or any filter comparison that is numeric (order ops,
    or a float literal operand).  Identity-only filters don't."""
    if spine.order:
        return True

    def walk(e) -> bool:
        if isinstance(e, Cmp):
            return e.op in ("<", "<=", ">", ">=") or \
                isinstance(e.lhs, float) or isinstance(e.rhs, float)
        if isinstance(e, BoolOp):
            return any(walk(a) for a in e.args)
        if isinstance(e, NotExpr):
            return walk(e.arg)
        return False

    return any(walk(e) for e in spine.filters)


def check_spine(spine: ModifierSpine, pipe_cols: Tuple[str, ...],
                catalog: Optional[Catalog] = None) -> Tuple[str, ...]:
    """Validate that a modifier spine is compilable over a pipeline that
    binds ``pipe_cols``; raises NotImplementedError (the backends'
    fall-back-to-eager signal) otherwise.  Returns the output columns.

    The device engines run with x64 disabled, so the dictionary's
    float64 value table is gathered as float32 on device.  When the
    spine actually reads values (numeric FILTER, ORDER BY) and the table
    is not exactly float32-representable — values above 2^24, sub-float32
    deltas, or an id space that large (ids are the sort fallback key) —
    the host engines would disagree with the device, so those templates
    stay on the (counted) eager path instead of silently diverging."""
    for v in filter_variables(spine.filters):
        if v not in pipe_cols:
            raise NotImplementedError(
                f"filter variable {v} is not bound by the BGP pipeline")
    if catalog is not None and catalog.dictionary is not None and \
            _spine_uses_values(spine):
        if len(catalog.dictionary) >= 2 ** 24:
            raise NotImplementedError(
                "id space exceeds float32-exact range for device sorts")
        vals = catalog.dictionary.values
        finite = vals[~np.isnan(vals)]
        if len(finite) and not np.array_equal(
                finite.astype(np.float32).astype(np.float64), finite):
            raise NotImplementedError(
                "dictionary value table is not float32-exact; numeric "
                "modifiers would diverge from the host engines")
    return tuple(spine.project) if spine.project is not None else pipe_cols


class PlanExecutor:
    """Builds and runs the jitted static program for a compiled Plan.

    ``caps[i]`` bounds the output of step i (step 0 = first scan; step i>0 =
    i-th join output); scan caps are table capacities.  ``run`` retries
    with doubled caps on overflow (host loop, geometric — at most
    ~log2(result/estimate) recompiles, amortized across a served workload).

    Bound s/o constants enter the program as runtime int32 scalars (their
    *presence* is static, their values are not), so every instantiation of
    a query template shares one compiled program — ``run(bounds=...)``
    re-binds without re-tracing.

    ``spine`` appends the query's solution modifiers to the traced
    program (FILTER masks, on-device projection, sort-based DISTINCT,
    value-table ORDER BY, static OFFSET/LIMIT window); filter constants
    ride the runtime ``fconsts`` input the same way scan bounds do, so
    modifier-bearing templates re-bind without re-tracing too.
    """

    bounds_from_plan = staticmethod(bounds_from_plan)

    def __init__(self, plan: Plan, catalog: Catalog, slack: float = 1.5,
                 spine: Optional[ModifierSpine] = None):
        if plan.empty:
            raise ValueError("cannot build executor for statistics-empty plan")
        self.plan = plan
        self.catalog = catalog
        self.spine = spine if spine is not None else ModifierSpine()
        self._pipe_cols = _pipeline_cols(plan)
        self._out_vars = check_spine(self.spine, self._pipe_cols, catalog)
        self.filter_slots = filter_const_slots(self.spine.filters)
        # DISTINCT/ORDER BY sort the whole static buffer; the join caps
        # are sized for the worst unfiltered join, which would make every
        # modifier query pay an O(cap log cap) sort over mostly-PAD rows.
        # Instead the spine starts from its own small capacity slot (an
        # overflow-checked compact before the sorts, appended to ``caps``
        # so the retry protocol grows it geometrically when a template's
        # true result is larger — and the grown cap persists).
        self._mod_resize = bool(self.spine.distinct or self.spine.order)
        self.tables = []
        self.caps: List[int] = []
        est = 0.0
        for i, step in enumerate(plan.steps):
            if step.uses_tt:
                raise NotImplementedError("device path requires bound predicates")
            t = catalog.table(step.kind, int(step.tp.p), step.p2)
            self.tables.append(t)
            scan_est = max(1.0, float(len(t)))
            if step.tp.n_bound() > 1:
                scan_est = max(1.0, scan_est * 0.01)
            est = scan_est if i == 0 else max(est, scan_est, est * 1.25)
            self.caps.append(round_up_pow2(int(est * slack) + 8, 16))
        if self._mod_resize:
            self.caps.append(_mod_cap_seed(self.spine, self.caps[-1]))
        self._default_bounds = bounds_from_plan(plan)

    def fconsts_from_mapping(self, mapping=None) -> np.ndarray:
        """Runtime filter-constant vector for one binding: template
        placeholder ids resolve through ``mapping``, concrete ids pass
        through — the filter counterpart of ``bounds_from_plan``."""
        m = mapping or {}
        return np.asarray([m.get(c, c) for c in self.filter_slots],
                          dtype=np.int32)

    def _apply_spine(self, b: JBindings, values: jax.Array,
                     fconsts: jax.Array, caps: Tuple[int, ...]
                     ) -> Tuple[JBindings, Optional[jax.Array]]:
        """FILTER* → [resize] → ORDER BY → project → DISTINCT →
        OFFSET/LIMIT, the canonical host sequence lowered onto the
        static relation (ordering precedes projection so sort keys
        outside the SELECT list work, exactly like the host engines).
        Returns the relation and the resize step's overflow flag (None
        when the spine needs no sorts)."""
        sp = self.spine
        ctr = [0]
        for expr in sp.filters:
            b = device_filter(b, expr, values, fconsts, ctr)
        mod_ovf = None
        if self._mod_resize:
            b, mod_ovf = device_resize(b, caps[len(self.plan.steps)])
        if sp.order:
            b = device_order(b, sp.order, values)
        b = device_project(b, self._out_vars)
        if sp.distinct:
            b = device_distinct(b)
        if sp.has_slice:
            b = device_slice(b, sp.offset, sp.limit)
        return b, mod_ovf

    # -- the traced program --------------------------------------------------
    def _scan_step(self, i: int, meta, table_rows: List[jax.Array],
                   table_ns: List[jax.Array], bounds: jax.Array,
                   caps: Tuple[int, ...]) -> JBindings:
        """One scan, picking the windowed form when the subject is bound
        (tables are subject-sorted, see :class:`repro.core.table.Table`)."""
        s_bound, o_bound, same, take, cols = meta
        out_cap = caps[i] if i == 0 else table_rows[i].shape[0]
        sb = bounds[i, 0] if s_bound is not None else None
        ob = bounds[i, 1] if o_bound is not None else None
        if s_bound is not None and o_bound is None:
            data, n, ovf = device_scan_windowed(table_rows[i], table_ns[i],
                                                sb, take, out_cap)
        else:
            data, n, ovf = device_scan(table_rows[i], table_ns[i], sb, ob,
                                       same, take, out_cap)
        return JBindings(cols, data, n, ovf)

    def _compose(self, caps: Tuple[int, ...], table_rows: List[jax.Array],
                 table_ns: List[jax.Array], bounds: jax.Array,
                 shared: Dict[int, Tuple[JBindings, Optional[Tuple[jax.Array, jax.Array]]]]
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """The scan/join pipeline both programs run.  Returns
        (data, n, per_step_overflow[n_steps]): overflow is reported PER
        STEP so the host retry doubles only the capacities that actually
        overflowed — wholesale doubling let one heavy constant inflate
        every buffer of the program, which is poison for batched serving
        (all batch elements pay the worst element's caps).  ``shared``
        maps step index -> precomputed (relation, presorted join key) for
        bounds-independent scans (empty for the single-request program)."""
        acc: Optional[JBindings] = None
        ovfs: List[jax.Array] = []
        no = jnp.asarray(False)
        for i, step in enumerate(self.plan.steps):
            if i in shared:
                cur, pre = shared[i]
            else:
                cur = self._scan_step(i, _step_meta(step), table_rows,
                                      table_ns, bounds, caps)
                pre = None
            if acc is None:
                acc = cur
                ovfs.append(cur.overflow)
            else:
                # strip sticky input flags: we want this join's OWN overflow
                joined = device_join(
                    JBindings(acc.cols, acc.data, acc.n, no),
                    JBindings(cur.cols, cur.data, cur.n, no), caps[i],
                    b_presorted=pre)
                ovfs.append(joined.overflow | cur.overflow)
                acc = joined
        assert acc is not None
        return acc.data, acc.n, jnp.stack(ovfs)

    def _program(self, caps: Tuple[int, ...], table_rows: List[jax.Array],
                 table_ns: List[jax.Array], bounds: jax.Array,
                 fconsts: jax.Array,
                 values: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        data, n, ovfs = self._compose(caps, table_rows, table_ns, bounds, {})
        b, mod_ovf = self._apply_spine(
            JBindings(self._pipe_cols, data, n, jnp.asarray(False)),
            values, fconsts, caps)
        if mod_ovf is not None:
            ovfs = jnp.concatenate([ovfs, mod_ovf[None]])
        return b.data, b.n, ovfs

    @functools.cached_property
    def _device_inputs(self) -> Tuple[List[jax.Array], List[jax.Array],
                                      jax.Array]:
        """Device-resident padded tables + the dictionary value table,
        uploaded ONCE per executor — the hot path must not re-pad and
        re-transfer O(table) bytes on every launch."""
        rows = [jnp.asarray(t.to_device().rows) for t in self.tables]
        ns = [jnp.asarray(np.int32(len(t))) for t in self.tables]
        vals = self.catalog.dictionary.values \
            if self.catalog.dictionary is not None \
            else np.empty(0, dtype=np.float64)
        values = jnp.asarray(vals.astype(np.float32))
        return rows, ns, values

    @functools.cached_property
    def _jitted(self):
        return jax.jit(self._program, static_argnums=(0,))

    # -- the batched traced program --------------------------------------------
    def _program_batched(self, caps: Tuple[int, ...],
                         table_rows: List[jax.Array],
                         table_ns: List[jax.Array],
                         bounds_b: jax.Array, fconsts_b: jax.Array,
                         values: jax.Array
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """B constant-bindings of the template in one program.

        Constants only enter scan *selection values*, so any step whose
        triple pattern binds no constant produces the same relation for
        every batch element.  Those scans — and the build-side sort of
        the joins that consume them — are hoisted OUT of the vmap and
        computed once per launch; only the constant-dependent scans and
        the (capacity-bounded, small) probe/expand phases replicate per
        element.  This is what makes a batch ~O(shared + B·small) instead
        of B times the full per-request program.
        """
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        plan = self.plan
        metas = [_step_meta(s) for s in plan.steps]

        # shared phase: bounds-independent scans + their join-key presort
        shared: Dict[int, Tuple[JBindings, Optional[Tuple[jax.Array, jax.Array]]]] = {}
        acc_cols: List[str] = []
        for i, step in enumerate(plan.steps):
            s_bound, o_bound, same, take, cols = metas[i]
            if i > 0 and s_bound is None and o_bound is None:
                data, n, ovf = device_scan(table_rows[i], table_ns[i], None,
                                           None, same, take,
                                           table_rows[i].shape[0])
                cur = JBindings(cols, data, n, ovf)
                # the join key device_join will pick: first accumulated
                # column present on the build side
                key = next((c for c in acc_cols if c in cols), None)
                pre = None
                if key is not None:
                    kb = build_key(cur, cols.index(key))
                    order_b = jnp.argsort(kb).astype(jnp.int32)
                    pre = (order_b, kb[order_b])
                shared[i] = (cur, pre)
            for c in cols:
                if c not in acc_cols:
                    acc_cols.append(c)

        def one(b, fc):
            data, n, ovfs = self._compose(caps, table_rows, table_ns, b,
                                          shared)
            jb, mod_ovf = self._apply_spine(
                JBindings(self._pipe_cols, data, n, jnp.asarray(False)),
                values, fc, caps)
            if mod_ovf is not None:
                ovfs = jnp.concatenate([ovfs, mod_ovf[None]])
            return jb.data, jb.n, ovfs

        return jax.vmap(one)(bounds_b, fconsts_b)

    @functools.cached_property
    def _jitted_batch(self):
        # jax.jit caches per static (caps, B) pair, so trace_count() moves
        # once per (template, bucket-shape) — never once per request.
        return jax.jit(self._program_batched, static_argnums=(0,))

    def lower(self, caps: Optional[Tuple[int, ...]] = None):
        caps = caps or tuple(self.caps)
        rows = [jax.ShapeDtypeStruct((round_up_pow2(len(t)), 2), jnp.int32)
                for t in self.tables]
        ns = [jax.ShapeDtypeStruct((), jnp.int32) for _ in self.tables]
        bshape = jax.ShapeDtypeStruct(self._default_bounds.shape, jnp.int32)
        fshape = jax.ShapeDtypeStruct((len(self.filter_slots),), jnp.int32)
        nv = len(self.catalog.dictionary) \
            if self.catalog.dictionary is not None else 0
        vshape = jax.ShapeDtypeStruct((nv,), jnp.float32)
        return self._jitted.lower(caps, rows, ns, bshape, fshape, vshape)

    def run(self, max_retries: int = 8,
            bounds: Optional[np.ndarray] = None,
            fconsts: Optional[np.ndarray] = None
            ) -> Tuple[np.ndarray, Tuple[str, ...]]:
        rows, ns, values = self._device_inputs
        b = self._default_bounds if bounds is None else \
            np.asarray(bounds, dtype=np.int32).reshape(self._default_bounds.shape)
        bj = jnp.asarray(b)
        fc = self.fconsts_from_mapping(None) if fconsts is None else \
            np.asarray(fconsts, dtype=np.int32).reshape(len(self.filter_slots))
        fj = jnp.asarray(fc)
        caps = tuple(self.caps)
        for _ in range(max_retries):
            data, n, ovf = self._jitted(caps, rows, ns, bj, fj, values)
            ovf = np.asarray(ovf)
            if not ovf.any():
                # keep grown caps: a hot template must not pay the
                # overflow->retry double-launch on every request
                self.caps = list(caps)
                n = int(n)
                cols = self._final_cols()
                return np.asarray(data)[:n], cols
            caps = double_caps(caps, ovf, len(self.plan.steps))
        raise RuntimeError("join capacity overflow after retries")

    def run_batch(self, bounds_batch: Sequence[np.ndarray],
                  fconsts_batch: Optional[Sequence[np.ndarray]] = None,
                  max_retries: int = 8) -> List[Tuple[np.ndarray, Tuple[str, ...]]]:
        """Execute B constant-bindings of this template's program in ONE
        XLA launch: the (B, n_steps, 2) bounds stack and the (B, n_fc)
        filter-constant stack are the only batched inputs (tables
        broadcast), so device work is amortized across the whole
        micro-batch.  Overflow on *any* batch element retries the whole
        batch with doubled caps — the batch shares one cap vector, which
        keeps the program count at one per (caps, B)."""
        if not bounds_batch:
            return []
        rows, ns, values = self._device_inputs
        shape = self._default_bounds.shape
        bb = np.stack([np.asarray(b, dtype=np.int32).reshape(shape)
                       for b in bounds_batch])
        bj = jnp.asarray(bb)
        n_fc = len(self.filter_slots)
        if fconsts_batch is None:
            fb = np.tile(self.fconsts_from_mapping(None), (len(bb), 1))
        else:
            fb = np.stack([np.asarray(f, dtype=np.int32).reshape(n_fc)
                           for f in fconsts_batch])
        fj = jnp.asarray(fb)
        caps = tuple(self.caps)
        for _ in range(max_retries):
            data, n, ovf = self._jitted_batch(caps, rows, ns, bj, fj, values)
            ovf = np.asarray(ovf)                # (B, n_steps)
            if not ovf.any():
                self.caps = list(caps)
                cols = self._final_cols()
                data = np.asarray(data)
                n = np.asarray(n)
                return [(data[i, : int(n[i])], cols)
                        for i in range(data.shape[0])]
            caps = double_caps(caps, ovf.any(axis=0), len(self.plan.steps))
        raise RuntimeError("join capacity overflow after retries (batched)")

    def _final_cols(self) -> Tuple[str, ...]:
        return self._out_vars
