"""Static-shape jitted executor — the device (TPU) path of the engine.

XLA requires static shapes, so every relation is a fixed-capacity buffer
``(data[cap, k], n)`` with PAD rows past ``n``; every operator returns an
overflow flag when a capacity would have been exceeded and the host re-runs
the plan with doubled capacities (the standard static-buffer serving
pattern).  Capacities are seeded from the catalog's ExtVP statistics — the
same statistics the paper uses for join ordering — so overflows are rare.

Join algorithm: sort-merge.  The probe side is key-sorted (XLA sort), the
build side binary-searched (``jnp.searchsorted``), match counts expanded
into output slots by a rank-search over the exclusive prefix sum.  All
steps are O(n log n) vectorized primitives that map to TPU-friendly sort /
gather / compare units — this is where the Pallas kernels of
:mod:`repro.kernels` plug in for the probe phase.

Join keys are single int32 columns (the first shared variable); any
further shared variables are post-filtered after expansion — BGP joins
share one variable in the overwhelming majority of cases (star/chain
joins), and this keeps the engine int32-only (x64 mode stays off for the
LM substrate).  Sentinels keep padded/NULL rows unmatched: probe-side pads
→ ``A_SENT``, build-side pads → ``B_SENT`` (distinct, sort-max), UNBOUND
values → per-side negative sentinels.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compiler import Plan, ScanStep
from repro.core.stats import Catalog
from repro.core.table import round_up_pow2
from repro.rdf.dictionary import PAD, UNBOUND
from repro.core.algebra import is_var

__all__ = ["JBindings", "PlanExecutor", "device_join", "device_scan",
           "bounds_from_plan", "trace_count"]

A_SENT = np.int32(2**31 - 1)   # probe-side padded-row key (== PAD)
B_SENT = np.int32(2**31 - 2)   # build-side padded-row key (sort-max, != A_SENT)
A_NULL = np.int32(-3)          # probe-side UNBOUND key
B_NULL = np.int32(-5)          # build-side UNBOUND key


@dataclass
class JBindings:
    """Static-shape relation: cols are trace-time metadata."""

    cols: Tuple[str, ...]
    data: jax.Array          # (cap, k) int32
    n: jax.Array             # () int32
    overflow: jax.Array      # () bool — sticky across operators

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


def _valid_mask(cap: int, n: jax.Array) -> jax.Array:
    return jnp.arange(cap, dtype=jnp.int32) < n


def _compact(data: jax.Array, keep: jax.Array, out_cap: int,
             fill: int = PAD) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Move keep-rows to the front (stable); returns (data, n, overflow)."""
    cap = data.shape[0]
    n_keep = jnp.sum(keep, dtype=jnp.int32)
    order = jnp.argsort(~keep, stable=True)           # keeps first
    gathered = data[order]
    if out_cap < cap:
        gathered = gathered[:out_cap]
    elif out_cap > cap:
        padrows = jnp.full((out_cap - cap, data.shape[1]), fill, jnp.int32)
        gathered = jnp.concatenate([gathered, padrows], axis=0)
    mask = _valid_mask(out_cap, n_keep)
    gathered = jnp.where(mask[:, None], gathered, fill)
    return gathered, jnp.minimum(n_keep, out_cap), n_keep > out_cap


def device_scan(rows: jax.Array, n: jax.Array, s_bound,
                o_bound, same_var: bool,
                out_cols: Sequence[int], out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Select + project one (s, o) table (Algorithm 2, device form).

    ``s_bound``/``o_bound`` are ``None`` (statically unbound) or an int32
    scalar — python int or traced value.  Passing bound constants as
    traced runtime values is what lets one compiled program serve every
    instantiation of a query template (constant re-binding)."""
    cap = rows.shape[0]
    keep = _valid_mask(cap, n)
    if s_bound is not None:
        keep &= rows[:, 0] == s_bound
    if o_bound is not None:
        keep &= rows[:, 1] == o_bound
    if same_var:
        keep &= rows[:, 0] == rows[:, 1]
    projected = rows[:, list(out_cols)] if out_cols else rows[:, :0]
    return _compact(projected, keep, out_cap)


def device_join(a: JBindings, b: JBindings, out_cap: int) -> JBindings:
    """Natural join of two static relations (sort-merge, rank expansion)."""
    shared = [c for c in a.cols if c in b.cols]
    b_only = [c for c in b.cols if c not in a.cols]
    out_cols = a.cols + tuple(b_only)

    cap_a, cap_b = a.capacity, b.capacity
    if not shared:  # cross join (rare; bounded by caps)
        ii = jnp.arange(out_cap, dtype=jnp.int32)
        a_idx = ii // jnp.maximum(b.n, 1)
        b_idx = ii % jnp.maximum(b.n, 1)
        total = a.n * b.n
        valid = ii < total
        data = jnp.concatenate(
            [a.data[jnp.clip(a_idx, 0, cap_a - 1)],
             b.data[jnp.clip(b_idx, 0, cap_b - 1)]], axis=1)
        data = jnp.where(valid[:, None], data, PAD)
        return JBindings(out_cols, data, jnp.minimum(total, out_cap).astype(jnp.int32),
                         a.overflow | b.overflow | (total > out_cap))

    ka = a.data[:, a.cols.index(shared[0])]
    kb = b.data[:, b.cols.index(shared[0])]
    ka = jnp.where(ka == UNBOUND, A_NULL, ka)
    kb = jnp.where(kb == UNBOUND, B_NULL, kb)
    ka = jnp.where(_valid_mask(cap_a, a.n), ka, A_SENT)
    kb = jnp.where(_valid_mask(cap_b, b.n), kb, B_SENT)

    order_b = jnp.argsort(kb).astype(jnp.int32)
    kb_sorted = kb[order_b]
    lo = jnp.searchsorted(kb_sorted, ka, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(kb_sorted, ka, side="right").astype(jnp.int32)
    cnt = hi - lo
    prefix = jnp.cumsum(cnt) - cnt               # exclusive prefix
    total = prefix[-1] + cnt[-1]

    j = jnp.arange(out_cap, dtype=jnp.int32)
    # rank search: which probe row produced output slot j
    a_idx = jnp.searchsorted(prefix + cnt, j, side="right").astype(jnp.int32)
    a_idx = jnp.clip(a_idx, 0, cap_a - 1)
    off = j - prefix[a_idx]
    b_pos = jnp.clip(lo[a_idx] + off, 0, cap_b - 1).astype(jnp.int32)
    b_idx = order_b[b_pos]
    valid = j < total

    left = a.data[a_idx]
    right = b.data[b_idx]

    # post-filter shared columns beyond the key (SQL NULL semantics)
    for c in shared[1:]:
        va = left[:, a.cols.index(c)]
        vb = right[:, b.cols.index(c)]
        valid &= (va == vb) & (va != UNBOUND)

    pieces = [left]
    if b_only:
        pieces.append(right[:, [b.cols.index(c) for c in b_only]])
    data = jnp.concatenate(pieces, axis=1)
    data, n, ovf = _compact(data, valid, out_cap)
    return JBindings(out_cols, data, n,
                     a.overflow | b.overflow | ovf | (total > out_cap))


# ---------------------------------------------------------------------------
# Plan executor
# ---------------------------------------------------------------------------

def _step_meta(step: ScanStep) -> Tuple[Optional[int], Optional[int], bool,
                                        Tuple[int, ...], Tuple[str, ...]]:
    tp = step.tp
    s_bound = None if is_var(tp.s) else int(tp.s)
    o_bound = None if is_var(tp.o) else int(tp.o)
    same = is_var(tp.s) and is_var(tp.o) and tp.s == tp.o
    cols: List[str] = []
    take: List[int] = []
    if is_var(tp.s):
        cols.append(tp.s)
        take.append(0)
    if is_var(tp.o) and tp.o not in cols:
        cols.append(tp.o)
        take.append(1)
    return s_bound, o_bound, same, tuple(take), tuple(cols)


_TRACE_COUNT = 0   # program traces (== XLA compiles); test probe


def trace_count() -> int:
    """Number of static programs traced so far in this process.  A served
    template workload should increase this once per (template, caps), not
    once per request — the observable for "no recompilation on re-bind"."""
    return _TRACE_COUNT


def bounds_from_plan(plan: Plan) -> np.ndarray:
    """Per-step (s, o) bound-constant values, UNBOUND where the slot is a
    variable — the runtime argument vector of the compiled program."""
    out = np.full((len(plan.steps), 2), UNBOUND, dtype=np.int32)
    for i, step in enumerate(plan.steps):
        if not is_var(step.tp.s):
            out[i, 0] = int(step.tp.s)
        if not is_var(step.tp.o):
            out[i, 1] = int(step.tp.o)
    return out


class PlanExecutor:
    """Builds and runs the jitted static program for a compiled Plan.

    ``caps[i]`` bounds the output of step i (step 0 = first scan; step i>0 =
    i-th join output); scan caps are table capacities.  ``run`` retries
    with doubled caps on overflow (host loop, geometric — at most
    ~log2(result/estimate) recompiles, amortized across a served workload).

    Bound s/o constants enter the program as runtime int32 scalars (their
    *presence* is static, their values are not), so every instantiation of
    a query template shares one compiled program — ``run(bounds=...)``
    re-binds without re-tracing.
    """

    bounds_from_plan = staticmethod(bounds_from_plan)

    def __init__(self, plan: Plan, catalog: Catalog, slack: float = 1.5):
        if plan.empty:
            raise ValueError("cannot build executor for statistics-empty plan")
        self.plan = plan
        self.catalog = catalog
        self.tables = []
        self.caps: List[int] = []
        est = 0.0
        for i, step in enumerate(plan.steps):
            if step.uses_tt:
                raise NotImplementedError("device path requires bound predicates")
            t = catalog.table(step.kind, int(step.tp.p), step.p2)
            self.tables.append(t)
            scan_est = max(1.0, float(len(t)))
            if step.tp.n_bound() > 1:
                scan_est = max(1.0, scan_est * 0.01)
            est = scan_est if i == 0 else max(est, scan_est, est * 1.25)
            self.caps.append(round_up_pow2(int(est * slack) + 8, 16))
        self._default_bounds = bounds_from_plan(plan)

    # -- the traced program --------------------------------------------------
    def _program(self, caps: Tuple[int, ...], table_rows: List[jax.Array],
                 table_ns: List[jax.Array],
                 bounds: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        plan = self.plan
        acc: Optional[JBindings] = None
        for i, step in enumerate(plan.steps):
            s_bound, o_bound, same, take, cols = _step_meta(step)
            data, n, ovf = device_scan(table_rows[i], table_ns[i],
                                       bounds[i, 0] if s_bound is not None else None,
                                       bounds[i, 1] if o_bound is not None else None,
                                       same, take,
                                       caps[i] if i == 0 else table_rows[i].shape[0])
            cur = JBindings(cols, data, n, ovf)
            if acc is None:
                acc = cur
            else:
                acc = device_join(acc, cur, caps[i])
        assert acc is not None
        return acc.data, acc.n, acc.overflow

    @functools.cached_property
    def _jitted(self):
        return jax.jit(self._program, static_argnums=(0,))

    def lower(self, caps: Optional[Tuple[int, ...]] = None):
        caps = caps or tuple(self.caps)
        rows = [jax.ShapeDtypeStruct((round_up_pow2(len(t)), 2), jnp.int32)
                for t in self.tables]
        ns = [jax.ShapeDtypeStruct((), jnp.int32) for _ in self.tables]
        bshape = jax.ShapeDtypeStruct(self._default_bounds.shape, jnp.int32)
        return self._jitted.lower(caps, rows, ns, bshape)

    def run(self, max_retries: int = 8,
            bounds: Optional[np.ndarray] = None) -> Tuple[np.ndarray, Tuple[str, ...]]:
        rows = [jnp.asarray(t.to_device().rows) for t in self.tables]
        ns = [jnp.asarray(np.int32(len(t))) for t in self.tables]
        b = self._default_bounds if bounds is None else \
            np.asarray(bounds, dtype=np.int32).reshape(self._default_bounds.shape)
        bj = jnp.asarray(b)
        caps = tuple(self.caps)
        for _ in range(max_retries):
            data, n, ovf = self._jitted(caps, rows, ns, bj)
            if not bool(ovf):
                n = int(n)
                cols = self._final_cols()
                return np.asarray(data)[:n], cols
            caps = tuple(c * 2 for c in caps)
        raise RuntimeError("join capacity overflow after retries")

    def _final_cols(self) -> Tuple[str, ...]:
        cols: List[str] = []
        for step in self.plan.steps:
            for v in _step_meta(step)[4]:
                if v not in cols:
                    cols.append(v)
        return tuple(cols)
