"""Static-shape jitted executor — the device (TPU) path of the engine.

XLA requires static shapes, so every relation is a fixed-capacity buffer
``(data[cap, k], n)`` with PAD rows past ``n``; every operator returns an
overflow flag when a capacity would have been exceeded and the host re-runs
the plan with doubled capacities (the standard static-buffer serving
pattern).  Capacities are seeded from the catalog's ExtVP statistics — the
same statistics the paper uses for join ordering — so overflows are rare.

Join algorithm: sort-merge.  The probe side is key-sorted (XLA sort), the
build side binary-searched (``jnp.searchsorted``), match counts expanded
into output slots by a rank-search over the exclusive prefix sum.  All
steps are O(n log n) vectorized primitives that map to TPU-friendly sort /
gather / compare units — this is where the Pallas kernels of
:mod:`repro.kernels` plug in for the probe phase.

Join keys are single int32 columns (the first shared variable); any
further shared variables are post-filtered after expansion — BGP joins
share one variable in the overwhelming majority of cases (star/chain
joins), and this keeps the engine int32-only (x64 mode stays off for the
LM substrate).  Sentinels keep padded/NULL rows unmatched: probe-side pads
→ ``A_SENT``, build-side pads → ``B_SENT`` (distinct, sort-max), UNBOUND
values → per-side negative sentinels.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.algebra import BoolOp, Bound, Cmp, FilterExpr, NotExpr, is_var
from repro.core.compiler import (
    BGPSeg, CombineSeg, CorePlan, CoreSeg, EmptySeg, FilterSeg, Plan,
    ScanStep, core_filter_exprs, seg_vars,
)
from repro.core.modifiers import (
    ModifierSpine, filter_const_slots, filter_variables,
)
from repro.core.stats import Catalog
from repro.core.table import pad_rows, round_up_pow2
from repro.rdf.dictionary import PAD, UNBOUND

__all__ = ["JBindings", "PlanExecutor", "device_join", "device_left_join",
           "device_union", "device_scan", "device_scan_tt",
           "device_scan_windowed", "build_key", "bounds_from_plan",
           "trace_count", "device_filter", "device_project",
           "device_distinct", "device_order", "device_slice",
           "numeric_value_keys", "prepare_value_keys"]

A_SENT = np.int32(2**31 - 1)   # probe-side padded-row key (== PAD)
B_SENT = np.int32(2**31 - 2)   # build-side padded-row key (sort-max, != A_SENT)
A_NULL = np.int32(-3)          # probe-side UNBOUND key
B_NULL = np.int32(-5)          # build-side UNBOUND key


# ---------------------------------------------------------------------------
# Double-single numeric keys
#
# The device engines run with x64 disabled, so float64 dictionary values
# cannot be compared/sorted on device directly.  Each float64 ``v`` is
# split into a float32 pair ``(hi, lo)`` with ``hi = f32(v)`` (nearest)
# and ``lo = f32(v - f64(hi))``: ``hi`` is monotone in ``v`` and, for
# equal ``hi``, the residual is monotone too, so LEXICOGRAPHIC pair
# comparison is order-equivalent to the float64 comparison whenever the
# pair mapping is injective over the values actually compared.  That
# injectivity is checked ONCE on the host (adjacent-unique over the
# sorted value+id key set) — tables that defeat it (sub-2^-29-relative
# deltas) raise NotImplementedError, which the backends turn into the
# counted eager fallback.  This replaces the old blanket "values must be
# float32-exact" bail-out: any id-space size and ordinary float64 value
# tables (2^24+, fractional, negative) now stay on device.
# ---------------------------------------------------------------------------

def _split_f64(v: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    hi = v.astype(np.float32)
    with np.errstate(invalid="ignore"):
        lo = (v - hi.astype(np.float64)).astype(np.float32)
    return hi, np.where(np.isnan(lo), np.float32(0.0), lo)


def _split_scalar(v: float) -> Tuple[np.float32, np.float32]:
    hi = np.float32(v)
    return hi, np.float32(np.float64(v) - np.float64(hi))


def _check_pair_injective(vals: np.ndarray, what: str) -> None:
    """Distinct float64 keys must map to distinct (hi, lo) pairs."""
    u = np.unique(vals[~np.isnan(vals)])
    if len(u) <= 1:
        return
    hi, lo = _split_f64(u)
    if not np.all((np.diff(hi) != 0) | (np.diff(lo) != 0)):
        raise NotImplementedError(
            f"{what} is not double-single distinguishable; numeric "
            "modifiers would diverge from the host engines")


def numeric_value_keys(dictionary) -> np.ndarray:
    """The device numeric-key table: float32 ``(nv, 4)`` of
    ``[cmp_hi, cmp_lo, ord_hi, ord_lo]`` per term id.  The cmp pair is
    NaN for non-numeric terms (comparisons drop those rows, matching the
    host engines); the ord pair falls back to the term id (the host
    ``order_rows`` key).  Cached on the dictionary; raises
    NotImplementedError when the pair encoding cannot distinguish the
    table's keys (the backends' fallback signal)."""
    if dictionary is None:
        return np.empty((0, 4), dtype=np.float32)
    cached = getattr(dictionary, "_ds_value_keys", None)
    if cached is not None and cached.shape[0] == len(dictionary):
        return cached
    vals = np.asarray(dictionary.values, dtype=np.float64)
    n = len(vals)
    cmp_hi, cmp_lo = _split_f64(vals)
    cmp_hi = np.where(np.isnan(vals), np.float32(np.nan), cmp_hi)
    ord64 = np.where(np.isnan(vals), np.arange(n, dtype=np.float64), vals)
    _check_pair_injective(ord64, "dictionary value/id key table")
    ord_hi, ord_lo = _split_f64(ord64)
    keys = np.stack([cmp_hi, cmp_lo, ord_hi, ord_lo], axis=1) \
        .astype(np.float32)
    try:
        dictionary._ds_value_keys = keys
    except AttributeError:
        pass
    return keys


def _float_literals(exprs: Sequence[FilterExpr]) -> List[float]:
    out: List[float] = []

    def walk(e) -> None:
        if isinstance(e, Cmp):
            for t in (e.lhs, e.rhs):
                if isinstance(t, float):
                    out.append(t)
        elif isinstance(e, BoolOp):
            for a in e.args:
                walk(a)
        elif isinstance(e, NotExpr):
            walk(e.arg)

    for e in exprs:
        walk(e)
    return out


def _exprs_use_values(exprs: Sequence[FilterExpr]) -> bool:
    """True when any filter comparison is numeric (order ops or a float
    literal operand) — i.e. reads the numeric key table."""

    def walk(e) -> bool:
        if isinstance(e, Cmp):
            return e.op in ("<", "<=", ">", ">=") or \
                isinstance(e.lhs, float) or isinstance(e.rhs, float)
        if isinstance(e, BoolOp):
            return any(walk(a) for a in e.args)
        if isinstance(e, NotExpr):
            return walk(e.arg)
        return False

    return any(walk(e) for e in exprs)


def prepare_value_keys(catalog: Optional[Catalog], spine: ModifierSpine,
                       filters: Sequence[FilterExpr]) -> np.ndarray:
    """The numeric key table a program needs — empty when nothing in the
    program reads values (identity-only filters, no ORDER BY), so
    value-free templates never pay the injectivity check and never fall
    back on a pathological dictionary."""
    uses = bool(spine.order) or _exprs_use_values(filters)
    if not uses or catalog is None or catalog.dictionary is None:
        return np.empty((0, 4), dtype=np.float32)
    keys = numeric_value_keys(catalog.dictionary)
    lits = _float_literals(list(filters))
    if lits:
        vals = np.asarray(catalog.dictionary.values, dtype=np.float64)
        _check_pair_injective(
            np.concatenate([vals[~np.isnan(vals)],
                            np.asarray(lits, dtype=np.float64)]),
            "filter literal vs dictionary value keys")
    return keys


@dataclass
class JBindings:
    """Static-shape relation: cols are trace-time metadata."""

    cols: Tuple[str, ...]
    data: jax.Array          # (cap, k) int32
    n: jax.Array             # () int32
    overflow: jax.Array      # () bool — sticky across operators

    @property
    def capacity(self) -> int:
        return self.data.shape[0]


def _valid_mask(cap: int, n: jax.Array) -> jax.Array:
    return jnp.arange(cap, dtype=jnp.int32) < n


def _compact(data: jax.Array, keep: jax.Array, out_cap: int,
             fill: int = PAD) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Move keep-rows to the front (stable); returns (data, n, overflow)."""
    cap = data.shape[0]
    n_keep = jnp.sum(keep, dtype=jnp.int32)
    order = jnp.argsort(~keep, stable=True)           # keeps first
    gathered = data[order]
    if out_cap < cap:
        gathered = gathered[:out_cap]
    elif out_cap > cap:
        padrows = jnp.full((out_cap - cap, data.shape[1]), fill, jnp.int32)
        gathered = jnp.concatenate([gathered, padrows], axis=0)
    mask = _valid_mask(out_cap, n_keep)
    gathered = jnp.where(mask[:, None], gathered, fill)
    return gathered, jnp.minimum(n_keep, out_cap), n_keep > out_cap


def device_scan(rows: jax.Array, n: jax.Array, s_bound,
                o_bound, same_var: bool,
                out_cols: Sequence[int], out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Select + project one (s, o) table (Algorithm 2, device form).

    ``s_bound``/``o_bound`` are ``None`` (statically unbound) or an int32
    scalar — python int or traced value.  Passing bound constants as
    traced runtime values is what lets one compiled program serve every
    instantiation of a query template (constant re-binding)."""
    cap = rows.shape[0]
    keep = _valid_mask(cap, n)
    if s_bound is not None:
        keep &= rows[:, 0] == s_bound
    if o_bound is not None:
        keep &= rows[:, 1] == o_bound
    if same_var:
        keep &= rows[:, 0] == rows[:, 1]
    projected = rows[:, list(out_cols)] if out_cols else rows[:, :0]
    return _compact(projected, keep, out_cap)


def build_key(b: JBindings, key_col: int) -> jax.Array:
    """The build-side join-key column with NULL/pad sentinels applied —
    the input of the build-side sort.  Exposed so a batched program can
    presort a *shared* (bounds-independent) build relation once and reuse
    it for every batch element (see ``device_join``'s ``b_presorted``)."""
    kb = b.data[:, key_col]
    kb = jnp.where(kb == UNBOUND, B_NULL, kb)
    return jnp.where(_valid_mask(b.capacity, b.n), kb, B_SENT)


def device_scan_windowed(rows: jax.Array, n: jax.Array, s_bound,
                         out_cols: Sequence[int],
                         out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Bound-subject scan over a subject-sorted table: the matching rows
    are one contiguous window found by binary search and need no compact
    sort, so the cost is O(log T + out_cap) instead of the full-table
    mask-and-compact of :func:`device_scan` — the difference between a
    per-request scan and a per-batch-element scan being effectively free.
    PAD rows sort after every valid id, so the search never needs the
    valid count.  Only usable without an object post-filter: overflow is
    the raw window width vs ``out_cap``, which for a filtered scan would
    be conservative (a hub subject with a selective object filter would
    permanently inflate the step's capacity — callers route that case to
    :func:`device_scan`, which counts true matches)."""
    cap = rows.shape[0]
    col = rows[:, 0]
    sb = jnp.asarray(s_bound, dtype=jnp.int32)
    lo = jnp.searchsorted(col, sb, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(col, sb, side="right").astype(jnp.int32)
    idx = lo + jnp.arange(out_cap, dtype=jnp.int32)
    keep = idx < hi
    g = rows[jnp.clip(idx, 0, cap - 1)]
    projected = g[:, list(out_cols)] if out_cols else g[:, :0]
    data = jnp.where(keep[:, None], projected, PAD)
    return data, jnp.minimum(hi - lo, out_cap), hi - lo > out_cap


def _join_expand(a: JBindings, b: JBindings, out_cap: int,
                 b_presorted: Optional[Tuple[jax.Array, jax.Array]] = None):
    """Shared expansion machinery of the join family: pair every probe
    row with its build-side matches into ``out_cap`` output slots.

    Returns ``(out_cols, data, a_idx, valid, total, needs_compact)``:
    ``a_idx[j]`` is the probe row that produced slot ``j`` (the hook the
    left-outer join uses to compute its matched set), ``valid`` the
    kept-slot mask, ``total`` the true (uncapped) match count.  When
    ``needs_compact`` is False the valid slots are already contiguous at
    the front (``valid == j < total``)."""
    shared = [c for c in a.cols if c in b.cols]
    b_only = [c for c in b.cols if c not in a.cols]
    out_cols = a.cols + tuple(b_only)

    cap_a, cap_b = a.capacity, b.capacity
    if not shared:  # cross join (rare; bounded by caps)
        ii = jnp.arange(out_cap, dtype=jnp.int32)
        a_idx = jnp.clip(ii // jnp.maximum(b.n, 1), 0, cap_a - 1)
        b_idx = ii % jnp.maximum(b.n, 1)
        total = a.n * b.n
        valid = ii < total
        data = jnp.concatenate(
            [a.data[a_idx], b.data[jnp.clip(b_idx, 0, cap_b - 1)]], axis=1)
        return out_cols, data, a_idx, valid, total, False

    ka = a.data[:, a.cols.index(shared[0])]
    ka = jnp.where(ka == UNBOUND, A_NULL, ka)
    ka = jnp.where(_valid_mask(cap_a, a.n), ka, A_SENT)
    if b_presorted is None:
        kb = build_key(b, b.cols.index(shared[0]))
        order_b = jnp.argsort(kb).astype(jnp.int32)
        kb_sorted = kb[order_b]
    else:
        order_b, kb_sorted = b_presorted
    lo = jnp.searchsorted(kb_sorted, ka, side="left").astype(jnp.int32)
    hi = jnp.searchsorted(kb_sorted, ka, side="right").astype(jnp.int32)
    cnt = hi - lo
    prefix = jnp.cumsum(cnt) - cnt               # exclusive prefix
    total = prefix[-1] + cnt[-1]

    j = jnp.arange(out_cap, dtype=jnp.int32)
    # rank search: which probe row produced output slot j
    a_idx = jnp.searchsorted(prefix + cnt, j, side="right").astype(jnp.int32)
    a_idx = jnp.clip(a_idx, 0, cap_a - 1)
    off = j - prefix[a_idx]
    b_pos = jnp.clip(lo[a_idx] + off, 0, cap_b - 1).astype(jnp.int32)
    b_idx = order_b[b_pos]
    valid = j < total

    left = a.data[a_idx]
    right = b.data[b_idx]

    # post-filter shared columns beyond the key (SQL NULL semantics)
    for c in shared[1:]:
        va = left[:, a.cols.index(c)]
        vb = right[:, b.cols.index(c)]
        valid &= (va == vb) & (va != UNBOUND)

    pieces = [left]
    if b_only:
        pieces.append(right[:, [b.cols.index(c) for c in b_only]])
    data = jnp.concatenate(pieces, axis=1)
    return out_cols, data, a_idx, valid, total, bool(shared[1:])


def device_join(a: JBindings, b: JBindings, out_cap: int,
                b_presorted: Optional[Tuple[jax.Array, jax.Array]] = None
                ) -> JBindings:
    """Natural join of two static relations (sort-merge, rank expansion).

    ``b_presorted`` is an optional ``(order_b, kb_sorted)`` pair from
    :func:`build_key` + sort, letting callers hoist the O(n log n)
    build-side sort out of a vmapped batch when ``b`` does not depend on
    the bound constants."""
    out_cols, data, _, valid, total, needs_compact = _join_expand(
        a, b, out_cap, b_presorted)
    if needs_compact:
        data, n, ovf = _compact(data, valid, out_cap)
    else:
        # matches are contiguous at j < total (cross join, or the
        # overwhelmingly common single-shared-variable star/chain case):
        # masking replaces the O(out_cap log out_cap) compact sort
        data = jnp.where(valid[:, None], data, PAD)
        n = jnp.minimum(total, out_cap).astype(jnp.int32)
        ovf = jnp.asarray(False)
    return JBindings(out_cols, data, n,
                     a.overflow | b.overflow | ovf | (total > out_cap))


def device_left_join(a: JBindings, b: JBindings, out_cap: int,
                     expr: Optional[FilterExpr] = None,
                     values: Optional[jax.Array] = None,
                     fconsts: Optional[jax.Array] = None,
                     ctr: Optional[List[int]] = None) -> JBindings:
    """OPTIONAL: left-outer join.  Inner rows first (probe-major, build
    rows in original order — the natural-join order), then each
    unmatched probe row once, UNBOUND-padded on the build-only columns,
    in probe order — exactly the eager ``left_outer_join`` sequence, so
    row-for-row parity with the host engines holds without a sort.

    ``expr`` is OPTIONAL's join condition: it filters the INNER rows
    only (a probe row whose matches all fail the condition comes out
    unmatched), with constants riding the shared runtime ``fconsts``
    vector like every other filter."""
    out_cols, data, a_idx, valid, total, _ = _join_expand(a, b, out_cap)
    cap_a = a.capacity
    if expr is not None:
        inner = JBindings(out_cols, data,
                          jnp.asarray(out_cap, jnp.int32), jnp.asarray(False))
        valid = valid & _filter_mask(expr, inner, values, fconsts, ctr)

    # matched set: scatter hit flags through a_idx (invalid slots are
    # routed to a dump slot so clipped indices cannot pollute the flags)
    hit = jnp.zeros((cap_a + 1,), bool) \
        .at[jnp.where(valid, a_idx, cap_a)].set(True)[:cap_a]
    unmatched = _valid_mask(cap_a, a.n) & ~hit

    k_b = len(out_cols) - len(a.cols)
    tail = a.data if not k_b else jnp.concatenate(
        [a.data, jnp.full((cap_a, k_b), UNBOUND, jnp.int32)], axis=1)
    buf = jnp.concatenate([data, tail], axis=0)
    keep = jnp.concatenate([valid, unmatched])
    out, n, ovf = _compact(buf, keep, out_cap)
    # total > out_cap also voids the matched-set computation (cut slots
    # never set their hit flag), so the overflow retry covers it
    return JBindings(out_cols, out, n,
                     a.overflow | b.overflow | ovf | (total > out_cap))


def device_union(a: JBindings, b: JBindings, out_cap: int) -> JBindings:
    """UNION: both operands lifted to the column union (UNBOUND fill),
    left rows first then right rows — the eager ``union`` sequence —
    via one stable compact over the concatenated buffers."""
    cols = a.cols + tuple(c for c in b.cols if c not in a.cols)

    def lift(x: JBindings) -> jax.Array:
        cap = x.capacity
        if not cols:
            return x.data[:, :0]
        arrs = [x.data[:, x.cols.index(c)] if c in x.cols
                else jnp.full((cap,), UNBOUND, jnp.int32) for c in cols]
        d = jnp.stack(arrs, axis=1)
        return jnp.where(_valid_mask(cap, x.n)[:, None], d, PAD)

    buf = jnp.concatenate([lift(a), lift(b)], axis=0)
    keep = jnp.concatenate([_valid_mask(a.capacity, a.n),
                            _valid_mask(b.capacity, b.n)])
    data, n, ovf = _compact(buf, keep, out_cap)
    return JBindings(cols, data, n, a.overflow | b.overflow | ovf)


def device_scan_tt(rows: jax.Array, n: jax.Array, s_bound, p_bound, o_bound,
                   eqs: Sequence[Tuple[int, int]], take: Sequence[int],
                   out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Select + project over the (N, 3) triples table — the unbound-
    predicate scan (and the ``layout="tt"`` baseline scan).  Bound s/o
    constants are runtime scalars like :func:`device_scan`'s; the bound
    predicate of a TT-layout scan is trace-time static (predicates are
    plan identity and never template-rebindable).  ``eqs`` carries the
    repeated-variable equality selections of patterns like ``?x ?p ?x``."""
    cap = rows.shape[0]
    keep = _valid_mask(cap, n)
    if s_bound is not None:
        keep &= rows[:, 0] == s_bound
    if p_bound is not None:
        keep &= rows[:, 1] == p_bound
    if o_bound is not None:
        keep &= rows[:, 2] == o_bound
    for i, j in eqs:
        keep &= rows[:, i] == rows[:, j]
    projected = rows[:, list(take)] if take else rows[:, :0]
    return _compact(projected, keep, out_cap)


# ---------------------------------------------------------------------------
# Solution modifiers on device (the spine of repro.core.modifiers)
#
# All five operators keep the JBindings invariant — valid rows occupy
# [0, n) contiguously with PAD rows behind — and none can overflow (a
# modifier never grows the relation), so the per-step overflow/retry
# protocol of the scan/join pipeline is untouched.
# ---------------------------------------------------------------------------

def _filter_operand(b: JBindings, values: jax.Array, term, numeric: bool,
                    fconsts: jax.Array, ctr: List[int]):
    """(ids, numeric (hi, lo) key pair) for one comparison operand.
    Constant ids are *runtime* scalars read from ``fconsts`` (slot order
    fixed by :func:`repro.core.modifiers.filter_const_slots`), so
    re-binding a template constant never re-traces; float literals are
    trace-time constants (they are part of the template text).  A
    variable the relation does not bind is UNBOUND everywhere — the
    eager ``_operand`` semantics, which OPTIONAL/UNION columns rely on."""
    cap = b.capacity
    nv = values.shape[0]
    dt = values.dtype
    if isinstance(term, str):            # variable
        if term in b.cols:
            ids = b.data[:, b.cols.index(term)]
        else:
            ids = jnp.full((cap,), UNBOUND, jnp.int32)
        if not numeric:
            return ids, None
        if nv:
            safe = jnp.clip(ids, 0, nv - 1)
            ok = ids >= 0
            hi = jnp.where(ok, values[safe, 0], jnp.nan)
            lo = jnp.where(ok, values[safe, 1], jnp.nan)
        else:
            hi = jnp.full((cap,), jnp.nan, dt)
            lo = hi
        return ids, (hi, lo)
    if isinstance(term, float):          # numeric literal (trace-time)
        fhi, flo = _split_scalar(term)
        return None, (jnp.full((cap,), fhi, dt), jnp.full((cap,), flo, dt))
    tid = fconsts[ctr[0]]                # constant id -> runtime slot
    ctr[0] += 1
    ids = jnp.full((cap,), tid, jnp.int32)
    if not numeric:
        return ids, None
    if nv:
        ok = (tid >= 0) & (tid < nv)
        safe = jnp.clip(tid, 0, nv - 1)
        hi = jnp.where(ok, values[safe, 0], jnp.nan)
        lo = jnp.where(ok, values[safe, 1], jnp.nan)
    else:
        hi = jnp.asarray(jnp.nan, dt)
        lo = hi
    return ids, (jnp.full((cap,), hi, dt), jnp.full((cap,), lo, dt))


def _filter_mask(expr: FilterExpr, b: JBindings, values: jax.Array,
                 fconsts: jax.Array, ctr: List[int]) -> jax.Array:
    """Boolean keep-mask over the relation's rows; mirrors the eager
    :func:`repro.core.executor.eval_filter` semantics exactly (identity
    comparison on ids, numeric comparison through the dictionary's
    double-single key pairs, UNBOUND/type-error rows dropped).  NaN key
    pairs make every comparison false, matching host NaN semantics."""
    if isinstance(expr, BoolOp):
        masks = [_filter_mask(e, b, values, fconsts, ctr) for e in expr.args]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if expr.op == "&&" else (out | m)
        return out
    if isinstance(expr, NotExpr):
        return ~_filter_mask(expr.arg, b, values, fconsts, ctr)
    if isinstance(expr, Bound):
        if expr.var not in b.cols:
            return jnp.zeros((b.capacity,), bool)
        return b.data[:, b.cols.index(expr.var)] != UNBOUND
    assert isinstance(expr, Cmp)
    numeric = expr.op in ("<", "<=", ">", ">=") or \
        isinstance(expr.lhs, float) or isinstance(expr.rhs, float)
    lid, lpair = _filter_operand(b, values, expr.lhs, numeric, fconsts, ctr)
    rid, rpair = _filter_operand(b, values, expr.rhs, numeric, fconsts, ctr)
    if numeric:
        lhi, llo = lpair
        rhi, rlo = rpair
        eq = (lhi == rhi) & (llo == rlo)
        lt = (lhi < rhi) | ((lhi == rhi) & (llo < rlo))
        if expr.op == "=":
            return eq
        if expr.op == "!=":
            return ~eq & ~jnp.isnan(lhi) & ~jnp.isnan(rhi)
        if expr.op == "<":
            return lt
        if expr.op == "<=":
            return lt | eq
        if expr.op == ">":
            return ~(lt | eq) & ~jnp.isnan(lhi) & ~jnp.isnan(rhi)
        return ~lt & ~jnp.isnan(lhi) & ~jnp.isnan(rhi)
    ok = (lid != UNBOUND) & (rid != UNBOUND)
    return ((lid == rid) if expr.op == "=" else (lid != rid)) & ok


def device_filter(b: JBindings, expr: FilterExpr, values: jax.Array,
                  fconsts: jax.Array, ctr: List[int]) -> JBindings:
    """FILTER: mask + stable compact (kept rows stay in order)."""
    keep = _filter_mask(expr, b, values, fconsts, ctr) & \
        _valid_mask(b.capacity, b.n)
    data, n, _ = _compact(b.data, keep, b.capacity)
    return JBindings(b.cols, data, n, b.overflow)


def device_project(b: JBindings, out_vars: Sequence[str]) -> JBindings:
    """Projection: gather the selected columns (UNBOUND-fill variables
    the pipeline does not produce), re-PAD invalid rows."""
    cap = b.capacity
    if not out_vars:
        return JBindings((), b.data[:, :0], b.n, b.overflow)
    cols = [b.data[:, b.cols.index(v)] if v in b.cols
            else jnp.full((cap,), UNBOUND, jnp.int32) for v in out_vars]
    data = jnp.stack(cols, axis=1)
    data = jnp.where(_valid_mask(cap, b.n)[:, None], data, PAD)
    return JBindings(tuple(out_vars), data, b.n, b.overflow)


def device_resize(b: JBindings, out_cap: int
                  ) -> Tuple[JBindings, jax.Array]:
    """Re-buffer the relation to ``out_cap`` rows — a pure static
    truncation (valid rows are contiguous at the front by the pipeline
    invariant, so no sort/gather is needed).  Returns the relation and
    an overflow flag for the retry protocol: DISTINCT/ORDER BY sort this
    buffer, so right-sizing it is what keeps modifier queries from
    paying an O(join_cap log join_cap) sort over mostly-PAD rows."""
    cap, k = b.data.shape
    if out_cap < cap:
        data = b.data[:out_cap]
    elif out_cap > cap:
        data = jnp.concatenate(
            [b.data, jnp.full((out_cap - cap, k), PAD, b.data.dtype)], axis=0)
    else:
        data = b.data
    ovf = b.n > out_cap
    return JBindings(b.cols, data, jnp.minimum(b.n, out_cap),
                     b.overflow), ovf


def device_distinct(b: JBindings) -> JBindings:
    """DISTINCT: lexsort + adjacent-unique to find duplicates, then a
    stable compact of the FIRST occurrence of each distinct row in the
    original order — exactly the eager engine's first-occurrence-stable
    dedup, so an order established before (or after) it survives."""
    cap, k = b.data.shape
    if k == 0:   # zero-column relation: dedup of n empty mappings is one
        return JBindings(b.cols, b.data, jnp.minimum(b.n, 1), b.overflow)
    valid = _valid_mask(cap, b.n)
    keys = [b.data[:, j] for j in range(k - 1, -1, -1)]
    keys.append((~valid).astype(jnp.int32))        # valid rows first
    order = jnp.lexsort(keys)
    sdata = b.data[order]
    svalid = valid[order]
    same_prev = jnp.concatenate([
        jnp.zeros((1,), bool),
        jnp.all(sdata[1:] == sdata[:-1], axis=1)])
    keep_sorted = svalid & ~same_prev
    keep = jnp.zeros(cap, bool).at[order].set(keep_sorted)
    data, n, _ = _compact(b.data, keep, cap)
    return JBindings(b.cols, data, n, b.overflow)


def device_order(b: JBindings, keys: Sequence[Tuple[str, bool]],
                 values: jax.Array) -> JBindings:
    """ORDER BY: stable lexsort over the dictionary's double-single
    ``(ord_hi, ord_lo)`` key pairs (numeric literals by value, other
    terms by id — the eager ``order_rows`` semantics); UNBOUND sorts
    last (SQL NULLS LAST, shared by all engines); PAD rows keep sorting
    behind every valid row."""
    cap = b.capacity
    valid = _valid_mask(cap, b.n)
    nv = values.shape[0]
    dt = values.dtype
    ks = []
    for var, asc in reversed(tuple(keys)):
        if var not in b.cols:
            continue                      # unbound key: constant, no-op
        ids = b.data[:, b.cols.index(var)]
        if nv:
            safe = jnp.clip(ids, 0, nv - 1)
            ok = ids >= 0
            hi = jnp.where(ok, values[safe, 2], ids.astype(dt))
            lo = jnp.where(ok, values[safe, 3], jnp.zeros((cap,), dt))
        else:
            hi = ids.astype(dt)
            lo = jnp.zeros((cap,), dt)
        hi = jnp.where(ids == UNBOUND, jnp.asarray(jnp.inf, dt), hi)
        if not asc:
            hi, lo = -hi, -lo
        ks.append(lo)                     # minor half of the pair first
        ks.append(hi)                     # lexsort: later keys dominate
    if not ks:
        return b
    ks.append((~valid).astype(jnp.int32))          # valid rows first
    order = jnp.lexsort(ks)
    return JBindings(b.cols, b.data[order], b.n, b.overflow)


def device_slice(b: JBindings, offset: int, limit: Optional[int]) -> JBindings:
    """OFFSET/LIMIT: static row-window over the compacted relation.  A
    LIMIT below the buffer capacity also *trims the buffer*, so only the
    final ≤ limit rows ever transfer back to the host."""
    cap, k = b.data.shape
    data, n = b.data, b.n
    if offset:
        shift = min(int(offset), cap)
        data = jnp.concatenate(
            [data[shift:], jnp.full((shift, k), PAD, data.dtype)], axis=0)
        n = jnp.maximum(n - offset, 0)
    if limit is not None:
        n = jnp.minimum(n, limit)
        if limit < cap:
            data = data[:max(int(limit), 0)]
    return JBindings(b.cols, data, n, b.overflow)


# ---------------------------------------------------------------------------
# Plan executor
# ---------------------------------------------------------------------------

def _step_meta(step: ScanStep) -> Tuple[Optional[int], Optional[int], bool,
                                        Tuple[int, ...], Tuple[str, ...]]:
    tp = step.tp
    s_bound = None if is_var(tp.s) else int(tp.s)
    o_bound = None if is_var(tp.o) else int(tp.o)
    same = is_var(tp.s) and is_var(tp.o) and tp.s == tp.o
    cols: List[str] = []
    take: List[int] = []
    if is_var(tp.s):
        cols.append(tp.s)
        take.append(0)
    if is_var(tp.o) and tp.o not in cols:
        cols.append(tp.o)
        take.append(1)
    return s_bound, o_bound, same, tuple(take), tuple(cols)


def _tt_meta(tp) -> Tuple[Optional[int], Optional[int], Optional[int],
                          Tuple[Tuple[int, int], ...], Tuple[int, ...],
                          Tuple[str, ...]]:
    """Static scan metadata of a triples-table step: per-position bound
    constants (presence is static; s/o VALUES ride the runtime bounds
    array, the predicate is trace-time static), repeated-variable
    equality selections, and the projected (s, p, o)-first-seen columns
    — the eager ``_scan_tt`` layout."""
    terms = (tp.s, tp.p, tp.o)
    s_b, p_b, o_b = (None if is_var(t) else int(t) for t in terms)
    cols: List[str] = []
    take: List[int] = []
    eqs: List[Tuple[int, int]] = []
    first: Dict[str, int] = {}
    for i, t in enumerate(terms):
        if not is_var(t):
            continue
        if t in first:
            eqs.append((first[t], i))
        else:
            first[t] = i
            cols.append(t)
            take.append(i)
    return s_b, p_b, o_b, tuple(eqs), tuple(take), tuple(cols)


def _step_cols(step: ScanStep) -> Tuple[str, ...]:
    if step.uses_tt:
        return _tt_meta(step.tp)[5]
    return _step_meta(step)[4]


_TRACE_COUNT = 0   # program traces (== XLA compiles); test probe


def trace_count() -> int:
    """Number of static programs traced so far in this process.  A served
    template workload should increase this once per (template, caps), not
    once per request — the observable for "no recompilation on re-bind"."""
    return _TRACE_COUNT


def bounds_from_plan(plan: Plan) -> np.ndarray:
    """Per-step (s, o) bound-constant values, UNBOUND where the slot is a
    variable — the runtime argument vector of the compiled program."""
    out = np.full((len(plan.steps), 2), UNBOUND, dtype=np.int32)
    for i, step in enumerate(plan.steps):
        if not is_var(step.tp.s):
            out[i, 0] = int(step.tp.s)
        if not is_var(step.tp.o):
            out[i, 1] = int(step.tp.o)
    return out


def _pipeline_cols(plan: Plan) -> Tuple[str, ...]:
    """Variables the scan/join pipeline produces, first-seen order."""
    cols: List[str] = []
    for step in plan.steps:
        for v in _step_cols(step):
            if v not in cols:
                cols.append(v)
    return tuple(cols)


def _exec_cols(seg: CoreSeg) -> Tuple[str, ...]:
    """Columns the device evaluation of a segment produces, in pipeline
    order (scan order within a BGP; left-then-right-only for combines —
    the same construction the eager tree evaluation uses)."""
    if isinstance(seg, EmptySeg):
        return tuple(seg.vars)
    if isinstance(seg, BGPSeg):
        return _pipeline_cols(seg.plan)
    if isinstance(seg, FilterSeg):
        return _exec_cols(seg.child)
    left = _exec_cols(seg.left)
    return left + tuple(c for c in _exec_cols(seg.right) if c not in left)


def _mod_cap_seed(spine: ModifierSpine, pipeline_cap: int) -> int:
    """Initial capacity of the modifier resize slot: generous around the
    slice window when there is one, a modest constant otherwise; never
    beyond the pipeline buffer (more rows cannot exist) and never below
    1/32 of it, so the overflow-retry loop reaches any true result size
    within its doubling budget."""
    if spine.limit is not None:
        est = max(64, 4 * (spine.offset + spine.limit))
    else:
        est = 4096
    est = max(est, pipeline_cap // 32)
    return min(round_up_pow2(est, 64), round_up_pow2(pipeline_cap, 64))


def double_caps(caps: Tuple[int, ...], ovf, n_steps: int) -> Tuple[int, ...]:
    """One overflow-retry step: double every overflowing capacity.  The
    modifier resize slot (index ``n_steps``, when present) additionally
    keeps pace with the pipeline caps — its overflow flag only fires
    once the pipeline actually delivers more rows, so without the floor
    the two growth phases would run in series and could exhaust the
    retry budget on explosive joins."""
    new = [c * 2 if ovf[i] else c for i, c in enumerate(caps)]
    if len(new) > n_steps and n_steps:
        pipe_max = max(new[:n_steps])
        new[n_steps] = min(max(new[n_steps], pipe_max // 4),
                           round_up_pow2(pipe_max, 64))
    return tuple(new)


def _spine_uses_values(spine: ModifierSpine) -> bool:
    """True when the compiled spine reads the numeric key table:
    ORDER BY keys, or any filter comparison that is numeric (order ops,
    or a float literal operand).  Identity-only filters don't."""
    return bool(spine.order) or _exprs_use_values(spine.filters)


def check_spine(spine: ModifierSpine, pipe_cols: Tuple[str, ...],
                catalog: Optional[Catalog] = None) -> Tuple[str, ...]:
    """Output columns of a spine over a pipeline binding ``pipe_cols``.

    Historically this also rejected filter variables outside the
    pipeline and non-float32-exact value tables; both limits are gone —
    missing filter variables are UNBOUND everywhere (the eager
    semantics) and numeric keys use exact double-single float32 pairs
    (validated by :func:`prepare_value_keys`, which still raises the
    backends' NotImplementedError fallback signal for tables whose keys
    the pair encoding cannot distinguish)."""
    return tuple(spine.project) if spine.project is not None else pipe_cols


class PlanExecutor:
    """Builds and runs the jitted static program for a compiled core.

    Accepts either a flat :class:`Plan` (a single BGP — the historical
    construction, still used directly by tests and benchmarks) or a
    :class:`CorePlan` segment tree covering FILTER/OPTIONAL/UNION cores
    and unbound-predicate (TT) scans.

    ``caps[i]`` for ``i < len(plan.steps)`` bounds the output of flat
    step i within its BGP segment (a segment's first step compacts to
    its cap; joins within the segment write at the following caps);
    combine segments (join/left/union) get their own capacity slots
    behind the flat steps, in evaluation (post-) order.  ``run`` retries
    with doubled caps on overflow (host loop, geometric — at most
    ~log2(result/estimate) recompiles, amortized across a served
    workload).

    Bound s/o constants enter the program as runtime int32 scalars (their
    *presence* is static, their values are not), so every instantiation of
    a query template shares one compiled program — ``run(bounds=...)``
    re-binds without re-tracing.

    ``spine`` appends the query's solution modifiers to the traced
    program (FILTER masks, on-device projection, sort-based DISTINCT,
    value-table ORDER BY, static OFFSET/LIMIT window); filter constants —
    the spine's AND the core's (OPTIONAL conditions, FILTER segments) —
    share one runtime ``fconsts`` input consumed in evaluation order, so
    modifier-bearing templates re-bind without re-tracing too.
    """

    bounds_from_plan = staticmethod(bounds_from_plan)

    def __init__(self, plan, catalog: Catalog, slack: float = 1.5,
                 spine: Optional[ModifierSpine] = None):
        if isinstance(plan, CorePlan):
            core = plan
        else:
            core = CorePlan(root=BGPSeg(plan=plan, start=0), flat=plan,
                            empty=plan.empty, vars=plan.vars)
        if core.empty:
            raise ValueError("cannot build executor for statistics-empty plan")
        self.core = core
        self.plan = core.flat      # what template re-binding operates on
        self.catalog = catalog
        self.spine = spine if spine is not None else ModifierSpine()
        self._pipe_cols = _exec_cols(core.root)
        self._out_vars = check_spine(self.spine, self._pipe_cols, catalog)
        self._core_filters = core_filter_exprs(core.root)
        self._all_filters = tuple(self._core_filters) + \
            tuple(self.spine.filters)
        self.filter_slots = filter_const_slots(self._all_filters)
        # raises NotImplementedError (→ counted eager fallback) only for
        # dictionaries whose numeric keys defeat the double-single pairs
        self._value_keys = prepare_value_keys(catalog, self.spine,
                                              self._all_filters)
        # DISTINCT/ORDER BY sort the whole static buffer; the join caps
        # are sized for the worst unfiltered join, which would make every
        # modifier query pay an O(cap log cap) sort over mostly-PAD rows.
        # Instead the spine starts from its own small capacity slot (an
        # overflow-checked compact before the sorts, appended to ``caps``
        # so the retry protocol grows it geometrically when a template's
        # true result is larger — and the grown cap persists).
        self._mod_resize = bool(self.spine.distinct or self.spine.order)
        self.tables = [
            None if step.uses_tt
            else catalog.table(step.kind, int(step.tp.p), step.p2)
            for step in self.plan.steps]
        self._has_tt = any(s.uses_tt for s in self.plan.steps)
        n_flat = len(self.plan.steps)
        flat_caps = [16] * n_flat
        comb_caps: List[int] = []
        self._comb_index: Dict[int, int] = {}

        def seed(seg: CoreSeg) -> float:
            if isinstance(seg, EmptySeg):
                return 1.0
            if isinstance(seg, FilterSeg):
                return seed(seg.child)
            if isinstance(seg, BGPSeg):
                est = 1.0
                for k, step in enumerate(seg.plan.steps):
                    i = seg.start + k
                    size = catalog.n_triples if step.uses_tt \
                        else len(self.tables[i])
                    scan_est = max(1.0, float(size))
                    if step.tp.n_bound() > 1:
                        scan_est = max(1.0, scan_est * 0.01)
                    est = scan_est if k == 0 else \
                        max(est, scan_est, est * 1.25)
                    flat_caps[i] = round_up_pow2(int(est * slack) + 8, 16)
                return est
            le, re_ = seed(seg.left), seed(seg.right)
            if seg.kind == "join":
                est = 1.25 * max(le, re_)
            elif seg.kind == "left":
                # inner rows plus (worst case) every left row unmatched
                est = 1.25 * max(le, re_) + le
            else:
                est = le + re_
            self._comb_index[id(seg)] = n_flat + len(comb_caps)
            comb_caps.append(round_up_pow2(int(est * slack) + 8, 16))
            return est

        seed(core.root)
        self.caps = flat_caps + comb_caps
        self._n_pipeline = len(self.caps)
        if self._mod_resize:
            pipe_cap = max(self.caps) if self.caps else 64
            self.caps.append(_mod_cap_seed(self.spine, pipe_cap))
        self._default_bounds = bounds_from_plan(self.plan)

    def fconsts_from_mapping(self, mapping=None) -> np.ndarray:
        """Runtime filter-constant vector for one binding: template
        placeholder ids resolve through ``mapping``, concrete ids pass
        through — the filter counterpart of ``bounds_from_plan``."""
        m = mapping or {}
        return np.asarray([m.get(c, c) for c in self.filter_slots],
                          dtype=np.int32)

    def _apply_spine(self, b: JBindings, values: jax.Array,
                     fconsts: jax.Array, caps: Tuple[int, ...],
                     ctr: List[int]) -> Tuple[JBindings, Optional[jax.Array]]:
        """FILTER* → [resize] → ORDER BY → project → DISTINCT →
        OFFSET/LIMIT, the canonical host sequence lowered onto the
        static relation (ordering precedes projection so sort keys
        outside the SELECT list work, exactly like the host engines).
        ``ctr`` is the fconsts cursor, shared with the core's filters
        (which consume their slots first).  Returns the relation and the
        resize step's overflow flag (None when the spine needs no
        sorts)."""
        sp = self.spine
        for expr in sp.filters:
            b = device_filter(b, expr, values, fconsts, ctr)
        mod_ovf = None
        if self._mod_resize:
            b, mod_ovf = device_resize(b, caps[self._n_pipeline])
        if sp.order:
            b = device_order(b, sp.order, values)
        b = device_project(b, self._out_vars)
        if sp.distinct:
            b = device_distinct(b)
        if sp.has_slice:
            b = device_slice(b, sp.offset, sp.limit)
        return b, mod_ovf

    # -- the traced program --------------------------------------------------
    def _scan_step(self, i: int, step: ScanStep, first: bool,
                   table_rows: List[jax.Array], table_ns: List[jax.Array],
                   tt_rows: jax.Array, tt_n: jax.Array, bounds: jax.Array,
                   caps: Tuple[int, ...]) -> JBindings:
        """One scan, picking the windowed form when the subject is bound
        (tables are subject-sorted, see :class:`repro.core.table.Table`);
        TT steps (unbound predicates, ``layout="tt"``) scan the shared
        padded triples table.  ``first`` marks the first step of a BGP
        segment, which compacts to its own capacity slot."""
        if step.uses_tt:
            s_b, p_b, o_b, eqs, take, cols = _tt_meta(step.tp)
            out_cap = caps[i] if first else tt_rows.shape[0]
            sb = bounds[i, 0] if s_b is not None else None
            ob = bounds[i, 1] if o_b is not None else None
            data, n, ovf = device_scan_tt(tt_rows, tt_n, sb, p_b, ob,
                                          eqs, take, out_cap)
            return JBindings(cols, data, n, ovf)
        s_bound, o_bound, same, take, cols = _step_meta(step)
        out_cap = caps[i] if first else table_rows[i].shape[0]
        sb = bounds[i, 0] if s_bound is not None else None
        ob = bounds[i, 1] if o_bound is not None else None
        if s_bound is not None and o_bound is None:
            data, n, ovf = device_scan_windowed(table_rows[i], table_ns[i],
                                                sb, take, out_cap)
        else:
            data, n, ovf = device_scan(table_rows[i], table_ns[i], sb, ob,
                                       same, take, out_cap)
        return JBindings(cols, data, n, ovf)

    def _compose_bgp(self, seg: BGPSeg, caps: Tuple[int, ...],
                     table_rows: List[jax.Array], table_ns: List[jax.Array],
                     tt_rows: jax.Array, tt_n: jax.Array, bounds: jax.Array,
                     ovfs: List[jax.Array],
                     shared: Dict[int, Tuple[JBindings, Optional[Tuple[jax.Array, jax.Array]]]]
                     ) -> JBindings:
        """The scan/join pipeline of one BGP segment.  Overflow is
        recorded PER STEP into ``ovfs`` (at the step's flat index) so
        the host retry doubles only the capacities that actually
        overflowed — wholesale doubling let one heavy constant inflate
        every buffer of the program, which is poison for batched serving
        (all batch elements pay the worst element's caps).  ``shared``
        maps flat step index -> precomputed (relation, presorted join
        key) for bounds-independent scans (empty for the single-request
        program)."""
        no = jnp.asarray(False)
        if not seg.plan.steps:
            # empty BGP: the unit relation (one empty solution mapping)
            return JBindings((), jnp.zeros((8, 0), jnp.int32),
                             jnp.asarray(1, jnp.int32), no)
        acc: Optional[JBindings] = None
        for k, step in enumerate(seg.plan.steps):
            i = seg.start + k
            if i in shared:
                cur, pre = shared[i]
            else:
                cur = self._scan_step(i, step, k == 0, table_rows, table_ns,
                                      tt_rows, tt_n, bounds, caps)
                pre = None
            if acc is None:
                acc = cur
                ovfs[i] = cur.overflow
            else:
                # strip sticky input flags: we want this join's OWN overflow
                joined = device_join(
                    JBindings(acc.cols, acc.data, acc.n, no),
                    JBindings(cur.cols, cur.data, cur.n, no), caps[i],
                    b_presorted=pre)
                ovfs[i] = joined.overflow | cur.overflow
                acc = joined
        assert acc is not None
        return JBindings(acc.cols, acc.data, acc.n, no)

    def _eval_seg(self, seg: CoreSeg, caps: Tuple[int, ...],
                  table_rows: List[jax.Array], table_ns: List[jax.Array],
                  tt_rows: jax.Array, tt_n: jax.Array, bounds: jax.Array,
                  fconsts: jax.Array, values: jax.Array, ctr: List[int],
                  ovfs: List[jax.Array],
                  shared: Dict[int, Tuple[JBindings, Optional[Tuple[jax.Array, jax.Array]]]]
                  ) -> JBindings:
        """Evaluate the core segment tree to one static relation.  Each
        combine writes its own overflow flag at its capacity index;
        child flags are recorded at the children, so every returned
        relation carries a clean (False) sticky flag."""
        no = jnp.asarray(False)
        if isinstance(seg, EmptySeg):
            k = len(seg.vars)
            return JBindings(tuple(seg.vars),
                             jnp.full((8, k), PAD, jnp.int32),
                             jnp.asarray(0, jnp.int32), no)
        if isinstance(seg, BGPSeg):
            return self._compose_bgp(seg, caps, table_rows, table_ns,
                                     tt_rows, tt_n, bounds, ovfs, shared)
        if isinstance(seg, FilterSeg):
            b = self._eval_seg(seg.child, caps, table_rows, table_ns,
                               tt_rows, tt_n, bounds, fconsts, values, ctr,
                               ovfs, shared)
            return device_filter(b, seg.expr, values, fconsts, ctr)
        left = self._eval_seg(seg.left, caps, table_rows, table_ns,
                              tt_rows, tt_n, bounds, fconsts, values, ctr,
                              ovfs, shared)
        right = self._eval_seg(seg.right, caps, table_rows, table_ns,
                               tt_rows, tt_n, bounds, fconsts, values, ctr,
                               ovfs, shared)
        ci = self._comb_index[id(seg)]
        if seg.kind == "join":
            out = device_join(left, right, caps[ci])
        elif seg.kind == "left":
            out = device_left_join(left, right, caps[ci], seg.expr,
                                   values, fconsts, ctr)
        else:
            out = device_union(left, right, caps[ci])
        ovfs[ci] = out.overflow
        return JBindings(out.cols, out.data, out.n, no)

    def _program(self, caps: Tuple[int, ...], table_rows: List[jax.Array],
                 table_ns: List[jax.Array], tt_rows: jax.Array,
                 tt_n: jax.Array, bounds: jax.Array, fconsts: jax.Array,
                 values: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
        global _TRACE_COUNT
        _TRACE_COUNT += 1
        ctr = [0]
        ovfs: List[jax.Array] = [jnp.asarray(False)] * self._n_pipeline
        b = self._eval_seg(self.core.root, caps, table_rows, table_ns,
                           tt_rows, tt_n, bounds, fconsts, values, ctr,
                           ovfs, {})
        b, mod_ovf = self._apply_spine(b, values, fconsts, caps, ctr)
        stacked = jnp.stack(ovfs) if ovfs else jnp.zeros((0,), bool)
        if mod_ovf is not None:
            stacked = jnp.concatenate([stacked, mod_ovf[None]])
        return b.data, b.n, stacked

    @functools.cached_property
    def _device_inputs(self) -> Tuple[List[jax.Array], List[jax.Array],
                                      jax.Array, jax.Array, jax.Array]:
        """Device-resident padded tables + the (optional) padded triples
        table + the numeric key table, uploaded ONCE per executor — the
        hot path must not re-pad and re-transfer O(table) bytes on every
        launch."""
        rows = [jnp.zeros((0, 2), jnp.int32) if t is None
                else jnp.asarray(t.to_device().rows) for t in self.tables]
        ns = [jnp.asarray(np.int32(0 if t is None else len(t)))
              for t in self.tables]
        if self._has_tt:
            tt = np.asarray(self.catalog.tt, dtype=np.int32)
            tt_rows = jnp.asarray(
                pad_rows(tt, round_up_pow2(max(len(tt), 1))))
            tt_n = jnp.asarray(np.int32(len(tt)))
        else:
            tt_rows = jnp.zeros((0, 3), jnp.int32)
            tt_n = jnp.asarray(np.int32(0))
        values = jnp.asarray(self._value_keys)
        return rows, ns, tt_rows, tt_n, values

    @functools.cached_property
    def _jitted(self):
        return jax.jit(self._program, static_argnums=(0,))

    # -- the batched traced program --------------------------------------------
    def _program_batched(self, caps: Tuple[int, ...],
                         table_rows: List[jax.Array],
                         table_ns: List[jax.Array], tt_rows: jax.Array,
                         tt_n: jax.Array, bounds_b: jax.Array,
                         fconsts_b: jax.Array, values: jax.Array
                         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """B constant-bindings of the template in one program.

        Constants only enter scan *selection values*, so any step whose
        triple pattern binds no constant produces the same relation for
        every batch element.  Those scans — and the build-side sort of
        the joins that consume them — are hoisted OUT of the vmap (per
        BGP segment) and computed once per launch; only the
        constant-dependent scans and the (capacity-bounded, small)
        probe/expand/combine phases replicate per element.  This is what
        makes a batch ~O(shared + B·small) instead of B times the full
        per-request program.
        """
        global _TRACE_COUNT
        _TRACE_COUNT += 1

        # shared phase: bounds-independent scans + their join-key presort
        shared: Dict[int, Tuple[JBindings, Optional[Tuple[jax.Array, jax.Array]]]] = {}

        def hoist(seg: CoreSeg) -> None:
            if isinstance(seg, FilterSeg):
                hoist(seg.child)
                return
            if isinstance(seg, CombineSeg):
                hoist(seg.left)
                hoist(seg.right)
                return
            if not isinstance(seg, BGPSeg):
                return
            acc_cols: List[str] = []
            for k, step in enumerate(seg.plan.steps):
                i = seg.start + k
                if step.uses_tt:
                    s_b, p_b, o_b, eqs, take, cols = _tt_meta(step.tp)
                    indep = k > 0 and s_b is None and o_b is None
                    if indep:
                        data, n, ovf = device_scan_tt(
                            tt_rows, tt_n, None, p_b, None, eqs, take,
                            tt_rows.shape[0])
                        cur = JBindings(cols, data, n, ovf)
                else:
                    s_bound, o_bound, same, take, cols = _step_meta(step)
                    indep = k > 0 and s_bound is None and o_bound is None
                    if indep:
                        data, n, ovf = device_scan(
                            table_rows[i], table_ns[i], None, None, same,
                            take, table_rows[i].shape[0])
                        cur = JBindings(cols, data, n, ovf)
                if indep:
                    # the join key device_join will pick: first
                    # accumulated column present on the build side
                    key = next((c for c in acc_cols if c in cols), None)
                    pre = None
                    if key is not None:
                        kb = build_key(cur, cols.index(key))
                        order_b = jnp.argsort(kb).astype(jnp.int32)
                        pre = (order_b, kb[order_b])
                    shared[i] = (cur, pre)
                for c in cols:
                    if c not in acc_cols:
                        acc_cols.append(c)

        hoist(self.core.root)

        def one(b, fc):
            ctr = [0]
            ovfs: List[jax.Array] = [jnp.asarray(False)] * self._n_pipeline
            jb = self._eval_seg(self.core.root, caps, table_rows, table_ns,
                                tt_rows, tt_n, b, fc, values, ctr, ovfs,
                                shared)
            jb, mod_ovf = self._apply_spine(jb, values, fc, caps, ctr)
            stacked = jnp.stack(ovfs) if ovfs else jnp.zeros((0,), bool)
            if mod_ovf is not None:
                stacked = jnp.concatenate([stacked, mod_ovf[None]])
            return jb.data, jb.n, stacked

        return jax.vmap(one)(bounds_b, fconsts_b)

    @functools.cached_property
    def _jitted_batch(self):
        # jax.jit caches per static (caps, B) pair, so trace_count() moves
        # once per (template, bucket-shape) — never once per request.
        return jax.jit(self._program_batched, static_argnums=(0,))

    def lower(self, caps: Optional[Tuple[int, ...]] = None):
        caps = caps or tuple(self.caps)
        rows = [jax.ShapeDtypeStruct(
                    (0 if t is None else round_up_pow2(len(t)), 2),
                    jnp.int32) for t in self.tables]
        ns = [jax.ShapeDtypeStruct((), jnp.int32) for _ in self.tables]
        tt_cap = round_up_pow2(max(self.catalog.n_triples, 1)) \
            if self._has_tt else 0
        ttshape = jax.ShapeDtypeStruct((tt_cap, 3), jnp.int32)
        ttn = jax.ShapeDtypeStruct((), jnp.int32)
        bshape = jax.ShapeDtypeStruct(self._default_bounds.shape, jnp.int32)
        fshape = jax.ShapeDtypeStruct((len(self.filter_slots),), jnp.int32)
        vshape = jax.ShapeDtypeStruct(self._value_keys.shape, jnp.float32)
        return self._jitted.lower(caps, rows, ns, ttshape, ttn, bshape,
                                  fshape, vshape)

    def run(self, max_retries: int = 16,
            bounds: Optional[np.ndarray] = None,
            fconsts: Optional[np.ndarray] = None,
            trace=None) -> Tuple[np.ndarray, Tuple[str, ...]]:
        rows, ns, tt_rows, tt_n, values = self._device_inputs
        b = self._default_bounds if bounds is None else \
            np.asarray(bounds, dtype=np.int32).reshape(self._default_bounds.shape)
        bj = jnp.asarray(b)
        fc = self.fconsts_from_mapping(None) if fconsts is None else \
            np.asarray(fconsts, dtype=np.int32).reshape(len(self.filter_slots))
        fj = jnp.asarray(fc)
        caps = tuple(self.caps)
        for attempt in range(max_retries):
            if trace is not None:
                # fenced launch span: block_until_ready keeps later host
                # work from absorbing the device time — traced requests
                # only (the untraced path stays fully async)
                sid = trace.start("device.launch", backend="jit",
                                  attempt=attempt, batch=1,
                                  cap_slots=sum(caps))
                data, n, ovf = self._jitted(caps, rows, ns, tt_rows,
                                            tt_n, bj, fj, values)
                jax.block_until_ready((data, n, ovf))
                ovf = np.asarray(ovf)
                trace.end(sid, overflow=bool(ovf.any()))
            else:
                data, n, ovf = self._jitted(caps, rows, ns, tt_rows, tt_n,
                                            bj, fj, values)
                ovf = np.asarray(ovf)
            if not ovf.any():
                # keep grown caps: a hot template must not pay the
                # overflow->retry double-launch on every request
                self.caps = list(caps)
                n = int(n)
                cols = self._final_cols()
                return np.asarray(data)[:n], cols
            caps = double_caps(caps, ovf, self._n_pipeline)
        raise RuntimeError("join capacity overflow after retries")

    def run_batch(self, bounds_batch: Sequence[np.ndarray],
                  fconsts_batch: Optional[Sequence[np.ndarray]] = None,
                  max_retries: int = 16,
                  trace=None) -> List[Tuple[np.ndarray, Tuple[str, ...]]]:
        """Execute B constant-bindings of this template's program in ONE
        XLA launch: the (B, n_steps, 2) bounds stack and the (B, n_fc)
        filter-constant stack are the only batched inputs (tables
        broadcast), so device work is amortized across the whole
        micro-batch.  Overflow on *any* batch element retries the whole
        batch with doubled caps — the batch shares one cap vector, which
        keeps the program count at one per (caps, B)."""
        if not bounds_batch:
            return []
        rows, ns, tt_rows, tt_n, values = self._device_inputs
        shape = self._default_bounds.shape
        bb = np.stack([np.asarray(b, dtype=np.int32).reshape(shape)
                       for b in bounds_batch])
        bj = jnp.asarray(bb)
        n_fc = len(self.filter_slots)
        if fconsts_batch is None:
            fb = np.tile(self.fconsts_from_mapping(None), (len(bb), 1))
        else:
            fb = np.stack([np.asarray(f, dtype=np.int32).reshape(n_fc)
                           for f in fconsts_batch])
        fj = jnp.asarray(fb)
        caps = tuple(self.caps)
        for attempt in range(max_retries):
            if trace is not None:
                sid = trace.start("device.launch", backend="jit",
                                  attempt=attempt, batch=len(bb),
                                  cap_slots=sum(caps))
                data, n, ovf = self._jitted_batch(caps, rows, ns, tt_rows,
                                                  tt_n, bj, fj, values)
                jax.block_until_ready((data, n, ovf))
                ovf = np.asarray(ovf)            # (B, n_pipeline[+1])
                trace.end(sid, overflow=bool(ovf.any()))
            else:
                data, n, ovf = self._jitted_batch(caps, rows, ns, tt_rows,
                                                  tt_n, bj, fj, values)
                ovf = np.asarray(ovf)            # (B, n_pipeline[+1])
            if not ovf.any():
                self.caps = list(caps)
                cols = self._final_cols()
                data = np.asarray(data)
                n = np.asarray(n)
                return [(data[i, : int(n[i])], cols)
                        for i in range(data.shape[0])]
            caps = double_caps(caps, ovf.any(axis=0), self._n_pipeline)
        raise RuntimeError("join capacity overflow after retries (batched)")

    def _final_cols(self) -> Tuple[str, ...]:
        return self._out_vars
