"""Property-table (Sempala-style) baseline layout (paper §4.3, §3.2).

Sempala answers star sub-patterns from a unified property table without
joins and decomposes complex queries into *disjoint triple groups*
(star-shaped sub-patterns) that are then joined.  We emulate exactly that
plan shape on the VP substrate:

* patterns are grouped by subject term (the star pivots);
* within a group, the subject set is first intersected across all member
  predicates (≡ the property-table row lookup: one "row scan" instead of
  joins — no ExtVP reduction is available to shrink inputs);
* groups are joined pairwise like Sempala joins its triple groups.

This reproduces the baseline's characteristic profile: stars are cheap
(pre-intersection ≈ the PT row filter), but inputs are full VP tables and
linear chains degenerate to plain joins — the behaviour Table 4 of the
paper shows for Sempala.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.algebra import BGP, TriplePattern, is_var
from repro.core.compiler import MISSING_TERM
from repro.core.executor import Bindings, natural_join, scan_step
from repro.core.compiler import ScanStep
from repro.core.stats import Catalog


def _star_groups(patterns: List[TriplePattern]) -> List[List[TriplePattern]]:
    groups: Dict[object, List[TriplePattern]] = {}
    for tp in patterns:
        groups.setdefault(tp.s, []).append(tp)
    return list(groups.values())


def _subject_intersection(group: List[TriplePattern],
                          catalog: Catalog) -> np.ndarray:
    """Common subjects over the group's predicates (the PT row filter)."""
    subjects = None
    for tp in group:
        if is_var(tp.p):
            continue
        t = catalog.table(None, int(tp.p))
        if t is None:
            return np.empty(0, dtype=np.int32)
        s = t.unique_s
        if not is_var(tp.o):
            s = np.unique(t.rows[t.rows[:, 1] == int(tp.o), 0])
        subjects = s if subjects is None else \
            np.intersect1d(subjects, s, assume_unique=True)
        if subjects is not None and len(subjects) == 0:
            break
    return subjects if subjects is not None else np.empty(0, np.int32)


def execute_pt_bgp(bgp: BGP, catalog: Catalog) -> Bindings:
    patterns = list(bgp.patterns)
    if not patterns:
        return Bindings.unit()
    for tp in patterns:
        if any((not is_var(t)) and int(t) == MISSING_TERM
               for t in (tp.s, tp.p, tp.o)):
            return Bindings.empty(bgp.vars())

    group_results: List[Bindings] = []
    for group in _star_groups(patterns):
        subjects = None
        if len(group) > 1 and not any(is_var(tp.p) for tp in group):
            subjects = _subject_intersection(group, catalog)
        acc = None
        for tp in group:
            step = ScanStep(tp, None, None, 1.0,
                            catalog.vp_size(int(tp.p)) if not is_var(tp.p)
                            else catalog.n_triples,
                            uses_tt=is_var(tp.p))
            b = scan_step(step, catalog)
            if subjects is not None and is_var(tp.s):
                mask = np.isin(b.col(tp.s), subjects)
                b = Bindings(b.cols, b.data[mask])
            acc = b if acc is None else natural_join(acc, b)
        group_results.append(acc)

    # Sempala: join the disjoint triple groups
    out = group_results[0]
    for g in group_results[1:]:
        out = natural_join(out, g)
    return out
