"""Eager (host) relational executor for compiled plans.

This is the reference engine: exact dynamic shapes, vectorized numpy.
It mirrors what Spark SQL does for S2RDF — materialized intermediate
relations, sort-merge natural joins, SQL-style outer joins for OPTIONAL —
and is the correctness baseline for both the jitted static-shape executor
(:mod:`repro.core.jexec`) and the distributed engine
(:mod:`repro.core.distributed`).

Semantics notes:
* Solution mappings are rows of int32 ids; ``UNBOUND`` (-1) encodes SQL
  NULL.  Like S2RDF (which compiles OPTIONAL to Spark SQL LEFT OUTER
  JOIN), we inherit SQL NULL-join semantics: an unbound value never
  satisfies a join/filter equality.
* FILTER comparisons: ``=``/``!=`` compare term identity (ids);
  ``<,<=,>,>=`` (or any comparison against a numeric constant) compare
  numeric literal values via the dictionary's value table; non-numeric
  terms never satisfy an order comparison (SPARQL type error -> row
  dropped).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.algebra import (
    BGP, BoolOp, Bound, Cmp, Distinct, Filter, FilterExpr, JoinPair, LeftJoin,
    Node, NotExpr, OrderBy, Project, Query, Slice, TriplePattern, UnionOp,
    is_var,
)
from repro.core.compiler import Plan, ScanStep, compile_bgp
from repro.core.modifiers import ModifierSpine, peel_spine
from repro.core.stats import Catalog
from repro.rdf.dictionary import UNBOUND

__all__ = ["Bindings", "execute", "execute_plan", "scan_step", "natural_join",
           "apply_spine_host", "stable_unique_rows", "order_rows"]


@dataclass
class Bindings:
    """A relation over query variables."""

    cols: Tuple[str, ...]
    data: np.ndarray  # (n, len(cols)) int32

    def __post_init__(self) -> None:
        arr = np.asarray(self.data, dtype=np.int32)
        if arr.ndim == 2 and arr.shape[1] == len(self.cols):
            self.data = arr
        elif len(self.cols):
            self.data = arr.reshape(-1, len(self.cols))
        else:  # 0-column relation (fully-bound patterns): keep row count
            n = arr.shape[0] if arr.ndim >= 1 else 0
            self.data = arr.reshape(n, 0)

    @staticmethod
    def empty(cols: Sequence[str]) -> "Bindings":
        return Bindings(tuple(cols), np.empty((0, len(cols)), dtype=np.int32))

    @staticmethod
    def unit() -> "Bindings":
        """The single empty mapping (identity of ⋈)."""
        return Bindings((), np.empty((1, 0), dtype=np.int32))

    def __len__(self) -> int:
        return self.data.shape[0]

    def col(self, var: str) -> np.ndarray:
        return self.data[:, self.cols.index(var)]

    def as_set(self) -> set:
        """Canonical comparable form: frozenset would lose duplicates; use
        sorted tuple list instead where bags matter."""
        return set(map(tuple, self.data.tolist()))

    def as_multiset(self) -> Dict[tuple, int]:
        out: Dict[tuple, int] = {}
        for row in self.data.tolist():
            t = tuple(row)
            out[t] = out.get(t, 0) + 1
        return out


# ---------------------------------------------------------------------------
# Scans
# ---------------------------------------------------------------------------

def scan_step(step: ScanStep, catalog: Catalog) -> Bindings:
    """Materialize one triple pattern from its selected table (Algorithm 2)."""
    tp = step.tp
    if step.uses_tt:
        return _scan_tt(tp, catalog)

    table = catalog.table(step.kind, int(tp.p), step.p2)
    if table is None:
        # predicate absent
        cols = tuple(v for v in (tp.s, tp.o) if is_var(v))
        return Bindings.empty(_dedup(cols))
    rows = table.rows  # (n, 2) [s, o]

    mask = np.ones(len(rows), dtype=bool)
    if not is_var(tp.s):
        mask &= rows[:, 0] == int(tp.s)
    if not is_var(tp.o):
        mask &= rows[:, 1] == int(tp.o)
    if is_var(tp.s) and is_var(tp.o) and tp.s == tp.o:
        mask &= rows[:, 0] == rows[:, 1]
    rows = rows[mask]

    cols: List[str] = []
    take: List[int] = []
    if is_var(tp.s):
        cols.append(tp.s)
        take.append(0)
    if is_var(tp.o) and tp.o not in cols:
        cols.append(tp.o)
        take.append(1)
    return Bindings(tuple(cols), rows[:, take])


def _scan_tt(tp: TriplePattern, catalog: Catalog) -> Bindings:
    tt = catalog.tt
    mask = np.ones(len(tt), dtype=bool)
    for pos, term in ((0, tp.s), (1, tp.p), (2, tp.o)):
        if not is_var(term):
            mask &= tt[:, pos] == int(term)
    rows = tt[mask]
    cols: List[str] = []
    take: List[int] = []
    for pos, term in ((0, tp.s), (1, tp.p), (2, tp.o)):
        if is_var(term):
            if term in cols:  # repeated variable: equality selection
                rows = rows[rows[:, pos] == rows[:, take[cols.index(term)]]]
            else:
                cols.append(term)
                take.append(pos)
    return Bindings(tuple(cols), rows[:, take])


def _dedup(cols: Sequence[str]) -> Tuple[str, ...]:
    seen: List[str] = []
    for c in cols:
        if c not in seen:
            seen.append(c)
    return tuple(seen)


# ---------------------------------------------------------------------------
# Joins
# ---------------------------------------------------------------------------

def _pack_keys(b: Bindings, shared: Sequence[str], null_code: int) -> np.ndarray:
    """int64 join key per row; rows with any UNBOUND key -> unmatchable."""
    c0 = b.col(shared[0]).astype(np.int64)
    if len(shared) == 1:
        key = c0
        isnull = c0 == UNBOUND
    else:
        c1 = b.col(shared[1]).astype(np.int64)
        key = c0 * np.int64(2**31) + c1
        isnull = (c0 == UNBOUND) | (c1 == UNBOUND)
    return np.where(isnull, np.int64(null_code), key)


def _cross(a: Bindings, b: Bindings) -> Bindings:
    na, nb = len(a), len(b)
    left = np.repeat(a.data, nb, axis=0)
    right = np.tile(b.data, (na, 1))
    return Bindings(a.cols + b.cols, np.concatenate([left, right], axis=1))


def natural_join(a: Bindings, b: Bindings,
                 return_provenance: bool = False):
    """Sort-merge natural join.  Optionally returns the source row index
    of ``a`` for each output row (for OPTIONAL's matched-set computation)."""
    shared = [c for c in a.cols if c in b.cols]
    b_only = [c for c in b.cols if c not in a.cols]
    out_cols = a.cols + tuple(b_only)

    if not shared:
        out = _cross(a, b)
        if return_provenance:
            prov = np.repeat(np.arange(len(a)), len(b))
            return out, prov
        return out

    # Join on (up to) two packed key columns; post-filter the rest.
    key_cols = shared[:2]
    ka = _pack_keys(a, key_cols, null_code=-3)
    kb = _pack_keys(b, key_cols, null_code=-5)

    order_b = np.argsort(kb, kind="stable")
    kb_sorted = kb[order_b]
    lo = np.searchsorted(kb_sorted, ka, side="left")
    hi = np.searchsorted(kb_sorted, ka, side="right")
    cnt = (hi - lo).astype(np.int64)
    total = int(cnt.sum())

    a_idx = np.repeat(np.arange(len(a)), cnt)
    starts = np.repeat(lo, cnt)
    prefix = np.cumsum(cnt) - cnt            # exclusive prefix, shape == cnt
    offs = np.arange(total, dtype=np.int64) - np.repeat(prefix, cnt)
    b_idx = order_b[starts + offs]

    left = a.data[a_idx]
    right = b.data[b_idx]

    # post-filter on remaining shared columns (SQL NULL never matches)
    keep = np.ones(total, dtype=bool)
    for c in shared[2:]:
        va = left[:, a.cols.index(c)]
        vb = right[:, b.cols.index(c)]
        keep &= (va == vb) & (va != UNBOUND)
    if not keep.all():
        left, right, a_idx = left[keep], right[keep], a_idx[keep]

    right_extra = right[:, [b.cols.index(c) for c in b_only]] if b_only else \
        np.empty((left.shape[0], 0), dtype=np.int32)
    out = Bindings(out_cols, np.concatenate([left, right_extra], axis=1))
    if return_provenance:
        return out, a_idx
    return out


def left_outer_join(a: Bindings, b: Bindings,
                    expr: Optional[FilterExpr], catalog: Catalog) -> Bindings:
    inner, prov = natural_join(a, b, return_provenance=True)
    if expr is not None and len(inner):
        keep = eval_filter(expr, inner, catalog)
        inner = Bindings(inner.cols, inner.data[keep])
        prov = prov[keep]
    matched = np.zeros(len(a), dtype=bool)
    matched[np.unique(prov)] = True
    b_only = [c for c in inner.cols if c not in a.cols]
    pad = np.full((int((~matched).sum()), len(b_only)), UNBOUND, dtype=np.int32)
    unmatched = np.concatenate([a.data[~matched], pad], axis=1)
    return Bindings(inner.cols, np.concatenate([inner.data, unmatched], axis=0))


def union(a: Bindings, b: Bindings) -> Bindings:
    cols = a.cols + tuple(c for c in b.cols if c not in a.cols)

    def lift(x: Bindings) -> np.ndarray:
        out = np.full((len(x), len(cols)), UNBOUND, dtype=np.int32)
        for j, c in enumerate(cols):
            if c in x.cols:
                out[:, j] = x.col(c)
        return out

    return Bindings(cols, np.concatenate([lift(a), lift(b)], axis=0))


# ---------------------------------------------------------------------------
# Filters
# ---------------------------------------------------------------------------

def _operand(b: Bindings, values: np.ndarray, term, numeric: bool):
    """Return (ids or None, numeric values) arrays for a filter operand.
    A variable the relation does not bind is UNBOUND everywhere (never
    satisfies a comparison — the reference-oracle semantics)."""
    if isinstance(term, str) and term.startswith("?"):
        ids = b.col(term) if term in b.cols else \
            np.full(len(b), UNBOUND, dtype=np.int32)
        if numeric:
            safe = np.clip(ids, 0, len(values) - 1)
            val = np.where(ids >= 0, values[safe], np.nan)
            return ids, val
        return ids, None
    if isinstance(term, float):
        return None, np.full(len(b), term)
    # constant id
    tid = int(term)
    if numeric:
        v = values[tid] if 0 <= tid < len(values) else np.nan
        return np.full(len(b), tid, dtype=np.int64), np.full(len(b), v)
    return np.full(len(b), tid, dtype=np.int64), None


def eval_filter(expr: FilterExpr, b: Bindings, catalog: Catalog) -> np.ndarray:
    """Boolean mask over rows of b."""
    values = catalog.dictionary.values if catalog.dictionary is not None else \
        np.empty(0, dtype=np.float64)

    if isinstance(expr, BoolOp):
        masks = [eval_filter(e, b, catalog) for e in expr.args]
        out = masks[0]
        for m in masks[1:]:
            out = (out & m) if expr.op == "&&" else (out | m)
        return out
    if isinstance(expr, NotExpr):
        return ~eval_filter(expr.arg, b, catalog)
    if isinstance(expr, Bound):
        if expr.var not in b.cols:
            return np.zeros(len(b), dtype=bool)
        return b.col(expr.var) != UNBOUND
    assert isinstance(expr, Cmp)

    numeric = expr.op in ("<", "<=", ">", ">=") or \
        isinstance(expr.lhs, float) or isinstance(expr.rhs, float)
    lid, lval = _operand(b, values, expr.lhs, numeric)
    rid, rval = _operand(b, values, expr.rhs, numeric)

    if numeric:
        with np.errstate(invalid="ignore"):
            if expr.op == "=":
                return np.asarray(lval == rval)
            if expr.op == "!=":
                return np.asarray(lval != rval) & ~np.isnan(lval) & ~np.isnan(rval)
            if expr.op == "<":
                return np.asarray(lval < rval)
            if expr.op == "<=":
                return np.asarray(lval <= rval)
            if expr.op == ">":
                return np.asarray(lval > rval)
            return np.asarray(lval >= rval)
    # identity comparisons on ids; UNBOUND never satisfies
    ok = (lid != UNBOUND) & (rid != UNBOUND)
    if expr.op == "=":
        return (lid == rid) & ok
    return (lid != rid) & ok


# ---------------------------------------------------------------------------
# Plan / node evaluation
# ---------------------------------------------------------------------------

def execute_plan(plan: Plan, catalog: Catalog) -> Bindings:
    if plan.empty:
        return Bindings.empty(plan.vars)
    if not plan.steps:
        return Bindings.unit()
    out = scan_step(plan.steps[0], catalog)
    for step in plan.steps[1:]:
        out = natural_join(out, scan_step(step, catalog))
    return out


def _eval(node: Node, catalog: Catalog, layout: str = "extvp") -> Bindings:
    if isinstance(node, BGP):
        if layout == "pt":   # Sempala-style property-table baseline
            from repro.core.pt import execute_pt_bgp
            return execute_pt_bgp(node, catalog)
        return execute_plan(compile_bgp(node, catalog, layout), catalog)
    if isinstance(node, JoinPair):
        return natural_join(_eval(node.left, catalog, layout),
                            _eval(node.right, catalog, layout))
    if isinstance(node, Filter):
        child = _eval(node.child, catalog, layout)
        if not len(child):
            return child
        return Bindings(child.cols, child.data[eval_filter(node.expr, child, catalog)])
    if isinstance(node, LeftJoin):
        return left_outer_join(_eval(node.left, catalog, layout),
                               _eval(node.right, catalog, layout), node.expr, catalog)
    if isinstance(node, UnionOp):
        return union(_eval(node.left, catalog, layout),
                     _eval(node.right, catalog, layout))
    if isinstance(node, Distinct):
        child = _eval(node.child, catalog, layout)
        return Bindings(child.cols, stable_unique_rows(child.data))
    if isinstance(node, OrderBy):
        return order_rows(_eval(node.child, catalog, layout), node.keys,
                          catalog)
    if isinstance(node, Slice):
        child = _eval(node.child, catalog, layout)
        end = None if node.limit is None else node.offset + node.limit
        return Bindings(child.cols, child.data[node.offset:end])
    if isinstance(node, Project):
        return _project(_eval(node.child, catalog, layout), node.vars)
    raise TypeError(f"unknown node {type(node)}")


def _project(b: Bindings, vars: Optional[List[str]]) -> Bindings:
    if vars is None:
        return b
    data = np.full((len(b), len(vars)), UNBOUND, dtype=np.int32)
    for j, v in enumerate(vars):
        if v in b.cols:
            data[:, j] = b.col(v)
    return Bindings(tuple(vars), data)


# ---------------------------------------------------------------------------
# Solution modifiers (canonical order, shared with the device engines)
# ---------------------------------------------------------------------------

def stable_unique_rows(data: np.ndarray) -> np.ndarray:
    """First-occurrence-stable row dedup.  SPARQL DISTINCT must preserve
    the sequence order (an ORDER BY established before or after it must
    survive); ``np.unique`` alone re-sorts the rows, which is the
    modifier-ordering bug this replaces."""
    if len(data) <= 1:
        return data
    _, idx = np.unique(data, axis=0, return_index=True)
    return data[np.sort(idx)]


def order_rows(b: Bindings, keys: Sequence[Tuple[str, bool]],
               catalog: Catalog) -> Bindings:
    """ORDER BY over the dictionary's numeric value table: numeric
    literals sort by value, everything else by term id; UNBOUND sorts
    last under ASC (SQL NULLS LAST, shared with the device engines);
    stable, so tied rows keep their prior order.  Keys over variables
    the relation does not bind are constant (≡ skipped)."""
    if not len(b) or not keys:
        return b
    values = catalog.dictionary.values if catalog.dictionary is not None \
        else np.empty(0, dtype=np.float64)
    ks = []
    for var, asc in reversed(keys):
        if var not in b.cols:
            continue
        ids = b.col(var)
        if len(values):
            safe = np.clip(ids, 0, len(values) - 1)
            v = np.where(ids >= 0, values[safe], np.nan)
        else:
            v = np.full(len(b), np.nan)
        v = np.where(np.isnan(v), ids.astype(np.float64), v)
        v = np.where(ids == UNBOUND, np.inf, v)
        ks.append(v if asc else -v)
    if not ks:
        return b
    return Bindings(b.cols, b.data[np.lexsort(ks)])


def apply_spine_host(b: Bindings, spine: ModifierSpine,
                     catalog: Catalog) -> Bindings:
    """Apply a modifier spine in the canonical SPARQL order:
    FILTER* → ORDER BY → project → DISTINCT → OFFSET/LIMIT (ordering
    runs before projection, so sort keys outside the SELECT list work;
    projection and stable dedup both preserve the established order)."""
    for expr in spine.filters:
        if len(b):
            b = Bindings(b.cols, b.data[eval_filter(expr, b, catalog)])
    if spine.order:
        b = order_rows(b, spine.order, catalog)
    b = _project(b, list(spine.project) if spine.project is not None else None)
    if spine.distinct:
        b = Bindings(b.cols, stable_unique_rows(b.data))
    if spine.has_slice:
        end = None if spine.limit is None else spine.offset + spine.limit
        b = Bindings(b.cols, b.data[spine.offset:end])
    return b


def execute(query: Query, catalog: Catalog, layout: str = "extvp") -> Bindings:
    """Evaluate a parsed query.  ``layout`` selects the storage schema the
    compiler targets: "extvp" (default), "vp" or "tt" (paper §4 baselines).

    The modifier spine is peeled off the root and applied in the
    canonical order → project → distinct → slice sequence (DISTINCT
    before the slice and order-preserving, ORDER BY before projection),
    fixing the historical dedup-after-LIMIT behaviour."""
    core, spine = peel_spine(query)
    return apply_spine_host(_eval(core, catalog, layout), spine, catalog)
