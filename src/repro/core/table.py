"""Relational table representations for the S2RDF engine.

Two forms exist:

* ``Table`` — host-side (numpy) exact-size two-column relation.  The
  catalog (VP + ExtVP) lives in this form; it is the analogue of the
  Parquet files S2RDF materializes in HDFS.  Tables are kept sorted by
  subject, with a lazily-built object-sorted view, mirroring how a
  Spark-side engine would keep sorted/clustered copies for merge joins
  (and how RDF-3X/Hexastore keep permuted indexes).

* ``DeviceTable`` — static-shape device form: rows padded to a power-of-two
  capacity with ``PAD`` keys (which sort after all valid ids), plus a valid
  count.  All jitted relational operators consume/produce this form, which
  is what makes the engine XLA/TPU-compatible.
"""

from __future__ import annotations

from collections.abc import Mapping
from dataclasses import dataclass
from functools import cached_property
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.rdf.dictionary import PAD

__all__ = ["Table", "DeviceTable", "LazyTableMap", "pad_rows",
           "round_up_pow2"]


def round_up_pow2(n: int, minimum: int = 8) -> int:
    c = minimum
    while c < n:
        c *= 2
    return c


def pad_rows(rows: np.ndarray, capacity: int) -> np.ndarray:
    """Pad (n, k) rows to (capacity, k) with PAD."""
    n, k = rows.shape
    assert capacity >= n, (capacity, n)
    out = np.full((capacity, k), PAD, dtype=np.int32)
    out[:n] = rows
    return out


@dataclass
class Table:
    """Host-side two-column relation (s, o), sorted by s."""

    rows: np.ndarray  # (n, 2) int32, sorted by (s, o)

    def __post_init__(self) -> None:
        self.rows = np.asarray(self.rows, dtype=np.int32).reshape(-1, 2)

    @staticmethod
    def from_unsorted(rows: np.ndarray) -> "Table":
        rows = np.asarray(rows, dtype=np.int32).reshape(-1, 2)
        order = np.lexsort((rows[:, 1], rows[:, 0]))
        return Table(rows[order])

    def __len__(self) -> int:
        return len(self.rows)

    @property
    def s(self) -> np.ndarray:
        return self.rows[:, 0]

    @property
    def o(self) -> np.ndarray:
        return self.rows[:, 1]

    @cached_property
    def rows_by_o(self) -> np.ndarray:
        """(n, 2) rows sorted by (o, s) — the object-clustered view."""
        order = np.lexsort((self.rows[:, 0], self.rows[:, 1]))
        return self.rows[order]

    @cached_property
    def unique_s(self) -> np.ndarray:
        return np.unique(self.rows[:, 0])

    @cached_property
    def unique_o(self) -> np.ndarray:
        return np.unique(self.rows[:, 1])

    def nbytes(self) -> int:
        return int(self.rows.nbytes)

    def to_device(self, capacity: Optional[int] = None) -> "DeviceTable":
        cap = capacity or round_up_pow2(len(self.rows))
        return DeviceTable(pad_rows(self.rows, cap), np.int32(len(self.rows)))


class LazyTableMap(Mapping):
    """A ``Mapping[key, Table]`` whose values materialize on first access.

    This is the table-provider indirection behind ``Catalog.vp`` and
    ``Catalog.extvp.tables``: an in-RAM catalog uses plain dicts, a
    persistent one (``repro.store``) uses a ``LazyTableMap`` of per-file
    loader callables that ``np.memmap`` the on-disk columns — the
    compiler and executors cannot tell the two apart.  Key/len/contains
    queries never touch a loader; each loader runs at most once and its
    ``Table`` is cached (so per-table ``cached_property`` views such as
    ``rows_by_o`` persist across accesses exactly like the in-RAM form).

    ``lengths`` (optional, per-key row counts — the store reader passes
    the manifest's) lets size accounting (``total_rows``) answer without
    running a single loader.
    """

    def __init__(self, loaders: Dict[object, Callable[[], "Table"]],
                 lengths: Optional[Dict[object, int]] = None):
        self._loaders = dict(loaders)
        self._cache: Dict[object, Table] = {}
        self._lengths = None if lengths is None else dict(lengths)

    def __getitem__(self, key) -> "Table":
        t = self._cache.get(key)
        if t is None:
            t = self._loaders[key]()        # KeyError propagates
            self._cache[key] = t
        return t

    def __contains__(self, key) -> bool:
        return key in self._loaders

    def __iter__(self):
        return iter(self._loaders)

    def __len__(self) -> int:
        return len(self._loaders)

    @property
    def n_loaded(self) -> int:
        """How many tables have been touched (lazy-load observability)."""
        return len(self._cache)

    def total_rows(self) -> int:
        """Total rows across all tables — from the ``lengths`` metadata
        when available (no loader runs), by materializing otherwise."""
        if self._lengths is not None:
            return int(sum(self._lengths.values()))
        return sum(len(self[k]) for k in self._loaders)

    def loader_for(self, key) -> Callable[[], "Table"]:
        """The zero-arg provider for ``key``, WITHOUT materializing it —
        lets a derived catalog (``Dataset.append_triples`` carry-over)
        re-wrap untouched tables lazily instead of loading them."""
        t = self._cache.get(key)
        if t is not None:
            return lambda: t
        return self._loaders[key]

    def materialize_all(self) -> None:
        """Force every table (the eager-load / benchmarking mode)."""
        for key in self._loaders:
            self[key]


@dataclass
class DeviceTable:
    """Static-shape device relation: (capacity, 2) rows + valid count."""

    rows: np.ndarray  # (capacity, 2) int32, valid prefix sorted by s, PAD tail
    n: np.ndarray     # int32 scalar

    @property
    def capacity(self) -> int:
        return self.rows.shape[0]

    def to_host(self) -> Table:
        n = int(self.n)
        return Table(np.asarray(self.rows)[:n])
