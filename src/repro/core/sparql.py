"""SPARQL subset parser (the paper uses Jena ARQ; we parse natively).

Supported grammar (SPARQL 1.0 core, matching §6):

    query      := prologue? SELECT 'DISTINCT'? ('*' | var+) WHERE? group
                  ('ORDER' 'BY' orderCond+)? ('LIMIT' int)? ('OFFSET' int)?
    prologue   := ('PREFIX' pname ':' '<' iri '>')*
    group      := '{' (triplesBlock | 'FILTER' '(' expr ')' |
                       'OPTIONAL' group | group ('UNION' group)* | group)* '}'
    triples    := term term term ('.' | ';' term term)* — ';' predicate lists
    term       := var | '<iri>' | pname:local | literal | number
    expr       := or-expr over comparisons, '&&', '||', '!', 'BOUND(?v)'

Terms are resolved against the graph :class:`~repro.rdf.Dictionary`:
prefixed names are looked up both raw (``wsdbm:User5``) and expanded via
the declared prefixes.  A bound term absent from the dictionary makes the
enclosing BGP provably empty, which the compiler exploits (≡ S2RDF's
statistics-only empty answers).
"""

from __future__ import annotations

import re
from typing import Dict, List, Optional, Tuple, Union

from repro.core.algebra import (
    BGP, BoolOp, Bound, Cmp, Distinct, Filter, FilterExpr, JoinPair, LeftJoin,
    Node, NotExpr, OrderBy, Project, Query, Slice, TriplePattern, UnionOp,
)
from repro.rdf.dictionary import Dictionary

__all__ = ["parse_sparql", "SparqlError", "MISSING_TERM"]

MISSING_TERM = -2  # bound term not present in the dictionary


class SparqlError(ValueError):
    pass


_TOKEN_RE = re.compile(
    r"""
      (?P<ws>\s+|\#[^\n]*)
    # No whitespace inside an IRI: '?a < 25 && ?b > 5' tokenizes as
    # comparisons.  A space-free '?a<25&&?b>5' still lexes '<25&&?b>' as
    # one IRI token (and then errors) — that matches the SPARQL IRIREF
    # grammar, which real lexers resolve the same way: put spaces around
    # '<' in filters.
    | (?P<iri><[^>\s]*>)
    | (?P<str>"(?:[^"\\]|\\.)*")
    | (?P<num>[+-]?\d+(?:\.\d+)?)
    | (?P<var>\?[A-Za-z_][A-Za-z0-9_]*)
    | (?P<pname>[A-Za-z_][A-Za-z0-9_\-]*:[A-Za-z0-9_\-\.]*)
    | (?P<kw>[A-Za-z_][A-Za-z0-9_]*)
    | (?P<op>&&|\|\||!=|<=|>=|[{}().;,*=<>!])
    """,
    re.VERBOSE,
)


def _tokenize(text: str) -> List[Tuple[str, str]]:
    toks, pos = [], 0
    while pos < len(text):
        m = _TOKEN_RE.match(text, pos)
        if not m:
            raise SparqlError(f"cannot tokenize at: {text[pos:pos+40]!r}")
        pos = m.end()
        kind = m.lastgroup
        if kind == "ws":
            continue
        toks.append((kind, m.group()))
    toks.append(("eof", ""))
    return toks


class _Parser:
    def __init__(self, text: str, dictionary: Dictionary,
                 prefixes: Optional[Dict[str, str]] = None):
        self.toks = _tokenize(text)
        self.i = 0
        self.d = dictionary
        self.prefixes: Dict[str, str] = dict(prefixes or {})

    # -- token helpers --------------------------------------------------------
    def peek(self, k: int = 0) -> Tuple[str, str]:
        return self.toks[min(self.i + k, len(self.toks) - 1)]

    def next(self) -> Tuple[str, str]:
        t = self.toks[self.i]
        self.i += 1
        return t

    def accept(self, val: str) -> bool:
        if self.peek()[1].upper() == val.upper() and self.peek()[0] in ("kw", "op"):
            self.i += 1
            return True
        return False

    def expect(self, val: str) -> None:
        if not self.accept(val):
            raise SparqlError(f"expected {val!r}, got {self.peek()[1]!r}")

    # -- term resolution -------------------------------------------------------
    def _resolve(self, term: str) -> int:
        tid = self.d.id_of(term)
        if tid is not None:
            return tid
        if ":" in term and not term.startswith('"'):
            pfx, local = term.split(":", 1)
            if pfx in self.prefixes:
                expanded = self.prefixes[pfx] + local
                tid = self.d.id_of(expanded)
                if tid is not None:
                    return tid
        return MISSING_TERM

    def parse_term(self) -> Union[str, int]:
        kind, val = self.next()
        if kind == "var":
            return val
        if kind == "iri":
            return self._resolve(val[1:-1])
        if kind == "pname":
            return self._resolve(val)
        if kind == "str":
            return self._resolve(val)
        if kind == "num":
            canon = f'"{val}"'
            tid = self._resolve(canon)
            if tid == MISSING_TERM and "." not in val:
                tid = self._resolve(f'"{int(val)}"')
            return tid
        if kind == "kw":
            if val == "a":  # rdf:type shorthand
                return self._resolve("rdf:type")
            return self._resolve(val)  # bare name (simplified notation)
        raise SparqlError(f"unexpected term token {val!r}")

    # -- grammar ----------------------------------------------------------------
    def parse_query(self) -> Query:
        while self.accept("PREFIX"):
            kind, val = self.next()
            # a PREFIX name is exactly "name:" — a pname token whose local
            # part is empty; anything else (missing colon, stray local
            # part) is a syntax error, not a silently-garbled prefix
            if kind != "pname" or not val.endswith(":"):
                raise SparqlError(
                    f"bad PREFIX name {val!r} (expected 'name:')")
            pfx = val[:-1]
            kind2, iri = self.next()
            if kind2 != "iri":
                raise SparqlError(f"bad PREFIX iri {iri!r}")
            self.prefixes[pfx] = iri[1:-1]

        self.expect("SELECT")
        distinct = self.accept("DISTINCT")
        select: Optional[List[str]] = None
        if self.accept("*"):
            select = None
        else:
            select = []
            while self.peek()[0] == "var":
                select.append(self.next()[1])
            if not select:
                raise SparqlError("empty SELECT clause")
        self.accept("WHERE")
        root: Node = self.parse_group()

        if self.accept("ORDER"):
            self.expect("BY")
            keys: List[Tuple[str, bool]] = []
            while True:
                if self.accept("ASC"):
                    self.expect("(")
                    keys.append((self.next()[1], True))
                    self.expect(")")
                elif self.accept("DESC"):
                    self.expect("(")
                    keys.append((self.next()[1], False))
                    self.expect(")")
                elif self.peek()[0] == "var":
                    keys.append((self.next()[1], True))
                else:
                    break
            root = OrderBy(root, keys)

        offset, limit = 0, None
        if self.accept("LIMIT"):
            limit = int(self.next()[1])
            if self.accept("OFFSET"):
                offset = int(self.next()[1])
        elif self.accept("OFFSET"):
            offset = int(self.next()[1])
            if self.accept("LIMIT"):
                limit = int(self.next()[1])
        if limit is not None or offset:
            root = Slice(root, offset, limit)

        if self.peek()[0] != "eof":
            raise SparqlError(f"trailing tokens at {self.peek()[1]!r}")
        return Query(root=root, select=select, distinct=distinct)

    def parse_group(self) -> Node:
        self.expect("{")
        node: Optional[Node] = None
        patterns: List[TriplePattern] = []
        filters: List[FilterExpr] = []
        optionals: List[Tuple[Node, Optional[FilterExpr]]] = []

        def flush() -> Optional[Node]:
            nonlocal patterns
            out: Optional[Node] = BGP(patterns) if patterns else None
            patterns = []
            return out

        def merge(a: Optional[Node], b: Optional[Node]) -> Optional[Node]:
            if a is None:
                return b
            if b is None:
                return a
            if isinstance(a, BGP) and isinstance(b, BGP):
                return BGP(a.patterns + b.patterns)
            # generic conjunction = join of two sub-results
            return JoinPair(a, b)

        while not self.accept("}"):
            tok_kind, tok_val = self.peek()
            up = tok_val.upper()
            if up == "FILTER":
                self.next()
                filters.append(self.parse_expr_parens())
            elif up == "OPTIONAL":
                self.next()
                right = self.parse_group()
                expr = None
                if isinstance(right, Filter):
                    right, expr = right.child, right.expr
                optionals.append((right, expr))
            elif tok_val == "{":
                sub = self.parse_group()
                while self.accept("UNION"):
                    sub2 = self.parse_group()
                    sub = UnionOp(sub, sub2)
                node = merge(merge(node, flush()), sub)
            else:
                patterns.append(self.parse_triples_same_subject())
                # '.' separators / ';' predicate lists handled inside
                while self.accept(";"):
                    if self.peek()[1] in (".", "}"):
                        break               # trailing ';' before '.' or '}'
                    prev = patterns[-1]
                    p = self.parse_term()
                    o = self.parse_term()
                    patterns.append(TriplePattern(prev.s, p, o))
                self.accept(".")

        node = merge(node, flush())
        if node is None:
            node = BGP([])
        for right, expr in optionals:
            node = LeftJoin(node, right, expr)
        for f in filters:
            node = Filter(f, node)
        return node

    def parse_triples_same_subject(self) -> TriplePattern:
        s = self.parse_term()
        p = self.parse_term()
        o = self.parse_term()
        return TriplePattern(s, p, o)

    # -- filter expressions -------------------------------------------------------
    def parse_expr_parens(self) -> FilterExpr:
        self.expect("(")
        e = self.parse_or()
        self.expect(")")
        return e

    def parse_or(self) -> FilterExpr:
        args = [self.parse_and()]
        while self.accept("||"):
            args.append(self.parse_and())
        return args[0] if len(args) == 1 else BoolOp("||", tuple(args))

    def parse_and(self) -> FilterExpr:
        args = [self.parse_unary()]
        while self.accept("&&"):
            args.append(self.parse_unary())
        return args[0] if len(args) == 1 else BoolOp("&&", tuple(args))

    def parse_unary(self) -> FilterExpr:
        if self.accept("!"):
            return NotExpr(self.parse_unary())
        if self.peek()[1] == "(":
            return self.parse_expr_parens()
        if self.peek()[1].upper() == "BOUND":
            self.next()
            self.expect("(")
            var = self.next()[1]
            self.expect(")")
            return Bound(var)
        lhs = self.parse_operand()
        kind, op = self.next()
        if op not in ("=", "!=", "<", "<=", ">", ">="):
            raise SparqlError(f"bad comparison operator {op!r}")
        rhs = self.parse_operand()
        return Cmp(op, lhs, rhs)

    def parse_operand(self) -> Union[str, int, float]:
        """Filter operand: var, term, or *numeric* constant (kept as float
        so comparisons work even for values outside the literal pool)."""
        if self.peek()[0] == "num":
            return float(self.next()[1])
        return self.parse_term()


def parse_sparql(text: str, dictionary: Dictionary,
                 prefixes: Optional[Dict[str, str]] = None) -> Query:
    return _Parser(text, dictionary, prefixes).parse_query()
