"""Brute-force reference implementation of the query semantics.

Direct transcription of the BGP semantics of paper §2.1 (solution
mappings, compatibility, ⋈ of bags) with the same SQL-style OPTIONAL /
FILTER conventions as the engine.  O(|G|^patterns) — only for tests and
tiny graphs; this is the oracle every executor must agree with.
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

from repro.core.algebra import (
    BGP, BoolOp, Bound, Cmp, Distinct, Filter, FilterExpr, JoinPair, LeftJoin,
    Node, NotExpr, OrderBy, Project, Query, Slice, TriplePattern, UnionOp,
    is_var,
)
from repro.rdf.dictionary import UNBOUND

Mapping = Dict[str, int]
MISSING_TERM = -2


def _match_tp(tp: TriplePattern, triples: np.ndarray) -> List[Mapping]:
    out: List[Mapping] = []
    for s, p, o in triples.tolist():
        mu: Mapping = {}
        ok = True
        for term, val in ((tp.s, s), (tp.p, p), (tp.o, o)):
            if is_var(term):
                if term in mu and mu[term] != val:
                    ok = False
                    break
                mu[term] = val
            elif int(term) != val:
                ok = False
                break
        if ok:
            out.append(mu)
    return out


def _compatible(a: Mapping, b: Mapping) -> bool:
    for k, v in a.items():
        if k in b:
            if v != b[k] or v == UNBOUND or b[k] == UNBOUND:
                return False
    return True


def _merge_bags(xs: List[Mapping], ys: List[Mapping]) -> List[Mapping]:
    out = []
    for x in xs:
        for y in ys:
            if _compatible(x, y):
                m = dict(x)
                m.update(y)
                out.append(m)
    return out


def _eval_bgp(bgp: BGP, triples: np.ndarray) -> List[Mapping]:
    res: List[Mapping] = [{}]
    for tp in bgp.patterns:
        if any((not is_var(t)) and int(t) == MISSING_TERM
               for t in (tp.s, tp.p, tp.o)):
            return []
        res = _merge_bags(res, _match_tp(tp, triples))
        if not res:
            return []
    return res


def _filter_val(expr: FilterExpr, mu: Mapping, values: np.ndarray) -> bool:
    if isinstance(expr, BoolOp):
        vals = [_filter_val(e, mu, values) for e in expr.args]
        return all(vals) if expr.op == "&&" else any(vals)
    if isinstance(expr, NotExpr):
        return not _filter_val(expr.arg, mu, values)
    if isinstance(expr, Bound):
        return mu.get(expr.var, UNBOUND) != UNBOUND
    assert isinstance(expr, Cmp)

    def resolve(t):
        if isinstance(t, str) and t.startswith("?"):
            return mu.get(t, UNBOUND)
        return t

    lhs, rhs = resolve(expr.lhs), resolve(expr.rhs)
    numeric = expr.op in ("<", "<=", ">", ">=") or \
        isinstance(lhs, float) or isinstance(rhs, float)
    if numeric:
        def num(t):
            if isinstance(t, float):
                return t
            tid = int(t)
            if 0 <= tid < len(values):
                return float(values[tid])
            return float("nan")
        lv, rv = num(lhs), num(rhs)
        if np.isnan(lv) or np.isnan(rv):
            return False
        return {"=": lv == rv, "!=": lv != rv, "<": lv < rv,
                "<=": lv <= rv, ">": lv > rv, ">=": lv >= rv}[expr.op]
    li, ri = int(lhs), int(rhs)
    if li == UNBOUND or ri == UNBOUND:
        return False
    return (li == ri) if expr.op == "=" else (li != ri)


def _eval(node: Node, triples: np.ndarray, values: np.ndarray) -> List[Mapping]:
    if isinstance(node, BGP):
        return _eval_bgp(node, triples)
    if isinstance(node, JoinPair):
        return _merge_bags(_eval(node.left, triples, values),
                           _eval(node.right, triples, values))
    if isinstance(node, Filter):
        return [m for m in _eval(node.child, triples, values)
                if _filter_val(node.expr, m, values)]
    if isinstance(node, LeftJoin):
        left = _eval(node.left, triples, values)
        right = _eval(node.right, triples, values)
        out = []
        for x in left:
            matches = []
            for y in right:
                if _compatible(x, y):
                    m = dict(x)
                    m.update(y)
                    if node.expr is None or _filter_val(node.expr, m, values):
                        matches.append(m)
            out.extend(matches if matches else [dict(x)])
        return out
    if isinstance(node, UnionOp):
        return _eval(node.left, triples, values) + _eval(node.right, triples, values)
    if isinstance(node, Distinct):
        return _distinct(_eval(node.child, triples, values))
    if isinstance(node, OrderBy):
        res = _eval(node.child, triples, values)
        for var, asc in reversed(node.keys):
            def key(m):
                tid = m.get(var, UNBOUND)
                if tid == UNBOUND:
                    return float("inf")   # NULLS LAST, like the engines
                v = float(values[tid]) if 0 <= tid < len(values) else float("nan")
                return float(tid) if np.isnan(v) else v
            res = sorted(res, key=key, reverse=not asc)
        return res
    if isinstance(node, Slice):
        res = _eval(node.child, triples, values)
        end = None if node.limit is None else node.offset + node.limit
        return res[node.offset:end]
    if isinstance(node, Project):
        return [{v: m.get(v, UNBOUND) for v in node.vars}
                for m in _eval(node.child, triples, values)]
    raise TypeError(type(node))


def _distinct(res: List[Mapping]) -> List[Mapping]:
    seen, out = set(), []
    for m in res:
        key = tuple(sorted(m.items()))
        if key not in seen:
            seen.add(key)
            out.append(m)
    return out


def execute_reference(query: Query, triples: np.ndarray,
                      values: Optional[np.ndarray] = None) -> List[Mapping]:
    """Evaluate a query by brute force. Returns a bag of mappings.

    Solution modifiers follow the canonical order shared with the
    engines (see :mod:`repro.core.modifiers`): the spine is peeled off
    the root and applied as FILTER* → ORDER BY → project → DISTINCT →
    OFFSET/LIMIT, with first-occurrence-stable dedup."""
    from repro.core.modifiers import peel_spine

    values = values if values is not None else np.empty(0)
    core, spine = peel_spine(query)
    res = _eval(core, triples, values)
    for expr in spine.filters:
        res = [m for m in res if _filter_val(expr, m, values)]
    for var, asc in reversed(spine.order):   # pre-projection, W3C order
        def key(m, var=var):
            tid = m.get(var, UNBOUND)
            if tid == UNBOUND:
                return float("inf")       # NULLS LAST, like the engines
            v = float(values[tid]) if 0 <= tid < len(values) else float("nan")
            return float(tid) if np.isnan(v) else v
        res = sorted(res, key=key, reverse=not asc)
    if spine.project is not None:
        res = [{v: m.get(v, UNBOUND) for v in spine.project} for m in res]
    if spine.distinct:
        res = _distinct(res)
    if spine.has_slice:
        end = None if spine.limit is None else spine.offset + spine.limit
        res = res[spine.offset:end]
    return res


def mappings_to_multiset(res: List[Mapping], cols) -> Dict[tuple, int]:
    """Canonical multiset form over a fixed column order (UNBOUND fill)."""
    out: Dict[tuple, int] = {}
    for m in res:
        t = tuple(int(m.get(c, UNBOUND)) for c in cols)
        out[t] = out.get(t, 0) + 1
    return out
