"""S2RDF core: ExtVP partitioning schema + SPARQL query engine (the paper's
primary contribution), in JAX-compatible form."""

from repro.core.algebra import BGP, Query, TriplePattern
from repro.core.compiler import Plan, compile_bgp, select_table
from repro.core.executor import Bindings, execute, execute_plan
from repro.core.sparql import parse_sparql
from repro.core.stats import Catalog, build_catalog
from repro.core.table import DeviceTable, Table
from repro.core.vp import build_extvp, build_vp

__all__ = [
    "BGP", "Query", "TriplePattern",
    "Plan", "compile_bgp", "select_table",
    "Bindings", "execute", "execute_plan",
    "parse_sparql",
    "Catalog", "build_catalog",
    "DeviceTable", "Table",
    "build_extvp", "build_vp",
]
