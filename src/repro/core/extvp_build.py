"""Device-accelerated ExtVP construction (the paper's §5 load job, batched).

The numpy builder in :mod:`repro.core.vp` walks all P²·3 (kind, p1, p2)
pairs in a Python loop, one host semi-join per pair.  This module is the
device analogue of S2RDF's distributed Spark load job:

* the VP catalog is packed **once** into padded per-predicate column
  tensors (``PackedVP``) — the per-predicate sort/unique work is hoisted
  out of the pair loop into the packing step;
* semi-join masks for whole **batches** of pairs are evaluated in one
  vmapped pass: through the tiled :func:`repro.kernels.ops.semijoin_mask`
  kernel when the Pallas path is enabled, and through a packed
  **presence bitmap** otherwise — dictionary ids are dense, so build-side
  membership is a single O(1) gather per probe key (the device analogue
  of a hash set; XLA's searchsorted is a poor fit for batched CPU
  probes);
* a ``shard_map`` variant (:func:`repro.core.distributed
  .extvp_pair_masks_sharded`) partitions the pair grid across the mesh,
  so a multi-device build evaluates P²·3/S pairs per device.

Host-side work that remains mirrors S2RDF's Spark *driver*: pair
planning (the disjoint-entity-range short-circuit), SF bookkeeping, and
slicing out the rows of materialized tables.  Results are byte-identical
to the numpy path (asserted in tests/test_extvp_build.py).

:func:`incremental_pairs` supports ``Dataset.append_triples``: only the
pairs whose inputs actually changed — a touched predicate on the probe
side, or new build-side keys inside the probe side's entity range — are
recomputed; every other pair's SF/size/table is carried over verbatim.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import LazyTableMap, Table, round_up_pow2
from repro.core.vp import (
    ExtVPBuild, KINDS, OS, SO, SS, _ranges_disjoint, _semijoin_mask,
)
from repro.kernels import ops

__all__ = [
    "PackedVP", "pack_vp", "all_pair_keys", "plan_pairs", "probe_col",
    "build_col", "batch_pair_masks", "batch_pair_masks_bitmap",
    "evaluate_pairs", "build_extvp_planned", "incremental_pairs",
]

#: presence bitmaps above this many total cells fall back to the kernel
#: path (2 · P · V bool cells ≈ bytes; 2^28 ≈ 256 MB)
BITMAP_CELL_LIMIT = 1 << 28

Key = Tuple[str, int, int]


def probe_col(kind: str) -> int:
    """Which VP column (0 = s, 1 = o) the probe side of ``kind`` reads."""
    return 1 if kind == OS else 0


def build_col(kind: str) -> int:
    """Which unique-column (0 = s, 1 = o) the build side of ``kind`` reads."""
    return 1 if kind == SO else 0


# ---------------------------------------------------------------------------
# Packing: VP catalog -> padded column tensors
# ---------------------------------------------------------------------------

@dataclass
class PackedVP:
    """The VP catalog as static-shape device tensors.

    ``keys[c, i]`` is predicate-slot ``i``'s column ``c`` (0 = s, 1 = o)
    in **row order** (so a semi-join mask indexes the table's rows
    directly), padded with ``PROBE_PAD``.  ``uniq[c, i]`` is the sorted
    unique values of that column, padded with ``BUILD_PAD`` (which sorts
    above every valid id, keeping the padded array ascending).
    ``present[c, i, v]`` is the same key set as a dense membership bitmap
    (ids are dictionary-dense, so ``v`` indexes directly); ``None`` when
    the id space is too large (``BITMAP_CELL_LIMIT``).
    """

    preds: Tuple[int, ...]          # slot -> predicate id
    slot: Dict[int, int]            # predicate id -> slot
    keys: np.ndarray                # (2, P, cap) int32
    uniq: np.ndarray                # (2, P, ucap) int32
    n: np.ndarray                   # (P,) int32 rows per predicate
    present: Optional[np.ndarray]   # (2, P, V) bool, or None


def pack_vp(vp: Dict[int, Table], with_bitmap: bool = True) -> PackedVP:
    """Pack every VP table's columns + sorted-unique key sets.

    This is the hoisted per-predicate work: each ``unique_s``/``unique_o``
    sort happens once here instead of once per pair in the build loop
    (``Table`` caches them, so a later numpy build reuses the same
    arrays).  ``with_bitmap=False`` skips the presence bitmap (the kernel
    path never reads it, and it is the one potentially large tensor).
    """
    preds = tuple(sorted(vp))
    n_preds = len(preds)
    cap = round_up_pow2(max((len(vp[p]) for p in preds), default=1))
    ucap = round_up_pow2(max(
        (max(len(vp[p].unique_s), len(vp[p].unique_o)) for p in preds),
        default=1))
    keys = np.full((2, n_preds, cap), ops.PROBE_PAD, dtype=np.int32)
    uniq = np.full((2, n_preds, ucap), ops.BUILD_PAD, dtype=np.int32)
    n = np.zeros(n_preds, dtype=np.int32)
    max_id = 0
    for i, p in enumerate(preds):
        t = vp[p]
        n[i] = len(t)
        keys[0, i, :len(t)] = t.s
        keys[1, i, :len(t)] = t.o
        uniq[0, i, :len(t.unique_s)] = t.unique_s
        uniq[1, i, :len(t.unique_o)] = t.unique_o
        if len(t):
            max_id = max(max_id, int(t.unique_s[-1]), int(t.unique_o[-1]))
    volume = round_up_pow2(max_id + 1)
    present: Optional[np.ndarray] = None
    if with_bitmap and n_preds and 2 * n_preds * volume <= BITMAP_CELL_LIMIT:
        present = np.zeros((2, n_preds, volume), dtype=bool)
        for i, p in enumerate(preds):
            present[0, i, vp[p].unique_s] = True
            present[1, i, vp[p].unique_o] = True
    return PackedVP(preds=preds, slot={p: i for i, p in enumerate(preds)},
                    keys=keys, uniq=uniq, n=n, present=present)


# ---------------------------------------------------------------------------
# Pair planning (host; identical semantics to the numpy loop)
# ---------------------------------------------------------------------------

def all_pair_keys(preds: Sequence[int],
                  kinds: Sequence[str] = KINDS) -> Iterator[Key]:
    """Every (kind, p1, p2) the schema defines, in the numpy loop's order
    (SS self-pairs are identity by definition and excluded, §5.2)."""
    for p1 in preds:
        for p2 in preds:
            for kind in kinds:
                if kind == SS and p1 == p2:
                    continue
                yield (kind, p1, p2)


def plan_pairs(vp: Dict[int, Table],
               keys_iter: Iterable[Key]) -> Tuple[List[Key], List[Key]]:
    """Split pairs into (pruned, evals): a pair whose probe-side and
    build-side entity ranges are disjoint is structurally empty (SF = 0)
    and never reaches a semi-join — the same short-circuit the numpy
    builder applies."""
    pruned: List[Key] = []
    evals: List[Key] = []
    for key in keys_iter:
        kind, p1, p2 = key
        t1, t2 = vp[p1], vp[p2]
        own = t1.unique_o if kind == OS else t1.unique_s
        other = t2.unique_o if kind == SO else t2.unique_s
        (pruned if _ranges_disjoint(own, other) else evals).append(key)
    return pruned, evals


# ---------------------------------------------------------------------------
# Device evaluation: one vmapped pass per pair batch
# ---------------------------------------------------------------------------

def batch_pair_masks(keys: jax.Array, uniq: jax.Array,
                     pcol: jax.Array, pidx: jax.Array,
                     bcol: jax.Array, bidx: jax.Array
                     ) -> Tuple[jax.Array, jax.Array]:
    """Semi-join masks + counts for a batch of packed pairs, one vmapped
    pass over the :func:`repro.kernels.ops.semijoin_mask` kernel (tiled
    Pallas broadcast-compare when enabled, jnp searchsorted otherwise).

    For slot ``j``: probe = ``keys[pcol[j], pidx[j]]`` (row order),
    build = ``uniq[bcol[j], bidx[j]]`` (ascending).  Padded probe lanes
    (``PROBE_PAD``) never match padded or real build keys, so the count
    is exact.  Also the per-shard body of the distributed pair grid."""
    def one(pc, pi, bc, bi):
        return ops.semijoin_mask(keys[pc, pi], uniq[bc, bi])

    masks = jax.vmap(one)(pcol, pidx, bcol, bidx)      # (B, cap) int32
    return masks, masks.sum(axis=1, dtype=jnp.int32)


def batch_pair_masks_bitmap(keys: jax.Array, present: jax.Array,
                            pcol: jax.Array, pidx: jax.Array,
                            bcol: jax.Array, bidx: jax.Array
                            ) -> Tuple[jax.Array, jax.Array]:
    """Bitmap variant of :func:`batch_pair_masks`: build-side membership
    is one gather per probe key into the dense presence bitmap — the
    device analogue of a hash-set probe, and the fast default off-TPU
    where a batched binary search gathers log₂(ucap) times instead.
    Probe pads (``PROBE_PAD`` ≥ V) fall out via the ``< V`` guard."""
    volume = present.shape[-1]

    def one(pc, pi, bc, bi):
        probe = keys[pc, pi]
        bitmap = present[bc, bi]
        hit = bitmap[jnp.clip(probe, 0, volume - 1)] & (probe < volume)
        return hit.astype(jnp.int32)

    masks = jax.vmap(one)(pcol, pidx, bcol, bidx)      # (B, cap) int32
    return masks, masks.sum(axis=1, dtype=jnp.int32)


@functools.lru_cache(maxsize=None)
def _jitted_batch_fn(use_bitmap: bool, pallas: bool):
    """One compiled wrapper per (path, pallas-flag) pair.  ``pallas`` is
    only a cache key: ``ops.semijoin_mask`` reads the mutable
    ``use_pallas`` state at trace time, so a toggle must map to a fresh
    function identity or jit would replay the stale trace."""
    body = batch_pair_masks_bitmap if use_bitmap else batch_pair_masks
    return jax.jit(lambda *args: body(*args))


def _batch_size(n_pairs: int, pair_batch: int, n_shards: int) -> int:
    """Static batch shape: power-of-two sized (bounding compile count)
    and never above the caller's ``pair_batch`` bound, then rounded up to
    a multiple of the shard count (the one case that may exceed it)."""
    b = round_up_pow2(min(max(n_pairs, 1), pair_batch), minimum=8)
    if b > pair_batch and b > 8:
        b //= 2
    return -(-b // n_shards) * n_shards


def evaluate_pairs(vp: Dict[int, Table], evals: Sequence[Key],
                   threshold: float, backend: str = "jax",
                   mesh=None, pair_batch: int = 512,
                   ) -> Tuple[Dict[Key, float], Dict[Key, int],
                              Dict[Key, Table]]:
    """Semi-join every pair in ``evals``; returns (sf, sizes, tables).

    ``backend="numpy"`` is the host loop (used by the incremental
    rebuild); ``"jax"`` batches the pair grid on the local device;
    ``"distributed"`` shards it across ``mesh`` (all devices when
    ``mesh`` is None).
    """
    sf: Dict[Key, float] = {}
    sizes: Dict[Key, int] = {}
    tables: Dict[Key, Table] = {}
    if not evals:
        return sf, sizes, tables

    if backend == "numpy":
        for key in evals:
            kind, p1, p2 = key
            t1, t2 = vp[p1], vp[p2]
            probe = t1.o if kind == OS else t1.s
            other = t2.unique_o if kind == SO else t2.unique_s
            mask = _semijoin_mask(probe, other)
            m = int(mask.sum())
            n1 = len(t1)
            sfv = m / n1 if n1 else 0.0
            sf[key] = sfv
            sizes[key] = m
            if 0 < sfv < 1.0 and sfv <= threshold:
                tables[key] = Table(t1.rows[mask])   # mask keeps s-order
        return sf, sizes, tables

    if backend not in ("jax", "distributed"):
        raise ValueError(f"unknown ExtVP build backend {backend!r}")

    n_shards = 1
    if backend == "distributed":
        if mesh is None:
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
        n_shards = int(np.prod(list(mesh.shape.values())))

    # The Pallas kernel path probes the sorted-unique tensor; the default
    # jnp path probes the dense presence bitmap (one gather per key).
    # Pack only the predicates this eval set references, so an
    # incremental rebuild of a few pairs is not charged for the whole
    # catalog (a full build references every predicate anyway).
    pallas = ops.pallas_enabled()
    used = {p for k in evals for p in (k[1], k[2])}
    packed = pack_vp({p: vp[p] for p in used}, with_bitmap=not pallas)
    use_bitmap = packed.present is not None and not pallas
    operand = jnp.asarray(packed.present if use_bitmap else packed.uniq)
    jkeys = jnp.asarray(packed.keys)
    batch = _batch_size(len(evals), pair_batch, n_shards)
    pcol = np.array([probe_col(k[0]) for k in evals], dtype=np.int32)
    pidx = np.array([packed.slot[k[1]] for k in evals], dtype=np.int32)
    bcol = np.array([build_col(k[0]) for k in evals], dtype=np.int32)
    bidx = np.array([packed.slot[k[2]] for k in evals], dtype=np.int32)

    for start in range(0, len(evals), batch):
        chunk = evals[start:start + batch]
        sl = slice(start, start + batch)
        parts = [pcol[sl], pidx[sl], bcol[sl], bidx[sl]]
        if len(chunk) < batch:       # pad by repeating the last pair
            parts = [np.concatenate([a, np.full(batch - len(chunk), a[-1],
                                                dtype=a.dtype)])
                     for a in parts]
        args = [jnp.asarray(a) for a in parts]
        if backend == "distributed":
            from repro.core.distributed import extvp_pair_masks_sharded
            masks, counts = extvp_pair_masks_sharded(
                jkeys, operand, *args, mesh=mesh, use_bitmap=use_bitmap)
        else:
            masks, counts = _jitted_batch_fn(use_bitmap, pallas)(
                jkeys, operand, *args)
        # bulk bookkeeping: SF for the whole chunk in one vectorized pass,
        # per-pair host work only where a table actually materializes
        masks = np.asarray(masks)
        counts = np.asarray(counts)[:len(chunk)].astype(np.int64)
        n1s = np.array([len(vp[k[1]]) for k in chunk], dtype=np.int64)
        sfv = np.where(n1s > 0, counts / np.maximum(n1s, 1), 0.0)
        sf.update(zip(chunk, sfv.tolist()))
        sizes.update(zip(chunk, counts.tolist()))
        for j in np.nonzero((sfv > 0) & (sfv < 1.0) & (sfv <= threshold))[0]:
            key = chunk[j]
            rows = vp[key[1]].rows
            tables[key] = Table(rows[masks[j, :len(rows)].astype(bool)])
    return sf, sizes, tables


def build_extvp_planned(vp: Dict[int, Table], threshold: float = 1.0,
                        kinds: Tuple[str, ...] = KINDS,
                        backend: str = "jax", mesh=None,
                        pair_batch: int = 512) -> ExtVPBuild:
    """Full ExtVP schema via the planned pipeline (prune -> evaluate ->
    materialize) on any substrate.  All backends share the pruning, SF
    arithmetic, and row slicing of :func:`evaluate_pairs`, so they are
    byte-identical by construction."""
    out = ExtVPBuild(threshold=threshold, backend=backend,
                     kinds=tuple(kinds))
    pruned, evals = plan_pairs(vp, all_pair_keys(sorted(vp), kinds))
    for key in pruned:
        out.sf[key] = 0.0
        out.sizes[key] = 0
    sf, sizes, tables = evaluate_pairs(vp, evals, threshold, backend=backend,
                                       mesh=mesh, pair_batch=pair_batch)
    out.sf.update(sf)
    out.sizes.update(sizes)
    out.tables.update(tables)
    out.n_semijoins = len(evals)
    return out


# ---------------------------------------------------------------------------
# Incremental rebuild (Dataset.append_triples)
# ---------------------------------------------------------------------------

def incremental_pairs(old: ExtVPBuild, old_vp: Dict[int, Table],
                      new_vp: Dict[int, Table], touched: Set[int],
                      threshold: float, kinds: Tuple[str, ...] = KINDS,
                      backend: str = "numpy", mesh=None,
                      pair_batch: int = 512
                      ) -> Tuple[ExtVPBuild, Dict[str, int]]:
    """Rebuild only the pairs an append actually touched.

    A pair (kind, p1, p2) is carried over from ``old`` verbatim when

    * neither predicate received new triples, or
    * only the build side ``p2`` did, and every **new** unique build key
      falls outside the probe side's entity range — appended rows can
      then only have added build keys that match nothing, so the mask
      (and with it SF, size and the materialized rows) is unchanged.

    Everything else is re-evaluated through :func:`evaluate_pairs` with
    the requested backend.  Returns the new build plus an accounting
    report (``reused`` / ``range_skipped`` / ``recomputed`` /
    ``evaluated`` pair counts).
    """
    out = ExtVPBuild(threshold=threshold, backend=backend,
                     kinds=tuple(kinds))
    recompute: List[Key] = []
    carried: List[Key] = []
    reused = range_skipped = 0

    def carry(key: Key) -> None:
        out.sf[key] = old.sf[key]
        out.sizes[key] = old.sizes[key]
        if key in old.tables:
            carried.append(key)

    for key in all_pair_keys(sorted(new_vp), kinds):
        kind, p1, p2 = key
        if key not in old.sf:            # never computed (e.g. new kind set)
            recompute.append(key)
            continue
        if p1 not in touched and p2 not in touched:
            carry(key)
            reused += 1
            continue
        if p1 not in touched and p2 in touched and p2 in old_vp:
            bc = build_col(kind)
            old_u = old_vp[p2].unique_o if bc else old_vp[p2].unique_s
            new_u = new_vp[p2].unique_o if bc else new_vp[p2].unique_s
            added = np.setdiff1d(new_u, old_u, assume_unique=True)
            own = new_vp[p1].unique_o if kind == OS else new_vp[p1].unique_s
            if len(added) == 0 or len(own) == 0 or \
                    added[0] > own[-1] or added[-1] < own[0]:
                carry(key)
                range_skipped += 1
                continue
        recompute.append(key)

    pruned, evals = plan_pairs(new_vp, recompute)
    for key in pruned:
        out.sf[key] = 0.0
        out.sizes[key] = 0
    sf, sizes, tables = evaluate_pairs(new_vp, evals, threshold,
                                       backend=backend, mesh=mesh,
                                       pair_batch=pair_batch)
    out.sf.update(sf)
    out.sizes.update(sizes)
    # Carried-over tables must not be forced out of a lazy provider
    # (a store-backed catalog memory-maps them on demand): when the old
    # provider can hand out raw loaders, the merged result stays lazy —
    # carried keys keep their loaders, recomputed ones bind concrete
    # Tables — so delta replay cost scales with the journal, not with
    # the number of materialized ExtVP tables.
    loader_for = getattr(old.tables, "loader_for", None)
    if loader_for is not None:
        loaders = {key: loader_for(key) for key in carried}
        loaders.update({key: (lambda t: lambda: t)(t)
                        for key, t in tables.items()})
        out.tables = LazyTableMap(
            loaders, lengths={key: out.sizes[key] for key in loaders})
    else:
        out.tables.update({key: old.tables[key] for key in carried})
        out.tables.update(tables)
    out.n_semijoins = len(evals)
    report = {"pairs": reused + range_skipped + len(recompute),
              "reused": reused, "range_skipped": range_skipped,
              "recomputed": len(recompute), "evaluated": len(evals)}
    return out, report
