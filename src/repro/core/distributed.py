"""Distributed query engine: shard_map over the mesh's data axes.

This is the JAX-native mapping of S2RDF's Spark execution model:

* **Storage partitioning.** Every VP/ExtVP table is hash-partitioned by
  subject id (``s % n_shards``) across the flattened data axes of the
  mesh — the analogue of HDFS blocks + Spark's hash partitioning.  An
  optional object-partitioned copy (``dual_partition=True``) mirrors a
  clustered secondary index and removes the shuffle for object-keyed
  probes (a beyond-paper optimization measured in §Perf).

* **Co-partitioned joins.** A join whose key both sides are already
  partitioned by executes fully locally (zero collective bytes) —
  subject-subject joins over s-partitioned tables hit this path, which is
  why star patterns are shuffle-free, exactly like Spark co-partitioning.

* **Shuffle joins.** Otherwise the engine *repartitions* the relation(s)
  by the join key: rows are bucketed by ``key % n_shards`` into
  fixed-capacity per-destination buckets and exchanged with
  ``lax.all_to_all`` — a static-shape Spark shuffle.  ExtVP's semi-join
  reduction shrinks exactly these exchanged bytes, which is the paper's
  central claim transposed to ICI collectives.

Every shard runs the same static-shape kernels as :mod:`repro.core.jexec`;
results stay sharded, with valid counts summed by ``psum``.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

try:                                    # jax >= 0.5 exports it at top level
    _shard_map = jax.shard_map
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map

from repro.core.algebra import is_var
from repro.core.compiler import (
    BGPSeg, CombineSeg, CorePlan, CoreSeg, EmptySeg, FilterSeg, Plan,
    ScanStep, core_filter_exprs,
)
from repro.core.jexec import (
    JBindings, bounds_from_plan, check_spine, device_distinct,
    device_filter, device_join, device_left_join, device_order,
    device_project, device_resize, device_scan, device_scan_tt,
    device_slice, device_union, double_caps, prepare_value_keys, _compact,
    _exec_cols, _mod_cap_seed, _step_meta, _tt_meta, _valid_mask,
)
from repro.core.modifiers import ModifierSpine, filter_const_slots
from repro.core.stats import Catalog
from repro.core.table import Table, round_up_pow2
from repro.rdf.dictionary import PAD, UNBOUND

__all__ = ["DistBindings", "DistributedExecutor", "shard_table",
           "repartition", "extvp_pair_masks_sharded"]


def _smap(body, mesh, in_specs, out_specs):
    """shard_map with the replication check off where the kwarg exists:
    the gathered modifier tail (sort/scatter over all_gather-ed, hence
    replicated, relations) is replication-safe by construction, but not
    every primitive in it carries a rep rule on every jax version."""
    try:
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=False)
    except TypeError:          # newer jax: the check_rep kwarg is gone
        # replint: disable=shard-map-check-rep -- the explicit decision is the check_rep=False attempt above; this branch only runs on jax versions that removed the kwarg (replication checking off by construction)
        return _shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)


# ---------------------------------------------------------------------------
# Host-side table sharding (storage layout)
# ---------------------------------------------------------------------------

def shard_table(table, n_shards: int, by: int = 0,
                min_cap: int = 16) -> Tuple[np.ndarray, np.ndarray]:
    """Hash-partition rows by column ``by``; returns (rows[S, cap, k], n[S]).

    Accepts a :class:`repro.core.table.Table` or a raw ``(N, k)`` int32
    array (the triples table of unbound-predicate scans)."""
    rows = table.rows if isinstance(table, Table) else np.asarray(table)
    k = rows.shape[1]
    dest = rows[:, by].astype(np.int64) % n_shards
    counts = np.bincount(dest, minlength=n_shards)
    cap = round_up_pow2(int(counts.max()) if len(rows) else 1, min_cap)
    out = np.full((n_shards, cap, k), PAD, dtype=np.int32)
    ns = np.zeros(n_shards, dtype=np.int32)
    order = np.argsort(dest, kind="stable")
    sorted_rows, sorted_dest = rows[order], dest[order]
    starts = np.searchsorted(sorted_dest, np.arange(n_shards))
    ends = np.searchsorted(sorted_dest, np.arange(n_shards), side="right")
    for i in range(n_shards):
        k = ends[i] - starts[i]
        out[i, :k] = sorted_rows[starts[i]:ends[i]]
        ns[i] = k
    return out, ns


# ---------------------------------------------------------------------------
# In-shard repartitioning (the static-shape Spark shuffle)
# ---------------------------------------------------------------------------

def repartition(data: jax.Array, n: jax.Array, key_col: int, n_shards: int,
                axis_name, out_cap: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exchange rows so that row.key % n_shards == shard_index afterwards.

    Runs inside shard_map.  data: (cap, k) local rows.  Returns
    (rows[out_cap, k], n, overflow).
    """
    cap, k = data.shape
    valid = _valid_mask(cap, n)
    key = data[:, key_col]
    dest = jnp.where(valid, key.astype(jnp.uint32) % n_shards, n_shards)

    bucket_cap = max(16, round_up_pow2(2 * cap // n_shards + 16))
    # stable sort by destination groups rows; rank-within-group = slot
    order = jnp.argsort(dest, stable=True).astype(jnp.int32)
    sdest = dest[order]
    sdata = data[order]
    group_start = jnp.searchsorted(sdest, jnp.arange(n_shards + 1, dtype=dest.dtype),
                                   side="left").astype(jnp.int32)
    rank = jnp.arange(cap, dtype=jnp.int32) - group_start[sdest]
    counts = group_start[1:] - group_start[:-1]          # per-dest counts
    overflow = jnp.any(counts[:n_shards] > bucket_cap)

    send = jnp.full((n_shards, bucket_cap, k), PAD, dtype=data.dtype)
    in_bounds = (rank < bucket_cap) & (sdest < n_shards)
    didx = jnp.where(in_bounds, sdest, n_shards).astype(jnp.int32)  # OOB -> drop
    ridx = jnp.clip(rank, 0, bucket_cap - 1)
    send = send.at[didx, ridx].set(sdata, mode="drop")

    recv = jax.lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                              tiled=False)
    recv = recv.reshape(n_shards * bucket_cap, k)
    keep = recv[:, 0] != PAD
    # compact into out_cap
    n_keep = jnp.sum(keep, dtype=jnp.int32)
    corder = jnp.argsort(~keep, stable=True)
    gathered = recv[corder][:out_cap]
    if gathered.shape[0] < out_cap:
        padrows = jnp.full((out_cap - gathered.shape[0], k), PAD, gathered.dtype)
        gathered = jnp.concatenate([gathered, padrows], axis=0)
    mask = _valid_mask(out_cap, jnp.minimum(n_keep, out_cap))
    gathered = jnp.where(mask[:, None], gathered, PAD)
    overflow = overflow | (n_keep > out_cap)
    overflow = jax.lax.pmax(overflow, axis_name)
    return gathered, jnp.minimum(n_keep, out_cap), overflow


# ---------------------------------------------------------------------------
# Distributed plan executor
# ---------------------------------------------------------------------------

@dataclass
class DistBindings:
    cols: Tuple[str, ...]
    data: jax.Array         # (cap, k) — local shard inside shard_map
    n: jax.Array
    overflow: jax.Array
    part_key: Optional[str]  # variable this relation is hash-partitioned by


class DistributedExecutor:
    """Executes a compiled Plan over a mesh via shard_map.

    ``axes`` are the mesh axis names the relational work shards over (the
    model axes of LM jobs are simply folded in — relational plans have no
    'model' dimension, so queries use every chip).
    """

    def __init__(self, plan, catalog: Catalog, mesh: Mesh,
                 axes: Sequence[str] = ("data",), slack: float = 2.0,
                 dual_partition: bool = False,
                 spine: Optional[ModifierSpine] = None):
        if isinstance(plan, CorePlan):
            core = plan
        else:
            core = CorePlan(root=BGPSeg(plan=plan, start=0), flat=plan,
                            empty=plan.empty, vars=plan.vars)
        if core.empty:
            raise ValueError("statistics-empty plan")
        self.core = core
        self.plan = core.flat      # what template re-binding operates on
        self.catalog = catalog
        self.mesh = mesh
        self.axes = tuple(axes)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axes]))
        self.dual_partition = dual_partition
        self.slack = slack
        # Solution modifiers: FILTER + projection are row-local and run
        # per shard; DISTINCT / ORDER BY / OFFSET / LIMIT need the whole
        # relation, so the (small, capacity-bounded) per-shard results
        # are all_gather-ed and the global modifiers run replicated.
        self.spine = spine if spine is not None else ModifierSpine()
        self._pipe_cols = _exec_cols(core.root)
        self._out_vars = check_spine(self.spine, self._pipe_cols, catalog)
        # core filters (OPTIONAL conditions, FILTER segments) consume
        # their fconsts slots first, then the spine's — one shared
        # runtime vector, evaluation order (see PlanExecutor)
        self._all_filters = tuple(core_filter_exprs(core.root)) + \
            tuple(self.spine.filters)
        self.filter_slots = filter_const_slots(self._all_filters)
        # raises NotImplementedError (→ counted eager fallback) only for
        # dictionaries whose numeric keys defeat the double-single pairs
        self._value_keys = prepare_value_keys(catalog, self.spine,
                                              self._all_filters)
        self.gathered = self.spine.needs_global

        # storage: shard every referenced table by subject (and object);
        # TT steps (unbound predicates) share one subject-sharded copy of
        # the triples table
        plan_f = self.plan
        tt_sh: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self.table_shards: List[Dict[str, Tuple[np.ndarray, np.ndarray]]] = []
        sizes: List[float] = []
        for step in plan_f.steps:
            if step.uses_tt:
                if tt_sh is None:
                    tt_sh = shard_table(np.asarray(catalog.tt, np.int32),
                                        self.n_shards, by=0)
                self.table_shards.append({"s": tt_sh})
                sizes.append(float(catalog.n_triples))
                continue
            t = catalog.table(step.kind, int(step.tp.p), step.p2)
            shards = {"s": shard_table(t, self.n_shards, by=0)}
            if dual_partition:
                shards["o"] = shard_table(t, self.n_shards, by=1)
            self.table_shards.append(shards)
            sizes.append(float(len(t)))

        # per-shard capacity seeds: the PlanExecutor estimate chain
        # divided by the shard count (each shard holds ~1/S of every
        # relation); combine segments (join/left/union) get their own
        # slots behind the flat steps, in evaluation (post-) order
        n_flat = len(plan_f.steps)
        flat_caps = [16] * n_flat
        comb_caps: List[int] = []
        self._comb_index: Dict[int, int] = {}

        def seed(seg: CoreSeg) -> float:
            if isinstance(seg, EmptySeg):
                return 1.0
            if isinstance(seg, FilterSeg):
                return seed(seg.child)
            if isinstance(seg, BGPSeg):
                est = 1.0
                for k, step in enumerate(seg.plan.steps):
                    i = seg.start + k
                    scan_est = max(1.0, sizes[i] / self.n_shards)
                    if step.tp.n_bound() > 1:
                        scan_est = max(1.0, scan_est * 0.01)
                    est = scan_est if k == 0 else \
                        max(est, scan_est, est * 1.25)
                    flat_caps[i] = round_up_pow2(int(est * slack) + 16, 16)
                return est
            le, re_ = seed(seg.left), seed(seg.right)
            if seg.kind == "join":
                est = 1.25 * max(le, re_)
            elif seg.kind == "left":
                # inner rows plus (worst case) every left row unmatched
                est = 1.25 * max(le, re_) + le
            else:
                est = le + re_
            self._comb_index[id(seg)] = n_flat + len(comb_caps)
            comb_caps.append(round_up_pow2(int(est * slack) + 16, 16))
            return est

        seed(core.root)
        self.caps = flat_caps + comb_caps
        self._n_pipeline = len(self.caps)
        # per-shard resize slot ahead of the gather: the global modifiers
        # then sort/compact S·mod_cap rows instead of S·join_cap (see
        # PlanExecutor; the slot rides the same overflow-retry protocol)
        self._mod_resize = self.gathered
        if self._mod_resize:
            pipe_cap = max(self.caps) if self.caps else 64
            self.caps.append(_mod_cap_seed(self.spine, pipe_cap))
        self._default_bounds = bounds_from_plan(plan_f)

        # Which storage copy each scan uses.  Beyond-paper optimization:
        # simulate the plan's join-key sequence and pick the copy whose
        # partition variable IS the upcoming join key — an object-keyed
        # probe then reads the o-partitioned copy and skips the all_to_all
        # entirely (the clustered-index analogue of ExtVP's philosophy:
        # trade precomputed storage for shuffle bytes).  The simulation
        # only makes sense within one scan/join pipeline, so it applies
        # when the whole core is a single BGP (FILTER wrappers are
        # transparent); tree cores read the s-copy everywhere.
        self.scan_copy: List[str] = ["s"] * n_flat
        root_bgp: CoreSeg = core.root
        while isinstance(root_bgp, FilterSeg):
            root_bgp = root_bgp.child
        if dual_partition and isinstance(root_bgp, BGPSeg):
            steps = root_bgp.plan.steps
            acc_cols: List[str] = []
            for i, step in enumerate(steps):
                tp = step.tp
                if not step.uses_tt:   # the TT copy is subject-sharded only
                    join_key = None
                    if i > 0:
                        scan_vars = [v for v in (tp.s, tp.o) if is_var(v)]
                        shared = [c for c in acc_cols if c in scan_vars]
                        join_key = shared[0] if shared else None
                    elif len(steps) > 1:
                        # first scan: partition by the 2nd step's join var
                        nxt = steps[1].tp
                        nxt_vars = {v for v in (nxt.s, nxt.o) if is_var(v)}
                        for v in (tp.s, tp.o):
                            if is_var(v) and v in nxt_vars:
                                join_key = v
                                break
                    if join_key is not None and is_var(tp.o) \
                            and join_key == tp.o:
                        self.scan_copy[i] = "o"
                for v in (tp.s, tp.p, tp.o):
                    if is_var(v) and v not in acc_cols:
                        acc_cols.append(v)

    # -- traced per-shard program ---------------------------------------------
    def _shard_index(self) -> jax.Array:
        """This shard's linear index over the data axes (traced)."""
        idx = jnp.asarray(0, jnp.int32)
        for a in self.axes:
            idx = idx * self.mesh.shape[a] + jax.lax.axis_index(a)
        return idx

    def _scan_step(self, i: int, step: ScanStep, rows, nrows,
                   bounds) -> DistBindings:
        """One shard-local scan.  TT steps (unbound predicates) read this
        shard's slice of the subject-sharded triples table; VP/ExtVP
        steps read the copy :attr:`scan_copy` picked."""
        tp = step.tp
        if step.uses_tt:
            s_b, p_b, o_b, eqs, take, cols = _tt_meta(tp)
            sb = bounds[i, 0] if s_b is not None else None
            ob = bounds[i, 1] if o_b is not None else None
            data, n, ovf = device_scan_tt(rows, nrows, sb, p_b, ob,
                                          eqs, take, rows.shape[0])
            part_var = tp.s if is_var(tp.s) else None
            return DistBindings(cols, data, n, ovf, part_var)
        s_bound, o_bound, same, take, cols = _step_meta(step)
        data, n, ovf = device_scan(rows, nrows,
                                   bounds[i, 0] if s_bound is not None else None,
                                   bounds[i, 1] if o_bound is not None else None,
                                   same, take, rows.shape[0])
        copy = self.scan_copy[i]
        part_var = None
        if copy == "s" and is_var(tp.s):
            part_var = tp.s
        elif copy == "o" and is_var(tp.o):
            part_var = tp.o
        return DistBindings(cols, data, n, ovf, part_var)

    def _compose_bgp(self, seg: BGPSeg, caps, flat_tables, bounds, ovfs,
                     axis) -> DistBindings:
        """The shard-local scan/join pipeline of one BGP segment; records
        each step's overflow at its flat index (see PlanExecutor)."""
        no = jnp.asarray(False)
        if not seg.plan.steps:
            # empty BGP: the unit relation (one empty solution mapping)
            # lives on shard 0 — anywhere else it would be counted S times
            n = (self._shard_index() == 0).astype(jnp.int32)
            return DistBindings((), jnp.zeros((8, 0), jnp.int32), n, no,
                                None)
        acc: Optional[DistBindings] = None
        for k, step in enumerate(seg.plan.steps):
            i = seg.start + k
            # local shard: (1, cap, k) and (1,) — drop the sharded axis
            rows, nrows = flat_tables[2 * i][0], flat_tables[2 * i + 1][0]
            cur = self._scan_step(i, step, rows, nrows, bounds)
            if acc is None:
                acc = cur
                ovfs[i] = cur.overflow
                continue
            joined = self._dist_join(acc, cur, caps[i], axis)
            ovfs[i] = joined.overflow | cur.overflow
            acc = joined
        return DistBindings(acc.cols, acc.data, acc.n, no, acc.part_key)

    def _eval_seg(self, seg: CoreSeg, caps, flat_tables, bounds, fconsts,
                  values, ctr, ovfs, axis) -> DistBindings:
        """Evaluate the core segment tree to one shard-local relation;
        mirrors :meth:`repro.core.jexec.PlanExecutor._eval_seg` with the
        combines going through the distributed (co-partition / gather)
        join family.  Each combine writes its own overflow flag at its
        capacity index, so returned relations carry clean flags."""
        no = jnp.asarray(False)
        if isinstance(seg, EmptySeg):
            k = len(seg.vars)
            return DistBindings(tuple(seg.vars),
                                jnp.full((8, k), PAD, jnp.int32),
                                jnp.asarray(0, jnp.int32), no, None)
        if isinstance(seg, BGPSeg):
            return self._compose_bgp(seg, caps, flat_tables, bounds, ovfs,
                                     axis)
        if isinstance(seg, FilterSeg):
            d = self._eval_seg(seg.child, caps, flat_tables, bounds,
                               fconsts, values, ctr, ovfs, axis)
            jb = device_filter(JBindings(d.cols, d.data, d.n, no),
                               seg.expr, values, fconsts, ctr)
            return DistBindings(jb.cols, jb.data, jb.n, no, d.part_key)
        left = self._eval_seg(seg.left, caps, flat_tables, bounds, fconsts,
                              values, ctr, ovfs, axis)
        right = self._eval_seg(seg.right, caps, flat_tables, bounds,
                               fconsts, values, ctr, ovfs, axis)
        ci = self._comb_index[id(seg)]
        if seg.kind == "join":
            out = self._dist_join(left, right, caps[ci], axis)
        elif seg.kind == "left":
            out = self._dist_left_join(left, right, caps[ci], axis,
                                       seg.expr, values, fconsts, ctr)
        else:
            out = self._dist_union(left, right, caps[ci])
        ovfs[ci] = out.overflow
        return DistBindings(out.cols, out.data, out.n, no, out.part_key)

    def _shard_program(self, caps, bounds, fconsts, values, *flat_tables):
        """Returns (data, n, total, per_step_overflow[n_pipeline]).  Like
        :meth:`repro.core.jexec.PlanExecutor._program`, overflow is
        reported per capacity slot so the host retry doubles only the
        overflowing capacities — one heavy constant must not inflate
        every buffer for the whole (batched) workload."""
        axis = self.axes if len(self.axes) > 1 else self.axes[0]
        ctr = [0]
        ovfs: List[jax.Array] = [jnp.asarray(False)] * self._n_pipeline
        acc = self._eval_seg(self.core.root, caps, flat_tables, bounds,
                             fconsts, values, ctr, ovfs, axis)
        out_ovf = jax.lax.pmax(jnp.stack(ovfs), axis)

        # shard-local modifiers: FILTER masks (+ projection when no
        # global modifier needs the un-projected sort keys)
        no = jnp.asarray(False)
        jb = JBindings(acc.cols, acc.data, acc.n, no)
        for expr in self.spine.filters:
            jb = device_filter(jb, expr, values, fconsts, ctr)
        if not self.gathered:
            jb = device_project(jb, self._out_vars)
            total = jax.lax.psum(jb.n, axis)
            return jb.data, jb.n[None], total, out_ovf
        if self._mod_resize:
            jb, mod_ovf = device_resize(jb, caps[self._n_pipeline])
            out_ovf = jnp.concatenate(
                [out_ovf, jax.lax.pmax(mod_ovf, axis)[None]])

        # global modifiers: gather the (capacity-bounded) shard results,
        # compact, then ORDER BY → project → DISTINCT → OFFSET/LIMIT
        # replicated (ordering before projection, as on the host paths) —
        # only the final n ≤ limit rows ever reach the host
        gdata = jax.lax.all_gather(jb.data, axis, axis=0, tiled=True)
        # positional validity (front-compacted shard blocks) — a 0-column
        # relation has no PAD slot to test
        keep = jax.lax.all_gather(
            jnp.arange(jb.data.shape[0], dtype=jnp.int32) < jb.n,
            axis, axis=0, tiled=True)
        cdata, cn, _ = _compact(gdata, keep, gdata.shape[0])
        gb = JBindings(jb.cols, cdata, cn, no)
        if self.spine.order:
            gb = device_order(gb, self.spine.order, values)
        gb = device_project(gb, self._out_vars)
        if self.spine.distinct:
            gb = device_distinct(gb)
        if self.spine.has_slice:
            gb = device_slice(gb, self.spine.offset, self.spine.limit)
        return gb.data, gb.n[None], gb.n, out_ovf

    def _dist_join(self, a: DistBindings, b: DistBindings, out_cap: int,
                   axis) -> DistBindings:
        """Join two shard-local relations; the returned ``overflow`` is
        this step's OWN flag (repartition bucket/compact + join output) —
        input flags are not propagated, the caller tracks them per step."""
        no = jnp.asarray(False)
        shared = [c for c in a.cols if c in b.cols]
        if not shared:
            # cross join: gather the (small) b side everywhere, then local
            b_all, bn_all = _allgather_relation(b, axis)
            jb = device_join(JBindings(a.cols, a.data, a.n, no),
                             JBindings(b.cols, b_all, bn_all, no),
                             out_cap)
            return DistBindings(jb.cols, jb.data, jb.n, jb.overflow, a.part_key)
        key = shared[0]
        ovf = no
        da, na = a.data, a.n
        db, nb = b.data, b.n
        # repartition any side not already partitioned by the join key
        if a.part_key != key:
            da, na, o1 = repartition(da, na, a.cols.index(key), self.n_shards,
                                     axis, max(da.shape[0], out_cap))
            ovf |= o1
        if b.part_key != key:
            db, nb, o2 = repartition(db, nb, b.cols.index(key), self.n_shards,
                                     axis, max(db.shape[0], out_cap))
            ovf |= o2
        jb = device_join(JBindings(a.cols, da, na, no),
                         JBindings(b.cols, db, nb, no),
                         out_cap)
        return DistBindings(jb.cols, jb.data, jb.n, jb.overflow | ovf, key)

    def _dist_left_join(self, a: DistBindings, b: DistBindings,
                        out_cap: int, axis, expr, values, fconsts,
                        ctr) -> DistBindings:
        """OPTIONAL over shard-local relations.  With a shared variable
        both sides are co-partitioned on it first, so each probe row
        meets ALL its matches locally and the unmatched (UNBOUND-padded)
        tail is computed shard-locally too; without one the (small) b
        side is gathered everywhere — either way the per-shard row sets
        partition the global left-outer-join result exactly."""
        no = jnp.asarray(False)
        shared = [c for c in a.cols if c in b.cols]
        if not shared:
            b_all, bn_all = _allgather_relation(b, axis)
            jb = device_left_join(JBindings(a.cols, a.data, a.n, no),
                                  JBindings(b.cols, b_all, bn_all, no),
                                  out_cap, expr, values, fconsts, ctr)
            return DistBindings(jb.cols, jb.data, jb.n, jb.overflow,
                                a.part_key)
        key = shared[0]
        ovf = no
        da, na = a.data, a.n
        db, nb = b.data, b.n
        if a.part_key != key:
            da, na, o1 = repartition(da, na, a.cols.index(key),
                                     self.n_shards, axis,
                                     max(da.shape[0], out_cap))
            ovf |= o1
        if b.part_key != key:
            db, nb, o2 = repartition(db, nb, b.cols.index(key),
                                     self.n_shards, axis,
                                     max(db.shape[0], out_cap))
            ovf |= o2
        jb = device_left_join(JBindings(a.cols, da, na, no),
                              JBindings(b.cols, db, nb, no),
                              out_cap, expr, values, fconsts, ctr)
        return DistBindings(jb.cols, jb.data, jb.n, jb.overflow | ovf, key)

    def _dist_union(self, a: DistBindings, b: DistBindings,
                    out_cap: int) -> DistBindings:
        """UNION is embarrassingly shard-local (no collective): each
        shard concatenates its slices of both operands.  The partition
        key survives only when both sides are partitioned by the SAME
        variable (rows keep satisfying key % S == shard)."""
        no = jnp.asarray(False)
        jb = device_union(JBindings(a.cols, a.data, a.n, no),
                          JBindings(b.cols, b.data, b.n, no), out_cap)
        pk = a.part_key if (a.part_key is not None
                            and a.part_key == b.part_key) else None
        return DistBindings(jb.cols, jb.data, jb.n, jb.overflow, pk)

    # -- public API --------------------------------------------------------------
    bounds_from_plan = staticmethod(bounds_from_plan)

    def fconsts_from_mapping(self, mapping=None) -> np.ndarray:
        """Runtime filter-constant vector (see
        :meth:`repro.core.jexec.PlanExecutor.fconsts_from_mapping`)."""
        m = mapping or {}
        return np.asarray([m.get(c, c) for c in self.filter_slots],
                          dtype=np.int32)

    @functools.cached_property
    def _values(self) -> jax.Array:
        # the (nv, 4) double-single numeric key table (replicated); see
        # repro.core.jexec.numeric_value_keys
        return jnp.asarray(self._value_keys)

    def _out_specs(self):
        if self.gathered:     # replicated post-gather results
            return (P(), P(), P(), P())
        return (P(self.axes), P(self.axes), P(), P())

    @functools.cached_property
    def _jitted(self):
        specs = [P(), P(), P()]   # bounds / fconsts / values replicated
        for shards, copy in zip(self.table_shards, self.scan_copy):
            specs.append(P(self.axes))      # rows (S, cap, 2) split on axes
            specs.append(P(self.axes))      # ns   (S,)

        def wrapper(caps, bounds, fconsts, values, *flat):
            fn = _smap(
                functools.partial(self._shard_program, caps),
                mesh=self.mesh,
                in_specs=tuple(specs),
                out_specs=self._out_specs(),
            )
            return fn(bounds, fconsts, values, *flat)

        return jax.jit(wrapper, static_argnums=(0,))

    @functools.cached_property
    def _jitted_batch(self):
        # Batched form: the (B, n_steps, 2) bounds stack is replicated to
        # every shard and vmapped *inside* shard_map, so the batch axis
        # rides alongside the data axis — every device executes all B
        # constant-bindings over its own table shard in one launch, and
        # results stay sharded per (request, shard) (or replicated per
        # request once a global modifier gathers them).
        specs = [P(), P(), P()]   # bounds (B,...) / fconsts (B,...) / values
        for _ in self.table_shards:
            specs.append(P(self.axes))      # rows (S, cap, 2) split on axes
            specs.append(P(self.axes))      # ns   (S,)

        if self.gathered:
            out_specs = (P(), P(), P(), P())
        else:
            out_specs = (P(None, self.axes), P(None, self.axes), P(), P())

        def wrapper(caps, bounds_b, fconsts_b, values, *flat):
            def shard_fn(bounds_b, fconsts_b, values, *flat):
                return jax.vmap(
                    lambda b, fc: self._shard_program(caps, b, fc, values,
                                                      *flat)
                )(bounds_b, fconsts_b)

            fn = _smap(
                shard_fn,
                mesh=self.mesh,
                in_specs=tuple(specs),
                out_specs=out_specs,
            )
            return fn(bounds_b, fconsts_b, values, *flat)

        return jax.jit(wrapper, static_argnums=(0,))

    def _flat_inputs(self):
        flat = []
        for shards, copy in zip(self.table_shards, self.scan_copy):
            rows, ns = shards[copy]
            flat.append(rows)
            flat.append(ns)
        return flat

    def lower(self, caps: Optional[Tuple[int, ...]] = None):
        caps = caps or tuple(self.caps)
        flat = [jax.ShapeDtypeStruct(x.shape, x.dtype) for x in self._flat_inputs()]
        bshape = jax.ShapeDtypeStruct(self._default_bounds.shape, jnp.int32)
        fshape = jax.ShapeDtypeStruct((len(self.filter_slots),), jnp.int32)
        vshape = jax.ShapeDtypeStruct(self._values.shape, jnp.float32)
        return self._jitted.lower(caps, bshape, fshape, vshape, *flat)

    def run(self, max_retries: int = 16,
            bounds: Optional[np.ndarray] = None,
            fconsts: Optional[np.ndarray] = None,
            trace=None) -> Tuple[np.ndarray, Tuple[str, ...]]:
        flat = self._flat_inputs()
        b = self._default_bounds if bounds is None else \
            np.asarray(bounds, dtype=np.int32).reshape(self._default_bounds.shape)
        bj = jnp.asarray(b)
        fc = self.fconsts_from_mapping(None) if fconsts is None else \
            np.asarray(fconsts, dtype=np.int32).reshape(len(self.filter_slots))
        fj = jnp.asarray(fc)
        caps = tuple(self.caps)
        for attempt in range(max_retries):
            if trace is not None:
                # fenced launch span (traced requests only) — see
                # PlanExecutor.run
                sid = trace.start("device.launch", backend="distributed",
                                  attempt=attempt, batch=1,
                                  shards=self.n_shards,
                                  cap_slots=sum(caps))
                data, ns, total, ovf = self._jitted(caps, bj, fj,
                                                    self._values, *flat)
                jax.block_until_ready((data, ns, ovf))
                ovf = np.asarray(ovf)
                trace.end(sid, overflow=bool(ovf.any()))
            else:
                data, ns, total, ovf = self._jitted(caps, bj, fj,
                                                    self._values, *flat)
                ovf = np.asarray(ovf)
            if not ovf.any():
                self.caps = list(caps)   # keep grown caps across requests
                data = np.asarray(data)
                ns = np.asarray(ns)
                if self.gathered:        # replicated, already finalized
                    return data[: int(ns[0])], self._final_cols()
                rows = []
                per = data.reshape(self.n_shards,
                                   data.shape[0] // self.n_shards,
                                   data.shape[-1])
                for i in range(self.n_shards):
                    rows.append(per[i][: int(ns[i])])
                out = np.concatenate(rows, axis=0) if rows else np.empty((0, 0))
                return out, self._final_cols()
            caps = double_caps(caps, ovf, self._n_pipeline)
        raise RuntimeError("distributed join capacity overflow after retries")

    def run_batch(self, bounds_batch: Sequence[np.ndarray],
                  fconsts_batch: Optional[Sequence[np.ndarray]] = None,
                  max_retries: int = 16,
                  trace=None) -> List[Tuple[np.ndarray, Tuple[str, ...]]]:
        """Execute B constant-bindings of the plan in one sharded launch;
        see :meth:`repro.core.jexec.PlanExecutor.run_batch` for the retry
        contract (any element overflowing retries the whole batch)."""
        if not bounds_batch:
            return []
        flat = self._flat_inputs()
        shape = self._default_bounds.shape
        bb = np.stack([np.asarray(b, dtype=np.int32).reshape(shape)
                       for b in bounds_batch])
        bj = jnp.asarray(bb)
        n_fc = len(self.filter_slots)
        if fconsts_batch is None:
            fb = np.tile(self.fconsts_from_mapping(None), (len(bb), 1))
        else:
            fb = np.stack([np.asarray(f, dtype=np.int32).reshape(n_fc)
                           for f in fconsts_batch])
        fj = jnp.asarray(fb)
        caps = tuple(self.caps)
        for attempt in range(max_retries):
            if trace is not None:
                sid = trace.start("device.launch", backend="distributed",
                                  attempt=attempt, batch=len(bb),
                                  shards=self.n_shards,
                                  cap_slots=sum(caps))
                data, ns, total, ovf = self._jitted_batch(
                    caps, bj, fj, self._values, *flat)
                jax.block_until_ready((data, ns, ovf))
                ovf = np.asarray(ovf)            # (B, n_steps)
                trace.end(sid, overflow=bool(ovf.any()))
            else:
                data, ns, total, ovf = self._jitted_batch(
                    caps, bj, fj, self._values, *flat)
                ovf = np.asarray(ovf)            # (B, n_steps)
            if not ovf.any():
                self.caps = list(caps)
                data = np.asarray(data)          # (B, S*cap, k)
                ns = np.asarray(ns)              # (B, S) or (B, 1)
                cols = self._final_cols()
                out = []
                for bi in range(data.shape[0]):
                    if self.gathered:
                        out.append((data[bi][: int(ns[bi, 0])], cols))
                        continue
                    per = data[bi].reshape(self.n_shards,
                                           data.shape[1] // self.n_shards,
                                           data.shape[-1])
                    rows = [per[i][: int(ns[bi, i])]
                            for i in range(self.n_shards)]
                    merged = np.concatenate(rows, axis=0) if rows \
                        else np.empty((0, 0))
                    out.append((merged, cols))
                return out
            caps = double_caps(caps, ovf.any(axis=0), self._n_pipeline)
        raise RuntimeError(
            "distributed join capacity overflow after retries (batched)")

    def _final_cols(self) -> Tuple[str, ...]:
        return self._out_vars


# ---------------------------------------------------------------------------
# Distributed ExtVP construction (the load-job analogue of the query engine)
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _extvp_pair_program(mesh: Mesh, axes: Tuple[str, ...], use_bitmap: bool,
                        pallas: bool):
    """``pallas`` is only a cache key: the kernel body reads the mutable
    ``ops.use_pallas`` state at trace time, so a toggle needs a fresh
    program rather than a replay of the stale trace."""
    from repro.core.extvp_build import (
        batch_pair_masks, batch_pair_masks_bitmap,
    )

    body = batch_pair_masks_bitmap if use_bitmap else batch_pair_masks
    specs = dict(in_specs=(P(), P(), P(axes), P(axes), P(axes), P(axes)),
                 out_specs=(P(axes), P(axes)))
    try:
        # pallas_call has no replication rule; the body has no collectives,
        # so skipping the check is sound
        fn = _shard_map(body, mesh=mesh, check_rep=False, **specs)
    except TypeError:           # newer jax: the check_rep kwarg is gone
        # replint: disable=shard-map-check-rep -- the explicit decision is the check_rep=False attempt above; this branch only runs on jax versions that removed the kwarg (the body has no collectives)
        fn = _shard_map(body, mesh=mesh, **specs)
    return jax.jit(fn)


def extvp_pair_masks_sharded(keys: jax.Array, build_operand: jax.Array,
                             pcol: jax.Array, pidx: jax.Array,
                             bcol: jax.Array, bidx: jax.Array, mesh: Mesh,
                             axes: Optional[Sequence[str]] = None,
                             use_bitmap: bool = False
                             ) -> Tuple[jax.Array, jax.Array]:
    """Semi-join masks for a batch of packed ExtVP pairs with the
    (kind, p1, p2) pair grid partitioned across the mesh.

    S2RDF runs the §5 semi-join reductions as a distributed Spark job;
    here the packed catalog (probe columns + the build-side operand —
    sorted-unique tensor for the kernel path, dense presence bitmap when
    ``use_bitmap``) is replicated and each device evaluates its B/S slice
    of the pair batch — the load-time counterpart of the query engine's
    sharded scans.  The batch size must divide evenly by the shard count
    (the planner in :mod:`repro.core.extvp_build` rounds it up).
    """
    axes = tuple(axes) if axes is not None else tuple(mesh.axis_names)
    n_shards = int(np.prod([mesh.shape[a] for a in axes]))
    if pcol.shape[0] % n_shards:
        raise ValueError(f"pair batch {pcol.shape[0]} must divide evenly "
                         f"across {n_shards} shards")
    from repro.kernels.ops import pallas_enabled
    return _extvp_pair_program(mesh, axes, use_bitmap, pallas_enabled())(
        keys, build_operand, pcol, pidx, bcol, bidx)


def _allgather_relation(b: DistBindings, axis):
    """Gather a (front-compacted) shard-local relation to every shard.
    Validity is positional — row i of a shard block is live iff
    ``i < n`` — which also covers 0-column relations (fully-constant
    patterns) that have no PAD slot to test."""
    data = jax.lax.all_gather(b.data, axis, axis=0, tiled=True)
    keep = jax.lax.all_gather(
        jnp.arange(b.data.shape[0], dtype=jnp.int32) < b.n,
        axis, axis=0, tiled=True)
    n_tot = jax.lax.psum(b.n, axis)
    return data[jnp.argsort(~keep, stable=True)], n_tot
