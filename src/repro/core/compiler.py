"""SPARQL → relational-plan compiler (paper §6, Algorithms 1–4).

``select_table``    — Algorithm 1 (TableSelection): per triple pattern,
choose the ExtVP table with the smallest SF over all SS/SO/OS correlations
to other patterns in the BGP; fall back to VP; TT for unbound predicates.

``compile_bgp``     — Algorithm 4 (BGP2SQL_OPT): join-order by
(#bound values, selected-table size), preferring join-connected patterns
so cross joins only happen when the BGP is genuinely disconnected;
short-circuits to the empty plan when any selected table has SF = 0
("a SPARQL query which contains a correlation between two predicates that
does not exist in the dataset can be answered by using the statistics
only").

The produced :class:`Plan` is declarative — a join-ordered list of
:class:`ScanStep` — and is executed by either the eager host executor
(:mod:`repro.core.executor`), the static-shape jitted executor
(:mod:`repro.core.jexec`) or the distributed shard_map engine
(:mod:`repro.core.distributed`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple, Union

from repro.core.algebra import (
    BGP, CORR_OS, CORR_SO, CORR_SS, Filter, FilterExpr, JoinPair, LeftJoin,
    Node, TriplePattern, UnionOp, correlations, is_var, tp_vars,
)
from repro.core.stats import Catalog

__all__ = ["ScanStep", "Plan", "select_table", "compile_bgp",
           "BGPSeg", "EmptySeg", "FilterSeg", "CombineSeg", "CorePlan",
           "compile_core", "core_filter_exprs", "seg_vars"]

MISSING_TERM = -2


@dataclass
class ScanStep:
    """One triple pattern bound to its selected table."""

    tp: TriplePattern
    kind: Optional[str]          # None => VP (or TT if tp.p is a var)
    p2: Optional[int]            # partner predicate for ExtVP tables
    sf: float                    # SF of the selected table
    size: int                    # tuples in the selected table (stats)
    uses_tt: bool = False        # unbound predicate => triples table

    def describe(self) -> str:
        if self.uses_tt:
            return f"TT{self.tp}"
        if self.kind is None:
            return f"VP[{self.tp.p}]{self.tp}"
        return f"ExtVP^{self.kind}[{self.tp.p}|{self.p2}]{self.tp} sf={self.sf:.3g}"


@dataclass
class Plan:
    steps: List[ScanStep] = field(default_factory=list)
    empty: bool = False          # statistics-proven empty result
    vars: Tuple[str, ...] = ()
    #: which join-order planner produced ``steps``: "greedy" (Algorithm 4)
    #: or "estimate" (cardinality-estimate enumeration).  A requested
    #: "estimate" that fell back (no distinct-count statistics) records
    #: "greedy" — the field reports what actually ran.
    planner: str = "greedy"

    def describe(self) -> str:
        if self.empty:
            return "EMPTY (statistics short-circuit)"
        return " ⋈ ".join(s.describe() for s in self.steps)


def select_table(tp: TriplePattern, bgp: List[TriplePattern],
                 catalog: Catalog, layout: str = "extvp") -> ScanStep:
    """Algorithm 1 (TableSelection).

    ``layout`` selects the storage schema under comparison (paper §4):
    "extvp" (the contribution), "vp" (Abadi-style vertical partitioning —
    the paper's own baseline) or "tt" (giant triples table)."""
    if layout == "tt":
        return ScanStep(tp, None, None, 1.0, catalog.n_triples, uses_tt=True)
    if is_var(tp.p):
        return ScanStep(tp, None, None, 1.0, catalog.n_triples, uses_tt=True)
    p = int(tp.p)
    if p == MISSING_TERM or catalog.vp_size(p) == 0:
        return ScanStep(tp, None, None, 0.0, 0)

    best_kind: Optional[str] = None
    best_p2: Optional[int] = None
    best_sf = 1.0
    best_size = catalog.vp_size(p)

    if layout == "vp":
        return ScanStep(tp, None, None, best_sf, best_size)

    for other in bgp:
        if other is tp or is_var(other.p):
            continue
        q = int(other.p)
        if q == MISSING_TERM:
            continue
        for corr in correlations(tp, other):
            if corr not in (CORR_SS, CORR_SO, CORR_OS):
                continue  # OO not precomputed (paper §5.2)
            sf = catalog.sf(corr, p, q)
            # Only credit reductions the store can actually serve: an SF
            # above the build threshold τ was never materialized, and
            # Catalog.table() would silently scan the full VP relation
            # while the recorded sf/size misled join ordering and the
            # cardinality estimator.  SF=0 stays selectable regardless —
            # it is a statistics-only short-circuit, no table needed.
            if sf < best_sf and (sf == 0.0 or catalog.materialized(corr, p, q)):
                best_sf = sf
                best_kind, best_p2 = corr, q
                best_size = catalog.size(corr, p, q)
    return ScanStep(tp, best_kind, best_p2, best_sf, best_size)


def _emptiness(tp: TriplePattern) -> bool:
    """A pattern with a bound term that is missing from the dictionary."""
    return any((not is_var(t)) and int(t) == MISSING_TERM
               for t in (tp.s, tp.p, tp.o))


def compile_bgp(bgp: BGP, catalog: Catalog, layout: str = "extvp",
                planner: str = "greedy") -> Plan:
    """Algorithm 4 (BGP2SQL_OPT): table selection + join ordering.

    ``planner`` selects the join-order strategy: ``"greedy"`` is the
    paper's (#bound values, table size) order; ``"estimate"`` runs the
    bounded cardinality-estimate enumerator (:mod:`repro.core.estimate`)
    over the same selected tables — emptiness short-circuits and table
    selection are planner-invariant, only the step order changes.  An
    estimate request silently falls back to greedy when the catalog has
    no distinct-count statistics (e.g. a version-1 store).
    """
    if planner not in ("greedy", "estimate"):
        raise ValueError(
            f"unknown planner {planner!r}; expected 'greedy' or 'estimate'")
    patterns = list(bgp.patterns)
    if not patterns:
        return Plan(steps=[], vars=())

    # Statistics-only empties: missing terms or SF=0 selected tables.
    if any(_emptiness(tp) for tp in patterns):
        return Plan(empty=True, vars=bgp.vars())

    selected = {id(tp): select_table(tp, patterns, catalog, layout)
                for tp in patterns}
    if any(s.sf == 0.0 for s in selected.values()):
        return Plan(empty=True, vars=bgp.vars())

    if planner == "estimate":
        from repro.core import estimate as _estimate
        enumerated = _estimate.order_steps(
            [selected[id(tp)] for tp in patterns], catalog)
        if enumerated is not None:
            return Plan(steps=enumerated, vars=bgp.vars(),
                        planner="estimate")

    # Join ordering.  Paper: order by #bound values first, then repeatedly
    # pick the smallest-table pattern that is join-connected to the bound
    # variable set (avoiding cross joins unless the BGP is disconnected).
    remaining = list(patterns)
    bound_vars: set = set()
    ordered: List[ScanStep] = []
    while remaining:
        def sort_key(tp: TriplePattern):
            step = selected[id(tp)]
            connected = bool(bound_vars) and bool(set(tp_vars(tp)) & bound_vars)
            # Prefer: connected (after first), more bound values, smaller table
            return (
                0 if (connected or not bound_vars) else 1,
                -tp.n_bound(),
                step.size,
            )

        nxt = min(remaining, key=sort_key)
        remaining.remove(nxt)
        ordered.append(selected[id(nxt)])
        bound_vars |= set(tp_vars(nxt))

    return Plan(steps=ordered, vars=bgp.vars())


# ---------------------------------------------------------------------------
# Core plans: pattern trees (OPTIONAL / UNION / FILTER over BGPs) compiled
# for the static-shape device executors.
#
# A *core* is the graph-pattern part of a query (the tree under the
# solution-modifier spine).  The device engines execute it as a tree of
# segments over ONE flat join-ordered scan list:
#
#   * ``BGPSeg``     — a compiled BGP (Algorithm 4 plan) whose steps live
#                      at ``[start, start + len(plan.steps))`` in the flat
#                      plan, so constant re-binding stays a single
#                      ``(n_steps, 2)`` runtime bounds array;
#   * ``FilterSeg``  — a FILTER applied to its child's relation;
#   * ``CombineSeg`` — join / left-outer join (OPTIONAL) / union of two
#                      child segments;
#   * ``EmptySeg``   — a statistics-proven empty subtree (SF = 0 or a
#                      missing term), kept in the tree because OPTIONAL
#                      and UNION survive an empty operand.
# ---------------------------------------------------------------------------

@dataclass
class BGPSeg:
    """A compiled BGP; ``start`` is its offset in ``CorePlan.flat``."""

    plan: Plan
    start: int = 0


@dataclass
class EmptySeg:
    """Statistics-proven empty subtree (vars kept for column layout)."""

    vars: Tuple[str, ...] = ()


@dataclass
class FilterSeg:
    child: "CoreSeg"
    expr: FilterExpr


@dataclass
class CombineSeg:
    kind: str                 # 'join' | 'left' | 'union'
    left: "CoreSeg"
    right: "CoreSeg"
    expr: Optional[FilterExpr] = None   # OPTIONAL's join condition


CoreSeg = Union[BGPSeg, EmptySeg, FilterSeg, CombineSeg]


@dataclass
class CorePlan:
    """A segment tree plus the flat scan plan the segments index into.

    ``flat`` is what template re-binding operates on
    (:func:`repro.engine.template.rebind_plan` /
    :func:`repro.core.jexec.bounds_from_plan` are tree-agnostic: scan
    constants are positional over the flat step list).
    """

    root: CoreSeg
    flat: Plan
    empty: bool
    vars: Tuple[str, ...]

    def describe(self) -> str:
        if self.empty:
            return "EMPTY (statistics short-circuit)"

        def rec(seg: CoreSeg) -> str:
            if isinstance(seg, BGPSeg):
                return seg.plan.describe()
            if isinstance(seg, EmptySeg):
                return "EMPTY"
            if isinstance(seg, FilterSeg):
                return f"FILTER({rec(seg.child)})"
            op = {"join": "⋈", "left": "⟕", "union": "∪"}[seg.kind]
            return f"({rec(seg.left)} {op} {rec(seg.right)})"

        return rec(self.root)


def seg_vars(seg: CoreSeg) -> Tuple[str, ...]:
    """Variables a segment's relation binds, in column order (the order
    the device pipeline produces: left-to-right, first-seen)."""
    if isinstance(seg, EmptySeg):
        return tuple(seg.vars)
    if isinstance(seg, BGPSeg):
        return seg.plan.vars
    if isinstance(seg, FilterSeg):
        return seg_vars(seg.child)
    left = seg_vars(seg.left)
    return left + tuple(v for v in seg_vars(seg.right) if v not in left)


def core_filter_exprs(seg: CoreSeg) -> List[FilterExpr]:
    """Filter expressions of a core in evaluation order — the order the
    traced program consumes their constant slots (child before own
    expression; combine children left before right before the OPTIONAL
    condition).  Prepended to the spine's filters when building the
    shared runtime ``fconsts`` vector."""
    if isinstance(seg, FilterSeg):
        return core_filter_exprs(seg.child) + [seg.expr]
    if isinstance(seg, CombineSeg):
        out = core_filter_exprs(seg.left) + core_filter_exprs(seg.right)
        if seg.expr is not None:
            out.append(seg.expr)
        return out
    return []


def compile_core(node: Node, catalog: Catalog,
                 layout: str = "extvp", planner: str = "greedy") -> CorePlan:
    """Compile a graph-pattern tree into a :class:`CorePlan`.

    Two phases: (1) bottom-up build with emptiness pruning — a
    statistics-empty BGP collapses to :class:`EmptySeg` and the pruning
    respects operator identity (a join with an empty operand is empty; a
    left join survives an empty RIGHT side — its left rows pass through
    UNBOUND-padded; a union survives either side empty); (2) flat-offset
    assignment over the pruned tree, so discarded subtrees contribute no
    scan steps, no capacities and no bounds rows.

    Raises ``NotImplementedError`` for node kinds outside the device
    fragment — the backends' fall-back-to-eager signal.
    """

    def build(n: Node) -> CoreSeg:
        if isinstance(n, BGP):
            plan = compile_bgp(n, catalog, layout, planner)
            if plan.empty:
                return EmptySeg(vars=plan.vars)
            return BGPSeg(plan=plan)
        if isinstance(n, Filter):
            child = build(n.child)
            if isinstance(child, EmptySeg):
                return child
            return FilterSeg(child=child, expr=n.expr)
        if isinstance(n, JoinPair):
            left, right = build(n.left), build(n.right)
            if isinstance(left, EmptySeg) or isinstance(right, EmptySeg):
                lv = seg_vars(left)
                return EmptySeg(vars=lv + tuple(
                    v for v in seg_vars(right) if v not in lv))
            return CombineSeg(kind="join", left=left, right=right)
        if isinstance(n, LeftJoin):
            left, right = build(n.left), build(n.right)
            if isinstance(left, EmptySeg):
                lv = seg_vars(left)
                return EmptySeg(vars=lv + tuple(
                    v for v in seg_vars(right) if v not in lv))
            return CombineSeg(kind="left", left=left, right=right,
                              expr=n.expr)
        if isinstance(n, UnionOp):
            left, right = build(n.left), build(n.right)
            if isinstance(left, EmptySeg) and isinstance(right, EmptySeg):
                lv = seg_vars(left)
                return EmptySeg(vars=lv + tuple(
                    v for v in seg_vars(right) if v not in lv))
            return CombineSeg(kind="union", left=left, right=right)
        raise NotImplementedError(
            f"device core does not cover {type(n).__name__}")

    root = build(node)

    flat_steps: List[ScanStep] = []

    def assign(seg: CoreSeg) -> None:
        if isinstance(seg, BGPSeg):
            seg.start = len(flat_steps)
            flat_steps.extend(seg.plan.steps)
        elif isinstance(seg, FilterSeg):
            assign(seg.child)
        elif isinstance(seg, CombineSeg):
            assign(seg.left)
            assign(seg.right)

    assign(root)
    empty = isinstance(root, EmptySeg)

    def used(seg: CoreSeg) -> bool:
        if isinstance(seg, BGPSeg):
            return seg.plan.planner == "estimate"
        if isinstance(seg, FilterSeg):
            return used(seg.child)
        if isinstance(seg, CombineSeg):
            return used(seg.left) or used(seg.right)
        return False

    flat = Plan(steps=flat_steps, empty=empty, vars=seg_vars(root),
                planner="estimate" if used(root) else "greedy")
    return CorePlan(root=root, flat=flat, empty=empty, vars=flat.vars)
