"""SPARQL → relational-plan compiler (paper §6, Algorithms 1–4).

``select_table``    — Algorithm 1 (TableSelection): per triple pattern,
choose the ExtVP table with the smallest SF over all SS/SO/OS correlations
to other patterns in the BGP; fall back to VP; TT for unbound predicates.

``compile_bgp``     — Algorithm 4 (BGP2SQL_OPT): join-order by
(#bound values, selected-table size), preferring join-connected patterns
so cross joins only happen when the BGP is genuinely disconnected;
short-circuits to the empty plan when any selected table has SF = 0
("a SPARQL query which contains a correlation between two predicates that
does not exist in the dataset can be answered by using the statistics
only").

The produced :class:`Plan` is declarative — a join-ordered list of
:class:`ScanStep` — and is executed by either the eager host executor
(:mod:`repro.core.executor`), the static-shape jitted executor
(:mod:`repro.core.jexec`) or the distributed shard_map engine
(:mod:`repro.core.distributed`).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from repro.core.algebra import (
    BGP, CORR_OS, CORR_SO, CORR_SS, TriplePattern, correlations, is_var,
    tp_vars,
)
from repro.core.stats import Catalog

__all__ = ["ScanStep", "Plan", "select_table", "compile_bgp"]

MISSING_TERM = -2


@dataclass
class ScanStep:
    """One triple pattern bound to its selected table."""

    tp: TriplePattern
    kind: Optional[str]          # None => VP (or TT if tp.p is a var)
    p2: Optional[int]            # partner predicate for ExtVP tables
    sf: float                    # SF of the selected table
    size: int                    # tuples in the selected table (stats)
    uses_tt: bool = False        # unbound predicate => triples table

    def describe(self) -> str:
        if self.uses_tt:
            return f"TT{self.tp}"
        if self.kind is None:
            return f"VP[{self.tp.p}]{self.tp}"
        return f"ExtVP^{self.kind}[{self.tp.p}|{self.p2}]{self.tp} sf={self.sf:.3g}"


@dataclass
class Plan:
    steps: List[ScanStep] = field(default_factory=list)
    empty: bool = False          # statistics-proven empty result
    vars: Tuple[str, ...] = ()

    def describe(self) -> str:
        if self.empty:
            return "EMPTY (statistics short-circuit)"
        return " ⋈ ".join(s.describe() for s in self.steps)


def select_table(tp: TriplePattern, bgp: List[TriplePattern],
                 catalog: Catalog, layout: str = "extvp") -> ScanStep:
    """Algorithm 1 (TableSelection).

    ``layout`` selects the storage schema under comparison (paper §4):
    "extvp" (the contribution), "vp" (Abadi-style vertical partitioning —
    the paper's own baseline) or "tt" (giant triples table)."""
    if layout == "tt":
        return ScanStep(tp, None, None, 1.0, catalog.n_triples, uses_tt=True)
    if is_var(tp.p):
        return ScanStep(tp, None, None, 1.0, catalog.n_triples, uses_tt=True)
    p = int(tp.p)
    if p == MISSING_TERM or catalog.vp_size(p) == 0:
        return ScanStep(tp, None, None, 0.0, 0)

    best_kind: Optional[str] = None
    best_p2: Optional[int] = None
    best_sf = 1.0
    best_size = catalog.vp_size(p)

    if layout == "vp":
        return ScanStep(tp, None, None, best_sf, best_size)

    for other in bgp:
        if other is tp or is_var(other.p):
            continue
        q = int(other.p)
        if q == MISSING_TERM:
            continue
        for corr in correlations(tp, other):
            if corr not in (CORR_SS, CORR_SO, CORR_OS):
                continue  # OO not precomputed (paper §5.2)
            sf = catalog.sf(corr, p, q)
            if sf < best_sf:
                best_sf = sf
                best_kind, best_p2 = corr, q
                best_size = catalog.size(corr, p, q)
    return ScanStep(tp, best_kind, best_p2, best_sf, best_size)


def _emptiness(tp: TriplePattern) -> bool:
    """A pattern with a bound term that is missing from the dictionary."""
    return any((not is_var(t)) and int(t) == MISSING_TERM
               for t in (tp.s, tp.p, tp.o))


def compile_bgp(bgp: BGP, catalog: Catalog, layout: str = "extvp") -> Plan:
    """Algorithm 4 (BGP2SQL_OPT): table selection + join ordering."""
    patterns = list(bgp.patterns)
    if not patterns:
        return Plan(steps=[], vars=())

    # Statistics-only empties: missing terms or SF=0 selected tables.
    if any(_emptiness(tp) for tp in patterns):
        return Plan(empty=True, vars=bgp.vars())

    selected = {id(tp): select_table(tp, patterns, catalog, layout)
                for tp in patterns}
    if any(s.sf == 0.0 for s in selected.values()):
        return Plan(empty=True, vars=bgp.vars())

    # Join ordering.  Paper: order by #bound values first, then repeatedly
    # pick the smallest-table pattern that is join-connected to the bound
    # variable set (avoiding cross joins unless the BGP is disconnected).
    remaining = list(patterns)
    bound_vars: set = set()
    ordered: List[ScanStep] = []
    while remaining:
        def sort_key(tp: TriplePattern):
            step = selected[id(tp)]
            connected = bool(bound_vars) and bool(set(tp_vars(tp)) & bound_vars)
            # Prefer: connected (after first), more bound values, smaller table
            return (
                0 if (connected or not bound_vars) else 1,
                -tp.n_bound(),
                step.size,
            )

        nxt = min(remaining, key=sort_key)
        remaining.remove(nxt)
        ordered.append(selected[id(nxt)])
        bound_vars |= set(tp_vars(nxt))

    return Plan(steps=ordered, vars=bgp.vars())
