"""Statistics catalog (paper §6: "S2RDF collects statistics about all
tables in ExtVP during the initial creation process, most notably the
selectivities (SF values) and actual sizes, such that these statistics can
be used for query generation. It also stores statistics about empty tables
... as this empowers the query compiler to know that a query has no results
without actually running it.").

``Catalog`` is the single source of truth the compiler reads:
  * VP tables per predicate (+ the base triples table for unbound
    predicates),
  * materialized ExtVP tables keyed (kind, p1, p2),
  * SF + size statistics for every pair (materialized or not).

It is deliberately host-side: S2RDF's Spark driver also keeps statistics on
the driver and only ships table scans to executors.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Mapping, Optional, Tuple

import numpy as np

from repro.core.table import Table
from repro.core.vp import ExtVPBuild, build_extvp, build_vp, KINDS

__all__ = ["Catalog", "build_catalog", "compute_distinct_counts",
           "compute_second_moments"]

Key = Tuple[str, int, int]

#: the shared SF=0 fallback relation — ``Catalog.table()`` hands this
#: singleton out instead of allocating a fresh empty Table per call
_EMPTY_TABLE = Table(np.empty((0, 2), dtype=np.int32))


@dataclass
class Catalog:
    """``vp`` and ``extvp.tables`` are *table providers*: any
    ``Mapping[key, Table]``.  In-RAM builds use plain dicts; stores
    loaded from disk use :class:`~repro.core.table.LazyTableMap`, whose
    values memory-map their column files on first touch — callers must
    not assume dict mutability (copy before mutating, as
    ``Dataset.append_triples`` does)."""

    tt: np.ndarray                      # int32[N, 3] (may be a memmap)
    vp: Mapping[int, Table]
    extvp: ExtVPBuild
    dictionary: object = None           # Optional[repro.rdf.Dictionary]
    vp_build_seconds: float = 0.0
    with_extvp: bool = True             # False: VP-only store (no pair stats)
    store: object = None                # Optional[repro.store.StoreInfo]
    #: per-predicate distinct-subject / distinct-object counts over the VP
    #: tables — the join-selectivity statistics the cardinality estimator
    #: (:mod:`repro.core.estimate`) consumes.  ``None`` on catalogs that
    #: predate them (e.g. version-1 stores): the estimate planner then
    #: falls back to the Algorithm-4 greedy order.  Persisted in the store
    #: manifest, so lazily loaded catalogs answer without materializing a
    #: single table.
    distinct_s: Optional[Dict[int, int]] = None
    distinct_o: Optional[Dict[int, int]] = None
    #: per-predicate second moments of the subject/object frequency
    #: distributions (Σ per-value-count², the self-join size).  m2/|VP|
    #: is the expected number of rows matching a constant drawn from the
    #: data distribution — robust to value skew (rdf:type!) where the
    #: uniform |VP|/distinct estimate collapses.  Optional refinement on
    #: top of the distinct counts; absent on older stores.
    m2_s: Optional[Dict[int, int]] = None
    m2_o: Optional[Dict[int, int]] = None

    # ---- statistics API (what Algorithms 1 & 4 consume) --------------------
    def sf(self, kind: str, p1: int, p2: int) -> float:
        """SF of ExtVP^kind_{p1|p2}; 1.0 if unknown (≡ no reduction info)."""
        if p1 not in self.vp:
            return 0.0  # predicate absent from the data: empty result
        return self.extvp.sf.get((kind, p1, p2), 1.0)

    def size(self, kind: str, p1: int, p2: int) -> int:
        if p1 not in self.vp:
            return 0
        key = (kind, p1, p2)
        if key in self.extvp.sizes:
            return self.extvp.sizes[key]
        return len(self.vp[p1])

    def vp_size(self, p: int) -> int:
        return len(self.vp[p]) if p in self.vp else 0

    def materialized(self, kind: str, p1: int, p2: int) -> bool:
        """True when ExtVP^kind_{p1|p2} exists in the materialized (SF ≤ τ)
        set — a containment check only, so lazy stores never load a table
        to answer it.  Table selection (Algorithm 1) must not credit a
        reduction that was pruned by the threshold: ``table()`` would
        silently fall back to the full VP relation while the plan's
        ordering and size statistics assume the reduced one."""
        return (kind, p1, p2) in self.extvp.tables

    @property
    def has_distinct_stats(self) -> bool:
        """True when per-predicate distinct counts are available (the
        estimate planner's enabling condition)."""
        return bool(self.distinct_s) and bool(self.distinct_o)

    def distinct(self, p: int) -> Optional[Tuple[int, int]]:
        """(distinct subjects, distinct objects) of VP_p, or ``None`` when
        the statistics are absent (old store) or the predicate is unknown."""
        if not self.distinct_s or not self.distinct_o:
            return None
        p = int(p)
        ds = self.distinct_s.get(p)
        do = self.distinct_o.get(p)
        if ds is None or do is None:
            return None
        return ds, do

    def second_moment(self, p: int) -> Optional[Tuple[int, int]]:
        """(Σ subject-count², Σ object-count²) of VP_p, or ``None`` when
        the skew statistics are absent — the estimator then assumes a
        uniform value distribution (``size / distinct``)."""
        if not self.m2_s or not self.m2_o:
            return None
        p = int(p)
        ms = self.m2_s.get(p)
        mo = self.m2_o.get(p)
        if ms is None or mo is None:
            return None
        return ms, mo

    # ---- table access -------------------------------------------------------
    def table(self, kind: Optional[str], p1: int, p2: Optional[int] = None) -> Optional[Table]:
        """Fetch a materialized table; VP when kind is None; None if absent.

        Falls back to the VP table when the ExtVP table was not materialized
        (SF=1, above threshold) — mirroring "S2RDF makes use of it, if they
        exist, or uses the normal VP tables instead" (§5.2).
        """
        if p1 not in self.vp:
            return None
        if kind is None:
            return self.vp[p1]
        t = self.extvp.tables.get((kind, p1, p2))
        if t is not None:
            return t
        sf = self.extvp.sf.get((kind, p1, p2), 1.0)
        if sf == 0.0:
            return _EMPTY_TABLE
        return self.vp[p1]

    @property
    def n_triples(self) -> int:
        return len(self.tt)

    # ---- storage accounting (paper Table 2) ---------------------------------
    def storage_report(self) -> Dict[str, float]:
        # never force a lazy provider's loaders just to count tuples —
        # LazyTableMap answers from its manifest-sourced length metadata
        total_rows = getattr(self.vp, "total_rows", None)
        vp_tuples = int(total_rows()) if total_rows is not None \
            else sum(len(t) for t in self.vp.values())
        ext_tuples = self.extvp.total_tuples()
        return {
            "n_triples": float(len(self.tt)),
            "vp_tables": float(len(self.vp)),
            "vp_tuples": float(vp_tuples),
            "extvp_tables": float(len(self.extvp.tables)),
            "extvp_tuples": float(ext_tuples),
            "extvp_over_vp": float(ext_tuples) / max(vp_tuples, 1),
            "extvp_empty": float(sum(1 for v in self.extvp.sf.values() if v == 0.0)),
            "extvp_identity": float(sum(1 for v in self.extvp.sf.values() if v == 1.0)),
            "vp_build_seconds": self.vp_build_seconds,
            "extvp_build_seconds": self.extvp.build_seconds,
            "n_semijoins": float(self.extvp.n_semijoins),
            # persisted form (0 when the catalog has no on-disk store)
            "store_bytes": float(self.store.total_bytes) if self.store else 0.0,
            "delta_segments": float(self.store.delta_segments)
            if self.store else 0.0,
        }


def compute_distinct_counts(
    vp: Mapping[int, Table],
) -> Tuple[Dict[int, int], Dict[int, int]]:
    """Per-predicate distinct-subject / distinct-object counts over a VP
    catalog — one sorted-unique pass per table (the tables' cached
    ``unique_s`` / ``unique_o`` views, which joins reuse later anyway)."""
    distinct_s = {int(p): int(len(t.unique_s)) for p, t in vp.items()}
    distinct_o = {int(p): int(len(t.unique_o)) for p, t in vp.items()}
    return distinct_s, distinct_o


def _m2(col: np.ndarray) -> int:
    counts = np.unique(np.asarray(col), return_counts=True)[1]
    return int((counts.astype(np.int64) ** 2).sum())


def compute_second_moments(
    vp: Mapping[int, Table],
) -> Tuple[Dict[int, int], Dict[int, int]]:
    """Per-predicate Σcount² over each VP column — the self-join sizes
    the estimator uses as skew-robust bound-term selectivities."""
    m2_s = {int(p): _m2(t.rows[:, 0]) for p, t in vp.items()}
    m2_o = {int(p): _m2(t.rows[:, 1]) for p, t in vp.items()}
    return m2_s, m2_o


def build_catalog(
    tt: np.ndarray,
    dictionary=None,
    threshold: float = 1.0,
    kinds: Tuple[str, ...] = KINDS,
    with_extvp: bool = True,
    build_backend: str = "numpy",
    mesh=None,
    pair_batch: int = 512,
) -> Catalog:
    """End-to-end load: TT -> VP -> ExtVP(τ) + stats.

    ``build_backend`` selects the ExtVP build substrate ("numpy" host
    loop, "jax" pair-batched device pipeline, or "distributed" shard_map
    pair grid over ``mesh``); all produce byte-identical catalogs.
    """
    t0 = time.perf_counter()
    vp = build_vp(tt)
    distinct_s, distinct_o = compute_distinct_counts(vp)
    m2_s, m2_o = compute_second_moments(vp)
    vp_secs = time.perf_counter() - t0
    if with_extvp:
        ext = build_extvp(vp, threshold=threshold, kinds=kinds,
                          backend=build_backend, mesh=mesh,
                          pair_batch=pair_batch)
    else:
        ext = ExtVPBuild(threshold=threshold, kinds=tuple(kinds))
    return Catalog(tt=np.asarray(tt, dtype=np.int32), vp=vp, extvp=ext,
                   dictionary=dictionary, vp_build_seconds=vp_secs,
                   with_extvp=with_extvp,
                   distinct_s=distinct_s, distinct_o=distinct_o,
                   m2_s=m2_s, m2_o=m2_o)
