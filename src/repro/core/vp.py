"""VP and ExtVP builders (paper §4.2, §5).

``build_vp``    — vertical partitioning: one (s, o) table per predicate.
``build_extvp`` — Extended Vertical Partitioning: for every ordered
predicate pair and correlation kind ∈ {SS, OS, SO}, the semi-join
reduction

    ExtVP^SS_{p1|p2} = VP_p1 ⋉_{s=s} VP_p2      (p1 ≠ p2)
    ExtVP^OS_{p1|p2} = VP_p1 ⋉_{o=s} VP_p2
    ExtVP^SO_{p1|p2} = VP_p1 ⋉_{s=o} VP_p2

OO correlations are not precomputed (paper §5.2: they are dominated by
same-predicate self-joins where the reduction is the identity).

A table is *materialized* only when it is a strict, non-empty reduction
whose selectivity factor ``SF = |ExtVP| / |VP_p1|`` is within the optional
threshold τ (§5.3).  Statistics (SF, sizes) are recorded for **all** pairs
— including empty (SF=0) and identity (SF=1) ones — because the query
compiler uses them for table selection, join ordering, and the
statistics-only ∅ short-circuit (ST-8).

The builder is the offline analogue of S2RDF's Spark load job.  Three
substrates implement it behind ``build_extvp(..., backend=...)``:

* ``"numpy"``       — the sequential host loop (sorted-array membership
                      via ``np.searchsorted``), one semi-join per pair;
* ``"jax"``         — the pair-batched device pipeline of
                      :mod:`repro.core.extvp_build`: the catalog is
                      packed once into padded column tensors and whole
                      batches of (kind, p1, p2) pairs are evaluated in a
                      single vmapped pass over the semi-join kernel
                      (Pallas path included when enabled);
* ``"distributed"`` — the same pipeline with the pair grid partitioned
                      across a device mesh via ``shard_map`` (the direct
                      analogue of S2RDF's distributed Spark load job).

All three produce byte-identical tables and statistics.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.table import Table

__all__ = ["build_vp", "build_extvp", "ExtVPBuild", "SS", "OS", "SO", "KINDS"]

SS, OS, SO = "SS", "OS", "SO"
KINDS = (SS, OS, SO)

Key = Tuple[str, int, int]  # (kind, p1, p2)


@dataclass
class ExtVPBuild:
    """Result of an ExtVP construction pass."""

    tables: Dict[Key, Table] = field(default_factory=dict)   # materialized only
    sf: Dict[Key, float] = field(default_factory=dict)       # stats for ALL pairs
    sizes: Dict[Key, int] = field(default_factory=dict)
    threshold: float = 1.0
    build_seconds: float = 0.0
    n_semijoins: int = 0
    backend: str = "numpy"
    kinds: Tuple[str, ...] = KINDS

    # -- paper Table 2 style accounting --------------------------------------
    def n_tables(self, lo: float = 0.0, hi: float = 1.0) -> int:
        """Pairs whose SF falls in the materialization band (lo, hi].

        Bounds are aligned with the materialization predicate
        ``0 < sf < 1 and sf <= τ``: the upper bound is *inclusive* (a
        table with SF exactly equal to τ is materialized and must be
        counted), while identity tables (SF = 1) never count, so
        ``n_tables(0, build.threshold) == len(build.tables)``.
        """
        return sum(1 for v in self.sf.values() if lo < v <= hi and v < 1.0)

    def total_tuples(self) -> int:
        # lazy table providers answer from their length metadata so
        # accounting never forces a load (see table.LazyTableMap)
        total_rows = getattr(self.tables, "total_rows", None)
        if total_rows is not None:
            return int(total_rows())
        return sum(len(t) for t in self.tables.values())


def build_vp(tt: np.ndarray) -> Dict[int, Table]:
    """Vertical partitioning of a triples table int32[N, 3] -> {pid: Table}."""
    tt = np.asarray(tt)
    order = np.argsort(tt[:, 1], kind="stable")
    sorted_tt = tt[order]
    pids, starts = np.unique(sorted_tt[:, 1], return_index=True)
    bounds = np.append(starts, len(sorted_tt))
    vp: Dict[int, Table] = {}
    for i, pid in enumerate(pids):
        chunk = sorted_tt[bounds[i]:bounds[i + 1]]
        vp[int(pid)] = Table.from_unsorted(chunk[:, [0, 2]])
    return vp


def _semijoin_mask(keys: np.ndarray, other_sorted_unique: np.ndarray) -> np.ndarray:
    """mask[i] = keys[i] ∈ other (other must be sorted unique)."""
    if len(other_sorted_unique) == 0:
        return np.zeros(len(keys), dtype=bool)
    idx = np.searchsorted(other_sorted_unique, keys)
    idx = np.minimum(idx, len(other_sorted_unique) - 1)
    return other_sorted_unique[idx] == keys


def _ranges_disjoint(a: np.ndarray, b: np.ndarray) -> bool:
    if len(a) == 0 or len(b) == 0:
        return True
    return a[-1] < b[0] or b[-1] < a[0]


def build_extvp(
    vp: Dict[int, Table],
    threshold: float = 1.0,
    kinds: Tuple[str, ...] = KINDS,
    backend: str = "numpy",
    mesh=None,
    pair_batch: int = 512,
) -> ExtVPBuild:
    """Compute the ExtVP schema over a VP catalog.

    ``threshold`` is the SF threshold τ of §5.3: tables with SF > τ are not
    materialized (their statistics still are).  τ=1.0 reproduces the
    unthresholded schema (SF=1 identity tables are never stored, exactly
    as in the paper — "red tables" of Fig. 10).

    ``backend`` selects the build substrate (module docstring): the
    ``"numpy"`` host loop, the ``"jax"`` pair-batched device pipeline, or
    the ``"distributed"`` shard_map pair grid over ``mesh`` (all local
    devices when None).  ``pair_batch`` bounds how many (kind, p1, p2)
    pairs one device launch evaluates.
    """
    if backend not in ("numpy", "jax", "distributed"):
        raise ValueError(f"unknown ExtVP build backend {backend!r}; "
                         "expected 'numpy', 'jax', or 'distributed'")
    t0 = time.perf_counter()
    # One pipeline for every substrate (plan -> evaluate -> materialize),
    # so the semi-join semantics live in exactly one place:
    # repro.core.extvp_build.evaluate_pairs.
    from repro.core.extvp_build import build_extvp_planned
    out = build_extvp_planned(vp, threshold=threshold, kinds=kinds,
                              backend=backend, mesh=mesh,
                              pair_batch=pair_batch)
    out.build_seconds = time.perf_counter() - t0
    return out
