"""VP and ExtVP builders (paper §4.2, §5).

``build_vp``    — vertical partitioning: one (s, o) table per predicate.
``build_extvp`` — Extended Vertical Partitioning: for every ordered
predicate pair and correlation kind ∈ {SS, OS, SO}, the semi-join
reduction

    ExtVP^SS_{p1|p2} = VP_p1 ⋉_{s=s} VP_p2      (p1 ≠ p2)
    ExtVP^OS_{p1|p2} = VP_p1 ⋉_{o=s} VP_p2
    ExtVP^SO_{p1|p2} = VP_p1 ⋉_{s=o} VP_p2

OO correlations are not precomputed (paper §5.2: they are dominated by
same-predicate self-joins where the reduction is the identity).

A table is *materialized* only when it is a strict, non-empty reduction
whose selectivity factor ``SF = |ExtVP| / |VP_p1|`` is within the optional
threshold τ (§5.3).  Statistics (SF, sizes) are recorded for **all** pairs
— including empty (SF=0) and identity (SF=1) ones — because the query
compiler uses them for table selection, join ordering, and the
statistics-only ∅ short-circuit (ST-8).

The builder is the offline analogue of S2RDF's Spark load job; it is pure
vectorized numpy (sorted-array membership via ``np.isin``), with an
optional Pallas-kernel path used by the device-side engine.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.table import Table

__all__ = ["build_vp", "build_extvp", "ExtVPBuild", "SS", "OS", "SO", "KINDS"]

SS, OS, SO = "SS", "OS", "SO"
KINDS = (SS, OS, SO)

Key = Tuple[str, int, int]  # (kind, p1, p2)


@dataclass
class ExtVPBuild:
    """Result of an ExtVP construction pass."""

    tables: Dict[Key, Table] = field(default_factory=dict)   # materialized only
    sf: Dict[Key, float] = field(default_factory=dict)       # stats for ALL pairs
    sizes: Dict[Key, int] = field(default_factory=dict)
    threshold: float = 1.0
    build_seconds: float = 0.0
    n_semijoins: int = 0

    # -- paper Table 2 style accounting --------------------------------------
    def n_tables(self, lo: float = 0.0, hi: float = 1.0) -> int:
        return sum(1 for v in self.sf.values() if lo < v < hi)

    def total_tuples(self) -> int:
        return sum(len(t) for t in self.tables.values())


def build_vp(tt: np.ndarray) -> Dict[int, Table]:
    """Vertical partitioning of a triples table int32[N, 3] -> {pid: Table}."""
    tt = np.asarray(tt)
    order = np.argsort(tt[:, 1], kind="stable")
    sorted_tt = tt[order]
    pids, starts = np.unique(sorted_tt[:, 1], return_index=True)
    bounds = np.append(starts, len(sorted_tt))
    vp: Dict[int, Table] = {}
    for i, pid in enumerate(pids):
        chunk = sorted_tt[bounds[i]:bounds[i + 1]]
        vp[int(pid)] = Table.from_unsorted(chunk[:, [0, 2]])
    return vp


def _semijoin_mask(keys: np.ndarray, other_sorted_unique: np.ndarray) -> np.ndarray:
    """mask[i] = keys[i] ∈ other (other must be sorted unique)."""
    if len(other_sorted_unique) == 0:
        return np.zeros(len(keys), dtype=bool)
    idx = np.searchsorted(other_sorted_unique, keys)
    idx = np.minimum(idx, len(other_sorted_unique) - 1)
    return other_sorted_unique[idx] == keys


def _ranges_disjoint(a: np.ndarray, b: np.ndarray) -> bool:
    if len(a) == 0 or len(b) == 0:
        return True
    return a[-1] < b[0] or b[-1] < a[0]


def build_extvp(
    vp: Dict[int, Table],
    threshold: float = 1.0,
    kinds: Tuple[str, ...] = KINDS,
) -> ExtVPBuild:
    """Compute the ExtVP schema over a VP catalog.

    ``threshold`` is the SF threshold τ of §5.3: tables with SF > τ are not
    materialized (their statistics still are).  τ=1.0 reproduces the
    unthresholded schema (SF=1 identity tables are never stored, exactly
    as in the paper — "red tables" of Fig. 10).
    """
    t0 = time.perf_counter()
    out = ExtVPBuild(threshold=threshold)
    preds = sorted(vp.keys())

    for p1 in preds:
        t1 = vp[p1]
        n1 = len(t1)
        for p2 in preds:
            t2 = vp[p2]
            for kind in kinds:
                if kind == SS and p1 == p2:
                    continue  # identity by definition; paper excludes it
                key = (kind, p1, p2)
                if kind == SS:
                    keys, other = t1.s, t2.unique_s
                elif kind == OS:
                    keys, other = t1.o, t2.unique_s
                else:  # SO
                    keys, other = t1.s, t2.unique_o
                # cheap structural-empty detection (disjoint entity blocks)
                own = t1.unique_o if kind == OS else t1.unique_s
                if _ranges_disjoint(own, other):
                    out.sf[key] = 0.0
                    out.sizes[key] = 0
                    continue
                out.n_semijoins += 1
                mask = _semijoin_mask(keys, other)
                m = int(mask.sum())
                sf = m / n1 if n1 else 0.0
                out.sf[key] = sf
                out.sizes[key] = m
                if 0 < sf < 1.0 and sf <= threshold:
                    rows = t1.rows[mask]
                    out.tables[key] = Table(rows)  # mask preserves s-order
    out.build_seconds = time.perf_counter() - t0
    return out
