"""Cardinality estimation + bounded join-order enumeration.

The paper's Algorithm 4 orders joins by (#bound values, selected-table
size) — raw table size is a poor proxy for *intermediate* cardinality, so
a locally-small ExtVP table can still explode mid-pipeline on snowflake
and complex shapes.  This module is the ``planner="estimate"`` alternative
(PRoST, arXiv 1802.05898, makes the same statistics-driven argument):

* **per-scan estimate** — SF × table size is already folded into
  ``ScanStep.size`` (Algorithm 1 selected the smallest ExtVP variant);
  bound subject/object terms multiply it by the column's second-moment
  selectivity m2/|VP|² (``Catalog.second_moment`` — the expected match
  fraction for a constant drawn from the data distribution, robust to
  value skew like ``rdf:type``), falling back to the uniform
  1/distinct-count divisor (``Catalog.distinct``) when the skew
  statistics are absent;
* **per-join selectivity** — the System-R rule: joining relations R and T
  on shared variable v multiplies |R|·|T| by 1/max(d_R(v), d_T(v)), where
  per-variable distinct-value counts d(·) seed from the scan statistics
  and propagate through the pipeline (capped by the running cardinality);
  disconnected steps contribute the full cross product — never an
  undercount.  (A second-moment *floor* on join selectivity was tried
  and rejected: it perturbs orders enough to lose the lucky-zero
  intermediates greedy stumbles into on correlated WatDiv shapes —
  fan-out chains like C2 remain the known weak spot of the uniform
  join model.);
* **bounded enumeration** — exact dynamic programming over pattern
  subsets (left-deep join trees) up to ``DP_LIMIT`` patterns, greedy
  selection with cardinality propagation beyond it.  Like Algorithm 4,
  cross joins are admitted only when no remaining pattern is
  join-connected, so enumerated orders stay inside the fragment every
  backend (eager / jit / distributed) already executes.

Estimation is *template-level*: placeholder constants count as bound
terms but their values never enter a formula, so the order chosen at
compile time is valid for every re-binding and is cached with the
``PreparedQuery`` — re-binding never re-enumerates.

Catalogs without distinct-count statistics (version-1 stores) make
``order_steps`` return ``None`` and the compiler falls back to the
Algorithm-4 greedy order.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.algebra import is_var, tp_vars

__all__ = ["DP_LIMIT", "StepEstimate", "supports", "scan_estimate",
           "estimate_order", "order_steps", "actual_cardinalities"]

#: exact-DP bound: 2^8 subset states; beyond this the enumerator switches
#: to greedy selection with cardinality propagation
DP_LIMIT = 8


@dataclass
class StepEstimate:
    """One pipeline position: the scan's own estimate and the estimated
    cardinality of the intermediate result after joining it in."""

    step: object                 # compiler.ScanStep
    scan_rows: float             # estimated scan output (SF × size × terms)
    rows: float                  # running pipeline cardinality


def supports(catalog) -> bool:
    """True when ``catalog`` carries the distinct-count statistics the
    estimator needs (false for catalogs loaded from version-1 stores)."""
    return bool(getattr(catalog, "has_distinct_stats", False))


def scan_estimate(step, catalog) -> Tuple[float, Dict[str, float]]:
    """Estimated output rows of one scan plus per-variable distinct-value
    estimates ``{var: d}`` for the variables it binds.

    The step's ``size`` is already SF × |VP| (Algorithm 1 picked the
    smallest ExtVP variant); bound subject/object terms multiply by the
    column's second-moment selectivity m2/|VP|² when the skew statistics
    are present (E[matches] for a data-distributed constant — immune to
    the uniformity trap on skewed columns like ``rdf:type``), else
    divide by the distinct count.  TT scans (unbound predicates) have no
    per-predicate statistics — their per-column distincts default to the
    table size, which makes joins through them conservatively weak.
    """
    tp = step.tp
    size = float(max(step.size, 0))
    if step.uses_tt and not is_var(tp.p):
        # layout="tt" forces a TT scan for a bound predicate; the scan
        # still only matches that predicate's rows
        size = float(catalog.vp_size(int(tp.p)))
    dist = None if (step.uses_tt or is_var(tp.p)) \
        else catalog.distinct(int(tp.p))
    ds, do = (float(dist[0]), float(dist[1])) if dist else (size, size)
    ds, do = max(ds, 1.0), max(do, 1.0)
    m2 = None if dist is None else catalog.second_moment(int(tp.p))
    vp_n = float(catalog.vp_size(int(tp.p))) if dist is not None else 0.0
    sel_s = m2[0] / vp_n ** 2 if m2 and vp_n else 1.0 / ds
    sel_o = m2[1] / vp_n ** 2 if m2 and vp_n else 1.0 / do

    rows = size
    s_var, o_var = is_var(tp.s), is_var(tp.o)
    if not s_var:
        rows *= sel_s
    if not o_var:
        rows *= sel_o
    if s_var and o_var and tp.s == tp.o:
        # ?x p ?x: the diagonal of the table
        rows /= max(ds, do)
    rows = max(rows, 0.0)

    dvar: Dict[str, float] = {}
    if s_var:
        dvar[tp.s] = max(min(ds, rows), 1.0)
    if o_var:
        dvar[tp.o] = min(max(min(do, rows), 1.0),
                         dvar.get(tp.o, float("inf")))
    if is_var(tp.p):
        # distinct predicates in the dataset (len() never loads a lazy map)
        dvar[tp.p] = max(min(float(len(catalog.vp)), rows), 1.0)
    return rows, dvar


def _join_in(rows: float, dvar: Dict[str, float],
             t_rows: float, t_dvar: Dict[str, float]
             ) -> Tuple[float, Dict[str, float]]:
    """Fold one scan into the running relation: System-R join selectivity
    per shared variable, cross product when none are shared."""
    shared = set(dvar) & set(t_dvar)
    out = rows * t_rows
    for v in shared:
        out /= max(dvar[v], t_dvar[v], 1.0)
    new_d: Dict[str, float] = {}
    for v in set(dvar) | set(t_dvar):
        d = min(dvar.get(v, float("inf")), t_dvar.get(v, float("inf")))
        new_d[v] = max(min(d, out), 0.0) if out > 0 else 0.0
    return out, new_d


def estimate_order(steps: Sequence, catalog) -> Optional[List[StepEstimate]]:
    """Propagate estimates through ``steps`` in the given order; ``None``
    when the catalog lacks distinct-count statistics."""
    if not supports(catalog):
        return None
    out: List[StepEstimate] = []
    rows, dvar = 0.0, {}
    for i, step in enumerate(steps):
        t_rows, t_dvar = scan_estimate(step, catalog)
        if i == 0:
            rows, dvar = t_rows, t_dvar
        else:
            rows, dvar = _join_in(rows, dvar, t_rows, t_dvar)
        out.append(StepEstimate(step=step, scan_rows=t_rows, rows=rows))
    return out


def _greedy_order(idx: List[int], scans, var_sets, tiebreak) -> List[int]:
    """Greedy selection with cardinality propagation (n > DP_LIMIT):
    start from the most selective scan, then repeatedly append the
    join-connected step minimizing the propagated cardinality."""
    first = min(idx, key=lambda i: (scans[i][0],) + tiebreak(i))
    order = [first]
    rows, dvar = scans[first]
    remaining = [i for i in idx if i != first]
    while remaining:
        connected = [i for i in remaining if var_sets[i] & set(dvar)]
        pool = connected or remaining        # cross joins only if forced
        best, best_state = None, None
        for i in pool:
            out, nd = _join_in(rows, dvar, *scans[i])
            key = (out,) + tiebreak(i)
            if best is None or key < best:
                best, best_state, pick = key, (out, nd), i
        order.append(pick)
        rows, dvar = best_state
        remaining.remove(pick)
    return order


def order_steps(steps: Sequence, catalog,
                dp_limit: int = DP_LIMIT) -> Optional[List]:
    """Enumerate a join order for ``steps`` minimizing the summed
    estimated intermediate cardinalities (the C_out cost).

    Exact subset DP over left-deep trees for ``len(steps) <= dp_limit``,
    greedy-with-propagation beyond.  Returns the reordered step list (a
    permutation of the input — table selection is untouched), or ``None``
    when the catalog has no distinct-count statistics (the caller then
    keeps the Algorithm-4 greedy order).
    """
    if not supports(catalog):
        return None
    steps = list(steps)
    n = len(steps)
    if n <= 1:
        return steps

    scans = [scan_estimate(s, catalog) for s in steps]
    var_sets = [set(tp_vars(s.tp)) for s in steps]

    def tiebreak(i: int) -> tuple:
        # deterministic: Algorithm-4's key, then the input position
        return (-steps[i].tp.n_bound(), steps[i].size, i)

    if n > dp_limit:
        order = _greedy_order(list(range(n)), scans, var_sets, tiebreak)
        return [steps[i] for i in order]

    # Exact DP over subsets (left-deep): state = joined subset,
    # value = (total C_out cost, running rows, per-var distincts, order).
    # A subset is only ever extended by a join-connected step unless NO
    # unjoined step connects — the same cross-join discipline as
    # Algorithm 4, so enumerated orders execute on every backend.
    best: Dict[int, tuple] = {}
    for i in range(n):
        rows, dvar = scans[i]
        key = 1 << i
        cand = (rows, rows, dvar, (i,))
        if key not in best or _beats(cand, best[key], tiebreak):
            best[key] = cand
    for mask in sorted(best.keys() | set(range(1, 1 << n)),
                       key=lambda m: bin(m).count("1")):
        state = best.get(mask)
        if state is None:
            continue
        cost, rows, dvar, order = state
        outside = [i for i in range(n) if not (mask >> i) & 1]
        if not outside:
            continue
        connected = [i for i in outside if var_sets[i] & set(dvar)]
        for i in (connected or outside):
            out, nd = _join_in(rows, dvar, *scans[i])
            key = mask | (1 << i)
            cand = (cost + out, out, nd, order + (i,))
            if key not in best or _beats(cand, best[key], tiebreak):
                best[key] = cand
    order = best[(1 << n) - 1][3]
    return [steps[i] for i in order]


def _beats(a: tuple, b: tuple, tiebreak) -> bool:
    """Deterministic DP dominance: lower cost, then lower final rows,
    then the lexicographically smaller tiebreak sequence."""
    ka = (a[0], a[1], tuple(tiebreak(i) for i in a[3]))
    kb = (b[0], b[1], tuple(tiebreak(i) for i in b[3]))
    return ka < kb


def actual_cardinalities(steps: Sequence, catalog) -> Optional[List[int]]:
    """Measured intermediate cardinalities of a flat BGP pipeline: scan
    and join the steps left-to-right on the host, recording each
    intermediate row count (``Engine.explain``'s estimated-vs-actual
    column).  Diagnostics only — runs the actual joins."""
    from repro.core.executor import natural_join, scan_step
    out: List[int] = []
    acc = None
    for step in steps:
        b = scan_step(step, catalog)
        acc = b if acc is None else natural_join(acc, b)
        out.append(int(len(acc.data)))
    return out
