"""SPARQL solution-modifier spine: one canonical form for every engine.

SPARQL queries produced by the parser are a *spine* of solution
modifiers wrapped around a graph-pattern core::

    Slice(OrderBy(Filter(... Filter(core) ...)))   + Query.select/.distinct

The W3C semantics pin the application order of the modifiers (SPARQL
1.1 §18.2.4–18.2.5): ORDER BY runs over the un-projected solutions (so
sorting by a variable outside the SELECT list is legal), dedup happens
on the *projected* rows and BEFORE the slice, and projection/DISTINCT
must not destroy the established order.  Historically each engine
re-derived that order ad hoc (and the eager engine applied DISTINCT
last, after LIMIT — the modifier-ordering bug this module exists to
kill).  ``peel_spine`` normalizes a query into ``(core, ModifierSpine)``
once, and every executor — the eager host engine, the brute-force
reference oracle, the jitted device pipeline and the distributed
shard_map engine — applies the same canonical sequence:

    core → FILTER* → ORDER BY → project → DISTINCT → OFFSET/LIMIT

with a first-occurrence-stable DISTINCT (it preserves the sorted order,
and because stable dedup commutes with a stable sort over projected
keys, this sequence also equals project→distinct→order→slice whenever
the sort keys survive projection).

The spine is also what the device backends compile: the jit/distributed
executors accept a ``ModifierSpine`` and lower each modifier onto the
static-shape relation (see :mod:`repro.core.jexec`), with the filter's
constant operands riding the runtime ``fconsts`` input so constant
re-binding never re-traces.
"""

from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Tuple

from repro.core.algebra import (
    BoolOp, Bound, Cmp, Distinct, Filter, FilterExpr, Node, NotExpr, OrderBy,
    Project, Query, Slice,
)

__all__ = [
    "ModifierSpine", "peel_spine", "filter_const_slots", "filter_variables",
    "substitute_term", "substitute_filter", "substitute_spine",
]


@dataclass(frozen=True)
class ModifierSpine:
    """The solution modifiers of one query, in canonical application
    order: ``filters`` → ``order`` → ``project`` → ``distinct`` →
    ``offset``/``limit``."""

    filters: Tuple[FilterExpr, ...] = ()
    project: Optional[Tuple[str, ...]] = None     # None = SELECT *
    distinct: bool = False
    order: Tuple[Tuple[str, bool], ...] = ()      # (var, ascending)
    offset: int = 0
    limit: Optional[int] = None

    @property
    def trivial(self) -> bool:
        return (not self.filters and self.project is None
                and not self.distinct and not self.order
                and not self.offset and self.limit is None)

    @property
    def has_slice(self) -> bool:
        return bool(self.offset) or self.limit is not None

    @property
    def needs_global(self) -> bool:
        """True when the modifier needs the WHOLE relation (cross-shard
        on a distributed engine): DISTINCT / ORDER BY / OFFSET / LIMIT.
        FILTER and projection are row-local and stay sharded."""
        return self.distinct or bool(self.order) or self.has_slice


def peel_spine(query: Query) -> Tuple[Node, ModifierSpine]:
    """Split ``query`` into its graph-pattern core and modifier spine.

    Peels the parser-shaped spine — ``Slice`` → ``OrderBy`` →
    ``Distinct`` → ``Project`` → ``Filter``* — off the root and folds
    ``Query.select`` / ``Query.distinct`` in.  Nodes nested in any other
    arrangement stay in the core (the host ``_eval`` still interprets
    them); the spine captures exactly the shapes the grammar can emit.
    """
    node = query.root
    offset, limit = 0, None
    order: Tuple[Tuple[str, bool], ...] = ()
    distinct = bool(query.distinct)
    project = tuple(query.select) if query.select is not None else None

    if isinstance(node, Slice):
        offset, limit = node.offset, node.limit
        node = node.child
    if isinstance(node, OrderBy):
        order = tuple(node.keys)
        node = node.child
    if isinstance(node, Distinct):
        distinct = True
        node = node.child
    if isinstance(node, Project) and project is None:
        project = tuple(node.vars) if node.vars is not None else None
        node = node.child
    filters: List[FilterExpr] = []
    while isinstance(node, Filter):
        filters.append(node.expr)
        node = node.child
    filters.reverse()  # innermost Filter applies first
    return node, ModifierSpine(filters=tuple(filters), project=project,
                               distinct=distinct, order=order,
                               offset=offset, limit=limit)


# ---------------------------------------------------------------------------
# Filter-expression introspection (what the device compiler consumes)
# ---------------------------------------------------------------------------

def filter_const_slots(filters: Tuple[FilterExpr, ...]) -> Tuple[int, ...]:
    """Constant (non-var, non-float) operand ids of the filter exprs, in
    deterministic walk order.  These are the runtime ``fconsts`` slots of
    the compiled device program: the traced filter reads ``fconsts[i]``
    where this walk saw slot ``i``, so re-binding a template constant is
    a pure input change — no re-trace.  Ids may be template placeholders
    (negative band) or concrete dictionary ids; ``fconsts_from_mapping``
    resolves both."""
    slots: List[int] = []

    def walk(e: FilterExpr) -> None:
        if isinstance(e, Cmp):
            for t in (e.lhs, e.rhs):
                if not isinstance(t, (str, float)):
                    slots.append(int(t))
        elif isinstance(e, BoolOp):
            for a in e.args:
                walk(a)
        elif isinstance(e, NotExpr):
            walk(e.arg)
        # Bound carries no constants

    for e in filters:
        walk(e)
    return tuple(slots)


def filter_variables(filters: Tuple[FilterExpr, ...]) -> Tuple[str, ...]:
    """Variables referenced by the filter exprs, first-seen order."""
    out: List[str] = []

    def walk(e: FilterExpr) -> None:
        if isinstance(e, Cmp):
            for t in (e.lhs, e.rhs):
                if isinstance(t, str) and t.startswith("?") and t not in out:
                    out.append(t)
        elif isinstance(e, BoolOp):
            for a in e.args:
                walk(a)
        elif isinstance(e, NotExpr):
            walk(e.arg)
        elif isinstance(e, Bound):
            if e.var not in out:
                out.append(e.var)

    for e in filters:
        walk(e)
    return tuple(out)


def substitute_term(t, mapping: Dict[int, int]):
    """Rewrite a constant id through ``mapping``; variables and float
    literals pass through.  The single id-substitution primitive shared
    by filter, triple-pattern and plan re-binding (see
    :mod:`repro.engine.template`)."""
    if isinstance(t, (str, float)):
        return t
    return mapping.get(int(t), t)


def substitute_filter(e: FilterExpr, mapping: Dict[int, int]) -> FilterExpr:
    """Clone a filter expression with constant ids rewritten."""
    if isinstance(e, Cmp):
        return Cmp(e.op, substitute_term(e.lhs, mapping),
                   substitute_term(e.rhs, mapping))
    if isinstance(e, BoolOp):
        return BoolOp(e.op, tuple(substitute_filter(a, mapping)
                                  for a in e.args))
    if isinstance(e, NotExpr):
        return NotExpr(substitute_filter(e.arg, mapping))
    assert isinstance(e, Bound)
    return e


def substitute_spine(spine: ModifierSpine,
                     mapping: Dict[int, int]) -> ModifierSpine:
    """Re-bind template placeholder ids inside the spine's filters (the
    host-path counterpart of the device ``fconsts`` input)."""
    if not mapping or not spine.filters:
        return spine
    return replace(spine, filters=tuple(substitute_filter(e, mapping)
                                        for e in spine.filters))
