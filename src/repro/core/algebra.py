"""SPARQL algebra (paper §2.1, §6).

Terms in patterns are either variables (strings starting with ``?``) or
dictionary-encoded constants (ints).  The algebra is the W3C SPARQL 1.0
core the paper supports: BGPs + FILTER / OPTIONAL / UNION / DISTINCT /
ORDER BY / LIMIT / OFFSET / projection.  (SPARQL 1.1 aggregations and
subqueries are out of scope, as in the paper.)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple, Union

TermT = Union[str, int]  # '?var' or dictionary id

__all__ = [
    "TriplePattern", "BGP", "FilterExpr", "Cmp", "BoolOp", "NotExpr", "Bound",
    "Filter", "LeftJoin", "UnionOp", "Distinct", "OrderBy", "Slice", "Project",
    "Query", "is_var", "tp_vars", "CORR_SS", "CORR_SO", "CORR_OS", "CORR_OO",
    "correlations",
]

CORR_SS, CORR_SO, CORR_OS, CORR_OO = "SS", "SO", "OS", "OO"


def is_var(t: TermT) -> bool:
    return isinstance(t, str) and t.startswith("?")


@dataclass(frozen=True)
class TriplePattern:
    s: TermT
    p: TermT
    o: TermT

    def n_bound(self) -> int:
        return sum(0 if is_var(t) else 1 for t in (self.s, self.p, self.o))

    def __repr__(self) -> str:  # compact
        return f"({self.s} {self.p} {self.o})"


def tp_vars(tp: TriplePattern) -> Tuple[str, ...]:
    return tuple(t for t in (tp.s, tp.p, tp.o) if is_var(t))


def correlations(a: TriplePattern, b: TriplePattern) -> List[str]:
    """Correlation kinds of ``a`` against ``b`` (paper Fig. 9).

    Returns the kinds through which ``a``'s table can be reduced: e.g. SS
    means a.s and b.s share a variable -> candidate ExtVP^SS_{a.p|b.p}.
    """
    out = []
    if is_var(a.s) and a.s == b.s:
        out.append(CORR_SS)
    if is_var(a.s) and a.s == b.o:
        out.append(CORR_SO)
    if is_var(a.o) and a.o == b.s:
        out.append(CORR_OS)
    if is_var(a.o) and a.o == b.o:
        out.append(CORR_OO)
    return out


# ---------------------------------------------------------------------------
# Filter expressions
# ---------------------------------------------------------------------------

class FilterExpr:
    pass


@dataclass(frozen=True)
class Cmp(FilterExpr):
    op: str                 # '=', '!=', '<', '<=', '>', '>='
    lhs: TermT              # var or const id
    rhs: TermT

    def __post_init__(self):
        assert self.op in ("=", "!=", "<", "<=", ">", ">=")


@dataclass(frozen=True)
class BoolOp(FilterExpr):
    op: str                 # '&&' or '||'
    args: Tuple[FilterExpr, ...]


@dataclass(frozen=True)
class NotExpr(FilterExpr):
    arg: FilterExpr


@dataclass(frozen=True)
class Bound(FilterExpr):
    var: str


# ---------------------------------------------------------------------------
# Graph-pattern algebra nodes
# ---------------------------------------------------------------------------

class Node:
    pass


@dataclass
class BGP(Node):
    patterns: List[TriplePattern]

    def vars(self) -> Tuple[str, ...]:
        seen: List[str] = []
        for tp in self.patterns:
            for v in tp_vars(tp):
                if v not in seen:
                    seen.append(v)
        return tuple(seen)


@dataclass
class JoinPair(Node):
    """Conjunction (join) of two non-BGP subpatterns."""
    left: Node
    right: Node


@dataclass
class Filter(Node):
    expr: FilterExpr
    child: Node


@dataclass
class LeftJoin(Node):        # OPTIONAL
    left: Node
    right: Node
    expr: Optional[FilterExpr] = None


@dataclass
class UnionOp(Node):
    left: Node
    right: Node


@dataclass
class Distinct(Node):
    child: Node


@dataclass
class OrderBy(Node):
    child: Node
    keys: List[Tuple[str, bool]]  # (var, ascending)


@dataclass
class Slice(Node):
    child: Node
    offset: int = 0
    limit: Optional[int] = None


@dataclass
class Project(Node):
    child: Node
    vars: Optional[List[str]]  # None = SELECT *


@dataclass
class Query:
    root: Node
    select: Optional[List[str]] = None   # None = *
    distinct: bool = False
