"""Production serving launcher — SPARQL query serving (the paper's kind)
over the distributed engine, or LM decode serving for the assigned archs.

    PYTHONPATH=src python -m repro.launch.serve --mode sparql --scale 1.0
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen1.5-0.5b
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.api import Model


def serve_sparql(args) -> None:
    from repro.core.compiler import compile_bgp
    from repro.core.distributed import DistributedExecutor
    from repro.core.sparql import parse_sparql
    from repro.core.stats import build_catalog
    from repro.rdf.generator import WatDivConfig, generate_watdiv
    from repro.rdf.workloads import ST_QUERIES

    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=args.scale, seed=0))
    cat = build_catalog(tt, d, threshold=0.25)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    print(f"store: {len(tt)} triples on {jax.device_count()} shard(s)")

    served = 0
    t0 = time.perf_counter()
    for name, qtext in ST_QUERIES.items():
        q = parse_sparql(qtext, d)
        plan = compile_bgp(q.root, cat)
        if plan.empty:
            print(f"  {name}: ∅ (statistics short-circuit)")
            served += 1
            continue
        ex = DistributedExecutor(plan, cat, mesh)
        data, cols = ex.run()
        print(f"  {name}: {len(data)} rows")
        served += 1
    print(f"served {served} queries in {time.perf_counter()-t0:.2f}s")


def serve_lm(args) -> None:
    cfg = get_reduced(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = args.batch, args.seq
    caches = model.init_caches(params if cfg.enc_dec else None, B, S)
    decode = jax.jit(model.decode, donate_argnums=(1,))

    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.perf_counter()
    for pos in range(args.tokens):
        logits, caches = model.decode(params, caches, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    print(f"{cfg.name} (reduced): decoded {args.tokens} tokens × batch {B} "
          f"in {dt:.2f}s = {args.tokens*B/dt:.0f} tok/s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sparql", choices=["sparql", "lm"])
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()
    if args.mode == "sparql":
        serve_sparql(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
