"""Production serving launcher — SPARQL query serving (the paper's kind)
over the distributed engine, or LM decode serving for the assigned archs.

    PYTHONPATH=src python -m repro.launch.serve --mode sparql --scale 1.0
    PYTHONPATH=src python -m repro.launch.serve --mode sparql \
        --store watdiv.store        # persist on first run, boot from the
                                    # store (no build pipeline) afterwards
    PYTHONPATH=src python -m repro.launch.serve --mode lm --arch qwen1.5-0.5b
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.models.api import Model


def serve_sparql(args) -> None:
    import json

    from repro.engine import Dataset, RuntimeConfig
    from repro.rdf.workloads import ST_QUERIES
    from repro.store import is_store

    t0 = time.perf_counter()
    if args.store and is_store(args.store):
        # persistent-store boot: manifest + lazy memmaps, the build
        # pipeline (build_catalog / build_extvp) never runs
        ds = Dataset.load(args.store, eager=args.eager_load)
        print(f"cold start from store {args.store!r} in "
              f"{time.perf_counter() - t0:.3f}s "
              f"({'eager' if args.eager_load else 'lazy memmap'})")
    else:
        ds = Dataset.watdiv(scale=args.scale, seed=0, threshold=0.25)
        if args.store:
            ds.save(args.store)
            print(f"built and persisted store {args.store!r} in "
                  f"{time.perf_counter() - t0:.3f}s "
                  "(next boot loads it without rebuilding)")
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    rt_kwargs = {}
    if args.batch_shapes:
        rt_kwargs["batch_shapes"] = tuple(
            int(t) for t in args.batch_shapes.replace(",", " ").split())
    if args.planner:
        rt_kwargs["planner"] = args.planner
    if args.trace_sample is not None:
        rt_kwargs["trace_sample_rate"] = args.trace_sample
    runtime = RuntimeConfig(**rt_kwargs) if rt_kwargs else None
    # "auto" routes per template across eager/jit (add --backend
    # distributed explicitly to pin the sharded path to a mesh)
    engine = ds.engine(args.backend,
                       mesh=mesh if args.backend == "distributed" else None,
                       runtime=runtime)
    print(f"store: {ds.n_triples} triples on {jax.device_count()} shard(s), "
          f"backend={engine.backend}")

    t0 = time.perf_counter()
    for p in range(max(1, args.passes)):
        for name, qtext in ST_QUERIES.items():
            res = engine.query(qtext)
            if p == 0:
                print(f"  {name}: {'∅' if len(res) == 0 else f'{len(res)} rows'}")
    m = engine.metrics.summary()
    print(f"served {int(m['served'])} queries in {time.perf_counter()-t0:.2f}s "
          f"(p50 {m['p50_ms']:.1f} ms, {int(m['short_circuits'])} "
          f"statistics-only empties, routed {m['routed']})")
    if args.runtime_report:
        print(json.dumps(engine.runtime_report(), indent=2))
    if args.trace_dump:
        with open(args.trace_dump, "w") as f:
            if args.trace_dump.endswith(".jsonl"):
                f.write(engine.tracer.to_jsonl())
            else:
                json.dump(engine.tracer.chrome_trace(), f)
        n = len(engine.tracer.recorder)
        print(f"wrote {n} trace(s) to {args.trace_dump!r} "
              f"(inspect: python tools/trace_inspect.py {args.trace_dump}; "
              "chrome://tracing loads the .json form)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(engine.metrics.prometheus())
        print(f"wrote Prometheus exposition to {args.metrics_out!r}")


def serve_lm(args) -> None:
    cfg = get_reduced(args.arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = args.batch, args.seq
    caches = model.init_caches(params if cfg.enc_dec else None, B, S)
    decode = jax.jit(model.decode, donate_argnums=(1,))

    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.perf_counter()
    for pos in range(args.tokens):
        logits, caches = model.decode(params, caches, tok, jnp.int32(pos))
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    dt = time.perf_counter() - t0
    print(f"{cfg.name} (reduced): decoded {args.tokens} tokens × batch {B} "
          f"in {dt:.2f}s = {args.tokens*B/dt:.0f} tok/s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sparql", choices=["sparql", "lm"])
    ap.add_argument("--backend", default="distributed",
                    help="ExecutionBackend registry key (eager/jit/"
                         "distributed) or 'auto' for per-template adaptive "
                         "routing (docs/serving.md)")
    ap.add_argument("--scale", type=float, default=0.5)
    ap.add_argument("--planner", default=None,
                    choices=["greedy", "estimate"],
                    help="join-order planner (default: REPRO_RT_PLANNER "
                         "env or 'greedy'); 'estimate' enumerates orders "
                         "by estimated intermediate cardinality")
    ap.add_argument("--batch-shapes", default=None,
                    help="comma-separated micro-batch bucket menu, e.g. "
                         "1,4,16 (default REPRO_RT_BATCH_SHAPES or "
                         "1,2,4,8,16,32; the tuner retires measured "
                         "regressions at runtime)")
    ap.add_argument("--passes", type=int, default=1,
                    help="serve the workload N times (give the adaptive "
                         "router warmup traffic)")
    ap.add_argument("--runtime-report", action="store_true",
                    help="print the adaptive-runtime JSON snapshot "
                         "(routing decisions, batch-shape menu, knobs)")
    ap.add_argument("--trace-sample", type=float, default=None,
                    help="per-request span-trace sampling rate in [0,1] "
                         "(default REPRO_RT_TRACE_SAMPLE or 0.0 = off; "
                         "see docs/observability.md)")
    ap.add_argument("--trace-dump", default=None,
                    help="write the flight recorder after serving: "
                         "Chrome chrome://tracing JSON, or JSONL when "
                         "the path ends in .jsonl")
    ap.add_argument("--metrics-out", default=None,
                    help="write the Prometheus text exposition of the "
                         "serving metrics to this file after serving")
    ap.add_argument("--store", default=None,
                    help="persistent catalog store directory: boot from it "
                         "when it exists (no build pipeline), else build "
                         "once and persist there")
    ap.add_argument("--eager-load", action="store_true",
                    help="materialize every table at boot instead of lazy "
                         "memory-mapping (see docs/serving.md)")
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()
    if args.mode == "sparql":
        serve_sparql(args)
    else:
        serve_lm(args)


if __name__ == "__main__":
    main()
