"""Production training launcher.

Wires every substrate piece: mesh + shardings, jitted train step with
donated state, deterministic data pipeline, async checkpointing with
elastic restore, straggler detection hooks.  On one CPU host this runs
reduced configs end-to-end (see examples/train_lm.py for the ergonomic
version); on a TPU fleet the same entry point runs the full configs —
``--multi-pod`` selects the (2,16,16) mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --reduced --steps 50
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, get_reduced
from repro.launch.mesh import make_production_mesh
from repro.models import sharding as shard_rules
from repro.models.api import Model
from repro.models.config import ShapeCell
from repro.train import checkpoint
from repro.train.data import DataConfig, make_batch
from repro.train.elastic import StragglerDetector
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_state import make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--mesh", default="auto",
                    help="'auto' = all local devices on one 'data' axis; "
                         "'production' = (16,16) / (2,16,16)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get(args.arch)
    model = Model(cfg)
    cell = ShapeCell("train", args.seq, args.batch, "train")

    if args.mesh == "production":
        mesh = make_production_mesh(multi_pod=args.multi_pod)
    else:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))

    params = model.init(jax.random.PRNGKey(0))
    opt_state = init_opt_state(params)
    pspecs = shard_rules.param_specs(params, mesh)
    pshard = shard_rules.to_shardings(pspecs, mesh)
    params = jax.tree.map(jax.device_put, params, pshard)

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum_steps=args.accum,
                                      compress_grads=args.compress_grads),
                      donate_argnums=(0, 1))
    dc = DataConfig(seed=0, vocab=min(cfg.vocab, 4096))
    detector = StragglerDetector()

    start = 0
    if args.ckpt_dir:
        last = checkpoint.latest_step(args.ckpt_dir)
        if last is not None:
            like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                                {"params": params, "opt": opt_state})
            restored = checkpoint.restore(args.ckpt_dir, last, like)
            params, opt_state = restored["params"], restored["opt"]
            start = last + 1
            print(f"[elastic] resumed from step {last} onto "
                  f"{jax.device_count()} devices")

    err_state = None
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(dc, cfg, cell, step).items()}
        if args.compress_grads:
            params, opt_state, err_state, metrics = step_fn(
                params, opt_state, batch, err_state)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        dt = time.perf_counter() - t0
        detector.observe({"host0": dt})
        if step % 10 == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.2f} {dt*1e3:.0f}ms")
        if args.ckpt_dir and step and step % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step,
                            {"params": params, "opt": opt_state})
    print("training complete")


if __name__ == "__main__":
    main()
