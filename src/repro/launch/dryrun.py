import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh).

The FIRST TWO LINES above must run before any jax import — jax locks the
device count at backend init.  This module is the proof artifact for the
production distribution config: a successful compile for the (16,16)
single-pod mesh and the (2,16,16) multi-pod mesh for every cell means the
shardings are coherent (no mismatched collectives, no unpartitionable
ops), and its cost/memory analysis feeds EXPERIMENTS.md §Dry-run,
§Roofline and §Perf.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
  ... --arch s2rdf            # the paper's own distributed query engine
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import HW, make_production_mesh, make_query_mesh
from repro.models import sharding as shard_rules
from repro.models.api import Model, model_flops, total_params
from repro.models.config import SHAPES, ShapeCell, shape_applicable
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_state import make_train_step


# ---------------------------------------------------------------------------
# Cell construction: the jitted function + arg structs/shardings per kind
# ---------------------------------------------------------------------------

def build_cell(cfg, cell: ShapeCell, mesh, compress_grads: bool = False):
    """Returns (jitted_fn, arg_structs) ready to .lower(*arg_structs)."""
    model = Model(cfg)
    pstructs = jax.eval_shape(model.init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    pspecs = shard_rules.param_specs(pstructs, mesh)
    pshard = shard_rules.to_shardings(pspecs, mesh)

    if cell.kind == "train":
        ostructs = jax.eval_shape(init_opt_state, pstructs)
        ospecs = shard_rules.opt_state_specs(pspecs, pstructs, mesh, cfg.zero1)
        ospecs = type(ostructs)(step=P(), mu=ospecs, nu=jax.tree.map(lambda s: s, ospecs))
        oshard = shard_rules.to_shardings(ospecs, mesh)
        bstructs = model.input_specs(cell)
        bshard = shard_rules.to_shardings(
            shard_rules.batch_specs(bstructs, mesh), mesh)
        step = make_train_step(model, OptConfig(),
                               compress_grads=compress_grads)
        if compress_grads:
            estructs = jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), pstructs)
            fn = jax.jit(step,
                         in_shardings=(pshard, oshard, bshard, pshard),
                         out_shardings=(pshard, oshard, pshard, None),
                         donate_argnums=(0, 1, 3))
            return fn, (pstructs, ostructs, bstructs, estructs)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        return fn, (pstructs, ostructs, bstructs)

    if cell.kind == "prefill":
        bstructs = model.input_specs(cell)
        bshard = shard_rules.to_shardings(
            shard_rules.batch_specs(bstructs, mesh), mesh)

        def prefill_fn(params, batch):
            return model.prefill(params, batch)

        fn = jax.jit(prefill_fn, in_shardings=(pshard, bshard))
        return fn, (pstructs, bstructs)

    assert cell.kind == "decode"
    specs = model.input_specs(cell)
    cstructs = specs["caches"]
    if cfg.dp_only_decode:
        from jax.sharding import PartitionSpec as _P
        pshard = shard_rules.to_shardings(
            jax.tree.map(lambda l: _P(*([None] * l.ndim)), pstructs), mesh)
        cspecs = shard_rules.cache_specs(cstructs, mesh)
        cspecs = jax.tree.map(
            lambda s: _P(*[e if e in ("data", ("pod", "data")) else None
                           for e in list(s)]),
            cspecs, is_leaf=lambda x: isinstance(x, _P))
        cshard = shard_rules.to_shardings(cspecs, mesh)
    else:
        cshard = shard_rules.to_shardings(
            shard_rules.cache_specs(cstructs, mesh), mesh)
    tshard = shard_rules.to_shardings(
        shard_rules.batch_specs({"tokens": specs["tokens"]}, mesh), mesh)["tokens"]

    def decode_fn(params, caches, tokens, pos):
        return model.decode(params, caches, tokens, pos)

    fn = jax.jit(decode_fn,
                 in_shardings=(pshard, cshard, tshard, None),
                 out_shardings=(None, cshard),
                 donate_argnums=(1,))
    return fn, (pstructs, cstructs, specs["tokens"], specs["pos"])


# ---------------------------------------------------------------------------
# S2RDF cell: the paper's engine on the production mesh
# ---------------------------------------------------------------------------

def build_s2rdf_cell(mesh_kind: str, scale: float = 2.0,
                     layout: str = "extvp", dual_partition: bool = False):
    """A representative snowflake plan over a WatDiv graph, distributed
    over all chips of the production mesh (flattened to a query mesh).
    ``layout="vp"`` compiles the same query against the VP baseline —
    the collective-byte ratio vs "extvp" is the paper's central claim
    (semi-join reduction shrinks shuffle traffic) measured on ICI."""
    from repro.engine import Dataset
    from repro.engine.backends import DistributedBackend

    ds = Dataset.watdiv(scale=scale, seed=0)
    mesh = make_query_mesh(multi_pod=(mesh_kind == "multi"))
    engine = ds.engine(DistributedBackend(dual_partition=dual_partition),
                       layout=layout, mesh=mesh)
    prepared = engine.prepare(
        "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p . "
        "?p sorg:price ?x . ?p rev:hasReview ?r . ?r rev:reviewer ?w }")
    return prepared, prepared.plan


# ---------------------------------------------------------------------------
# Record extraction
# ---------------------------------------------------------------------------

def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalize ``compiled.cost_analysis()`` across jax versions (older
    releases return a one-element list of per-program dicts)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def _raw_costs(compiled) -> Dict[str, float]:
    ca = cost_analysis_dict(compiled)
    coll = collective_bytes(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": float(coll["total"]),
            "coll_by_kind": {k: v for k, v in coll.items()
                             if k not in ("total", "weighted")}}


def pick_unroll(n_groups: int) -> int:
    for k in (2, 3, 4, 5):
        if n_groups % k == 0 and n_groups > k:
            return k
    return 1


def corrected_costs(a1: Dict[str, float], ak: Dict[str, float], g: int,
                    k: int) -> Dict[str, float]:
    """XLA cost_analysis counts while-loop bodies ONCE (verified on this
    backend, see EXPERIMENTS.md §Dry-run): with A1 = nonloop + body and
    Ak = nonloop + k·body, the depth-corrected total is
    A1 + (G-1)·(Ak-A1)/(k-1).  Applied to flops, HBM bytes, and the
    HLO-parsed collective bytes (collectives inside the loop body are
    likewise emitted once)."""
    out = {}
    for key in ("flops", "bytes", "coll"):
        body = max(0.0, (ak[key] - a1[key]) / (k - 1))
        out[key] = a1[key] + (g - 1) * body
    return out


def analyze(compiled, n_chips: int, mflops: Optional[float],
            costs: Optional[Dict[str, float]] = None) -> Dict[str, Any]:
    ca = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    if costs is not None:
        flops = costs["flops"]
        bytes_acc = costs["bytes"]
        coll = dict(coll)
        coll["total"] = costs["coll"]
    else:
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))

    # NOTE: with SPMD partitioning, cost_analysis reports per-program
    # (= per-chip) numbers; collective bytes parsed from HLO likewise.
    compute_s = flops / HW.PEAK_FLOPS_BF16
    memory_s = bytes_acc / HW.HBM_BW
    collective_s = coll["total"] / HW.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    # The XLA byte count sums every instruction's operand+output bytes on an
    # UNFUSED CPU-backend HLO — an upper bound on TPU HBM traffic.  The
    # matching lower bound is one pass over the live buffers:
    mem_lo = (getattr(mem, "argument_size_in_bytes", 0)
              + getattr(mem, "output_size_in_bytes", 0)
              + getattr(mem, "temp_size_in_bytes", 0))
    memory_s_lower = float(mem_lo) / HW.HBM_BW
    bound_lo = max(compute_s, memory_s_lower, collective_s)
    rec = {
        "hlo_flops_per_chip": flops,
        "hlo_bytes_per_chip": bytes_acc,
        "collective_bytes_per_chip": coll["total"],
        "collective_bytes_weighted": coll["weighted"],
        "collectives": {k: v for k, v in coll.items()
                        if k not in ("total", "weighted")},
        **terms,
        "memory_s_lower": memory_s_lower,
        "dominant": dominant,
        "step_seconds_bound": bound_s,
        "step_seconds_bound_lower": bound_lo,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "n_chips": n_chips,
    }
    if mflops:
        rec["model_flops_total"] = mflops
        rec["model_flops_per_chip"] = mflops / n_chips
        rec["useful_compute_ratio"] = (mflops / n_chips) / max(flops, 1.0)
        rec["roofline_fraction"] = ((mflops / n_chips) / HW.PEAK_FLOPS_BF16) \
            / max(bound_s, 1e-30)
        rec["roofline_fraction_upper"] = ((mflops / n_chips) / HW.PEAK_FLOPS_BF16) \
            / max(bound_lo, 1e-30)
    return rec


def run_cell(arch: str, shape_name: str, mesh_kind: str) -> Dict[str, Any]:
    t0 = time.time()
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    try:
        if arch == "s2rdf":
            ex, plan = build_s2rdf_cell(mesh_kind)
            lowered = ex.lower()
            compiled = lowered.compile()
            n_chips = 512 if mesh_kind == "multi" else 256
            rec.update(analyze(compiled, n_chips, None))
            rec["plan"] = plan.describe()
            rec["status"] = "ok"
        else:
            cfg = get(arch)
            cell = next(c for c in SHAPES if c.name == shape_name)
            ok, reason = shape_applicable(cfg, cell)
            if not ok:
                rec["status"] = "skipped"
                rec["reason"] = reason
                return rec
            mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
            n_chips = int(np.prod(list(mesh.shape.values())))
            fn, structs = build_cell(cfg, cell, mesh)
            compiled = fn.lower(*structs).compile()
            a1 = _raw_costs(compiled)
            # scan-depth correction: second compile with unrolled loop body
            g = cfg.n_groups
            k = pick_unroll(g)
            costs = None
            if k > 1:
                cfg_k = dataclasses.replace(cfg, scan_unroll=k)
                fn_k, structs_k = build_cell(cfg_k, cell, mesh)
                ak = _raw_costs(fn_k.lower(*structs_k).compile())
                costs = corrected_costs(a1, ak, g, k)
            rec.update(analyze(compiled, n_chips, model_flops(cfg, cell),
                               costs))
            rec["raw_flops_per_chip"] = a1["flops"]
            rec["scan_correction"] = {"n_groups": g, "unroll_probe": k}
            rec["total_params"] = total_params(cfg)
            rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001 — a failed cell is a reported bug
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, 'all', or 's2rdf'")
    ap.add_argument("--shape", default="all",
                    help="shape cell name or 'all'")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSONL here")
    args = ap.parse_args()

    archs = list(ARCHS) if args.arch == "all" else [args.arch]
    shapes = [c.name for c in SHAPES] if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    results = []
    for arch in archs:
        for shape in (["-"] if arch == "s2rdf" else shapes):
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind)
                results.append(rec)
                line = json.dumps(rec)
                if args.out:
                    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                    with open(args.out, "a") as f:
                        f.write(line + "\n")
                brief = {k: rec.get(k) for k in
                         ("arch", "shape", "mesh", "status", "dominant",
                          "compute_s", "memory_s", "collective_s",
                          "roofline_fraction", "error", "wall_s")}
                print(json.dumps(brief))

    n_ok = sum(1 for r in results if r["status"] == "ok")
    n_skip = sum(1 for r in results if r["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"# dry-run: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
