"""HLO analysis: collective-byte accounting for the roofline model.

``cost_analysis()`` gives FLOPs and HBM bytes but not collective traffic,
so we parse the compiled HLO text: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction contributes its *operand* bytes (what a chip puts on the
wire).  Operand shapes are resolved from the defining instructions, so
the parser handles both inline-typed operands and name-only references.

The estimator is deliberately simple (matching the brief's three-term
model): collective seconds = bytes / (chips × ICI link bandwidth).  Ring
algorithms move ~2× the payload for all-reduce — recorded as a separate
"weighted" figure for the §Perf discussion.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Tuple

import numpy as np

__all__ = ["collective_bytes", "parse_hlo_shapes", "COLLECTIVE_OPS"]

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

# e.g.  bf16[16,4096,128]{2,1,0}   or   f32[] or (tuple, ...)
_SHAPE_RE = re.compile(r"(?P<dt>[a-z]+\d*)\[(?P<dims>[\d,]*)\]")
# NOTE: tuple types may contain /*index=5*/ comments (hence [^)] not [^=])
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w\.\-]+)\s*=\s*(?P<type>\([^)]*\)|[a-z]+\d*\[[\d,]*\][^\s]*)\s+"
    r"(?P<op>[\w\-]+)\((?P<args>.*)\)", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        nbytes = _DTYPE_BYTES.get(m.group("dt"))
        if nbytes is None:
            continue
        dims = m.group("dims")
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def parse_hlo_shapes(hlo: str) -> Dict[str, int]:
    """instruction name -> output bytes."""
    out: Dict[str, int] = {}
    for m in _INSTR_RE.finditer(hlo):
        out[m.group("name")] = _shape_bytes(m.group("type"))
    return out


def collective_bytes(hlo: str) -> Dict[str, float]:
    """Sum of operand bytes per collective kind + totals.

    Returns {kind: bytes, ..., 'total': ..., 'weighted': ...} where
    'weighted' applies ring-cost factors (all-reduce 2(n-1)/n ≈ 2×,
    all-gather/reduce-scatter (n-1)/n ≈ 1×, all-to-all (n-1)/n ≈ 1×,
    collective-permute 1×)."""
    shapes = parse_hlo_shapes(hlo)
    per_kind: Dict[str, float] = defaultdict(float)

    for m in _INSTR_RE.finditer(hlo):
        op = m.group("op")
        kind = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-"):  # e.g. all-reduce-start
                kind = c
                break
        if kind is None:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        args = m.group("args")
        # operand bytes: inline-typed args or name lookups
        nbytes = 0
        inline = _shape_bytes(args)
        if inline:
            nbytes = inline
        else:
            for ref in re.finditer(r"%?([\w\.\-]+)", args):
                nbytes += shapes.get(ref.group(1), 0)
        # for all-gather the operand is the shard; for reduce-scatter the
        # full input; either way operand bytes = what leaves the chip.
        per_kind[kind] += nbytes

    weights = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}
    total = sum(per_kind.values())
    weighted = sum(v * weights[k] for k, v in per_kind.items())
    out = dict(per_kind)
    out["total"] = total
    out["weighted"] = weighted
    return out
