"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never
touches jax device state).  Single-pod = (16, 16) ("data", "model") =
256 chips (one v5e pod slice); multi-pod adds a leading "pod"=2 axis
(512 chips).  The dry-run process forces 512 host devices; the single-pod
mesh then uses the first 256 (a pod is a contiguous ICI domain — device
order matters on real hardware and jax.devices() preserves it).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_query_mesh", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) > n:
        devices = devices[:n]
    return jax.make_mesh(shape, axes, devices=devices)


def make_query_mesh(n_shards: Optional[int] = None, *, multi_pod: bool = False):
    """1-D mesh for the S2RDF engine: relational plans have no 'model'
    dimension, so queries flatten every chip onto a single 'data' axis."""
    n = n_shards or (512 if multi_pod else 256)
    devices = jax.devices()
    if len(devices) > n:
        devices = devices[:n]
    return jax.make_mesh((n,), ("data",), devices=devices)


class HW:
    """TPU v5e hardware constants for the roofline model (per chip)."""

    PEAK_FLOPS_BF16 = 197e12      # FLOP/s
    HBM_BW = 819e9                # B/s
    ICI_BW = 50e9                 # B/s per link (~3 links usable/chip on 2D torus)
    HBM_BYTES = 16 * 2**30        # 16 GiB
