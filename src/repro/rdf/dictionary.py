"""Dictionary encoding of RDF terms.

RDF terms (IRIs, literals) are strings; TPUs operate on dense integer
tensors.  Every term in a graph is assigned a dense ``int32`` id.  This is
the explicit analogue of what the paper gets implicitly from Parquet's
dictionary + run-length encoding (§2.2): after encoding, every relational
operation in the engine touches only ``int32`` columns.

Numeric literals additionally get a parallel ``float64`` value table so that
SPARQL FILTER comparisons (``?price < 500``) can be evaluated as a gather
from a dense array instead of string parsing at query time.

Ids are dense in ``[0, n_terms)``.  ``UNBOUND = -1`` is reserved as the
sentinel for unbound variables in OPTIONAL / UNION results, and
``PAD = 2**31 - 1`` as the padding key that sorts after every valid id in
sorted static-shape tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

# Sentinels -----------------------------------------------------------------
UNBOUND: int = -1              # OPTIONAL/UNION missing binding
PAD: int = np.iinfo(np.int32).max  # padding key; sorts after all valid ids


def _try_float(term: str) -> float:
    """Numeric value of a literal term, or NaN."""
    # Plain numeric literal ("42", "19.99") or typed ("\"42\"^^xsd:integer").
    s = term
    if s.startswith('"'):
        end = s.find('"', 1)
        if end > 0:
            s = s[1:end]
    try:
        return float(s)
    except ValueError:
        return float("nan")


@dataclass
class Dictionary:
    """Bidirectional term <-> id mapping with a numeric-value side table."""

    term_to_id: Dict[str, int] = field(default_factory=dict)
    id_to_term: List[str] = field(default_factory=list)
    _values: List[float] = field(default_factory=list)

    # -- construction -------------------------------------------------------
    def add(self, term: str) -> int:
        tid = self.term_to_id.get(term)
        if tid is None:
            tid = len(self.id_to_term)
            self.term_to_id[term] = tid
            self.id_to_term.append(term)
            self._values.append(_try_float(term))
        return tid

    def add_all(self, terms: Iterable[str]) -> np.ndarray:
        return np.asarray([self.add(t) for t in terms], dtype=np.int32)

    @classmethod
    def from_terms(cls, terms: Sequence[str],
                   values: Optional[Sequence[float]] = None) -> "Dictionary":
        """Rebuild a dictionary from an id-ordered term list (the store
        loader's path, :mod:`repro.store.reader`).  ``values`` is the
        persisted float64 numeric-value table; when absent it is
        recomputed term by term — passing it skips the string parsing
        and guarantees bit-identical values (NaN payloads included)."""
        d = cls()
        d.id_to_term = list(terms)
        d.term_to_id = {t: i for i, t in enumerate(d.id_to_term)}
        if len(d.term_to_id) != len(d.id_to_term):
            raise ValueError("duplicate terms in id-ordered term list")
        if values is None:
            d._values = [_try_float(t) for t in d.id_to_term]
        else:
            if len(values) != len(d.id_to_term):
                raise ValueError(
                    f"value table length {len(values)} != {len(d.id_to_term)} terms")
            d._values = [float(v) for v in values]
        return d

    # -- lookup --------------------------------------------------------------
    def __len__(self) -> int:
        return len(self.id_to_term)

    def id_of(self, term: str) -> Optional[int]:
        return self.term_to_id.get(term)

    def term_of(self, tid: int) -> str:
        if tid == UNBOUND:
            return "UNBOUND"
        return self.id_to_term[tid]

    def decode_rows(self, rows: np.ndarray) -> List[tuple]:
        return [tuple(self.term_of(int(t)) for t in row) for row in np.asarray(rows)]

    @property
    def values(self) -> np.ndarray:
        """float64[n_terms] numeric value per id (NaN if not numeric)."""
        return np.asarray(self._values, dtype=np.float64)

    # -- bulk encoding -------------------------------------------------------
    def encode_triples(self, triples: Sequence[tuple]) -> np.ndarray:
        """Encode an iterable of (s, p, o) string triples to int32[N, 3]."""
        out = np.empty((len(triples), 3), dtype=np.int32)
        for i, (s, p, o) in enumerate(triples):
            out[i, 0] = self.add(s)
            out[i, 1] = self.add(p)
            out[i, 2] = self.add(o)
        return out


def encode_graph(triples: Sequence[tuple]) -> tuple:
    """Convenience: encode string triples, returning (tt, dictionary)."""
    d = Dictionary()
    tt = d.encode_triples(triples)
    return tt, d
