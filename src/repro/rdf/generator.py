"""WatDiv-like scalable RDF graph generator.

The paper evaluates on the Waterloo SPARQL Diversity Test Suite (WatDiv)
[Aluç et al., §7].  The original generator is a C++ tool; this module is a
vectorized numpy re-implementation of its *relevant structure*: an
e-commerce + social-network schema whose predicate cardinalities match the
figures the paper reports and whose correlation selectivities reproduce the
selectivity classes used in the paper's Selectivity Testing (ST) use case
(§7.1):

* ``friendOf``  ~ 0.4 |G|   (the largest predicate; ST-1, ST-5, IL paths)
* ``follows``   ~ 0.3 |G|   (second largest; together 0.7 |G|, §7.3)
* ``likes``     ~ 0.01 |G|  (small-input predicate, ST-4)
* ``reviewer``  ~ 0.01 |G|  (small-input predicate, ST-2)
* OS-correlation selectivities vs ``friendOf`` of ~0.9 / ~0.5 / ~0.05
  (via ``email`` / ``likes`` / ``purchased`` subject coverage)
* SS-correlation selectivities of ~0.9 / ~0.77 (via ``email`` / ``gender``)
* SO-correlation selectivities of ~0.9 / ~0.3 / ~0.04
  (via ``follows`` / ``reviewer`` / ``invitedBy`` object coverage)
* structurally-empty correlations (e.g. literal objects joined against
  entity subjects) so that ST-8's statistics-only ∅ answer is exercised.

Entity id layout is blocked so term strings can be materialized lazily and
the generator stays O(N) vectorized:

    [predicates | classes | users | products | reviews | retailers |
     websites | cities | countries | genres | categories |
     integer literals 0..NUM_POOL | string-literal pool]

Scale: ``scale_factor=1.0`` produces ~1.0e5 triples (WatDiv SF1); the
paper's SF10000 would be ~1.09e9.  Everything is deterministic given
``seed``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np

from repro.rdf.dictionary import Dictionary

# --------------------------------------------------------------------------
# Schema
# --------------------------------------------------------------------------

PREDICATES: List[str] = [
    "rdf:type",          # everything
    "wsdbm:follows",     # user -> user         ~0.3 |G|
    "wsdbm:friendOf",    # user -> user         ~0.4 |G|
    "wsdbm:likes",       # user -> product      ~1%  (50% of users)
    "wsdbm:purchased",   # user -> product      (5% of users)     OS low
    "wsdbm:invitedBy",   # user -> user         (4% object cover) SO low
    "sorg:email",        # user -> literal      (90% of users)    OS/SS high
    "wsdbm:gender",      # user -> literal      (77% of users)    SS mid
    "foaf:age",          # user -> int literal  (50% of users)    OS mid
    "wsdbm:subscribes",  # user -> website      (80% of users)
    "rev:reviewer",      # review -> user       ~1%
    "rev:rating",        # review -> int literal
    "rev:hasReview",     # product -> review
    "sorg:caption",      # product -> literal   (60% of products)
    "sorg:price",        # product -> int literal
    "sorg:hasGenre",     # product -> genre
    "sorg:soldBy",       # product -> retailer
    "wsdbm:sells",       # retailer -> product
    "sorg:locatedIn",    # retailer -> city
    "gn:partOf",         # city -> country
    "sorg:homepage",     # retailer -> website
    "wsdbm:hits",        # website -> int literal
]

CLASSES: List[str] = [
    "wsdbm:User",
    "wsdbm:Product",
    "wsdbm:Review",
    "wsdbm:Retailer",
    "wsdbm:Website",
    "wsdbm:City",
    "wsdbm:Country",
    "wsdbm:Genre",
]

NUM_POOL = 1001          # integer literals 0..1000
STR_POOL = 997           # shared string-literal pool (emails/captions/genders)


@dataclass
class WatDivConfig:
    scale_factor: float = 1.0
    seed: int = 0
    # entity counts per unit scale factor
    users_per_sf: int = 1000
    products_per_sf: int = 250
    reviews_per_sf: int = 1100
    retailers_per_sf: int = 20
    websites_per_sf: int = 50
    n_cities: int = 100
    n_countries: int = 25
    n_genres: int = 21
    n_categories: int = 12

    @property
    def n_users(self) -> int:
        return max(20, int(self.users_per_sf * self.scale_factor))

    @property
    def n_products(self) -> int:
        return max(10, int(self.products_per_sf * self.scale_factor))

    @property
    def n_reviews(self) -> int:
        return max(10, int(self.reviews_per_sf * self.scale_factor))

    @property
    def n_retailers(self) -> int:
        return max(5, int(self.retailers_per_sf * self.scale_factor))

    @property
    def n_websites(self) -> int:
        return max(5, int(self.websites_per_sf * self.scale_factor))


@dataclass
class WatDivSchema:
    """Id layout + handles the query workloads need."""

    pred: Dict[str, int] = field(default_factory=dict)
    cls: Dict[str, int] = field(default_factory=dict)
    user0: int = 0
    n_users: int = 0
    product0: int = 0
    n_products: int = 0
    review0: int = 0
    n_reviews: int = 0
    retailer0: int = 0
    n_retailers: int = 0
    website0: int = 0
    n_websites: int = 0
    city0: int = 0
    n_cities: int = 0
    country0: int = 0
    n_countries: int = 0
    genre0: int = 0
    n_genres: int = 0
    category0: int = 0
    n_categories: int = 0
    num0: int = 0        # id of integer literal "0"
    str0: int = 0
    n_terms: int = 0

    def num_literal(self, v: int) -> int:
        assert 0 <= v < NUM_POOL
        return self.num0 + v


def _zipf_targets(rng: np.random.Generator, n_src: int, n_edges: int,
                  alpha: float = 1.5) -> np.ndarray:
    """Zipf-ish out-degree allocation: returns int64[n_src] summing n_edges."""
    if n_src == 0 or n_edges == 0:
        return np.zeros(n_src, dtype=np.int64)
    w = (1.0 / np.arange(1, n_src + 1) ** alpha)
    rng.shuffle(w)
    w /= w.sum()
    deg = rng.multinomial(n_edges, w)
    return deg.astype(np.int64)


def _edges(rng: np.random.Generator, src_ids: np.ndarray, deg: np.ndarray,
           dst_lo: int, dst_n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Expand per-source degrees into (s, o) edge arrays with random targets."""
    s = np.repeat(src_ids, deg)
    o = rng.integers(dst_lo, dst_lo + dst_n, size=s.shape[0], dtype=np.int64)
    return s, o


def generate_watdiv(cfg: WatDivConfig) -> Tuple[np.ndarray, Dictionary, WatDivSchema]:
    """Generate the graph.  Returns (tt int32[N,3], dictionary, schema)."""
    rng = np.random.default_rng(cfg.seed)
    sch = WatDivSchema()

    # ---- id layout ---------------------------------------------------------
    next_id = 0

    def block(n: int) -> int:
        nonlocal next_id
        lo = next_id
        next_id += n
        return lo

    for p in PREDICATES:
        sch.pred[p] = block(1)
    for c in CLASSES:
        sch.cls[c] = block(1)
    sch.user0, sch.n_users = block(cfg.n_users), cfg.n_users
    sch.product0, sch.n_products = block(cfg.n_products), cfg.n_products
    sch.review0, sch.n_reviews = block(cfg.n_reviews), cfg.n_reviews
    sch.retailer0, sch.n_retailers = block(cfg.n_retailers), cfg.n_retailers
    sch.website0, sch.n_websites = block(cfg.n_websites), cfg.n_websites
    sch.city0, sch.n_cities = block(cfg.n_cities), cfg.n_cities
    sch.country0, sch.n_countries = block(cfg.n_countries), cfg.n_countries
    sch.genre0, sch.n_genres = block(cfg.n_genres), cfg.n_genres
    sch.category0, sch.n_categories = block(cfg.n_categories), cfg.n_categories
    sch.num0 = block(NUM_POOL)
    sch.str0 = block(STR_POOL)
    sch.n_terms = next_id

    U, P, R = cfg.n_users, cfg.n_products, cfg.n_reviews
    users = np.arange(sch.user0, sch.user0 + U, dtype=np.int64)
    products = np.arange(sch.product0, sch.product0 + P, dtype=np.int64)
    reviews = np.arange(sch.review0, sch.review0 + R, dtype=np.int64)
    retailers = np.arange(sch.retailer0, sch.retailer0 + cfg.n_retailers, dtype=np.int64)
    websites = np.arange(sch.website0, sch.website0 + cfg.n_websites, dtype=np.int64)
    cities = np.arange(sch.city0, sch.city0 + cfg.n_cities, dtype=np.int64)

    chunks: List[Tuple[int, np.ndarray, np.ndarray]] = []  # (pred id, s, o)

    def emit(pname: str, s: np.ndarray, o: np.ndarray) -> None:
        chunks.append((sch.pred[pname], np.asarray(s), np.asarray(o)))

    def subset(ids: np.ndarray, frac: float) -> np.ndarray:
        k = int(round(len(ids) * frac))
        return rng.choice(ids, size=k, replace=False)

    # ---- "other" predicates first; friendOf/follows sized from their total -
    # rdf:type
    emit("rdf:type", users, np.full(U, sch.cls["wsdbm:User"]))
    emit("rdf:type", products,
         sch.category0 + rng.integers(0, cfg.n_categories, P))
    emit("rdf:type", reviews, np.full(R, sch.cls["wsdbm:Review"]))
    emit("rdf:type", retailers, np.full(cfg.n_retailers, sch.cls["wsdbm:Retailer"]))
    emit("rdf:type", websites, np.full(cfg.n_websites, sch.cls["wsdbm:Website"]))

    # user attributes (subject coverage tuned for ST selectivity classes)
    u_email = subset(users, 0.90)
    emit("sorg:email", u_email, sch.str0 + rng.integers(0, STR_POOL, len(u_email)))
    u_gender = subset(users, 0.77)
    emit("wsdbm:gender", u_gender,
         sch.str0 + rng.integers(0, 3, len(u_gender)))
    u_age = subset(users, 0.50)
    emit("foaf:age", u_age,
         sch.num0 + rng.integers(18, 91, len(u_age)))

    # user -> product (likes: 50% of users, avg 2.2 products)
    u_like = subset(users, 0.50)
    deg = rng.poisson(2.2, len(u_like)) + 1
    emit("wsdbm:likes", *_edges(rng, u_like, deg, sch.product0, P))

    # user -> product (purchased: 5% of users)  -> OS(friendOf|purchased)~0.05
    u_buy = subset(users, 0.05)
    deg = rng.poisson(1.5, len(u_buy)) + 1
    emit("wsdbm:purchased", *_edges(rng, u_buy, deg, sch.product0, P))

    # user -> user (invitedBy: objects cover ~4% of users) -> SO low
    u_inviters = subset(users, 0.04)
    n_inv = max(4, int(0.04 * U))
    emit("wsdbm:invitedBy",
         rng.choice(users, n_inv),
         rng.choice(u_inviters, n_inv) if len(u_inviters) else users[:0])

    # user -> website
    u_sub = subset(users, 0.80)
    deg = rng.poisson(1.5, len(u_sub)) + 1
    emit("wsdbm:subscribes", *_edges(rng, u_sub, deg, sch.website0, cfg.n_websites))

    # reviews: written by 30% of users -> SO(.|reviewer)~0.3
    u_reviewers = subset(users, 0.30)
    emit("rev:reviewer", reviews, rng.choice(u_reviewers, R))
    emit("rev:rating", reviews, sch.num0 + rng.integers(1, 11, R))
    emit("rev:hasReview", rng.integers(sch.product0, sch.product0 + P, R), reviews)

    # products
    p_cap = subset(products, 0.60)
    emit("sorg:caption", p_cap, sch.str0 + rng.integers(0, STR_POOL, len(p_cap)))
    emit("sorg:price", products, sch.num0 + rng.integers(1, NUM_POOL, P))
    deg = rng.poisson(1.5, P) + 1
    emit("sorg:hasGenre", *_edges(rng, products, deg, sch.genre0, cfg.n_genres))
    p_sold = rng.integers(sch.retailer0, sch.retailer0 + cfg.n_retailers, P)
    emit("sorg:soldBy", products, p_sold)
    emit("wsdbm:sells", p_sold, products)      # inverse edges

    # retailers / websites / geo
    emit("sorg:locatedIn", retailers,
         rng.integers(sch.city0, sch.city0 + cfg.n_cities, cfg.n_retailers))
    emit("gn:partOf", cities,
         rng.integers(sch.country0, sch.country0 + cfg.n_countries, cfg.n_cities))
    emit("sorg:homepage", retailers,
         rng.integers(sch.website0, sch.website0 + cfg.n_websites, cfg.n_retailers))
    emit("wsdbm:hits", websites, sch.num0 + rng.integers(0, NUM_POOL, cfg.n_websites))

    n_other = sum(len(s) for _, s, _ in chunks)

    # ---- the two giant social predicates (0.4 / 0.3 of |G|) ----------------
    # other : follows : friendOf  =  3 : 3 : 4  =>  |G| ~ n_other * 10/3
    n_follows = n_other
    n_friend = int(round(n_other * 4 / 3))
    deg = _zipf_targets(rng, U, n_follows)
    emit("wsdbm:follows", *_edges(rng, users, deg, sch.user0, U))
    deg = _zipf_targets(rng, U, n_friend)
    emit("wsdbm:friendOf", *_edges(rng, users, deg, sch.user0, U))

    # ---- assemble ----------------------------------------------------------
    n_total = sum(len(s) for _, s, _ in chunks)
    tt = np.empty((n_total, 3), dtype=np.int32)
    pos = 0
    for pid, s, o in chunks:
        k = len(s)
        tt[pos:pos + k, 0] = s
        tt[pos:pos + k, 1] = pid
        tt[pos:pos + k, 2] = o
        pos += k
    # deduplicate (multi-edges collapse, like real RDF sets)
    tt = np.unique(tt, axis=0)
    rng.shuffle(tt, axis=0)

    d = _build_dictionary(sch)
    return tt, d, sch


def _build_dictionary(sch: WatDivSchema) -> Dictionary:
    """Materialize term strings for the blocked id layout."""
    d = Dictionary()
    for p in PREDICATES:
        d.add(p)
    for c in CLASSES:
        d.add(c)

    def addrange(prefix: str, lo: int, n: int) -> None:
        assert len(d) == lo, (prefix, len(d), lo)
        for i in range(n):
            d.add(f"{prefix}{i}")

    addrange("wsdbm:User", sch.user0, sch.n_users)
    addrange("wsdbm:Product", sch.product0, sch.n_products)
    addrange("wsdbm:Review", sch.review0, sch.n_reviews)
    addrange("wsdbm:Retailer", sch.retailer0, sch.n_retailers)
    addrange("wsdbm:Website", sch.website0, sch.n_websites)
    addrange("gn:City", sch.city0, sch.n_cities)
    addrange("gn:Country", sch.country0, sch.n_countries)
    addrange("sorg:Genre", sch.genre0, sch.n_genres)
    addrange("wsdbm:ProductCategory", sch.category0, sch.n_categories)
    assert len(d) == sch.num0
    for v in range(NUM_POOL):
        d.add(f'"{v}"')
    for i in range(STR_POOL):
        d.add(f'"str{i}"')
    assert len(d) == sch.n_terms
    return d
