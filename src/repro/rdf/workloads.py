"""WatDiv query workloads (paper §7).

Three use cases, matching the paper's experimental design:

* **ST — Selectivity Testing** (§7.1, Appendix B): pairs/triples of
  patterns whose ExtVP tables span the selectivity classes the paper
  sweeps (OS 0.9/0.5/0.05, SO 0.9/0.3/0.04, SS 0.9/0.77, plus the
  statistics-only-empty ST-8 pair).
* **Basic Testing** (§7.2, Appendix A): 20 templates over four shapes —
  star (S1–S7), linear (L1–L5), snowflake (F1–F5), complex (C1–C3).
* **IL — Incremental Linear Testing** (§7.3, Appendix C): linear chains
  of diameter 5..10, user-bound (IL-1), retailer-bound (IL-2) and
  unbound (IL-3).

The WatDiv appendices are not redistributed with the paper text, so the
templates here are reconstructed to the documented shape/selectivity
classes over this generator's schema; ``%x%`` placeholders instantiate to
random entities (deterministic per seed), as the WatDiv driver does.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.rdf.generator import WatDivSchema

# ---------------------------------------------------------------------------
# Selectivity Testing (ST)
# ---------------------------------------------------------------------------

ST_QUERIES: Dict[str, str] = {
    # OS effectiveness, big first table (|VP_friendOf| ~ 0.4|G|)
    "ST-1-1": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v1 sorg:email ?v2 }",
    "ST-1-2": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v1 wsdbm:likes ?v2 }",
    "ST-1-3": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v1 wsdbm:purchased ?v2 }",
    # OS effectiveness, small first table (|VP_reviewer| ~ 0.01|G|)
    "ST-2-1": "SELECT * WHERE { ?v0 rev:reviewer ?v1 . ?v1 sorg:email ?v2 }",
    "ST-2-2": "SELECT * WHERE { ?v0 rev:reviewer ?v1 . ?v1 wsdbm:likes ?v2 }",
    "ST-2-3": "SELECT * WHERE { ?v0 rev:reviewer ?v1 . ?v1 wsdbm:purchased ?v2 }",
    # SO effectiveness, big second table
    "ST-3-1": "SELECT * WHERE { ?v0 wsdbm:follows ?v1 . ?v1 wsdbm:friendOf ?v2 }",
    "ST-3-2": "SELECT * WHERE { ?v0 rev:reviewer ?v1 . ?v1 wsdbm:friendOf ?v2 }",
    "ST-3-3": "SELECT * WHERE { ?v0 wsdbm:invitedBy ?v1 . ?v1 wsdbm:friendOf ?v2 }",
    # SO effectiveness, small second table
    "ST-4-1": "SELECT * WHERE { ?v0 wsdbm:follows ?v1 . ?v1 wsdbm:likes ?v2 }",
    "ST-4-2": "SELECT * WHERE { ?v0 rev:reviewer ?v1 . ?v1 wsdbm:likes ?v2 }",
    "ST-4-3": "SELECT * WHERE { ?v0 wsdbm:invitedBy ?v1 . ?v1 wsdbm:likes ?v2 }",
    # SS effectiveness
    "ST-5-1": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v0 sorg:email ?v2 }",
    "ST-5-2": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v0 wsdbm:gender ?v2 }",
    # high selectivity on small inputs (linear / star)
    "ST-6-1": "SELECT * WHERE { ?v0 wsdbm:invitedBy ?v1 . ?v1 wsdbm:purchased ?v2 }",
    "ST-6-2": "SELECT * WHERE { ?v0 wsdbm:purchased ?v1 . ?v0 wsdbm:invitedBy ?v2 }",
    # OS-vs-SO choice in a chain (middle pattern has both candidates)
    "ST-7-1": "SELECT * WHERE { ?v0 wsdbm:follows ?v1 . ?v1 wsdbm:friendOf ?v2 . "
              "?v2 wsdbm:purchased ?v3 }",
    "ST-7-2": "SELECT * WHERE { ?v0 wsdbm:invitedBy ?v1 . ?v1 wsdbm:friendOf ?v2 . "
              "?v2 sorg:email ?v3 }",
    # statistics-only empty answers
    "ST-8-1": "SELECT * WHERE { ?v0 sorg:price ?v1 . ?v1 wsdbm:follows ?v2 }",
    "ST-8-2": "SELECT * WHERE { ?v0 wsdbm:friendOf ?v1 . ?v1 wsdbm:follows ?v2 . "
              "?v2 sorg:hasGenre ?v3 }",
}

# ---------------------------------------------------------------------------
# Basic Testing (S/L/F/C)
# ---------------------------------------------------------------------------

BASIC_TEMPLATES: Dict[str, str] = {
    # --- star ---
    "S1": "SELECT * WHERE { ?v0 sorg:soldBy %retailer% . ?v0 sorg:price ?v2 . "
          "?v0 rdf:type ?v3 . ?v0 sorg:caption ?v4 . ?v0 sorg:hasGenre ?v5 }",
    "S2": "SELECT * WHERE { ?v0 wsdbm:gender %gender% . ?v0 sorg:email ?v2 . "
          "?v0 rdf:type wsdbm:User }",
    "S3": "SELECT * WHERE { ?v0 rdf:type %category% . ?v0 sorg:caption ?v1 . "
          "?v0 sorg:price ?v2 . ?v0 sorg:hasGenre ?v3 }",
    "S4": "SELECT * WHERE { ?v0 wsdbm:subscribes %website% . ?v0 sorg:email ?v1 . "
          "?v0 foaf:age ?v2 }",
    "S5": "SELECT * WHERE { ?v0 rev:rating %rating% . ?v0 rev:reviewer ?v1 }",
    "S6": "SELECT * WHERE { ?v0 sorg:locatedIn ?v1 . ?v0 sorg:homepage ?v2 . "
          "?v0 wsdbm:sells ?v3 }",
    "S7": "SELECT * WHERE { ?v0 wsdbm:likes %product% . ?v0 wsdbm:gender ?v1 . "
          "?v0 sorg:email ?v2 }",
    # --- linear ---
    "L1": "SELECT * WHERE { %user% wsdbm:follows ?v1 . ?v1 wsdbm:likes ?v2 . "
          "?v2 sorg:price ?v3 }",
    "L2": "SELECT * WHERE { ?v0 wsdbm:likes %product% . ?v0 wsdbm:friendOf ?v1 . "
          "?v1 sorg:email ?v2 }",
    "L3": "SELECT * WHERE { %retailer% wsdbm:sells ?v1 . ?v1 rev:hasReview ?v2 . "
          "?v2 rev:rating ?v3 }",
    "L4": "SELECT * WHERE { ?v0 sorg:locatedIn ?v1 . ?v1 gn:partOf %country% }",
    "L5": "SELECT * WHERE { %user% wsdbm:friendOf ?v1 . ?v1 wsdbm:subscribes ?v2 . "
          "?v2 wsdbm:hits ?v3 }",
    # --- snowflake ---
    "F1": "SELECT * WHERE { ?v0 rev:hasReview ?v1 . ?v1 rev:rating ?v2 . "
          "?v1 rev:reviewer ?v3 . ?v0 sorg:price ?v4 . ?v0 sorg:soldBy ?v5 . "
          "?v5 sorg:locatedIn ?v6 }",
    "F2": "SELECT * WHERE { ?v0 wsdbm:likes ?v1 . ?v0 wsdbm:friendOf ?v2 . "
          "?v2 sorg:email ?v3 . ?v1 sorg:price ?v4 . ?v1 sorg:hasGenre %genre% }",
    "F3": "SELECT * WHERE { %retailer% wsdbm:sells ?v1 . ?v1 sorg:hasGenre ?v2 . "
          "?v1 rev:hasReview ?v3 . ?v3 rev:reviewer ?v4 . ?v4 wsdbm:gender ?v5 }",
    "F4": "SELECT * WHERE { ?v0 wsdbm:subscribes ?v1 . ?v1 wsdbm:hits ?v2 . "
          "?v0 wsdbm:likes ?v3 . ?v3 sorg:caption ?v4 . ?v0 foaf:age ?v5 . "
          "FILTER(?v5 > 40) }",
    "F5": "SELECT * WHERE { ?v0 rev:hasReview ?v1 . ?v1 rev:rating ?v2 . "
          "?v1 rev:reviewer ?v3 . ?v3 wsdbm:follows ?v4 . ?v0 sorg:soldBy %retailer% . "
          "FILTER(?v2 > 5) }",
    # --- complex ---
    "C1": "SELECT * WHERE { ?v0 wsdbm:follows ?v1 . ?v1 wsdbm:friendOf ?v2 . "
          "?v2 wsdbm:likes ?v3 . ?v3 rev:hasReview ?v4 . ?v4 rev:reviewer ?v5 . "
          "?v5 sorg:email ?v6 }",
    "C2": "SELECT * WHERE { ?v0 wsdbm:likes ?v1 . ?v1 sorg:soldBy ?v2 . "
          "?v2 wsdbm:sells ?v3 . ?v3 rev:hasReview ?v4 . ?v4 rev:reviewer ?v5 . "
          "?v5 wsdbm:friendOf ?v6 . ?v5 foaf:age ?v7 . FILTER(?v7 < 30) }",
    "C3": "SELECT * WHERE { ?v0 wsdbm:likes ?v1 . ?v0 wsdbm:friendOf ?v2 . "
          "?v0 wsdbm:gender ?v3 . OPTIONAL { ?v0 foaf:age ?v4 } }",
}

# ---------------------------------------------------------------------------
# Incremental Linear Testing (IL)
# ---------------------------------------------------------------------------

_IL1_EDGES = ["wsdbm:follows", "wsdbm:friendOf", "wsdbm:likes", "rev:hasReview",
              "rev:reviewer", "wsdbm:follows", "wsdbm:friendOf", "wsdbm:likes",
              "rev:hasReview", "rev:reviewer"]
_IL2_EDGES = ["wsdbm:sells", "rev:hasReview", "rev:reviewer", "wsdbm:follows",
              "wsdbm:friendOf", "wsdbm:likes", "rev:hasReview", "rev:reviewer",
              "wsdbm:follows", "wsdbm:friendOf"]


def il_query(kind: int, diameter: int, start: str = "?v0") -> str:
    """IL-<kind>-<diameter>; kind 1 = user-bound, 2 = retailer-bound,
    3 = unbound (IL-1 edge sequence)."""
    assert 5 <= diameter <= 10
    edges = _IL2_EDGES if kind == 2 else _IL1_EDGES
    tps = []
    subj = start if kind != 3 else "?v0"
    for i, p in enumerate(edges[:diameter]):
        obj = f"?v{i + 1}"
        tps.append(f"{subj} {p} {obj}")
        subj = obj
    return "SELECT * WHERE { " + " . ".join(tps) + " }"


def instantiate(template: str, sch: WatDivSchema, rng: np.random.Generator) -> str:
    """Fill %placeholders% with random entities of the right class."""
    def pick(lo, n):
        return int(rng.integers(lo, lo + n))

    subs = {
        "%retailer%": f"wsdbm:Retailer{pick(0, sch.n_retailers)}",
        "%user%": f"wsdbm:User{pick(0, sch.n_users)}",
        "%product%": f"wsdbm:Product{pick(0, sch.n_products)}",
        "%website%": f"wsdbm:Website{pick(0, sch.n_websites)}",
        "%country%": f"gn:Country{pick(0, sch.n_countries)}",
        "%genre%": f"sorg:Genre{pick(0, sch.n_genres)}",
        "%category%": f"wsdbm:ProductCategory{pick(0, sch.n_categories)}",
        "%gender%": f'"str{pick(0, 3)}"',
        "%rating%": f'"{pick(1, 10)}"',
    }
    out = template
    for k, v in subs.items():
        out = out.replace(k, v)
    return out


def basic_queries(sch: WatDivSchema, seed: int = 0,
                  n_instances: int = 3) -> Dict[str, List[str]]:
    rng = np.random.default_rng(seed)
    return {name: [instantiate(t, sch, rng) for _ in range(n_instances)]
            for name, t in BASIC_TEMPLATES.items()}


def il_queries(sch: WatDivSchema, seed: int = 0, n_instances: int = 3,
               il3_max_diameter: int = 6) -> Dict[str, List[str]]:
    """IL-3 (fully unbound) result sets grow ~10× per hop — the paper's
    own Table 5 shows 'F' (failure) entries for several systems there; on
    a single host we cap IL-3 at ``il3_max_diameter`` and report the rest
    as F."""
    rng = np.random.default_rng(seed)
    out: Dict[str, List[str]] = {}
    for diameter in range(5, 11):
        out[f"IL-1-{diameter}"] = [
            il_query(1, diameter, f"wsdbm:User{rng.integers(0, sch.n_users)}")
            for _ in range(n_instances)]
        out[f"IL-2-{diameter}"] = [
            il_query(2, diameter,
                     f"wsdbm:Retailer{rng.integers(0, sch.n_retailers)}")
            for _ in range(n_instances)]
        if diameter <= il3_max_diameter:
            out[f"IL-3-{diameter}"] = [il_query(3, diameter)]
    return out
