"""RDF data substrate: dictionary encoding, N-Triples IO, WatDiv-like generator."""

from repro.rdf.dictionary import Dictionary, encode_graph, PAD, UNBOUND
from repro.rdf.generator import WatDivConfig, generate_watdiv
from repro.rdf.ntriples import parse_ntriples, write_ntriples

__all__ = [
    "Dictionary",
    "encode_graph",
    "PAD",
    "UNBOUND",
    "WatDivConfig",
    "generate_watdiv",
    "parse_ntriples",
    "write_ntriples",
]
