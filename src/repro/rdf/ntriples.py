"""Minimal N-Triples reader/writer.

The evaluation datasets are generated in-process (``generator.py``), but a
production deployment ingests N-Triples from a data lake, so the loader is a
first-class substrate component.  Handles IRIs (``<...>``), plain/typed
literals and blank nodes; skips comments and blank lines.
"""

from __future__ import annotations

from typing import List, Tuple


def _parse_term(s: str, pos: int) -> Tuple[str, int]:
    """Parse one term starting at pos; return (term, next_pos)."""
    while pos < len(s) and s[pos].isspace():
        pos += 1
    if pos >= len(s):
        raise ValueError(f"unexpected end of line in {s!r}")
    c = s[pos]
    if c == "<":  # IRI
        end = s.index(">", pos)
        return s[pos + 1 : end], end + 1
    if c == '"':  # literal, possibly with ^^type or @lang
        end = pos + 1
        while end < len(s):
            if s[end] == "\\":
                end += 2
                continue
            if s[end] == '"':
                break
            end += 1
        lit_end = end + 1
        # consume datatype / langtag
        if lit_end < len(s) and s[lit_end] == "@":
            while lit_end < len(s) and not s[lit_end].isspace():
                lit_end += 1
        elif s[lit_end : lit_end + 2] == "^^":
            lit_end += 2
            if lit_end < len(s) and s[lit_end] == "<":
                lit_end = s.index(">", lit_end) + 1
        return s[pos:lit_end], lit_end
    if c == "_":  # blank node _:b0
        end = pos
        while end < len(s) and not s[end].isspace():
            end += 1
        return s[pos:end], end
    raise ValueError(f"cannot parse term at {s[pos:pos+40]!r}")


def parse_ntriples(text: str) -> List[Tuple[str, str, str]]:
    triples = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        s, pos = _parse_term(line, 0)
        p, pos = _parse_term(line, pos)
        o, pos = _parse_term(line, pos)
        triples.append((s, p, o))
    return triples


def write_ntriples(triples, path: str) -> None:
    def fmt(t: str) -> str:
        if t.startswith('"') or t.startswith("_:"):
            return t
        return f"<{t}>"

    with open(path, "w") as f:
        for s, p, o in triples:
            f.write(f"{fmt(s)} {fmt(p)} {fmt(o)} .\n")
