"""Centralized runtime knobs for the adaptive execution layer.

Every tunable of the :class:`~repro.runtime.router.BackendRouter`, the
:class:`~repro.runtime.tuner.BatchTuner` and the micro-batching layer
lives here, alpa ``GlobalConfig``-style: one object, defaults readable in
one place, every knob overridable from the environment (``REPRO_RT_*``)
so a deployment can be re-tuned without touching code.

The config also owns the **clock**.  Router and tuner decisions depend
only on latencies measured through ``config.clock`` — inject a fake
clock and every decision becomes deterministic and unit-testable
(``tests/test_runtime.py`` scripts entire convergence histories this
way).

    cfg = RuntimeConfig(router_warmup=3, batch_shapes=(1, 4, 16))
    eng = dataset.engine("auto", runtime=cfg)

    REPRO_RT_BATCH_SHAPES=1,2,4,8 python -m repro.launch.serve ...
"""

from __future__ import annotations

import os
import time
from typing import Callable, Dict, Tuple

__all__ = ["RuntimeConfig", "runtime_config"]


def _env_str(name: str, default: str) -> str:
    return os.environ.get(name, default)


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def _env_float(name: str, default: float) -> float:
    return float(os.environ.get(name, default))


def _env_bool(name: str, default: bool) -> bool:
    raw = os.environ.get(name)
    if raw is None:
        return default
    return raw.strip().lower() not in ("", "0", "false", "no", "off")


def _env_shapes(name: str, default: Tuple[int, ...]) -> Tuple[int, ...]:
    raw = os.environ.get(name)
    if raw is None:
        return default
    shapes = tuple(int(tok) for tok in raw.replace(",", " ").split())
    if not shapes or min(shapes) < 1:
        raise ValueError(f"{name} must be positive ints, got {raw!r}")
    return tuple(sorted(set(shapes)))


class RuntimeConfig:
    """All adaptive-runtime knobs, with ``REPRO_RT_*`` env overrides.

    Keyword arguments override both the defaults and the environment;
    unknown names raise (typos must not silently become dead knobs).
    """

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 **overrides):
        ######## Backend router ########
        # measured executions per (signature, backend) before the router
        # starts exploiting the observed winner
        self.router_warmup = _env_int("REPRO_RT_WARMUP", 2)
        # after convergence, every Nth request of a signature re-probes a
        # non-winning backend (drift detection for losers that improved;
        # a winner that degrades is caught by its own EWMA)
        self.router_probe_every = _env_int("REPRO_RT_PROBE_EVERY", 32)
        # EWMA smoothing for per-backend latency estimates
        self.router_alpha = _env_float("REPRO_RT_ALPHA", 0.3)
        # first N observations per (signature, backend) are discarded
        # from the EWMA: they carry trace/compile time, not steady-state
        # latency (they still advance the warmup counter)
        self.router_discard = _env_int("REPRO_RT_DISCARD", 1)
        # ring-buffer length of the per-decision log in runtime_report()
        self.router_log_size = _env_int("REPRO_RT_LOG_SIZE", 256)
        # every Nth request of a signature clears its *fallback* exclusions
        # so backends that gained coverage (e.g. after an engine upgrade
        # compiled formerly-fallback operators) are re-tried; ``failed``
        # exclusions (prepare raised) stay permanent.  0 disables.
        self.router_readmit_every = _env_int("REPRO_RT_READMIT_EVERY", 512)

        ######## Query planner ########
        # join-order planner: "greedy" is the paper's Algorithm 4
        # (#bound values, table size); "estimate" enumerates orders by
        # estimated intermediate cardinality (repro.core.estimate) and
        # falls back to greedy on catalogs without distinct-count
        # statistics.  Part of the Engine's plan-cache key, so flipping
        # it mid-session re-plans instead of serving a stale order.
        self.planner = _env_str("REPRO_RT_PLANNER", "greedy")

        ######## Batch-shape tuner ########
        # launches a bucket needs before it can be retired (or retire
        # a rival); compile-discard launches do not count
        self.tuner_min_samples = _env_int("REPRO_RT_TUNER_MIN_SAMPLES", 3)
        # bucket B is retired when its per-slot time exceeds a smaller
        # active bucket's by this factor — batching that measures slower
        # than less batching is pure loss
        self.tuner_margin = _env_float("REPRO_RT_TUNER_MARGIN", 1.1)
        self.tuner_alpha = _env_float("REPRO_RT_TUNER_ALPHA", 0.3)
        # first N launches per bucket shape are compile-heavy; discard
        self.tuner_discard = _env_int("REPRO_RT_TUNER_DISCARD", 1)

        ######## Static analysis ########
        # run the plan/IR verifier (repro.analysis.verifier) over every
        # prepared artifact inside Engine prepare; violations raise
        # PlanVerificationError before anything executes.  Off by default
        # in production (the checks cost a few percent of prepare());
        # tests/conftest.py turns it on for the whole suite.
        self.verify_plans = _env_bool("REPRO_RT_VERIFY_PLANS", False)

        ######## Observability ########
        # fraction of requests that carry a full span trace (repro.obs):
        # 0.0 disables tracing entirely (the engine's guard-first fast
        # path — benchmarks/trace_overhead.py gates it at <=1% overhead),
        # 1.0 traces everything; in between is deterministic stride
        # sampling (1 in round(1/rate) requests)
        self.trace_sample_rate = _env_float("REPRO_RT_TRACE_SAMPLE", 0.0)
        # flight-recorder ring: newest N complete traces kept in memory
        self.trace_ring = _env_int("REPRO_RT_TRACE_RING", 256)
        # traces slower than this end-to-end survive ring eviction in the
        # slow-query reservoir (up to trace_slow_keep, slowest win)
        self.trace_slow_ms = _env_float("REPRO_RT_TRACE_SLOW_MS", 100.0)
        self.trace_slow_keep = _env_int("REPRO_RT_TRACE_SLOW_KEEP", 64)
        # join estimated vs. actual per-step cardinalities onto each
        # traced request's device-launch spans (the explain() drift
        # report as a sampled always-on artifact); cached per
        # (signature, binding), host-computed — disable if even sampled
        # requests must never run host joins
        self.trace_cardinality = _env_bool("REPRO_RT_TRACE_CARDINALITY",
                                           True)

        ######## Micro-batching ########
        # static batch-shape menu (Engine pads buckets up to these); the
        # tuner retires entries it measures as regressions
        self.batch_shapes = _env_shapes("REPRO_RT_BATCH_SHAPES",
                                        (1, 2, 4, 8, 16, 32))
        self.max_batch = _env_int("REPRO_RT_MAX_BATCH", 32)
        self.flush_ms = _env_float("REPRO_RT_FLUSH_MS", 2.0)

        # injectable time source (seconds); every latency the router or
        # tuner ever sees is measured through this
        self.clock = clock

        for name, value in overrides.items():
            if not hasattr(self, name):
                raise ValueError(f"unknown RuntimeConfig knob {name!r}")
            setattr(self, name, value)
        if isinstance(self.batch_shapes, (list, tuple)):
            self.batch_shapes = tuple(sorted(set(int(s)
                                                 for s in self.batch_shapes)))
        if not self.batch_shapes or min(self.batch_shapes) < 1:
            raise ValueError("batch_shapes must be positive ints")
        if not 0.0 <= float(self.trace_sample_rate) <= 1.0:
            raise ValueError(
                f"trace_sample_rate must be in [0, 1], got "
                f"{self.trace_sample_rate!r}")
        if self.planner not in ("greedy", "estimate"):
            raise ValueError(
                f"planner must be 'greedy' or 'estimate', "
                f"got {self.planner!r}")

    def snapshot(self) -> Dict[str, object]:
        """JSON-friendly view of every knob (for ``runtime_report()``)."""
        return {k: (list(v) if isinstance(v, tuple) else v)
                for k, v in vars(self).items() if k != "clock"}


#: Process-wide default instance (alpa's ``global_config`` idiom).
#: Engines constructed without an explicit ``runtime=`` share it.
runtime_config = RuntimeConfig()
