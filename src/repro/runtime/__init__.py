"""Adaptive execution runtime: measured, self-tuning serving decisions.

S2RDF's core idea — pick the cheapest physical access path per query
from statistics — applied to the serving layer itself:

* :class:`RuntimeConfig` centralizes every runtime knob (alpa
  ``GlobalConfig`` idiom) with ``REPRO_RT_*`` env overrides and an
  injectable clock.
* :class:`BackendRouter` routes each template signature to the backend
  (eager / jit / distributed) its own measured latencies favor, with
  warmup, periodic re-probing, and deterministic exclusion of backends
  that failed to prepare or fell back to the host path.
* :class:`BatchTuner` adapts the micro-batch shape menu from observed
  per-slot latency and occupancy, retiring bucket sizes that measure
  slower than smaller ones.

``Engine(dataset, backend="auto")`` (and ``SparqlServer(...,
backend="auto")``, ``repro.launch.serve --backend auto``) wires all
three together; ``engine.runtime_report()`` snapshots every decision.
See docs/serving.md ("Adaptive runtime").
"""

from repro.runtime.config import RuntimeConfig, runtime_config
from repro.runtime.router import BackendRouter, RouteDecision
from repro.runtime.tuner import BatchTuner

__all__ = ["RuntimeConfig", "runtime_config", "BackendRouter",
           "RouteDecision", "BatchTuner"]
