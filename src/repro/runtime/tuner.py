"""Self-tuning micro-batch shape menu.

The micro-batcher pads each bucket of same-template requests up to a
static batch shape so the number of compiled programs per template stays
bounded.  The *menu* of shapes was a hand-picked constant — and the
repo's own numbers prove constants go stale: ``BENCH_serve_throughput
.json`` records batch-32 serving at *lower* qps than batch-8.  A bigger
launch is not automatically a better launch (cap growth, padding, cache
pressure); which sizes win is a property of the machine and workload,
so it must be measured, not assumed.

``BatchTuner`` owns the menu.  Every vectorized launch reports
``(shape, live_requests, wall_ms)``; the tuner keeps per-bucket EWMAs of

* **per-slot time** — ``wall_ms / shape``, the marginal cost of a batch
  slot.  If a larger bucket's per-slot time exceeds a smaller active
  bucket's by ``tuner_margin``, the larger bucket is **retired**: padding
  *up* to it was strictly worse than launching the smaller shape more
  often.  This is how the batch-32 regression is discovered at runtime
  rather than hard-coded away.
* **occupancy / padding waste** — live slots per launch, reported so an
  operator can see which shapes their traffic actually fills.

The first ``tuner_discard`` launches per shape are excluded from the
estimates (they carry XLA trace/compile time), and retirement needs
``tuner_min_samples`` counted launches on both buckets — one noisy
launch never reshapes the menu.  The smallest shape is never retired.
All decisions are deterministic given the observation stream
(``tests/test_runtime.py`` scripts one).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.runtime.config import RuntimeConfig

__all__ = ["BatchTuner"]


@dataclass
class _BucketStat:
    launches: int = 0            # counted launches (post-discard)
    discarded: int = 0           # compile-heavy launches excluded
    per_slot_ms: Optional[float] = None
    occupancy: Optional[float] = None
    live_requests: int = 0
    padded_slots: int = 0


class BatchTuner:
    """Adapt a static batch-shape menu from observed launch latencies."""

    def __init__(self, shapes: Tuple[int, ...], config: RuntimeConfig):
        shapes = tuple(sorted(set(int(s) for s in shapes)))
        if not shapes or shapes[0] < 1:
            raise ValueError("batch shapes must be positive ints")
        self.config = config
        self.shapes: Tuple[int, ...] = shapes
        self._retired: Dict[int, str] = {}
        self._stats: Dict[int, _BucketStat] = {s: _BucketStat()
                                               for s in shapes}

    # -- menu ------------------------------------------------------------------
    def active_shapes(self) -> Tuple[int, ...]:
        return tuple(s for s in self.shapes if s not in self._retired)

    def max_shape(self) -> int:
        return self.active_shapes()[-1]

    def bucket_for(self, n: int) -> int:
        """Smallest active shape holding ``n`` requests (callers chunk
        anything larger than the biggest active shape)."""
        for s in self.active_shapes():
            if s >= n:
                return s
        return self.max_shape()

    # -- observations ----------------------------------------------------------
    def observe(self, shape: int, live: int, wall_ms: float) -> None:
        """One vectorized launch of ``shape`` slots, ``live`` of them
        real requests, measured at ``wall_ms``."""
        st = self._stats.get(shape)
        if st is None:
            st = self._stats[shape] = _BucketStat()
        st.live_requests += live
        st.padded_slots += shape - live
        if st.discarded < self.config.tuner_discard:
            st.discarded += 1       # trace/compile launch; not evidence
            return
        st.launches += 1
        alpha = self.config.tuner_alpha
        per_slot = wall_ms / shape
        occ = live / shape
        st.per_slot_ms = per_slot if st.per_slot_ms is None else \
            (1.0 - alpha) * st.per_slot_ms + alpha * per_slot
        st.occupancy = occ if st.occupancy is None else \
            (1.0 - alpha) * st.occupancy + alpha * occ
        self._maybe_retire()

    def _maybe_retire(self) -> None:
        """Retire any bucket whose per-slot time is beaten by a smaller
        active bucket beyond the margin (both sufficiently sampled)."""
        need = self.config.tuner_min_samples
        margin = self.config.tuner_margin
        active = self.active_shapes()
        for i in range(len(active) - 1, 0, -1):     # never the smallest
            big = active[i]
            bs = self._stats[big]
            if bs.launches < need or bs.per_slot_ms is None:
                continue
            for small in active[:i]:
                ss = self._stats[small]
                if ss.launches < need or ss.per_slot_ms is None:
                    continue
                if bs.per_slot_ms > margin * ss.per_slot_ms:
                    self._retired[big] = (
                        f"per-slot {bs.per_slot_ms:.4f} ms > "
                        f"{margin:.2f}x bucket-{small} "
                        f"({ss.per_slot_ms:.4f} ms)")
                    break

    # -- observability ---------------------------------------------------------
    def report(self) -> Dict[str, object]:
        buckets = {}
        for s in sorted(self._stats):
            st = self._stats[s]
            slots = st.live_requests + st.padded_slots
            buckets[str(s)] = {
                "launches": st.launches,
                "per_slot_ms": None if st.per_slot_ms is None
                else round(st.per_slot_ms, 4),
                "occupancy": None if st.occupancy is None
                else round(st.occupancy, 4),
                "padding_waste": (st.padded_slots / slots) if slots else 0.0,
                "retired": self._retired.get(s),
            }
        return {"menu": list(self.shapes),
                "active": list(self.active_shapes()),
                "retired": {str(s): why for s, why in self._retired.items()},
                "buckets": buckets}
