"""Per-template backend routing from measured latencies.

S2RDF picks the cheapest physical *table* per triple pattern from
statistics (paper §4/§6); this module applies the same discipline one
level up, to the execution substrate itself.  The repo's own benchmarks
show why a static choice is wrong: jit is 0.47× eager on one WatDiv
template and 3.7× on another (``BENCH_modifier_queries.json``) — the
winner is a property of the template, so the router keys on the template
signature.

Lifecycle of a signature:

1. **warmup** — the first ``router_warmup`` measured executions on each
   eligible backend, round-robin (fewest-samples-first, deterministic).
   The first ``router_discard`` samples per backend are excluded from
   the latency estimate: they carry trace/compile time.
2. **measured** — traffic routes to the backend with the lowest latency
   EWMA.  A winner that degrades raises its own EWMA and loses the seat
   on a later request — no special drift machinery needed.
3. **probe** — every ``router_probe_every``-th request re-measures a
   non-winning backend (rotating), so a loser that *improved* can win
   the seat back.  Probes are real requests: the answer is correct
   either way, only its latency differs.
4. **fallback / failed** — a backend whose ``prepare`` raised, or whose
   prepared query silently fell back to the eager host path
   (``PreparedQuery.fallback``), is excluded for that signature and the
   router deterministically re-routes; routing to a device backend that
   would run eager code adds overhead and pollutes the estimates.
   Fallback exclusions are **re-admitted** every
   ``router_readmit_every`` requests: a fallback records a *coverage*
   limit of the prepared program, and coverage grows (the device path
   now compiles OPTIONAL/UNION and unbound predicates that used to bail
   out), so formerly-excluded signatures must become routable again
   without a process restart.  ``failed`` exclusions (prepare raised)
   stay permanent.

Every decision is pure bookkeeping over observed latencies — inject a
clock / scripted latencies and the whole history is reproducible
(``tests/test_runtime.py``).
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Set, Tuple

from repro.runtime.config import RuntimeConfig

__all__ = ["BackendRouter", "RouteDecision"]


@dataclass(frozen=True)
class RouteDecision:
    """One routing choice: where the request goes and why."""

    backend: str
    #: "forced" (single-backend engine), "warmup", "measured", or "probe"
    reason: str


@dataclass
class _SigState:
    """Mutable routing state of one template signature."""

    ewma_ms: Dict[str, float] = field(default_factory=dict)
    samples: Dict[str, int] = field(default_factory=dict)
    failed: Set[str] = field(default_factory=set)     # prepare raised
    fallback: Set[str] = field(default_factory=set)   # prepared eager-fellback
    requests: int = 0
    probes: int = 0
    switches: int = 0
    readmits: int = 0
    choice: Optional[str] = None
    reason: str = "warmup"


class BackendRouter:
    """Route each template signature to its measured-fastest backend.

    ``backends`` is the candidate list in priority order (ties and
    warmup rotation follow it; ``"eager"`` should come first — it is the
    backend that can never fail or fall back).  With a single candidate
    the router degenerates to a pass-through that still answers
    :meth:`peek` / :meth:`report` (so ``Engine.explain`` and
    ``runtime_report`` behave uniformly on static engines).
    """

    def __init__(self, backends: Tuple[str, ...], config: RuntimeConfig):
        if not backends:
            raise ValueError("router needs at least one backend")
        self.backends: Tuple[str, ...] = tuple(backends)
        self.config = config
        self._sigs: Dict[str, _SigState] = {}
        self.log: Deque[Dict[str, object]] = deque(
            maxlen=max(1, config.router_log_size))

    # -- state access ----------------------------------------------------------
    def _state(self, sig: str) -> _SigState:
        st = self._sigs.get(sig)
        if st is None:
            st = self._sigs[sig] = _SigState()
        return st

    def eligible(self, sig: str) -> List[str]:
        st = self._state(sig)
        out = [b for b in self.backends
               if b not in st.failed and b not in st.fallback]
        # every candidate eliminated (a pathological registration order):
        # eager semantics still demand an answer — route to the first
        # candidate anyway rather than deadlock
        return out or [self.backends[0]]

    # -- exclusion -------------------------------------------------------------
    def mark_failed(self, sig: str, backend: str) -> None:
        """``prepare`` raised on this backend for this template: never
        route there again for this signature."""
        self._state(sig).failed.add(backend)

    def mark_fallback(self, sig: str, backend: str) -> None:
        """The backend prepared this template as an eager fallback:
        routing there would measure eager latency under the wrong label."""
        self._state(sig).fallback.add(backend)

    # -- decisions -------------------------------------------------------------
    def _pick(self, sig: str, probe_ok: bool) -> RouteDecision:
        st = self._state(sig)
        elig = self.eligible(sig)
        if len(self.backends) == 1:
            return RouteDecision(self.backends[0], "forced")
        if len(elig) == 1:
            # everything else failed / fell back — deterministic fallback
            return RouteDecision(elig[0], "measured" if st.samples.get(
                elig[0]) else "warmup")
        # each backend owes `discard` compile-heavy executions plus
        # `warmup` counted ones before it can be judged
        warmup = self.config.router_warmup + self.config.router_discard
        pending = [b for b in elig if st.samples.get(b, 0) < warmup]
        if pending:
            # fewest-samples-first keeps the rotation fair and
            # deterministic under serial execution
            b = min(pending, key=lambda b: (st.samples.get(b, 0),
                                            self.backends.index(b)))
            return RouteDecision(b, "warmup")
        winner = min(elig, key=lambda b: (st.ewma_ms.get(b, float("inf")),
                                          self.backends.index(b)))
        if probe_ok:
            others = [b for b in elig if b != winner]
            if others:
                b = others[st.probes % len(others)]
                st.probes += 1
                return RouteDecision(b, "probe")
        return RouteDecision(winner, "measured")

    def decide(self, sig: str, n: int = 1) -> RouteDecision:
        """The routing decision for the next ``n`` same-signature
        requests — a micro-batch group decides ONCE, so a probe measures
        the loser on a realistic batched launch (and per-request router
        overhead stays off the batched fast path).  The request counter
        paces probing: a probe fires whenever it crosses a multiple of
        ``router_probe_every``."""
        st = self._state(sig)
        before = st.requests
        st.requests += n
        every = self.config.router_probe_every
        crossed = every > 0 and (before // every) != (st.requests // every)
        readmit = self.config.router_readmit_every
        if st.fallback and readmit > 0 and \
                (before // readmit) != (st.requests // readmit):
            # periodic coverage re-check: the next prepare of a cleared
            # backend either compiles for real now or marks it fallback
            # again — one extra prepare per window, not per request
            st.fallback.clear()
            st.readmits += 1
        d = self._pick(sig, probe_ok=crossed)
        if d.reason != "probe":
            # a switch is a *measured* change of seat — warmup rotation
            # is exploration, not a decision reversal
            if d.reason == "measured" and st.reason == "measured" and \
                    st.choice is not None and d.backend != st.choice:
                st.switches += 1
            st.choice = d.backend
            st.reason = d.reason
        return d

    def peek(self, sig: str) -> RouteDecision:
        """What :meth:`decide` would choose, without consuming a request
        (used by ``Engine.explain``)."""
        return self._pick(sig, probe_ok=False)

    # -- observations ----------------------------------------------------------
    def observe(self, sig: str, backend: str, latency_ms: float,
                reason: str = "measured", weight: int = 1) -> None:
        """Record one measured execution.  ``weight`` counts the requests
        the measurement covered (a micro-batch launch observes its
        per-request latency once, weighted by the batch)."""
        st = self._state(sig)
        n = st.samples.get(backend, 0)
        st.samples[backend] = n + 1
        self.log.append({"t": self.config.clock(), "sig": sig,
                         "backend": backend, "reason": reason,
                         "ms": latency_ms, "weight": weight})
        if n < self.config.router_discard:
            return                      # compile-heavy first sample(s)
        prev = st.ewma_ms.get(backend)
        alpha = self.config.router_alpha
        st.ewma_ms[backend] = latency_ms if prev is None else \
            (1.0 - alpha) * prev + alpha * latency_ms

    # -- observability ---------------------------------------------------------
    def estimates(self, sig: str) -> Dict[str, float]:
        """Per-backend latency EWMAs for one signature — what a routing
        decision was judged against (the trace stream attaches these to
        every ``router.decide`` event, so ``tools/trace_inspect.py`` can
        answer "why eager?" from the trace alone)."""
        st = self._sigs.get(sig)
        if st is None:
            return {}
        return {b: round(v, 4) for b, v in st.ewma_ms.items()}

    def routed_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for entry in self.log:
            b = entry["backend"]  # type: ignore[assignment]
            out[b] = out.get(b, 0) + int(entry["weight"])  # type: ignore
        return out

    def report(self) -> Dict[str, object]:
        """JSON-friendly snapshot: per-signature estimates, choices and
        exclusions, plus the tail of the decision log."""
        sigs = {}
        for sig, st in self._sigs.items():
            sigs[sig] = {
                "choice": st.choice,
                "reason": st.reason,
                "requests": st.requests,
                "probes": st.probes,
                "switches": st.switches,
                "readmits": st.readmits,
                "ewma_ms": {b: round(v, 4) for b, v in st.ewma_ms.items()},
                "samples": dict(st.samples),
                "failed": sorted(st.failed),
                "fallback": sorted(st.fallback),
            }
        return {"backends": list(self.backends), "signatures": sigs,
                "decisions": list(self.log)}
