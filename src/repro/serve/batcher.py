"""Micro-batching front end: same-template requests share one launch.

Template-level work (parsing, Algorithm-1/4 compilation, XLA tracing) is
already amortized by the plan cache; this module amortizes the *launch*:
requests are enqueued with :meth:`MicroBatcher.submit`, grouped by
template signature into size/latency-bounded buckets, stacked into one
batched program execution (:meth:`repro.engine.Engine.query_batch`), and
demultiplexed back into per-request :class:`~repro.engine.Result`s.

The batcher is synchronous and single-threaded — the serving analogue of
an event-loop tick.  A bucket drains when it reaches ``max_batch``, when
the oldest queued request has waited longer than ``flush_ms`` (checked on
every ``submit``), or when a caller forces it (``flush()`` /
``PendingQuery.result()``).  Inside the engine each bucket is padded up
to a static batch shape so the number of compiled programs per template
stays bounded (see ``Engine.batch_shapes`` and docs/serving.md).
"""

from __future__ import annotations

import time
from collections import OrderedDict
from typing import Dict, List, Optional

from repro.engine import Engine, Result, template_signature

__all__ = ["MicroBatcher", "PendingQuery"]

_UNSET = object()


class PendingQuery:
    """Handle for one submitted request; resolves when its bucket drains."""

    def __init__(self, batcher: "MicroBatcher", qtext: str, sig: str):
        self.qtext = qtext
        self.signature = sig
        self._batcher = batcher
        self._result = _UNSET
        self._error: Optional[BaseException] = None
        self.submitted_at = time.perf_counter()
        # sampled requests carry their TraceContext from submit onward,
        # so the queue wait is part of the trace (None when unsampled)
        self.trace = None
        self._queue_sid: Optional[int] = None

    def done(self) -> bool:
        return self._result is not _UNSET or self._error is not None

    def result(self) -> Result:
        """The request's Result, draining its bucket if still queued.
        Re-raises the execution error if the request's batch failed."""
        if not self.done():
            self._batcher.flush_group(self.signature)
        assert self.done(), "flush did not resolve this request"
        if self._error is not None:
            raise self._error
        return self._result  # type: ignore[return-value]


class MicroBatcher:
    """Queue + bucketizer in front of an :class:`~repro.engine.Engine`.

    ``max_batch`` bounds bucket size (larger buckets are chunked by the
    engine anyway); ``flush_ms`` bounds the queueing latency a request
    can pay waiting for batch-mates.
    """

    def __init__(self, engine: Engine, max_batch: int = 32,
                 flush_ms: float = 2.0):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.engine = engine
        self.max_batch = int(max_batch)
        self.flush_ms = float(flush_ms)
        self._queues: "OrderedDict[str, List[PendingQuery]]" = OrderedDict()

    # -- queue state -----------------------------------------------------------
    def effective_max_batch(self) -> int:
        """The live bucket bound: ``max_batch`` capped by the largest
        batch shape the engine's tuner still considers worth launching —
        once a shape is retired as a measured regression, letting buckets
        fill to it would only split into smaller chunks anyway, while the
        earlier requests waited for nothing."""
        limit = getattr(self.engine, "max_active_batch", None)
        return min(self.max_batch, limit()) if limit is not None \
            else self.max_batch

    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _flush_expired(self) -> None:
        """Drain only the buckets whose OLDEST request has waited past
        ``flush_ms`` — fresh buckets keep filling (draining everything on
        one stale signature would collapse batch occupancy).  Errors stay
        on the affected tickets (``result()`` re-raises)."""
        now = time.perf_counter()
        for sig in list(self._queues):
            group = self._queues.get(sig)
            if group and (now - group[0].submitted_at) * 1e3 >= self.flush_ms:
                try:
                    self.flush_group(sig)
                except Exception:
                    pass

    # -- submission ------------------------------------------------------------
    def submit(self, qtext: str) -> PendingQuery:
        """Enqueue one request; returns a handle that resolves when the
        request's bucket drains (size bound, latency bound, or explicit
        flush)."""
        sig = template_signature(qtext)
        ticket = PendingQuery(self, qtext, sig)
        tr = getattr(self.engine, "tracer", None)
        if tr is not None and tr.active:
            ticket.trace = tr.begin(qtext, sig=sig)
            if ticket.trace is not None:
                ticket._queue_sid = ticket.trace.start("queue")
        self._queues.setdefault(sig, []).append(ticket)
        # Auto-flushes swallow execution errors: the caller of THIS submit
        # must still receive its ticket; every failed request's ticket
        # carries the error and result() re-raises it.
        if len(self._queues[sig]) >= self.effective_max_batch():
            try:
                self.flush_group(sig)
            except Exception:
                pass
        # latency bound is checked regardless of the size-bound branch: a
        # hot template's full buckets must not starve another template's
        # lone queued request past its deadline
        self._flush_expired()
        return ticket

    # -- draining --------------------------------------------------------------
    def flush_group(self, sig: str) -> int:
        """Drain one signature's bucket through a batched execution.  On
        an execution error every ticket of the bucket carries the error
        (``result()`` re-raises it) and the error propagates to the
        flusher — tickets are never silently dropped."""
        group = self._queues.pop(sig, [])
        if not group:
            return 0
        for ticket in group:
            if ticket.trace is not None and ticket._queue_sid is not None:
                ticket.trace.end(ticket._queue_sid, batch=len(group))
        # the traces kwarg is only passed when something was actually
        # sampled — stubbed/custom query_batch implementations without
        # the parameter keep working on the untraced path
        kwargs = {}
        if any(t.trace is not None for t in group):
            kwargs["traces"] = [t.trace for t in group]
        try:
            results = self.engine.query_batch(
                [t.qtext for t in group], **kwargs)
        except BaseException as exc:
            for ticket in group:
                ticket._error = exc
            raise
        now = time.perf_counter()
        for ticket, res in zip(group, results):
            ticket._result = res
            self.engine.metrics.record_queue(
                (now - ticket.submitted_at) * 1e3)
        return len(group)

    def flush(self) -> int:
        """Drain every bucket; returns the number of requests served.  A
        failing bucket does not abort the rest — every bucket drains, its
        tickets carrying any error, and the first error re-raises at the
        end."""
        n = 0
        first_exc: Optional[BaseException] = None
        for sig in list(self._queues):
            try:
                n += self.flush_group(sig)
            except Exception as exc:
                if first_exc is None:
                    first_exc = exc
        if first_exc is not None:
            raise first_exc
        return n
