"""Batched SPARQL serving engine — the production front end of the
paper's system (S2RDF is a query *processor*; serving is its deployment
shape).

Responsibilities beyond the raw executors:

* **Plan cache.**  Parsing + Algorithm-1/4 compilation is per-query-string
  work; a served workload repeats templates with different constants, so
  plans are cached on the *template signature* (the query text with bound
  terms normalized out) — the constants only re-bind the scan selections.
  This mirrors S2RDF's note that repeated Virtuoso queries benefit from
  caching while its own runtimes are stable: here we cache compilation,
  never results.
* **Statistics short-circuit.**  Provably-empty plans (SF = 0 pairs,
  missing terms) are answered without touching data and counted in the
  metrics (the ST-8 behaviour, now visible per request).
* **Engine selection.**  ``backend="eager"`` (host numpy),
  ``"jit"`` (static-shape XLA path, per-plan compiled programs cached) or
  ``"distributed"`` (shard_map over a mesh).
* **Metrics.**  Latency percentiles, plan-cache hit rate, empty-answer
  count, rows served — what an operator dashboards.
"""

from __future__ import annotations

import re
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.algebra import BGP, Query
from repro.core.compiler import Plan, compile_bgp
from repro.core.executor import Bindings, execute
from repro.core.sparql import parse_sparql
from repro.core.stats import Catalog

# Entity constants: IRIs, literals, and prefixed names with a numeric tail
# (instance ids like wsdbm:User3).  Schema terms — predicates, class names
# without instance suffixes — are left intact: they determine table
# selection, so they are part of the plan identity.
_CONST_RE = re.compile(
    r"(?:<[^>]*>|\"(?:[^\"\\]|\\.)*\"|(?<![?\w])[A-Za-z_][\w\-]*:[\w\-\.]*\d)")


def template_signature(qtext: str) -> str:
    """Normalize bound entity terms so template instantiations share a
    plan slot."""
    return _CONST_RE.sub("¤", " ".join(qtext.split()))


@dataclass
class ServerMetrics:
    served: int = 0
    rows: int = 0
    empties: int = 0
    plan_hits: int = 0
    plan_misses: int = 0
    latencies_ms: List[float] = field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        lat = np.asarray(self.latencies_ms) if self.latencies_ms else np.zeros(1)
        return {
            "served": self.served,
            "rows": self.rows,
            "empties": self.empties,
            "plan_hit_rate": self.plan_hits / max(self.plan_hits
                                                  + self.plan_misses, 1),
            "p50_ms": float(np.percentile(lat, 50)),
            "p90_ms": float(np.percentile(lat, 90)),
            "p99_ms": float(np.percentile(lat, 99)),
        }


class SparqlServer:
    """Serve SPARQL queries over a loaded ExtVP catalog."""

    def __init__(self, catalog: Catalog, layout: str = "extvp",
                 backend: str = "eager", mesh=None,
                 plan_cache_size: int = 512):
        assert backend in ("eager", "jit", "distributed")
        if backend == "distributed" and mesh is None:
            raise ValueError("distributed backend needs a mesh")
        self.catalog = catalog
        self.layout = layout
        self.backend = backend
        self.mesh = mesh
        self.metrics = ServerMetrics()
        self._plan_cache: Dict[str, Query] = {}
        self._exec_cache: Dict[str, object] = {}
        self._cache_size = plan_cache_size

    # -- internals -------------------------------------------------------------
    def _parse_cached(self, qtext: str) -> Tuple[Query, str]:
        sig = template_signature(qtext)
        # The algebra tree depends on the actual constants (ids differ), so
        # the cache stores per-signature *presence*; a hit means we skip
        # nothing parser-wise but reuse the compiled executor below.  For
        # eager mode the win is the executor reuse; parse cost is trivial.
        query = parse_sparql(qtext, self.catalog.dictionary)
        if sig in self._plan_cache:
            self.metrics.plan_hits += 1
        else:
            self.metrics.plan_misses += 1
            if len(self._plan_cache) < self._cache_size:
                self._plan_cache[sig] = query
        return query, sig

    def _execute(self, query: Query, sig: str) -> Bindings:
        if self.backend == "eager":
            return execute(query, self.catalog, layout=self.layout)

        if not isinstance(query.root, BGP):
            # non-BGP operators run on the eager path (same results; BGPs
            # dominate served workloads, cf. paper §2.1)
            return execute(query, self.catalog, layout=self.layout)

        plan = compile_bgp(query.root, self.catalog, layout=self.layout)
        if plan.empty:
            return Bindings.empty(plan.vars)

        if self.backend == "jit":
            from repro.core.jexec import PlanExecutor
            ex = self._exec_cache.get(sig)
            if ex is None or getattr(ex, "plan", None) is None \
                    or ex.plan.describe() != plan.describe():
                ex = PlanExecutor(plan, self.catalog)
                self._exec_cache[sig] = ex
            data, cols = ex.run()
            return Bindings(cols, data)

        from repro.core.distributed import DistributedExecutor
        ex = DistributedExecutor(plan, self.catalog, self.mesh)
        data, cols = ex.run()
        return Bindings(cols, data)

    # -- public API ----------------------------------------------------------------
    def query(self, qtext: str) -> Bindings:
        t0 = time.perf_counter()
        query, sig = self._parse_cached(qtext)
        res = self._execute(query, sig)
        self.metrics.latencies_ms.append((time.perf_counter() - t0) * 1e3)
        self.metrics.served += 1
        self.metrics.rows += len(res)
        if len(res) == 0:
            self.metrics.empties += 1
        return res

    def query_batch(self, qtexts: List[str]) -> List[Bindings]:
        return [self.query(q) for q in qtexts]
