"""Batched SPARQL serving front end — now a thin shell over the unified
:mod:`repro.engine` facade.

Everything this module used to hand-roll (template-signature plan cache,
per-backend executor wiring, statistics short-circuit accounting, metrics)
lives in :class:`repro.engine.Engine`; ``SparqlServer`` remains as the
stable serving-layer entry point:

* **Plan cache.**  Parsing + Algorithm-1/4 compilation is per-template
  work; a served workload repeats templates with different constants, so
  prepared queries are cached in a bounded LRU on the template signature
  and the constants re-bind as runtime values — no re-parse, no
  re-compile (we cache compilation, never results).
* **Statistics short-circuit.**  Provably-empty plans are answered
  without touching data and counted in the metrics.
* **Engine selection.**  Any registered ExecutionBackend: ``"eager"``
  (host numpy), ``"jit"`` (static-shape XLA programs) or
  ``"distributed"`` (shard_map over a mesh) out of the box.
* **Metrics.**  Latency percentiles, plan-cache hit rate, empty-answer
  count, rows served — what an operator dashboards.
"""

from __future__ import annotations

from typing import List

from repro.core.stats import Catalog
from repro.engine import (
    Dataset, Engine, Result, ServerMetrics, available_backends,
    template_signature,
)

__all__ = ["SparqlServer", "ServerMetrics", "template_signature"]


class SparqlServer:
    """Serve SPARQL queries over a loaded ExtVP catalog.

    A facade over ``Dataset.engine(backend)``; kept for serving-layer
    ergonomics and backwards compatibility.
    """

    def __init__(self, catalog: Catalog, layout: str = "extvp",
                 backend: str = "eager", mesh=None,
                 plan_cache_size: int = 512):
        if backend not in available_backends():
            raise ValueError(
                f"unknown backend {backend!r}; available: {available_backends()}")
        self.dataset = Dataset(catalog=catalog, dictionary=catalog.dictionary)
        self.engine: Engine = self.dataset.engine(
            backend, layout=layout, mesh=mesh,
            plan_cache_size=plan_cache_size)
        self.catalog = catalog
        self.layout = layout
        self.backend = backend
        self.mesh = mesh

    @property
    def metrics(self) -> ServerMetrics:
        return self.engine.metrics

    # Back-compat views of the (now unified, bounded) prepared-query LRU.
    @property
    def _plan_cache(self):
        return self.engine.cache

    @property
    def _exec_cache(self):
        return self.engine.cache

    # -- public API ----------------------------------------------------------------
    def query(self, qtext: str) -> Result:
        return self.engine.query(qtext)

    def query_batch(self, qtexts: List[str]) -> List[Result]:
        return self.engine.query_batch(qtexts)
