"""Batched SPARQL serving front end — a thin shell over the unified
:mod:`repro.engine` facade plus a micro-batching request queue.

Everything this module used to hand-roll (template-signature plan cache,
per-backend executor wiring, statistics short-circuit accounting, metrics)
lives in :class:`repro.engine.Engine`; ``SparqlServer`` remains as the
stable serving-layer entry point:

* **Plan cache.**  Parsing + Algorithm-1/4 compilation is per-template
  work; a served workload repeats templates with different constants, so
  prepared queries are cached in a bounded LRU on the template signature
  and the constants re-bind as runtime values — no re-parse, no
  re-compile (we cache compilation, never results).
* **Micro-batching.**  ``submit()`` enqueues a request; a
  :class:`~repro.serve.batcher.MicroBatcher` groups same-template
  requests into size/latency-bounded buckets, executes each bucket as
  ONE batched program launch (constants stacked into a leading batch
  axis), and demuxes per-request results.  ``query()`` stays the
  immediate single-request path.
* **Statistics short-circuit.**  Provably-empty plans are answered
  without touching data and counted in the metrics.
* **Engine selection.**  Any registered ExecutionBackend: ``"eager"``
  (host numpy), ``"jit"`` (static-shape XLA programs) or
  ``"distributed"`` (shard_map over a mesh) out of the box — or
  ``"auto"``, which routes each template to the backend its measured
  latencies favor (:mod:`repro.runtime`; ``runtime_report()`` shows
  every decision).
* **Metrics.**  Latency percentiles, plan-cache hit rate, empty-answer
  count, rows served, batch occupancy / padding waste / queue latency —
  what an operator dashboards (definitions in docs/serving.md).
"""

from __future__ import annotations

import os
from typing import List, Optional, Sequence, Union

from repro.core.stats import Catalog
from repro.engine import (
    Dataset, Engine, Result, ServerMetrics, available_backends,
    template_signature,
)
from repro.serve.batcher import MicroBatcher, PendingQuery

__all__ = ["SparqlServer", "ServerMetrics", "MicroBatcher", "PendingQuery",
           "template_signature"]


class SparqlServer:
    """Serve SPARQL queries over a loaded ExtVP catalog.

    A facade over ``Dataset.engine(backend)`` with a micro-batching
    queue in front; kept for serving-layer ergonomics and backwards
    compatibility.  Batching knobs (``max_batch``, ``flush_ms``,
    ``batch_shapes``) are documented in docs/serving.md.

    ``catalog`` may also be a **store path** (str / PathLike): the
    server then boots from the persistent columnar store via
    ``Dataset.load`` — lazy, memory-mapped, and without ever touching
    the build pipeline (cold-start knobs ``eager_load`` /
    ``verify_store`` are documented in docs/serving.md).
    """

    def __init__(self, catalog: Union[Catalog, str, os.PathLike],
                 layout: str = "extvp",
                 backend: str = "eager", mesh=None,
                 plan_cache_size: int = 512,
                 max_batch: Optional[int] = None,
                 flush_ms: Optional[float] = None,
                 batch_shapes: Optional[Sequence[int]] = None,
                 eager_load: bool = False, verify_store: bool = False,
                 runtime=None):
        # "auto" is the adaptive runtime, not a registry key: the engine
        # routes each template to the measured-fastest registered backend
        if backend != "auto" and backend not in available_backends():
            raise ValueError(
                f"unknown backend {backend!r}; available: "
                f"{available_backends()} (or 'auto')")
        # Engine.__init__ (reached below) fails fast on backend="distributed"
        # with mesh=None — a server booted without a mesh must raise here at
        # construction, never accept traffic and error per-request.
        if isinstance(catalog, (str, os.PathLike)):
            self.dataset = Dataset.load(catalog, eager=eager_load,
                                        verify=verify_store, mesh=mesh)
            catalog = self.dataset.catalog
        else:
            self.dataset = Dataset(catalog=catalog,
                                   dictionary=catalog.dictionary)
        self.engine: Engine = self.dataset.engine(
            backend, layout=layout, mesh=mesh,
            plan_cache_size=plan_cache_size, batch_shapes=batch_shapes,
            runtime=runtime)
        cfg = self.engine.config
        self.batcher = MicroBatcher(
            self.engine,
            max_batch=cfg.max_batch if max_batch is None else max_batch,
            flush_ms=cfg.flush_ms if flush_ms is None else flush_ms)
        self.catalog = catalog
        self.layout = layout
        self.backend = backend
        self.mesh = mesh

    @property
    def metrics(self) -> ServerMetrics:
        return self.engine.metrics

    def runtime_report(self):
        """Snapshot of the adaptive runtime: per-template routing state,
        batch-shape menu and per-bucket stats, knob values, and the
        serving metrics (see docs/serving.md, "Adaptive runtime")."""
        return self.engine.runtime_report()

    # Back-compat views of the (now unified, bounded) prepared-query LRU.
    @property
    def _plan_cache(self):
        return self.engine.cache

    @property
    def _exec_cache(self):
        return self.engine.cache

    # -- public API ----------------------------------------------------------------
    def query(self, qtext: str) -> Result:
        """Immediate single-request execution (no queueing)."""
        return self.engine.query(qtext)

    def submit(self, qtext: str) -> PendingQuery:
        """Enqueue a request for micro-batched execution; resolve the
        returned handle with ``.result()`` (forces its bucket) or drain
        everything with :meth:`flush`."""
        return self.batcher.submit(qtext)

    def flush(self) -> int:
        """Drain all queued requests; returns how many were served."""
        return self.batcher.flush()

    def query_batch(self, qtexts: List[str]) -> List[Result]:
        """Serve a request list through the micro-batcher: same-template
        requests share one program launch; results in submission order."""
        tickets = [self.batcher.submit(q) for q in qtexts]
        self.batcher.flush()
        return [t.result() for t in tickets]
