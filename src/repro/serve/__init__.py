"""Serving runtime: the batched SPARQL query server (the paper's kind)."""

from repro.serve.engine import ServerMetrics, SparqlServer

__all__ = ["SparqlServer", "ServerMetrics"]
