"""Serving runtime: the micro-batched SPARQL query server."""

from repro.serve.batcher import MicroBatcher, PendingQuery
from repro.serve.engine import ServerMetrics, SparqlServer

__all__ = ["SparqlServer", "ServerMetrics", "MicroBatcher", "PendingQuery"]
