"""Flight recorder: the last N traces + the slow-query reservoir.

A serving process cannot keep every trace, but the two populations an
operator actually asks for are bounded:

* the **ring** — the newest ``trace_ring`` complete traces, whatever
  their latency (the "what is the system doing right now" view);
* the **slow reservoir** — traces whose end-to-end latency exceeded
  ``trace_slow_ms`` are kept *out* of the ring's eviction, up to
  ``trace_slow_keep`` of them (slowest win).  A burst of fast traffic
  must never flush the one trace that explains a tail-latency page.

Export formats:

* :meth:`FlightRecorder.chrome_trace` — the Chrome ``chrome://tracing``
  / Perfetto JSON object format (``ph: "X"`` complete events, µs
  timestamps, one ``tid`` per trace), loadable directly in the browser;
* :meth:`FlightRecorder.to_jsonl` — one self-contained JSON object per
  trace (machine-diffable; ``tools/trace_inspect.py``'s native input).
"""

from __future__ import annotations

import json
from collections import deque
from typing import Any, Dict, List, Optional

__all__ = ["FlightRecorder"]


class FlightRecorder:
    """Bounded store of finished :class:`~repro.obs.tracer.TraceContext`s."""

    def __init__(self, ring: int = 256, slow_ms: float = 100.0,
                 slow_keep: int = 64):
        self.ring_size = max(1, int(ring))
        self.slow_ms = float(slow_ms)
        self.slow_keep = max(0, int(slow_keep))
        self._ring: "deque" = deque(maxlen=self.ring_size)
        self._slow: List[Any] = []      # kept sorted fastest-first
        self.dropped = 0                # ring evictions (not slow-kept)

    def add(self, ctx) -> None:
        if len(self._ring) == self._ring.maxlen:
            self.dropped += 1
        self._ring.append(ctx)
        dur = ctx.duration_ms
        if self.slow_keep and dur is not None and dur > self.slow_ms:
            self._slow.append(ctx)
            self._slow.sort(key=lambda c: c.duration_ms or 0.0)
            if len(self._slow) > self.slow_keep:
                self._slow.pop(0)       # evict the fastest slow trace

    def traces(self) -> List[Any]:
        """Ring ∪ slow reservoir, deduped, oldest first."""
        seen = set()
        out = []
        for ctx in list(self._slow) + list(self._ring):
            if ctx.trace_id not in seen:
                seen.add(ctx.trace_id)
                out.append(ctx)
        out.sort(key=lambda c: c.spans[0].t0)
        return out

    def __len__(self) -> int:
        return len(self.traces())

    # -- export ----------------------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        """Chrome trace-event JSON: every span a complete (``ph: "X"``)
        event in microseconds, every trace its own ``tid`` so requests
        stack as separate rows; span events ride along as instants."""
        events: List[Dict[str, Any]] = []
        for ctx in self.traces():
            tid = ctx.trace_id
            for span in ctx.spans:
                if span.t1 is None:
                    continue
                events.append({
                    "name": span.name, "ph": "X", "pid": 0, "tid": tid,
                    "ts": span.t0 * 1e6,
                    "dur": (span.t1 - span.t0) * 1e6,
                    "args": _jsonable(span.attrs),
                })
                for ev in span.events:
                    events.append({
                        "name": ev["name"], "ph": "i", "s": "t",
                        "pid": 0, "tid": tid, "ts": ev["t"] * 1e6,
                        "args": _jsonable(ev["attrs"]),
                    })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.chrome_trace())

    def trace_dicts(self) -> List[Dict[str, Any]]:
        """One nested dict per trace (the JSONL row shape)."""
        out = []
        for ctx in self.traces():
            out.append({
                "trace_id": ctx.trace_id,
                "duration_ms": ctx.duration_ms,
                "slow": (ctx.duration_ms or 0.0) > self.slow_ms,
                "spans": [{
                    "sid": s.sid, "name": s.name, "parent": s.parent,
                    "t0": s.t0, "t1": s.t1,
                    "duration_ms": s.duration_ms,
                    "attrs": _jsonable(s.attrs),
                    "events": [{"name": e["name"], "t": e["t"],
                                "attrs": _jsonable(e["attrs"])}
                               for e in s.events],
                } for s in ctx.spans],
            })
        return out

    def to_jsonl(self) -> str:
        return "".join(json.dumps(t) + "\n" for t in self.trace_dicts())


def _jsonable(attrs: Dict[str, Any]) -> Dict[str, Any]:
    """Attrs must survive json.dumps whatever callers attached (numpy
    scalars, tuples); degrade unknowns to repr instead of raising."""
    out: Dict[str, Any] = {}
    for k, v in attrs.items():
        if isinstance(v, (str, bool)) or v is None:
            out[k] = v
        elif isinstance(v, (int, float)):
            out[k] = v
        elif isinstance(v, (list, tuple)):
            out[k] = [_jsonable({"v": x})["v"] for x in v]
        elif isinstance(v, dict):
            out[k] = _jsonable(v)
        elif hasattr(v, "item"):        # numpy scalar
            out[k] = v.item()
        else:
            out[k] = repr(v)
    return out
