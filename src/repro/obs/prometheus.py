"""Prometheus text-exposition rendering of the serving metrics.

Renders a :class:`~repro.engine.engine.ServerMetrics` (duck-typed — this
module must not import the engine, the engine imports *it*) into the
Prometheus `text exposition format`: counters for every request-path
count, native histograms for request/queue latency from the
:class:`~repro.obs.histogram.LogHistogram`s, per-stage span histograms
from the tracer's aggregates, and router/tuner state as labelled gauges.

Metric names (all documented in docs/observability.md):

* ``repro_served_total``, ``repro_rows_total``, ``repro_empties_total``,
  ``repro_short_circuits_total``, ``repro_device_fallbacks_total``,
  ``repro_plan_hits_total``, ``repro_plan_misses_total``,
  ``repro_batches_total``, ``repro_batched_requests_total``,
  ``repro_padding_slots_total``
* ``repro_routed_total{backend=...}``
* ``repro_request_latency_ms`` / ``repro_queue_ms`` (histograms)
* ``repro_stage_ms{stage=...}`` (histogram per span name)
* ``repro_traces_total{state=started|finished|sampled_out}``
* ``repro_router_ewma_ms{sig=...,backend=...}``,
  ``repro_router_requests{sig=...}``
* ``repro_tuner_per_slot_ms{shape=...}``,
  ``repro_tuner_occupancy{shape=...}``,
  ``repro_tuner_shape_active{shape=...}``
"""

from __future__ import annotations

from typing import Dict, List, Optional

from repro.obs.histogram import LogHistogram

__all__ = ["render"]


def _esc(v: object) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"') \
        .replace("\n", r"\n")


def _labels(kv: Dict[str, object]) -> str:
    if not kv:
        return ""
    inner = ",".join(f'{k}="{_esc(v)}"' for k, v in kv.items())
    return "{" + inner + "}"


def _counter(lines: List[str], name: str, value, help_: str,
             label_values: Optional[Dict[str, Dict[str, object]]] = None,
             kind: str = "counter") -> None:
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} {kind}")
    if label_values is None:
        lines.append(f"{name} {value}")
    else:
        for labels, v in label_values.items():
            lines.append(f"{name}{labels} {v}")


def _histogram(lines: List[str], name: str, hist: LogHistogram,
               help_: str, labels: Optional[Dict[str, object]] = None
               ) -> None:
    labels = labels or {}
    lines.append(f"# HELP {name} {help_}")
    lines.append(f"# TYPE {name} histogram")
    for edge, cum in hist.cumulative_buckets():
        le = "+Inf" if edge == float("inf") else f"{edge:.6g}"
        lines.append(f"{name}_bucket{_labels({**labels, 'le': le})} {cum}")
    lines.append(f"{name}_bucket{_labels({**labels, 'le': '+Inf'})} "
                 f"{hist.count}")
    lines.append(f"{name}_sum{_labels(labels)} {hist.sum_ms:.6g}")
    lines.append(f"{name}_count{_labels(labels)} {hist.count}")


def render(metrics) -> str:
    """The full exposition page for one engine's ``ServerMetrics``."""
    lines: List[str] = []
    for attr, help_ in (
            ("served", "requests answered"),
            ("rows", "result rows returned"),
            ("empties", "zero-row answers"),
            ("short_circuits", "answers from statistics alone"),
            ("device_fallbacks", "requests served via eager fallback"),
            ("plan_hits", "plan-cache hits"),
            ("plan_misses", "plan-cache misses"),
            ("batches", "batched device launches"),
            ("batched_requests", "requests served through a batch"),
            ("padding_slots", "batch slots wasted on padding")):
        _counter(lines, f"repro_{attr}_total", getattr(metrics, attr),
                 help_)
    routed = getattr(metrics, "routed", {}) or {}
    if routed:
        _counter(lines, "repro_routed_total", None,
                 "requests per executing backend",
                 {_labels({"backend": b}): n
                  for b, n in sorted(routed.items())})
    _histogram(lines, "repro_request_latency_ms", metrics.latency_hist,
               "end-to-end request latency (ms)")
    _histogram(lines, "repro_queue_ms", metrics.queue_hist,
               "micro-batch queue wait, submit to result (ms)")

    tracer = getattr(metrics, "tracer", None)
    if tracer is not None:
        _counter(lines, "repro_traces_total", None,
                 "trace lifecycle counts",
                 {_labels({"state": s}): getattr(tracer, s)
                  for s in ("started", "finished", "sampled_out")})
        for stage in sorted(tracer.stage_hist):
            _histogram(lines, "repro_stage_ms", tracer.stage_hist[stage],
                       "per-stage span duration (ms)", {"stage": stage})

    report = metrics.runtime_report()
    router = report.get("router") if isinstance(report, dict) else None
    if router:
        ewma_rows: Dict[str, object] = {}
        req_rows: Dict[str, object] = {}
        for sig, st in router.get("signatures", {}).items():
            req_rows[_labels({"sig": sig})] = st.get("requests", 0)
            for backend, ms in st.get("ewma_ms", {}).items():
                ewma_rows[_labels({"sig": sig, "backend": backend})] = ms
        if req_rows:
            _counter(lines, "repro_router_requests", None,
                     "requests routed per template signature", req_rows)
        if ewma_rows:
            _counter(lines, "repro_router_ewma_ms", None,
                     "router latency estimate per (signature, backend)",
                     ewma_rows, kind="gauge")
    tuner = report.get("tuner") if isinstance(report, dict) else None
    if tuner:
        active = set(tuner.get("active", []))
        slot_rows: Dict[str, object] = {}
        occ_rows: Dict[str, object] = {}
        act_rows: Dict[str, object] = {}
        for shape, st in tuner.get("buckets", {}).items():
            act_rows[_labels({"shape": shape})] = \
                int(int(shape) in active)
            if st.get("per_slot_ms") is not None:
                slot_rows[_labels({"shape": shape})] = st["per_slot_ms"]
            if st.get("occupancy") is not None:
                occ_rows[_labels({"shape": shape})] = st["occupancy"]
        if act_rows:
            _counter(lines, "repro_tuner_shape_active", None,
                     "1 when the batch shape is still in the menu",
                     act_rows, kind="gauge")
        if slot_rows:
            _counter(lines, "repro_tuner_per_slot_ms", None,
                     "EWMA per-slot launch time per batch shape",
                     slot_rows, kind="gauge")
        if occ_rows:
            _counter(lines, "repro_tuner_occupancy", None,
                     "EWMA live-slot fraction per batch shape",
                     occ_rows, kind="gauge")
    return "\n".join(lines) + "\n"
