"""Fixed-memory streaming latency histograms.

``ServerMetrics`` used to keep bounded *sample lists* and compute
percentiles with ``np.percentile`` — O(window) memory per metric, a
truncation cliff at ``_MAX_SAMPLES``, and no way to merge two engines'
metrics without concatenating raw samples.  :class:`LogHistogram` is the
replacement: geometric (log-spaced) buckets with exact counts.

* **O(1) memory, O(1) record** — a fixed bucket array (``~110`` int
  slots spanning 1 µs … ~134 s) plus under/overflow slots; recording is
  one ``log2`` and one increment, with no truncation ever.
* **Bounded percentile error** — every sample lands in a bucket whose
  upper edge is at most ``GROWTH`` (2^0.25 ≈ 1.19×) above it, so any
  reported percentile is within +19% of the exact order statistic
  (asserted against exact samples in ``tests/test_obs.py``).
* **Mergeable** — two histograms with the same bucket layout add
  bucket-wise, so per-engine / per-process metrics aggregate exactly.
* **Prometheus-ready** — :meth:`cumulative_buckets` is precisely the
  ``le``-labelled cumulative form the text exposition format wants.

All values are **milliseconds** (the unit every latency in this repo is
measured in).
"""

from __future__ import annotations

import math
from typing import Dict, Iterator, List, Optional, Tuple

__all__ = ["LogHistogram", "GROWTH", "LO_MS", "N_BUCKETS"]

#: geometric growth factor per bucket: 2^0.25 ≈ 1.189 — the relative
#: percentile error bound (a sample's bucket upper edge is < GROWTH× it)
GROWTH = 2.0 ** 0.25
#: lower edge of the first real bucket (1 µs); everything at or below
#: lands in the underflow slot and reports LO_MS
LO_MS = 1e-3
#: real buckets; LO_MS * GROWTH**N_BUCKETS ≈ 134 s, past any latency the
#: serving layer should ever see — beyond that is the overflow slot
N_BUCKETS = 108

_INV_LOG_STEP = 1.0 / (0.25 * math.log(2.0))
_LOG_LO = math.log(LO_MS)


def _bucket_index(ms: float) -> int:
    """Slot for ``ms``: 0 = underflow, 1..N_BUCKETS = real buckets,
    N_BUCKETS + 1 = overflow."""
    if ms <= LO_MS:
        return 0
    i = int(math.floor((math.log(ms) - _LOG_LO) * _INV_LOG_STEP)) + 1
    return min(i, N_BUCKETS + 1)


def _upper_edge(index: int) -> float:
    """Upper edge of slot ``index`` (underflow reports LO_MS; overflow
    has no finite edge and reports +inf)."""
    if index <= 0:
        return LO_MS
    if index > N_BUCKETS:
        return math.inf
    return LO_MS * GROWTH ** index


class LogHistogram:
    """Log-bucketed histogram of millisecond latencies.

    Exact counts in geometric buckets; percentiles are the upper edge of
    the bucket holding the requested order statistic, clamped to the
    exact observed max (so a lone sample reports itself, not its bucket
    ceiling).
    """

    __slots__ = ("_counts", "count", "sum_ms", "min_ms", "max_ms")

    def __init__(self) -> None:
        self._counts: List[int] = [0] * (N_BUCKETS + 2)
        self.count = 0
        self.sum_ms = 0.0
        self.min_ms: Optional[float] = None
        self.max_ms: Optional[float] = None

    def record(self, ms: float, count: int = 1) -> None:
        """Add ``count`` observations of ``ms`` — O(1) regardless of
        ``count`` (one bucket increment), unlike the sample lists this
        replaces which materialized ``[ms] * count``."""
        if count <= 0:
            return
        ms = float(ms)
        self._counts[_bucket_index(ms)] += count
        self.count += count
        self.sum_ms += ms * count
        if self.min_ms is None or ms < self.min_ms:
            self.min_ms = ms
        if self.max_ms is None or ms > self.max_ms:
            self.max_ms = ms

    # -- queries ---------------------------------------------------------------
    @property
    def mean_ms(self) -> Optional[float]:
        return (self.sum_ms / self.count) if self.count else None

    def percentile(self, q: float) -> Optional[float]:
        """The ``q``-th percentile (0..100) as the holding bucket's upper
        edge, or ``None`` when the histogram is empty — an idle server
        must never fabricate a 0.0 latency."""
        if self.count == 0:
            return None
        if not 0.0 <= q <= 100.0:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        # rank of the order statistic (1-based, ceil — the classic
        # nearest-rank definition, exact for bucket counts)
        rank = max(1, math.ceil(q / 100.0 * self.count))
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                edge = _upper_edge(i)
                hi = self.max_ms if self.max_ms is not None else edge
                lo = self.min_ms if self.min_ms is not None else edge
                return min(max(edge, lo), hi)
        return self.max_ms  # unreachable; counts sum to self.count

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Add ``other``'s counts into this histogram (exact — both use
        the module-wide bucket layout); returns ``self``."""
        for i, c in enumerate(other._counts):
            self._counts[i] += c
        self.count += other.count
        self.sum_ms += other.sum_ms
        for theirs in (other.min_ms,):
            if theirs is not None and \
                    (self.min_ms is None or theirs < self.min_ms):
                self.min_ms = theirs
        for theirs in (other.max_ms,):
            if theirs is not None and \
                    (self.max_ms is None or theirs > self.max_ms):
                self.max_ms = theirs
        return self

    def cumulative_buckets(self) -> Iterator[Tuple[float, int]]:
        """``(upper_edge_ms, cumulative_count)`` pairs for every
        *occupied prefix* of the bucket array — the Prometheus
        ``le``-label series.  Empty trailing buckets are skipped (the
        ``+Inf`` bucket, always emitted by the renderer, carries the
        total)."""
        cum = 0
        remaining = self.count
        for i, c in enumerate(self._counts):
            if remaining == 0:
                return
            cum += c
            remaining -= c
            if c:
                yield _upper_edge(i), cum

    def to_dict(self) -> Dict[str, object]:
        """JSON-friendly snapshot (only occupied buckets)."""
        return {
            "count": self.count,
            "sum_ms": self.sum_ms,
            "min_ms": self.min_ms,
            "max_ms": self.max_ms,
            "p50_ms": self.percentile(50),
            "p90_ms": self.percentile(90),
            "p99_ms": self.percentile(99),
            "buckets": [[edge, cum] for edge, cum
                        in self.cumulative_buckets()],
        }

    def __len__(self) -> int:
        return self.count

    def __repr__(self) -> str:
        return (f"LogHistogram(count={self.count}, "
                f"p50={self.percentile(50)}, p99={self.percentile(99)})")
