"""Per-request span tracing with deterministic sampling.

One sampled request carries one :class:`TraceContext` through the whole
serving path — batcher queue, routing, plan cache, device launch,
demux/decode — collecting explicit start/end **span** records plus
instantaneous **events** (plan-cache hit/miss, routing decisions with
the losing EWMAs, tuner retirements).  Everything is measured through
the :class:`~repro.runtime.config.RuntimeConfig` clock, so traces are
deterministic and unit-testable with an injected fake clock, exactly
like the router and tuner.

The cardinal rule is that **disabled tracing costs ~nothing**: the hot
path's only obligation is

    tr = engine.tracer
    ctx = tr.begin(qtext) if tr is not None and tr.active else None

— one attribute load and one float compare when ``trace_sample_rate``
is 0 (``benchmarks/trace_overhead.py`` gates this at ≤1%).  Sampling is
deterministic stride sampling (1 in ``round(1/rate)`` requests), not
random — reproducible under test and immune to unlucky streaks.

Finished traces flow into the tracer's
:class:`~repro.obs.recorder.FlightRecorder` (ring + slow-query
reservoir) and feed per-stage :class:`~repro.obs.histogram.LogHistogram`
aggregates, which :mod:`repro.obs.prometheus` exposes as
``repro_stage_ms`` series.
"""

from __future__ import annotations

from typing import Any, Dict, List, Optional

from repro.obs.histogram import LogHistogram
from repro.obs.recorder import FlightRecorder

__all__ = ["Span", "TraceContext", "Tracer"]


class Span:
    """One timed region of a trace.  ``t0``/``t1`` are raw clock seconds
    (the config clock's units); ``t1 is None`` while the span is open."""

    __slots__ = ("sid", "name", "parent", "t0", "t1", "attrs", "events")

    def __init__(self, sid: int, name: str, parent: Optional[int],
                 t0: float, attrs: Dict[str, Any]):
        self.sid = sid
        self.name = name
        self.parent = parent      # parent span's sid (None for the root)
        self.t0 = t0
        self.t1: Optional[float] = None
        self.attrs = attrs
        self.events: List[Dict[str, Any]] = []

    @property
    def duration_ms(self) -> Optional[float]:
        return None if self.t1 is None else (self.t1 - self.t0) * 1e3

    def __repr__(self) -> str:
        dur = self.duration_ms
        shown = "open" if dur is None else f"{dur:.3f}ms"
        return f"Span({self.sid}, {self.name!r}, {shown})"


class TraceContext:
    """The spans and events of ONE sampled request.

    Span 0 is the root (``request``); :meth:`start`/:meth:`end` manage a
    stack of open spans so nesting falls out of call order.  The context
    is carried *by argument* through the engine, batcher, prepared
    queries and executors — there is no thread-local or global state, so
    the untraced path never looks anything up.
    """

    __slots__ = ("trace_id", "clock", "spans", "_open", "_tracer",
                 "duration_ms")

    def __init__(self, trace_id: int, clock, tracer: "Optional[Tracer]",
                 name: str = "request", **attrs: Any):
        self.trace_id = trace_id
        self.clock = clock
        self._tracer = tracer
        self.duration_ms: Optional[float] = None
        root = Span(0, name, None, clock(), attrs)
        self.spans: List[Span] = [root]
        self._open: List[int] = [0]

    @property
    def root(self) -> Span:
        return self.spans[0]

    # -- spans -----------------------------------------------------------------
    def start(self, name: str, **attrs: Any) -> int:
        """Open a child span under the innermost open span; returns its
        sid for :meth:`end`."""
        sid = len(self.spans)
        parent = self._open[-1] if self._open else 0
        self.spans.append(Span(sid, name, parent, self.clock(), attrs))
        self._open.append(sid)
        return sid

    def end(self, sid: int, **attrs: Any) -> None:
        """Close span ``sid`` (and anything left open inside it — a
        child that escaped its ``end`` must not dangle past its parent)."""
        t = self.clock()
        while self._open and self._open[-1] != sid:
            inner = self.spans[self._open.pop()]
            if inner.t1 is None:
                inner.t1 = t
        if self._open and self._open[-1] == sid:
            self._open.pop()
        span = self.spans[sid]
        if span.t1 is None:
            span.t1 = t
        if attrs:
            span.attrs.update(attrs)

    # -- events / annotations --------------------------------------------------
    def event(self, name: str, **attrs: Any) -> None:
        """Instantaneous event on the innermost open span."""
        holder = self.spans[self._open[-1]] if self._open else self.root
        holder.events.append({"name": name, "t": self.clock(),
                              "attrs": attrs})

    def annotate(self, sid: int = 0, **attrs: Any) -> None:
        """Attach attributes to span ``sid`` (default: the root)."""
        self.spans[sid].attrs.update(attrs)

    def annotate_named(self, name: str, **attrs: Any) -> int:
        """Attach attributes to every span called ``name`` (how the
        engine joins estimated/actual cardinalities onto device-launch
        spans after the fact); returns the number annotated."""
        n = 0
        for span in self.spans:
            if span.name == name:
                span.attrs.update(attrs)
                n += 1
        return n

    def finish(self, **attrs: Any) -> None:
        """Close the root (and any stragglers) and hand the complete
        trace to the tracer's recorder/aggregates."""
        if self.root.t1 is not None:
            return                      # already finished (idempotent)
        self.end(0, **attrs)
        self.duration_ms = self.root.duration_ms
        if self._tracer is not None:
            self._tracer._finished(self)


class Tracer:
    """Sampling front door + aggregate sink for :class:`TraceContext`.

    Reads ``trace_sample_rate`` from the config on every :meth:`begin`,
    so the rate is live-tunable (the overhead benchmark warms caches at
    rate 1.0 and then measures at the gated rates on the same engine).
    """

    def __init__(self, config):
        self.config = config
        self.recorder = FlightRecorder(
            ring=getattr(config, "trace_ring", 256),
            slow_ms=getattr(config, "trace_slow_ms", 100.0),
            slow_keep=getattr(config, "trace_slow_keep", 64))
        #: per span-name duration aggregates (repro_stage_ms in the
        #: Prometheus exposition)
        self.stage_hist: Dict[str, LogHistogram] = {}
        self.started = 0          # sampled-in traces begun
        self.finished = 0
        self.sampled_out = 0      # requests the stride skipped
        self._seen = 0            # all begin() calls (stride counter)
        self._next_id = 0

    @property
    def active(self) -> bool:
        """False ⇒ the engine must not even build a TraceContext — the
        guard the ≤1%-overhead gate measures."""
        return self.config.trace_sample_rate > 0.0

    def begin(self, qtext: Optional[str] = None,
              **attrs: Any) -> Optional[TraceContext]:
        """A TraceContext for this request, or ``None`` when the stride
        samples it out (sampled-out requests create zero records)."""
        rate = self.config.trace_sample_rate
        if rate <= 0.0:
            return None
        self._seen += 1
        if rate < 1.0:
            stride = max(1, round(1.0 / rate))
            if (self._seen - 1) % stride != 0:
                self.sampled_out += 1
                return None
        self._next_id += 1
        self.started += 1
        if qtext is not None:
            attrs.setdefault("qtext", qtext[:200])
        return TraceContext(self._next_id, self.config.clock, self,
                            **attrs)

    def _finished(self, ctx: TraceContext) -> None:
        self.finished += 1
        for span in ctx.spans:
            dur = span.duration_ms
            if dur is None:
                continue
            hist = self.stage_hist.get(span.name)
            if hist is None:
                hist = self.stage_hist[span.name] = LogHistogram()
            hist.record(dur)
        self.recorder.add(ctx)

    # -- export passthroughs ---------------------------------------------------
    def chrome_trace(self) -> Dict[str, Any]:
        return self.recorder.chrome_trace()

    def to_jsonl(self) -> str:
        return self.recorder.to_jsonl()
