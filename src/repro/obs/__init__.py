"""Low-overhead observability: spans, flight recorder, histograms.

The serving layer's evidence plane.  Three pieces, all wired through
:class:`~repro.runtime.config.RuntimeConfig` knobs (``REPRO_RT_TRACE_*``)
and all costing ~nothing when off:

* :mod:`repro.obs.tracer` — per-request span traces with deterministic
  stride sampling (``trace_sample_rate``), carried by argument through
  ``Engine.query``/``query_batch``, the micro-batcher, prepared queries
  and both device executors;
* :mod:`repro.obs.recorder` — the flight recorder: a ring of the last N
  complete traces plus a slow-query reservoir, exportable as Chrome
  ``chrome://tracing`` JSON and JSONL (``tools/trace_inspect.py``,
  ``launch/serve.py --trace-dump``);
* :mod:`repro.obs.histogram` — O(1)-memory log-bucketed latency
  histograms backing ``ServerMetrics`` percentiles and the Prometheus
  text exposition (:mod:`repro.obs.prometheus`,
  ``ServerMetrics.prometheus()``, ``launch/serve.py --metrics-out``).

See docs/observability.md for the span taxonomy, bucket scheme and
metric names.
"""

from repro.obs.histogram import LogHistogram
from repro.obs.recorder import FlightRecorder
from repro.obs.tracer import Span, TraceContext, Tracer

__all__ = ["LogHistogram", "FlightRecorder", "Span", "TraceContext",
           "Tracer"]
