"""Persistent columnar catalog store (the analogue of S2RDF's one-time
Parquet load job on HDFS, paper §4–§5): write a built catalog to disk
once, then boot any number of query processes from it — memory-mapped,
zero-copy, without ever re-running the semi-join grid.

    ds = Dataset.watdiv(scale=1.0, threshold=0.25)
    ds.save("watdiv.store")                    # streaming columnar write
    ...
    ds = Dataset.load("watdiv.store")          # lazy memmap cold start
    ds.engine("jit").query(...)                # tables fault in on touch

Layout, manifest and integrity rules: :mod:`repro.store.format`.
Append journal (delta segments + compaction): :mod:`repro.store.delta`.
"""

from repro.store.delta import (
    DeltaSegment, append_segment, clear_segments, delta_stats, read_segments,
)
from repro.store.format import (
    FORMAT_NAME, FORMAT_VERSION, StoreChecksumError, StoreError,
    StoreFormatError, is_store, load_manifest, section_bytes,
)
from repro.store.reader import StoreInfo, load_catalog, load_dictionary
from repro.store.writer import write_store

__all__ = [
    "FORMAT_NAME", "FORMAT_VERSION",
    "StoreError", "StoreFormatError", "StoreChecksumError",
    "is_store", "load_manifest", "section_bytes",
    "StoreInfo", "load_catalog", "load_dictionary", "write_store",
    "DeltaSegment", "append_segment", "read_segments", "clear_segments",
    "delta_stats",
]
