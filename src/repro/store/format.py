"""On-disk columnar catalog format (the persisted analogue of S2RDF's
Parquet store on HDFS, paper §4–§5).

A store is a directory::

    <path>/
      manifest.json            # versioned JSON manifest (written LAST)
      dictionary.json          # JSON array of terms, id order
      values.bin               # float64[n_terms] numeric literal values
      tt.bin                   # int32[N, 3] triples table, row-major
      vp/<pid>.bin             # int32[n, 2] (s, o) rows, sorted by (s, o)
      extvp/<kind>_<p1>_<p2>.bin   # materialized ExtVP tables, same layout
      delta/seg-<seq>.json     # append journal (see repro.store.delta)

All ``.bin`` files are raw **little-endian** column files with no header:
the manifest records dtype-implied row/column counts, byte sizes and a
CRC-32 per file, so a reader can ``np.memmap`` any table zero-copy and
verify integrity independently.  The manifest also persists the
driver-side statistics (SF + sizes for **all** pairs, paper §6) so a
loaded catalog answers the compiler's statistics queries without touching
a single column file.

The manifest is written last (via tmp + ``os.replace``): a directory
without a readable, well-formed manifest is not a store.
"""

from __future__ import annotations

import json
import os
import zlib
from typing import Dict, Tuple

import numpy as np

__all__ = [
    "FORMAT_NAME", "FORMAT_VERSION", "SUPPORTED_VERSIONS", "MANIFEST_NAME",
    "INT_DTYPE",
    "VAL_DTYPE", "CHUNK_BYTES", "StoreError", "StoreFormatError",
    "StoreChecksumError", "key_to_str", "str_to_key", "table_filename",
    "crc32", "crc32_file", "load_manifest", "manifest_path", "is_store",
    "section_bytes",
]

FORMAT_NAME = "s2rdf-columnar-store"
#: version 2 added the per-predicate distinct-subject/object counts
#: ("distinct" manifest section) that feed the cardinality estimator;
#: version-1 stores still load — they just carry no distinct statistics,
#: so the estimate planner falls back to the greedy order.
FORMAT_VERSION = 2
SUPPORTED_VERSIONS = (1, 2)
MANIFEST_NAME = "manifest.json"

#: every table column file is raw little-endian int32; the numeric-literal
#: value table is little-endian float64
INT_DTYPE = np.dtype("<i4")
VAL_DTYPE = np.dtype("<f8")

#: streaming granularity for writes / checksum scans
CHUNK_BYTES = 1 << 22


class StoreError(Exception):
    """Base class for persistent-store failures."""


class StoreFormatError(StoreError):
    """Missing / malformed / unsupported manifest or file layout."""


class StoreChecksumError(StoreError):
    """A file's bytes do not match the CRC-32 recorded in the manifest."""


# ---------------------------------------------------------------------------
# Keys and filenames
# ---------------------------------------------------------------------------

Key = Tuple[str, int, int]


def key_to_str(key: Key) -> str:
    """(kind, p1, p2) -> "SS:3:7" (manifest dict key)."""
    kind, p1, p2 = key
    return f"{kind}:{int(p1)}:{int(p2)}"


def str_to_key(s: str) -> Key:
    kind, p1, p2 = s.split(":")
    return (kind, int(p1), int(p2))


def table_filename(kind: str, p1: int, p2: int) -> str:
    return f"extvp/{kind}_{int(p1)}_{int(p2)}.bin"


def manifest_path(path: str) -> str:
    return os.path.join(path, MANIFEST_NAME)


def is_store(path) -> bool:
    """True when ``path`` holds a readable store manifest."""
    return os.path.isfile(manifest_path(os.fspath(path)))


# ---------------------------------------------------------------------------
# Checksums
# ---------------------------------------------------------------------------

def crc32(data: bytes, value: int = 0) -> int:
    """CRC-32 of a byte chunk, chainable via ``value``."""
    return zlib.crc32(data, value)


def crc32_file(path: str) -> int:
    """Streaming CRC-32 of a file (never loads it whole)."""
    crc = 0
    with open(path, "rb") as f:
        while True:
            chunk = f.read(CHUNK_BYTES)
            if not chunk:
                return crc
            crc = zlib.crc32(chunk, crc)


# ---------------------------------------------------------------------------
# Manifest
# ---------------------------------------------------------------------------

_REQUIRED_TOP = ("format", "version", "threshold", "kinds", "with_extvp",
                 "dictionary", "tt", "vp", "extvp", "sf", "sizes")


def load_manifest(path: str) -> Dict:
    """Read + structurally validate ``<path>/manifest.json``.

    Raises :class:`StoreFormatError` on a missing manifest, unparseable
    JSON, a foreign format tag, an unsupported version, or missing
    sections — checksum verification is separate (it requires reading
    the column files, which the lazy loader defers).
    """
    mpath = manifest_path(path)
    if not os.path.isfile(mpath):
        raise StoreFormatError(f"no store at {path!r}: missing {MANIFEST_NAME}")
    try:
        with open(mpath, encoding="utf-8") as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise StoreFormatError(f"unreadable manifest {mpath!r}: {e}") from e
    if not isinstance(manifest, dict) or manifest.get("format") != FORMAT_NAME:
        raise StoreFormatError(
            f"{mpath!r} is not a {FORMAT_NAME} manifest "
            f"(format={manifest.get('format') if isinstance(manifest, dict) else None!r})")
    if manifest.get("version") not in SUPPORTED_VERSIONS:
        raise StoreFormatError(
            f"unsupported store version {manifest.get('version')!r} "
            f"(this reader speaks versions {SUPPORTED_VERSIONS})")
    missing = [k for k in _REQUIRED_TOP if k not in manifest]
    if missing:
        raise StoreFormatError(f"manifest {mpath!r} missing sections: {missing}")
    return manifest


def section_bytes(manifest: Dict, path: str) -> Dict[str, int]:
    """On-disk bytes per store section (manifest / dictionary / tt / vp /
    extvp / delta) from manifest-recorded sizes plus a live scan of the
    delta journal."""
    from repro.store.delta import delta_stats
    d = manifest["dictionary"]
    n_delta, delta_bytes = delta_stats(path)
    return {
        "manifest": os.path.getsize(manifest_path(path)),
        "dictionary": int(d["terms"]["nbytes"]) + int(d["values"]["nbytes"]),
        "tt": int(manifest["tt"]["nbytes"]),
        "vp": sum(int(e["nbytes"]) for e in manifest["vp"].values()),
        "extvp": sum(int(e["nbytes"]) for e in manifest["extvp"].values()),
        "delta": delta_bytes,
    }
