"""Store reader: on-disk columnar store -> ``Catalog``.

Cold-start is **zero-copy and lazy**: ``load_catalog`` parses only the
manifest (statistics, SF/size maps, dictionary) and hands the catalog
:class:`~repro.core.table.LazyTableMap` views whose per-table loaders
``np.memmap`` the raw little-endian column files on first
``Catalog.table()`` touch — no table bytes are read (or even mapped)
until a query actually scans them.  ``eager=True`` materializes every
table into RAM up front (the benchmarking / latency-critical mode);
``verify=True`` additionally CRC-checks each file's bytes when it is
first read (always up-front under ``eager``).

Loaded catalogs are indistinguishable from freshly built in-RAM ones:
the compiler and every execution backend go through the same
``Catalog.vp`` / ``Catalog.extvp.tables`` mappings and ``Catalog.table()``
accessor either way.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.table import LazyTableMap, Table
from repro.store.format import (
    INT_DTYPE, VAL_DTYPE, StoreChecksumError, StoreFormatError, crc32_file,
    load_manifest, section_bytes, str_to_key,
)

__all__ = ["StoreInfo", "load_catalog", "load_dictionary"]


@dataclass
class StoreInfo:
    """What a catalog knows about its on-disk form (for Table 2 style
    accounting in ``Catalog.storage_report()`` and the inspect tool)."""

    path: str
    bytes_by_section: Dict[str, int] = field(default_factory=dict)
    delta_segments: int = 0

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_section.values())


def _check_entry(path: str, entry: Dict, dtype: np.dtype) -> str:
    """Structural validation of one manifest file entry; returns the
    absolute file path.  One ``stat`` per file — the lazy path defers it
    to first touch so cold-start cost stays O(manifest), not O(files)."""
    fpath = os.path.join(path, entry["file"])
    try:
        actual = os.stat(fpath).st_size
    except OSError:
        raise StoreFormatError(f"store file missing: {fpath!r}") from None
    expect = int(entry["nbytes"])
    if actual != expect:
        raise StoreFormatError(
            f"{fpath!r}: size {actual} != manifest nbytes {expect}")
    rows = int(entry.get("rows", 0))
    cols = int(entry.get("cols", 1))
    if "rows" in entry and rows * cols * dtype.itemsize != expect:
        raise StoreFormatError(
            f"{fpath!r}: {rows}x{cols} {dtype} rows do not fill {expect} bytes")
    return fpath


def _verify_crc(fpath: str, entry: Dict) -> None:
    actual = crc32_file(fpath)
    if actual != int(entry["crc32"]):
        raise StoreChecksumError(
            f"{fpath!r}: CRC-32 {actual:#010x} != manifest "
            f"{int(entry['crc32']):#010x}")


def _map_rows(fpath: str, rows: int, cols: int, eager: bool) -> np.ndarray:
    if rows == 0:
        return np.empty((0, cols), dtype=np.int32)
    if eager:
        return np.fromfile(fpath, dtype=INT_DTYPE).reshape(rows, cols)
    return np.memmap(fpath, dtype=INT_DTYPE, mode="r", shape=(rows, cols))


def _table_loader(path: str, entry: Dict, eager: bool, verify: bool):
    """A zero-arg loader closure for one column file (LazyTableMap value).

    All validation (size stat, optional CRC) runs on first touch, so a
    lazy cold start never stats a table file it does not use; under
    ``eager`` the caller materializes everything at load time and every
    check runs up front."""
    def load() -> Table:
        fpath = _check_entry(path, entry, INT_DTYPE)
        if verify:
            _verify_crc(fpath, entry)
        return Table(_map_rows(fpath, int(entry["rows"]),
                               int(entry["cols"]), eager))
    return load


def load_dictionary(path: str, manifest: Dict, verify: bool = False):
    """Rebuild the term dictionary (terms JSON + float64 value table)."""
    from repro.rdf.dictionary import Dictionary
    d = manifest["dictionary"]
    tpath = _check_entry(path, d["terms"], np.dtype("u1"))
    vpath = _check_entry(path, d["values"], VAL_DTYPE)
    if verify:
        _verify_crc(tpath, d["terms"])
        _verify_crc(vpath, d["values"])
    try:
        with open(tpath, encoding="utf-8") as f:
            terms = json.load(f)
    except ValueError as e:
        raise StoreFormatError(f"unreadable term file {tpath!r}: {e}") from e
    if not isinstance(terms, list) or len(terms) != int(d["n_terms"]):
        raise StoreFormatError(
            f"{tpath!r}: expected a JSON array of {d['n_terms']} terms")
    values = np.fromfile(vpath, dtype=VAL_DTYPE) if os.path.getsize(vpath) \
        else np.empty((0,), dtype=VAL_DTYPE)
    return Dictionary.from_terms(terms, values)


def load_catalog(path: str, eager: bool = False, verify: bool = False
                 ) -> Tuple["Catalog", object]:
    """Open the store at ``path`` -> ``(Catalog, Dictionary)``.

    ``eager`` reads every column file into RAM now (and with ``verify``
    checks every checksum now); the default maps tables lazily.
    """
    path = os.fspath(path)
    manifest = load_manifest(path)

    from repro.core.stats import Catalog
    from repro.core.vp import ExtVPBuild

    dictionary = load_dictionary(path, manifest, verify=verify)

    tt_entry = manifest["tt"]
    tt_path = _check_entry(path, tt_entry, INT_DTYPE)
    if verify:
        _verify_crc(tt_path, tt_entry)
    tt = _map_rows(tt_path, int(tt_entry["rows"]), 3, eager)

    vp = LazyTableMap({int(pid): _table_loader(path, entry, eager, verify)
                       for pid, entry in manifest["vp"].items()},
                      lengths={int(pid): int(entry["rows"])
                               for pid, entry in manifest["vp"].items()})
    ext_tables = LazyTableMap(
        {str_to_key(k): _table_loader(path, entry, eager, verify)
         for k, entry in manifest["extvp"].items()},
        lengths={str_to_key(k): int(entry["rows"])
                 for k, entry in manifest["extvp"].items()})

    stats = manifest.get("stats", {})
    ext = ExtVPBuild(
        tables=ext_tables,
        sf={str_to_key(k): float(v) for k, v in manifest["sf"].items()},
        sizes={str_to_key(k): int(v) for k, v in manifest["sizes"].items()},
        threshold=float(manifest["threshold"]),
        build_seconds=float(stats.get("extvp_build_seconds", 0.0)),
        n_semijoins=int(stats.get("n_semijoins", 0)),
        backend=manifest.get("build_backend", "numpy"),
        kinds=tuple(manifest["kinds"]),
    )
    if eager:
        vp.materialize_all()
        ext_tables.materialize_all()

    # distinct-count statistics (format version 2; absent in version-1
    # manifests — the catalog then reports has_distinct_stats=False and
    # the estimate planner falls back to greedy).  Served straight from
    # the manifest: planning never touches a column file.
    distinct = manifest.get("distinct")
    distinct_s = distinct_o = m2_s = m2_o = None
    if isinstance(distinct, dict) and "s" in distinct and "o" in distinct:
        distinct_s = {int(p): int(v) for p, v in distinct["s"].items()}
        distinct_o = {int(p): int(v) for p, v in distinct["o"].items()}
        if "s2" in distinct and "o2" in distinct:
            # skew (second-moment) statistics — optional within v2
            m2_s = {int(p): int(v) for p, v in distinct["s2"].items()}
            m2_o = {int(p): int(v) for p, v in distinct["o2"].items()}

    from repro.store.delta import delta_stats
    n_delta, _ = delta_stats(path)
    info = StoreInfo(path=path,
                     bytes_by_section=section_bytes(manifest, path),
                     delta_segments=n_delta)
    cat = Catalog(tt=tt, vp=vp, extvp=ext, dictionary=dictionary,
                  vp_build_seconds=float(stats.get("vp_build_seconds", 0.0)),
                  with_extvp=bool(manifest["with_extvp"]),
                  store=info, distinct_s=distinct_s, distinct_o=distinct_o,
                  m2_s=m2_s, m2_o=m2_o)
    return cat, dictionary
