"""Streaming store writer: Catalog -> on-disk columnar store.

Every file is written in bounded chunks (the CRC-32 accumulates as the
bytes stream out, so no table is ever serialized twice or held whole as
bytes) and lands via ``<file>.tmp`` + ``os.replace``.  The manifest goes
last: until it is in place the directory is not a valid store, so a
crashed save never yields a half-readable catalog.  Replacing (rather
than truncating) also makes ``Dataset.compact()`` safe while the *same*
store's column files are still memory-mapped by the live catalog — the
old inodes stay alive under the open maps until they are dropped.
"""

from __future__ import annotations

import json
import os
from typing import Dict, Tuple

import numpy as np

from repro.store.format import (
    CHUNK_BYTES, FORMAT_NAME, FORMAT_VERSION, INT_DTYPE, MANIFEST_NAME,
    VAL_DTYPE, key_to_str, manifest_path, table_filename,
)
from repro.store.format import crc32 as _crc32

__all__ = ["write_store"]


def _write_bytes(path: str, data: bytes) -> Tuple[int, int]:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
    os.replace(tmp, path)
    return len(data), _crc32(data)


def _write_array(path: str, arr: np.ndarray, dtype: np.dtype) -> Tuple[int, int]:
    """Stream ``arr`` to ``path`` as raw ``dtype`` rows; (nbytes, crc32)."""
    arr = np.asarray(arr)
    if arr.dtype != dtype:
        arr = arr.astype(dtype)
    rows_per_chunk = max(1, CHUNK_BYTES // max(arr[:1].nbytes, 1)) \
        if len(arr) else 1
    crc = 0
    nbytes = 0
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        for i in range(0, len(arr), rows_per_chunk):
            chunk = np.ascontiguousarray(arr[i:i + rows_per_chunk]).tobytes()
            f.write(chunk)
            crc = _crc32(chunk, crc)
            nbytes += len(chunk)
    os.replace(tmp, path)
    return nbytes, crc


def _table_entry(path: str, rel: str, rows: np.ndarray, cols: int) -> Dict:
    nbytes, crc = _write_array(os.path.join(path, rel), rows, INT_DTYPE)
    return {"file": rel, "rows": int(len(rows)), "cols": cols,
            "nbytes": nbytes, "crc32": crc}


def _prune_stale(dirpath: str, keep: set) -> None:
    """Remove ``.bin``/``.tmp`` files a rewrite no longer references
    (unlink is safe under live memory maps)."""
    if not os.path.isdir(dirpath):
        return
    for name in os.listdir(dirpath):
        if name not in keep and (name.endswith(".bin") or name.endswith(".tmp")):
            os.remove(os.path.join(dirpath, name))


def write_store(catalog, dictionary, path: str,
                build_backend: str = "numpy") -> Dict:
    """Persist ``catalog`` (+ its ``dictionary``) under directory ``path``.

    Returns the manifest dict that was written.  Safe to call on a path
    that already holds a store: files are atomically replaced, stale
    table files pruned, and the delta journal is NOT touched here (the
    caller decides whether the rewrite supersedes it — ``Dataset.save``
    clears it).
    """
    path = os.fspath(path)
    os.makedirs(path, exist_ok=True)
    os.makedirs(os.path.join(path, "vp"), exist_ok=True)
    os.makedirs(os.path.join(path, "extvp"), exist_ok=True)

    ext = catalog.extvp
    manifest: Dict = {
        "format": FORMAT_NAME,
        "version": FORMAT_VERSION,
        "threshold": float(ext.threshold),
        "kinds": list(ext.kinds),
        "with_extvp": bool(catalog.with_extvp),
        "build_backend": build_backend,
        "stats": {
            "vp_build_seconds": float(catalog.vp_build_seconds),
            "extvp_build_seconds": float(ext.build_seconds),
            "n_semijoins": int(ext.n_semijoins),
        },
    }

    # dictionary: terms as a JSON array (id order), values as float64 bin
    terms = list(dictionary.id_to_term)
    tdata = json.dumps(terms, ensure_ascii=False).encode("utf-8")
    tn, tcrc = _write_bytes(os.path.join(path, "dictionary.json"), tdata)
    vn, vcrc = _write_array(os.path.join(path, "values.bin"),
                            dictionary.values, VAL_DTYPE)
    manifest["dictionary"] = {
        "n_terms": len(terms),
        "terms": {"file": "dictionary.json", "nbytes": tn, "crc32": tcrc},
        "values": {"file": "values.bin", "nbytes": vn, "crc32": vcrc},
    }

    manifest["tt"] = _table_entry(path, "tt.bin", catalog.tt, 3)

    vp_entries: Dict[str, Dict] = {}
    for pid in sorted(catalog.vp):
        rel = f"vp/{int(pid)}.bin"
        vp_entries[str(int(pid))] = _table_entry(path, rel,
                                                 catalog.vp[pid].rows, 2)
    manifest["vp"] = vp_entries

    ext_entries: Dict[str, Dict] = {}
    for key in sorted(ext.tables):
        kind, p1, p2 = key
        rel = table_filename(kind, p1, p2)
        ext_entries[key_to_str(key)] = _table_entry(path, rel,
                                                    ext.tables[key].rows, 2)
    manifest["extvp"] = ext_entries

    # driver-side statistics for ALL pairs (materialized or not, §6)
    manifest["sf"] = {key_to_str(k): float(v)
                      for k, v in sorted(ext.sf.items())}
    manifest["sizes"] = {key_to_str(k): int(v)
                         for k, v in sorted(ext.sizes.items())}

    # per-predicate distinct-subject/object counts (format version 2):
    # the cardinality estimator's join-selectivity statistics, served
    # from the manifest so lazy loads never materialize a table to plan
    if catalog.distinct_s is not None and catalog.distinct_o is not None:
        manifest["distinct"] = {
            "s": {str(int(p)): int(v)
                  for p, v in sorted(catalog.distinct_s.items())},
            "o": {str(int(p)): int(v)
                  for p, v in sorted(catalog.distinct_o.items())},
        }
        # frequency second moments (skew statistics) ride along when the
        # catalog has them — optional even within format version 2
        if catalog.m2_s is not None and catalog.m2_o is not None:
            manifest["distinct"]["s2"] = {
                str(int(p)): int(v) for p, v in sorted(catalog.m2_s.items())}
            manifest["distinct"]["o2"] = {
                str(int(p)): int(v) for p, v in sorted(catalog.m2_o.items())}

    _prune_stale(os.path.join(path, "vp"),
                 {os.path.basename(e["file"]) for e in vp_entries.values()})
    _prune_stale(os.path.join(path, "extvp"),
                 {os.path.basename(e["file"]) for e in ext_entries.values()})

    mdata = json.dumps(manifest, ensure_ascii=False, indent=1).encode("utf-8")
    tmp = manifest_path(path) + ".tmp"
    with open(tmp, "wb") as f:
        f.write(mdata)
    os.replace(tmp, manifest_path(path))
    return manifest
