"""Delta segments: the append journal of a persistent store.

``Dataset.append_triples`` on a store-attached dataset journals the raw
string triples as one JSON segment per append::

    delta/seg-000007.json
    {"format": "s2rdf-delta", "version": 1, "seq": 7,
     "n_triples": 3, "payload_crc32": ..., "triples": [[s, p, o], ...]}

The base store is never rewritten on append — ``Dataset.load`` replays
the segments in sequence through the incremental build path
(:func:`repro.core.extvp_build.incremental_pairs`), which recomputes only
the ExtVP pairs each append actually touched.  ``Dataset.compact()``
folds the journal into a fresh base and clears it.

Segments carry *string* triples (not ids): the dictionary grows during
replay exactly as it did during the original append, so a replayed
catalog is byte-identical to the pre-restart one.
"""

from __future__ import annotations

import json
import os
import re
from dataclasses import dataclass
from typing import List, Tuple

from repro.store.format import StoreChecksumError, StoreFormatError, crc32

DELTA_FORMAT = "s2rdf-delta"
DELTA_VERSION = 1
DELTA_DIR = "delta"

_SEG_RE = re.compile(r"^seg-(\d{6})\.json$")

__all__ = ["DeltaSegment", "append_segment", "read_segments",
           "clear_segments", "delta_stats", "DELTA_DIR"]


@dataclass
class DeltaSegment:
    seq: int
    triples: List[Tuple[str, str, str]]
    path: str
    nbytes: int


def _delta_dir(store_path: str) -> str:
    return os.path.join(os.fspath(store_path), DELTA_DIR)


def _payload_crc(triples) -> int:
    payload = json.dumps([list(t) for t in triples], ensure_ascii=False,
                         separators=(",", ":"))
    return crc32(payload.encode("utf-8"))


def _segment_files(store_path: str) -> List[Tuple[int, str]]:
    ddir = _delta_dir(store_path)
    if not os.path.isdir(ddir):
        return []
    out = []
    for name in os.listdir(ddir):
        m = _SEG_RE.match(name)
        if m:
            out.append((int(m.group(1)), os.path.join(ddir, name)))
    return sorted(out)


def next_seq(store_path: str) -> int:
    files = _segment_files(store_path)
    return (files[-1][0] + 1) if files else 1


def append_segment(store_path: str, triples) -> DeltaSegment:
    """Journal one append as the next numbered segment (tmp + replace,
    so a crash mid-write never leaves a half segment behind)."""
    triples = [tuple(t) for t in triples]
    seq = next_seq(store_path)
    ddir = _delta_dir(store_path)
    os.makedirs(ddir, exist_ok=True)
    seg = {
        "format": DELTA_FORMAT, "version": DELTA_VERSION, "seq": seq,
        "n_triples": len(triples), "payload_crc32": _payload_crc(triples),
        "triples": [list(t) for t in triples],
    }
    path = os.path.join(ddir, f"seg-{seq:06d}.json")
    tmp = path + ".tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(seg, f, ensure_ascii=False)
    os.replace(tmp, path)
    return DeltaSegment(seq=seq, triples=triples, path=path,
                        nbytes=os.path.getsize(path))


def read_segments(store_path: str) -> List[DeltaSegment]:
    """All journal segments in sequence order, payload-checksummed.

    Delta segments are always verified (unlike lazily-touched column
    files they are the mutation-prone part of the store and are small).
    """
    out: List[DeltaSegment] = []
    for seq, path in _segment_files(store_path):
        try:
            with open(path, encoding="utf-8") as f:
                seg = json.load(f)
        except (OSError, ValueError) as e:
            raise StoreFormatError(f"unreadable delta segment {path!r}: {e}") from e
        if seg.get("format") != DELTA_FORMAT or seg.get("version") != DELTA_VERSION:
            raise StoreFormatError(f"{path!r} is not a {DELTA_FORMAT} segment")
        triples = [tuple(t) for t in seg.get("triples", [])]
        if len(triples) != seg.get("n_triples") or \
                _payload_crc(triples) != seg.get("payload_crc32"):
            raise StoreChecksumError(f"delta segment {path!r} failed its "
                                     "payload checksum")
        out.append(DeltaSegment(seq=int(seg["seq"]), triples=triples,
                                path=path, nbytes=os.path.getsize(path)))
    return out


def clear_segments(store_path: str) -> int:
    """Drop the journal (after a compact); returns segments removed."""
    files = _segment_files(store_path)
    for _, path in files:
        os.remove(path)
    return len(files)


def delta_stats(store_path: str) -> Tuple[int, int]:
    """(segment count, total journal bytes) without parsing payloads."""
    files = _segment_files(store_path)
    return len(files), sum(os.path.getsize(p) for _, p in files)
