"""Train step assembly: value_and_grad + microbatch gradient accumulation
+ gradient compression hook + AdamW, all shardable under pjit.

``make_train_step(model, opt_cfg, ...)`` returns a pure function
``(params, opt_state, batch) -> (params, opt_state, metrics)`` — the
object the launcher jits with in/out shardings and the dry-run lowers.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.train.compression import compress_decompress
from repro.train.optimizer import AdamWState, OptConfig, adamw_update

Params = Any


def make_train_step(model: Model, opt_cfg: OptConfig,
                    accum_steps: int = 1,
                    compress_grads: bool = False) -> Callable:
    """Build the train-step function.

    accum_steps > 1 splits the global batch into microbatches along dim 0
    and accumulates gradients in fp32 via ``lax.scan`` — constant memory
    in the number of microbatches, the standard large-batch trick.
    compress_grads applies bf16 compression with error feedback between
    grad computation and the optimizer (see compression.py); under data
    parallelism XLA's all-reduce then moves half the bytes.
    """

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params: Params, opt_state: AdamWState,
                   batch: Dict[str, jax.Array],
                   err_state: Optional[Params] = None):
        if accum_steps == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            def micro(batch_i):
                return jax.tree.map(
                    lambda x: x.reshape((accum_steps, -1) + x.shape[1:])
                    if x.ndim else x, batch_i)

            micro_batches = micro(batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_loss, acc_g = acc
                return (acc_loss + l,
                        jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                     acc_g, g)), ()

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0.0), zero),
                                            micro_batches)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)

        if compress_grads:
            grads, err_state = compress_decompress(grads, err_state)

        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        if compress_grads:
            return params, opt_state, err_state, metrics
        return params, opt_state, metrics

    return train_step
