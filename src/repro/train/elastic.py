"""Elastic scaling, failure handling and straggler mitigation.

This container has one host, so the *policies* are implemented against an
abstract cluster membership interface and unit-tested with simulated
failures; on a real multi-host deployment `ClusterView` reads the JAX
distributed runtime (coordinator heartbeats) instead of the injected
callbacks.  The mechanisms:

* **Failure detection** — heartbeat timestamps per host; a host silent
  for ``timeout_s`` is declared dead.
* **Elastic re-mesh** — given the surviving host set, pick the largest
  mesh (pods × data × model) we can build with the configured model-axis
  size, re-shard from the last checkpoint (checkpoint.py restores onto
  any mesh), and scale the per-host batch to preserve the global batch.
* **Straggler mitigation** — per-step host timings feed an EWMA; hosts
  slower than ``straggler_factor ×`` median for ``patience`` consecutive
  steps are treated as failed (synchronous data parallelism means one
  straggler gates the fleet — eject-and-reshard beats waiting, cf.
  backup workers in large-scale SGD).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["ClusterView", "ElasticPolicy", "MeshPlan", "StragglerDetector"]


@dataclass
class MeshPlan:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_hosts: int
    per_host_batch: int

    @property
    def n_devices(self) -> int:
        return int(np.prod(self.shape))


@dataclass
class ClusterView:
    """Membership via heartbeats (injected clock for tests)."""

    timeout_s: float = 30.0
    _last_seen: Dict[str, float] = field(default_factory=dict)

    def heartbeat(self, host: str, now: Optional[float] = None) -> None:
        self._last_seen[host] = time.monotonic() if now is None else now

    def alive(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return sorted(h for h, seen in self._last_seen.items()
                      if t - seen <= self.timeout_s)

    def dead(self, now: Optional[float] = None) -> List[str]:
        t = time.monotonic() if now is None else now
        return sorted(h for h, seen in self._last_seen.items()
                      if t - seen > self.timeout_s)


@dataclass
class ElasticPolicy:
    """Chooses the mesh after membership changes.

    Keeps the model axis fixed (TP degree is a property of the model
    fitting in HBM, not of cluster size) and scales the data/pod axes to
    the largest usable host count; global batch is preserved by scaling
    per-host batch, so optimizer hyperparameters stay valid.
    """

    devices_per_host: int = 4
    model_axis: int = 16
    global_batch: int = 256

    def plan(self, n_hosts: int) -> MeshPlan:
        if n_hosts <= 0:
            raise RuntimeError("no hosts alive")
        total = n_hosts * self.devices_per_host
        if total < self.model_axis:
            # degenerate cluster: shrink TP (restore handles resharding)
            model = 1 << int(np.floor(np.log2(total)))
            data = total // model
        else:
            model = self.model_axis
            data = total // model
        # keep data a divisor of global batch for exact microbatching
        while data > 1 and self.global_batch % data != 0:
            data -= 1
        used = data * model
        per_host_batch = max(1, self.global_batch // data)
        return MeshPlan(shape=(data, model), axis_names=("data", "model"),
                        n_hosts=used // self.devices_per_host or 1,
                        per_host_batch=per_host_batch)


@dataclass
class StragglerDetector:
    straggler_factor: float = 1.8
    patience: int = 3
    ewma: float = 0.5
    _avg: Dict[str, float] = field(default_factory=dict)
    _strikes: Dict[str, int] = field(default_factory=dict)

    def observe(self, timings: Dict[str, float]) -> List[str]:
        """Feed per-host step seconds; returns hosts to eject."""
        for h, t in timings.items():
            prev = self._avg.get(h, t)
            self._avg[h] = (1 - self.ewma) * prev + self.ewma * t
        med = float(np.median(list(self._avg.values())))
        out = []
        for h, avg in self._avg.items():
            if avg > self.straggler_factor * med:
                self._strikes[h] = self._strikes.get(h, 0) + 1
                if self._strikes[h] >= self.patience:
                    out.append(h)
            else:
                self._strikes[h] = 0
        return sorted(out)

    def forget(self, host: str) -> None:
        self._avg.pop(host, None)
        self._strikes.pop(host, None)
