"""Checkpointing: sharded save/restore with elastic re-sharding and an
async writer — the fault-tolerance substrate.

Format: one ``.npz`` per host (this container: one) holding flattened
leaves keyed by pytree path, plus a JSON manifest with step, pytree
structure, leaf shapes/dtypes and the writer's mesh shape.  Restore onto
a *different* mesh/device-count works because leaves are saved unsharded
(gathered) — at 1000-node scale the same format shards per-host via the
process-local addressable slices (``save(..., per_host=True)`` writes
only what this process owns; restore stitches by path).

The async writer moves serialization off the training thread: ``save``
returns a future after snapshotting device arrays to host memory
(blocking only for device→host copy, which train steps can't overlap
anyway), then a daemon thread does compression + fsync + atomic rename.
Atomicity: write to ``<dir>.tmp`` then ``os.replace`` so a crash never
leaves a half checkpoint; ``latest_step`` only believes manifests.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any

_EXEC = ThreadPoolExecutor(max_workers=1, thread_name_prefix="ckpt")


def _flatten_with_paths(tree: Params) -> Dict[str, np.ndarray]:
    flat = {}

    def walk(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(walk, tree)
    return flat


def save(ckpt_dir: str, step: int, tree: Params,
         extra: Optional[Dict[str, Any]] = None,
         async_write: bool = True) -> Future:
    """Snapshot ``tree`` at ``step``.  Returns a Future (already done if
    async_write=False)."""
    flat = _flatten_with_paths(tree)   # device->host copy happens here
    manifest = {
        "step": int(step),
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        "extra": extra or {},
        "n_devices": jax.device_count(),
    }

    def write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        return final

    if async_write:
        return _EXEC.submit(write)
    f: Future = Future()
    f.set_result(write())
    return f


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            manifest = os.path.join(ckpt_dir, name, "manifest.json")
            if os.path.exists(manifest):
                steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like: Params,
            shardings: Optional[Params] = None) -> Params:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional pytree of NamedSharding)
    places leaves directly onto the *current* mesh — this is the elastic
    path: the saved mesh shape is irrelevant because leaves are stored
    logically unsharded."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as npz:
        flat = {k: npz[k] for k in npz.files}

    shard_flat = _flatten_with_paths_structs(shardings) if shardings else {}

    def walk(tree_path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in tree_path)
        arr = flat[key]
        assert tuple(arr.shape) == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        sh = shard_flat.get(key)
        if sh is not None:
            return jax.device_put(arr.astype(leaf.dtype), sh)
        return jax.numpy.asarray(arr.astype(leaf.dtype))

    return jax.tree_util.tree_map_with_path(walk, like)


def _flatten_with_paths_structs(tree: Params) -> Dict[str, Any]:
    flat = {}

    def walk(path, leaf):
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = leaf
        return leaf

    jax.tree_util.tree_map_with_path(
        walk, tree, is_leaf=lambda x: hasattr(x, "spec") or hasattr(x, "devices"))
    return flat
