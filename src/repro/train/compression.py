"""Gradient compression with error feedback.

At 1000+-node scale the gradient all-reduce dominates step time for small
models / large DP degrees.  Casting gradients to bf16 before the
all-reduce halves collective bytes; the *error-feedback* accumulator
(Karimireddy et al. 2019) keeps the quantization error in fp32 and folds
it into the next step, preserving convergence.

``compress_decompress`` is inserted between grad computation and the
optimizer; under pjit the all-reduce XLA inserts for data-parallel grads
then operates on the bf16 values.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


def init_error_state(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Params,
                        err: Optional[Params]) -> Tuple[Params, Params]:
    """Returns (decompressed bf16-rounded grads, new error state)."""
    if err is None:
        err = init_error_state(grads)

    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q = corrected.astype(jnp.bfloat16)          # the wire format
        new_e = corrected - q.astype(jnp.float32)   # residual kept locally
        return q.astype(jnp.float32), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])
