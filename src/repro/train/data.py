"""Deterministic synthetic LM data pipeline.

Fault-tolerance property: the batch for (seed, step, shard) is a pure
function — any node can recompute any other node's shard after a
failure, and restart-at-step-k is bit-exact without data-loader state in
the checkpoint.  Real deployments swap `_tokens_for` for a deterministic
tokenized-shard reader with the same (seed, step, shard) contract.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig, ShapeCell


@dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    vocab: int = 32000
    # a tiny Markov-ish structure so losses actually go down
    pattern_period: int = 17


def _tokens_for(dc: DataConfig, step: int, shard: int, shape) -> np.ndarray:
    rng = np.random.default_rng(
        np.random.SeedSequence([dc.seed, step, shard]))
    b, s = shape
    base = rng.integers(0, dc.vocab, (b, 1))
    drift = rng.integers(1, 5, (b, 1))
    pos = np.arange(s)[None, :]
    noise = rng.integers(0, dc.vocab, (b, s))
    mix = rng.random((b, s)) < 0.25
    toks = (base + drift * (pos % dc.pattern_period)) % dc.vocab
    return np.where(mix, noise, toks).astype(np.int32)


def make_batch(dc: DataConfig, cfg: ArchConfig, cell: ShapeCell, step: int,
               shard: int = 0, n_shards: int = 1) -> Dict[str, np.ndarray]:
    b = cell.global_batch // n_shards
    s = cell.seq_len
    dcv = DataConfig(dc.seed, min(dc.vocab, cfg.vocab), dc.pattern_period)
    if cfg.enc_dec:
        rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step, shard, 7]))
        frames = rng.standard_normal((b, cfg.n_frames, cfg.d_model)).astype(np.float32) * 0.1
        toks = _tokens_for(dcv, step, shard, (b, s))
        return {"frames": frames, "tokens": toks, "labels": toks.copy()}
    if cfg.vlm:
        rng = np.random.default_rng(np.random.SeedSequence([dc.seed, step, shard, 9]))
        patches = rng.standard_normal((b, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.1
        toks = _tokens_for(dcv, step, shard, (b, s - cfg.n_patches))
        return {"tokens": toks, "labels": toks.copy(), "patches": patches}
    toks = _tokens_for(dcv, step, shard, (b, s))
    return {"tokens": toks, "labels": toks.copy()}
