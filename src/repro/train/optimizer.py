"""AdamW optimizer (pure JAX — no external deps) with global-norm
clipping, decoupled weight decay, linear-warmup cosine schedule, and
optional bf16 gradient compression with error feedback (see
compression.py).

Optimizer state is a pytree congruent with the parameters; its sharding
is derived from the parameter sharding in models/sharding.py (with
ZeRO-1-style extra sharding over the data axis when ``cfg.zero1``)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array          # () int32
    mu: Params               # first moment (fp32)
    nu: Params               # second moment (fp32)


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_opt_state(params: Params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def _schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree: Params) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(cfg: OptConfig, grads: Params, state: AdamWState,
                 params: Params) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / c1
        vhat = v / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (standard practice)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, AdamWState(step, new_m, new_v), metrics
