"""replint — AST trace-safety lint for the JAX/Pallas substrate (Level 2).

A static pass over Python source that flags the pitfalls which only
surface at trace/serving time in this codebase: Python control flow on
traced values, host synchronisations inside device code, int literals
past the int32 lattice, shapes derived from traced counts, and
``shard_map`` calls that never took an explicit ``check_rep`` decision.

The lint is *scoped*: most rules only apply inside functions the
analyzer believes are traced.  A function is traced when any of
these hold, closed under the intra-module call graph:

* it is decorated with a tracer (``jax.jit``, ``vmap``, ``pmap``,
  ``shard_map``, ``pallas_call``, possibly through ``partial``);
* its name is passed to a tracer call site anywhere in the module
  (``jax.jit(self._program, ...)``, ``lax.scan(body, ...)``,
  ``shard_map(fn, mesh, ...)``);
* its name marks it as device code (``device_*`` / ``_device*``);
* it is called (by simple name or ``self.name``) from a traced function.

Taint model (which expressions hold *traced values*): results of
``jnp.* / lax.* / pl.*`` calls and of calls to ``device_*``-named
functions are tainted; taint propagates through arithmetic,
comparisons, subscripts and assignments.  Calls to other local helpers
are *untainted* even when those helpers are themselves traced — in this
codebase they return trace-static metadata (column tuples, bound flags),
and branching on their results is the supported idiom.  ``.shape`` /
``.ndim`` / ``.dtype`` / ``.size`` attribute reads are always untainted
(static under trace).  ``.n`` / ``.data`` / ``.overflow`` reads are
always tainted: those are the JBindings device-value attributes, and a
shape derived from ``.n`` is the classic retrace bug.  Function
parameters are untainted — jitted entry points routinely take static
arguments, and the rules target values that are *provably*
device-resident, not possibly so.  A Python list/tuple/set holding
traced values is tracked as a *container* (level 2): iterating or
truth-testing it is host-side and fine, but indexing it yields a traced
value and handing it to ``np.*`` (e.g. ``np.stack(masks)``) is still a
host sync.

Suppressions: ``# replint: disable=<rule> -- <justification>`` on the
offending line, or standing alone on the line directly above it.  A
directive without the ``-- <justification>`` tail is itself a finding
(``bare-suppression``) and cannot be suppressed.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = ["LintFinding", "RULES", "lint_source", "lint_file", "lint_paths"]

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)

#: rule id -> one-line description (the lint catalog; docs/architecture.md
#: mirrors this table).
RULES: Dict[str, str] = {
    "traced-branch":
        "Python if/while/for/ternary on a traced value inside a traced "
        "function — concretizes the tracer (ConcretizationTypeError) or "
        "bakes one branch into the compiled program.",
    "host-sync":
        "Host synchronisation inside a traced function: .item(), or "
        "np.* / float() / int() / bool() applied to a traced value — "
        "blocks on device transfer and breaks tracing.",
    "int32-overflow":
        "Integer literal outside int32 range inside a traced function — "
        "silently promotes the lattice past the engine's int32 id space.",
    "nonstatic-shape":
        "Array constructor whose shape derives from a traced value "
        "(e.g. a JBindings .n) — shapes must be static under jit; this "
        "retraces per distinct value or fails outright.",
    "shard-map-check-rep":
        "shard_map call without an explicit check_rep decision — "
        "replication checking must be chosen deliberately (and the "
        "choice justified) at every call site.",
    "bare-suppression":
        "replint suppression without a '-- <justification>' tail — "
        "unexplained suppressions are not allowed.",
}

#: names that establish a traced context when used as a decorator or when
#: a function is passed to them at a call site.
_TRACER_NAMES = {
    "jit", "vmap", "pmap", "shard_map", "pallas_call", "scan",
    "while_loop", "fori_loop", "cond", "checkpoint", "remat", "custom_vjp",
}

#: module aliases whose call results are device (traced) values.
_DEVICE_MODULES = {"jnp", "lax", "pl", "pltpu"}

#: host modules whose calls on traced values force a device->host sync.
_HOST_MODULES = {"np", "numpy"}

#: attribute reads that are static under trace (never tainted).
_STATIC_ATTRS = {"shape", "ndim", "dtype", "size"}

#: attribute reads that are always traced values (JBindings convention).
_TAINTED_ATTRS = {"n", "data", "overflow"}

#: array constructors whose first positional / ``shape=`` argument must be
#: static under trace.
_SHAPE_FNS = {"zeros", "ones", "full", "empty", "arange", "broadcast_to"}


@dataclass(frozen=True)
class LintFinding:
    """One lint violation at a source location."""

    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule}: {self.message}"


def _is_tracer_name(name: Optional[str]) -> bool:
    if not name:
        return False
    return (name in _TRACER_NAMES
            or name.endswith("shard_map") or name.endswith("smap"))


def _terminal(node: ast.expr) -> Optional[str]:
    """The last dotted component of a Name/Attribute chain, else None."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _root(node: ast.expr) -> Optional[str]:
    """The base Name of a Name/Attribute chain (``np`` in ``np.a.b``)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _is_tracer_decorator(dec: ast.expr) -> bool:
    if isinstance(dec, (ast.Name, ast.Attribute)):
        return _is_tracer_name(_terminal(dec))
    if isinstance(dec, ast.Call):
        if _is_tracer_name(_terminal(dec.func)):
            return True
        # functools.partial(jax.jit, ...) style
        if _terminal(dec.func) == "partial":
            return any(_is_tracer_name(_terminal(a))
                       for a in dec.args
                       if isinstance(a, (ast.Name, ast.Attribute)))
    return False


class _ModuleScan(ast.NodeVisitor):
    """First pass: function registry, traced seeds, call graph, and the
    module-wide ``shard-map-check-rep`` check."""

    def __init__(self, path: str) -> None:
        self.path = path
        self.funcs: Dict[str, ast.AST] = {}
        self.seeds: Set[str] = set()
        self.callees: Dict[str, Set[str]] = {}
        self.findings: List[LintFinding] = []
        self._stack: List[str] = []

    # -- functions -----------------------------------------------------------
    def _handle_def(self, node) -> None:
        self.funcs[node.name] = node
        if node.name.startswith("device_") or node.name.startswith("_device"):
            self.seeds.add(node.name)
        if any(_is_tracer_decorator(d) for d in node.decorator_list):
            self.seeds.add(node.name)
        self.callees.setdefault(node.name, set())
        self._stack.append(node.name)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _handle_def
    visit_AsyncFunctionDef = _handle_def

    # -- call sites ----------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        term = _terminal(node.func)
        if self._stack and term:
            self.callees[self._stack[-1]].add(term)
        if _is_tracer_name(term):
            # a function object handed to a tracer is traced
            for arg in node.args:
                at = _terminal(arg)
                if at:
                    self.seeds.add(at)
        if term is not None and term.endswith("shard_map"):
            if not any(kw.arg == "check_rep" for kw in node.keywords):
                self.findings.append(LintFinding(
                    self.path, node.lineno, node.col_offset,
                    "shard-map-check-rep",
                    "shard_map call without an explicit check_rep= decision"))
        self.generic_visit(node)

    def traced(self) -> Set[str]:
        """Seeds closed under the intra-module call graph."""
        traced = set(self.seeds)
        changed = True
        while changed:
            changed = False
            for fn in list(traced):
                for callee in self.callees.get(fn, ()):
                    if callee in self.funcs and callee not in traced:
                        traced.add(callee)
                        changed = True
        return traced


#: taint lattice: 0 = clean (host / trace-static), 1 = traced device
#: value, 2 = host container holding traced values.
_CLEAN, _TRACED, _CONTAINER = 0, 1, 2


class _TracedChecker:
    """Second pass: taint-tracking walk over one traced function body."""

    def __init__(self, path: str, traced: Set[str],
                 findings: List[LintFinding]) -> None:
        self.path = path
        self.traced = traced
        self.findings = findings
        self.tainted: Dict[str, int] = {}

    def _emit(self, node: ast.AST, rule: str, message: str) -> None:
        self.findings.append(LintFinding(
            self.path, node.lineno, node.col_offset, rule, message))

    # -- statements ----------------------------------------------------------
    def run(self, body: Sequence[ast.stmt]) -> None:
        for stmt in body:
            self.stmt(stmt)

    def stmt(self, s: ast.stmt) -> None:
        if isinstance(s, ast.Assign):
            t = self.expr(s.value)
            for target in s.targets:
                self._bind(target, t)
        elif isinstance(s, ast.AnnAssign):
            t = self.expr(s.value) if s.value is not None else _CLEAN
            self._bind(s.target, t)
        elif isinstance(s, ast.AugAssign):
            t = self.expr(s.value)
            if isinstance(s.target, ast.Name):
                prev = self.tainted.get(s.target.id, _CLEAN)
                if max(t, prev):
                    self.tainted[s.target.id] = max(t, prev)
        elif isinstance(s, ast.If):
            if self.expr(s.test) == _TRACED:
                self._emit(s, "traced-branch",
                           "Python `if` on a traced value")
            self.run(s.body)
            self.run(s.orelse)
        elif isinstance(s, ast.While):
            if self.expr(s.test) == _TRACED:
                self._emit(s, "traced-branch",
                           "Python `while` on a traced value")
            self.run(s.body)
            self.run(s.orelse)
        elif isinstance(s, ast.For):
            it = self.expr(s.iter)
            if it == _TRACED:
                self._emit(s, "traced-branch",
                           "Python `for` iterating a traced value")
            # iterating a traced array or a container of traced values
            # binds traced elements either way
            self._bind(s.target, _TRACED if it else _CLEAN)
            self.run(s.body)
            self.run(s.orelse)
        elif isinstance(s, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs inherit the closure's taint
            self.run(s.body)
        elif isinstance(s, ast.Return):
            if s.value is not None:
                self.expr(s.value)
        elif isinstance(s, ast.Expr):
            self.expr(s.value)
        elif isinstance(s, ast.With):
            for item in s.items:
                self.expr(item.context_expr)
            self.run(s.body)
        elif isinstance(s, ast.Try):
            self.run(s.body)
            for h in s.handlers:
                self.run(h.body)
            self.run(s.orelse)
            self.run(s.finalbody)
        elif isinstance(s, (ast.Assert, ast.Raise, ast.Delete)):
            for child in ast.iter_child_nodes(s):
                if isinstance(child, ast.expr):
                    self.expr(child)
        # pass/import/global/... carry no expressions we track

    def _bind(self, target: ast.expr, taint: int) -> None:
        if isinstance(target, ast.Name):
            if taint:
                self.tainted[target.id] = taint
            else:
                self.tainted.pop(target.id, None)
        elif isinstance(target, (ast.Tuple, ast.List)):
            # unpacking a container of traced values binds traced names
            elt = _TRACED if taint else _CLEAN
            for e in target.elts:
                self._bind(e, elt)
        elif isinstance(target, (ast.Subscript, ast.Attribute, ast.Starred)):
            self.expr(target)

    # -- expressions ---------------------------------------------------------
    def expr(self, e: Optional[ast.expr]) -> int:
        """Walk an expression, emitting findings; returns its taint level."""
        if e is None:
            return _CLEAN
        if isinstance(e, ast.Name):
            return self.tainted.get(e.id, _CLEAN)
        if isinstance(e, ast.Constant):
            if isinstance(e.value, int) and not isinstance(e.value, bool):
                if e.value > INT32_MAX or e.value < INT32_MIN:
                    self._emit(e, "int32-overflow",
                               f"int literal {e.value} exceeds int32 range "
                               "in traced code")
            return _CLEAN
        if isinstance(e, ast.Attribute):
            base = self.expr(e.value)
            if e.attr in _STATIC_ATTRS:
                return _CLEAN
            if e.attr in _TAINTED_ATTRS:
                return _TRACED
            return base
        if isinstance(e, ast.Call):
            return self._call(e)
        if isinstance(e, ast.IfExp):
            if self.expr(e.test) == _TRACED:
                self._emit(e, "traced-branch",
                           "conditional expression on a traced value")
            return max(self.expr(e.body), self.expr(e.orelse))
        if isinstance(e, ast.BinOp):
            return max(self.expr(e.left), self.expr(e.right))
        if isinstance(e, ast.UnaryOp):
            return self.expr(e.operand)
        if isinstance(e, ast.Compare):
            t = self.expr(e.left)
            for cmp in e.comparators:
                t = max(self.expr(cmp), t)
            # `x is None` / `in` on a traced operand is a host identity /
            # membership test over Python structure, not device compute
            if all(isinstance(op, (ast.Is, ast.IsNot, ast.In, ast.NotIn))
                   for op in e.ops):
                return _CLEAN
            return min(t, _TRACED)
        if isinstance(e, ast.BoolOp):
            t = _CLEAN
            for v in e.values:
                t = max(self.expr(v), t)
            return min(t, _TRACED)
        if isinstance(e, ast.Subscript):
            val = self.expr(e.value)
            sub = self.expr(e.slice)
            if val == _CONTAINER:
                # slicing a container keeps the container level; indexing
                # it yields one of its traced elements
                val = _CONTAINER if isinstance(e.slice, ast.Slice) \
                    else _TRACED
            return max(val, min(sub, _TRACED))
        if isinstance(e, (ast.Tuple, ast.List, ast.Set)):
            t = _CLEAN
            for elt in e.elts:
                t = max(self.expr(elt), t)
            return _CONTAINER if t else _CLEAN
        if isinstance(e, ast.Dict):
            t = _CLEAN
            for k in e.keys:
                if k is not None:
                    t = max(self.expr(k), t)
            for v in e.values:
                t = max(self.expr(v), t)
            return _CONTAINER if t else _CLEAN
        if isinstance(e, ast.Slice):
            t = self.expr(e.lower)
            t = max(self.expr(e.upper), t)
            return max(self.expr(e.step), t)
        if isinstance(e, ast.Starred):
            return self.expr(e.value)
        if isinstance(e, ast.Lambda):
            self.expr(e.body)
            return _CLEAN
        if isinstance(e, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                          ast.DictComp)):
            t = _CLEAN
            for gen in e.generators:
                gt = self.expr(gen.iter)
                self._bind(gen.target, _TRACED if gt else _CLEAN)
                for cond in gen.ifs:
                    self.expr(cond)
                t = max(gt, t)
            if isinstance(e, ast.DictComp):
                t = max(self.expr(e.key), t)
                t = max(self.expr(e.value), t)
            else:
                t = max(self.expr(e.elt), t)
            return _CONTAINER if t else _CLEAN
        # fallback: max over child expressions
        t = _CLEAN
        for child in ast.iter_child_nodes(e):
            if isinstance(child, ast.expr):
                t = max(self.expr(child), t)
        return t

    def _call(self, e: ast.Call) -> int:
        func_taint = _CLEAN
        if isinstance(e.func, (ast.Name, ast.Attribute)):
            # walking the func expr also handles taint of `x.sum` etc.
            func_taint = self.expr(e.func)
        arg_taints = [self.expr(a) for a in e.args]
        kw_taints = {kw.arg: self.expr(kw.value) for kw in e.keywords}
        any_arg = any(arg_taints) or any(kw_taints.values())

        term = _terminal(e.func)
        root = _root(e.func)

        # host syncs -----------------------------------------------------
        if term == "item":
            self._emit(e, "host-sync",
                       ".item() forces a device->host sync in traced code")
        elif root in _HOST_MODULES and any_arg:
            self._emit(e, "host-sync",
                       f"{root}.{term}() on a traced value forces a host "
                       "sync and escapes the tracer")
        elif isinstance(e.func, ast.Name) and e.func.id in (
                "float", "int", "bool") and any_arg:
            self._emit(e, "host-sync",
                       f"{e.func.id}() on a traced value forces a host sync")

        # non-static shapes ----------------------------------------------
        if term in _SHAPE_FNS:
            shape_args = []
            if e.args:
                shape_args.append(arg_taints[0])
            if "shape" in kw_taints:
                shape_args.append(kw_taints["shape"])
            if any(shape_args):
                self._emit(e, "nonstatic-shape",
                           f"{term}() shape derives from a traced value — "
                           "shapes must be static under jit")
        elif term == "reshape" and isinstance(e.func, ast.Attribute) \
                and _root(e.func) not in (_HOST_MODULES | _DEVICE_MODULES
                                          | {"jax"}) and any_arg:
            self._emit(e, "nonstatic-shape",
                       ".reshape() target derives from a traced value — "
                       "shapes must be static under jit")

        # result taint: only calls that provably build device values.
        # Other local helpers — even traced ones — return trace-static
        # metadata in this codebase (column tuples, bound flags), and
        # branching on their results is fine.
        if root in _DEVICE_MODULES:
            return _TRACED
        if term and (term.startswith("device_") or term.startswith("_device")):
            return _TRACED
        return min(func_taint, _TRACED)


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------

_SUPPRESS_RE = re.compile(
    r"#\s*replint:\s*disable=([A-Za-z0-9_,-]+)\s*(?:--\s*(\S.*))?\s*$")


def _scan_suppressions(
    source: str, path: str,
) -> Tuple[Dict[int, Set[str]], List[LintFinding]]:
    """Map of line -> suppressed rules, plus bare-suppression findings.

    An inline directive covers its own line; a directive on a line of its
    own covers the line below it.
    """
    suppressed: Dict[int, Set[str]] = {}
    findings: List[LintFinding] = []
    for lineno, text in enumerate(source.splitlines(), start=1):
        m = _SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        justified = m.group(2) is not None
        if not justified:
            findings.append(LintFinding(
                path, lineno, m.start(), "bare-suppression",
                "suppression lacks a '-- <justification>' tail"))
        target = lineno + 1 if text.lstrip().startswith("#") else lineno
        suppressed.setdefault(target, set()).update(rules)
    return suppressed, findings


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> List[LintFinding]:
    """Lint one module's source text; returns findings sorted by location."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as exc:
        return [LintFinding(path, exc.lineno or 1, exc.offset or 0,
                            "syntax-error", f"cannot parse: {exc.msg}")]

    scan = _ModuleScan(path)
    scan.visit(tree)
    traced = scan.traced()

    findings: List[LintFinding] = list(scan.findings)
    for name in sorted(traced):
        node = scan.funcs.get(name)
        if node is None:
            continue  # seed referenced a name defined elsewhere
        checker = _TracedChecker(path, traced, findings)
        checker.run(node.body)

    suppressed, bare = _scan_suppressions(source, path)
    kept = [
        f for f in findings
        if not (f.rule in suppressed.get(f.line, ())
                and f.rule != "bare-suppression")
    ]
    kept.extend(bare)
    # nested traced defs are visited both standalone and through their
    # enclosing function — deduplicate by location+rule
    unique = {(f.line, f.col, f.rule): f for f in kept}
    return sorted(unique.values(), key=lambda f: (f.line, f.col, f.rule))


def lint_file(path) -> List[LintFinding]:
    p = Path(path)
    return lint_source(p.read_text(encoding="utf-8"), str(p))


def lint_paths(paths: Iterable) -> List[LintFinding]:
    """Lint files and/or directory trees (``*.py``, sorted, recursive)."""
    findings: List[LintFinding] = []
    for entry in paths:
        p = Path(entry)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings.extend(lint_file(f))
    return findings
