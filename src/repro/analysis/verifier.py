"""Plan/IR static verifier: pre-execution invariant checks.

The compiler (:mod:`repro.core.compiler`) and the static-shape executors
(:mod:`repro.core.jexec`, :mod:`repro.core.distributed`) share a set of
invariants that nothing used to *check* — a violated one only surfaced
as a wrong answer in the differential fuzz or a silent
``device_fallbacks`` increment.  This module is the fence: a
non-executing pass over a compiled :class:`~repro.core.compiler.Plan` /
:class:`~repro.core.compiler.CorePlan` (and, at the executor level, the
capacity-slot accounting of a built ``PlanExecutor`` /
``DistributedExecutor``) that turns those runtime fuzz findings into
structured pre-execution failures.

Invariants (rule name → what must hold):

``cross-join``          join order never takes an unforced cross product:
                        a step sharing no variable with the accumulated
                        set is only legal when NO remaining step connects
                        (Algorithm 4's discipline, shared by the estimate
                        enumerator).  Structurally forced cross products
                        (disconnected BGPs, joins of var-disjoint groups)
                        are warnings, not errors.
``sf-zero-step``        an SF=0 scan must have been short-circuited to
                        the statistics-only empty plan, never executed.
``empty-flag``          ``Plan.empty``/``CorePlan.empty`` agree with the
                        tree (an empty plan carries no steps; a CorePlan
                        is empty iff its root collapsed to ``EmptySeg``).
``planner-tag``         ``Plan.planner`` names a real join-order planner.
``sentinel-collision``  bound term ids never collide with the reserved
                        sentinels: valid ids are dense ``[0, n)`` and
                        template placeholders live at
                        ``PLACEHOLDER_BASE - i``; anything in between
                        (UNBOUND = -1, MISSING_TERM = -2, the executor
                        NULL sentinels) must never appear as a bound term.
``table-choice``        a ``ScanStep``'s recorded (kind, p2, sf, size)
                        match the catalog's statistics — stale or
                        fabricated stats would corrupt capacity seeding
                        and join ordering.
``extvp-materialized``  a selected ExtVP table actually exists in the
                        catalog's materialized (SF ≤ τ) set; SF = 0
                        choices are exempt (they short-circuit).
``extvp-partner``       an ExtVP^kind[p|p2] choice has its partner
                        pattern (predicate p2, matching correlation) in
                        the same BGP — a semi-join reduction against an
                        absent partner silently drops rows.
``flat-offset``         ``CorePlan.flat`` is exactly the concatenation of
                        its BGP segments' steps at their recorded
                        ``start`` offsets (what constant re-binding and
                        the runtime bounds array index into).
``cap-slots``           the executor's capacity vector has exactly one
                        slot per flat step, one per ``CombineSeg``
                        (contiguous, behind the flat slots, bijective via
                        ``_comb_index``) and one modifier resize slot iff
                        the spine needs it — the overflow flags the
                        retry protocol reads are positional over this
                        layout.
``modifier-slice``      OFFSET/LIMIT are non-negative.
``filter-var``          (warning) FILTER / OPTIONAL-condition variables
                        are bound by the segment they attach to.  A miss
                        is legal SPARQL — the engines evaluate unbound
                        filter variables as UNBOUND — so this diagnoses
                        rather than rejects.
``projection-var``      (warning) projection / ORDER BY variables exist
                        in the core's output; missing ones are
                        UNBOUND-filled on every engine.

``verify_prepared`` dispatches over the engine's ``PreparedQuery``
shapes (duck-typed, no engine import): executor-backed prepared queries
get the full core + cap-slot pass, eager BGP plans the flat-plan pass,
host operator trees nothing (they are interpreted, not compiled).
Wired into ``Engine._build`` behind ``RuntimeConfig(verify_plans=...)``
/ ``REPRO_RT_VERIFY_PLANS`` and surfaced by ``Engine.explain()``.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Tuple

from repro.core.algebra import correlations
from repro.core.compiler import (
    BGPSeg, CombineSeg, CorePlan, CoreSeg, EmptySeg, FilterSeg, Plan,
    seg_vars,
)
from repro.core.modifiers import ModifierSpine, filter_variables

__all__ = [
    "PlanDiagnostic", "PlanVerificationError", "VerificationReport",
    "verify_plan", "verify_core", "verify_executor", "verify_prepared",
    "ALL_CHECKS",
]

#: ids below this bound are template placeholders (engine/template.py's
#: reserved band); kept as a literal here so the core-level verifier does
#: not import the engine layer (the value is pinned by tests).
PLACEHOLDER_BASE = -1000

ERROR = "error"
WARNING = "warning"

#: every invariant this module can check, in report order
ALL_CHECKS: Tuple[str, ...] = (
    "cross-join", "sf-zero-step", "empty-flag", "planner-tag",
    "sentinel-collision", "table-choice", "extvp-materialized",
    "extvp-partner", "flat-offset", "cap-slots", "modifier-slice",
    "filter-var", "projection-var",
)

_PLANNERS = ("greedy", "estimate")
_EXTVP_KINDS = ("SS", "SO", "OS")


@dataclass(frozen=True)
class PlanDiagnostic:
    """One verifier finding: which invariant, how bad, where."""

    rule: str
    severity: str                # "error" | "warning"
    message: str
    location: str = ""           # e.g. "step 2", "seg@4", "spine"

    def __str__(self) -> str:
        at = f" at {self.location}" if self.location else ""
        return f"[{self.severity}] {self.rule}{at}: {self.message}"


class PlanVerificationError(Exception):
    """Raised by :meth:`VerificationReport.raise_if_failed` when a plan
    violates an error-severity invariant.  Carries the structured
    diagnostics so callers (and tests) can assert on rules, not on
    message strings."""

    def __init__(self, diagnostics: Sequence[PlanDiagnostic]):
        self.diagnostics = tuple(diagnostics)
        super().__init__(
            "plan verification failed:\n"
            + "\n".join(str(d) for d in self.diagnostics))

    def rules(self) -> Tuple[str, ...]:
        return tuple(d.rule for d in self.diagnostics)


@dataclass
class VerificationReport:
    """Outcome of one verification pass: every diagnostic plus the list
    of checks that ran (so "ok" is distinguishable from "unverifiable")."""

    diagnostics: Tuple[PlanDiagnostic, ...] = ()
    checks: Tuple[str, ...] = ()

    @property
    def errors(self) -> Tuple[PlanDiagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == ERROR)

    @property
    def warnings(self) -> Tuple[PlanDiagnostic, ...]:
        return tuple(d for d in self.diagnostics if d.severity == WARNING)

    @property
    def ok(self) -> bool:
        """No error-severity findings (warnings do not fail a plan)."""
        return not self.errors

    def raise_if_failed(self) -> "VerificationReport":
        if not self.ok:
            raise PlanVerificationError(self.errors)
        return self

    def rules(self) -> Tuple[str, ...]:
        return tuple(d.rule for d in self.diagnostics)

    def describe(self) -> str:
        """One ``Engine.explain()`` line."""
        if not self.checks:
            return "verify: skipped (host-interpreted operator tree)"
        if not self.diagnostics:
            return f"verify: ok ({len(self.checks)} checks)"
        if self.ok:
            return (f"verify: ok ({len(self.checks)} checks, "
                    f"{len(self.warnings)} warning(s): "
                    + "; ".join(str(d) for d in self.warnings) + ")")
        return ("verify: FAILED — "
                + "; ".join(str(d) for d in self.errors))


class _Collector:
    def __init__(self) -> None:
        self.diags: List[PlanDiagnostic] = []

    def error(self, rule: str, message: str, location: str = "") -> None:
        self.diags.append(PlanDiagnostic(rule, ERROR, message, location))

    def warn(self, rule: str, message: str, location: str = "") -> None:
        self.diags.append(PlanDiagnostic(rule, WARNING, message, location))


# ---------------------------------------------------------------------------
# Flat-plan checks
# ---------------------------------------------------------------------------

def _sentinel_error(i: int, pos: str, tid: int, c: _Collector) -> None:
    c.error("sentinel-collision",
            f"bound {pos}-term id {tid} collides with the "
            f"reserved sentinel band (-1 > id > {PLACEHOLDER_BASE})",
            f"step {i}")


def _check_plan(plan: Plan, catalog, c: _Collector,
                where: str = "") -> None:
    """Per-step invariants plus the Algorithm 4 join-order discipline.

    This runs on every ``prepare()`` cache miss when ``verify_plans`` is
    on, so the per-step checks are fused into one pass with the variable
    tests inlined (``is_var`` per term plus a helper call per check adds
    up to most of the verifier's cost on small plans):

    * sentinel collisions — a bound term id is a dictionary id (>= 0) or
      a template placeholder (the reserved band below
      ``PLACEHOLDER_BASE``); the sentinel gap in between (UNBOUND,
      MISSING_TERM, the executor NULL keys) must never appear bound;
    * table choice — recorded (kind, p2, sf, size) match the catalog;
    * ExtVP materialization and partner-pattern presence;
    * join order — a cross product is only taken when no remaining
      pattern is join-connected (shared by the estimate enumerator).
    """
    loc = where or "plan"
    if plan.planner not in _PLANNERS:
        c.error("planner-tag",
                f"unknown planner tag {plan.planner!r} (expected one of "
                f"{_PLANNERS})", loc)
    if plan.empty:
        if plan.steps:
            c.error("empty-flag",
                    f"statistics-empty plan carries {len(plan.steps)} "
                    "scan steps", loc)
        return
    steps = plan.steps
    n = len(steps)
    vsets: List[frozenset] = []     # per-step variable sets (join order)
    by_pred: dict = {}              # bound predicate -> [(idx, tp)]
    pending = []                    # ExtVP partner checks, deferred until
                                    # by_pred covers the whole plan
    for i in range(n):
        step = steps[i]
        tp = step.tp
        t_s, t_p, t_o = tp.s, tp.p, tp.o
        vs = []
        # unrolled per-term scan (variable collection + sentinel check):
        # a (pos, term) tuple loop here costs four allocations per step
        if isinstance(t_s, str):
            vs.append(t_s)
        else:
            tid = int(t_s)
            if 0 > tid > PLACEHOLDER_BASE and step.sf != 0.0:
                _sentinel_error(i, "s", tid, c)
        p_var = isinstance(t_p, str)
        if p_var:
            vs.append(t_p)
        else:
            tid = int(t_p)
            if 0 > tid > PLACEHOLDER_BASE and step.sf != 0.0:
                _sentinel_error(i, "p", tid, c)
            by_pred.setdefault(tid, []).append((i, tp))
        if isinstance(t_o, str):
            vs.append(t_o)
        else:
            tid = int(t_o)
            if 0 > tid > PLACEHOLDER_BASE and step.sf != 0.0:
                _sentinel_error(i, "o", tid, c)
        vsets.append(frozenset(vs))
        if step.sf == 0.0:
            c.error("sf-zero-step",
                    "SF=0 scan in a non-empty plan: the statistics prove "
                    "the result empty, the plan must short-circuit",
                    f"step {i}")
            continue
        if step.uses_tt:
            if step.kind is not None or step.p2 is not None:
                c.error("table-choice",
                        "a triples-table step cannot carry an ExtVP "
                        "choice", f"step {i}")
            continue
        if p_var:
            c.error("table-choice",
                    "unbound predicate without uses_tt (no table to scan)",
                    f"step {i}")
            continue
        p = int(tp.p)
        if step.kind is None:
            # VP scan: recorded stats must be the VP table's
            if step.sf != 1.0 or step.size != catalog.vp_size(p):
                c.error("table-choice",
                        f"VP step records sf={step.sf} size={step.size}, "
                        f"catalog has sf=1.0 size={catalog.vp_size(p)}",
                        f"step {i}")
            continue
        if step.kind not in _EXTVP_KINDS or step.p2 is None:
            c.error("table-choice",
                    f"ExtVP kind {step.kind!r} with partner {step.p2!r} "
                    "is not a precomputed correlation (SS/SO/OS + "
                    "partner)", f"step {i}")
            continue
        p2 = int(step.p2)
        cat_sf = catalog.sf(step.kind, p, p2)
        cat_size = catalog.size(step.kind, p, p2)
        if step.sf != cat_sf or step.size != cat_size:
            c.error("table-choice",
                    f"ExtVP^{step.kind}[{p}|{p2}] records sf={step.sf} "
                    f"size={step.size}, catalog has sf={cat_sf} "
                    f"size={cat_size}", f"step {i}")
        if step.sf > 0.0 and (step.kind, p, p2) not in catalog.extvp.tables:
            c.error("extvp-materialized",
                    f"ExtVP^{step.kind}[{p}|{p2}] (sf={step.sf:.3g}) is "
                    f"not in the catalog's materialized set (threshold "
                    f"τ={catalog.extvp.threshold}); the scan would "
                    "silently read the full VP table while the plan "
                    "credits the reduced size", f"step {i}")
        pending.append((i, step, tp, p, p2))
    # the reduction's partner pattern must be in the same BGP, with the
    # matching correlation — otherwise the semi-join filter drops rows
    # the query should produce
    for i, step, tp, p, p2 in pending:
        for j, other_tp in by_pred.get(p2, ()):
            if j != i and step.kind in correlations(tp, other_tp):
                break
        else:
            c.error("extvp-partner",
                    f"ExtVP^{step.kind}[{p}|{p2}] has no partner pattern "
                    f"with predicate {p2} and a {step.kind} correlation "
                    "in the plan", f"step {i}")
    # Algorithm 4 / estimate-enumerator discipline: a cross product is
    # only taken when no remaining pattern is join-connected
    if n > 1:
        acc = set(vsets[0])
        for i in range(1, n):
            vars_i = vsets[i]
            if not (vars_i & acc):
                connected_later = [
                    j for j in range(i + 1, n) if vsets[j] & acc]
                if connected_later:
                    c.error("cross-join",
                            f"step {i} shares no variable with the joined "
                            f"prefix while step(s) {connected_later} do — "
                            "an unforced cross product (planner "
                            f"{plan.planner!r} must prefer connected "
                            "steps)",
                            (where + " " if where else "") + f"step {i}")
                # else: the BGP is genuinely disconnected here — forced,
                # and bounded by the executor's capacity protocol
            acc |= vars_i


def verify_plan(plan: Plan, catalog,
                spine: Optional[ModifierSpine] = None
                ) -> VerificationReport:
    """Verify one flat :class:`Plan` (a single BGP pipeline), optionally
    with the modifier spine that will run over it."""
    c = _Collector()
    _check_plan(plan, catalog, c)
    if spine is not None:
        _check_spine(spine, plan.vars, c)
    return VerificationReport(tuple(c.diags), ALL_CHECKS)


# ---------------------------------------------------------------------------
# Core (segment-tree) checks
# ---------------------------------------------------------------------------

def _walk_bgp_segs(seg: CoreSeg, out: List[BGPSeg]) -> None:
    """BGP segments in flat-offset assignment order (compile_core's
    ``assign`` traversal: depth-first, left before right)."""
    if isinstance(seg, BGPSeg):
        out.append(seg)
    elif isinstance(seg, FilterSeg):
        _walk_bgp_segs(seg.child, out)
    elif isinstance(seg, CombineSeg):
        _walk_bgp_segs(seg.left, out)
        _walk_bgp_segs(seg.right, out)


def _walk_combines(seg: CoreSeg, out: List[CombineSeg]) -> None:
    """Combine segments in evaluation (post-) order — the executor's
    ``seed`` order, which fixes their capacity-slot indices."""
    if isinstance(seg, FilterSeg):
        _walk_combines(seg.child, out)
    elif isinstance(seg, CombineSeg):
        _walk_combines(seg.left, out)
        _walk_combines(seg.right, out)
        out.append(seg)


def _expr_vars(expr) -> Tuple[str, ...]:
    return filter_variables((expr,))


def _tree_vars(seg: CoreSeg, cache: dict) -> frozenset:
    """Bound-variable set of a segment, memoized by node identity —
    ``seg_vars`` recurses from scratch at every call, which turns the
    per-node checks below into O(n²) on deep UNION/OPTIONAL chains."""
    v = cache.get(id(seg))
    if v is None:
        if isinstance(seg, FilterSeg):
            v = _tree_vars(seg.child, cache)
        elif isinstance(seg, CombineSeg):
            v = _tree_vars(seg.left, cache) | _tree_vars(seg.right, cache)
        else:
            v = frozenset(seg_vars(seg))
        cache[id(seg)] = v
    return v


def _check_tree(seg: CoreSeg, c: _Collector, vcache: dict,
                path: str = "root") -> None:
    if isinstance(seg, (EmptySeg, BGPSeg)):
        return
    if isinstance(seg, FilterSeg):
        bound = _tree_vars(seg.child, vcache)
        loose = [v for v in _expr_vars(seg.expr) if v not in bound]
        if loose:
            c.warn("filter-var",
                   f"FILTER references {loose} which the segment below "
                   "never binds; the expression evaluates with UNBOUND "
                   "there (legal SPARQL, usually a query bug)", path)
        _check_tree(seg.child, c, vcache, path + ".child")
        return
    assert isinstance(seg, CombineSeg)
    lv = _tree_vars(seg.left, vcache)
    rv = _tree_vars(seg.right, vcache)
    if seg.kind in ("join", "left") and not (lv & rv) and lv and rv:
        c.warn("cross-join",
               f"{seg.kind} combine of variable-disjoint operands — a "
               "structurally forced cross product (bounded by the "
               "combine's capacity slot)", path)
    if seg.expr is not None:
        bound = lv | rv
        loose = [v for v in _expr_vars(seg.expr) if v not in bound]
        if loose:
            c.warn("filter-var",
                   f"OPTIONAL condition references {loose} which neither "
                   "operand binds; it evaluates with UNBOUND", path)
    _check_tree(seg.left, c, vcache, path + ".left")
    _check_tree(seg.right, c, vcache, path + ".right")


def _check_flat_offsets(core: CorePlan, segs: List[BGPSeg],
                        c: _Collector) -> None:
    offset = 0
    for k, seg in enumerate(segs):
        n = len(seg.plan.steps)
        if seg.start != offset:
            c.error("flat-offset",
                    f"BGP segment {k} records start={seg.start}, "
                    f"traversal order implies {offset}", f"seg@{seg.start}")
        window = core.flat.steps[seg.start: seg.start + n]
        if len(window) != n or any(a is not b for a, b
                                   in zip(window, seg.plan.steps)):
            c.error("flat-offset",
                    f"flat steps [{seg.start}, {seg.start + n}) are not "
                    f"segment {k}'s steps — constant re-binding would "
                    "write the wrong bounds rows", f"seg@{seg.start}")
        offset += n
    if offset != len(core.flat.steps):
        c.error("flat-offset",
                f"flat plan has {len(core.flat.steps)} steps, segments "
                f"account for {offset}")


def _check_spine(spine: ModifierSpine, out_vars: Sequence[str],
                 c: _Collector) -> None:
    if spine.offset < 0 or (spine.limit is not None and spine.limit < 0):
        c.error("modifier-slice",
                f"negative slice window (offset={spine.offset}, "
                f"limit={spine.limit})", "spine")
    bound = set(out_vars)
    loose = [v for v in filter_variables(spine.filters) if v not in bound]
    if loose:
        c.warn("filter-var",
               f"spine FILTER references {loose} which the core never "
               "binds; rows evaluate with UNBOUND there", "spine")
    if spine.project is not None:
        missing = [v for v in spine.project if v not in bound]
        if missing:
            c.warn("projection-var",
                   f"projection selects {missing} which the core never "
                   "binds; those columns are UNBOUND-filled", "spine")
    missing_order = [v for v, _ in spine.order if v not in bound]
    if missing_order:
        c.warn("projection-var",
               f"ORDER BY keys {missing_order} are never bound; they "
               "order nothing (constant keys)", "spine")


def verify_core(core: CorePlan, catalog,
                spine: Optional[ModifierSpine] = None
                ) -> VerificationReport:
    """Verify a :class:`CorePlan` segment tree: per-segment plan checks,
    tree-level variable/connectivity checks, flat-offset layout, and
    (when given) the modifier spine over the core's output."""
    c = _Collector()
    if core.empty != isinstance(core.root, EmptySeg):
        c.error("empty-flag",
                f"CorePlan.empty={core.empty} but root is "
                f"{type(core.root).__name__}")
    if core.flat.empty != core.empty:
        c.error("empty-flag",
                f"flat plan empty={core.flat.empty} disagrees with "
                f"core empty={core.empty}")
    if not core.empty:
        segs: List[BGPSeg] = []
        _walk_bgp_segs(core.root, segs)
        for k, seg in enumerate(segs):
            _check_plan(seg.plan, catalog, c, where=f"seg{k}")
            if seg.plan.empty:
                c.error("empty-flag",
                        f"segment {k} is statistics-empty but was not "
                        "pruned to EmptySeg", f"seg{k}")
        _check_flat_offsets(core, segs, c)
        _check_tree(core.root, c, {})
    if spine is not None:
        _check_spine(spine, core.vars, c)
    return VerificationReport(tuple(c.diags), ALL_CHECKS)


# ---------------------------------------------------------------------------
# Executor-level checks (capacity-slot accounting)
# ---------------------------------------------------------------------------

def verify_executor(ex, catalog=None) -> VerificationReport:
    """Verify a built ``PlanExecutor`` / ``DistributedExecutor``: the
    full core pass plus the capacity-slot protocol both executors share
    — ``caps = [one per flat step] + [one per CombineSeg, post-order] +
    [modifier resize slot iff the spine needs one]``, with the overflow
    flags positional over exactly this layout (``double_caps`` doubles
    ``caps[i]`` because ``ovf[i]`` fired; a missing or duplicated slot
    silently grows the wrong buffer)."""
    catalog = catalog if catalog is not None else ex.catalog
    report = verify_core(ex.core, catalog, spine=ex.spine)
    c = _Collector()
    c.diags.extend(report.diagnostics)

    n_flat = len(ex.plan.steps)
    combines: List[CombineSeg] = []
    _walk_combines(ex.core.root, combines)
    n_comb = len(combines)

    # the distributed executor gathers shards for global modifiers; the
    # single-device executor resizes for DISTINCT/ORDER sorts
    spine = ex.spine
    if hasattr(ex, "gathered"):
        want_resize = bool(spine.needs_global)
    else:
        want_resize = bool(spine.distinct or spine.order)
    if bool(ex._mod_resize) != want_resize:
        c.error("cap-slots",
                f"_mod_resize={ex._mod_resize} but the spine implies "
                f"{want_resize} (distinct={spine.distinct}, "
                f"order={bool(spine.order)}, slice={spine.has_slice})",
                "caps")
    want_len = n_flat + n_comb + (1 if ex._mod_resize else 0)
    if len(ex.caps) != want_len:
        c.error("cap-slots",
                f"{len(ex.caps)} capacity slots for {n_flat} flat steps "
                f"+ {n_comb} combines + "
                f"{1 if ex._mod_resize else 0} modifier slot(s) "
                f"(expected {want_len})", "caps")
    if ex._n_pipeline != n_flat + n_comb:
        c.error("cap-slots",
                f"_n_pipeline={ex._n_pipeline}, expected "
                f"{n_flat + n_comb} (flat + combine slots)", "caps")
    idx = ex._comb_index
    want_ids = {id(s) for s in combines}
    if set(idx.keys()) != want_ids or \
            sorted(idx.values()) != list(range(n_flat, n_flat + n_comb)):
        c.error("cap-slots",
                f"combine slot index maps {len(idx)} segment(s) onto "
                f"slots {sorted(idx.values())}; expected a bijection "
                f"onto [{n_flat}, {n_flat + n_comb})", "caps")
    for i, cap in enumerate(ex.caps):
        if not isinstance(cap, (int,)) or cap < 1:
            c.error("cap-slots",
                    f"capacity slot {i} is {cap!r} (positive int "
                    "required)", "caps")
    return VerificationReport(tuple(c.diags), ALL_CHECKS)


# ---------------------------------------------------------------------------
# PreparedQuery dispatch (duck-typed; no engine import)
# ---------------------------------------------------------------------------

def verify_prepared(prepared, catalog) -> VerificationReport:
    """Verify whatever a backend's ``prepare`` produced.

    * executor-backed (jit/distributed): full core + cap-slot pass;
    * eager with a compiled flat plan: flat-plan + spine pass;
    * statistics-empty: the empty-flag consistency check;
    * host operator trees (no compiled artifact): nothing to verify —
      the report says so instead of claiming "ok".
    """
    ex = getattr(prepared, "executor", None)
    if ex is not None:
        return verify_executor(ex, catalog)
    plan = getattr(prepared, "plan", None)
    if plan is not None:
        spine = getattr(prepared, "spine", None)
        return verify_plan(plan, catalog, spine=spine)
    return VerificationReport((), ())
