"""Static analysis over the engine: plan/IR verification and trace lint.

Two independent layers share this package because they guard the same
contract — everything the engine runs must stay inside the statically
verifiable, device-executable fragment:

* :mod:`repro.analysis.verifier` — a non-executing pass over compiled
  :class:`~repro.core.compiler.Plan` / ``CorePlan`` artifacts (and the
  executors built from them) checking the invariants the paper's
  Algorithms 1/4 and the static-shape runtime rely on.  Wired into
  ``Engine`` prepare behind ``RuntimeConfig(verify_plans=...)``.
* :mod:`repro.analysis.lint` — an AST lint ("replint") over the source
  tree for JAX/Pallas trace-safety pitfalls, run as a CI gate through
  ``tools/replint.py``.
"""

from repro.analysis.lint import (
    LintFinding, RULES, lint_file, lint_paths, lint_source,
)
from repro.analysis.verifier import (
    PlanDiagnostic, PlanVerificationError, VerificationReport,
    verify_core, verify_executor, verify_plan, verify_prepared,
)

__all__ = [
    "PlanDiagnostic", "PlanVerificationError", "VerificationReport",
    "verify_plan", "verify_core", "verify_executor", "verify_prepared",
    "LintFinding", "RULES", "lint_source", "lint_file", "lint_paths",
]
