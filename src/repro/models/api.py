"""Unified model API over all families (decoder-only / MoE / hybrid / SSM
/ enc-dec / VLM): init, loss, prefill, decode, input specs per shape cell.

This is the single surface the launcher, dry-run, trainers and tests go
through — per-family dispatch lives here and nowhere else.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, transformer
from repro.models.config import ArchConfig, ShapeCell

Params = Dict[str, Any]


@dataclass
class Model:
    cfg: ArchConfig

    # -- parameters -----------------------------------------------------------
    def init(self, key) -> Params:
        if self.cfg.enc_dec:
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    # -- training --------------------------------------------------------------
    def loss(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        if self.cfg.enc_dec:
            return encdec.seq2seq_loss(params, batch, self.cfg)
        return transformer.lm_loss(params, batch, self.cfg)

    # -- serving -----------------------------------------------------------------
    def prefill(self, params: Params, batch: Dict[str, jax.Array]) -> jax.Array:
        if self.cfg.enc_dec:
            enc_out = encdec.encode(params, batch["frames"], self.cfg)
            logits = encdec.decode_train(params, batch["tokens"], enc_out, self.cfg)
            return logits[:, -1:]
        return transformer.prefill(params, batch["tokens"], self.cfg,
                                   batch.get("patches"))

    def init_caches(self, params_or_none, batch: int, s_max: int) -> Params:
        if self.cfg.enc_dec:
            return encdec.init_caches(params_or_none, self.cfg, batch, s_max)
        return transformer.init_caches(self.cfg, batch, s_max)

    def decode(self, params: Params, caches: Params, tokens: jax.Array,
               pos: jax.Array):
        if self.cfg.enc_dec:
            return encdec.decode_step(params, caches, tokens, pos, self.cfg)
        return transformer.decode_step(params, caches, tokens, pos, self.cfg)

    # -- shape cells ---------------------------------------------------------------
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for every model input of this cell
        (no allocation).  For decode cells this includes the caches."""
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32 = jnp.int32
        act = jnp.dtype(cfg.dtype)

        def tok(shape):
            return jax.ShapeDtypeStruct(shape, i32)

        if cell.kind == "train":
            if cfg.enc_dec:
                return {"frames": jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), act),
                        "tokens": tok((B, S)), "labels": tok((B, S))}
            if cfg.vlm:
                s_text = S - cfg.n_patches
                return {"tokens": tok((B, s_text)), "labels": tok((B, s_text)),
                        "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act)}
            return {"tokens": tok((B, S)), "labels": tok((B, S))}

        if cell.kind == "prefill":
            if cfg.enc_dec:
                return {"frames": jax.ShapeDtypeStruct((B, cfg.n_frames, cfg.d_model), act),
                        "tokens": tok((B, S))}
            if cfg.vlm:
                return {"tokens": tok((B, S - cfg.n_patches)),
                        "patches": jax.ShapeDtypeStruct((B, cfg.n_patches, cfg.d_model), act)}
            return {"tokens": tok((B, S))}

        assert cell.kind == "decode"
        caches = jax.eval_shape(
            lambda: self.init_caches(
                jax.eval_shape(lambda k: self.init(k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
                if cfg.enc_dec else None, B, S))
        return {"tokens": tok((B, 1)),
                "pos": jax.ShapeDtypeStruct((), i32),
                "caches": caches}

    # -- synthetic batches for smoke tests / examples ---------------------------------
    def dummy_batch(self, cell: ShapeCell, key) -> Dict[str, jax.Array]:
        specs = self.input_specs(cell)

        def make(path, s):
            name = "/".join(str(getattr(k, "key", k)) for k in path)
            if s.dtype == jnp.int32 and ("tokens" in name or "labels" in name):
                return jax.random.randint(key, s.shape, 0, self.cfg.vocab, jnp.int32)
            if s.dtype == jnp.int32:
                return jnp.zeros(s.shape, jnp.int32)
            return jnp.zeros(s.shape, s.dtype)

        return jax.tree_util.tree_map_with_path(
            make, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def model_flops(cfg: ArchConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS = 6·N_active·D for train, 2·N_active·D for inference
    (forward only) — the §Roofline 'useful compute' yardstick."""
    n_active = active_params(cfg)
    tokens = cell.global_batch * (cell.seq_len if cell.kind != "decode" else 1)
    mult = 6.0 if cell.kind == "train" else 2.0
    return mult * n_active * tokens


def active_params(cfg: ArchConfig) -> float:
    """Active (per-token) parameter count, analytic."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    hd, h, kv = cfg.hd, cfg.n_heads, cfg.n_kv
    attn_p = d * hd * (h + 2 * kv) + h * hd * d
    dense_p = 3 * d * ff
    m = cfg.moe
    moe_active = 3 * d * m.d_ff_expert * m.top_k + \
        3 * d * m.d_ff_shared * m.n_shared + d * m.n_experts
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    ssm_p = 2 * d * di + 2 * d * n + d * (di // max(cfg.ssm_headdim, 1)) \
        + di * d + cfg.ssm_conv * (di + 2 * n)

    total = v * d  # embedding (active on input+output)
    if not cfg.tie_embeddings:
        total += v * d

    def block_cost(spec):
        mixer, mlp = spec
        c = 0.0
        if mixer in ("attn", "attn_local"):
            c += attn_p
        elif mixer == "mamba":
            c += ssm_p
        if mlp == "dense":
            c += dense_p
        elif mlp == "moe":
            c += moe_active
        return c

    if cfg.first_layer_override:
        total += block_cost(cfg.first_layer_override)
    per_group = sum(block_cost(s) for s in cfg.group_pattern)
    total += per_group * cfg.n_groups
    if cfg.enc_dec:
        total += cfg.n_enc_layers * (attn_p + 2 * d * ff) \
            + cfg.n_layers * attn_p  # cross attention
    return float(total)


def total_params(cfg: ArchConfig) -> float:
    """Total parameter count (MoE experts all counted)."""
    m = cfg.moe
    if not m.n_experts:
        return active_params(cfg)
    moe_total_minus_active = 3 * cfg.d_model * m.d_ff_expert * (m.n_experts - m.top_k)
    n_moe_layers = sum(1 for s in cfg.group_pattern if s[1] == "moe") * cfg.n_groups
    return active_params(cfg) + moe_total_minus_active * n_moe_layers
