"""Mamba2 (SSD — state-space duality) mixer block.

Implements the chunked SSD algorithm (Dao & Gu 2024, arXiv:2405.21060):
the selective state-space recurrence

    h_t = a_t · h_t-1 + dt_t · B_t ⊗ x_t          (per head, a_t = exp(dt·A))
    y_t = C_t · h_t + D · x_t

is evaluated as (i) an intra-chunk *quadratic attention-like* form — all
MXU matmuls over (Q, Q) chunk tiles, which is the whole point of SSD on
TPU — plus (ii) an inter-chunk state scan of the (H, N, P) chunk states
(``lax.scan``, O(S/Q) sequential steps).

Projections are split per stream (z, x, B, C, dt) instead of one fused
in_proj: mathematically identical, but it lets the d_inner streams shard
cleanly on the mesh's ``model`` axis (heads × headdim live in d_inner)
while the tiny B/C/dt streams stay replicated — slicing a fused
projection across a sharded axis would force XLA reshards every layer.

The decode path is the O(1)-per-token recurrence over a persistent
(B, H, N, P) state plus a (K-1)-deep depthwise-conv ring buffer — this is
what makes the 500k-token cell feasible (state size is independent of
context length), per DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import _init, rmsnorm

Params = Dict[str, Any]


def ssm_dims(cfg: ArchConfig) -> Tuple[int, int, int, int]:
    """(d_inner, n_heads, head_dim, d_state)."""
    di = cfg.ssm_expand * cfg.d_model
    pd = cfg.ssm_headdim
    assert di % pd == 0
    return di, di // pd, pd, cfg.ssm_state


def init_ssm(key, cfg: ArchConfig, dtype) -> Params:
    d = cfg.d_model
    di, h, pdim, n = ssm_dims(cfg)
    ks = jax.random.split(key, 9)
    s = 1.0 / np.sqrt(d)
    return {
        "in_z": _init(ks[0], (d, di), s, dtype),
        "in_x": _init(ks[1], (d, di), s, dtype),
        "in_b": _init(ks[2], (d, n), s, dtype),
        "in_c": _init(ks[3], (d, n), s, dtype),
        "in_dt": _init(ks[4], (d, h), s, dtype),
        "conv_x": _init(ks[5], (cfg.ssm_conv, di), 0.5, dtype),
        "conv_b": _init(ks[6], (cfg.ssm_conv, n), 0.5, dtype),
        "conv_c": _init(ks[7], (cfg.ssm_conv, n), 0.5, dtype),
        "conv_bias_x": jnp.zeros((di,), dtype),
        "conv_bias_b": jnp.zeros((n,), dtype),
        "conv_bias_c": jnp.zeros((n,), dtype),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": _init(ks[8], (di, d), 1.0 / np.sqrt(di), dtype),
    }


def _causal_conv(w, bias, x: jax.Array) -> jax.Array:
    """Depthwise causal conv (kernel K) via K shifted adds; x (B, S, C)."""
    k = w.shape[0]
    out = x * w[k - 1].astype(x.dtype)
    for i in range(1, k):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1]]
        out = out + shifted * w[k - 1 - i].astype(x.dtype)
    return jax.nn.silu(out + bias.astype(x.dtype))


def _streams(p: Params, x: jax.Array, cfg: ArchConfig):
    dt_ = x.dtype
    z = jnp.einsum("bsd,de->bse", x, p["in_z"].astype(dt_))
    xi = jnp.einsum("bsd,de->bse", x, p["in_x"].astype(dt_))
    bm = jnp.einsum("bsd,dn->bsn", x, p["in_b"].astype(dt_))
    cm = jnp.einsum("bsd,dn->bsn", x, p["in_c"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"].astype(dt_))
    return z, xi, bm, cm, dt


def ssd_forward(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Full-sequence SSD (train / prefill).  x (B, S, D) -> (B, S, D)."""
    b, s, d = x.shape
    di, h, pdim, n = ssm_dims(cfg)
    q = cfg.ssm_chunk
    assert s % q == 0, (s, q)
    nc = s // q

    z, xi, bm, cm, dt = _streams(p, x, cfg)
    xi = _causal_conv(p["conv_x"], p["conv_bias_x"], xi)
    bm = _causal_conv(p["conv_b"], p["conv_bias_b"], bm)
    cm = _causal_conv(p["conv_c"], p["conv_bias_c"], cm)
    xs = xi.reshape(b, s, h, pdim)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    a = -jnp.exp(p["a_log"])                                       # (H,)
    log_decay = dtv * a[None, None, :]                             # (B,S,H) ≤ 0

    # chunk views
    xs_c = xs.reshape(b, nc, q, h, pdim)
    b_c = bm.reshape(b, nc, q, n)
    c_c = cm.reshape(b, nc, q, n)
    ld_c = log_decay.reshape(b, nc, q, h)
    dt_c = dtv.reshape(b, nc, q, h)

    cum = jnp.cumsum(ld_c, axis=2)                   # (B,nc,Q,H)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    li = cum[:, :, :, None, :]
    lj = cum[:, :, None, :, :]
    lmask = (jnp.arange(q)[:, None] >= jnp.arange(q)[None, :])
    lmat = jnp.exp(jnp.where(lmask[None, None, ..., None], li - lj, -1e30))
    scores = jnp.einsum("bcin,bcjn->bcij",
                        c_c.astype(jnp.float32), b_c.astype(jnp.float32))
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]               # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp",
                         scores[..., None] * lmat, xdt)

    # chunk states: S_c = Σ_j exp(cum_last - cum_j) B_j ⊗ xdt_j  -> (B,nc,H,N,P)
    tail_decay = jnp.exp(cum[:, :, -1:, :] - cum)                  # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcjn,bcjh,bcjhp->bchnp",
                         b_c.astype(jnp.float32), tail_decay, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                        # (B,nc,H)

    def scan_fn(hprev, inp):
        s_c, cd = inp                                # (B,H,N,P), (B,H)
        hnew = hprev * cd[..., None, None] + s_c
        return hnew, hprev                           # emit state BEFORE chunk

    h0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    _, h_in = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(s_chunk, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_in = jnp.moveaxis(h_in, 0, 1)                  # (B,nc,H,N,P)

    in_decay = jnp.exp(cum)                          # decay chunk-start -> i
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp",
                         c_c.astype(jnp.float32), h_in, in_decay)

    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    y = y + xs.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)

    # gated RMSNorm + out proj
    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm_scale"]}, y, cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Decode (O(1) per token)
# ---------------------------------------------------------------------------

def ssm_decode_state(cfg: ArchConfig, batch: int, dtype=jnp.float32):
    di, h, pdim, n = ssm_dims(cfg)
    return {
        "h": jnp.zeros((batch, h, n, pdim), dtype),
        "conv_x": jnp.zeros((batch, cfg.ssm_conv - 1, di), dtype),
        "conv_b": jnp.zeros((batch, cfg.ssm_conv - 1, n), dtype),
        "conv_c": jnp.zeros((batch, cfg.ssm_conv - 1, n), dtype),
    }


def _conv_step(w, bias, buf, cur, dtype):
    """One causal-conv step over ring buffer; returns (out, new_buf)."""
    window = jnp.concatenate([buf, cur[:, None].astype(buf.dtype)], axis=1)
    out = jnp.einsum("bkc,kc->bc", window.astype(dtype), w.astype(dtype))
    return jax.nn.silu(out + bias.astype(dtype)), window[:, 1:]


def ssd_decode(p: Params, x: jax.Array, state: Dict[str, jax.Array],
               cfg: ArchConfig):
    """x (B, 1, D); returns (y (B,1,D), new_state)."""
    b = x.shape[0]
    di, h, pdim, n = ssm_dims(cfg)
    z, xi, bm, cm, dt = _streams(p, x, cfg)

    xo, ncx = _conv_step(p["conv_x"], p["conv_bias_x"], state["conv_x"],
                         xi[:, 0], x.dtype)
    bo, ncb = _conv_step(p["conv_b"], p["conv_bias_b"], state["conv_b"],
                         bm[:, 0], x.dtype)
    co, ncc = _conv_step(p["conv_c"], p["conv_bias_c"], state["conv_c"],
                         cm[:, 0], x.dtype)

    xs = xo.reshape(b, h, pdim).astype(jnp.float32)
    bv = bo.astype(jnp.float32)
    cv = co.astype(jnp.float32)
    dtv = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dtv * (-jnp.exp(p["a_log"]))[None, :])                  # (B,H)

    hst = state["h"].astype(jnp.float32)
    hst = hst * a[..., None, None] + \
        jnp.einsum("bn,bhp->bhnp", bv, xs * dtv[..., None])
    y = jnp.einsum("bn,bhnp->bhp", cv, hst) + xs * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)

    y = y * jax.nn.silu(z)
    y = rmsnorm({"scale": p["norm_scale"]}, y, cfg.norm_eps)
    y = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(x.dtype))
    return y, {"h": hst.astype(state["h"].dtype), "conv_x": ncx,
               "conv_b": ncb, "conv_c": ncc}
