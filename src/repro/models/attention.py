"""GQA attention with global/local (sliding-window) masking and a static
KV-cache decode path.

Shapes:  x (B, S, D);  q (B, S, H, hd);  k/v (B, S, KV, hd);  GQA repeats
each KV head across H/KV query heads via reshape-free broadcasting in the
einsum (q grouped as (B, S, KV, H/KV, hd)) — no materialized repeat.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import _init, apply_rope, rope_angles

Params = Dict[str, Any]


def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.hd
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    p = {
        "wq": _init(ks[0], (d, h, hd), s, dtype),
        "wk": _init(ks[1], (d, kv, hd), s, dtype),
        "wv": _init(ks[2], (d, kv, hd), s, dtype),
        "wo": _init(ks[3], (h, hd, d), 1.0 / np.sqrt(h * hd), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    return p


def _qkv(p: Params, x: jax.Array, cfg: ArchConfig):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _mask(sq: int, skv: int, offset, local_window: Optional[int]) -> jax.Array:
    """(sq, skv) bool mask.  offset = absolute position of query 0 minus
    absolute position of key 0 (0 for self-attn train/prefill)."""
    qi = jnp.arange(sq)[:, None] + offset
    kj = jnp.arange(skv)[None, :]
    m = kj <= qi
    if local_window is not None:
        m &= kj > qi - local_window
    return m


def _sdpa(q, k, v, mask, cfg: ArchConfig) -> jax.Array:
    """q (B,Sq,H,hd), k/v (B,Skv,KV,hd) -> (B,Sq,H,hd), GQA grouped."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    logits = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    logits = logits / np.sqrt(cfg.hd)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(b, sq, h, hd)


def _sdpa_chunked(q, k, v, cfg: ArchConfig, local: bool,
                  chunk: int) -> jax.Array:
    """Flash-style self-attention (§Perf): online-softmax over KV chunks.

    Never materializes the (Sq, Skv) score tensor — peak intermediate is
    (B, KV, G, Sq, chunk) — cutting attention HBM traffic by ~Skv/chunk
    and bounding VMEM-resident working sets the way a fused TPU attention
    kernel does.  Causal and sliding-window masks are applied per chunk
    from position arithmetic.  Exact (not approximate): equivalence vs
    the dense path is asserted in tests/test_arch_smoke.py.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    assert sq % 1 == 0 and k.shape[1] % chunk == 0, (sq, k.shape, chunk)
    nc = k.shape[1] // chunk
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / np.sqrt(cfg.hd)
    q_pos = jnp.arange(sq)

    # trace-time chunk loop (nc is small and static): exact cost accounting
    # on the CPU analysis backend AND the blocked live-set that a fused TPU
    # attention kernel would have — a causal chunk j only exists while
    # processed.  Fully-masked chunks (j ahead of every query) are elided
    # AT TRACE TIME below, so sliding-window layers do ~window/S of the work.
    m = jnp.full((b, kvh, g, sq), -1e30, jnp.float32)
    l = jnp.zeros((b, kvh, g, sq), jnp.float32)
    acc = jnp.zeros((b, sq, kvh, g, hd), q.dtype)
    for j in range(nc):
        k_lo = j * chunk
        if k_lo > sq - 1:       # entirely above the causal diagonal
            continue
        kj = k[:, k_lo:k_lo + chunk]
        vj = v[:, k_lo:k_lo + chunk]
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kj).astype(jnp.float32) * scale
        k_pos = k_lo + jnp.arange(chunk)
        msk = k_pos[None, :] <= q_pos[:, None]          # (sq, chunk)
        if local:
            msk &= k_pos[None, :] > q_pos[:, None] - cfg.sliding_window
        s = jnp.where(msk[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkh->bskgh", p.astype(q.dtype), vj)
        acc = acc * jnp.moveaxis(corr, -1, 1)[..., None].astype(acc.dtype) + pv
        m = m_new
    linv = (1.0 / jnp.maximum(l, 1e-30)).astype(q.dtype)
    out = acc * jnp.moveaxis(linv, -1, 1)[..., None]
    return out.reshape(b, sq, h, hd)


def attention(p: Params, x: jax.Array, cfg: ArchConfig,
              local: bool = False,
              positions: Optional[jax.Array] = None) -> jax.Array:
    """Self-attention over the full sequence (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    pos = positions if positions is not None else jnp.arange(s)
    sin, cos = rope_angles(pos, cfg.hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    if cfg.flash_chunk and s % cfg.flash_chunk == 0 and s > cfg.flash_chunk:
        o = _sdpa_chunked(q, k, v, cfg, local, cfg.flash_chunk)
    else:
        mask = _mask(s, s, 0, cfg.sliding_window if local else None)
        o = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def attention_bidir(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Bidirectional self-attention (whisper encoder)."""
    b, s, _ = x.shape
    q, k, v = _qkv(p, x, cfg)
    sin, cos = rope_angles(jnp.arange(s), cfg.hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    mask = jnp.ones((s, s), bool)
    o = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))


def init_cross_attention(key, cfg: ArchConfig, dtype) -> Params:
    return init_attention(key, cfg, dtype)


def cross_attention(p: Params, x: jax.Array, enc_kv: Tuple[jax.Array, jax.Array],
                    cfg: ArchConfig) -> jax.Array:
    """x (B,Sq,D) attends over precomputed encoder (k, v) (B,Senc,KV,hd)."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    k, v = enc_kv
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    o = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(dt))


def encoder_kv(p: Params, enc_out: jax.Array) -> Tuple[jax.Array, jax.Array]:
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    if "bk" in p:
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return k, v


# ---------------------------------------------------------------------------
# Decode path (static KV cache)
# ---------------------------------------------------------------------------

def attention_decode(p: Params, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, cfg: ArchConfig,
                     write_pos: Optional[jax.Array] = None):
    """One-token decode.  x (B, 1, D); caches (B, S_cache, KV, hd); ``pos``
    is the absolute position (drives RoPE + validity mask); ``write_pos``
    the cache slot (== pos for global layers, pos % window for the ring
    cache of sliding-window layers — ring entries are all within the
    window by construction, so validity is just "slot already written").
    Returns (out, k_cache, v_cache)."""
    b, _, _ = x.shape
    s_cache = k_cache.shape[1]
    wp = pos if write_pos is None else write_pos
    q, k, v = _qkv(p, x, cfg)
    sin, cos = rope_angles(pos[None], cfg.hd, cfg.rope_theta)
    q = apply_rope(q, sin, cos)
    k = apply_rope(k, sin, cos)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), wp, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), wp, axis=1)

    kj = jnp.arange(s_cache)
    mask = (kj <= pos) | jnp.full((s_cache,), pos >= s_cache)
    o = _sdpa(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
              mask[None, :], cfg)
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(x.dtype))
    return out, k_cache, v_cache
