"""Primitive layers: norms, embeddings, RoPE, dense projections, MLPs.

Pure-functional: parameters are plain dict pytrees; every init function
returns (params, ...) and every apply function takes (params, x).
Parameters for the scanned layer stack carry a leading (n_groups,) axis —
see transformer.py.  dtype policy: params in ``param_dtype`` (fp32 by
default), activations in ``dtype`` (bf16) — matmuls run bf16 on the MXU
with fp32 accumulation (XLA default for dot_general on TPU).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Params = Dict[str, Any]


def _init(key, shape, scale: float, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype) -> Params:
    return {"table": _init(key, (vocab, d), 1.0 / np.sqrt(d), dtype)}


def embed(p: Params, tokens: jax.Array, dtype) -> jax.Array:
    return p["table"].astype(dtype)[tokens]


def unembed(p: Params, x: jax.Array) -> jax.Array:
    """Tied unembedding: logits in fp32 for loss stability."""
    return jnp.einsum("...d,vd->...v", x.astype(jnp.float32),
                      p["table"].astype(jnp.float32))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_angles(positions: jax.Array, hd: int, theta: float) -> Tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape positions.shape + (hd/2,), fp32."""
    freqs = theta ** (-jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (..., S, H, hd); sin/cos: (..., S, hd/2) broadcast over heads."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    x1, x2 = jnp.split(xf, 2, axis=-1)
    s, c = sin[..., None, :], cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(dt)


# ---------------------------------------------------------------------------
# Dense MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(d_ff)
    return {
        "w_gate": _init(k1, (d, d_ff), s_in, dtype),
        "w_up": _init(k2, (d, d_ff), s_in, dtype),
        "w_down": _init(k3, (d_ff, d), s_out, dtype),
    }


def mlp(p: Params, x: jax.Array) -> jax.Array:
    """SwiGLU (the assigned families all use gated MLPs)."""
    dt = x.dtype
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(dt))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"].astype(dt))


def init_gelu_mlp(key, d: int, d_ff: int, dtype) -> Params:
    """Non-gated GELU MLP (whisper encoder/decoder FFN)."""
    k1, k2 = jax.random.split(key)
    return {
        "w_in": _init(k1, (d, d_ff), 1.0 / np.sqrt(d), dtype),
        "b_in": jnp.zeros((d_ff,), dtype),
        "w_out": _init(k2, (d_ff, d), 1.0 / np.sqrt(d_ff), dtype),
        "b_out": jnp.zeros((d,), dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, p["w_in"].astype(dt)) + p["b_in"].astype(dt)
    h = jax.nn.gelu(h)
    return jnp.einsum("...f,fd->...d", h, p["w_out"].astype(dt)) + p["b_out"].astype(dt)
