"""Decoder-only transformer stack (covers dense, MoE, hybrid and SSM
families, plus the LLaVA text backbone with stubbed patch embeddings).

The layer stack is a ``lax.scan`` over *groups* (one group = one period of
``cfg.group_pattern``); every group's parameters carry a leading
(n_groups,) axis.  One group body is compiled regardless of depth — the
72-layer Jamba lowers the same HLO size as a 8-layer toy — and XLA's
latency-hiding scheduler can overlap the per-group collectives with
compute across scan iterations.

Decode state: per group-position either an attention KV cache
(n_groups, B, S_max, KV, hd) or an SSM state {h, conv} with leading
(n_groups,) — also scanned.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ArchConfig
from repro.models.layers import (embed, init_embed, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm, unembed)

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, spec, cfg: ArchConfig, pdt) -> Params:
    mixer_kind, mlp_kind = spec
    k1, k2 = jax.random.split(key)
    p: Params = {"mixer_norm": init_rmsnorm(cfg.d_model, pdt),
                 "mlp_norm": init_rmsnorm(cfg.d_model, pdt)}
    if mixer_kind in ("attn", "attn_local"):
        p["mixer"] = attn.init_attention(k1, cfg, pdt)
    elif mixer_kind == "mamba":
        p["mixer"] = ssm_mod.init_ssm(k1, cfg, pdt)
    elif mixer_kind != "none":
        raise ValueError(mixer_kind)
    if mlp_kind == "dense":
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, pdt)
    elif mlp_kind == "moe":
        p["mlp"] = moe_mod.init_moe(k2, cfg, pdt)
    elif mlp_kind == "none":      # pure-mixer block (mamba2)
        del p["mlp_norm"]
    else:
        raise ValueError(mlp_kind)
    return p


def init_params(cfg: ArchConfig, key) -> Params:
    pdt = _pdtype(cfg)
    keys = jax.random.split(key, 4 + len(cfg.group_pattern))
    params: Params = {"embed": init_embed(keys[0], cfg.vocab, cfg.d_model, pdt),
                      "final_norm": init_rmsnorm(cfg.d_model, pdt)}
    if not cfg.tie_embeddings:
        params["lm_head"] = init_embed(keys[1], cfg.vocab, cfg.d_model, pdt)
    if cfg.first_layer_override:
        params["first"] = _init_block(keys[2], cfg.first_layer_override, cfg, pdt)

    # stacked group params: vmap init over group index
    def init_group(k):
        ks = jax.random.split(k, len(cfg.group_pattern))
        return {f"pos_{i}": _init_block(ks[i], spec, cfg, pdt)
                for i, spec in enumerate(cfg.group_pattern)}

    gkeys = jax.random.split(keys[3], cfg.n_groups)
    params["groups"] = jax.vmap(init_group)(gkeys)
    return params


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(bp: Params, spec, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    mixer_kind, mlp_kind = spec
    if mixer_kind != "none":
        h = rmsnorm(bp["mixer_norm"], x, cfg.norm_eps)
        if mixer_kind == "attn":
            x = x + attn.attention(bp["mixer"], h, cfg, local=False)
        elif mixer_kind == "attn_local":
            x = x + attn.attention(bp["mixer"], h, cfg, local=True)
        else:
            x = x + ssm_mod.ssd_forward(bp["mixer"], h, cfg)
    if mlp_kind != "none":
        h = rmsnorm(bp["mlp_norm"], x, cfg.norm_eps)
        if mlp_kind == "dense":
            x = x + mlp(bp["mlp"], h)
        else:
            x = x + moe_mod.moe_layer(bp["mlp"], h, cfg)
    return x


def forward_hidden(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Embedded input (B, S, D) -> final hidden states (B, S, D)."""

    if cfg.first_layer_override:
        x = _apply_block(params["first"], cfg.first_layer_override, x, cfg)

    def group_body(xc, gp):
        for i, spec in enumerate(cfg.group_pattern):
            xc = _apply_block(gp[f"pos_{i}"], spec, xc, cfg)
        return xc, ()

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(
            group_body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    x, _ = jax.lax.scan(body, x, params["groups"], unroll=cfg.scan_unroll)
    return rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params: Params, tokens: jax.Array, cfg: ArchConfig,
            patches: Optional[jax.Array] = None) -> jax.Array:
    """tokens (B, S[, + patches (B, P, D)]) -> logits (B, S_total, V) bf16."""
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens, dt)
    if patches is not None:
        x = jnp.concatenate([patches.astype(dt), x], axis=1)
    x = forward_hidden(params, x, cfg)
    head = params.get("lm_head", params["embed"])
    return jnp.einsum("bsd,vd->bsv", x, head["table"].astype(dt))


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ArchConfig) -> jax.Array:
    """Next-token cross-entropy; labels < 0 are masked out."""
    logits = forward(params, batch["tokens"], cfg, batch.get("patches"))
    n_patch = 0 if batch.get("patches") is None else batch["patches"].shape[1]
    logits = logits[:, n_patch:]
    labels = batch["labels"]
    lg = logits[:, :-1].astype(jnp.float32)
    tg = labels[:, 1:]
    mask = (tg >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, jnp.maximum(tg, 0)[..., None],
                                 axis=-1)[..., 0]
    nll = (lse - picked) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Decode (one token, static caches)
# ---------------------------------------------------------------------------

def init_caches(cfg: ArchConfig, batch: int, s_max: int) -> Params:
    """Per-group-position stacked caches."""
    dt = _dtype(cfg)
    caches: Params = {}
    for i, (mixer_kind, _) in enumerate(cfg.group_pattern):
        if mixer_kind in ("attn", "attn_local"):
            # local layers only ever read the last `sliding_window` positions,
            # so their cache is bounded by the window, not the context
            s_cache = s_max if mixer_kind == "attn" else \
                min(s_max, int(2 ** np.ceil(np.log2(max(cfg.sliding_window, 2)))))
            caches[f"pos_{i}"] = {
                "k": jnp.zeros((cfg.n_groups, batch, s_cache, cfg.n_kv, cfg.hd), dt),
                "v": jnp.zeros((cfg.n_groups, batch, s_cache, cfg.n_kv, cfg.hd), dt),
            }
        elif mixer_kind == "mamba":
            st = ssm_mod.ssm_decode_state(cfg, batch)
            caches[f"pos_{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (cfg.n_groups,) + a.shape), st)
    if cfg.first_layer_override:
        mixer_kind, _ = cfg.first_layer_override
        if mixer_kind in ("attn", "attn_local"):
            caches["first"] = {
                "k": jnp.zeros((batch, s_max, cfg.n_kv, cfg.hd), dt),
                "v": jnp.zeros((batch, s_max, cfg.n_kv, cfg.hd), dt),
            }
        elif mixer_kind == "mamba":
            caches["first"] = ssm_mod.ssm_decode_state(cfg, batch)
    return caches


def _decode_block(bp: Params, cache, spec, x, pos, cfg: ArchConfig):
    mixer_kind, mlp_kind = spec
    new_cache = cache
    if mixer_kind != "none":
        h = rmsnorm(bp["mixer_norm"], x, cfg.norm_eps)
        if mixer_kind in ("attn", "attn_local"):
            s_cache = cache["k"].shape[1]
            # local layers write round-robin into their window-sized ring
            wpos = pos % s_cache if mixer_kind == "attn_local" else pos
            o, kc, vc = attn.attention_decode(
                bp["mixer"], h, cache["k"], cache["v"], pos, cfg,
                write_pos=wpos)
            x = x + o
            new_cache = {"k": kc, "v": vc}
        else:
            o, new_cache = ssm_mod.ssd_decode(bp["mixer"], h, cache, cfg)
            x = x + o
    if mlp_kind != "none":
        h = rmsnorm(bp["mlp_norm"], x, cfg.norm_eps)
        if mlp_kind == "dense":
            x = x + mlp(bp["mlp"], h)
        else:
            x = x + moe_mod.moe_layer(bp["mlp"], h, cfg)
    return x, new_cache


def decode_step(params: Params, caches: Params, tokens: jax.Array,
                pos: jax.Array, cfg: ArchConfig):
    """tokens (B, 1) at absolute position pos -> (logits (B,1,V), caches)."""
    dt = _dtype(cfg)
    x = embed(params["embed"], tokens, dt)

    if cfg.first_layer_override:
        x, new_first = _decode_block(params["first"], caches.get("first"),
                                     cfg.first_layer_override, x, pos, cfg)
    else:
        new_first = None

    def group_body(xc, scanned):
        gp, gcache = scanned
        new_caches = {}
        for i, spec in enumerate(cfg.group_pattern):
            xc, nc = _decode_block(gp[f"pos_{i}"], gcache.get(f"pos_{i}"),
                                   spec, xc, pos, cfg)
            if nc is not None:
                new_caches[f"pos_{i}"] = nc
        return xc, new_caches

    group_caches = {k: v for k, v in caches.items() if k != "first"}
    x, new_group_caches = jax.lax.scan(group_body, x,
                                       (params["groups"], group_caches),
                                       unroll=cfg.scan_unroll)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    head = params.get("lm_head", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, head["table"].astype(dt))

    out_caches = dict(new_group_caches)
    if new_first is not None:
        out_caches["first"] = new_first
    return logits, out_caches


def prefill(params: Params, tokens: jax.Array, cfg: ArchConfig,
            patches: Optional[jax.Array] = None) -> jax.Array:
    """Prefill = forward pass returning last-position logits (the cache-
    populating variant is exercised via decode_step; for the roofline the
    compute shape is what matters)."""
    logits = forward(params, tokens, cfg, patches)
    return logits[:, -1:]
