"""Mixture-of-Experts layer with sort-based capacity dispatch.

Routing is GShard/Switch-style top-k with a static per-expert capacity
``C = ceil(T·k/E · capacity_factor)`` (tokens over capacity are dropped —
their residual path still carries them).  Dispatch avoids the O(T·E·C)
one-hot einsum entirely: assignments are *sorted by expert* and each
token's slot is its rank within its expert's run — the same
sort + rank-search machinery the relational engine uses for joins, which
keeps everything O(T·k log T·k) in sort/gather primitives.

Expert compute is a single batched einsum over the (E, C, D) dispatch
buffer, so sharding E over the mesh's ``model`` axis gives expert
parallelism with XLA inserting the token all-to-alls.

DeepSeekMoE extras: ``n_shared`` always-on shared experts (dense SwiGLU
over the full d_ff_shared) added to the routed output.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ArchConfig
from repro.models.layers import _init, init_mlp, mlp

Params = Dict[str, Any]


def moe_capacity(cfg: ArchConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(np.ceil(n_tokens * m.top_k / m.n_experts * m.capacity_factor))
    return max(8, -(-c // 8) * 8)  # round up to multiple of 8


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(m.d_ff_expert)
    p: Params = {
        "router": _init(ks[0], (d, m.n_experts), s_in, jnp.float32),
        "w_gate": _init(ks[1], (m.n_experts, d, m.d_ff_expert), s_in, dtype),
        "w_up": _init(ks[2], (m.n_experts, d, m.d_ff_expert), s_in, dtype),
        "w_down": _init(ks[3], (m.n_experts, m.d_ff_expert, d), s_out, dtype),
    }
    if m.n_shared:
        p["shared"] = init_mlp(ks[4], d, m.d_ff_shared * m.n_shared, dtype)
    return p


def moe_layer(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x (B, S, D) -> (B, S, D)."""
    m = cfg.moe
    b, s, d = x.shape
    if m.dispatch_blocks and b % m.dispatch_blocks == 0:
        # blocked data-local dispatch (§Perf): vmap the whole routing over
        # batch blocks; per-block capacity keeps totals identical.
        nb = m.dispatch_blocks
        xb = x.reshape(nb, (b // nb) * s, d)
        yb = jax.vmap(lambda xi: _dispatch_compute(p, xi, cfg))(xb)
        return yb.reshape(b, s, d)
    return _dispatch_compute(p, x.reshape(b * s, d), cfg).reshape(b, s, d)


def _dispatch_compute(p: Params, xf: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Route + expert-FFN + combine for a flat (T, D) token block."""
    m = cfg.moe
    t, d = xf.shape
    k = m.top_k
    e = m.n_experts
    cap = moe_capacity(cfg, t)

    # --- routing (fp32) ------------------------------------------------------
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)                  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # --- sort-based dispatch --------------------------------------------------
    flat_e = top_e.reshape(t * k).astype(jnp.int32)
    flat_tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    flat_w = top_w.reshape(t * k)

    order = jnp.argsort(flat_e, stable=True).astype(jnp.int32)
    se = flat_e[order]
    stok = flat_tok[order]
    sw = flat_w[order]
    group_start = jnp.searchsorted(se, jnp.arange(e, dtype=jnp.int32),
                                   side="left").astype(jnp.int32)
    rank = jnp.arange(t * k, dtype=jnp.int32) - group_start[se]
    keep = rank < cap

    didx = jnp.where(keep, se, e)                           # OOB -> dropped
    ridx = jnp.clip(rank, 0, cap - 1)
    xe = jnp.zeros((e, cap, d), xf.dtype)
    xe = xe.at[didx, ridx].set(xf[stok], mode="drop")

    # --- expert FFN (EP einsum; E shards over 'model') -------------------------
    dt = xf.dtype
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(dt))
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(dt))

    # --- combine ---------------------------------------------------------------
    contrib = ye[jnp.clip(se, 0, e - 1), ridx] * \
        jnp.where(keep, sw, 0.0).astype(dt)[:, None]
    yf = jnp.zeros((t, d), dt).at[stok].add(contrib)

    if m.n_shared:
        yf = yf + mlp(p["shared"], xf)
    return yf


def aux_load_balance_loss(p: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Switch-style load-balance auxiliary loss (fraction · probability)."""
    m = cfg.moe
    t = x.shape[0] * x.shape[1]
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, -1).reshape(t, m.n_experts)
    top1 = jnp.argmax(probs, -1)
    frac = jnp.mean(jax.nn.one_hot(top1, m.n_experts, dtype=jnp.float32), 0)
    imp = jnp.mean(probs, 0)
    return m.n_experts * jnp.sum(frac * imp)
