"""Sharding rules: parameter / optimizer-state / activation PartitionSpecs.

Conventions (meshes built by launch/mesh.py):
  * batch axes of activations shard over the data axes
    (("pod","data") multi-pod, ("data",) single-pod);
  * tensor-parallel dims shard over "model": attention heads, FFN hidden,
    MoE experts (EP), SSM d_inner, vocab (embedding + logits);
  * dims not divisible by the model-axis size stay replicated (e.g. KV
    heads = 8 on a 16-way model axis — XLA would pad; replication is the
    deliberate, Llama-TP-style choice);
  * optimizer moments inherit the param spec; with ``cfg.zero1`` the
    largest replicated dim additionally shards over "data" (ZeRO-1).

Specs are derived *structurally* from parameter names + shapes, so any
pytree produced by the model inits gets consistent rules without
per-arch tables.
"""

from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def _model_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# Rules keyed by parameter leaf name: map dim index -> axis, guarded by
# divisibility.  `None` entries mean replicated.
_NAME_RULES = {
    # embeddings / unembeddings: vocab on model
    "table": (0, "model"),
    # attention
    "wq": (1, "model"), "wk": (1, "model"), "wv": (1, "model"),
    "wo": (0, "model"),
    "bq": (0, "model"), "bk": (0, "model"), "bv": (0, "model"),
    # dense MLPs (SwiGLU + GELU)
    "w_gate": (-1, "model"), "w_up": (-1, "model"), "w_down": (-2, "model"),
    "w_in": (-1, "model"), "b_in": (-1, "model"), "w_out": (-2, "model"),
    # MoE: expert dim on model (EP).  (w_gate/w_up/w_down of experts are
    # 3D — handled by ndim check below.)
    "router": None,
    # SSM streams: d_inner on model; B/C/dt tiny -> replicated
    "in_z": (-1, "model"), "in_x": (-1, "model"),
    "in_b": None, "in_c": None, "in_dt": None,
    "conv_x": (-1, "model"), "conv_bias_x": (-1, "model"),
    "conv_b": None, "conv_c": None, "conv_bias_b": None, "conv_bias_c": None,
    "a_log": (-1, "model"), "dt_bias": (-1, "model"), "d_skip": (-1, "model"),
    "norm_scale": (-1, "model"),
    "out_proj": (0, "model"),
    # norms / misc
    "scale": None, "bias": None, "b_out": None,
}


def _spec_for(path: str, leaf, msize: int) -> P:
    name = path.split("/")[-1]
    shape = leaf.shape
    rule = _NAME_RULES.get(name, None)

    # MoE expert tensors: (..., E, D, F) with a leading stacked-group axis
    # possibly present.  Identify by 3+ dims for w_gate/w_up/w_down inside
    # an "mlp" that has a router sibling — structurally: ndim >= 3 after
    # stripping the group axis; shard the expert dim.
    if name in ("w_gate", "w_up", "w_down") and leaf.ndim >= 3:
        # dims: [groups?, E, D, F].  Expert dim is ndim-3.
        edim = leaf.ndim - 3
        if shape[edim] % msize == 0 and shape[edim] >= msize:
            spec = [None] * leaf.ndim
            spec[edim] = "model"
            return P(*spec)
        # fall through to hidden-dim rule

    if rule is None:
        return P()
    dim, axis = rule
    dim = dim % leaf.ndim if leaf.ndim else 0
    # stacked group axis shifts positive dims by one; detect: rules were
    # written for unstacked params.  Positive dims: if the leaf has an
    # extra leading axis vs the rule's intent, shift.  We handle this by
    # preferring the *negative* interpretation when divisibility fails.
    candidates = [dim]
    if rule[0] >= 0:
        candidates.append(rule[0] + 1 if rule[0] + 1 < leaf.ndim else dim)
    for dcand in candidates:
        if shape[dcand] % msize == 0 and shape[dcand] >= msize:
            spec = [None] * leaf.ndim
            spec[dcand] = axis
            return P(*spec)
    return P()


def param_specs(params: Params, mesh: Mesh) -> Params:
    msize = _model_size(mesh)

    def walk(path, leaf):
        key = "/".join(str(getattr(k, "key", k)) for k in path)
        return _spec_for(key, leaf, msize)

    return jax.tree_util.tree_map_with_path(walk, params)


def opt_state_specs(pspecs: Params, params: Params, mesh: Mesh,
                    zero1: bool) -> Params:
    """Moments inherit the param spec; ZeRO-1 additionally shards the
    largest replicated dim over the data axes when divisible."""
    daxes = data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def widen(spec: P, leaf):
        if not zero1 or leaf.ndim == 0:
            return spec
        entries = list(spec) + [None] * (leaf.ndim - len(spec))
        # find largest dim currently replicated & divisible by data size
        order = sorted(range(leaf.ndim), key=lambda i: -leaf.shape[i])
        for i in order:
            if entries[i] is None and leaf.shape[i] % dsize == 0 \
                    and leaf.shape[i] >= dsize:
                entries[i] = daxes if len(daxes) > 1 else daxes[0]
                break
        return P(*entries)

    return jax.tree.map(widen, pspecs, params)


def _dsize(mesh: Mesh) -> int:
    daxes = data_axes(mesh)
    return int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1


def batch_specs(batch_tree: Params, mesh: Mesh) -> Params:
    """Shard the leading (batch) dim of every input over the data axes
    (replicate when the batch doesn't divide — e.g. global_batch=1)."""
    daxes = data_axes(mesh)
    ax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    dsz = _dsize(mesh)

    def spec(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dsz or leaf.shape[0] < dsz:
            return P(*([None] * leaf.ndim))
        return P(ax, *([None] * (leaf.ndim - 1)))

    return jax.tree.map(spec, batch_tree)


def cache_specs(cache_tree: Params, mesh: Mesh) -> Params:
    """KV caches / SSM states: batch dim over data; KV-head or d_inner dim
    over model when divisible.  Layout (groups?, B, S, KV, hd) or SSM
    {h: (groups?, B, H, N, P), conv_*: (groups?, B, K-1, C)}."""
    daxes = data_axes(mesh)
    dax = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)
    msize = _model_size(mesh)

    dsz = _dsize(mesh)

    def walk(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = leaf.ndim
        spec = [None] * nd
        # batch dim: first dim whose name isn't the stacked group axis —
        # structurally: KV caches are 5D (G,B,S,KV,hd) or 4D (B,S,KV,hd);
        # ssm h is 5D (G,B,H,N,P) or 4D; conv bufs 4D (G,B,K,C) or 3D.
        bdim = 1 if nd >= 4 and name in ("k", "v", "h") else \
            (1 if nd == 4 and name.startswith("conv") else 0)
        if name in ("k", "v") and nd == 4:
            bdim = 0
        if name == "h" and nd == 4:
            bdim = 0
        if name.startswith("conv") and nd == 3:
            bdim = 0
        if name in ("cross_k", "cross_v"):
            bdim = 1
        batch_ok = leaf.shape[bdim] % dsz == 0 and leaf.shape[bdim] >= dsz
        if batch_ok:
            spec[bdim] = dax
        # model axis: KV heads (dim -2 of k/v) or SSM heads (dim bdim+1 of h)
        if name in ("k", "v", "cross_k", "cross_v") and leaf.shape[-2] % msize == 0 \
                and leaf.shape[-2] >= msize:
            spec[nd - 2] = "model"
        if name == "h" and leaf.shape[bdim + 1] % msize == 0 \
                and leaf.shape[bdim + 1] >= msize:
            spec[bdim + 1] = "model"
        if name == "conv_x" and leaf.shape[-1] % msize == 0 \
                and leaf.shape[-1] >= msize:
            spec[nd - 1] = "model"
        # long-context, batch-1 decode: sequence-parallel KV — shard the
        # cache length over the data axes instead of the batch
        if not batch_ok and name in ("k", "v") and nd >= 3:
            sdim = bdim + 1
            if leaf.shape[sdim] % dsz == 0 and leaf.shape[sdim] >= dsz:
                spec[sdim] = dax
        return P(*spec)

    return jax.tree_util.tree_map_with_path(walk, cache_tree)


def to_shardings(spec_tree: Params, mesh: Mesh) -> Params:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
