"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, n_frames, d_model) — the
transformer backbone (12+12 layers for whisper-small) is the real system
under test.  Encoder layers are bidirectional; decoder layers interleave
causal self-attention, cross-attention over the encoder output, and GELU
FFNs.  Both stacks lower as ``lax.scan`` over per-layer-stacked params.

Decode state = causal self-KV caches plus the cross K/V projections
computed once from the encoder output (the standard serving split).
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ArchConfig
from repro.models.layers import (embed, gelu_mlp, init_embed, init_gelu_mlp,
                                 init_layernorm, layernorm)

Params = Dict[str, Any]


def _dt(cfg):
    return jnp.dtype(cfg.dtype)


def init_params(cfg: ArchConfig, key) -> Params:
    pdt = jnp.dtype(cfg.param_dtype)
    k = jax.random.split(key, 6)

    def enc_layer(kk):
        k1, k2 = jax.random.split(kk)
        return {
            "attn": attn.init_attention(k1, cfg, pdt),
            "attn_norm": init_layernorm(cfg.d_model, pdt),
            "mlp": init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, pdt),
            "mlp_norm": init_layernorm(cfg.d_model, pdt),
        }

    def dec_layer(kk):
        k1, k2, k3 = jax.random.split(kk, 3)
        return {
            "self": attn.init_attention(k1, cfg, pdt),
            "self_norm": init_layernorm(cfg.d_model, pdt),
            "cross": attn.init_cross_attention(k2, cfg, pdt),
            "cross_norm": init_layernorm(cfg.d_model, pdt),
            "mlp": init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, pdt),
            "mlp_norm": init_layernorm(cfg.d_model, pdt),
        }

    return {
        "embed": init_embed(k[0], cfg.vocab, cfg.d_model, pdt),
        "enc_layers": jax.vmap(enc_layer)(jax.random.split(k[1], cfg.n_enc_layers)),
        "enc_norm": init_layernorm(cfg.d_model, pdt),
        "dec_layers": jax.vmap(dec_layer)(jax.random.split(k[2], cfg.n_layers)),
        "dec_norm": init_layernorm(cfg.d_model, pdt),
    }


def encode(params: Params, frames: jax.Array, cfg: ArchConfig) -> jax.Array:
    """frames (B, F, D) stub embeddings -> encoder states (B, F, D)."""
    x = frames.astype(_dt(cfg))

    def body(xc, lp):
        h = layernorm(lp["attn_norm"], xc, cfg.norm_eps)
        xc = xc + attn.attention_bidir(lp["attn"], h, cfg)
        h = layernorm(lp["mlp_norm"], xc, cfg.norm_eps)
        xc = xc + gelu_mlp(lp["mlp"], h)
        return xc, ()

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_layers"], unroll=cfg.scan_unroll)
    return layernorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params: Params, tokens: jax.Array, enc_out: jax.Array,
                 cfg: ArchConfig) -> jax.Array:
    """Teacher-forced decoder -> logits (B, S, V)."""
    dt = _dt(cfg)
    x = embed(params["embed"], tokens, dt)

    def body(xc, lp):
        h = layernorm(lp["self_norm"], xc, cfg.norm_eps)
        xc = xc + attn.attention(lp["self"], h, cfg)
        h = layernorm(lp["cross_norm"], xc, cfg.norm_eps)
        kv = attn.encoder_kv(lp["cross"], enc_out)
        xc = xc + attn.cross_attention(lp["cross"], h, kv, cfg)
        h = layernorm(lp["mlp_norm"], xc, cfg.norm_eps)
        xc = xc + gelu_mlp(lp["mlp"], h)
        return xc, ()

    if cfg.remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_layers"], unroll=cfg.scan_unroll)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(dt))


def seq2seq_loss(params: Params, batch: Dict[str, jax.Array],
                 cfg: ArchConfig) -> jax.Array:
    enc_out = encode(params, batch["frames"], cfg)
    logits = decode_train(params, batch["tokens"], enc_out, cfg)
    lg = logits[:, :-1].astype(jnp.float32)
    tg = batch["labels"][:, 1:]
    mask = (tg >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    picked = jnp.take_along_axis(lg, jnp.maximum(tg, 0)[..., None], axis=-1)[..., 0]
    return ((lse - picked) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_caches(params: Params, cfg: ArchConfig, batch: int, s_max: int,
                enc_out: Optional[jax.Array] = None) -> Params:
    """Self-KV caches + per-layer cross K/V from the encoder output."""
    dt = _dt(cfg)
    L = cfg.n_layers
    caches: Params = {
        "k": jnp.zeros((L, batch, s_max, cfg.n_kv, cfg.hd), dt),
        "v": jnp.zeros((L, batch, s_max, cfg.n_kv, cfg.hd), dt),
    }
    if enc_out is None:
        enc_out = jnp.zeros((batch, cfg.n_frames, cfg.d_model), dt)

    def cross_kv(lp):
        k, v = attn.encoder_kv(lp["cross"], enc_out)
        return k.astype(dt), v.astype(dt)

    ck, cv = jax.lax.map(cross_kv, params["dec_layers"])
    caches["cross_k"], caches["cross_v"] = ck, cv
    return caches


def decode_step(params: Params, caches: Params, tokens: jax.Array,
                pos: jax.Array, cfg: ArchConfig):
    dt = _dt(cfg)
    x = embed(params["embed"], tokens, dt)

    def body(xc, scanned):
        lp, kc, vc, ck, cv = scanned
        h = layernorm(lp["self_norm"], xc, cfg.norm_eps)
        o, kc, vc = attn.attention_decode(lp["self"], h, kc, vc, pos, cfg)
        xc = xc + o
        h = layernorm(lp["cross_norm"], xc, cfg.norm_eps)
        xc = xc + attn.cross_attention(lp["cross"], h,
                                       (ck.astype(xc.dtype), cv.astype(xc.dtype)),
                                       cfg)
        h = layernorm(lp["mlp_norm"], xc, cfg.norm_eps)
        xc = xc + gelu_mlp(lp["mlp"], h)
        return xc, (kc, vc)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["dec_layers"], caches["k"], caches["v"],
                  caches["cross_k"], caches["cross_v"]),
        unroll=cfg.scan_unroll)
    x = layernorm(params["dec_norm"], x, cfg.norm_eps)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["table"].astype(dt))
    new_caches = dict(caches)
    new_caches["k"], new_caches["v"] = nk, nv
    return logits, new_caches
