"""Architecture configuration.

One :class:`ArchConfig` describes any of the assigned architectures; the
layer stack is expressed as a repeating *group pattern* (period-p list of
block descriptors) so heterogeneous stacks (gemma3's 5:1 local:global,
jamba's 7:1 mamba:attention with interleaved MoE) still lower as a single
``lax.scan`` over groups — one compiled group body regardless of depth,
which keeps dry-run compile times and HLO size flat in ``n_layers``.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

# Block descriptor: (mixer, mlp)
#   mixer ∈ {"attn", "attn_local", "mamba", "none"}
#   mlp   ∈ {"dense", "moe"}
BlockSpecT = Tuple[str, str]


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 1
    d_ff_expert: int = 0
    n_shared: int = 0          # always-on shared experts (DeepSeekMoE)
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    # §Perf: dispatch in D independent token blocks (vmapped).  Blocks map
    # 1:1 onto data shards, so routing sort/rank/scatter stays shard-local
    # and the (block × expert) dispatch buffer is fully 2D-sharded
    # (data × model) — no cross-chip permutes.  0 = single global dispatch
    # (the paper-faithful GShard-style baseline).
    dispatch_blocks: int = 0


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | audio | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 1024           # for attn_local blocks
    group_pattern: Tuple[BlockSpecT, ...] = (("attn", "dense"),)
    first_layer_override: Optional[BlockSpecT] = None  # e.g. deepseek dense L0
    moe: MoEConfig = MoEConfig()
    # ssm (mamba2)
    ssm_expand: int = 2
    ssm_state: int = 128
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128
    # encoder-decoder (whisper)
    enc_dec: bool = False
    n_enc_layers: int = 0
    n_frames: int = 1500                 # stub frontend sequence length
    # vlm
    vlm: bool = False
    n_patches: int = 576                 # stub anyres patch count per example
    # numerics / runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True
    scan_unroll: int = 1   # lax.scan unroll factor (cost-analysis correction
                           # + a perf knob: higher unroll exposes more overlap)
    zero1: bool = False                  # shard optimizer state over data axis
    # §Perf: flash-style chunked attention for train/prefill self-attention
    # (online softmax over KV chunks; 0 = dense S×S path).
    flash_chunk: int = 0
    # §Perf: serve decode data-parallel-only (params replicated, no TP).
    # Right for small models: kills every model-axis collective per token
    # (measured 16x latency-bound win on mamba2-370m decode_32k).
    dp_only_decode: bool = False
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # long-context capability (decides long_500k applicability)
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_groups(self) -> int:
        p = len(self.group_pattern)
        layers = self.n_layers - (1 if self.first_layer_override else 0)
        assert layers % p == 0, (self.name, self.n_layers, p)
        return layers // p

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        small = dict(
            n_layers=len(self.group_pattern) * 2
            + (1 if self.first_layer_override else 0),
            d_model=64,
            n_heads=4,
            n_kv=min(self.n_kv, 4) if self.n_kv >= self.n_heads // 4 else 2,
            d_ff=128,
            vocab=512,
            head_dim=16,
            n_enc_layers=2 if self.enc_dec else 0,
            n_frames=16 if self.enc_dec else self.n_frames,
            n_patches=8 if self.vlm else self.n_patches,
            ssm_state=16,
            ssm_headdim=8,
            ssm_chunk=8,
            sliding_window=8,
            remat=False,
        )
        if self.moe.n_experts:
            small["moe"] = MoEConfig(
                n_experts=4, top_k=min(2, self.moe.top_k), d_ff_expert=32,
                n_shared=min(1, self.moe.n_shared), d_ff_shared=32,
                capacity_factor=2.0)
        small.update(overrides)
        # keep n_kv dividing n_heads
        cfg = dataclasses.replace(self, **small)
        assert cfg.n_heads % cfg.n_kv == 0
        return cfg


# ---------------------------------------------------------------------------
# Input-shape cells (assigned to every architecture)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # "train" | "prefill" | "decode"


SHAPES: Tuple[ShapeCell, ...] = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


def shape_applicable(cfg: ArchConfig, cell: ShapeCell) -> Tuple[bool, str]:
    """(runnable, reason).  long_500k only for sub-quadratic archs."""
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention architecture: 524288-token dense KV "
                       "decode excluded per DESIGN.md §Arch-applicability")
    return True, ""
