#!/usr/bin/env python
"""Docs lint: every intra-repo markdown link must resolve.

Scans all ``*.md`` files (skipping hidden and build directories), pulls
``[text](target)`` links, and verifies that relative targets exist on
disk (anchors are stripped; external ``http(s)://`` / ``mailto:``
targets are ignored).  Exit code 1 on any broken link — CI fails fast
with a file:line listing.

    python tools/check_docs.py [repo_root]
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_DIRS = {".git", ".github", "node_modules", "__pycache__", ".venv",
             "results"}
EXTERNAL = ("http://", "https://", "mailto:")
#: docs that must exist (repo-root-relative) — a rename or deletion
#: must update every inbound link AND this registry, deliberately
REQUIRED_DOCS = (
    "README.md",
    "ROADMAP.md",
    "docs/architecture.md",
    "docs/serving.md",
    "docs/observability.md",
)


def iter_markdown(root: Path):
    for path in sorted(root.rglob("*.md")):
        if any(part in SKIP_DIRS or part.startswith(".")
               for part in path.relative_to(root).parts[:-1]):
            continue
        yield path


def check_file(path: Path, root: Path) -> list:
    errors = []
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            resolved = (path.parent / rel).resolve()
            if not resolved.exists():
                errors.append(
                    f"{path.relative_to(root)}:{lineno}: broken link "
                    f"-> {target}")
    return errors


def main() -> int:
    root = Path(sys.argv[1]).resolve() if len(sys.argv) > 1 \
        else Path(__file__).resolve().parent.parent
    errors = []
    for rel in REQUIRED_DOCS:
        if not (root / rel).exists():
            errors.append(f"{rel}: required doc missing")
    n_files = 0
    for path in iter_markdown(root):
        n_files += 1
        errors.extend(check_file(path, root))
    for e in errors:
        print(e)
    print(f"checked {n_files} markdown files: "
          f"{'OK' if not errors else f'{len(errors)} broken link(s)'}")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
