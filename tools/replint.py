#!/usr/bin/env python3
"""replint CLI — trace-safety lint over the repo's JAX/Pallas source.

Usage:
    python tools/replint.py src/repro [--strict] [--json findings.json]

Prints findings as ``path:line:col: rule: message``.  With ``--strict``
the process exits 1 when any finding survives suppression filtering —
the CI gate.  ``--json`` additionally writes the findings as a
machine-readable array (uploaded as a CI artifact).
"""

from __future__ import annotations

import argparse
import json
import sys
from dataclasses import asdict
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.analysis.lint import RULES, lint_paths  # noqa: E402


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    parser.add_argument("paths", nargs="*",
                        help="files or directories to lint")
    parser.add_argument("--strict", action="store_true",
                        help="exit 1 when any finding remains")
    parser.add_argument("--json", metavar="FILE", default=None,
                        help="write findings as JSON to FILE")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rule, desc in RULES.items():
            print(f"{rule}: {desc}")
        return 0
    if not args.paths:
        parser.error("paths required (or --list-rules)")

    findings = lint_paths(args.paths)
    for f in findings:
        print(f)

    if args.json:
        Path(args.json).write_text(
            json.dumps([asdict(f) for f in findings], indent=2) + "\n",
            encoding="utf-8")

    n = len(findings)
    print(f"replint: {n} finding(s)" if n else "replint: clean",
          file=sys.stderr)
    return 1 if (n and args.strict) else 0


if __name__ == "__main__":
    raise SystemExit(main())
