#!/usr/bin/env python3
"""Inspect flight-recorder dumps (``launch/serve.py --trace-dump``).

Reads either export format — Chrome ``chrome://tracing`` JSON (a
``{"traceEvents": [...]}`` object) or JSONL (one trace object per line,
the :meth:`repro.obs.recorder.FlightRecorder.to_jsonl` shape) — and
answers the operator questions a raw dump can't:

    python tools/trace_inspect.py traces.json            # per-trace table
    python tools/trace_inspect.py traces.json --stages   # stage totals
    python tools/trace_inspect.py traces.json --slowest 5
    python tools/trace_inspect.py traces.json --why 3    # routing story
    python tools/trace_inspect.py traces.jsonl --drift   # est vs actual

``--why`` prints the trace's ``router.decide`` / ``router.exclude``
events with the losing EWMAs attached — "why did this request run on
eager?" straight from the trace stream, no separate runtime report.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List


def _load(path: str) -> List[Dict[str, Any]]:
    """Normalize either dump format to the JSONL trace-dict shape."""
    text = open(path).read()
    try:
        doc = json.loads(text)
    except json.JSONDecodeError:
        # multiple top-level objects: one trace dict per line (JSONL)
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]
    if isinstance(doc, dict) and "traceEvents" in doc:
        return _from_chrome(doc["traceEvents"])
    return [doc] if isinstance(doc, dict) else list(doc)


def _from_chrome(events: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Group chrome events back into per-trace dicts (tid == trace id;
    ``ph: "X"`` spans, ``ph: "i"`` instants)."""
    by_tid: Dict[int, Dict[str, Any]] = {}
    for ev in events:
        tid = ev.get("tid", 0)
        tr = by_tid.setdefault(tid, {"trace_id": tid, "spans": []})
        if ev.get("ph") == "X":
            tr["spans"].append({
                "name": ev["name"],
                "t0": ev["ts"] / 1e6,
                "t1": (ev["ts"] + ev.get("dur", 0.0)) / 1e6,
                "duration_ms": ev.get("dur", 0.0) / 1e3,
                "attrs": ev.get("args", {}), "events": [],
            })
        elif ev.get("ph") == "i" and tr["spans"]:
            tr["spans"][-1]["events"].append(
                {"name": ev["name"], "t": ev["ts"] / 1e6,
                 "attrs": ev.get("args", {})})
    out = []
    for tr in by_tid.values():
        root = next((s for s in tr["spans"] if s["name"] == "request"),
                    None)
        tr["duration_ms"] = root["duration_ms"] if root else None
        out.append(tr)
    out.sort(key=lambda t: min((s["t0"] for s in t["spans"]),
                               default=0.0))
    return out


def _root(trace: Dict[str, Any]) -> Dict[str, Any]:
    for s in trace["spans"]:
        if s["name"] == "request":
            return s
    return trace["spans"][0] if trace["spans"] else {"attrs": {}}


def _all_events(trace: Dict[str, Any]):
    for span in trace["spans"]:
        for ev in span.get("events", []):
            yield ev


def _fmt_ms(v) -> str:
    return "?" if v is None else f"{v:9.3f}"


def cmd_table(traces: List[Dict[str, Any]]) -> None:
    print(f"{'trace':>6} {'total_ms':>9} {'backend':>12} "
          f"{'spans':>5}  query")
    for tr in traces:
        root = _root(tr)
        attrs = root.get("attrs", {})
        q = attrs.get("qtext", attrs.get("sig", ""))
        q = " ".join(str(q).split())
        print(f"{tr.get('trace_id', '?'):>6} "
              f"{_fmt_ms(tr.get('duration_ms'))} "
              f"{attrs.get('backend', '?'):>12} "
              f"{len(tr['spans']):>5}  {q[:70]}")


def cmd_stages(traces: List[Dict[str, Any]]) -> None:
    total: Dict[str, float] = defaultdict(float)
    count: Dict[str, int] = defaultdict(int)
    for tr in traces:
        for s in tr["spans"]:
            if s.get("duration_ms") is not None:
                total[s["name"]] += s["duration_ms"]
                count[s["name"]] += 1
    print(f"{'stage':>16} {'count':>6} {'total_ms':>10} {'mean_ms':>9}")
    for name in sorted(total, key=lambda n: -total[n]):
        print(f"{name:>16} {count[name]:>6} {total[name]:>10.3f} "
              f"{total[name] / count[name]:>9.3f}")


def cmd_slowest(traces: List[Dict[str, Any]], n: int) -> None:
    ranked = sorted(traces, key=lambda t: -(t.get("duration_ms") or 0.0))
    cmd_table(ranked[:n])


def cmd_why(traces: List[Dict[str, Any]], trace_id: int) -> int:
    tr = next((t for t in traces if t.get("trace_id") == trace_id), None)
    if tr is None:
        print(f"no trace {trace_id} in dump "
              f"(have: {[t.get('trace_id') for t in traces]})")
        return 1
    found = False
    for ev in _all_events(tr):
        if ev["name"] not in ("router.decide", "router.exclude"):
            continue
        found = True
        a = ev.get("attrs", {})
        if ev["name"] == "router.exclude":
            print(f"excluded {a.get('backend')}: {a.get('why')}")
            continue
        ewma = a.get("ewma_ms") or {}
        chosen = a.get("backend")
        losers = ", ".join(f"{b}={ewma[b]}ms" for b in sorted(ewma)
                           if b != chosen)
        own = f"{ewma[chosen]}ms" if chosen in ewma else "no estimate yet"
        print(f"ran on {chosen} ({a.get('reason')}): own EWMA {own}"
              + (f"; losing: {losers}" if losers else ""))
    if not found:
        print("no routing events in this trace")
    return 0


def cmd_drift(traces: List[Dict[str, Any]]) -> None:
    for tr in traces:
        cards = _root(tr).get("attrs", {}).get("cardinalities")
        if not cards:
            continue
        print(f"trace {tr.get('trace_id')}:")
        for c in cards:
            est, act = c.get("est"), c.get("actual")
            ratio = "?" if not est or act is None \
                else f"{(act / est):.2f}x"
            print(f"  step {c.get('step')}: est={est} actual={act} "
                  f"({ratio})  {c.get('op', '')}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("dump", help="trace dump (.json chrome / .jsonl)")
    ap.add_argument("--stages", action="store_true",
                    help="aggregate per-stage span totals")
    ap.add_argument("--slowest", type=int, metavar="N", default=None,
                    help="show only the N slowest traces")
    ap.add_argument("--why", type=int, metavar="TRACE_ID", default=None,
                    help="print the routing decision story of one trace")
    ap.add_argument("--drift", action="store_true",
                    help="estimated vs. actual per-step cardinalities")
    args = ap.parse_args(argv)
    traces = _load(args.dump)
    if not traces:
        print("empty dump")
        return 1
    if args.why is not None:
        return cmd_why(traces, args.why)
    if args.drift:
        cmd_drift(traces)
    elif args.stages:
        cmd_stages(traces)
    elif args.slowest is not None:
        cmd_slowest(traces, args.slowest)
    else:
        cmd_table(traces)
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main())
    except BrokenPipeError:     # e.g. piped into head
        sys.exit(0)
