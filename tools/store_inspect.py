#!/usr/bin/env python
"""Inspect a persistent columnar catalog store.

    PYTHONPATH=src python tools/store_inspect.py <path> [--no-verify]

Prints the manifest version, the SF-threshold τ, table counts, on-disk
bytes per section, and the delta-journal state, then (unless
``--no-verify``) streams every column file through its manifest CRC-32
and checks every delta segment's payload checksum.  Exit status is
non-zero on a missing/malformed store or any checksum mismatch, so this
doubles as a fsck for CI and operators.
"""

from __future__ import annotations

import argparse
import os
import sys


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n} B"


def inspect(path: str, verify: bool = True) -> int:
    from repro.store import (StoreError, load_manifest, read_segments,
                             section_bytes)
    from repro.store.format import crc32_file

    try:
        manifest = load_manifest(path)
    except StoreError as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 1

    sec = section_bytes(manifest, path)
    sf = manifest["sf"]
    n_empty = sum(1 for v in sf.values() if v == 0.0)
    n_identity = sum(1 for v in sf.values() if v == 1.0)
    print(f"store:            {os.path.abspath(path)}")
    print(f"format:           {manifest['format']} v{manifest['version']}")
    print(f"threshold τ:      {manifest['threshold']}")
    print(f"kinds:            {' '.join(manifest['kinds'])}")
    print(f"build backend:    {manifest.get('build_backend', '?')}")
    print(f"triples:          {manifest['tt']['rows']}")
    print(f"dictionary terms: {manifest['dictionary']['n_terms']}")
    print(f"VP tables:        {len(manifest['vp'])}")
    print(f"ExtVP tables:     {len(manifest['extvp'])} materialized "
          f"({len(sf)} pair stats, {n_empty} empty, {n_identity} identity)")
    print("on-disk bytes:")
    for name in ("manifest", "dictionary", "tt", "vp", "extvp", "delta"):
        print(f"  {name:<11} {_fmt_bytes(sec[name])}")
    print(f"  {'total':<11} {_fmt_bytes(sum(sec.values()))}")

    try:
        segments = read_segments(path)   # always payload-checksummed
    except StoreError as e:
        print(f"ERROR: {e}", file=sys.stderr)
        return 1
    print(f"delta segments:   {len(segments)} "
          f"({sum(len(s.triples) for s in segments)} journaled triples)")

    if not verify:
        return 0
    entries = [manifest["dictionary"]["terms"], manifest["dictionary"]["values"],
               manifest["tt"], *manifest["vp"].values(),
               *manifest["extvp"].values()]
    bad = 0
    for entry in entries:
        fpath = os.path.join(path, entry["file"])
        if not os.path.isfile(fpath):
            print(f"MISSING: {entry['file']}", file=sys.stderr)
            bad += 1
            continue
        actual = crc32_file(fpath)
        if actual != int(entry["crc32"]):
            print(f"CHECKSUM MISMATCH: {entry['file']} "
                  f"({actual:#010x} != {int(entry['crc32']):#010x})",
                  file=sys.stderr)
            bad += 1
    if bad:
        print(f"checksums:        FAILED ({bad}/{len(entries)} files)",
              file=sys.stderr)
        return 1
    print(f"checksums:        OK ({len(entries)} files)")
    return 0


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("path", help="store directory (holds manifest.json)")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the streaming checksum pass over column files")
    args = ap.parse_args()
    sys.exit(inspect(args.path, verify=not args.no_verify))


if __name__ == "__main__":
    main()
