"""Static-analysis layer: plan/IR verifier negative + corpus tests, and
replint unit tests over known-bad snippets.

Every error-severity invariant in ``repro.analysis.verifier`` has a
mutation test here proving it fires with the right diagnostic; the
corpus tests prove the verifier is silent on every legitimate plan the
differential fuzz and the WatDiv basic suite produce (both planners,
all backends).
"""

import dataclasses

import pytest

from repro.analysis import (
    PlanVerificationError, lint_paths, lint_source,
    verify_core, verify_executor, verify_plan, verify_prepared,
)
from repro.core.compiler import Plan, compile_bgp, compile_core, select_table
from repro.core.jexec import PlanExecutor
from repro.core.modifiers import ModifierSpine, peel_spine
from repro.core.sparql import parse_sparql
from repro.core.stats import build_catalog
from repro.rdf.dictionary import Dictionary

G1_TRIPLES = [
    ("A", "follows", "B"), ("B", "follows", "C"), ("B", "follows", "D"),
    ("C", "follows", "D"), ("A", "likes", "I1"), ("A", "likes", "I2"),
    ("C", "likes", "I2"),
]


def fresh_g1(threshold=1.0):
    d = Dictionary()
    tt = d.encode_triples(G1_TRIPLES)
    return build_catalog(tt, d, threshold=threshold), d


def plan_for(qtext, cat, d, planner="greedy"):
    return compile_bgp(parse_sparql(qtext, d).root, cat, planner=planner)


def error_rules(report):
    return {diag.rule for diag in report.errors}


# ---------------------------------------------------------------------------
# verifier: legitimate plans are silent
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("planner", ["greedy", "estimate"])
def test_clean_plans_verify_ok(planner):
    cat, d = fresh_g1()
    for q in (
        "SELECT * WHERE { ?x follows ?y }",
        "SELECT * WHERE { ?x follows ?y . ?y likes ?z }",
        "SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
        "?y follows ?z . ?z likes ?w }",
    ):
        report = verify_plan(plan_for(q, cat, d, planner), cat)
        assert report.ok and not report.diagnostics, (q, report.diagnostics)
        assert report.checks  # ran, not skipped


def test_statistics_empty_plan_verifies():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x likes ?y . ?y follows ?z }", cat, d)
    assert plan.empty  # OS likes|follows has SF = 0 on G1
    assert verify_plan(plan, cat).ok


# ---------------------------------------------------------------------------
# verifier: each invariant fires on a mutated plan (negative tests)
# ---------------------------------------------------------------------------

def test_cross_join_rejected():
    cat, d = fresh_g1()
    plan = plan_for(
        "SELECT * WHERE { ?a follows ?b . ?b follows ?c . ?c likes ?w }",
        cat, d)
    by_pred_pos = {
        frozenset(v for v in (s.tp.s, s.tp.o)): s for s in plan.steps}
    s_ab = by_pred_pos[frozenset({"?a", "?b"})]
    s_bc = by_pred_pos[frozenset({"?b", "?c"})]
    s_cw = by_pred_pos[frozenset({"?c", "?w"})]
    # ?c likes ?w placed while disconnected from {?a, ?b}, although the
    # connecting step comes later: an unforced cross product
    bad = Plan(steps=[s_ab, s_cw, s_bc], vars=plan.vars,
               planner=plan.planner)
    assert "cross-join" in error_rules(verify_plan(bad, cat))


def test_sf_zero_step_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    plan.steps[0].sf = 0.0
    assert "sf-zero-step" in error_rules(verify_plan(plan, cat))


def test_empty_flag_mismatch_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    plan.empty = True
    assert "empty-flag" in error_rules(verify_plan(plan, cat))


def test_unknown_planner_tag_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    plan.planner = "quantum"
    assert "planner-tag" in error_rules(verify_plan(plan, cat))


def test_sentinel_collision_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    # UNBOUND (-1) as a bound subject id collides with the sentinel band
    plan.steps[0].tp = dataclasses.replace(plan.steps[0].tp, s=-1)
    assert "sentinel-collision" in error_rules(verify_plan(plan, cat))


def test_fabricated_table_stats_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    plan.steps[0].sf = 0.5    # VP scan must record sf=1.0 + the VP size
    assert "table-choice" in error_rules(verify_plan(plan, cat))


def test_unmaterialized_extvp_choice_rejected():
    cat, d = fresh_g1(threshold=0.25)
    follows, likes = d.term_to_id["follows"], d.term_to_id["likes"]
    assert cat.sf("SO", likes, follows) > cat.extvp.threshold
    plan = plan_for("SELECT * WHERE { ?x follows ?y . ?y likes ?z }",
                    cat, d)
    step = next(s for s in plan.steps if int(s.tp.p) == likes)
    # force the SF > τ choice Algorithm 1 must no longer make: the stats
    # are the catalog's own, so only the materialization check can fire
    step.kind, step.p2 = "SO", follows
    step.sf = cat.sf("SO", likes, follows)
    step.size = cat.size("SO", likes, follows)
    assert error_rules(verify_plan(plan, cat)) == {"extvp-materialized"}


def test_extvp_partner_missing_rejected():
    cat, d = fresh_g1()
    follows, likes = d.term_to_id["follows"], d.term_to_id["likes"]
    plan = plan_for("SELECT * WHERE { ?y likes ?z }", cat, d)
    step = plan.steps[0]
    step.kind, step.p2 = "SO", follows
    step.sf = cat.sf("SO", likes, follows)
    step.size = cat.size("SO", likes, follows)
    assert "extvp-partner" in error_rules(verify_plan(plan, cat))


def test_flat_offset_corruption_rejected():
    cat, d = fresh_g1()
    query = parse_sparql(
        "SELECT * WHERE { { ?a follows ?b } UNION { ?a likes ?b } }", d)
    node, spine = peel_spine(query)
    core = compile_core(node, cat)
    core.root.right.start += 1
    assert "flat-offset" in error_rules(verify_core(core, cat, spine=spine))


def test_dropped_cap_slot_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y . ?y likes ?z }",
                    cat, d)
    ex = PlanExecutor(plan, cat)
    assert verify_executor(ex).ok
    ex.caps = ex.caps[:-1]
    assert "cap-slots" in error_rules(verify_executor(ex))


def test_corrupted_combine_index_rejected():
    cat, d = fresh_g1()
    query = parse_sparql(
        "SELECT * WHERE { ?a follows ?b OPTIONAL { ?b likes ?w } }", d)
    node, spine = peel_spine(query)
    core = compile_core(node, cat)
    ex = PlanExecutor(core, cat, spine=spine)
    assert verify_executor(ex).ok
    (seg_id, slot), = ex._comb_index.items()
    ex._comb_index = {seg_id: slot + 1}   # points at a non-combine slot
    assert "cap-slots" in error_rules(verify_executor(ex))


def test_negative_slice_rejected():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    spine = ModifierSpine(offset=-1)
    assert "modifier-slice" in error_rules(verify_plan(plan, cat, spine))


def test_raise_if_failed_carries_diagnostics():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    plan.planner = "quantum"
    with pytest.raises(PlanVerificationError) as exc:
        verify_plan(plan, cat).raise_if_failed()
    assert "planner-tag" in exc.value.rules()
    assert "quantum" in str(exc.value)


# ---------------------------------------------------------------------------
# verifier: warnings (diagnose, never reject)
# ---------------------------------------------------------------------------

def test_phantom_filter_var_warns():
    cat, d = fresh_g1()
    query = parse_sparql(
        "SELECT * WHERE { ?x follows ?y FILTER(?zz != ?x) }", d)
    node, spine = peel_spine(query)
    core = compile_core(node, cat)
    report = verify_core(core, cat, spine=spine)
    assert report.ok  # legal SPARQL: evaluates with UNBOUND
    assert "filter-var" in {diag.rule for diag in report.warnings}


def test_phantom_projection_var_warns():
    cat, d = fresh_g1()
    plan = plan_for("SELECT * WHERE { ?x follows ?y }", cat, d)
    spine = ModifierSpine(project=("?nope",))
    report = verify_plan(plan, cat, spine=spine)
    assert report.ok
    assert "projection-var" in {diag.rule for diag in report.warnings}


# ---------------------------------------------------------------------------
# Algorithm 1 only credits materialized reductions (the verifier-driven
# compiler fix)
# ---------------------------------------------------------------------------

def test_select_table_skips_unmaterialized_pairs():
    cat, d = fresh_g1(threshold=0.25)
    follows, likes = d.term_to_id["follows"], d.term_to_id["likes"]
    q = parse_sparql("SELECT * WHERE { ?x follows ?y . ?y likes ?z }", d)
    tps = list(q.root.patterns)
    by_pred = {int(tp.p): tp for tp in tps}
    # OS follows|likes has SF = 0.25 ≤ τ: materialized, selected
    f_step = select_table(by_pred[follows], tps, cat)
    assert (f_step.kind, f_step.p2) == ("OS", likes)
    # SO likes|follows has SF = 1/3 > τ: NOT materialized — Algorithm 1
    # must fall back to VP instead of crediting a reduction the store
    # cannot serve (the scan would read the full VP table anyway)
    l_step = select_table(by_pred[likes], tps, cat)
    assert l_step.kind is None and l_step.p2 is None
    assert l_step.sf == 1.0 and l_step.size == cat.vp_size(likes)
    # and the full plan now verifies clean at this τ
    assert verify_plan(plan_for(
        "SELECT * WHERE { ?x follows ?y . ?y likes ?z }", cat, d), cat).ok


def test_select_table_keeps_sf_zero_short_circuit():
    # SF=0 pairs are never materialized yet MUST stay selectable — they
    # are the statistics-only empty answer (paper §6)
    cat, d = fresh_g1(threshold=0.25)
    plan = plan_for("SELECT * WHERE { ?x likes ?y . ?y follows ?z }",
                    cat, d)
    assert plan.empty


# ---------------------------------------------------------------------------
# verifier: corpus sweeps (zero violations on everything the fuzz and the
# WatDiv basic suite produce)
# ---------------------------------------------------------------------------

def test_fixed_corpus_zero_violations():
    import jax
    from test_differential import FIXED_QUERIES, fixed_corpus_triples
    from repro.engine import Dataset, RuntimeConfig

    mesh = jax.make_mesh((1,), ("data",))
    triples = fixed_corpus_triples()
    for tau in (0.25, 1.0):
        ds = Dataset.from_triples(triples, threshold=tau)
        for planner in ("greedy", "estimate"):
            cfg = RuntimeConfig(planner=planner)
            for backend in ("eager", "jit", "distributed"):
                eng = ds.engine(backend, mesh=mesh, runtime=cfg)
                for qtext in FIXED_QUERIES:
                    report = verify_prepared(eng.prepare(qtext), ds.catalog)
                    assert report.ok, \
                        (tau, planner, backend, qtext, report.errors)


def test_watdiv_basic_suite_zero_violations(watdiv_small):
    from repro.rdf.workloads import basic_queries

    cat, d, sch = watdiv_small
    for planner in ("greedy", "estimate"):
        for name, instances in basic_queries(sch, n_instances=1).items():
            for qtext in instances:
                node, spine = peel_spine(parse_sparql(qtext, d))
                core = compile_core(node, cat, planner=planner)
                report = verify_core(core, cat, spine=spine)
                assert report.ok, (planner, name, qtext, report.errors)


# ---------------------------------------------------------------------------
# engine integration
# ---------------------------------------------------------------------------

def test_engine_explains_verdict_and_config_knob():
    from repro.engine import Dataset, RuntimeConfig

    cfg = RuntimeConfig(verify_plans=True)
    assert cfg.verify_plans is True
    assert "verify_plans" in cfg.snapshot()
    ds = Dataset.from_triples(G1_TRIPLES)
    eng = ds.engine("jit", runtime=cfg)
    out = eng.explain("SELECT * WHERE { ?x <follows> ?y . ?y <likes> ?z }")
    assert "verify: ok" in out


def test_unverifiable_prepared_reports_skip():
    report = verify_prepared(object(), None)
    assert report.ok and not report.checks
    assert "skipped" in report.describe()


# ---------------------------------------------------------------------------
# replint: known-bad snippets
# ---------------------------------------------------------------------------

def rules_of(findings):
    return [f.rule for f in findings]


def test_lint_traced_branch():
    findings = lint_source(
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    y = jnp.sum(x)\n"
        "    if y > 0:\n"
        "        return y\n"
        "    return -y\n")
    assert rules_of(findings) == ["traced-branch"]
    assert findings[0].line == 5


def test_lint_traced_while_and_ternary():
    findings = lint_source(
        "import jax.numpy as jnp\n"
        "def device_count(x):\n"
        "    t = jnp.sum(x)\n"
        "    while t > 0:\n"
        "        t = t - 1\n"
        "    return t if t > 0 else -t\n")
    assert rules_of(findings) == ["traced-branch", "traced-branch"]


def test_lint_host_sync_item_and_np():
    findings = lint_source(
        "import numpy as np\n"
        "import jax.numpy as jnp\n"
        "def device_norm(x):\n"
        "    t = jnp.exp(x)\n"
        "    a = np.asarray(t)\n"
        "    return t.sum().item()\n")
    assert sorted(rules_of(findings)) == ["host-sync", "host-sync"]


def test_lint_host_sync_float_cast():
    findings = lint_source(
        "import jax.numpy as jnp\n"
        "def device_f(x):\n"
        "    return float(jnp.sum(x))\n")
    assert rules_of(findings) == ["host-sync"]


def test_lint_int32_overflow():
    findings = lint_source(
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    return x + 3000000000\n")
    assert rules_of(findings) == ["int32-overflow"]


def test_lint_nonstatic_shape_from_traced_n():
    findings = lint_source(
        "import jax.numpy as jnp\n"
        "def device_pad(b):\n"
        "    return jnp.zeros((b.n,), jnp.int32)\n")
    assert rules_of(findings) == ["nonstatic-shape"]


def test_lint_shard_map_check_rep():
    src = (
        "from jax.experimental.shard_map import shard_map\n"
        "def build(body, mesh, specs):\n"
        "    return shard_map(body, mesh=mesh, in_specs=specs,\n"
        "                     out_specs=specs)\n")
    assert rules_of(lint_source(src)) == ["shard-map-check-rep"]
    ok = src.replace("out_specs=specs)", "out_specs=specs, check_rep=False)")
    assert lint_source(ok) == []


def test_lint_functions_passed_to_tracers_are_traced():
    findings = lint_source(
        "import jax, jax.numpy as jnp\n"
        "def body(x):\n"
        "    s = jnp.sum(x)\n"
        "    if s > 0:\n"
        "        return s\n"
        "    return x\n"
        "g = jax.jit(body)\n")
    assert rules_of(findings) == ["traced-branch"]


def test_lint_call_graph_propagation():
    findings = lint_source(
        "import jax, jax.numpy as jnp\n"
        "def helper(x):\n"
        "    m = jnp.max(x)\n"
        "    if m > 0:\n"
        "        return m\n"
        "    return x\n"
        "@jax.jit\n"
        "def entry(x):\n"
        "    return helper(x)\n")
    assert rules_of(findings) == ["traced-branch"]


def test_lint_static_idioms_stay_clean():
    assert lint_source(
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x, flag):\n"
        "    # static-shape branch + host list of traced values: all fine\n"
        "    if x.shape[0] > 2:\n"
        "        x = x[:2]\n"
        "    if flag:\n"
        "        x = -x\n"
        "    masks = [jnp.sum(x), jnp.prod(x)]\n"
        "    out = masks[0]\n"
        "    for m in masks[1:]:\n"
        "        out = out + m\n"
        "    if len(masks) > 1:\n"
        "        out = out * 2\n"
        "    total = jnp.sum(out)\n"
        "    if total is not None:\n"
        "        out = out + 1\n"
        "    return out\n") == []


def test_lint_untraced_functions_not_checked():
    # host-side code may branch on numpy values freely
    assert lint_source(
        "import numpy as np\n"
        "def host(x):\n"
        "    y = np.sum(x)\n"
        "    if y > 0:\n"
        "        return float(y)\n"
        "    return 0.0\n") == []


def test_lint_suppression_requires_justification():
    base = (
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    y = jnp.sum(x)\n"
        "    if y > 0:  # replint: disable=traced-branch{tail}\n"
        "        return y\n"
        "    return -y\n")
    justified = base.format(tail=" -- static under concrete test harness")
    assert lint_source(justified) == []
    bare = base.format(tail="")
    assert rules_of(lint_source(bare)) == ["bare-suppression"]


def test_lint_standalone_suppression_line_covers_next_line():
    src = (
        "import jax, jax.numpy as jnp\n"
        "@jax.jit\n"
        "def f(x):\n"
        "    y = jnp.sum(x)\n"
        "    # replint: disable=traced-branch -- trace-time constant here\n"
        "    if y > 0:\n"
        "        return y\n"
        "    return -y\n")
    assert lint_source(src) == []


def test_repo_lint_is_clean():
    import pathlib

    src = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"
    findings = lint_paths([src])
    assert findings == [], "\n".join(str(f) for f in findings)
