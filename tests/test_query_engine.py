"""Parser, compiler (Algorithms 1/4) and eager executor vs the brute-force
oracle — including hypothesis property tests over random graphs + BGPs."""

import collections

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.algebra import BGP, TriplePattern
from repro.core.compiler import compile_bgp, select_table
from repro.core.executor import execute
from repro.core.reference import execute_reference, mappings_to_multiset
from repro.core.sparql import parse_sparql
from repro.core.stats import build_catalog
from repro.rdf.dictionary import Dictionary


def run_both(qtext, cat, d):
    q = parse_sparql(qtext, d)
    got = execute(q, cat)
    ref = execute_reference(q, cat.tt, d.values)
    assert mappings_to_multiset(ref, got.cols) == got.as_multiset(), qtext
    return got


class TestPaperExample:
    def test_q1_result(self, g1):
        cat, d = g1
        got = run_both(
            "SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
            "?y follows ?z . ?z likes ?w }", cat, d)
        assert len(got) == 1
        row = {c: d.term_of(int(v)) for c, v in zip(got.cols, got.data[0])}
        assert row == {"?x": "A", "?y": "B", "?z": "C", "?w": "I2"}

    def test_q1_table_selection(self, g1):
        """Fig. 11: tp3 = (?y follows ?z) must select ExtVP^OS_{follows|likes}."""
        cat, d = g1
        f, l = d.id_of("follows"), d.id_of("likes")
        tps = [
            TriplePattern("?x", l, "?w"), TriplePattern("?x", f, "?y"),
            TriplePattern("?y", f, "?z"), TriplePattern("?z", l, "?w"),
        ]
        step = select_table(tps[2], tps, build_catalog(cat.tt, d))
        assert (step.kind, step.p2) == ("OS", l)
        assert step.sf == 0.25

    def test_join_order_smallest_first(self, g1):
        """Fig. 12: the two smallest tables (tp3, tp4) join first."""
        cat, d = g1
        q = parse_sparql(
            "SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
            "?y follows ?z . ?z likes ?w }", d)
        plan = compile_bgp(q.root, cat)
        sizes = [s.size for s in plan.steps]
        assert sizes[0] == min(sizes)


class TestStatisticsShortCircuit:
    def test_empty_correlation(self, watdiv_small):
        """ST-8 behaviour: provably-empty queries never touch data."""
        cat, d, sch = watdiv_small
        q = parse_sparql(
            "SELECT * WHERE { ?p sorg:price ?x . ?x wsdbm:follows ?y }", d)
        plan = compile_bgp(q.root, cat)
        assert plan.empty
        assert len(execute(q, cat)) == 0

    def test_missing_term(self, watdiv_small):
        cat, d, _ = watdiv_small
        q = parse_sparql(
            "SELECT * WHERE { ?s wsdbm:doesNotExist ?o }", d)
        assert compile_bgp(q.root, cat).empty

    def test_large_intermediate_skipped(self, watdiv_small):
        """ST-8-2: big intermediates never materialize when stats say empty."""
        cat, d, _ = watdiv_small
        q = parse_sparql(
            "SELECT * WHERE { ?a wsdbm:friendOf ?b . ?b wsdbm:follows ?c . "
            "?c sorg:hasGenre ?g }", d)
        plan = compile_bgp(q.root, cat)
        assert plan.empty  # users never subjects of hasGenre


class TestOperators:
    def test_filter_numeric(self, watdiv_small):
        cat, d, _ = watdiv_small
        run_both("SELECT * WHERE { ?u foaf:age ?a . FILTER(?a > 50) }", cat, d)
        run_both("SELECT * WHERE { ?p sorg:price ?x . FILTER(?x >= 900 && ?x < 950) }",
                 cat, d)

    def test_filter_identity(self, watdiv_small):
        cat, d, _ = watdiv_small
        run_both('SELECT * WHERE { ?u wsdbm:gender ?g . FILTER(?g = "str1") }', cat, d)
        run_both('SELECT * WHERE { ?u wsdbm:gender ?g . FILTER(?g != "str1") }', cat, d)

    def test_optional(self, watdiv_small):
        cat, d, _ = watdiv_small
        got = run_both(
            "SELECT * WHERE { ?u wsdbm:likes ?p OPTIONAL { ?u foaf:age ?a } }",
            cat, d)
        assert (got.col("?a") == -1).any()   # some users have no age

    def test_union(self, watdiv_small):
        cat, d, _ = watdiv_small
        run_both(
            "SELECT * WHERE { { ?u wsdbm:purchased ?p } UNION { ?u wsdbm:likes ?p } }",
            cat, d)

    def test_distinct_orderby_limit(self, watdiv_small):
        cat, d, _ = watdiv_small
        got = run_both(
            "SELECT DISTINCT ?a WHERE { ?u foaf:age ?a } ORDER BY ?a LIMIT 5",
            cat, d)
        assert len(got) <= 5
        vals = d.values[got.data[:, 0]]
        assert np.all(np.diff(vals) >= 0)

    def test_bound_object(self, watdiv_small):
        cat, d, sch = watdiv_small
        run_both("SELECT * WHERE { ?u wsdbm:likes wsdbm:Product1 . "
                 "?u sorg:email ?e }", cat, d)

    def test_unbound_predicate_uses_tt(self, watdiv_small):
        cat, d, _ = watdiv_small
        got = run_both("SELECT * WHERE { wsdbm:Retailer1 ?p ?o }", cat, d)
        assert len(got) > 0

    def test_projection_select(self, watdiv_small):
        cat, d, _ = watdiv_small
        got = run_both("SELECT ?u WHERE { ?u wsdbm:likes ?p . ?p sorg:price ?x }",
                       cat, d)
        assert got.cols == ("?u",)


# ---------------------------------------------------------------------------
# Property tests: random graphs × random BGPs vs brute force
# ---------------------------------------------------------------------------

@st.composite
def random_graph_and_bgp(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    n_terms = draw(st.integers(3, 10))
    n_preds = draw(st.integers(1, 4))
    n_triples = draw(st.integers(1, 50))
    tt = np.stack([
        rng.integers(0, n_terms, n_triples),
        n_terms + rng.integers(0, n_preds, n_triples),
        rng.integers(0, n_terms, n_triples),
    ], axis=1).astype(np.int32)
    tt = np.unique(tt, axis=0)

    n_patterns = draw(st.integers(1, 4))
    var_pool = ["?a", "?b", "?c", "?d", "?e"]

    def term(position):
        choice = draw(st.integers(0, 9))
        if choice < 6:
            return var_pool[draw(st.integers(0, len(var_pool) - 1))]
        if position == 1:
            return int(n_terms + draw(st.integers(0, n_preds - 1)))
        return int(draw(st.integers(0, n_terms - 1)))

    patterns = []
    for _ in range(n_patterns):
        s, o = term(0), term(2)
        # predicate: mostly bound (the realistic case the engine optimizes)
        p = term(1) if draw(st.integers(0, 4)) == 0 else \
            int(n_terms + draw(st.integers(0, n_preds - 1)))
        patterns.append(TriplePattern(s, p, o))
    return tt, patterns


@settings(max_examples=60, deadline=None)
@given(random_graph_and_bgp())
def test_bgp_engine_matches_oracle(case):
    tt, patterns = case
    from repro.core.algebra import Query
    cat = build_catalog(tt)
    q = Query(root=BGP(patterns), select=None, distinct=False)
    got = execute(q, cat)
    ref = execute_reference(q, tt)
    assert mappings_to_multiset(ref, got.cols) == got.as_multiset(), \
        (patterns, got.data, ref)


class TestPtBaseline:
    """Sempala-style property-table layout (paper §4.3 baseline)."""

    def test_pt_agrees_with_extvp(self, watdiv_small):
        cat, d, sch = watdiv_small
        from repro.rdf.workloads import basic_queries
        import collections
        for name, insts in basic_queries(sch, seed=5, n_instances=1).items():
            q = parse_sparql(insts[0], d)
            a = execute(q, cat, layout="extvp")
            b = execute(q, cat, layout="pt")
            key = sorted(a.cols)
            ma = collections.Counter(map(tuple, a.data[:, [a.cols.index(c) for c in key]].tolist()))
            mb = collections.Counter(map(tuple, b.data[:, [b.cols.index(c) for c in key]].tolist()))
            assert ma == mb, name

    def test_pt_star_group_decomposition(self, watdiv_small):
        cat, d, _ = watdiv_small
        from repro.core.algebra import BGP
        from repro.core.pt import _star_groups
        q = parse_sparql(
            "SELECT * WHERE { ?u sorg:email ?e . ?u foaf:age ?a . "
            "?u wsdbm:likes ?p . ?p sorg:price ?x }", d)
        groups = _star_groups(q.root.patterns)
        assert sorted(len(g) for g in groups) == [1, 3]
