"""Launch-layer tests: HLO collective parser, roofline math, mesh
construction, elastic-policy integration with the dry-run helpers."""

import numpy as np
import pytest

from repro.launch.hlo import collective_bytes, parse_hlo_shapes

HLO_SAMPLE = """
HloModule test

ENTRY %main (p0: bf16[16,128]) -> bf16[16,128] {
  %p0 = bf16[16,128]{1,0} parameter(0)
  %ar = bf16[16,128]{1,0} all-reduce(%p0), replica_groups={}, to_apply=%add
  %ag = bf16[64,128]{1,0} all-gather(%ar), dimensions={0}
  %slice = bf16[16,128]{1,0} slice(%ag), slice={[0:16], [0:128]}
  %a2a = (s32[1,32,2]{2,1,0}, s32[1,32,2]{2,1,0}, /*index=2*/s32[1,32,2]{2,1,0}) all-to-all(%slice, %slice, %slice), dimensions={0}
  %rs = bf16[4,128]{1,0} reduce-scatter(%slice), dimensions={0}, to_apply=%add
  ROOT %cp = bf16[16,128]{1,0} collective-permute(%slice), source_target_pairs={{0,1}}
}
"""


class TestHloParser:
    def test_shapes_parsed(self):
        shapes = parse_hlo_shapes(HLO_SAMPLE)
        assert shapes["p0"] == 16 * 128 * 2
        assert shapes["ag"] == 64 * 128 * 2

    def test_collective_bytes(self):
        out = collective_bytes(HLO_SAMPLE)
        assert out["all-reduce"] == 16 * 128 * 2          # operand of %ar
        assert out["all-gather"] == 16 * 128 * 2          # operand (shard)
        # tuple-result all-to-all with /*index=N*/ comments: 3 operands
        assert out["all-to-all"] == 3 * 16 * 128 * 2
        assert out["reduce-scatter"] == 16 * 128 * 2
        assert out["collective-permute"] == 16 * 128 * 2
        assert out["total"] == sum(
            out[k] for k in ("all-reduce", "all-gather", "all-to-all",
                             "reduce-scatter", "collective-permute"))
        # ring weighting doubles all-reduce only
        assert out["weighted"] == out["total"] + out["all-reduce"]

    def test_async_start_done_counted_once(self):
        hlo = """
  %ars = bf16[16,128]{1,0} all-reduce-start(%p0), to_apply=%add
  %ard = bf16[16,128]{1,0} all-reduce-done(%ars)
  %p0 = bf16[16,128]{1,0} parameter(0)
"""
        out = collective_bytes(hlo)
        assert out["all-reduce"] == 16 * 128 * 2


class TestCostCorrection:
    def test_unroll_diff_math(self):
        from repro.launch.dryrun import corrected_costs, pick_unroll
        # nonloop = 100, body = 10, G = 24
        a1 = {"flops": 110.0, "bytes": 110.0, "coll": 110.0}
        a2 = {"flops": 120.0, "bytes": 120.0, "coll": 120.0}
        c = corrected_costs(a1, a2, g=24, k=2)
        assert c["flops"] == pytest.approx(100 + 24 * 10)

    def test_pick_unroll_divides(self):
        from repro.launch.dryrun import pick_unroll
        for g in (24, 8, 40, 27, 9, 12, 60, 48):
            k = pick_unroll(g)
            assert k > 1 and g % k == 0

    def test_xla_undercounts_loop_bodies(self):
        """The measurement bug the correction exists for (documents the
        refuted 'trust cost_analysis' hypothesis, EXPERIMENTS.md §Dry-run)."""
        import jax
        import jax.numpy as jnp
        D, L, B = 64, 8, 4
        w = jnp.zeros((L, D, D), jnp.float32)
        x = jnp.zeros((B, D), jnp.float32)

        def body(x, wl):
            return x @ wl, ()

        def f_scan(x, w):
            return jax.lax.scan(body, x, w)[0].sum()

        def f_unroll(x, w):
            for i in range(L):
                x, _ = body(x, w[i])
            return x.sum()

        from repro.launch.dryrun import cost_analysis_dict
        fs = cost_analysis_dict(jax.jit(f_scan).lower(x, w).compile())["flops"]
        fu = cost_analysis_dict(jax.jit(f_unroll).lower(x, w).compile())["flops"]
        assert fu > 4 * fs  # unrolled counts every layer; scan ~one body


def test_make_production_mesh_shapes():
    import jax
    from repro.launch.mesh import make_production_mesh
    if jax.device_count() < 512:
        pytest.skip("needs forced 512-device process (dry-run only)")
    mesh = make_production_mesh()
    assert dict(mesh.shape) == {"data": 16, "model": 16}


def test_dryrun_artifact_complete():
    """The committed dry-run results must cover every cell × both meshes."""
    import json
    import os
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not yet materialized")
    recs = {}
    for line in open(path):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    from repro.configs import ARCHS
    from repro.models.config import SHAPES, shape_applicable, ShapeCell
    from repro.configs import get
    missing, bad = [], []
    for arch in ARCHS:
        for cell in SHAPES:
            for mesh in ("single", "multi"):
                r = recs.get((arch, cell.name, mesh))
                if r is None:
                    missing.append((arch, cell.name, mesh))
                elif r["status"] == "error":
                    bad.append((arch, cell.name, mesh))
                elif r["status"] == "skipped":
                    assert not shape_applicable(get(arch), cell)[0]
    if missing:
        pytest.skip(f"sweep incomplete: {len(missing)} cells pending")
    assert not bad, bad
