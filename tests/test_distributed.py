"""Distributed engine tests.  Multi-device cases run in a subprocess with
XLA_FLAGS forcing 8 host devices (the main test process must keep seeing
exactly one device, per the dry-run isolation rule)."""

import os
import subprocess
import sys
import textwrap

import collections
import jax
import numpy as np
import pytest

from repro.core.compiler import compile_bgp
from repro.core.distributed import DistributedExecutor, shard_table
from repro.core.executor import execute
from repro.core.sparql import parse_sparql
from repro.core.table import Table


def test_shard_table_partitions():
    rows = np.array([[0, 5], [1, 6], [2, 7], [3, 8], [9, 1]], dtype=np.int32)
    t = Table.from_unsorted(rows)
    shards, ns = shard_table(t, 4, by=0)
    assert ns.sum() == 5
    for i in range(4):
        part = shards[i][: ns[i]]
        assert np.all(part[:, 0] % 4 == i)


def test_single_device_mesh(watdiv_small):
    """The distributed engine degenerates correctly on a 1-device mesh."""
    cat, d, _ = watdiv_small
    mesh = jax.make_mesh((1,), ("data",))
    q = parse_sparql(
        "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p }", d)
    plan = compile_bgp(q.root, cat)
    ex = DistributedExecutor(plan, cat, mesh)
    data, cols = ex.run()
    ref = execute(q, cat)
    m1 = collections.Counter(
        tuple(int(x) for x in r)
        for r in data[:, [cols.index(c) for c in ref.cols]])
    m2 = collections.Counter(map(tuple, ref.data.tolist()))
    assert m1 == m2


_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import collections
    import jax
    import numpy as np
    from repro.rdf.generator import WatDivConfig, generate_watdiv
    from repro.core.stats import build_catalog
    from repro.core.sparql import parse_sparql
    from repro.core.compiler import compile_bgp
    from repro.core.distributed import DistributedExecutor
    from repro.core.executor import execute

    assert len(jax.devices()) == 8
    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=0.1, seed=7))
    cat = build_catalog(tt, d)
    mesh = jax.make_mesh((8,), ("data",))

    queries = [
        "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p . ?p sorg:price ?x }",
        "SELECT * WHERE { ?u sorg:email ?e . ?u foaf:age ?a . ?u wsdbm:likes ?p }",
        "SELECT * WHERE { wsdbm:User3 wsdbm:follows ?v . ?v sorg:email ?e }",
        "SELECT * WHERE { ?r rev:reviewer ?u . ?u wsdbm:friendOf ?f . ?f wsdbm:likes ?p }",
        # modifier spine: FILTER + DISTINCT + ORDER BY + LIMIT runs on
        # device with the global tail gathered across the 8 shards
        "SELECT DISTINCT ?p ?x WHERE { ?p rev:hasReview ?r . ?r rev:rating ?x"
        " FILTER(?x > 5) } ORDER BY DESC(?x) ?p LIMIT 12",
    ]
    from repro.core.modifiers import peel_spine

    star_hlo = None
    for i, qtext in enumerate(queries):
        q = parse_sparql(qtext, d)
        core, spine = peel_spine(q)
        plan = compile_bgp(core, cat)
        ex = DistributedExecutor(plan, cat, mesh, spine=spine)
        data, cols = ex.run()
        ref = execute(q, cat)
        if spine.has_slice:                # sliced: exact rows must match
            assert np.array_equal(
                data[:, [cols.index(c) for c in ref.cols]], ref.data), \
                f"query {i} mismatch"
        else:
            m1 = collections.Counter(tuple(int(x) for x in r)
                                     for r in data[:, [cols.index(c) for c in ref.cols]])
            m2 = collections.Counter(map(tuple, ref.data.tolist()))
            assert m1 == m2, f"query {i} mismatch"
        if i == 1:
            star_hlo = ex.lower().compile().as_text()
    # star query must be shuffle-free (co-partitioned SS joins)
    assert star_hlo.count("all-to-all(") == 0, "star query should not shuffle"
    print("DIST_OK")
""")


@pytest.mark.slow
def test_distributed_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "DIST_OK" in res.stdout
