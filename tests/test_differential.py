"""Differential test harness: every execution backend (eager / jit /
distributed) over every build substrate (numpy / jax) and τ must agree
with the brute-force semantics oracle (``core/reference.py``) on random
graphs × random BGP/FILTER/OPTIONAL/UNION queries.

This systematically sweeps the backend × τ × catalog-build surface that
hand-picked queries cannot cover; it runs under ``_hypothesis_shim``
(deterministic per-test RNG) when real hypothesis is absent.
"""

from collections import Counter

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reference import execute_reference, mappings_to_multiset
from repro.core.sparql import parse_sparql
from repro.engine import Dataset

TAUS = (0.25, 1.0)


# ---------------------------------------------------------------------------
# Random graphs and queries
# ---------------------------------------------------------------------------

def random_triples(rng, n_ent, n_preds, n_triples):
    return [(f"e{rng.integers(0, n_ent)}", f"p{rng.integers(0, n_preds)}",
             f"e{rng.integers(0, n_ent)}") for _ in range(n_triples)]


def _random_pattern(rng, subj, obj, n_ent, n_preds):
    """One triple pattern; var/constant mix on s and o, bound predicate
    (random constants may reference terms absent from the graph — the
    statistics short-circuit path)."""
    s = subj if rng.random() < 0.8 else f"e{rng.integers(0, n_ent)}"
    o = obj if rng.random() < 0.8 else f"e{rng.integers(0, n_ent)}"
    p = f"p{rng.integers(0, n_preds)}"
    return f"{s} {p} {o}"


def random_query(rng, n_ent, n_preds):
    """A random SELECT * query: a chained BGP, optionally wrapped in
    FILTER / OPTIONAL / UNION (exercised by all backends; non-BGP roots
    route device backends through their fallback path)."""
    n_pat = int(rng.integers(1, 4))
    pats = [_random_pattern(rng, f"?v{i}", f"?v{i + 1}", n_ent, n_preds)
            for i in range(n_pat)]
    shape = rng.integers(0, 4)
    if shape == 0:                      # plain BGP
        body = " . ".join(pats)
    elif shape == 1:                    # FILTER over the chain vars
        body = " . ".join(pats) + f" FILTER(?v0 != ?v{n_pat})"
    elif shape == 2:                    # OPTIONAL tail
        opt = _random_pattern(rng, f"?v{n_pat}", "?w", n_ent, n_preds)
        body = " . ".join(pats) + f" OPTIONAL {{ {opt} }}"
    else:                               # UNION of two chains
        alt = _random_pattern(rng, "?v0", "?v1", n_ent, n_preds)
        body = f"{{ {' . '.join(pats)} }} UNION {{ {alt} }}"
    return f"SELECT * WHERE {{ {body} }}"


def assert_matches_oracle(res, qtext, dictionary, tt, ctx):
    query = parse_sparql(qtext, dictionary)
    ref = execute_reference(query, tt, dictionary.values)
    cols = sorted(res.cols)
    want = mappings_to_multiset(ref, cols)
    got = dict(res.as_multiset(cols))
    assert got == want, (ctx, qtext)


# ---------------------------------------------------------------------------
# The differential sweep
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.data())
def test_backends_match_reference(data):
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    n_ent = int(rng.integers(4, 16))
    n_preds = int(rng.integers(1, 4))
    triples = random_triples(rng, n_ent, n_preds, int(rng.integers(4, 50)))
    tau = data.draw(st.sampled_from(TAUS))

    ds_np = Dataset.from_triples(triples, threshold=tau)
    ds_jx = Dataset.from_triples(triples, threshold=tau, build_backend="jax")
    # numpy- and jax-built catalogs are interchangeable
    assert ds_np.catalog.extvp.sf == ds_jx.catalog.extvp.sf
    assert set(ds_np.catalog.extvp.tables) == set(ds_jx.catalog.extvp.tables)

    d = ds_np.dictionary
    tt = ds_np.catalog.tt
    mesh = jax.make_mesh((1,), ("data",))
    engines = [
        ("eager/numpy-built", ds_np.engine("eager")),
        ("jit/numpy-built", ds_np.engine("jit")),
        ("distributed/numpy-built", ds_np.engine("distributed", mesh=mesh)),
        ("eager/jax-built", ds_jx.engine("eager")),
    ]
    for qi in range(3):
        qtext = random_query(rng, n_ent, n_preds)
        for name, eng in engines:
            res = eng.query(qtext)
            assert_matches_oracle(res, qtext, d, tt,
                                  (seed, tau, name, qi))


def test_differential_fixed_seed_regressions():
    """A pinned mini-corpus (graph + the query shapes the sweep draws
    from) so failures here are reproducible without any shim/hypothesis
    draw order involved."""
    rng = np.random.default_rng(1234)
    triples = random_triples(rng, 8, 2, 30)
    queries = [
        "SELECT * WHERE { ?v0 p0 ?v1 . ?v1 p1 ?v2 }",
        "SELECT * WHERE { ?v0 p0 ?v1 FILTER(?v0 != ?v1) }",
        "SELECT * WHERE { ?v0 p0 ?v1 OPTIONAL { ?v1 p1 ?w } }",
        "SELECT * WHERE { { ?v0 p0 ?v1 . ?v1 p0 ?v2 } UNION { ?v0 p1 ?v1 } }",
        "SELECT * WHERE { e1 p0 ?v1 . ?v1 p1 ?v2 }",
        "SELECT * WHERE { ?v0 p0 e9999 }",     # absent constant: empty
    ]
    mesh = jax.make_mesh((1,), ("data",))
    for tau in TAUS:
        ds = Dataset.from_triples(triples, threshold=tau,
                                  build_backend="jax")
        d, tt = ds.dictionary, ds.catalog.tt
        for backend in ("eager", "jit", "distributed"):
            eng = ds.engine(backend, mesh=mesh)
            for qtext in queries:
                res = eng.query(qtext)
                assert_matches_oracle(res, qtext, d, tt, (tau, backend))
