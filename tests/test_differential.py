"""Differential test harness: every execution backend (eager / jit /
distributed) over every build substrate (numpy / jax) and τ must agree
with the brute-force semantics oracle (``core/reference.py``) on random
graphs × random BGP/FILTER/OPTIONAL/UNION queries × random solution-
modifier spines (DISTINCT / ORDER BY / LIMIT / OFFSET / FILTER).

Comparison rules:
* un-sliced queries: exact multiset equality against the oracle;
* sliced queries (LIMIT/OFFSET): the row *count* must equal the
  oracle's and every returned row must come from the oracle's pre-slice
  bag (with ties, SPARQL does not pin which equal-key rows survive a
  cut, and the backends may break ties differently than the oracle);
* jit and distributed must match eager ROW FOR ROW on every query — the
  device pipeline implements the same canonical join → left-join →
  union → project → distinct → order → slice sequence with the same
  stable tie-breaking;
* the device backends must answer the whole corpus — OPTIONAL, UNION,
  unbound predicates, and every modifier spine — with
  ``device_fallbacks == 0``;
* **order invariance**: every query also executes under the
  cardinality-estimate planner (``planner="estimate"``) on all three
  backends — any enumerated join order must be row-for-row equivalent to
  eager under the same planner, multiset-equivalent to the Algorithm-4
  greedy order, and must stay on the device path
  (``device_fallbacks == 0``).

This systematically sweeps the backend × τ × catalog-build surface that
hand-picked queries cannot cover; it runs under ``_hypothesis_shim``
(deterministic per-test RNG) when real hypothesis is absent.
"""

import re
from collections import Counter

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.reference import execute_reference, mappings_to_multiset
from repro.core.sparql import parse_sparql
from repro.engine import Dataset, RuntimeConfig

TAUS = (0.25, 1.0)
PLANNERS = ("greedy", "estimate")
_SLICE_RE = re.compile(r"\s(?:LIMIT|OFFSET)\s+\d+")


# ---------------------------------------------------------------------------
# Random graphs and queries
# ---------------------------------------------------------------------------

def random_triples(rng, n_ent, n_preds, n_triples):
    return [(f"e{rng.integers(0, n_ent)}", f"p{rng.integers(0, n_preds)}",
             f"e{rng.integers(0, n_ent)}") for _ in range(n_triples)]


def _random_pattern(rng, subj, obj, n_ent, n_preds, pred=None):
    """One triple pattern; var/constant mix on s and o, bound predicate
    unless ``pred`` names a variable (random constants may reference
    terms absent from the graph — the statistics short-circuit path)."""
    s = subj if rng.random() < 0.8 else f"e{rng.integers(0, n_ent)}"
    o = obj if rng.random() < 0.8 else f"e{rng.integers(0, n_ent)}"
    p = pred if pred is not None else f"p{rng.integers(0, n_preds)}"
    return f"{s} {p} {o}"


def random_query(rng, n_ent, n_preds):
    """A random query: a chained BGP, optionally wrapped in FILTER /
    OPTIONAL / UNION / unbound-predicate / nested shapes, under a random
    solution-modifier spine (DISTINCT / ORDER BY / LIMIT / OFFSET) drawn
    independently of the shape — every core class is exercised WITH
    modifiers.  All of these compile onto the device path of the
    jit/distributed backends (``device_fallbacks`` stays 0)."""
    n_pat = int(rng.integers(1, 4))
    pats = [_random_pattern(rng, f"?v{i}", f"?v{i + 1}", n_ent, n_preds)
            for i in range(n_pat)]
    shape = rng.integers(0, 8)
    if shape == 0:                      # plain BGP
        body = " . ".join(pats)
    elif shape == 1:                    # FILTER over the chain vars
        body = " . ".join(pats) + f" FILTER(?v0 != ?v{n_pat})"
    elif shape == 2:                    # OPTIONAL tail
        opt = _random_pattern(rng, f"?v{n_pat}", "?w", n_ent, n_preds)
        body = " . ".join(pats) + f" OPTIONAL {{ {opt} }}"
    elif shape == 3:                    # UNION of two chains
        alt = _random_pattern(rng, "?v0", "?v1", n_ent, n_preds)
        body = f"{{ {' . '.join(pats)} }} UNION {{ {alt} }}"
    elif shape == 4:                    # boolean FILTER combinators
        body = " . ".join(pats) + \
            f" FILTER(?v0 != ?v{n_pat} || !(?v0 = ?v1) && BOUND(?v0))"
    elif shape == 5:                    # unbound predicate in the chain
        k = int(rng.integers(0, n_pat))
        pats[k] = _random_pattern(rng, f"?v{k}", f"?v{k + 1}", n_ent,
                                  n_preds, pred="?q")
        body = " . ".join(pats)
    elif shape == 6:                    # full triples scan + OPTIONAL
        opt = _random_pattern(rng, "?v1", "?w", n_ent, n_preds)
        body = f"?v0 ?q ?v1 OPTIONAL {{ {opt} }}"
    else:                               # OPTIONAL nested under UNION
        opt = _random_pattern(rng, f"?v{n_pat}", "?w", n_ent, n_preds)
        alt = _random_pattern(rng, "?v0", "?v1", n_ent, n_preds)
        body = (f"{{ {' . '.join(pats)} OPTIONAL {{ {opt} }} }} "
                f"UNION {{ {alt} }}")

    distinct = "DISTINCT " if rng.random() < 0.4 else ""
    tail = ""
    if rng.random() < 0.5:              # ORDER BY over 1-2 chain vars
        n_keys = int(rng.integers(1, min(n_pat + 1, 2) + 1))
        keys = rng.choice(n_pat + 1, size=n_keys, replace=False)
        tail += " ORDER BY " + " ".join(
            f"DESC(?v{k})" if rng.random() < 0.5 else f"?v{k}" for k in keys)
    if rng.random() < 0.4:
        tail += f" LIMIT {int(rng.integers(0, 8))}"
        if rng.random() < 0.5:
            tail += f" OFFSET {int(rng.integers(0, 4))}"
    elif rng.random() < 0.15:
        tail += f" OFFSET {int(rng.integers(1, 4))}"
    return f"SELECT {distinct}* WHERE {{ {body} }}{tail}"


def assert_matches_oracle(res, qtext, dictionary, tt, ctx):
    query = parse_sparql(qtext, dictionary)
    ref = execute_reference(query, tt, dictionary.values)
    cols = sorted(res.cols)
    got = dict(res.as_multiset(cols))
    unsliced = _SLICE_RE.sub("", qtext)
    if unsliced != qtext:
        # LIMIT/OFFSET: with ties the engines may legally cut different
        # rows than the oracle — pin the count and the pre-slice bag
        assert sum(got.values()) == len(ref), (ctx, qtext)
        full = mappings_to_multiset(
            execute_reference(parse_sparql(unsliced, dictionary), tt,
                              dictionary.values), cols)
        for row, cnt in got.items():
            assert cnt <= full.get(row, 0), (ctx, qtext, row)
    else:
        want = mappings_to_multiset(ref, cols)
        assert got == want, (ctx, qtext)


def assert_rows_equal(a, b, ctx):
    """Exact row-for-row equality (order included) over shared cols."""
    assert set(a.cols) == set(b.cols), (ctx, a.cols, b.cols)
    cols = sorted(a.cols)
    da = a.data[:, [a.cols.index(c) for c in cols]]
    db = b.data[:, [b.cols.index(c) for c in cols]]
    assert np.array_equal(da, db), (ctx, da, db)


def assert_multiset_equal(a, b, qtext, ctx):
    """Cross-planner fence: different join orders may produce different
    row orders, but the bags must agree.  Sliced queries are exempt (with
    ties, SPARQL does not pin which equal-key rows survive the cut — the
    oracle check already pins their count and pre-slice bag)."""
    if _SLICE_RE.search(qtext):
        assert len(a) == len(b), (ctx, qtext)
        return
    cols = sorted(a.cols)
    assert dict(a.as_multiset(cols)) == dict(b.as_multiset(cols)), \
        (ctx, qtext)


# ---------------------------------------------------------------------------
# The differential sweep
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.data())
def test_backends_match_reference(data):
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    n_ent = int(rng.integers(4, 16))
    n_preds = int(rng.integers(1, 4))
    triples = random_triples(rng, n_ent, n_preds, int(rng.integers(4, 50)))
    tau = data.draw(st.sampled_from(TAUS))

    ds_np = Dataset.from_triples(triples, threshold=tau)
    ds_jx = Dataset.from_triples(triples, threshold=tau, build_backend="jax")
    # numpy- and jax-built catalogs are interchangeable
    assert ds_np.catalog.extvp.sf == ds_jx.catalog.extvp.sf
    assert set(ds_np.catalog.extvp.tables) == set(ds_jx.catalog.extvp.tables)

    d = ds_np.dictionary
    tt = ds_np.catalog.tt
    mesh = jax.make_mesh((1,), ("data",))
    est = RuntimeConfig(planner="estimate")
    engines = [
        ("eager/numpy-built", ds_np.engine("eager")),
        ("jit/numpy-built", ds_np.engine("jit")),
        ("distributed/numpy-built", ds_np.engine("distributed", mesh=mesh)),
        ("eager/jax-built", ds_jx.engine("eager")),
        # the order-invariance fence: the SAME catalog under the
        # cardinality-estimate planner, on every backend
        ("eager/est-planner", ds_np.engine("eager", runtime=est)),
        ("jit/est-planner", ds_np.engine("jit", runtime=est)),
        ("dist/est-planner",
         ds_np.engine("distributed", mesh=mesh, runtime=est)),
    ]
    device_engines = [n for n, _ in engines
                      if n.split("/")[0] in ("jit", "dist", "distributed")]
    for qi in range(3):
        qtext = random_query(rng, n_ent, n_preds)
        results = {}
        for name, eng in engines:
            res = eng.query(qtext)
            results[name] = res
            assert_matches_oracle(res, qtext, d, tt,
                                  (seed, tau, name, qi))
        # the device pipelines must reproduce eager row-for-row — under
        # each planner separately (the planners may order rows apart)
        assert_rows_equal(results["jit/numpy-built"],
                          results["eager/numpy-built"],
                          (seed, tau, "jit-vs-eager", qtext))
        assert_rows_equal(results["distributed/numpy-built"],
                          results["eager/numpy-built"],
                          (seed, tau, "dist-vs-eager", qtext))
        assert_rows_equal(results["jit/est-planner"],
                          results["eager/est-planner"],
                          (seed, tau, "jit-vs-eager/est", qtext))
        assert_rows_equal(results["dist/est-planner"],
                          results["eager/est-planner"],
                          (seed, tau, "dist-vs-eager/est", qtext))
        # and the enumerated order must be bag-equal to Algorithm 4
        assert_multiset_equal(results["eager/est-planner"],
                              results["eager/numpy-built"], qtext,
                              (seed, tau, "est-vs-greedy"))
    # every fuzzed query — OPTIONAL / UNION / unbound predicates and all
    # modifier spines included — compiled onto the device path, under
    # BOTH planners
    for name, eng in engines:
        if name in device_engines:
            assert eng.metrics.device_fallbacks == 0, (seed, tau, name)


# The pinned mini-corpus (shared with tests/test_analysis.py's verifier
# sweep): every query shape the randomized sweep draws from, over a
# reproducible graph.
FIXED_QUERIES = [
        "SELECT * WHERE { ?v0 p0 ?v1 . ?v1 p1 ?v2 }",
        "SELECT * WHERE { ?v0 p0 ?v1 FILTER(?v0 != ?v1) }",
        "SELECT * WHERE { ?v0 p0 ?v1 OPTIONAL { ?v1 p1 ?w } }",
        "SELECT * WHERE { { ?v0 p0 ?v1 . ?v1 p0 ?v2 } UNION { ?v0 p1 ?v1 } }",
        "SELECT * WHERE { e1 p0 ?v1 . ?v1 p1 ?v2 }",
        "SELECT * WHERE { ?v0 p0 e9999 }",     # absent constant: empty
        # solution-modifier spines over BGP cores (device-compiled)
        "SELECT DISTINCT ?v1 WHERE { ?v0 p0 ?v1 }",
        "SELECT * WHERE { ?v0 p0 ?v1 . ?v1 p1 ?v2 } ORDER BY ?v2 DESC(?v0)",
        "SELECT DISTINCT * WHERE { ?v0 p0 ?v1 FILTER(?v0 != ?v1) } "
        "ORDER BY ?v0 ?v1 LIMIT 5",
        "SELECT ?v1 WHERE { ?v0 p0 ?v1 } ORDER BY ?v1 LIMIT 3 OFFSET 2",
        "SELECT DISTINCT ?v1 WHERE { e1 p0 ?v1 } ORDER BY DESC(?v1) LIMIT 2",
        # modifier spines over non-BGP cores (device-compiled too)
        "SELECT DISTINCT ?v0 WHERE { { ?v0 p0 ?v1 } UNION { ?v0 p1 ?v1 } } "
        "ORDER BY ?v0 LIMIT 4",
        "SELECT * WHERE { ?v0 p0 ?v1 OPTIONAL { ?v1 p1 ?w } } "
        "ORDER BY ?w ?v0 LIMIT 5",
        "SELECT DISTINCT ?w WHERE { ?v0 p0 ?v1 OPTIONAL { ?v1 p1 ?w } }",
        "SELECT * WHERE { ?v0 p0 ?v1 "
        "OPTIONAL { ?v1 p1 ?w FILTER(?w != ?v0) } }",
        "SELECT * WHERE { ?v0 p0 ?v1 OPTIONAL { ?v1 p1 ?w } "
        "FILTER(BOUND(?w) || ?v0 != ?v1) }",
        # unbound predicates: full TT scans, joins through ?q
        "SELECT * WHERE { ?v0 ?q ?v1 }",
        "SELECT * WHERE { ?v0 ?q ?v0 }",
        "SELECT DISTINCT ?q WHERE { ?v0 ?q ?v1 } ORDER BY ?q",
        "SELECT * WHERE { ?v0 ?q ?v1 . ?v1 p0 ?v2 } "
        "ORDER BY ?v1 DESC(?v0) LIMIT 6",
        # nested shapes: OPTIONAL / unbound predicate under UNION
        "SELECT * WHERE { { ?v0 p0 ?v1 OPTIONAL { ?v1 ?q ?w } } "
        "UNION { ?v0 p1 ?v1 } } ORDER BY ?v1 LIMIT 7",
        "SELECT DISTINCT * WHERE { { ?v0 p0 ?v1 } UNION { ?v0 p1 ?v1 } } "
        "ORDER BY DESC(?v1) ?v0",
]


def fixed_corpus_triples():
    """The pinned graph the mini-corpus runs over."""
    rng = np.random.default_rng(1234)
    return random_triples(rng, 8, 2, 30)


def test_differential_fixed_seed_regressions():
    """A pinned mini-corpus (graph + the query shapes the sweep draws
    from) so failures here are reproducible without any shim/hypothesis
    draw order involved."""
    triples = fixed_corpus_triples()
    queries = FIXED_QUERIES
    mesh = jax.make_mesh((1,), ("data",))
    for tau in TAUS:
        ds = Dataset.from_triples(triples, threshold=tau,
                                  build_backend="jax")
        d, tt = ds.dictionary, ds.catalog.tt
        # one engine set per planner over the SAME dataset: the whole
        # corpus must hold under the Algorithm-4 greedy order AND any
        # enumerated cardinality-estimate order, on every backend
        runtimes = {"greedy": None,
                    "estimate": RuntimeConfig(planner="estimate")}
        for qtext in queries:
            per = {}
            for pname, cfg in runtimes.items():
                for backend in ("eager", "jit", "distributed"):
                    eng = ds.engine(backend, mesh=mesh, runtime=cfg)
                    res = eng.query(qtext)
                    per[(pname, backend)] = res
                    assert_matches_oracle(res, qtext, d, tt,
                                          (tau, pname, backend))
                    if backend != "eager":
                        assert eng.metrics.device_fallbacks == 0, \
                            (tau, pname, backend, qtext)
                assert_rows_equal(per[(pname, "jit")],
                                  per[(pname, "eager")],
                                  (tau, pname, "jit-vs-eager", qtext))
                assert_rows_equal(per[(pname, "distributed")],
                                  per[(pname, "eager")],
                                  (tau, pname, "dist-vs-eager", qtext))
            assert_multiset_equal(per[("estimate", "eager")],
                                  per[("greedy", "eager")], qtext,
                                  (tau, "est-vs-greedy"))
