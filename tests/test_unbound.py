"""Property tests for the UNBOUND sentinel.

``UNBOUND = -1`` is the engine-wide encoding of an unbound variable in
OPTIONAL / UNION solutions.  These tests pin its contract:

* it can never collide with a dictionary id (ids are dense in
  ``[0, n_terms)``) no matter which terms the graph contains;
* ``Result.to_terms()`` omits unbound slots instead of decoding them;
* DISTINCT treats UNBOUND as a first-class value per the W3C multiset
  semantics — an unbound solution is distinct from every bound one and
  duplicates of it collapse to a single row;
* ORDER BY sorts UNBOUND last under ASC and first under DESC (SQL
  NULLS LAST), identically on eager / jit / distributed and the
  brute-force reference;
* ``Result.as_multiset`` / ``Result.same_as`` canonicalize
  UNBOUND-filled columns against missing columns, so backends that drop
  an all-unbound variable and backends that materialize it compare
  equal.
"""

import jax
import numpy as np
import pytest

from repro.core.executor import Bindings
from repro.core.reference import execute_reference
from repro.core.sparql import parse_sparql
from repro.engine import Dataset
from repro.engine.result import Result
from repro.rdf.dictionary import PAD, UNBOUND, Dictionary

TRIPLES = [
    ("a1", "p0", "b1"), ("a2", "p0", "b2"), ("a3", "p0", "b3"),
    ("b1", "p1", "c1"), ("b1", "p1", "c2"),
]
OPT_Q = "SELECT * WHERE { ?s p0 ?o OPTIONAL { ?o p1 ?w } }"


def _engines(ds):
    mesh = jax.make_mesh((1,), ("data",))
    return [("eager", ds.engine("eager")),
            ("jit", ds.engine("jit")),
            ("distributed", ds.engine("distributed", mesh=mesh))]


# ---------------------------------------------------------------------------
# Sentinel vs dictionary ids
# ---------------------------------------------------------------------------

def test_unbound_never_collides_with_dictionary_ids():
    """Ids are dense non-negative ints; the sentinels live outside that
    range for any term set — including terms that *look* like the
    sentinels."""
    rng = np.random.default_rng(7)
    corpora = [
        ["a", "b", "c"],
        ["-1", "UNBOUND", str(UNBOUND), str(PAD), '"-1"'],
        [f"e{rng.integers(0, 50)}" for _ in range(200)],
        [f'"{v}"' for v in rng.normal(size=50)],
    ]
    for terms in corpora:
        d = Dictionary()
        ids = [d.add(t) for t in terms]
        assert all(i >= 0 for i in ids)
        assert UNBOUND not in ids and PAD not in ids
        assert sorted(set(ids)) == list(range(len(d)))
    # and the engine-visible sentinel really is the reserved value
    assert UNBOUND == -1 and UNBOUND < 0 <= PAD


def test_unbound_rows_flow_through_optional():
    ds = Dataset.from_triples(TRIPLES)
    for name, eng in _engines(ds):
        res = eng.query(OPT_Q)
        w = res.data[:, res.cols.index("?w")]
        assert len(res) == 4, name
        assert int((w == UNBOUND).sum()) == 2, name   # a2, a3 unmatched
        assert all(v >= 0 for v in w[w != UNBOUND]), name


# ---------------------------------------------------------------------------
# to_terms / decoding
# ---------------------------------------------------------------------------

def test_to_terms_omits_unbound_slots():
    ds = Dataset.from_triples(TRIPLES)
    rows = ds.engine("jit").query(OPT_Q).to_terms()
    assert len(rows) == 4
    for m in rows:
        assert "?s" in m and "?o" in m
        assert all(v != "UNBOUND" for v in m.values())
    unmatched = [m for m in rows if "?w" not in m]
    assert sorted(m["?s"] for m in unmatched) == ["a2", "a3"]


# ---------------------------------------------------------------------------
# DISTINCT (W3C multiset semantics)
# ---------------------------------------------------------------------------

def test_distinct_keeps_unbound_as_a_solution():
    """SELECT DISTINCT ?w: the two unmatched rows collapse into ONE
    unbound solution which is distinct from every bound ?w."""
    ds = Dataset.from_triples(TRIPLES)
    qtext = "SELECT DISTINCT ?w WHERE { ?s p0 ?o OPTIONAL { ?o p1 ?w } }"
    ref = execute_reference(parse_sparql(qtext, ds.dictionary),
                            ds.catalog.tt, ds.dictionary.values)
    assert sorted(m.get("?w", UNBOUND) for m in ref).count(UNBOUND) == 1
    for name, eng in _engines(ds):
        res = eng.query(qtext)
        w = sorted(res.data[:, res.cols.index("?w")].tolist())
        assert len(w) == 3, name                      # {UNBOUND, c1, c2}
        assert w.count(UNBOUND) == 1, name
        assert w == sorted(m.get("?w", UNBOUND) for m in ref), name


# ---------------------------------------------------------------------------
# ORDER BY (NULLS LAST)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("desc", [False, True])
def test_unbound_sort_position(desc):
    key = "DESC(?w)" if desc else "?w"
    qtext = f"SELECT * WHERE {{ ?s p0 ?o OPTIONAL {{ ?o p1 ?w }} }} " \
            f"ORDER BY {key}"
    ds = Dataset.from_triples(TRIPLES)
    ref = execute_reference(parse_sparql(qtext, ds.dictionary),
                            ds.catalog.tt, ds.dictionary.values)
    ref_w = [m.get("?w", UNBOUND) for m in ref]
    rows = []
    for name, eng in _engines(ds):
        res = eng.query(qtext)
        w = res.data[:, res.cols.index("?w")].tolist()
        bound_zone = w[2:] if desc else w[:2]         # 2 matched rows
        unbound_zone = w[:2] if desc else w[2:]
        assert all(v != UNBOUND for v in bound_zone), (name, w)
        assert all(v == UNBOUND for v in unbound_zone), (name, w)
        assert [v == UNBOUND for v in w] == \
            [v == UNBOUND for v in ref_w], (name, w, ref_w)
        rows.append((name, res.data[:, [res.cols.index(c)
                                        for c in sorted(res.cols)]]))
    for name, data in rows[1:]:                       # engines agree rowwise
        assert np.array_equal(data, rows[0][1]), name


# ---------------------------------------------------------------------------
# Result canonicalization: UNBOUND column vs missing column
# ---------------------------------------------------------------------------

def test_as_multiset_fills_missing_columns_with_unbound():
    r = Result(Bindings(("?a",), np.array([[3], [5]], dtype=np.int32)))
    bag = r.as_multiset(["?a", "?b"])
    assert bag == {(3, UNBOUND): 1, (5, UNBOUND): 1}


def test_same_as_unbound_vs_missing_column():
    dropped = Result(Bindings(("?a",), np.array([[3], [5]], dtype=np.int32)))
    filled = Result(Bindings(("?a", "?b"),
                             np.array([[3, UNBOUND], [5, UNBOUND]],
                                      dtype=np.int32)))
    bound = Result(Bindings(("?a", "?b"),
                            np.array([[3, 9], [5, UNBOUND]],
                                     dtype=np.int32)))
    # an all-UNBOUND column and an absent column encode the same mappings
    assert dropped.same_as(filled) and filled.same_as(dropped)
    # ... but actual bound values still distinguish results
    assert not dropped.same_as(bound) and not bound.same_as(filled)
    # and the relation is symmetric + reflexive on itself
    assert dropped.same_as(dropped)
