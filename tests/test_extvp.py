"""ExtVP schema construction vs the set-comprehension definitions of §5.2,
plus the paper's G1 worked example (Figs. 1, 8, 10)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.stats import build_catalog
from repro.core.table import Table
from repro.core.vp import KINDS, OS, SO, SS, build_extvp, build_vp
from repro.rdf.dictionary import Dictionary


def brute_extvp(vp, kind, p1, p2):
    """§5.2 definitions, literally."""
    t1, t2 = vp[p1].rows, vp[p2].rows
    if kind == SS:
        keys = set(t2[:, 0].tolist())
        keep = [r for r in t1.tolist() if r[0] in keys]
    elif kind == OS:
        keys = set(t2[:, 0].tolist())
        keep = [r for r in t1.tolist() if r[1] in keys]
    else:
        keys = set(t2[:, 1].tolist())
        keep = [r for r in t1.tolist() if r[0] in keys]
    return sorted(map(tuple, keep))


class TestG1:
    """Paper Fig. 10: the full ExtVP data model for G1."""

    def test_fig10_sf_values(self, g1):
        cat, d = g1
        f, l = d.id_of("follows"), d.id_of("likes")
        assert cat.sf(OS, f, l) == 0.25        # ExtVP^OS_follows|likes
        assert cat.sf(OS, f, f) == 0.5         # follows o ∈ follows s: B,C
        assert cat.sf(SS, f, l) == 0.5
        assert cat.sf(SO, f, f) == 0.75
        assert cat.sf(SO, f, l) == pytest.approx(0.0)   # likes objects are items
        assert cat.sf(OS, l, f) == pytest.approx(0.0)   # item never follows
        assert cat.sf(SS, l, f) == 1.0         # identity -> not materialized
        assert (SS, l, f) not in cat.extvp.tables

    def test_fig8_semijoin_content(self, g1):
        cat, d = g1
        f, l = d.id_of("follows"), d.id_of("likes")
        t = cat.table(OS, f, l)
        rows = [tuple(d.term_of(int(x)) for x in r) for r in t.rows]
        assert rows == [("B", "C")]   # only B->C has o that likes something

    def test_identity_and_empty_not_materialized(self, g1):
        cat, _ = g1
        for key, sf in cat.extvp.sf.items():
            if sf in (0.0, 1.0):
                assert key not in cat.extvp.tables
            else:
                assert key in cat.extvp.tables


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_extvp_matches_definitions(data):
    """Property: ExtVP == §5.2 set comprehension on random small graphs."""
    rng_seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(rng_seed)
    n_preds = data.draw(st.integers(1, 4))
    n_terms = data.draw(st.integers(2, 12))
    n_triples = data.draw(st.integers(0, 60))
    tt = np.stack([
        rng.integers(0, n_terms, n_triples),
        n_terms + rng.integers(0, n_preds, n_triples),
        rng.integers(0, n_terms, n_triples),
    ], axis=1).astype(np.int32)
    tt = np.unique(tt, axis=0)
    vp = build_vp(tt)
    ext = build_extvp(vp)
    for p1 in vp:
        for p2 in vp:
            for kind in KINDS:
                if kind == SS and p1 == p2:
                    continue
                expected = brute_extvp(vp, kind, p1, p2)
                sf = ext.sf[(kind, p1, p2)]
                assert sf == len(expected) / max(len(vp[p1]), 1)
                if 0 < sf < 1:
                    got = sorted(map(tuple, ext.tables[(kind, p1, p2)].rows.tolist()))
                    assert got == expected
                elif sf == 1.0:
                    assert sorted(map(tuple, vp[p1].rows.tolist())) == expected


def test_threshold_materialization():
    """§5.3: τ controls materialization but never statistics."""
    d = Dictionary()
    triples = [("a", "p", "b"), ("b", "p", "c"), ("c", "p", "d"), ("d", "p", "e"),
               ("b", "q", "x")]
    tt = d.encode_triples(triples)
    vp = build_vp(tt)
    full = build_extvp(vp, threshold=1.0)
    thr = build_extvp(vp, threshold=0.2)
    assert full.sf == thr.sf                       # stats identical
    assert set(thr.tables) <= set(full.tables)     # strictly fewer tables
    for key, t in thr.tables.items():
        assert thr.sf[key] <= 0.2


def test_n_tables_counts_sf_equal_tau():
    """§5.3 boundary: a table with SF exactly equal to τ IS materialized,
    and Table-2 accounting must see it — ``n_tables(0, τ)`` uses the
    same inclusive upper bound as the materialization predicate."""
    d = Dictionary()
    # p: a->b->c->d, q: subjects {a, b} => SF(SS, p, q) = 2/3,
    # SF(SS, q, p) = 1.0 (identity), SF(SO, p, p) = 2/3 ...
    triples = [("a", "p", "b"), ("b", "p", "c"), ("c", "p", "d"),
               ("a", "q", "x"), ("b", "q", "y")]
    tt = d.encode_triples(triples)
    vp = build_vp(tt)
    tau = 2 / 3
    build = build_extvp(vp, threshold=tau)
    key = ("SS", d.id_of("p"), d.id_of("q"))
    assert build.sf[key] == tau
    assert key in build.tables                    # SF == τ is materialized
    # ... and visible to the accounting at exactly the same bound
    assert build.n_tables(0.0, tau) == len(build.tables)
    assert build.n_tables(0.0, build.sf[key] - 1e-9) < build.n_tables(0.0, tau)
    # identity tables never count, matching materialization
    assert build.n_tables(0.0, 1.0) == \
        sum(1 for v in build.sf.values() if 0 < v < 1.0)


def test_n_tables_matches_materialization_across_taus(watdiv_small):
    """For every τ, n_tables(0, τ) equals the number of materialized
    tables of a τ-thresholded build (the alignment the strict upper
    bound used to break at SF == τ)."""
    cat, _, _ = watdiv_small
    sfs = sorted({v for v in cat.extvp.sf.values() if 0 < v < 1})
    for tau in [sfs[0], sfs[len(sfs) // 2], sfs[-1], 0.25]:
        thr = build_extvp(cat.vp, threshold=tau)
        assert thr.n_tables(0.0, tau) == len(thr.tables), tau


def test_vp_partitions_cover_tt(watdiv_small):
    cat, d, sch = watdiv_small
    assert sum(len(t) for t in cat.vp.values()) == len(cat.tt)
    # every VP table sorted by s
    for t in cat.vp.values():
        s = t.rows[:, 0]
        assert np.all(s[:-1] <= s[1:])


def test_storage_report_structure(watdiv_small):
    cat, _, _ = watdiv_small
    rep = cat.storage_report()
    assert rep["vp_tuples"] == rep["n_triples"]
    assert rep["extvp_tables"] > 0
    assert rep["extvp_empty"] > 0        # heterogeneous schema -> many empties
