"""Serving engine: plan cache, short-circuit accounting, backend parity."""

import collections

import numpy as np
import pytest

from repro.rdf.workloads import ST_QUERIES, basic_queries
from repro.serve.engine import SparqlServer, template_signature


def test_template_signature_normalizes_constants():
    a = template_signature(
        "SELECT * WHERE { ?v0 wsdbm:likes wsdbm:Product3 . ?v0 sorg:email ?e }")
    b = template_signature(
        "SELECT * WHERE { ?v0 wsdbm:likes wsdbm:Product77 . ?v0 sorg:email ?e }")
    assert a == b
    c = template_signature(
        "SELECT * WHERE { ?v0 wsdbm:follows wsdbm:User1 . ?v0 sorg:email ?e }")
    assert a != c


def test_serving_metrics_and_cache(watdiv_small):
    cat, d, sch = watdiv_small
    server = SparqlServer(cat)
    reqs = []
    for name, insts in basic_queries(sch, seed=3, n_instances=3).items():
        reqs.extend(insts)
    reqs.extend(ST_QUERIES.values())
    for q in reqs:
        server.query(q)
    m = server.metrics.summary()
    assert m["served"] == len(reqs)
    # 3 instantiations per template -> cache hits on repeats
    assert m["plan_hit_rate"] > 0.3
    assert m["empties"] >= 2           # ST-8-1/2 short-circuits
    assert m["p50_ms"] >= 0


def test_backend_parity_eager_vs_jit(watdiv_small):
    cat, d, _ = watdiv_small
    eager = SparqlServer(cat, backend="eager")
    jit = SparqlServer(cat, backend="jit")
    queries = [
        "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p }",
        "SELECT * WHERE { ?u sorg:email ?e . ?u foaf:age ?a }",
        "SELECT * WHERE { ?p sorg:price ?x . ?x wsdbm:follows ?y }",  # empty
    ]
    for q in queries:
        a = eager.query(q)
        b = jit.query(q)
        assert len(a) == len(b), q
        if len(a):
            key = sorted(a.cols)
            ma = collections.Counter(
                map(tuple, a.data[:, [a.cols.index(c) for c in key]].tolist()))
            mb = collections.Counter(
                map(tuple, b.data[:, [b.cols.index(c) for c in key]].tolist()))
            assert ma == mb, q


def test_jit_executor_reuse(watdiv_small):
    """Same template, different constants -> the compiled program is reused."""
    cat, d, sch = watdiv_small
    server = SparqlServer(cat, backend="jit")
    q1 = "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }"
    q2 = "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v . ?v sorg:email ?e }"
    server.query(q1)
    n_exec = len(server._exec_cache)
    server.query(q2)
    assert len(server._exec_cache) == n_exec  # reused slot, no new build
