"""Unified Dataset/Engine facade: backend registry, cross-backend result
parity (ST + Basic workloads), plan-cache/re-binding behavior (a repeated
templated query must neither re-parse nor re-compile), and Result views."""

import numpy as np
import pytest

from repro.core import jexec
from repro.engine import (
    Dataset, Engine, ExecutionBackend, Result, available_backends,
    create_backend, register_backend, template_signature,
)
from repro.engine.template import QueryTemplate, extract_constants
from repro.rdf.workloads import ST_QUERIES, basic_queries


@pytest.fixture(scope="module")
def ds(watdiv_small):
    cat, d, sch = watdiv_small
    return Dataset(catalog=cat, dictionary=d, schema=sch)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_builtin_backends_registered():
    names = available_backends()
    for name in ("eager", "jit", "distributed"):
        assert name in names


def test_unknown_backend_rejected(ds):
    with pytest.raises(ValueError, match="unknown backend"):
        ds.engine("no-such-engine").query("SELECT * WHERE { ?s ?p ?o }")


def test_custom_backend_pluggable(ds):
    """A registered backend is addressable by name with no call-site
    changes — the facade's extension point."""
    eager = create_backend("eager")

    class Probe(ExecutionBackend):
        name = "probe"
        prepared = 0

        def prepare(self, template, ctx):
            Probe.prepared += 1
            return eager.prepare(template, ctx)

    register_backend("probe", Probe)
    try:
        eng = ds.engine("probe")
        res = eng.query("SELECT * WHERE { ?u wsdbm:follows ?v }")
        assert len(res) > 0
        assert Probe.prepared == 1
    finally:
        from repro.engine import backends as _b
        _b._REGISTRY.pop("probe", None)
        ds._engines.pop(("probe", "extvp", id(None)), None)


# ---------------------------------------------------------------------------
# Cross-backend parity (multiset equality under SPARQL bag semantics)
# ---------------------------------------------------------------------------

def test_parity_st_workload(ds):
    eager = ds.engine("eager")
    jit = ds.engine("jit")
    for name, qtext in ST_QUERIES.items():
        a = eager.query(qtext)
        b = jit.query(qtext)
        assert a.same_as(b), name


def test_parity_basic_workload(ds):
    eager = ds.engine("eager")
    jit = ds.engine("jit")
    for name, instances in basic_queries(ds.schema, seed=11,
                                         n_instances=2).items():
        for qtext in instances:
            a = eager.query(qtext)
            b = jit.query(qtext)
            assert a.same_as(b), (name, qtext)


# ---------------------------------------------------------------------------
# Plan cache + constant re-binding
# ---------------------------------------------------------------------------

def test_templated_query_not_recompiled(ds):
    """Second instantiation of a template: plan-cache hit, and the XLA
    trace count (== compile count) must not move."""
    eng = Engine(ds, backend="jit", plan_cache_size=64)
    q1 = "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }"
    q2 = "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v . ?v sorg:email ?e }"
    assert template_signature(q1) == template_signature(q2)

    r1 = eng.query(q1)
    traces_after_first = jexec.trace_count()
    r2 = eng.query(q2)
    assert jexec.trace_count() == traces_after_first   # no recompilation
    assert eng.metrics.plan_hits == 1
    assert eng.metrics.plan_misses == 1
    assert len(eng.cache) == 1

    # and the re-bound results are the template instantiations' own answers
    eager = ds.engine("eager")
    assert r1.same_as(eager.query(q1))
    assert r2.same_as(eager.query(q2))


def test_rebinding_matches_fresh_compilation(ds):
    """Re-bound prepared queries == from-scratch execution for many
    instantiations of one template (eager backend: no re-planning)."""
    eng = Engine(ds, backend="eager")
    from repro.core.executor import execute
    from repro.core.sparql import parse_sparql
    rng = np.random.default_rng(5)
    for _ in range(6):
        uid = int(rng.integers(0, ds.schema.n_users))
        q = (f"SELECT * WHERE {{ wsdbm:User{uid} wsdbm:follows ?v . "
             f"?v wsdbm:likes ?p }}")
        got = eng.query(q)
        ref = execute(parse_sparql(q, ds.dictionary), ds.catalog)
        assert got.same_as(Result(ref, ds.dictionary))
    assert eng.metrics.plan_misses == 1
    assert eng.metrics.plan_hits == 5


def test_plan_cache_is_lru_bounded(ds):
    eng = Engine(ds, backend="eager", plan_cache_size=2)
    queries = [
        "SELECT * WHERE { ?u wsdbm:follows ?v }",
        "SELECT * WHERE { ?u wsdbm:likes ?p }",
        "SELECT * WHERE { ?u sorg:email ?e }",
    ]
    for q in queries:
        eng.query(q)
    assert len(eng.cache) == 2            # bounded, oldest evicted
    assert eng.cache.evictions == 1
    assert template_signature(queries[0]) not in eng.cache
    assert template_signature(queries[2]) in eng.cache


def test_missing_constant_short_circuits(ds):
    """An instantiation whose constant is absent from the dictionary is
    the statistics-only empty answer — served from the cached template."""
    eng = Engine(ds, backend="jit")
    q1 = "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }"
    q2 = "SELECT * WHERE { wsdbm:User999999 wsdbm:follows ?v . ?v sorg:email ?e }"
    assert len(eng.query(q1)) > 0
    traces = jexec.trace_count()
    res = eng.query(q2)
    assert len(res) == 0
    assert jexec.trace_count() == traces
    assert eng.metrics.empties == 1


def test_template_constant_extraction():
    q = ("SELECT * WHERE { ?v0 wsdbm:likes wsdbm:Product3 . "
         "?v0 sorg:email \"x@y\" . ?v0 foaf:age ?a . FILTER(?a > 40) }")
    assert extract_constants(q) == ["wsdbm:Product3", '"x@y"']
    sig = template_signature(q)
    assert "Product3" not in sig and "40" in sig   # schema + literals differ


def test_entity_with_trailing_letters_after_digit():
    """The slot regex must consume whole tokens: wsdbm:User3a once split
    into '<¤0>a' mid-token and broke parsing for valid queries."""
    ds = Dataset.from_triples([
        ("wsdbm:User3a", "wsdbm:follows", "wsdbm:User4"),
        ("wsdbm:User4", "wsdbm:follows", "wsdbm:User3a"),
    ])
    eng = ds.engine("eager")
    r = eng.query("SELECT * WHERE { wsdbm:User3a wsdbm:follows ?y }")
    assert r.to_terms() == [{"?y": "wsdbm:User4"}]
    # and the template re-binds across such names
    r2 = eng.query("SELECT * WHERE { wsdbm:User4 wsdbm:follows ?y }")
    assert r2.to_terms() == [{"?y": "wsdbm:User3a"}]
    assert eng.metrics.plan_hits == 1


def test_non_rebindable_exact_repeat_cached(ds):
    """IRI-form predicates make a template non-rebindable (the constant
    sits in predicate position); identical repeats must still reuse the
    prepared program instead of re-parsing and re-compiling."""
    eng = Engine(ds, backend="jit")
    q = "SELECT * WHERE { ?x <wsdbm:follows> ?y . ?y <sorg:email> ?e }"
    r1 = eng.query(q)
    traces = jexec.trace_count()
    r2 = eng.query(q)
    assert jexec.trace_count() == traces
    assert eng.metrics.plan_hits == 1 and len(eng.cache) == 1
    assert r1.same_as(r2) and len(r1) > 0


def test_short_circuit_metric(ds):
    eng = Engine(ds, backend="eager")
    eng.query("SELECT * WHERE { ?p sorg:price ?x . ?x wsdbm:follows ?y }")
    eng.query("SELECT * WHERE { wsdbm:User999999 wsdbm:follows ?v }")
    eng.query("SELECT * WHERE { ?u wsdbm:follows ?v }")
    assert eng.metrics.short_circuits == 2
    assert eng.metrics.empties == 2


def test_template_binding(ds):
    q1 = "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v }"
    t = QueryTemplate(q1, ds.dictionary)
    assert t.rebindable and t.n_slots == 1
    b = t.binding_for("SELECT * WHERE { wsdbm:User7 wsdbm:follows ?v }")
    assert not b.missing
    assert list(b.mapping.values()) == [ds.dictionary.id_of("wsdbm:User7")]


# ---------------------------------------------------------------------------
# Result type
# ---------------------------------------------------------------------------

def test_result_views():
    ds = Dataset.from_triples([
        ("A", "follows", "B"), ("B", "follows", "C"), ("A", "likes", "I1"),
    ])
    res = ds.query("SELECT * WHERE { ?x follows ?y }")
    assert isinstance(res, Result)
    assert set(res.cols) == {"?x", "?y"}
    arr = res.to_numpy()
    assert arr.shape == (2, 2) and arr.dtype == np.int32
    terms = res.to_terms()
    assert {frozenset(t.items()) for t in terms} == {
        frozenset({("?x", "A"), ("?y", "B")}),
        frozenset({("?x", "B"), ("?y", "C")}),
    }


def test_result_multiset_ignores_column_order():
    from repro.core.executor import Bindings
    a = Result(Bindings(("?x", "?y"), np.array([[1, 2], [3, 4]], np.int32)))
    b = Result(Bindings(("?y", "?x"), np.array([[4, 3], [2, 1]], np.int32)))
    assert a.same_as(b)
    c = Result(Bindings(("?x", "?y"), np.array([[1, 2]], np.int32)))
    assert not a.same_as(c)


def test_dataset_from_ntriples(tmp_path):
    from repro.rdf.ntriples import write_ntriples
    path = str(tmp_path / "g.nt")
    write_ntriples([("A", "follows", "B"), ("B", "follows", "C")], path)
    ds = Dataset.from_ntriples(path)
    assert ds.n_triples == 2
    assert len(ds.query("SELECT * WHERE { ?x follows ?y }")) == 2


# ---------------------------------------------------------------------------
# Serving layer rides the same facade
# ---------------------------------------------------------------------------

def test_server_delegates_to_engine(ds):
    from repro.serve import SparqlServer
    server = SparqlServer(ds.catalog, backend="jit")
    q1 = "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }"
    q2 = "SELECT * WHERE { wsdbm:User2 wsdbm:follows ?v . ?v sorg:email ?e }"
    server.query(q1)
    traces = jexec.trace_count()
    server.query(q2)
    assert jexec.trace_count() == traces
    m = server.metrics.summary()
    assert m["served"] == 2 and m["plan_hit_rate"] == 0.5
    assert isinstance(server.engine, Engine)
