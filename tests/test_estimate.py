"""Property tests for the cardinality estimator and the estimate planner
(:mod:`repro.core.estimate`, ``planner="estimate"``), plus the plan-cache
regression fence for the planner knob.

Covered properties:
* single-pattern BGPs estimate exactly (the scan estimate IS the table
  size the statistics recorded);
* adding correlated *functional* patterns (≤1 object per subject) never
  increases the estimate — monotone non-increasing growth of a star;
* SF=0 / missing-term short-circuits still produce ``Plan(empty=True)``
  under BOTH planners at both τ ∈ {0.25, 1.0};
* disconnected BGPs estimate the full cross-product — never a silent
  undercount;
* the enumerator returns a permutation of the selected steps and only
  cross-joins when the BGP is genuinely disconnected;
* the Engine's LRU keys on the planner knob: flipping ``planner``
  mid-session (or sharing a dataset between two engines with different
  planners) can never serve a plan ordered by the other planner.
"""

import numpy as np
import pytest

from repro.core import estimate as est
from repro.core.compiler import compile_bgp
from repro.core.modifiers import peel_spine
from repro.core.sparql import parse_sparql
from repro.engine import Dataset, RuntimeConfig

TAUS = (0.25, 1.0)


def _graph(seed, n_ent=24, n_preds=4, n_triples=140):
    rng = np.random.default_rng(seed)
    return [(f"e{rng.integers(0, n_ent)}", f"p{rng.integers(0, n_preds)}",
             f"e{rng.integers(0, n_ent)}") for _ in range(n_triples)]


def _bgp_plan(ds, body, planner="estimate", tau_layout="extvp"):
    query = parse_sparql(f"SELECT * WHERE {{ {body} }}", ds.dictionary)
    core, _ = peel_spine(query)
    return compile_bgp(core, ds.catalog, tau_layout, planner)


def _final_estimate(ds, body):
    plan = _bgp_plan(ds, body)
    rows = est.estimate_order(plan.steps, ds.catalog)
    assert rows is not None
    return rows[-1].rows


# ---------------------------------------------------------------------------
# Estimator properties
# ---------------------------------------------------------------------------

def test_single_pattern_estimate_is_exact():
    """One unbound pattern: the estimate is the recorded table size, which
    is the exact answer — for VP scans and the full TT scan alike."""
    for seed in (0, 1, 2):
        ds = Dataset.from_triples(_graph(seed), threshold=0.25)
        eng = ds.engine("eager", runtime=RuntimeConfig(planner="estimate"))
        for body in ("?s p0 ?o", "?s p2 ?o", "?s ?p ?o"):
            got = len(eng.query(f"SELECT * WHERE {{ {body} }}"))
            assert _final_estimate(ds, body) == pytest.approx(got), \
                (seed, body)


def test_estimate_monotone_under_functional_correlation():
    """Growing a subject star with *functional* predicates (every entity
    has at most one object per predicate, like an email or gender edge)
    can only filter rows, and the estimate must reflect that: each added
    correlated pattern keeps the estimate non-increasing."""
    for seed in (5, 6):
        rng = np.random.default_rng(seed)
        triples = []
        for e in range(30):
            # p0: fan-out edges; p1..p3: functional attributes (some
            # entities lack them, so the patterns genuinely filter)
            for _ in range(int(rng.integers(1, 4))):
                triples.append((f"e{e}", "p0", f"e{rng.integers(0, 30)}"))
            for p in ("p1", "p2", "p3"):
                if rng.random() < 0.8:
                    triples.append((f"e{e}", p, f"v{rng.integers(0, 6)}"))
        ds = Dataset.from_triples(triples, threshold=1.0)
        star = ["?x p0 ?y0", "?x p1 ?y1", "?x p2 ?y2", "?x p3 ?y3"]
        prev = float("inf")
        for k in range(1, len(star) + 1):
            cur = _final_estimate(ds, " . ".join(star[:k]))
            assert cur <= prev + 1e-9, (seed, k, cur, prev)
            prev = cur


def test_short_circuits_survive_estimate_planner():
    """SF=0 correlations and missing-dictionary terms must still compile
    to ``Plan(empty=True)`` at both τ values under BOTH planners — the
    statistics-only empty answer is planner-invariant."""
    # p0 edges only ever leave e-entities into v-entities; p1 only
    # connects w-entities, so the OS correlation p0|p1 is empty (SF=0)
    triples = [(f"e{i}", "p0", f"v{i}") for i in range(8)] + \
              [(f"w{i}", "p1", f"w{i + 1}") for i in range(8)]
    for tau in TAUS:
        ds = Dataset.from_triples(triples, threshold=tau)
        for planner in ("greedy", "estimate"):
            p = _bgp_plan(ds, "?a p0 ?b . ?b p1 ?c", planner=planner)
            assert p.empty, (tau, planner, "SF=0")
            p = _bgp_plan(ds, "?a p0 ?b . ?b p1 e9999", planner=planner)
            assert p.empty, (tau, planner, "missing term")
            eng = ds.engine("eager",
                            runtime=RuntimeConfig(planner=planner))
            res = eng.query("SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }")
            assert len(res) == 0
            assert eng.metrics.short_circuits >= 1, (tau, planner)


def test_bound_term_estimate_is_skew_aware():
    """A constant on a heavily skewed column (one dominant value, like
    ``rdf:type``) must estimate near the dominant frequency — the second
    moment m2/|VP| — not the uniform size/distinct average."""
    # p0: 60 of 64 objects are the same value; p1: 3 near-uniform values
    triples = [(f"e{i}", "p0", "big" if i < 60 else f"t{i}")
               for i in range(64)]
    triples += [(f"e{i}", "p1", f"g{i % 3}") for i in range(60)]
    ds = Dataset.from_triples(triples, threshold=1.0)
    skewed = _bgp_plan(ds, "?s p0 big")
    uniform = _bgp_plan(ds, "?s p1 g0")
    e_skew = est.scan_estimate(skewed.steps[0], ds.catalog)[0]
    e_unif = est.scan_estimate(uniform.steps[0], ds.catalog)[0]
    assert e_skew == pytest.approx((60 ** 2 + 4) / 64)   # m2/|VP| ≈ 56.3
    assert e_unif == pytest.approx(60 / 3)               # uniform stays
    # uniform fallback when the skew stats are absent (older store)
    ds.catalog.m2_s = ds.catalog.m2_o = None
    assert est.scan_estimate(skewed.steps[0], ds.catalog)[0] == \
        pytest.approx(64 / 5)                            # size/distinct_o


def test_disconnected_bgp_estimates_cross_product():
    """No shared variables => the estimate is the exact cross-product of
    the table sizes, not a silent undercount."""
    for seed in (7, 8):
        ds = Dataset.from_triples(_graph(seed), threshold=1.0)
        eng = ds.engine("eager", runtime=RuntimeConfig(planner="estimate"))
        body = "?a p0 ?b . ?c p1 ?d"
        got = len(eng.query(f"SELECT * WHERE {{ {body} }}"))
        n0 = ds.catalog.vp_size(int(ds.dictionary.term_to_id["p0"]))
        n1 = ds.catalog.vp_size(int(ds.dictionary.term_to_id["p1"]))
        assert got == n0 * n1
        assert _final_estimate(ds, body) == pytest.approx(got), seed


def test_enumerator_permutes_and_stays_connected():
    """The enumerator reorders the SAME selected steps (table selection
    is planner-invariant) and every non-first step joins a variable that
    is already bound, unless the BGP is disconnected."""
    ds = Dataset.from_triples(_graph(11), threshold=0.25)
    bodies = [
        "?a p0 ?b . ?b p1 ?c . ?c p2 ?d",
        "?a p0 ?b . ?a p1 ?c . ?b p2 ?d . ?c p3 ?e",
        "e1 p0 ?b . ?b p1 ?c . ?c p2 ?d . ?d p3 ?e . ?e p0 ?f",
    ]
    from repro.core.algebra import tp_vars
    for body in bodies:
        greedy = _bgp_plan(ds, body, planner="greedy")
        estimate = _bgp_plan(ds, body, planner="estimate")
        assert estimate.planner == "estimate"
        key = lambda s: (str(s.tp), s.kind, s.p2, s.sf, s.size, s.uses_tt)
        assert sorted(map(key, greedy.steps)) == \
            sorted(map(key, estimate.steps)), body
        bound = set()
        for i, step in enumerate(estimate.steps):
            if i:
                assert bound & set(tp_vars(step.tp)), (body, i)
            bound |= set(tp_vars(step.tp))


def test_estimate_falls_back_without_distinct_stats():
    """A catalog stripped of distinct counts (the version-1 store shape)
    must compile under planner="estimate" via the greedy path — and the
    plan records the planner that actually ran."""
    ds = Dataset.from_triples(_graph(13), threshold=0.25)
    ds.catalog.distinct_s = ds.catalog.distinct_o = None
    assert not est.supports(ds.catalog)
    plan = _bgp_plan(ds, "?a p0 ?b . ?b p1 ?c", planner="estimate")
    assert not plan.empty and plan.planner == "greedy"
    eng = ds.engine("eager", runtime=RuntimeConfig(planner="estimate"))
    res = eng.query("SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }")
    ref = ds.engine("eager").query("SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }")
    assert dict(res.as_multiset(sorted(res.cols))) == \
        dict(ref.as_multiset(sorted(ref.cols)))


# ---------------------------------------------------------------------------
# Plan-cache planner keying
# ---------------------------------------------------------------------------

def test_plan_cache_keys_on_planner_knob():
    """Flipping ``config.planner`` mid-session must compile a fresh plan
    (distinct cache entry), never serve the other planner's order; and
    two engines sharing one dataset but holding different planner configs
    stay fully independent."""
    ds = Dataset.from_triples(_graph(17), threshold=0.25)
    q = "SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }"

    cfg = RuntimeConfig(planner="greedy")
    eng = ds.engine("eager", runtime=cfg)
    p_greedy = eng.prepare(q)
    assert p_greedy.plan.planner == "greedy"
    cfg.planner = "estimate"
    p_est = eng.prepare(q)
    assert p_est is not p_greedy
    assert p_est.plan.planner == "estimate"
    assert len(eng.cache) == 2           # both orders cached side by side
    cfg.planner = "greedy"
    assert eng.prepare(q) is p_greedy    # flip back: cached, not rebuilt
    assert eng.runtime_report()["planner"] == "greedy"

    # two engines over the SAME dataset with different planner configs
    cfg_g, cfg_e = RuntimeConfig(planner="greedy"), \
        RuntimeConfig(planner="estimate")
    eng_g = ds.engine("eager", runtime=cfg_g)
    eng_e = ds.engine("eager", runtime=cfg_e)
    assert eng_g is not eng_e
    rg, re_ = eng_g.query(q), eng_e.query(q)
    assert eng_g.prepare(q).plan.planner == "greedy"
    assert eng_e.prepare(q).plan.planner == "estimate"
    assert eng_e.runtime_report()["planner"] == "estimate"
    assert dict(rg.as_multiset(sorted(rg.cols))) == \
        dict(re_.as_multiset(sorted(re_.cols)))


def test_runtime_config_rejects_unknown_planner():
    with pytest.raises(ValueError):
        RuntimeConfig(planner="cost-based-v2")
    ds = Dataset.from_triples(_graph(19), threshold=1.0)
    with pytest.raises(ValueError):
        _bgp_plan(ds, "?a p0 ?b", planner="nope")
