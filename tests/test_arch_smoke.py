"""Per-architecture smoke tests: REDUCED same-family configs, one
forward/train step + a few decode steps on CPU, asserting output shapes
and finiteness.  Full configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get, get_reduced
from repro.models.api import Model, active_params, total_params
from repro.models.config import SHAPES, ShapeCell, shape_applicable

SMOKE_CELL = ShapeCell("smoke", 32, 2, "train")


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_train_step_smoke(name):
    cfg = get_reduced(name)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.dummy_batch(SMOKE_CELL, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(m.loss)(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_decode_smoke(name):
    cfg = get_reduced(name)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    caches = m.init_caches(params if cfg.enc_dec else None, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for pos in range(4):
        logits, caches = m.decode(params, caches, tok, jnp.int32(pos))
        assert logits.shape == (2, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits, np.float32)).all()
        tok = jnp.argmax(logits[:, :, :64], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_shapes(name):
    cfg = get_reduced(name)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    cell = ShapeCell("p", 32, 2, "prefill")
    batch = m.dummy_batch(cell, jax.random.PRNGKey(1))
    logits = m.prefill(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_decode_matches_forward_qwen():
    """Teacher-forced forward == step-by-step decode (the KV-cache path)."""
    cfg = get_reduced("qwen1.5-0.5b", dtype="float32", param_dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab)
    from repro.models.transformer import forward
    full = forward(params, tokens, cfg)
    caches = m.init_caches(None, 2, S)
    outs = []
    for t in range(S):
        lg, caches = m.decode(params, caches, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_hybrid():
    """Same equivalence through mamba+attn+moe blocks (jamba family)."""
    cfg = get_reduced("jamba-1.5-large-398b", dtype="float32",
                      param_dtype="float32")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S = 8
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, S), 0, cfg.vocab)
    from repro.models.transformer import forward
    full = forward(params, tokens, cfg)
    caches = m.init_caches(None, 2, S)
    outs = []
    for t in range(S):
        lg, caches = m.decode(params, caches, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(step),
                               rtol=5e-3, atol=5e-3)


def test_local_global_masks_differ():
    """gemma3 family: sliding-window layers must mask differently."""
    from repro.models.attention import _mask
    m_global = _mask(8, 8, 0, None)
    m_local = _mask(8, 8, 0, 4)
    assert bool(m_global[7, 0]) and not bool(m_local[7, 0])
    assert bool(m_local[7, 4])


def test_param_counts_match_nominal():
    nominal = {
        "qwen1.5-0.5b": (0.5e9, 0.15), "gemma3-12b": (12e9, 0.15),
        "mistral-nemo-12b": (12e9, 0.15), "granite-3-2b": (2.6e9, 0.15),
        "granite-moe-1b-a400m": (1.3e9, 0.15), "deepseek-moe-16b": (16.4e9, 0.15),
        "jamba-1.5-large-398b": (398e9, 0.10), "whisper-small": (0.24e9, 0.25),
        "llava-next-34b": (34e9, 0.15), "mamba2-370m": (0.37e9, 0.25),
    }
    for name, (want, tol) in nominal.items():
        got = total_params(get(name))
        assert abs(got - want) / want < tol, (name, got, want)


def test_moe_active_params():
    cfg = get("granite-moe-1b-a400m")
    act = active_params(cfg)
    assert 0.3e9 < act < 0.55e9          # "a400m"
    cfg2 = get("deepseek-moe-16b")
    assert 2.0e9 < active_params(cfg2) < 3.5e9   # ~2.8B active


def test_long500k_applicability():
    long_cell = SHAPES[3]
    assert long_cell.name == "long_500k"
    runnable = {n for n in ARCHS
                if shape_applicable(get(n), long_cell)[0]}
    assert runnable == {"jamba-1.5-large-398b", "mamba2-370m"}
