"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (hypothesis) and
tile-boundary edge cases, in interpret mode on CPU."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.mergejoin import TILE_A, TILE_B, join_probe_pallas
from repro.kernels.semijoin import semijoin_membership_pallas


def _sorted_build(rng, n, lo=0, hi=5000):
    return np.sort(rng.integers(lo, hi, n).astype(np.int32))


class TestSemijoinKernel:
    @pytest.mark.parametrize("n_a,n_b", [
        (TILE_A, TILE_B),             # single tile
        (2 * TILE_A, 3 * TILE_B),     # multi-tile grid
        (TILE_A, 4 * TILE_B),         # build sweep
    ])
    def test_tile_shapes(self, n_a, n_b):
        rng = np.random.default_rng(0)
        a = rng.integers(0, 3000, n_a).astype(np.int32)
        b = _sorted_build(rng, n_b, 0, 3000)
        got = semijoin_membership_pallas(jnp.asarray(a), jnp.asarray(b),
                                         interpret=True)
        want = ref.semijoin_membership_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_disjoint_ranges_all_zero(self):
        a = np.arange(TILE_A, dtype=np.int32)
        b = np.arange(10_000, 10_000 + TILE_B, dtype=np.int32)
        got = semijoin_membership_pallas(jnp.asarray(a), jnp.asarray(b),
                                         interpret=True)
        assert int(np.asarray(got).sum()) == 0

    def test_all_members(self):
        b = np.arange(TILE_B, dtype=np.int32)
        a = np.tile(b, TILE_A // TILE_B)
        got = semijoin_membership_pallas(jnp.asarray(a), jnp.asarray(np.sort(b)),
                                         interpret=True)
        assert int(np.asarray(got).sum()) == TILE_A


class TestJoinProbeKernel:
    @pytest.mark.parametrize("n_a,n_b", [
        (TILE_A, TILE_B),
        (2 * TILE_A, 2 * TILE_B),
    ])
    def test_lo_cnt(self, n_a, n_b):
        rng = np.random.default_rng(1)
        a = rng.integers(0, 800, n_a).astype(np.int32)    # many duplicates
        b = _sorted_build(rng, n_b, 0, 800)
        lo, cnt = join_probe_pallas(jnp.asarray(a), jnp.asarray(b),
                                    interpret=True)
        wlo, wcnt = ref.join_probe_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(wlo))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(wcnt))

    def test_duplicates_across_build_tiles(self):
        """A key whose run of duplicates spans a build-tile boundary."""
        b = np.full(2 * TILE_B, 7, dtype=np.int32)
        b[:4] = 3
        b = np.sort(b)
        a = np.full(TILE_A, 7, dtype=np.int32)
        lo, cnt = join_probe_pallas(jnp.asarray(a), jnp.asarray(b),
                                    interpret=True)
        assert int(np.asarray(lo)[0]) == 4
        assert int(np.asarray(cnt)[0]) == 2 * TILE_B - 4


class TestOpsWrappers:
    """Ragged sizes + sentinel padding through the public API."""

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(0, 3000), st.integers(0, 1500))
    def test_semijoin_mask_ragged(self, seed, n_a, n_b):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 2000, n_a).astype(np.int32)
        b = _sorted_build(rng, n_b, 0, 2000)
        got = ops.semijoin_mask(jnp.asarray(a), jnp.asarray(b),
                                force_pallas=True)
        want = ref.semijoin_membership_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=20, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 2500), st.integers(1, 1200))
    def test_join_probe_ragged(self, seed, n_a, n_b):
        rng = np.random.default_rng(seed)
        a = rng.integers(0, 500, n_a).astype(np.int32)
        b = _sorted_build(rng, n_b, 0, 500)
        lo, cnt = ops.join_probe(jnp.asarray(a), jnp.asarray(b),
                                 force_pallas=True)
        wlo, wcnt = ref.join_probe_ref(jnp.asarray(a), jnp.asarray(b))
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(wlo))
        np.testing.assert_array_equal(np.asarray(cnt), np.asarray(wcnt))

    def test_jnp_path_matches_pallas_path(self):
        rng = np.random.default_rng(3)
        a = jnp.asarray(rng.integers(0, 999, 700).astype(np.int32))
        b = jnp.asarray(_sorted_build(rng, 350, 0, 999))
        np.testing.assert_array_equal(
            np.asarray(ops.semijoin_mask(a, b)),
            np.asarray(ops.semijoin_mask(a, b, force_pallas=True)))
        l1, c1 = ops.join_probe(a, b)
        l2, c2 = ops.join_probe(a, b, force_pallas=True)
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))


def test_extvp_build_with_kernel_matches_numpy(watdiv_small):
    """The kernel path reproduces the numpy ExtVP semi-join masks."""
    cat, d, sch = watdiv_small
    f = sch.pred["wsdbm:friendOf"]
    l = sch.pred["wsdbm:likes"]
    t1, t2 = cat.vp[f], cat.vp[l]
    mask = ops.semijoin_mask(jnp.asarray(t1.o), jnp.asarray(t2.unique_s),
                             force_pallas=True)
    want = cat.table("OS", f, l).rows
    got = t1.rows[np.asarray(mask).astype(bool)]
    np.testing.assert_array_equal(np.sort(got, axis=0), np.sort(want, axis=0))


class TestBucketCountKernel:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(0, 2**31 - 1), st.integers(1, 3000),
           st.sampled_from([2, 8, 16, 64, 256]))
    def test_histogram_ragged(self, seed, n, nb):
        rng = np.random.default_rng(seed)
        keys = rng.integers(0, 100000, n).astype(np.int32)
        valid = rng.random(n) < 0.8
        got = ops.bucket_count(jnp.asarray(keys), jnp.asarray(valid), nb,
                               force_pallas=True)
        want = ref.bucket_count_ref(jnp.asarray(keys), jnp.asarray(valid), nb)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_numpy_bincount(self):
        rng = np.random.default_rng(0)
        keys = rng.integers(0, 999, 2048).astype(np.int32)
        valid = np.ones(2048, bool)
        got = ops.bucket_count(jnp.asarray(keys), jnp.asarray(valid), 16,
                               force_pallas=True)
        want = np.bincount(keys % 16, minlength=16)
        np.testing.assert_array_equal(np.asarray(got), want)
