"""Micro-batched execution: batched-vs-sequential parity on every
backend, one-compile-per-(template, bucket-shape), and the serving-layer
submit/flush queue."""

import jax
import numpy as np
import pytest

from repro.core import jexec
from repro.engine import Dataset, Engine
from repro.serve import SparqlServer


@pytest.fixture(scope="module")
def ds(watdiv_small):
    cat, d, sch = watdiv_small
    return Dataset(catalog=cat, dictionary=d, schema=sch)


def _template_instances(n, start=1):
    return [f"SELECT * WHERE {{ wsdbm:User{u} wsdbm:follows ?v . "
            f"?v sorg:email ?e }}" for u in range(start, start + n)]


MIXED_BATCH = (
    _template_instances(4)
    + ["SELECT * WHERE { wsdbm:User999999 wsdbm:follows ?v . "
       "?v sorg:email ?e }",                                  # missing const
       "SELECT * WHERE { ?p sorg:price ?x . ?x wsdbm:follows ?y }",  # empty plan
       "SELECT * WHERE { ?u wsdbm:likes ?p }"]                # second template
)


# ---------------------------------------------------------------------------
# Batched vs sequential parity (the eager loop is the oracle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["eager", "jit"])
def test_batch_parity(ds, backend):
    eng = Engine(ds, backend=backend)
    oracle = Engine(ds, backend="eager")
    batched = eng.query_batch(MIXED_BATCH)
    for q, got in zip(MIXED_BATCH, batched):
        assert got.same_as(oracle.query(q)), q


def test_batch_parity_distributed(ds):
    mesh = jax.make_mesh((1,), ("data",))
    eng = Engine(ds, backend="distributed", mesh=mesh)
    oracle = Engine(ds, backend="eager")
    batched = eng.query_batch(MIXED_BATCH)
    for q, got in zip(MIXED_BATCH, batched):
        assert got.same_as(oracle.query(q)), q


def _optional_union_instances(n, start=1):
    opt = [f"SELECT * WHERE {{ wsdbm:User{u} wsdbm:follows ?v "
           f"OPTIONAL {{ ?v sorg:email ?e }} }}"
           for u in range(start, start + n)]
    uni = [f"SELECT * WHERE {{ {{ wsdbm:User{u} wsdbm:follows ?v }} "
           f"UNION {{ wsdbm:User{u} wsdbm:likes ?v }} }} ORDER BY ?v"
           for u in range(start, start + n)]
    return opt + uni


@pytest.mark.parametrize("backend", ["jit", "auto"])
def test_batch_parity_optional_union(ds, backend):
    """OPTIONAL and UNION templates — now device-compiled — keep exact
    batched-vs-sequential parity, including under ``backend="auto"``
    where the router may land them on either substrate.  No instance may
    fall back to the host path."""
    eng = Engine(ds, backend=backend)
    oracle = Engine(ds, backend="eager")
    queries = _optional_union_instances(6)
    batched = eng.query_batch(queries)
    for q, got in zip(queries, batched):
        assert got.same_as(oracle.query(q)), q
    sequential = [eng.query(q) for q in queries]
    for q, got, want in zip(queries, batched, sequential):
        assert got.same_as(want), q
    assert eng.metrics.device_fallbacks == 0


def test_prepared_run_batch_matches_run_loop(ds):
    """PreparedQuery.run_batch == [run(b) for b] on the device backend,
    including missing-constant short-circuits inside the batch."""
    eng = Engine(ds, backend="jit")
    queries = _template_instances(3) + [
        "SELECT * WHERE { wsdbm:User999999 wsdbm:follows ?v . "
        "?v sorg:email ?e }"]
    prepared = eng.prepare(queries[0])
    bindings = [prepared.template.binding_for(q) for q in queries]
    assert bindings[-1].missing
    batched = prepared.run_batch(bindings)
    for b, got in zip(bindings, batched):
        assert got.same_as(prepared.run(b))
    assert len(batched[-1]) == 0


# ---------------------------------------------------------------------------
# Compilation accounting: one program per (template, bucket shape)
# ---------------------------------------------------------------------------

def test_one_compile_per_template_and_bucket_shape(ds):
    eng = Engine(ds, backend="jit")
    t0 = jexec.trace_count()
    eng.query_batch(_template_instances(5))      # bucket shape 8
    assert jexec.trace_count() == t0 + 1
    eng.query_batch(_template_instances(7, start=2))    # same bucket, reuse
    assert jexec.trace_count() == t0 + 1
    eng.query_batch(_template_instances(3, start=11))   # bucket shape 4
    assert jexec.trace_count() == t0 + 2
    m = eng.metrics.summary()
    assert m["batches"] == 3
    assert m["batched_requests"] == 15
    # 15 requests over 8+8+4 = 20 slots
    assert m["batch_occupancy"] == pytest.approx(15 / 20)
    assert m["padding_waste"] == pytest.approx(5 / 20)


def test_missing_constants_do_not_shrink_batch_shape(ds):
    """A missing-constant request inside a bucket is answered on the
    host; the device batch is padded back to the bucket shape, so the
    live-count never becomes a fresh compile shape."""
    eng = Engine(ds, backend="jit")
    full = _template_instances(4)
    eng.query_batch(full)                        # compile bucket shape 4
    t0 = jexec.trace_count()
    with_missing = _template_instances(3) + [
        "SELECT * WHERE { wsdbm:User999999 wsdbm:follows ?v . "
        "?v sorg:email ?e }"]
    res = eng.query_batch(with_missing)          # 3 live of bucket 4
    assert jexec.trace_count() == t0             # reused the B=4 program
    assert len(res[-1]) == 0


def test_batch32_single_launch_matches_sequential_eager(ds):
    """Acceptance probe: a 32-request same-template batch is ONE XLA
    program launch, multiset-equal to 32 sequential eager runs.  (Users
    25/32 are skipped: their follows-degree overflows the statistics-
    seeded scan capacity, which legitimately retries with doubled caps —
    a second program — in batched and sequential mode alike.)"""
    users = [u for u in range(0, 40) if u not in (25, 32)][:32]
    queries = [f"SELECT * WHERE {{ wsdbm:User{u} wsdbm:follows ?v . "
               f"?v sorg:email ?e }}" for u in users]
    eng = Engine(ds, backend="jit")
    t0 = jexec.trace_count()
    batched = eng.query_batch(queries)
    assert jexec.trace_count() == t0 + 1         # one program, 32 requests
    oracle = Engine(ds, backend="eager")
    for q, got in zip(queries, batched):
        assert got.same_as(oracle.query(q)), q
    m = eng.metrics.summary()
    assert m["batches"] == 1 and m["batch_occupancy"] == 1.0


def test_bucket_shape_menu():
    ds2 = Dataset.from_triples([("A", "follows", "B")])
    eng = ds2.engine("eager")
    assert [eng.bucket_shape(n) for n in (1, 2, 3, 5, 8, 9, 32, 100)] == \
        [1, 2, 4, 8, 8, 16, 32, 32]
    with pytest.raises(ValueError, match="batch_shapes"):
        Engine(ds2, backend="eager", batch_shapes=[0, 2])


def test_query_batch_preserves_submission_order(ds):
    """Interleaved templates come back in input order, not group order."""
    a = _template_instances(3)
    b = ["SELECT * WHERE { ?u wsdbm:likes ?p }"]
    interleaved = [a[0], b[0], a[1], a[2]]
    eng = Engine(ds, backend="jit")
    got = eng.query_batch(interleaved)
    oracle = Engine(ds, backend="eager")
    for q, r in zip(interleaved, got):
        assert r.same_as(oracle.query(q)), q


# ---------------------------------------------------------------------------
# Serving layer: submit / flush / demux
# ---------------------------------------------------------------------------

def test_server_submit_flush_demux(ds):
    # flush_ms=inf: this test drives the queue manually, so the latency
    # bound must not fire between slow (compiling) submits
    srv = SparqlServer(ds.catalog, backend="jit", max_batch=8,
                       flush_ms=1e9)
    queries = _template_instances(5)
    tickets = [srv.submit(q) for q in queries]
    assert srv.batcher.pending() == 5
    assert not tickets[0].done()
    served = srv.flush()
    assert served == 5 and srv.batcher.pending() == 0
    oracle = SparqlServer(ds.catalog, backend="eager")
    for q, t in zip(queries, tickets):
        assert t.done() and t.result().same_as(oracle.query(q))
    m = srv.metrics.summary()
    assert m["batches"] == 1 and m["batched_requests"] == 5
    assert len(srv.metrics.queue_ms) == 5


def test_server_full_bucket_auto_flushes(ds):
    srv = SparqlServer(ds.catalog, backend="jit", max_batch=4,
                       flush_ms=1e9)
    tickets = [srv.submit(q) for q in _template_instances(4)]
    assert all(t.done() for t in tickets)        # size bound hit
    assert srv.batcher.pending() == 0


def test_ticket_result_forces_own_group(ds):
    srv = SparqlServer(ds.catalog, backend="eager", max_batch=32,
                       flush_ms=1e9)
    t1 = srv.submit(_template_instances(1)[0])
    t2 = srv.submit("SELECT * WHERE { ?u wsdbm:likes ?p }")
    assert len(t2.result()) > 0                  # drains only t2's bucket
    assert not t1.done() and srv.batcher.pending() == 1
    assert len(t1.result()) >= 0
    assert srv.batcher.pending() == 0


def test_server_query_batch_routes_through_batcher(ds):
    srv = SparqlServer(ds.catalog, backend="jit")
    res = srv.query_batch(MIXED_BATCH)
    oracle = SparqlServer(ds.catalog, backend="eager")
    for q, r in zip(MIXED_BATCH, res):
        assert r.same_as(oracle.query(q)), q
    assert srv.metrics.summary()["batches"] >= 2


def test_latency_flush_on_submit(ds, monkeypatch):
    srv = SparqlServer(ds.catalog, backend="eager", max_batch=32,
                       flush_ms=0.0)
    t1 = srv.submit(_template_instances(1)[0])
    # flush_ms=0: the next submit sees the deadline expired and drains all
    t2 = srv.submit(_template_instances(1, start=2)[0])
    assert t1.done()


def test_full_bucket_does_not_starve_other_signatures(ds):
    """A size-triggered flush of a hot template must not skip the
    latency check for other templates' queued requests."""
    srv = SparqlServer(ds.catalog, backend="eager", max_batch=2,
                       flush_ms=0.0)
    lone = srv.submit("SELECT * WHERE { ?u wsdbm:likes ?p }")
    srv.submit(_template_instances(1)[0])
    # this submit fills the hot bucket (size flush) AND must still honor
    # the expired deadline of the lone other-template request
    srv.submit(_template_instances(1, start=2)[0])
    assert lone.done()


def test_failed_batch_resolves_tickets_with_error(ds):
    srv = SparqlServer(ds.catalog, backend="eager", max_batch=32,
                       flush_ms=1e9)
    t1 = srv.submit(_template_instances(1)[0])
    t2 = srv.submit(_template_instances(1, start=2)[0])

    def boom(qtexts):
        raise RuntimeError("capacity overflow")
    srv.engine.query_batch = boom
    with pytest.raises(RuntimeError, match="capacity overflow"):
        srv.flush()
    assert t1.done() and t2.done()
    with pytest.raises(RuntimeError, match="capacity overflow"):
        t1.result()


# ---------------------------------------------------------------------------
# Fail-fast construction (bugfix): distributed backend without a mesh
# ---------------------------------------------------------------------------

def test_distributed_without_mesh_fails_at_construction(ds):
    with pytest.raises(ValueError, match="mesh"):
        SparqlServer(ds.catalog, backend="distributed")
    with pytest.raises(ValueError, match="mesh"):
        Engine(ds, backend="distributed")
