"""§Perf optimization paths must be exact (not approximate) rewrites:
blocked MoE dispatch, flash-chunked attention, dp-only decode knob."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.api import Model
from repro.models.config import ShapeCell


class TestBlockedDispatch:
    def test_matches_global_when_capacity_ample(self):
        cfg = get_reduced("granite-moe-1b-a400m", dtype="float32",
                          param_dtype="float32")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, cfg.d_model)) * 0.3
        y_global = moe_mod.moe_layer(p, x, cfg)
        cfg_b = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_blocks=4,
                                         capacity_factor=8.0))
        y_blocked = moe_mod.moe_layer(p, x, cfg_b)
        np.testing.assert_allclose(np.asarray(y_global),
                                   np.asarray(y_blocked), atol=1e-5)

    def test_blocked_trains(self):
        cfg = get_reduced("deepseek-moe-16b")
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dispatch_blocks=2))
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = m.dummy_batch(ShapeCell("t", 32, 4, "train"),
                              jax.random.PRNGKey(1))
        loss, grads = jax.value_and_grad(m.loss)(params, batch)
        assert np.isfinite(float(loss))


class TestFlashAttention:
    @pytest.mark.parametrize("local", [False, True])
    @pytest.mark.parametrize("chunk,seq", [(8, 32), (16, 64), (8, 64)])
    def test_matches_dense(self, local, chunk, seq):
        cfg = get_reduced("gemma3-12b", dtype="float32",
                          param_dtype="float32", sliding_window=8)
        p = attn.init_attention(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model)) * 0.3
        dense = attn.attention(p, x, cfg, local=local)
        flash = attn.attention(p, x,
                               dataclasses.replace(cfg, flash_chunk=chunk),
                               local=local)
        np.testing.assert_allclose(np.asarray(dense), np.asarray(flash),
                                   rtol=2e-4, atol=2e-5)

    def test_full_model_loss_unchanged(self):
        cfg = get_reduced("qwen1.5-0.5b", dtype="float32",
                          param_dtype="float32")
        m1 = Model(cfg)
        m2 = Model(dataclasses.replace(cfg, flash_chunk=8))
        params = m1.init(jax.random.PRNGKey(0))
        batch = m1.dummy_batch(ShapeCell("t", 32, 2, "train"),
                               jax.random.PRNGKey(1))
        l1 = float(m1.loss(params, batch))
        l2 = float(m2.loss(params, batch))
        assert abs(l1 - l2) < 1e-4, (l1, l2)

    def test_gradients_flow(self):
        cfg = get_reduced("mistral-nemo-12b", flash_chunk=8)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        batch = m.dummy_batch(ShapeCell("t", 32, 2, "train"),
                              jax.random.PRNGKey(1))
        loss, grads = jax.value_and_grad(m.loss)(params, batch)
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        assert np.isfinite(float(gnorm)) and float(gnorm) > 0


def test_ssm_chunk_sizes_equivalent():
    """ssm_chunk is a pure performance knob (§Perf): outputs identical."""
    from repro.models import ssm as ssm_mod
    base = get_reduced("mamba2-370m", dtype="float32", param_dtype="float32",
                       ssm_chunk=4)
    p = ssm_mod.init_ssm(jax.random.PRNGKey(0), base, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, base.d_model)) * 0.3
    y4 = ssm_mod.ssd_forward(p, x, base)
    y8 = ssm_mod.ssd_forward(p, x, dataclasses.replace(base, ssm_chunk=8))
    y16 = ssm_mod.ssd_forward(p, x, dataclasses.replace(base, ssm_chunk=16))
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), atol=1e-4)
