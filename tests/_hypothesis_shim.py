"""Minimal stand-in for the ``hypothesis`` API surface this suite uses.

The tier-1 suite must collect (and the property tests should still
exercise randomized inputs) on a clean environment without the real
``hypothesis`` package.  ``conftest.py`` installs this module under the
``hypothesis`` / ``hypothesis.strategies`` names ONLY when the real
package is missing.

Covered surface: ``given``, ``settings(max_examples=, deadline=)``,
``strategies.integers/sampled_from/data/composite``.  Draws come from a
deterministic per-test ``numpy`` RNG, so failures are reproducible; there
is no shrinking.
"""

from __future__ import annotations

import hashlib
import sys
import types
from typing import Any, Callable, List

import numpy as np

_DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A thunk from RNG to value."""

    def __init__(self, fn: Callable[[np.random.Generator], Any]):
        self._fn = fn

    def sample(self, rng: np.random.Generator) -> Any:
        return self._fn(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)))


def sampled_from(elements) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(
        lambda rng: elements[int(rng.integers(0, len(elements)))])


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: bool(rng.integers(0, 2)))


def floats(min_value: float, max_value: float) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: float(rng.uniform(min_value, max_value)))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: np.random.Generator) -> List[Any]:
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return SearchStrategy(draw)


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.sample(rng) for s in strategies))


def just(value) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


class _DataObject:
    """`st.data()` draw handle."""

    def __init__(self, rng: np.random.Generator):
        self._rng = rng

    def draw(self, strategy: SearchStrategy, label: str = "") -> Any:
        return strategy.sample(self._rng)


def data() -> SearchStrategy:
    return SearchStrategy(lambda rng: _DataObject(rng))


def composite(fn: Callable) -> Callable[..., SearchStrategy]:
    def build(*args, **kwargs) -> SearchStrategy:
        def draw_value(rng: np.random.Generator):
            handle = _DataObject(rng)
            return fn(handle.draw, *args, **kwargs)
        return SearchStrategy(draw_value)
    return build


def settings(*args, max_examples: int = _DEFAULT_MAX_EXAMPLES,
             deadline=None, **kwargs):
    def apply(fn):
        fn._shim_max_examples = max_examples
        return fn
    return apply


def assume(condition: bool) -> None:
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def given(*strategies: SearchStrategy):
    """Run the test body for N deterministic examples.

    The wrapper hides the drawn parameters from pytest's fixture
    resolution (varargs are not fixture names), so given-tests compose
    with plain fixtures exactly like under real hypothesis as long as the
    drawn arguments come last — the only pattern this suite uses.
    """

    def decorate(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed0 = int.from_bytes(
                hashlib.sha256(fn.__qualname__.encode()).digest()[:4], "big")
            for i in range(n):
                rng = np.random.default_rng((seed0 + i) % 2**32)
                drawn = [s.sample(rng) for s in strategies]
                try:
                    fn(*args, *drawn, **kwargs)
                except _Unsatisfied:
                    continue
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__dict__.update(getattr(fn, "__dict__", {}))
        wrapper.hypothesis = types.SimpleNamespace(inner_test=fn)
        return wrapper

    return decorate


def install() -> None:
    """Register this module as ``hypothesis`` if the real one is absent."""
    try:
        import hypothesis  # noqa: F401
        return
    except ImportError:
        pass
    shim = types.ModuleType("hypothesis")
    shim.given = given
    shim.settings = settings
    shim.assume = assume
    shim.HealthCheck = types.SimpleNamespace(all=lambda: [])
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "sampled_from", "booleans", "floats", "lists",
                 "tuples", "just", "data", "composite", "SearchStrategy"):
        setattr(strategies, name, globals()[name])
    shim.strategies = strategies
    shim.__is_shim__ = True
    sys.modules["hypothesis"] = shim
    sys.modules["hypothesis.strategies"] = strategies
