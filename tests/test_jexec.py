"""Static-shape jitted executor vs the eager engine, incl. overflow-retry."""

import collections

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.algebra import BGP, Query, TriplePattern
from repro.core.compiler import compile_bgp
from repro.core.executor import execute
from repro.core.jexec import PlanExecutor
from repro.core.sparql import parse_sparql
from repro.core.stats import build_catalog


def compare(qtext, cat, d):
    q = parse_sparql(qtext, d)
    plan = compile_bgp(q.root, cat)
    ex = PlanExecutor(plan, cat)
    data, cols = ex.run()
    ref = execute(q, cat)
    m1 = collections.Counter(
        tuple(int(x) for x in r)
        for r in data[:, [cols.index(c) for c in ref.cols]])
    m2 = collections.Counter(map(tuple, ref.data.tolist()))
    assert m1 == m2, qtext
    return data, cols


def test_q1_device(g1):
    cat, d = g1
    data, cols = compare(
        "SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
        "?y follows ?z . ?z likes ?w }", cat, d)
    assert len(data) == 1


@pytest.mark.parametrize("qtext", [
    "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p }",
    "SELECT * WHERE { ?u sorg:email ?e . ?u foaf:age ?a }",
    "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p . ?p sorg:price ?x }",
    "SELECT * WHERE { wsdbm:User1 wsdbm:follows ?v . ?v sorg:email ?e }",
    "SELECT * WHERE { ?r rev:reviewer ?u . ?u wsdbm:friendOf ?f }",
    "SELECT * WHERE { ?p rev:hasReview ?r . ?r rev:rating ?x . ?p sorg:price ?y }",
])
def test_watdiv_queries(watdiv_small, qtext):
    cat, d, _ = watdiv_small
    compare(qtext, cat, d)


def test_overflow_retry(watdiv_small):
    """Force tiny capacities; the executor must retry and still be exact."""
    cat, d, _ = watdiv_small
    q = parse_sparql(
        "SELECT * WHERE { ?u wsdbm:follows ?v . ?v wsdbm:likes ?p }", d)
    plan = compile_bgp(q.root, cat)
    ex = PlanExecutor(plan, cat)
    ex.caps = [16 for _ in ex.caps]            # deliberately too small
    data, cols = ex.run()
    ref = execute(q, cat)
    assert len(data) == len(ref)


@settings(max_examples=25, deadline=None)
@given(st.data())
def test_device_join_random(data_strategy):
    """device path == eager path on random 2-pattern BGPs."""
    rng = np.random.default_rng(data_strategy.draw(st.integers(0, 2**31 - 1)))
    n_terms = data_strategy.draw(st.integers(2, 8))
    n_triples = data_strategy.draw(st.integers(1, 40))
    tt = np.stack([
        rng.integers(0, n_terms, n_triples),
        np.full(n_triples, n_terms + rng.integers(0, 2)),
        rng.integers(0, n_terms, n_triples),
    ], axis=1).astype(np.int32)
    tt = np.unique(tt, axis=0)
    cat = build_catalog(tt)
    preds = sorted(cat.vp.keys())
    pat = [TriplePattern("?a", preds[0], "?b"),
           TriplePattern("?b", preds[-1], "?c")]
    q = Query(root=BGP(pat), select=None, distinct=False)
    plan = compile_bgp(q.root, cat)
    ref = execute(q, cat)
    if plan.empty:
        assert len(ref) == 0
        return
    ex = PlanExecutor(plan, cat)
    got, cols = ex.run()
    m1 = collections.Counter(
        tuple(int(x) for x in r)
        for r in got[:, [cols.index(c) for c in ref.cols]])
    m2 = collections.Counter(map(tuple, ref.data.tolist()))
    assert m1 == m2
