"""Optimizer, checkpoint/restore (incl. elastic), compression, data
determinism, elastic policy and straggler mitigation."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models.api import Model
from repro.models.config import ShapeCell
from repro.train import checkpoint
from repro.train.compression import compress_decompress, init_error_state
from repro.train.data import DataConfig, make_batch
from repro.train.elastic import ClusterView, ElasticPolicy, StragglerDetector
from repro.train.optimizer import OptConfig, adamw_update, init_opt_state
from repro.train.train_state import make_train_step

CELL = ShapeCell("t", 32, 4, "train")


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen1.5-0.5b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def test_loss_decreases(setup):
    cfg, model, params = setup
    opt = OptConfig(lr=3e-3, warmup_steps=2, total_steps=100)
    step_fn = jax.jit(make_train_step(model, opt))
    opt_state = init_opt_state(params)
    dc = DataConfig(seed=1, vocab=64)   # low-entropy synthetic stream
    losses = []
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in
                 make_batch(dc, cfg, CELL, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] * 0.75, (losses[0], losses[-1])


def test_grad_accumulation_equivalence(setup):
    cfg, model, params = setup
    opt = OptConfig(lr=1e-3, clip_norm=1e9)   # no clipping: exact equality
    s1 = make_train_step(model, opt, accum_steps=1)
    s2 = make_train_step(model, opt, accum_steps=2)
    dc = DataConfig(seed=2, vocab=cfg.vocab)
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, cfg, CELL, 0).items()}
    o1 = init_opt_state(params)
    o2 = init_opt_state(params)
    p1, _, m1 = jax.jit(s1)(params, o1, batch)
    p2, _, m2 = jax.jit(s2)(params, o2, batch)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-2, atol=2e-4)


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params = setup
    opt_state = init_opt_state(params)
    tree = {"params": params, "opt": opt_state}
    fut = checkpoint.save(str(tmp_path), 7, tree, extra={"note": "x"},
                          async_write=True)
    fut.result()
    assert checkpoint.latest_step(str(tmp_path)) == 7
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
    restored = checkpoint.restore(str(tmp_path), 7, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path, setup):
    """A .tmp directory (simulated crash) is never reported as a step."""
    cfg, model, params = setup
    checkpoint.save(str(tmp_path), 3, {"p": params}, async_write=False)
    os.makedirs(os.path.join(str(tmp_path), "step_00000009.tmp"))
    assert checkpoint.latest_step(str(tmp_path)) == 3


def test_resume_determinism(tmp_path, setup):
    """save at step k, keep training vs restore + train: identical."""
    cfg, model, params = setup
    opt = OptConfig(lr=1e-3)
    step_fn = jax.jit(make_train_step(model, opt))
    dc = DataConfig(seed=3, vocab=cfg.vocab)
    o = init_opt_state(params)
    p = params
    for step in range(3):
        batch = {k: jnp.asarray(v) for k, v in make_batch(dc, cfg, CELL, step).items()}
        p, o, _ = step_fn(p, o, batch)
        if step == 1:
            checkpoint.save(str(tmp_path), 1, {"params": p, "opt": o},
                            async_write=False)
    like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                        {"params": p, "opt": o})
    restored = checkpoint.restore(str(tmp_path), 1, like)
    p2, o2 = restored["params"], restored["opt"]
    batch = {k: jnp.asarray(v) for k, v in make_batch(dc, cfg, CELL, 2).items()}
    p2, o2, _ = step_fn(p2, o2, batch)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_compression_error_feedback():
    # 1 + 2^-12 is invisible to bf16 (7 mantissa bits, spacing 2^-7 at 1.0);
    # error feedback must recover it over a full 32-step feedback period.
    g = {"w": jnp.full((128,), 1.0 + 2 ** -12, jnp.float32)}
    err = init_error_state(g)
    total_applied = jnp.zeros((128,))
    n = 64  # two full periods
    for _ in range(n):
        cg, err = compress_decompress(g, err)
        total_applied = total_applied + cg["w"]
    np.testing.assert_allclose(np.asarray(total_applied) / n,
                               np.asarray(g["w"]), rtol=1e-4)
    # without feedback the bias never closes
    naive = g["w"].astype(jnp.bfloat16).astype(jnp.float32)
    assert abs(float(naive[0]) - float(g["w"][0])) > 1e-4


def test_data_determinism():
    cfg = get_reduced("qwen1.5-0.5b")
    dc = DataConfig(seed=5, vocab=cfg.vocab)
    b1 = make_batch(dc, cfg, CELL, 11, shard=2, n_shards=4)
    b2 = make_batch(dc, cfg, CELL, 11, shard=2, n_shards=4)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(dc, cfg, CELL, 12, shard=2, n_shards=4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


class TestElastic:
    def test_failure_detection(self):
        cv = ClusterView(timeout_s=10)
        cv.heartbeat("h0", now=0.0)
        cv.heartbeat("h1", now=0.0)
        cv.heartbeat("h0", now=8.0)
        assert cv.alive(now=12.0) == ["h0"]
        assert cv.dead(now=12.0) == ["h1"]

    def test_remesh_plan_shrinks(self):
        pol = ElasticPolicy(devices_per_host=4, model_axis=16, global_batch=256)
        full = pol.plan(n_hosts=128)          # 512 devices
        assert full.shape == (32, 16)
        degraded = pol.plan(n_hosts=100)      # 400 devices
        assert degraded.shape[1] == 16
        assert degraded.n_devices <= 400
        assert 256 % degraded.shape[0] == 0

    def test_remesh_tiny_cluster(self):
        pol = ElasticPolicy(devices_per_host=4, model_axis=16, global_batch=256)
        tiny = pol.plan(n_hosts=1)
        assert tiny.n_devices <= 4

    def test_straggler_ejection(self):
        det = StragglerDetector(straggler_factor=1.5, patience=2)
        timings = {f"h{i}": 1.0 for i in range(8)}
        assert det.observe(timings) == []
        slow = dict(timings, h3=5.0)
        assert det.observe(slow) == []        # strike 1
        assert det.observe(slow) == ["h3"]    # strike 2 -> eject

    def test_straggler_recovers(self):
        det = StragglerDetector(straggler_factor=1.5, patience=2, ewma=1.0)
        slow = {f"h{i}": 1.0 for i in range(8)}
        slow["h3"] = 5.0
        det.observe(slow)
        ok = {f"h{i}": 1.0 for i in range(8)}
        assert det.observe(ok) == []          # strike reset
