"""Property tests for the pair-batched device ExtVP build (§5 load job):
numpy/jax/distributed backends must be byte-identical, per-pair device
masks must equal the ``_semijoin_mask`` numpy ground truth (including
empty, identity and disjoint-range short-circuit cases), and
``Dataset.append_triples`` must be equivalent to a from-scratch build."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import extvp_build as eb
from repro.core.stats import build_catalog
from repro.core.vp import (
    ExtVPBuild, KINDS, OS, SO, SS, _ranges_disjoint, _semijoin_mask,
    build_extvp, build_vp,
)
from repro.engine import Dataset
from repro.kernels import ops


def random_tt(rng, n_preds, n_terms, n_triples):
    tt = np.stack([
        rng.integers(0, n_terms, n_triples),
        n_terms + rng.integers(0, n_preds, n_triples),
        rng.integers(0, n_terms, n_triples),
    ], axis=1).astype(np.int32)
    return np.unique(tt, axis=0)


def assert_builds_equal(a: ExtVPBuild, b: ExtVPBuild,
                        check_semijoins: bool = True) -> None:
    assert a.sf == b.sf
    assert a.sizes == b.sizes
    assert set(a.tables) == set(b.tables)
    for k in a.tables:
        assert np.array_equal(a.tables[k].rows, b.tables[k].rows), k
    if check_semijoins:
        assert a.n_semijoins == b.n_semijoins


# ---------------------------------------------------------------------------
# Full-build parity: numpy vs jax vs distributed
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.data())
def test_jax_build_matches_numpy(data):
    """Random graphs × τ: the pair-batched build is byte-identical."""
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    tt = random_tt(rng, data.draw(st.integers(1, 5)),
                   data.draw(st.integers(2, 24)),
                   data.draw(st.integers(0, 120)))
    tau = data.draw(st.sampled_from([0.25, 0.5, 1.0]))
    vp = build_vp(tt)
    base = build_extvp(vp, threshold=tau)
    dev = build_extvp(vp, threshold=tau, backend="jax",
                      pair_batch=data.draw(st.sampled_from([8, 32, 512])))
    assert_builds_equal(base, dev)


def test_distributed_build_single_device(watdiv_small):
    """The shard_map pair grid degenerates correctly on a 1-device mesh."""
    cat, d, _ = watdiv_small
    mesh = jax.make_mesh((1,), ("data",))
    base = build_extvp(cat.vp, threshold=0.25)
    dist = build_extvp(cat.vp, threshold=0.25, backend="distributed",
                       mesh=mesh, pair_batch=64)
    assert_builds_equal(base, dist)


def test_watdiv_smoke_byte_identity(watdiv_small):
    """Acceptance: jax build is byte-identical on the WatDiv smoke graph,
    end to end through build_catalog."""
    cat, d, _ = watdiv_small
    dev = build_catalog(cat.tt, d, threshold=1.0, build_backend="jax")
    assert_builds_equal(cat.extvp, dev.extvp)
    assert dev.extvp.backend == "jax"


def test_build_backend_validation():
    with pytest.raises(ValueError, match="build backend"):
        build_extvp({}, backend="spark")


# ---------------------------------------------------------------------------
# Per-pair ground truth (empty / identity / disjoint short-circuit)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def crafted_vp():
    """Hand-built VP exercising every SF regime.

    Predicates (ids 1000+): p0 subjects {0,1,2}; p1 subjects {0,1,2}
    (SS identity for p0); p2 subjects {100} (range-disjoint from p0's);
    p3 subjects {1,9} (range-overlapping but empty SS vs p4);
    p4 subjects {0,2} (strict reduction of p0)."""
    triples = np.array([
        [0, 1000, 10], [1, 1000, 11], [2, 1000, 12],
        [0, 1001, 5], [1, 1001, 6], [2, 1001, 7],
        [100, 1002, 200],
        [1, 1003, 1], [9, 1003, 4],
        [0, 1004, 8], [2, 1004, 9],
    ], dtype=np.int32)
    return build_vp(triples)


def test_per_pair_masks_match_ground_truth(crafted_vp):
    """Every (kind, p1, p2) — pruned or not — gets the exact numpy mask
    from the device batch, for both the bitmap and kernel paths."""
    vp = crafted_vp
    packed = eb.pack_vp(vp)
    pairs = list(eb.all_pair_keys(sorted(vp)))
    pcol = jnp.asarray([eb.probe_col(k[0]) for k in pairs], jnp.int32)
    pidx = jnp.asarray([packed.slot[k[1]] for k in pairs], jnp.int32)
    bcol = jnp.asarray([eb.build_col(k[0]) for k in pairs], jnp.int32)
    bidx = jnp.asarray([packed.slot[k[2]] for k in pairs], jnp.int32)
    runs = [eb.batch_pair_masks_bitmap(jnp.asarray(packed.keys),
                                       jnp.asarray(packed.present),
                                       pcol, pidx, bcol, bidx),
            eb.batch_pair_masks(jnp.asarray(packed.keys),
                                jnp.asarray(packed.uniq),
                                pcol, pidx, bcol, bidx)]
    for masks, counts in runs:
        masks, counts = np.asarray(masks), np.asarray(counts)
        for j, (kind, p1, p2) in enumerate(pairs):
            t1, t2 = vp[p1], vp[p2]
            probe = t1.o if kind == OS else t1.s
            other = t2.unique_o if kind == SO else t2.unique_s
            want = _semijoin_mask(probe, other)
            got = masks[j, :len(t1)].astype(bool)
            assert np.array_equal(got, want), (kind, p1, p2)
            assert int(counts[j]) == int(want.sum())
            # padded probe lanes never count
            assert not masks[j, len(t1):].any()


def test_sf_regimes_and_short_circuit(crafted_vp):
    """Empty, identity and disjoint-range cases land identically in both
    builders, and pruned pairs never reach a semi-join."""
    vp = crafted_vp
    base = build_extvp(vp, threshold=1.0)
    dev = build_extvp(vp, threshold=1.0, backend="jax", pair_batch=8)
    assert_builds_equal(base, dev)

    # identity: every p0 subject appears in p1 -> SF=1, not materialized
    assert base.sf[(SS, 1000, 1001)] == 1.0
    assert (SS, 1000, 1001) not in base.tables
    # disjoint ranges: pruned (SF=0) without evaluating a semi-join
    pruned, evals = eb.plan_pairs(vp, eb.all_pair_keys(sorted(vp)))
    assert (SS, 1000, 1002) in pruned
    assert base.sf[(SS, 1000, 1002)] == 0.0
    assert dev.n_semijoins == len(evals) < len(pruned) + len(evals)
    # overlapping ranges but empty result: evaluated, SF=0
    assert (SS, 1004, 1003) in evals
    assert base.sf[(SS, 1004, 1003)] == 0.0
    assert (SS, 1004, 1003) not in base.tables
    # strict reduction: materialized with exact rows
    assert base.sf[(SS, 1000, 1004)] == pytest.approx(2 / 3)
    assert np.array_equal(base.tables[(SS, 1000, 1004)].rows,
                          np.array([[0, 10], [2, 12]], dtype=np.int32))


def test_build_matches_under_pallas_interpret():
    """The vmapped-kernel path (Pallas interpret mode on CPU) produces
    the identical schema on a small graph."""
    rng = np.random.default_rng(11)
    vp = build_vp(random_tt(rng, 3, 12, 80))
    base = build_extvp(vp)
    prev = ops.pallas_enabled()
    ops.use_pallas(True)
    try:
        dev = build_extvp(vp, backend="jax", pair_batch=8)
    finally:
        ops.use_pallas(prev)
    assert_builds_equal(base, dev)


# ---------------------------------------------------------------------------
# Incremental append
# ---------------------------------------------------------------------------

def _triples(rng, n, n_ent, preds):
    return [(f"e{rng.integers(0, n_ent)}", rng.choice(preds),
             f"e{rng.integers(0, n_ent)}") for _ in range(n)]


def assert_datasets_equivalent(ds: Dataset, scratch: Dataset) -> None:
    assert np.array_equal(ds.catalog.tt, scratch.catalog.tt)
    assert set(ds.catalog.vp) == set(scratch.catalog.vp)
    for p in ds.catalog.vp:
        assert np.array_equal(ds.catalog.vp[p].rows,
                              scratch.catalog.vp[p].rows), p
    assert_builds_equal(ds.catalog.extvp, scratch.catalog.extvp,
                        check_semijoins=False)


def test_append_triples_equivalent_to_scratch():
    rng = np.random.default_rng(5)
    base = _triples(rng, 120, 24, ["p0", "p1", "p2", "p3"])
    extra = _triples(rng, 50, 24, ["p1", "p4"])   # p4 is a new predicate
    ds = Dataset.from_triples(base, threshold=0.5)
    report = ds.append_triples(extra)
    scratch = Dataset.from_triples(base + extra, threshold=0.5)
    assert_datasets_equivalent(ds, scratch)
    # untouched (p0, p2, p3) x (p0, p2, p3) pairs were carried over
    assert report["reused"] > 0
    assert report is ds.last_append_report
    # query results agree across backends after the append
    q = "SELECT * WHERE { ?a p1 ?b . ?b p0 ?c }"
    assert ds.engine("eager").query(q).same_as(scratch.engine("eager").query(q))
    assert ds.engine("jit").query(q).same_as(scratch.engine("eager").query(q))


def test_append_out_of_range_keys_skip_recompute():
    """New build-side keys outside every probe range: the pair results
    are carried over, not re-semi-joined — and still match scratch."""
    base = [(f"a{i}", "pA", f"a{i+1}") for i in range(6)] + \
           [(f"a{i}", "pB", f"a{i+2}") for i in range(5)]
    extra = [(f"z{i}", "pB", f"z{i+1}") for i in range(4)]  # fresh entities
    ds = Dataset.from_triples(base, threshold=1.0)
    report = ds.append_triples(extra)
    scratch = Dataset.from_triples(base + extra, threshold=1.0)
    assert report["range_skipped"] > 0
    assert_datasets_equivalent(ds, scratch)


def test_append_empty_and_engine_invalidation():
    ds = Dataset.from_triples([("a", "p", "b")], threshold=1.0)
    eng = ds.engine("eager")
    report = ds.append_triples([])
    assert report["recomputed"] == 0
    assert ds.engine("eager") is eng          # no-op append keeps engines
    ds.append_triples([("b", "p", "c")])
    assert ds.engine("eager") is not eng      # real append invalidates
    res = ds.engine("eager").query("SELECT * WHERE { ?x p ?y . ?y p ?z }")
    assert len(res) == 1


def test_append_without_extvp_stays_extvp_less():
    """A store built with with_extvp=False must append without touching
    (or back-filling) the ExtVP schema — it has no pair stats to extend."""
    ds = Dataset.from_triples([("a", "p", "b"), ("c", "q", "d")],
                              with_extvp=False)
    report = ds.append_triples([("x", "p", "y"), ("x", "r", "z")])
    assert report["recomputed"] == 0
    assert not ds.catalog.extvp.sf and not ds.catalog.extvp.tables
    scratch = Dataset.from_triples(
        [("a", "p", "b"), ("c", "q", "d"), ("x", "p", "y"), ("x", "r", "z")],
        with_extvp=False)
    assert np.array_equal(ds.catalog.tt, scratch.catalog.tt)
    for p in scratch.catalog.vp:
        assert np.array_equal(ds.catalog.vp[p].rows,
                              scratch.catalog.vp[p].rows)
    q = "SELECT * WHERE { ?s p ?o }"
    assert ds.engine("eager").query(q).same_as(scratch.engine("eager").query(q))
    # the opt-out survives appends even when the initial graph is empty
    empty = Dataset.from_triples([], with_extvp=False)
    empty.append_triples([("a", "p", "b")])
    assert not empty.catalog.extvp.sf and not empty.catalog.with_extvp


@settings(max_examples=10, deadline=None)
@given(st.data())
def test_append_property(data):
    """Random base/extra splits: incremental == scratch for every build
    backend and τ."""
    seed = data.draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    preds = [f"p{i}" for i in range(data.draw(st.integers(1, 4)))]
    base = _triples(rng, data.draw(st.integers(1, 80)), 20, preds)
    extra = _triples(rng, data.draw(st.integers(1, 40)), 30,
                     preds + ["pnew"])
    tau = data.draw(st.sampled_from([0.25, 1.0]))
    backend = data.draw(st.sampled_from(["numpy", "jax"]))
    ds = Dataset.from_triples(base, threshold=tau, build_backend=backend)
    ds.append_triples(extra)
    scratch = Dataset.from_triples(base + extra, threshold=tau,
                                   build_backend=backend)
    assert_datasets_equivalent(ds, scratch)


# ---------------------------------------------------------------------------
# Multi-device pair grid (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

_SUBPROCESS_PROG = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import numpy as np
    from repro.core.vp import build_extvp, build_vp

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(2)
    n = 4000
    tt = np.stack([rng.integers(0, 150, n), 150 + rng.integers(0, 12, n),
                   rng.integers(0, 150, n)], axis=1).astype(np.int32)
    vp = build_vp(np.unique(tt, axis=0))
    mesh = jax.make_mesh((8,), ("data",))
    base = build_extvp(vp, threshold=0.5)
    dist = build_extvp(vp, threshold=0.5, backend="distributed", mesh=mesh,
                       pair_batch=64)
    assert dist.sf == base.sf
    assert dist.sizes == base.sizes
    assert set(dist.tables) == set(base.tables)
    for k in base.tables:
        assert np.array_equal(base.tables[k].rows, dist.tables[k].rows)
    print("DIST_BUILD_OK")
""")


@pytest.mark.slow
def test_distributed_build_8dev_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run([sys.executable, "-c", _SUBPROCESS_PROG],
                         capture_output=True, text=True, env=env, timeout=600)
    assert res.returncode == 0, res.stderr[-4000:]
    assert "DIST_BUILD_OK" in res.stdout
