"""Shared fixtures.  NOTE: no XLA_FLAGS device-count forcing here — smoke
tests and benches must see the real single CPU device; multi-device tests
spawn subprocesses with their own XLA_FLAGS."""

import os
import sys

# the plan verifier is on for the whole suite (the process-wide
# RuntimeConfig reads the env at import, so set it before repro loads);
# explicit env settings still win
os.environ.setdefault("REPRO_RT_VERIFY_PLANS", "1")

sys.path.insert(0, os.path.dirname(__file__))
from _hypothesis_shim import install as _install_hypothesis_shim

_install_hypothesis_shim()   # no-op when the real hypothesis is importable

import numpy as np
import pytest

from repro.rdf.dictionary import Dictionary
from repro.rdf.generator import WatDivConfig, generate_watdiv
from repro.core.stats import build_catalog


@pytest.fixture(scope="session")
def g1():
    """The paper's running example graph G1 (Fig. 1)."""
    triples = [
        ("A", "follows", "B"), ("B", "follows", "C"), ("B", "follows", "D"),
        ("C", "follows", "D"), ("A", "likes", "I1"), ("A", "likes", "I2"),
        ("C", "likes", "I2"),
    ]
    d = Dictionary()
    tt = d.encode_triples(triples)
    cat = build_catalog(tt, d)
    return cat, d


@pytest.fixture(scope="session")
def watdiv_small():
    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=0.1, seed=7))
    cat = build_catalog(tt, d)
    return cat, d, sch


@pytest.fixture(scope="session")
def watdiv_medium():
    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=0.5, seed=3))
    cat = build_catalog(tt, d)
    return cat, d, sch
