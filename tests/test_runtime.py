"""Adaptive execution runtime (repro.runtime): deterministic router and
tuner behavior under an injected clock with scripted latencies, config
env/kwarg plumbing, and Engine / SparqlServer integration under
``backend="auto"`` (parity against an eager oracle, exclusion of failed
and fallback backends, runtime_report shape)."""

import numpy as np
import pytest

from repro.engine import Dataset, template_signature
from repro.runtime import (
    BackendRouter, BatchTuner, RouteDecision, RuntimeConfig,
)


class FakeClock:
    """Deterministic time source; advances only when told to."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, seconds):
        self.t += seconds


def _cfg(**kw):
    kw.setdefault("clock", FakeClock())
    return RuntimeConfig(**kw)


@pytest.fixture(scope="module")
def ds(watdiv_small):
    cat, d, sch = watdiv_small
    return Dataset(catalog=cat, dictionary=d, schema=sch)


SIG = "SELECT * WHERE { ?u <p> ?v }"


def _drive(router, sig, latencies, n):
    """Run n scripted requests: decide, then observe the scripted
    latency of whichever backend was chosen.  Returns the decisions."""
    out = []
    for _ in range(n):
        d = router.decide(sig)
        router.observe(sig, d.backend, latencies[d.backend],
                       reason=d.reason)
        out.append(d)
    return out


# ---------------------------------------------------------------------------
# RuntimeConfig
# ---------------------------------------------------------------------------

def test_config_env_overrides(monkeypatch):
    monkeypatch.setenv("REPRO_RT_WARMUP", "7")
    monkeypatch.setenv("REPRO_RT_BATCH_SHAPES", "8,1,4")
    cfg = RuntimeConfig()
    assert cfg.router_warmup == 7
    assert cfg.batch_shapes == (1, 4, 8)        # sorted, deduped


def test_config_kwargs_beat_env(monkeypatch):
    monkeypatch.setenv("REPRO_RT_WARMUP", "7")
    assert RuntimeConfig(router_warmup=3).router_warmup == 3


def test_config_unknown_knob_raises():
    with pytest.raises(ValueError, match="unknown RuntimeConfig knob"):
        RuntimeConfig(router_warmupp=3)


def test_config_bad_shapes_raise(monkeypatch):
    with pytest.raises(ValueError):
        RuntimeConfig(batch_shapes=())
    monkeypatch.setenv("REPRO_RT_BATCH_SHAPES", "0,4")
    with pytest.raises(ValueError):
        RuntimeConfig()


def test_config_snapshot_is_json_friendly():
    snap = _cfg(batch_shapes=(1, 2)).snapshot()
    assert "clock" not in snap
    assert snap["batch_shapes"] == [1, 2]
    import json
    json.dumps(snap)                            # must not raise


# ---------------------------------------------------------------------------
# BackendRouter: scripted-latency unit tests
# ---------------------------------------------------------------------------

def test_router_converges_to_fast_backend():
    cfg = _cfg(router_warmup=2, router_discard=1, router_probe_every=0)
    r = BackendRouter(("eager", "jit"), cfg)
    decisions = _drive(r, SIG, {"eager": 1.0, "jit": 0.2}, 12)
    # warmup = (warmup + discard) per backend = 6 requests, then exploit
    assert [d.reason for d in decisions[:6]] == ["warmup"] * 6
    assert all(d == RouteDecision("jit", "measured")
               for d in decisions[6:])
    st = r.report()["signatures"][SIG]
    assert st["choice"] == "jit" and st["reason"] == "measured"
    # warmup measured each backend 3 times; exploitation keeps sampling
    # only the winner
    assert st["samples"]["eager"] == 3 and st["samples"]["jit"] == 9


def test_router_decisions_deterministic():
    """Same scripted history -> identical decision sequence."""
    lat = {"eager": 0.4, "jit": 0.9}
    runs = []
    for _ in range(2):
        r = BackendRouter(("eager", "jit"),
                          _cfg(router_warmup=1, router_discard=0,
                               router_probe_every=4))
        runs.append([(d.backend, d.reason)
                     for d in _drive(r, SIG, lat, 20)])
    assert runs[0] == runs[1]


def test_router_discard_excludes_compile_sample():
    cfg = _cfg(router_warmup=1, router_discard=1, router_probe_every=0)
    r = BackendRouter(("eager", "jit"), cfg)
    # first jit sample is compile-heavy; it must not poison the estimate
    r.observe(SIG, "jit", 250.0)
    r.observe(SIG, "jit", 0.2)
    r.observe(SIG, "eager", 1.0)
    r.observe(SIG, "eager", 1.0)
    st = r.report()["signatures"][SIG]
    assert st["ewma_ms"]["jit"] == pytest.approx(0.2)
    assert r.decide(SIG) == RouteDecision("jit", "measured")


def test_router_winner_drift_switches_seat():
    """A winner that degrades loses the seat through its own EWMA —
    and the reversal is counted as a switch."""
    cfg = _cfg(router_warmup=1, router_discard=0, router_alpha=0.5,
               router_probe_every=0)
    r = BackendRouter(("eager", "jit"), cfg)
    lat = {"eager": 1.0, "jit": 0.2}
    _drive(r, SIG, lat, 4)
    assert r.peek(SIG).backend == "jit"
    lat["jit"] = 6.0                             # drift: jit degrades
    decisions = _drive(r, SIG, lat, 6)
    assert decisions[-1] == RouteDecision("eager", "measured")
    st = r.report()["signatures"][SIG]
    assert st["switches"] >= 1


def test_router_probe_rediscovers_improved_loser():
    """Exploit never starves measurement: every probe_every-th request
    re-measures a loser, so one that improved wins the seat back."""
    cfg = _cfg(router_warmup=1, router_discard=0, router_alpha=0.5,
               router_probe_every=4)
    r = BackendRouter(("eager", "jit"), cfg)
    lat = {"eager": 0.3, "jit": 2.0}
    _drive(r, SIG, lat, 3)
    assert r.peek(SIG).backend == "eager"
    lat["jit"] = 0.05                            # loser improves
    decisions = _drive(r, SIG, lat, 12)
    assert any(d.reason == "probe" and d.backend == "jit"
               for d in decisions)
    assert r.peek(SIG).backend == "jit"


def test_router_never_routes_to_excluded_backend():
    cfg = _cfg(router_warmup=2, router_probe_every=2)
    r = BackendRouter(("eager", "jit"), cfg)
    r.mark_failed(SIG, "jit")
    decisions = _drive(r, SIG, {"eager": 1.0, "jit": 0.1}, 16)
    assert all(d.backend == "eager" for d in decisions)
    r2 = BackendRouter(("eager", "jit"), cfg)
    r2.mark_fallback(SIG, "jit")
    assert all(d.backend == "eager"
               for d in _drive(r2, SIG, {"eager": 1.0, "jit": 0.1}, 16))


def test_router_exclusion_is_per_signature():
    r = BackendRouter(("eager", "jit"), _cfg(router_warmup=1,
                                             router_discard=0))
    r.mark_failed(SIG, "jit")
    other = "SELECT * WHERE { ?a <q> ?b }"
    assert "jit" in r.eligible(other)
    assert "jit" not in r.eligible(SIG)


def test_router_decision_log_bounded():
    cfg = _cfg(router_log_size=8, router_warmup=1, router_discard=0)
    r = BackendRouter(("eager", "jit"), cfg)
    _drive(r, SIG, {"eager": 1.0, "jit": 0.5}, 50)
    assert len(r.report()["decisions"]) == 8


# ---------------------------------------------------------------------------
# BatchTuner: scripted-launch unit tests
# ---------------------------------------------------------------------------

def test_tuner_retires_measured_slow_bucket():
    """A bucket whose per-slot time is beaten by a smaller bucket past
    the margin is retired — the serve-throughput batch-32 regression,
    discovered rather than hard-coded away."""
    cfg = _cfg(tuner_min_samples=3, tuner_discard=1, tuner_margin=1.1)
    t = BatchTuner((1, 8, 32), cfg)
    for _ in range(4):                           # 1 discard + 3 counted
        t.observe(8, 8, 8 * 0.1)                 # 0.10 ms / slot
        t.observe(32, 20, 32 * 0.25)             # 0.25 ms / slot: slower
    assert t.active_shapes() == (1, 8)
    assert t.max_shape() == 8
    assert t.bucket_for(20) == 8                 # callers chunk above max
    rep = t.report()
    assert "32" in rep["retired"]
    assert rep["buckets"]["32"]["retired"] is not None


def test_tuner_needs_min_samples_before_retiring():
    cfg = _cfg(tuner_min_samples=3, tuner_discard=0, tuner_margin=1.1)
    t = BatchTuner((8, 32), cfg)
    t.observe(8, 8, 0.8)
    t.observe(32, 32, 32.0)                      # looks awful, once
    t.observe(8, 8, 0.8)
    t.observe(32, 32, 32.0)                      # twice — still < 3
    assert t.active_shapes() == (8, 32)


def test_tuner_smallest_shape_never_retired():
    cfg = _cfg(tuner_min_samples=1, tuner_discard=0, tuner_margin=1.0)
    t = BatchTuner((1, 4), cfg)
    for _ in range(5):
        t.observe(1, 1, 50.0)                    # tiny bucket, terrible
        t.observe(4, 4, 0.4)
    assert 1 in t.active_shapes()


def test_tuner_discard_excludes_compile_launch():
    cfg = _cfg(tuner_min_samples=1, tuner_discard=1, tuner_margin=1.5)
    t = BatchTuner((4, 8), cfg)
    t.observe(8, 8, 800.0)                       # trace/compile launch
    t.observe(8, 8, 0.8)
    assert t.report()["buckets"]["8"]["per_slot_ms"] == pytest.approx(0.1)


def test_tuner_bucket_for_matches_menu():
    t = BatchTuner((1, 2, 4, 8, 16, 32), _cfg())
    assert [t.bucket_for(n) for n in (1, 2, 3, 5, 8, 9, 32, 100)] == \
        [1, 2, 4, 8, 8, 16, 32, 32]


# ---------------------------------------------------------------------------
# Engine integration: backend="auto"
# ---------------------------------------------------------------------------

Q_FOLLOWS = ("SELECT * WHERE {{ wsdbm:User{0} wsdbm:follows ?v . "
             "?v sorg:email ?e }}")
Q_LIKES = ("SELECT ?p WHERE {{ wsdbm:User{0} wsdbm:likes ?v . "
           "?v sorg:price ?p }}")


def test_auto_batched_matches_sequential_eager(ds):
    """Whatever the router decides, answers must match the eager oracle
    on both the single-request and the micro-batched path."""
    oracle = ds.engine("eager")
    eng = ds.engine(
        "auto", runtime=RuntimeConfig(router_warmup=1, router_discard=0,
                                      router_probe_every=3))
    queries = [Q_FOLLOWS.format(u % 7) for u in range(11)] + \
              [Q_LIKES.format(u % 5) for u in range(9)]
    for q in queries:
        assert eng.query(q).same_as(oracle.query(q)), q
    for q, got in zip(queries, eng.query_batch(queries)):
        assert got.same_as(oracle.query(q)), q
    rep = eng.runtime_report()
    assert rep["backend"] == "auto" and rep["auto"]
    # both backends were actually exercised during warmup
    routed = rep["metrics"]["routed"]
    assert routed.get("eager", 0) > 0 and routed.get("jit", 0) > 0
    ds._engines.clear()


def test_auto_never_routes_to_failing_backend(ds):
    """A backend whose prepare raises is excluded for that signature and
    the request is still answered (deterministic fallback)."""
    eng = ds.engine(
        "auto", runtime=RuntimeConfig(router_warmup=1, router_discard=0))
    oracle = ds.engine("eager")

    def boom(template, ctx):
        raise RuntimeError("injected prepare failure")

    eng._backends["jit"].prepare = boom
    q = Q_FOLLOWS.format(1)
    for _ in range(6):
        assert eng.query(q).same_as(oracle.query(q))
    st = eng.router.report()["signatures"][template_signature(q)]
    assert st["failed"] == ["jit"]
    assert eng.metrics.routed == {"eager": 6}
    ds._engines.clear()


def test_auto_excludes_device_fallback_preparations(ds):
    """A template the device path cannot express (prepared.fallback) is
    never routed to the device backend — eager latencies must not be
    measured under the jit label.  OPTIONAL/UNION/unbound predicates all
    device-compile now, so the host-only ``layout="pt"`` storage format
    is the exemplar fallback class."""
    eng = ds.engine(
        "auto", layout="pt",
        runtime=RuntimeConfig(router_warmup=1, router_discard=0))
    q = "SELECT * WHERE { ?v0 wsdbm:likes ?v1 }"
    for _ in range(4):
        eng.query(q)
    st = eng.router.report()["signatures"][template_signature(q)]
    assert st["fallback"] == ["jit"]
    assert st["choice"] == "eager"
    assert eng.metrics.device_fallbacks == 0
    ds._engines.clear()


def test_auto_readmits_fallback_exclusions(ds):
    """Fallback exclusions are coverage records, not verdicts: every
    ``router_readmit_every`` requests the set is cleared and the next
    prepare re-tests the backend.  On a still-uncovered template (pt
    layout) the backend is re-excluded and eager keeps the seat; the
    ``readmits`` counter records each re-check."""
    eng = ds.engine(
        "auto", layout="pt",
        runtime=RuntimeConfig(router_warmup=1, router_discard=0,
                              router_readmit_every=6))
    q = "SELECT * WHERE { ?v0 wsdbm:likes ?v1 }"
    for _ in range(14):
        eng.query(q)
    st = eng.router.report()["signatures"][template_signature(q)]
    assert st["readmits"] == 2                   # requests 6 and 12
    assert st["fallback"] == ["jit"]             # re-excluded each time
    assert st["choice"] == "eager"
    assert eng.metrics.device_fallbacks == 0
    # readmit_every=0 disables the mechanism entirely
    eng2 = ds.engine(
        "auto", layout="pt",
        runtime=RuntimeConfig(router_warmup=1, router_discard=0,
                              router_readmit_every=0))
    for _ in range(14):
        eng2.query(q)
    st2 = eng2.router.report()["signatures"][template_signature(q)]
    assert st2["readmits"] == 0
    assert st2["fallback"] == ["jit"]
    ds._engines.clear()


def test_explain_reports_plan_and_route(ds):
    eng = ds.engine("auto", runtime=RuntimeConfig(router_warmup=1,
                                                  router_discard=0))
    q = Q_FOLLOWS.format(2)
    text = eng.explain(q)
    assert "backend: " in text
    assert "(warmup" in text                     # nothing measured yet
    for _ in range(4):
        eng.query(q)
    text = eng.explain(q)
    assert "(measured; measured " in text or "(probe" in text
    static = ds.engine("eager")
    assert "backend: eager (forced)" in static.explain(q)
    ds._engines.clear()


def test_engine_default_config_is_shared_global(ds):
    from repro.runtime.config import runtime_config
    eng = ds.engine("eager")
    assert eng.config is runtime_config
    ds._engines.clear()


def test_runtime_report_shape(ds):
    eng = ds.engine("auto", runtime=RuntimeConfig())
    eng.query(Q_FOLLOWS.format(3))
    rep = eng.runtime_report()
    assert set(rep) == {"backend", "auto", "planner", "router", "tuner",
                        "config", "metrics"}
    assert rep["planner"] == "greedy"
    assert set(rep["router"]) == {"backends", "signatures", "decisions"}
    assert set(rep["tuner"]) == {"menu", "active", "retired", "buckets"}
    assert rep["config"]["router_warmup"] == rep["config"]["router_warmup"]
    import json
    json.dumps(rep)                              # operator-facing: JSON-able
    ds._engines.clear()


def test_retired_shape_shrinks_batcher_bound(ds):
    from repro.serve import MicroBatcher
    eng = ds.engine("auto", runtime=RuntimeConfig(
        tuner_min_samples=1, tuner_discard=0), batch_shapes=(1, 4, 16))
    for _ in range(2):
        eng.tuner.observe(4, 4, 0.4)             # 0.1 ms / slot
        eng.tuner.observe(16, 16, 8.0)           # 0.5 ms / slot: retire
    assert eng.max_active_batch() == 4
    b = MicroBatcher(eng, max_batch=32)
    assert b.effective_max_batch() == 4
    ds._engines.clear()


# ---------------------------------------------------------------------------
# SparqlServer integration
# ---------------------------------------------------------------------------

def test_server_auto_end_to_end(watdiv_small):
    from repro.serve import SparqlServer
    cat, d, sch = watdiv_small
    srv = SparqlServer(cat, backend="auto",
                       runtime=RuntimeConfig(router_warmup=1,
                                             router_discard=0))
    oracle = SparqlServer(cat, backend="eager")
    queries = [Q_FOLLOWS.format(u % 6) for u in range(10)]
    tickets = [srv.submit(q) for q in queries]
    srv.flush()
    for q, t in zip(queries, tickets):
        assert t.done() and t.result().same_as(oracle.query(q))
    rep = srv.runtime_report()
    assert rep["backend"] == "auto"
    assert rep["metrics"]["served"] == 10
    sig = template_signature(queries[0])
    assert sig in rep["router"]["signatures"]
    # the metrics object exposes the same snapshot without the engine
    assert srv.metrics.runtime_report()["backend"] == "auto"


def test_server_rejects_unknown_backend(watdiv_small):
    from repro.serve import SparqlServer
    cat, d, sch = watdiv_small
    with pytest.raises(ValueError, match="unknown backend"):
        SparqlServer(cat, backend="warp")
