"""Solution-modifier semantics and the device modifier pipeline.

The SPARQL modifier order is ORDER BY → project → DISTINCT →
OFFSET/LIMIT with an order-preserving DISTINCT.  The pre-fix eager
engine applied ``np.unique`` dedup *after* ORDER BY and LIMIT had run
inside the root (destroying the sort order and deduping the truncated
rows), and the device backends silently fell back to eager for every
modifier-bearing query — these tests pin the fixed semantics row-for-row
against hand-computed oracles on all three backends, and pin the jit
path's compile-once behaviour through the modifier chain.
"""

import jax
import numpy as np
import pytest

from repro.core import jexec
from repro.core.modifiers import peel_spine
from repro.core.sparql import SparqlError, parse_sparql
from repro.engine import Dataset

# prices: p1=30, p2=10, p3=20, p4=10  (dictionary ids in insertion order)
TRIPLES = [
    ("ex:p1", "ex:price", '"30"'),
    ("ex:p2", "ex:price", '"10"'),
    ("ex:p3", "ex:price", '"20"'),
    ("ex:p4", "ex:price", '"10"'),
    ("ex:u1", "ex:likes", "ex:p1"),
    ("ex:u1", "ex:likes", "ex:p2"),
    ("ex:u2", "ex:likes", "ex:p2"),
    ("ex:u2", "ex:likes", "ex:p3"),
    ("ex:u1", "ex:likes", "ex:p4"),
]

BACKENDS = ("eager", "jit", "distributed")


@pytest.fixture(scope="module")
def ds():
    return Dataset.from_triples(TRIPLES)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1,), ("data",))


def engine(ds, mesh, backend):
    return ds.engine(backend, mesh=mesh if backend == "distributed" else None)


def ids(ds, *terms):
    return [ds.dictionary.id_of(t) for t in terms]


# ---------------------------------------------------------------------------
# Modifier-ordering regression (fails on the pre-fix execute())
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_distinct_order_limit_regression(ds, mesh, backend):
    """DISTINCT must dedup BEFORE the limit and preserve the order:
    prices {30, 10, 20, 10} → distinct {30,10,20} → asc {10,20,30} →
    LIMIT 2 = [10, 20].  The pre-fix pipeline ordered+limited first
    ([10, 10]) and then np.unique'd ([10]): one wrong row."""
    eng = engine(ds, mesh, backend)
    res = eng.query("SELECT DISTINCT ?x WHERE { ?p ex:price ?x } "
                    "ORDER BY ?x LIMIT 2")
    want = np.array(ids(ds, '"10"', '"20"'), dtype=np.int32).reshape(2, 1)
    assert res.cols == ("?x",)
    assert np.array_equal(res.data, want), (backend, res.data, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_desc_order_survives_distinct(ds, mesh, backend):
    eng = engine(ds, mesh, backend)
    res = eng.query("SELECT DISTINCT ?x WHERE { ?p ex:price ?x } "
                    "ORDER BY DESC(?x) LIMIT 2")
    want = np.array(ids(ds, '"30"', '"20"'), dtype=np.int32).reshape(2, 1)
    assert np.array_equal(res.data, want), (backend, res.data, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_offset_window(ds, mesh, backend):
    """ORDER BY ?x ?u over (u, x) pairs: [(u1,10),(u1,10),(u2,10),
    (u2,20),(u1,30)]; OFFSET 1 LIMIT 2 → [(u1,10),(u2,10)]."""
    eng = engine(ds, mesh, backend)
    res = eng.query("SELECT ?u ?x WHERE { ?u ex:likes ?p . ?p ex:price ?x } "
                    "ORDER BY ?x ?u LIMIT 2 OFFSET 1")
    u1, u2, v10 = ids(ds, "ex:u1", "ex:u2", '"10"')
    want = np.array([[u1, v10], [u2, v10]], dtype=np.int32)
    assert res.cols == ("?u", "?x")
    assert np.array_equal(res.data, want), (backend, res.data, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_distinct_is_first_occurrence_stable(ds, mesh, backend):
    """Without ORDER BY, DISTINCT keeps the first occurrence in pipeline
    order (subject-sorted price table → x = [30, 10, 20, 10])."""
    eng = engine(ds, mesh, backend)
    res = eng.query("SELECT DISTINCT ?x WHERE { ?p ex:price ?x }")
    want = np.array(ids(ds, '"30"', '"10"', '"20"'),
                    dtype=np.int32).reshape(3, 1)
    assert np.array_equal(res.data, want), (backend, res.data, want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_limit_zero_and_offset_past_end(ds, mesh, backend):
    eng = engine(ds, mesh, backend)
    assert len(eng.query("SELECT ?x WHERE { ?p ex:price ?x } LIMIT 0")) == 0
    assert len(eng.query("SELECT ?x WHERE { ?p ex:price ?x } OFFSET 99")) == 0


# ---------------------------------------------------------------------------
# FILTER on the device path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS[1:])
@pytest.mark.parametrize("qtext", [
    "SELECT * WHERE { ?u ex:likes ?p . ?p ex:price ?x FILTER(?x < 25) }",
    "SELECT * WHERE { ?u ex:likes ?p . ?p ex:price ?x "
    "FILTER(?x < 25 && ?x > 5) }",
    "SELECT ?u WHERE { ?u ex:likes ?p . ?p ex:price ?x "
    "FILTER(!(?x = 10) || BOUND(?u)) }",
    "SELECT ?u ?x WHERE { ?u ex:likes ?p . ?p ex:price ?x "
    "FILTER(?u != ex:u2) } ORDER BY DESC(?x)",
    "SELECT DISTINCT ?x WHERE { ?u ex:likes ?p . ?p ex:price ?x "
    "FILTER(?x >= 10) } ORDER BY ?x OFFSET 1",
])
def test_device_filter_matches_eager_row_for_row(ds, mesh, backend, qtext):
    got = engine(ds, mesh, backend).query(qtext)
    ref = engine(ds, mesh, "eager").query(qtext)
    assert got.cols == ref.cols
    assert np.array_equal(got.data, ref.data), (backend, qtext, got.data,
                                                ref.data)


def test_jit_modifier_query_compiles_once(ds, mesh):
    """A FILTER + DISTINCT + ORDER BY + LIMIT template prepares onto the
    device path (no eager fallback) and compiles once per (template,
    batch shape): constant re-binding and repeated batches re-use the
    program."""
    eng = ds.engine("jit")
    eager = ds.engine("eager")

    def q(u):
        return (f"SELECT DISTINCT ?x WHERE {{ ex:u{u} ex:likes ?p . "
                f"?p ex:price ?x FILTER(?x > 5) }} ORDER BY DESC(?x) LIMIT 2")

    prepared = eng.prepare(q(1))
    assert prepared.backend == "jit" and not prepared.fallback
    core, spine = peel_spine(prepared.template.query)
    assert spine.distinct and spine.order and spine.limit == 2 and spine.filters

    t0 = jexec.trace_count()
    r1 = eng.query(q(1))
    traces_after_first = jexec.trace_count()
    assert traces_after_first > t0          # first run compiles
    r2 = eng.query(q(2))
    assert jexec.trace_count() == traces_after_first   # re-bind: no re-trace
    for u, r in ((1, r1), (2, r2)):
        ref = eager.query(q(u))
        assert np.array_equal(r.data, ref.data), (u, r.data, ref.data)

    # batched: one compile per bucket shape, none for a repeat batch
    t1 = jexec.trace_count()
    outs = eng.query_batch([q(1), q(2), q(1), q(2)])
    assert jexec.trace_count() == t1 + 1
    outs2 = eng.query_batch([q(2), q(2), q(1), q(1)])
    assert jexec.trace_count() == t1 + 1
    for u, r in zip((2, 2, 1, 1), outs2):
        assert np.array_equal(r.data, eager.query(q(u)).data)


def test_distributed_modifier_batch_matches_eager(ds, mesh):
    eng = ds.engine("distributed", mesh=mesh)
    eager = ds.engine("eager")

    def q(u):
        return (f"SELECT DISTINCT ?x WHERE {{ ex:u{u} ex:likes ?p . "
                f"?p ex:price ?x }} ORDER BY ?x LIMIT 3")

    outs = eng.query_batch([q(1), q(2), q(1)])
    for u, r in zip((1, 2, 1), outs):
        ref = eager.query(q(u))
        assert np.array_equal(r.data, ref.data), (u, r.data, ref.data)


def test_missing_constant_still_short_circuits(ds, mesh):
    for backend in BACKENDS:
        eng = engine(ds, mesh, backend)
        res = eng.query("SELECT DISTINCT ?x WHERE { ex:u999 ex:likes ?p . "
                        "?p ex:price ?x } ORDER BY ?x LIMIT 2")
        assert len(res) == 0, backend


@pytest.mark.parametrize("backend", BACKENDS)
def test_order_by_unprojected_variable(ds, mesh, backend):
    """ORDER BY runs before projection (W3C §18.2.4): sorting by a
    variable outside the SELECT list must still order the rows."""
    eng = engine(ds, mesh, backend)
    res = eng.query("SELECT ?p WHERE { ?p ex:price ?x } ORDER BY DESC(?x)")
    want = np.array(ids(ds, "ex:p1", "ex:p3", "ex:p2", "ex:p4"),
                    dtype=np.int32).reshape(4, 1)     # 30, 20, 10, 10
    assert res.cols == ("?p",)
    assert np.array_equal(res.data, want), (backend, res.data, want)


def test_non_float32_exact_values_stay_on_device(mesh):
    """Numeric device comparisons use exact double-single float32 key
    pairs, so values past the float32-exact integer range (2**24 + 1 is
    the first such int) no longer force the eager fallback — adjacent
    2**24-range ints compare exactly on device."""
    big = Dataset.from_triples([("ex:a", "ex:p", '"16777217"'),
                                ("ex:b", "ex:p", '"16777216"')])
    for backend in BACKENDS[1:]:
        eng = engine(big, mesh, backend)
        res = eng.query("SELECT ?s WHERE { ?s ex:p ?x "
                        "FILTER(?x > 16777216) }")
        assert res.to_terms() == [{"?s": "ex:a"}], (backend, res.to_terms())
        assert eng.metrics.device_fallbacks == 0, backend
        res2 = eng.query("SELECT ?s ?x WHERE { ?s ex:p ?x } ORDER BY ?x")
        assert [m["?x"] for m in res2.to_terms()] == \
            ['"16777216"', '"16777217"'], (backend, res2.to_terms())
        assert eng.metrics.device_fallbacks == 0, backend


# ---------------------------------------------------------------------------
# Fallback observability
# ---------------------------------------------------------------------------

def test_device_fallback_counter(ds, mesh):
    eng = ds.engine("jit")
    eng.query("SELECT ?x WHERE { ?p ex:price ?x } ORDER BY ?x LIMIT 1")
    assert eng.metrics.device_fallbacks == 0      # modifiers stay on device
    eng.query("SELECT * WHERE { ?u ex:likes ?p OPTIONAL { ?p ex:price ?x } }")
    assert eng.metrics.device_fallbacks == 0      # OPTIONAL compiles too
    # the host-only pt layout is the remaining (counted) fallback class
    pt = ds.engine("jit", layout="pt")
    pt.query("SELECT * WHERE { ?u ex:likes ?p OPTIONAL { ?p ex:price ?x } }")
    assert pt.metrics.device_fallbacks == 1
    assert pt.metrics.summary()["device_fallbacks"] == 1
    # the eager backend is never a "fallback"
    e = ds.engine("eager")
    e.query("SELECT * WHERE { ?u ex:likes ?p OPTIONAL { ?p ex:price ?x } }")
    assert e.metrics.device_fallbacks == 0


def test_eager_caches_plan_for_modifier_spines(ds):
    """Modifier-bearing BGP cores take the compiled-plan path on eager
    (no per-request re-parse/re-compile), not the substitute_query path."""
    eng = ds.engine("eager")
    prepared = eng.prepare(
        "SELECT DISTINCT ?x WHERE { ?p ex:price ?x FILTER(?x > 5) } "
        "ORDER BY ?x LIMIT 2")
    assert prepared.plan is not None and not prepared.plan.empty
    assert prepared.spine is not None and prepared.spine.distinct


# ---------------------------------------------------------------------------
# Parser regressions
# ---------------------------------------------------------------------------

def test_prefix_without_colon_raises(ds):
    with pytest.raises(SparqlError):
        parse_sparql("PREFIX ex <http://e/> SELECT * WHERE { ?s ?p ?o }",
                     ds.dictionary)


def test_prefix_with_local_part_raises(ds):
    # previously accepted silently (prefix mangled to 'ex')
    with pytest.raises(SparqlError):
        parse_sparql("PREFIX ex:x <http://e/> "
                     "SELECT * WHERE { ?s ex:likes ?o }", ds.dictionary)


def test_valid_prefix_still_parses(ds):
    q = parse_sparql("PREFIX foo: <ex:> "
                     "SELECT * WHERE { ?u foo:likes ?p }", ds.dictionary)
    assert q.root.patterns[0].p == ds.dictionary.id_of("ex:likes")


@pytest.mark.parametrize("qtext", [
    "SELECT * WHERE { ?u ex:likes ?a ; ex:likes ?b ; . }",
    "SELECT * WHERE { ?u ex:likes ?a ; ex:likes ?b ; }",
])
def test_trailing_semicolon_in_predicate_list(ds, qtext):
    q = parse_sparql(qtext, ds.dictionary)
    ref = parse_sparql("SELECT * WHERE { ?u ex:likes ?a ; ex:likes ?b }",
                       ds.dictionary)
    assert q.root.patterns == ref.root.patterns


def test_lt_comparison_before_later_gt(ds):
    """'?x < 25 && ?x > 5' must tokenize as comparisons, not as one
    '< ... >' IRI (IRIs contain no whitespace)."""
    q = parse_sparql("SELECT * WHERE { ?p ex:price ?x "
                     "FILTER(?x < 25 && ?x > 5) }", ds.dictionary)
    core, spine = peel_spine(q)
    assert len(spine.filters) == 1
