"""Observability layer (repro.obs): streaming histogram error bounds,
deterministic stride sampling, span nesting under an injected fake
clock, flight-recorder ring/slow-reservoir retention, Chrome trace
export round-trips, ServerMetrics histogram migration (None percentiles
on an idle server, O(1) trimming behind the compat list views), and the
engine/batcher integration — device-launch spans carrying estimated AND
actual per-step cardinalities on both device backends."""

import json

import jax
import numpy as np
import pytest

from repro.engine import Dataset, RuntimeConfig, ServerMetrics
from repro.obs import FlightRecorder, LogHistogram, TraceContext, Tracer
from repro.obs.histogram import GROWTH, LO_MS


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, seconds):
        self.t += seconds


def _tracer(**kw):
    kw.setdefault("clock", FakeClock())
    kw.setdefault("trace_sample_rate", 1.0)
    return Tracer(RuntimeConfig(**kw))


# ---------------------------------------------------------------- histogram

class TestLogHistogram:
    def test_empty_is_none_not_zero(self):
        h = LogHistogram()
        assert h.percentile(50) is None
        assert h.percentile(99) is None
        assert h.mean_ms is None
        assert len(h) == 0

    def test_single_sample_reports_itself(self):
        h = LogHistogram()
        h.record(3.7)
        # clamped to the observed max, not the bucket's upper edge
        assert h.percentile(50) == pytest.approx(3.7)
        assert h.percentile(99) == pytest.approx(3.7)

    def test_percentile_error_bound(self):
        """Any percentile is within a factor GROWTH (≈1.19×) above the
        exact nearest-rank order statistic."""
        rng = np.random.default_rng(0)
        samples = np.exp(rng.normal(1.0, 1.5, size=2000))  # ms, heavy tail
        h = LogHistogram()
        for s in samples:
            h.record(float(s))
        ordered = np.sort(samples)
        for q in (1, 25, 50, 90, 99, 99.9):
            rank = max(1, int(np.ceil(q / 100.0 * len(ordered))))
            exact = ordered[rank - 1]
            got = h.percentile(q)
            assert exact <= got <= exact * GROWTH * (1 + 1e-12), \
                f"p{q}: exact={exact} got={got}"

    def test_out_of_range_samples_clamped_to_observed(self):
        h = LogHistogram()
        h.record(1e-9)          # underflow slot
        assert h.percentile(50) == pytest.approx(1e-9)
        h2 = LogHistogram()
        h2.record(1e9)          # overflow slot (no finite edge)
        assert h2.percentile(99) == pytest.approx(1e9)

    def test_merge_equals_combined_recording(self):
        rng = np.random.default_rng(1)
        a_samples = rng.exponential(5.0, 300)
        b_samples = rng.exponential(50.0, 300)
        a, b, both = LogHistogram(), LogHistogram(), LogHistogram()
        for s in a_samples:
            a.record(float(s))
            both.record(float(s))
        for s in b_samples:
            b.record(float(s))
            both.record(float(s))
        a.merge(b)
        assert a.count == both.count
        assert a.sum_ms == pytest.approx(both.sum_ms)
        assert a.min_ms == both.min_ms and a.max_ms == both.max_ms
        for q in (50, 90, 99):
            assert a.percentile(q) == both.percentile(q)

    def test_record_large_count_is_o1(self):
        h = LogHistogram()
        h.record(2.0, count=10**9)      # would OOM as a sample list
        assert h.count == 10**9
        assert h.percentile(99) == pytest.approx(2.0)

    def test_cumulative_buckets_monotone_and_total(self):
        h = LogHistogram()
        for ms in (0.01, 0.5, 0.5, 7.0, 300.0):
            h.record(ms)
        pairs = list(h.cumulative_buckets())
        edges = [e for e, _ in pairs]
        cums = [c for _, c in pairs]
        assert edges == sorted(edges)
        assert cums == sorted(cums) and cums[-1] == h.count

    def test_invalid_percentile(self):
        h = LogHistogram()
        h.record(1.0)
        with pytest.raises(ValueError):
            h.percentile(101)


# ------------------------------------------------------------------ sampling

class TestSampling:
    def test_rate_zero_inactive(self):
        tr = _tracer(trace_sample_rate=0.0)
        assert not tr.active
        assert tr.begin("q") is None

    def test_rate_one_samples_everything(self):
        tr = _tracer(trace_sample_rate=1.0)
        assert all(tr.begin("q") is not None for _ in range(10))
        assert tr.started == 10 and tr.sampled_out == 0

    def test_stride_sampling_deterministic(self):
        tr = _tracer(trace_sample_rate=0.5)
        picks = [tr.begin("q") is not None for _ in range(8)]
        assert picks == [True, False] * 4
        assert tr.sampled_out == 4

    def test_sampled_out_leaves_zero_records(self):
        tr = _tracer(trace_sample_rate=0.25)
        for _ in range(8):
            ctx = tr.begin("q")
            if ctx is not None:
                ctx.finish()
        assert tr.started == 2 and tr.sampled_out == 6
        assert len(tr.recorder) == 2   # nothing from the sampled-out 6

    def test_rate_is_read_live_from_config(self):
        tr = _tracer(trace_sample_rate=1.0)
        assert tr.begin("q") is not None
        tr.config.trace_sample_rate = 0.0
        assert not tr.active and tr.begin("q") is None

    def test_invalid_rate_rejected(self):
        with pytest.raises(ValueError):
            RuntimeConfig(trace_sample_rate=1.5)
        with pytest.raises(ValueError):
            RuntimeConfig(trace_sample_rate=-0.1)


# -------------------------------------------------------------------- spans

class TestSpanNesting:
    def test_nesting_and_ordering(self):
        tr = _tracer()
        clock = tr.config.clock
        ctx = tr.begin("q")
        clock.advance(0.001)
        a = ctx.start("plan")
        clock.advance(0.002)
        b = ctx.start("verify")            # nested inside plan
        clock.advance(0.003)
        ctx.end(b)
        clock.advance(0.001)
        ctx.end(a)
        clock.advance(0.001)
        c = ctx.start("execute")           # sibling after plan
        clock.advance(0.005)
        ctx.end(c)
        ctx.finish()

        spans = {s.sid: s for s in ctx.spans}
        assert spans[b].parent == a and spans[a].parent == 0
        assert spans[c].parent == 0
        # children inside parent bounds
        assert spans[a].t0 <= spans[b].t0 and spans[b].t1 <= spans[a].t1
        # siblings non-overlapping and ordered
        assert spans[a].t1 <= spans[c].t0
        assert spans[b].duration_ms == pytest.approx(3.0)
        assert ctx.duration_ms == pytest.approx(13.0)

    def test_dangling_child_closed_by_parent_end(self):
        tr = _tracer()
        ctx = tr.begin("q")
        outer = ctx.start("outer")
        inner = ctx.start("inner")
        tr.config.clock.advance(0.004)
        ctx.end(outer)                     # inner never ended explicitly
        assert ctx.spans[inner].t1 == ctx.spans[outer].t1
        ctx.finish()

    def test_finish_idempotent_and_closes_stragglers(self):
        tr = _tracer()
        ctx = tr.begin("q")
        ctx.start("open-span")
        tr.config.clock.advance(0.010)
        ctx.finish(backend="jit")
        ctx.finish()                       # second call is a no-op
        assert tr.finished == 1
        assert all(s.t1 is not None for s in ctx.spans)
        assert ctx.root.attrs["backend"] == "jit"

    def test_events_attach_to_innermost_open_span(self):
        tr = _tracer()
        ctx = tr.begin("q")
        sid = ctx.start("plan")
        ctx.event("plan_cache", hit=False)
        ctx.end(sid)
        ctx.event("root-level")
        assert ctx.spans[sid].events[0]["name"] == "plan_cache"
        assert ctx.root.events[0]["name"] == "root-level"

    def test_annotate_named(self):
        tr = _tracer()
        ctx = tr.begin("q")
        for _ in range(2):
            ctx.end(ctx.start("device.launch"))
        assert ctx.annotate_named("device.launch", cardinalities=[1]) == 2
        assert ctx.annotate_named("no-such-span", x=1) == 0


# ----------------------------------------------------------- flight recorder

def _fake_trace(clock, trace_id, duration_s):
    ctx = TraceContext(trace_id, clock, None)
    clock.advance(duration_s)
    ctx.finish()
    return ctx


class TestFlightRecorder:
    def test_ring_evicts_but_slow_reservoir_keeps(self):
        clock = FakeClock()
        rec = FlightRecorder(ring=4, slow_ms=10.0, slow_keep=2)
        slow = _fake_trace(clock, 1, 0.050)     # 50 ms — slow
        rec.add(slow)
        for i in range(10):                     # fast flood evicts the ring
            rec.add(_fake_trace(clock, 10 + i, 0.001))
        ids = {c.trace_id for c in rec.traces()}
        assert slow.trace_id in ids             # survived ring eviction
        assert len([i for i in ids if i >= 10]) == 4
        assert rec.dropped > 0

    def test_slow_reservoir_keeps_slowest(self):
        clock = FakeClock()
        rec = FlightRecorder(ring=1, slow_ms=10.0, slow_keep=2)
        for tid, dur in ((1, 0.020), (2, 0.040), (3, 0.030)):
            rec.add(_fake_trace(clock, tid, dur))
        ids = {c.trace_id for c in rec.traces()}
        assert 2 in ids and 3 in ids            # the two slowest kept
        assert 1 not in ids                     # fastest slow trace evicted

    def test_chrome_trace_round_trip(self):
        tr = _tracer()
        clock = tr.config.clock
        for _ in range(3):
            ctx = tr.begin("SELECT * WHERE { ?s ?p ?o }")
            sid = ctx.start("plan", planner="greedy")
            clock.advance(0.002)
            ctx.end(sid)
            inner = ctx.start("execute")
            clock.advance(0.004)
            ctx.end(inner, rows=np.int64(7))    # numpy attr must degrade
            ctx.finish()
        doc = json.loads(json.dumps(tr.chrome_trace()))
        assert doc["displayTimeUnit"] == "ms"
        spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
        by_tid = {}
        for e in spans:
            by_tid.setdefault(e["tid"], []).append(e)
        assert len(by_tid) == 3
        for tid, evs in by_tid.items():
            root = next(e for e in evs if e["name"] == "request")
            children = [e for e in evs if e is not root]
            # children within root bounds, monotone and non-overlapping
            prev_end = root["ts"]
            for e in sorted(children, key=lambda e: e["ts"]):
                assert e["ts"] >= prev_end
                assert e["ts"] + e["dur"] <= root["ts"] + root["dur"]
                prev_end = e["ts"] + e["dur"]
        rows = next(e["args"]["rows"] for e in spans
                    if e["name"] == "execute")
        assert rows == 7 and isinstance(rows, int)

    def test_jsonl_round_trip(self):
        tr = _tracer()
        ctx = tr.begin("q")
        tr.config.clock.advance(0.2)       # 200 ms > slow_ms default
        ctx.finish()
        rows = [json.loads(line) for line in
                tr.to_jsonl().splitlines()]
        assert len(rows) == 1
        assert rows[0]["slow"] is True
        assert rows[0]["spans"][0]["name"] == "request"


# ------------------------------------------------------------ server metrics

class TestServerMetrics:
    def test_idle_percentiles_are_none(self):
        m = ServerMetrics()
        s = m.summary()
        assert s["p50_ms"] is None and s["p99_ms"] is None
        assert s["queue_p50_ms"] is None and s["queue_p99_ms"] is None

    def test_histogram_primary_compat_list_views(self):
        m = ServerMetrics()
        m.record_latency(5.0)
        m.record_latency(2.0, count=3)
        m.record_queue(1.5)
        assert m.latencies_ms == [5.0, 2.0, 2.0, 2.0]
        assert m.queue_ms == [1.5]
        assert m.latency_hist.count == 4
        assert m.summary()["p50_ms"] == pytest.approx(2.0, rel=GROWTH)

    def test_list_views_trim_o1_under_flood(self):
        from repro.engine.engine import _MAX_SAMPLES
        m = ServerMetrics()
        m.record_latency(1.0, count=_MAX_SAMPLES * 3)
        assert len(m.latencies_ms) == _MAX_SAMPLES    # bounded window
        assert m.latency_hist.count == _MAX_SAMPLES * 3  # exact, untrimmed

    def test_prometheus_exposition(self):
        m = ServerMetrics()
        m.served = 3
        m.record_latency(4.0)
        m.record_route("jit", 3)
        text = m.prometheus()
        assert "repro_served_total 3" in text
        assert 'repro_routed_total{backend="jit"} 3' in text
        assert 'repro_request_latency_ms_bucket{le="+Inf"} 1' in text
        assert "repro_request_latency_ms_count 1" in text


# -------------------------------------------------------- engine integration

QA = "SELECT * WHERE { ?v0 <wsdbm:follows> ?v1 . ?v1 <wsdbm:likes> ?v2 }"
QB = "SELECT * WHERE { ?v0 <rev:reviewer> ?v1 . ?v1 <wsdbm:likes> ?v2 }"


@pytest.fixture(scope="module")
def ds(watdiv_small):
    cat, d, sch = watdiv_small
    return Dataset(catalog=cat, dictionary=d, schema=sch)


def _launch_spans(tracer):
    return [e for e in tracer.chrome_trace()["traceEvents"]
            if e.get("ph") == "X" and e["name"] == "device.launch"]


class TestEngineTracing:
    def test_jit_trace_carries_cardinalities(self, ds):
        eng = ds.engine("jit",
                        runtime=RuntimeConfig(trace_sample_rate=1.0))
        eng.query(QA)
        eng.query(QA)        # second pass: plan-cache hit
        eng.query(QB)
        assert eng.metrics.device_fallbacks == 0
        launches = _launch_spans(eng.tracer)
        assert launches and all("cardinalities" in e["args"]
                                for e in launches)
        for e in launches:
            assert e["args"]["backend"] == "jit"
            for step in e["args"]["cardinalities"]:
                assert step["actual"] is not None
                assert step["est"] is None or step["est"] >= 0
        # router/plan-cache story is in the event stream
        events = [ev for tr in eng.tracer.recorder.traces()
                  for s in tr.spans for ev in s.events]
        names = [ev["name"] for ev in events]
        assert "router.decide" in names
        outcomes = [ev["attrs"]["outcome"] for ev in events
                    if ev["name"] == "plan_cache"]
        assert "miss" in outcomes and "hit" in outcomes
        decide = next(ev for ev in events if ev["name"] == "router.decide")
        assert "ewma_ms" in decide["attrs"]

    def test_untraced_engine_records_nothing(self, ds):
        eng = ds.engine("jit", runtime=RuntimeConfig())  # rate 0 default
        res = eng.query(QA)
        assert eng.tracer.started == 0
        assert len(eng.tracer.recorder) == 0
        assert res is not None

    def test_distributed_trace_carries_cardinalities(self, ds):
        mesh = jax.make_mesh((jax.device_count(),), ("data",))
        eng = ds.engine("distributed", mesh=mesh,
                        runtime=RuntimeConfig(trace_sample_rate=1.0))
        eng.query(QA)
        assert eng.metrics.device_fallbacks == 0
        launches = _launch_spans(eng.tracer)
        assert launches
        for e in launches:
            assert e["args"]["backend"] == "distributed"
            assert e["args"]["shards"] == jax.device_count()
            assert all(s["actual"] is not None
                       for s in e["args"]["cardinalities"])

    def test_traced_matches_untraced_results(self, ds):
        plain = ds.engine("jit", runtime=RuntimeConfig())
        traced = ds.engine("jit",
                           runtime=RuntimeConfig(trace_sample_rate=1.0))
        for q in (QA, QB):
            a, b = plain.query(q), traced.query(q)
            assert a.cols == b.cols
            assert sorted(map(tuple, a.to_numpy().tolist())) \
                == sorted(map(tuple, b.to_numpy().tolist()))

    def test_batcher_queue_spans(self, ds):
        from repro.serve.batcher import MicroBatcher
        eng = ds.engine("jit",
                        runtime=RuntimeConfig(trace_sample_rate=1.0))
        mb = MicroBatcher(eng, max_batch=8, flush_ms=1e9)
        tickets = [mb.submit(QA) for _ in range(3)]
        mb.flush()
        assert all(t.result() is not None for t in tickets)
        ct = eng.tracer.chrome_trace()
        queues = [e for e in ct["traceEvents"]
                  if e.get("ph") == "X" and e["name"] == "queue"]
        assert len(queues) == 3
        assert all(e["args"]["batch"] == 3 for e in queues)
        execs = [e for e in ct["traceEvents"]
                 if e.get("ph") == "X" and e["name"] == "execute"]
        shared = [e["args"].get("shared_launch") for e in execs]
        assert shared.count(False) == 1 and shared.count(True) == 2

    def test_prometheus_end_to_end(self, ds):
        eng = ds.engine("jit",
                        runtime=RuntimeConfig(trace_sample_rate=1.0))
        eng.query(QA)
        text = eng.metrics.prometheus()
        assert "repro_served_total 1" in text
        assert 'repro_traces_total{state="finished"} 1' in text
        assert 'repro_stage_ms_bucket{stage="device.launch"' in text
