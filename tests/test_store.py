"""Persistent columnar store: round-trip identity, lazy/eager parity,
delta journal replay, corruption handling, and serving-boot guarantees.

The acceptance bar (ISSUE 5): a catalog saved to disk and loaded back
must be **byte-identical** to the freshly built one — across τ and build
backends — and must answer every query identically on every execution
backend; ``SparqlServer`` must boot from a store path with zero build-
pipeline invocations.
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.stats import build_catalog
from repro.core.table import LazyTableMap, Table
from repro.engine import Dataset, RuntimeConfig
from repro.rdf.dictionary import Dictionary
from repro.serve import SparqlServer
from repro.store import (
    StoreChecksumError, StoreError, StoreFormatError, is_store,
    load_manifest, read_segments,
)

from test_differential import (
    assert_matches_oracle, assert_rows_equal, random_query, random_triples,
)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TAUS = (0.25, 1.0)
BUILD_BACKENDS = ("numpy", "jax", "distributed")


# ---------------------------------------------------------------------------
# Helpers
# ---------------------------------------------------------------------------

def _triples(n_ent=40, n_preds=6, n=260, seed=0):
    rng = np.random.default_rng(seed)
    return random_triples(rng, n_ent, n_preds, n)


def assert_catalogs_identical(a, b, ctx=""):
    """Byte-level equality of two catalogs (tables, stats, dictionary)."""
    assert np.asarray(a.tt).tobytes() == np.asarray(b.tt).tobytes(), ctx
    assert set(a.vp) == set(b.vp), ctx
    for p in a.vp:
        assert np.asarray(a.vp[p].rows).tobytes() == \
            np.asarray(b.vp[p].rows).tobytes(), (ctx, p)
    assert set(a.extvp.tables) == set(b.extvp.tables), ctx
    for k in a.extvp.tables:
        assert np.asarray(a.extvp.tables[k].rows).tobytes() == \
            np.asarray(b.extvp.tables[k].rows).tobytes(), (ctx, k)
    assert a.extvp.sf == b.extvp.sf, ctx
    assert a.extvp.sizes == b.extvp.sizes, ctx
    assert a.extvp.threshold == b.extvp.threshold, ctx
    assert tuple(a.extvp.kinds) == tuple(b.extvp.kinds), ctx
    assert a.with_extvp == b.with_extvp, ctx
    # distinct-count and skew statistics (format v2) round-trip exactly —
    # absent on both sides or int-identical per predicate
    assert a.distinct_s == b.distinct_s, ctx
    assert a.distinct_o == b.distinct_o, ctx
    assert a.m2_s == b.m2_s, ctx
    assert a.m2_o == b.m2_o, ctx
    da, db = a.dictionary, b.dictionary
    assert da.id_to_term == db.id_to_term, ctx
    assert da.values.tobytes() == db.values.tobytes(), ctx  # NaN-exact


def _flip_byte(path, offset=3):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


# ---------------------------------------------------------------------------
# Round-trip byte identity: τ × build backend × lazy/eager
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", TAUS)
@pytest.mark.parametrize("build_backend", BUILD_BACKENDS)
def test_roundtrip_byte_identity(tmp_path, tau, build_backend):
    ds = Dataset.from_triples(_triples(), threshold=tau,
                              build_backend=build_backend)
    store = tmp_path / "store"
    ds.save(store)
    for eager in (False, True):
        loaded = Dataset.load(store, eager=eager, verify=True)
        assert_catalogs_identical(ds.catalog, loaded.catalog,
                                  (tau, build_backend, eager))


def test_roundtrip_vp_only_store(tmp_path):
    ds = Dataset.from_triples(_triples(), with_extvp=False)
    ds.save(tmp_path / "s")
    loaded = Dataset.load(tmp_path / "s")
    assert not loaded.catalog.with_extvp
    assert len(loaded.catalog.extvp.sf) == 0
    assert_catalogs_identical(ds.catalog, loaded.catalog)


def test_roundtrip_watdiv_vocabulary(tmp_path):
    """WatDiv terms (prefixed IRIs, numeric literals) survive the
    dictionary round trip and keep the numeric value table bit-exact."""
    ds = Dataset.watdiv(scale=0.2, seed=1, threshold=0.25)
    ds.save(tmp_path / "s")
    loaded = Dataset.load(tmp_path / "s")
    assert_catalogs_identical(ds.catalog, loaded.catalog)
    assert loaded.dictionary.term_to_id == ds.dictionary.term_to_id


def test_save_is_rerunnable_and_prunes_stale_tables(tmp_path):
    """Re-saving over an existing store replaces files atomically and
    drops tables the new catalog no longer references."""
    big = Dataset.from_triples(_triples(n_preds=8), threshold=1.0)
    big.save(tmp_path / "s")
    small = Dataset.from_triples(_triples(n_preds=3, seed=1), threshold=0.25)
    small.save(tmp_path / "s")
    loaded = Dataset.load(tmp_path / "s", verify=True)
    assert_catalogs_identical(small.catalog, loaded.catalog)
    manifest = load_manifest(str(tmp_path / "s"))
    on_disk = set(os.listdir(tmp_path / "s" / "vp"))
    assert on_disk == {os.path.basename(e["file"])
                       for e in manifest["vp"].values()}


# ---------------------------------------------------------------------------
# Laziness: zero-copy memmap tables materialize on first touch
# ---------------------------------------------------------------------------

def test_lazy_load_touches_nothing_until_queried(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    loaded = Dataset.load(tmp_path / "s")
    vp, ext = loaded.catalog.vp, loaded.catalog.extvp.tables
    assert isinstance(vp, LazyTableMap) and isinstance(ext, LazyTableMap)
    assert vp.n_loaded == 0 and ext.n_loaded == 0
    # statistics answer without touching any column file
    some = next(iter(loaded.catalog.extvp.sf))
    loaded.catalog.sf(*some)
    assert vp.n_loaded == 0 and ext.n_loaded == 0
    # a query faults in only what it scans
    loaded.engine("eager").query("SELECT * WHERE { ?s p0 ?o }")
    assert 0 < vp.n_loaded + ext.n_loaded < len(vp) + len(ext)
    # memmap-backed: the table's row storage is the on-disk file
    pid = loaded.dictionary.id_of("p0")
    base, mapped = vp[pid].rows, False
    while base is not None:
        if isinstance(base, np.memmap):
            mapped = True
            break
        base = getattr(base, "base", None)
    assert mapped, "lazy-loaded table is not memory-mapped"


def test_storage_report_and_replay_stay_lazy(tmp_path):
    """Accounting and delta replay must not force the lazy provider:
    storage_report answers from manifest metadata, and replay re-wraps
    carried ExtVP tables as loaders instead of materializing them."""
    ds = Dataset.from_triples(_triples(n_preds=6), threshold=1.0)
    ds.save(tmp_path / "s")
    ds.append_triples([("e1", "p1", "e2")])      # one journaled segment

    loaded = Dataset.load(tmp_path / "s")        # replays the segment
    ext = loaded.catalog.extvp.tables
    assert isinstance(ext, LazyTableMap)
    assert ext.n_loaded == 0, "replay materialized carried ExtVP tables"
    rep = loaded.storage_report()
    assert ext.n_loaded == 0, "storage_report forced table loads"
    # ...and the lazily-counted tuples still match the real ones
    want = ds.storage_report()
    for k in ("vp_tuples", "extvp_tuples", "extvp_tables", "n_triples"):
        assert rep[k] == want[k], k


def test_eager_load_materializes_everything(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    loaded = Dataset.load(tmp_path / "s", eager=True)
    vp, ext = loaded.catalog.vp, loaded.catalog.extvp.tables
    assert vp.n_loaded == len(vp) and ext.n_loaded == len(ext)
    assert not isinstance(vp[next(iter(vp))].rows, np.memmap)


# ---------------------------------------------------------------------------
# Acceptance: loaded catalogs answer identically on every backend
# ---------------------------------------------------------------------------

def test_loaded_catalog_query_parity_all_backends(tmp_path):
    triples = _triples(seed=3)
    built = Dataset.from_triples(triples, threshold=0.25)
    built.save(tmp_path / "s")
    lazy = Dataset.load(tmp_path / "s")
    mesh = jax.make_mesh((1,), ("data",))
    queries = [
        "SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }",
        "SELECT DISTINCT * WHERE { ?a p2 ?b } ORDER BY ?a LIMIT 5",
        "SELECT * WHERE { ?a p0 ?b OPTIONAL { ?b p3 ?c } }",
    ]
    for q in queries:
        want = built.engine("eager").query(q)
        for backend in ("eager", "jit", "distributed"):
            got = lazy.engine(backend,
                              mesh=mesh if backend == "distributed"
                              else None).query(q)
            assert dict(got.as_multiset(sorted(got.cols))) == \
                dict(want.as_multiset(sorted(want.cols))), (backend, q)


@settings(max_examples=6, deadline=None)
@given(st.data())
def test_lazy_eager_parity_differential(data):
    """Differential fuzz: lazy and eager loads of the same store agree
    with each other row-for-row AND with the semantics oracle, across
    random graphs × random query shapes × τ."""
    import tempfile
    seed = data.draw(st.integers(0, 2**32 - 1), label="seed")
    rng = np.random.default_rng(seed)
    n_ent, n_preds = 18, 4
    triples = random_triples(rng, n_ent, n_preds, int(rng.integers(30, 150)))
    tau = [0.25, 1.0][int(rng.integers(0, 2))]
    ds = Dataset.from_triples(triples, threshold=tau)
    with tempfile.TemporaryDirectory() as tmp:
        store = os.path.join(tmp, "s")
        ds.save(store)
        lazy = Dataset.load(store)
        eager = Dataset.load(store, eager=True, verify=True)
        tt = ds.catalog.tt
        for _ in range(3):
            q = random_query(rng, n_ent, n_preds)
            r_lazy = lazy.engine("eager").query(q)
            r_eager = eager.engine("eager").query(q)
            assert_rows_equal(r_lazy, r_eager, ("lazy-vs-eager", seed, q))
            assert_matches_oracle(r_lazy, q, lazy.dictionary, tt,
                                  ("store-vs-oracle", seed, tau))


# ---------------------------------------------------------------------------
# Delta segments: append journaling, replay, compaction
# ---------------------------------------------------------------------------

def test_append_journals_and_replays(tmp_path):
    base = _triples(seed=5)
    extra1 = [("e1", "p1", "e2"), ("e2", "p0", "e3"), ("eX", "pNew", "eY")]
    extra2 = [("e5", "p2", "e1")]
    ds = Dataset.from_triples(base, threshold=0.25)
    ds.save(tmp_path / "s")
    ds.append_triples(extra1)
    ds.append_triples(extra2)
    segs = read_segments(str(tmp_path / "s"))
    assert [s.triples for s in segs] == [[tuple(t) for t in extra1],
                                         [tuple(t) for t in extra2]]
    # replayed load == in-process appended state, byte for byte
    replayed = Dataset.load(tmp_path / "s")
    assert_catalogs_identical(ds.catalog, replayed.catalog)
    # ...and == a from-scratch build over the concatenation
    scratch = Dataset.from_triples(base + extra1 + extra2, threshold=0.25)
    assert_catalogs_identical(scratch.catalog, replayed.catalog)
    assert replayed.storage_report()["delta_segments"] == 2


def test_compact_folds_journal_into_base(tmp_path):
    base = _triples(seed=6)
    extra = [("e0", "p0", "e1"), ("a", "b", "c")]
    ds = Dataset.from_triples(base, threshold=0.25)
    ds.save(tmp_path / "s")
    ds.append_triples(extra)
    assert ds.storage_report()["delta_segments"] == 1
    ds.compact()
    assert ds.storage_report()["delta_segments"] == 0
    assert read_segments(str(tmp_path / "s")) == []
    recold = Dataset.load(tmp_path / "s", verify=True)
    assert_catalogs_identical(ds.catalog, recold.catalog)
    scratch = Dataset.from_triples(base + extra, threshold=0.25)
    assert_catalogs_identical(scratch.catalog, recold.catalog)


def test_append_without_store_does_not_journal(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.append_triples([("x", "y", "z")])
    assert ds.store_path is None
    assert ds.storage_report()["delta_segments"] == 0.0


def test_compact_requires_attachment():
    ds = Dataset.from_triples(_triples())
    with pytest.raises(ValueError, match="store"):
        ds.compact()


# ---------------------------------------------------------------------------
# Corruption / error paths
# ---------------------------------------------------------------------------

def test_load_missing_store(tmp_path):
    assert not is_store(tmp_path / "nope")
    with pytest.raises(StoreFormatError, match="missing manifest.json"):
        Dataset.load(tmp_path / "nope")


def test_load_garbage_manifest(tmp_path):
    d = tmp_path / "s"
    d.mkdir()
    (d / "manifest.json").write_text("{not json")
    with pytest.raises(StoreFormatError, match="unreadable"):
        Dataset.load(d)


def test_load_foreign_format_and_version(tmp_path):
    ds = Dataset.from_triples(_triples())
    ds.save(tmp_path / "s")
    mpath = tmp_path / "s" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["version"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(StoreFormatError, match="version"):
        Dataset.load(tmp_path / "s")
    manifest["format"] = "something-else"
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(StoreFormatError, match="not a"):
        Dataset.load(tmp_path / "s")


def test_distinct_stats_roundtrip_byte_identical(tmp_path):
    """Format v2: per-predicate distinct subject/object counts land in the
    manifest, load back int-identical WITHOUT touching any column file,
    and survive a save→load→save cycle byte-identically."""
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    assert ds.catalog.has_distinct_stats
    ds.save(tmp_path / "a")
    manifest = load_manifest(str(tmp_path / "a"))
    assert manifest["version"] == 2
    assert set(manifest["distinct"]["s"]) == \
        {str(p) for p in ds.catalog.vp}

    loaded = Dataset.load(tmp_path / "a")
    assert loaded.catalog.distinct_s == ds.catalog.distinct_s
    assert loaded.catalog.distinct_o == ds.catalog.distinct_o
    # stats served from the manifest alone — the lazy maps stay cold
    assert loaded.catalog.vp.n_loaded == 0
    assert loaded.catalog.extvp.tables.n_loaded == 0
    # ...and the estimate planner runs off them on the loaded store
    eng = loaded.engine("eager", runtime=RuntimeConfig(planner="estimate"))
    q = "SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }"
    assert eng.prepare(q).plan.planner == "estimate"

    loaded.save(tmp_path / "b")                  # second hop: byte-identical
    m2 = load_manifest(str(tmp_path / "b"))
    assert json.dumps(m2["distinct"], sort_keys=True) == \
        json.dumps(manifest["distinct"], sort_keys=True)


def test_version1_manifest_loads_with_greedy_fallback(tmp_path):
    """A pre-distinct-stats (version 1) store loads cleanly: the catalog
    reports the stats as absent and planner="estimate" silently degrades
    to the greedy order instead of crashing."""
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    mpath = tmp_path / "s" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["version"] = 1
    del manifest["distinct"]
    mpath.write_text(json.dumps(manifest))

    loaded = Dataset.load(tmp_path / "s", verify=True)   # no StoreFormatError
    assert loaded.catalog.distinct_s is None
    assert loaded.catalog.distinct_o is None
    assert not loaded.catalog.has_distinct_stats

    q = "SELECT * WHERE { ?a p0 ?b . ?b p1 ?c }"
    eng = loaded.engine("eager", runtime=RuntimeConfig(planner="estimate"))
    assert eng.prepare(q).plan.planner == "greedy"       # clean fallback
    got = eng.query(q)
    ref = ds.engine("eager").query(q)
    assert dict(got.as_multiset(sorted(got.cols))) == \
        dict(ref.as_multiset(sorted(ref.cols)))


def test_checksum_mismatch_surfaces_on_touch(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    manifest = load_manifest(str(tmp_path / "s"))
    rel = next(iter(manifest["vp"].values()))["file"]
    _flip_byte(tmp_path / "s" / rel)
    # lazy + verify: the load itself succeeds (nothing read yet)...
    loaded = Dataset.load(tmp_path / "s", verify=True)
    pid = int(next(iter(manifest["vp"])))
    with pytest.raises(StoreChecksumError, match="CRC-32"):
        loaded.catalog.vp[pid]                 # ...the touch fails
    # eager + verify fails at load time
    with pytest.raises(StoreChecksumError):
        Dataset.load(tmp_path / "s", eager=True, verify=True)


def test_truncated_table_fails_even_without_verify(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    manifest = load_manifest(str(tmp_path / "s"))
    rel = next(iter(manifest["vp"].values()))["file"]
    fpath = tmp_path / "s" / rel
    fpath.write_bytes(fpath.read_bytes()[:-8])
    loaded = Dataset.load(tmp_path / "s")     # size checked on touch
    pid = int(next(iter(manifest["vp"])))
    with pytest.raises(StoreFormatError, match="size"):
        loaded.catalog.vp[pid]


def test_corrupted_delta_segment(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    ds.append_triples([("q", "r", "s")])
    seg = read_segments(str(tmp_path / "s"))[0]
    data = json.loads(open(seg.path).read())
    data["triples"][0][0] = "tampered"
    open(seg.path, "w").write(json.dumps(data))
    with pytest.raises(StoreChecksumError, match="delta"):
        Dataset.load(tmp_path / "s")


# ---------------------------------------------------------------------------
# Serving boots from the store — zero build-pipeline invocations
# ---------------------------------------------------------------------------

def test_server_boots_from_store_without_build(tmp_path, monkeypatch):
    ds = Dataset.watdiv(scale=0.2, seed=0, threshold=0.25)
    ds.save(tmp_path / "s")
    want = ds.engine("eager").query(
        "SELECT * WHERE { ?u wsdbm:follows ?v }")

    def _no_build(*a, **k):
        raise AssertionError("build pipeline invoked during store boot")
    import repro.core.extvp_build as eb
    import repro.core.stats as stats_mod
    import repro.core.vp as vp_mod
    monkeypatch.setattr(vp_mod, "build_extvp", _no_build)
    monkeypatch.setattr(vp_mod, "build_vp", _no_build)
    monkeypatch.setattr(eb, "build_extvp_planned", _no_build)
    monkeypatch.setattr(stats_mod, "build_catalog", _no_build)

    srv = SparqlServer(str(tmp_path / "s"), backend="eager")
    got = srv.query("SELECT * WHERE { ?u wsdbm:follows ?v }")
    assert dict(got.as_multiset(sorted(got.cols))) == \
        dict(want.as_multiset(sorted(want.cols)))
    assert srv.dataset.store_path == str(tmp_path / "s")


# ---------------------------------------------------------------------------
# Satellites: empty-table singleton, storage_report accounting, inspect tool
# ---------------------------------------------------------------------------

def test_sf_zero_fallback_is_singleton():
    ds = Dataset.from_triples([("a", "p", "b"), ("c", "q", "d")],
                              threshold=1.0)
    cat = ds.catalog
    empty_keys = [k for k, v in cat.extvp.sf.items() if v == 0.0]
    assert empty_keys, "fixture should have an SF=0 pair"
    k = empty_keys[0]
    t1 = cat.table(*k)
    t2 = cat.table(*k)
    assert t1 is t2 and len(t1) == 0
    # and the singleton is shared across catalogs
    ds2 = Dataset.from_triples([("a", "p", "b"), ("c", "q", "d")])
    k2 = [k for k, v in ds2.catalog.extvp.sf.items() if v == 0.0][0]
    assert ds2.catalog.table(*k2) is t1


def test_storage_report_store_accounting(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    rep = ds.storage_report()
    assert rep["store_bytes"] == 0.0 and rep["delta_segments"] == 0.0
    ds.save(tmp_path / "s")
    rep = ds.storage_report()
    sec = ds.catalog.store.bytes_by_section
    assert rep["store_bytes"] == float(sum(sec.values())) > 0
    assert set(sec) == {"manifest", "dictionary", "tt", "vp", "extvp",
                        "delta"}
    # column bytes match the raw int32 encoding exactly
    assert sec["tt"] == ds.catalog.tt.nbytes
    assert sec["vp"] == sum(t.nbytes() for t in ds.catalog.vp.values())
    ds.append_triples([("n1", "n2", "n3")])
    rep = ds.storage_report()
    assert rep["delta_segments"] == 1.0
    # a loaded catalog reports the same persisted totals
    loaded = Dataset.load(tmp_path / "s")
    assert loaded.storage_report()["delta_segments"] == 1.0
    assert loaded.storage_report()["store_bytes"] > 0


def test_store_inspect_tool(tmp_path):
    ds = Dataset.from_triples(_triples(), threshold=0.25)
    ds.save(tmp_path / "s")
    env = dict(os.environ,
               PYTHONPATH="src" + os.pathsep + os.environ.get("PYTHONPATH", ""))
    proc = subprocess.run(
        [sys.executable, "tools/store_inspect.py", str(tmp_path / "s")],
        capture_output=True, text=True, cwd=REPO, env=env)
    assert proc.returncode == 0, proc.stderr
    out = proc.stdout
    assert "threshold τ:      0.25" in out
    assert "checksums:        OK" in out
    assert f"VP tables:        {len(ds.catalog.vp)}" in out
    # corrupt one file -> nonzero exit + mismatch report
    manifest = load_manifest(str(tmp_path / "s"))
    _flip_byte(tmp_path / "s" / next(iter(manifest["vp"].values()))["file"])
    proc = subprocess.run(
        [sys.executable, "tools/store_inspect.py", str(tmp_path / "s")],
        capture_output=True, text=True, cwd=REPO, env=env)
    assert proc.returncode == 1
    assert "CHECKSUM MISMATCH" in proc.stderr


def test_dictionary_from_terms_roundtrip():
    d = Dictionary()
    d.add_all(["iri:a", '"42"^^xsd:integer', "19.99", "plain text"])
    d2 = Dictionary.from_terms(d.id_to_term, d.values)
    assert d2.term_to_id == d.term_to_id
    assert d2.values.tobytes() == d.values.tobytes()
    d3 = Dictionary.from_terms(d.id_to_term)       # recomputed values
    assert d3.values.tobytes() == d.values.tobytes()
    with pytest.raises(ValueError, match="length"):
        Dictionary.from_terms(["a"], [1.0, 2.0])
    with pytest.raises(ValueError, match="duplicate"):
        Dictionary.from_terms(["a", "a"])
