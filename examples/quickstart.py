"""Quickstart: the paper's running example (Figs. 1–12) end to end,
through the unified ``Dataset``/``Engine`` API.

Builds graph G1, constructs VP + ExtVP with statistics, compiles query Q1
showing Algorithm-1 table selection + Algorithm-4 join ordering, and
executes it on all registered backends plus the VP storage baseline.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro import Dataset


def main() -> None:
    # --- Fig. 1: RDF graph G1 -------------------------------------------------
    triples = [
        ("A", "follows", "B"), ("B", "follows", "C"), ("B", "follows", "D"),
        ("C", "follows", "D"), ("A", "likes", "I1"), ("A", "likes", "I2"),
        ("C", "likes", "I2"),
    ]
    ds = Dataset.from_triples(triples)
    print(f"G1: {ds.n_triples} triples, {len(ds.dictionary)} terms")

    # --- §5: VP + ExtVP construction -------------------------------------------
    rep = ds.storage_report()
    print(f"VP tables: {int(rep['vp_tables'])}  "
          f"ExtVP materialized: {int(rep['extvp_tables'])}  "
          f"(empty: {int(rep['extvp_empty'])}, identity: {int(rep['extvp_identity'])})")
    f, l = ds.dictionary.id_of("follows"), ds.dictionary.id_of("likes")
    print(f"SF(ExtVP^OS_follows|likes) = {ds.catalog.sf('OS', f, l)}   # Fig. 10: 0.25")

    # --- §6: query Q1 -----------------------------------------------------------
    q1 = ("SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
          "?y follows ?z . ?z likes ?w }")
    eager = ds.engine("eager")
    print("\ncompiled plan (table selection + join order):")
    print(" ", eager.explain(q1))

    res = eager.query(q1)
    print("\nresult (paper: ?x→A ?y→B ?z→C ?w→I2):")
    for row in res.to_terms():
        print(" ", row)

    # --- device path -------------------------------------------------------------
    res_jit = ds.engine("jit").query(q1)
    print(f"\njitted static-shape engine agrees: {res_jit.same_as(res)}")

    # --- baseline comparison (column order differs; bag comparison aligns) ------
    res_vp = ds.engine("eager", layout="vp").query(q1)
    print(f"VP baseline result identical: {res_vp.same_as(res)}")


if __name__ == "__main__":
    main()
