"""Quickstart: the paper's running example (Figs. 1–12) end to end.

Builds graph G1, constructs VP + ExtVP with statistics, compiles query Q1
showing Algorithm-1 table selection + Algorithm-4 join ordering, and
executes it on all three engines (eager / jitted-static / the VP
baseline).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.compiler import compile_bgp
from repro.core.executor import execute
from repro.core.jexec import PlanExecutor
from repro.core.sparql import parse_sparql
from repro.core.stats import build_catalog
from repro.rdf.dictionary import Dictionary


def main() -> None:
    # --- Fig. 1: RDF graph G1 -------------------------------------------------
    triples = [
        ("A", "follows", "B"), ("B", "follows", "C"), ("B", "follows", "D"),
        ("C", "follows", "D"), ("A", "likes", "I1"), ("A", "likes", "I2"),
        ("C", "likes", "I2"),
    ]
    d = Dictionary()
    tt = d.encode_triples(triples)
    print(f"G1: {len(tt)} triples, {len(d)} terms")

    # --- §5: VP + ExtVP construction -------------------------------------------
    cat = build_catalog(tt, d)
    rep = cat.storage_report()
    print(f"VP tables: {int(rep['vp_tables'])}  "
          f"ExtVP materialized: {int(rep['extvp_tables'])}  "
          f"(empty: {int(rep['extvp_empty'])}, identity: {int(rep['extvp_identity'])})")
    f, l = d.id_of("follows"), d.id_of("likes")
    print(f"SF(ExtVP^OS_follows|likes) = {cat.sf('OS', f, l)}   # Fig. 10: 0.25")

    # --- §6: query Q1 -----------------------------------------------------------
    q1 = parse_sparql(
        "SELECT * WHERE { ?x likes ?w . ?x follows ?y . "
        "?y follows ?z . ?z likes ?w }", d)
    plan = compile_bgp(q1.root, cat)
    print("\ncompiled plan (table selection + join order):")
    print(" ", plan.describe())

    res = execute(q1, cat)
    rows = [{c: d.term_of(int(v)) for c, v in zip(res.cols, r)}
            for r in res.data]
    print("\nresult (paper: ?x→A ?y→B ?z→C ?w→I2):")
    for r in rows:
        print(" ", r)

    # --- device path -------------------------------------------------------------
    ex = PlanExecutor(plan, cat)
    data, cols = ex.run()
    print(f"\njitted static-shape engine agrees: "
          f"{sorted(map(tuple, data.tolist())) == sorted(map(tuple, res.data[:, [res.cols.index(c) for c in cols]].tolist()))}")

    # --- baseline comparison (align columns: join orders differ) --------------------
    res_vp = execute(q1, cat, layout="vp")
    aligned = res_vp.data[:, [res_vp.cols.index(c) for c in res.cols]]
    print(f"VP baseline result identical: "
          f"{sorted(map(tuple, aligned.tolist())) == sorted(map(tuple, res.data.tolist()))}")


if __name__ == "__main__":
    main()
