"""Train a ~100M-class LM for a few hundred steps on the synthetic
pipeline — exercises the full training substrate (AdamW, schedule, grad
accumulation, checkpointing + resume, deterministic data).

On CPU the default is a width-reduced qwen-family config (~13M params;
pass --width 768 --layers 12 for the true ~100M at a few s/step); on a
real TPU slice the same script takes --arch to train any assigned config.

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get
from repro.models.api import Model
from repro.models.config import ShapeCell
from repro.train import checkpoint
from repro.train.data import DataConfig, make_batch
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_state import make_train_step
import dataclasses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    base = get(args.arch)
    cfg = dataclasses.replace(
        base, n_layers=args.layers * len(base.group_pattern),
        d_model=args.width, n_heads=max(4, args.width // 64),
        n_kv=max(2, min(base.n_kv, args.width // 128)),
        d_ff=args.width * 3, vocab=8192, head_dim=None, remat=False)
    # keep n_kv dividing n_heads
    while cfg.n_heads % cfg.n_kv:
        cfg = dataclasses.replace(cfg, n_kv=cfg.n_kv - 1)
    model = Model(cfg)
    cell = ShapeCell("train", args.seq, args.batch, "train")

    params = model.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    print(f"{cfg.name} (reduced): {n_params/1e6:.1f}M params, "
          f"batch {args.batch}x{args.seq}")

    opt_cfg = OptConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, accum_steps=args.accum))
    opt_state = init_opt_state(params)
    dc = DataConfig(seed=0, vocab=min(cfg.vocab, 4096))

    start = 0
    last = checkpoint.latest_step(args.ckpt_dir)
    if last is not None:
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
                            {"params": params, "opt": opt_state})
        restored = checkpoint.restore(args.ckpt_dir, last, like)
        params, opt_state = restored["params"], restored["opt"]
        start = last + 1
        print(f"resumed from checkpoint step {last}")

    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v)
                 for k, v in make_batch(dc, cfg, cell, step).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.perf_counter() - t0
            tput = (step - start + 1) * args.batch * args.seq / max(dt, 1e-9)
            print(f"step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  "
                  f"lr {float(metrics['lr']):.2e}  {tput:,.0f} tok/s")
        if args.ckpt_every and step and step % args.ckpt_every == 0:
            checkpoint.save(args.ckpt_dir, step,
                            {"params": params, "opt": opt_state})
    print("done.")


if __name__ == "__main__":
    main()
