"""End-to-end driver (the paper's kind: a query engine serving requests).

Generates a WatDiv graph, builds the ExtVP store, then serves a batched
mixed workload (Basic Testing + IL + ST templates) measuring per-query
latency and throughput — the serving analogue of the paper's §7
evaluation, with the statistics short-circuit and layout comparison
visible per request.

    PYTHONPATH=src python examples/serve_sparql.py --scale 1.0 --requests 60
"""

import argparse
import time

import numpy as np

from repro.core.compiler import compile_bgp
from repro.core.executor import execute
from repro.core.sparql import parse_sparql
from repro.core.stats import build_catalog
from repro.rdf.generator import WatDivConfig, generate_watdiv
from repro.rdf.workloads import ST_QUERIES, basic_queries, il_queries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--layout", default="extvp", choices=["extvp", "vp", "tt"])
    args = ap.parse_args()

    print(f"generating WatDiv SF={args.scale} ...")
    t0 = time.perf_counter()
    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=args.scale, seed=0))
    print(f"  {len(tt)} triples in {time.perf_counter()-t0:.2f}s")

    print("building VP + ExtVP store ...")
    t0 = time.perf_counter()
    cat = build_catalog(tt, d, threshold=0.25)   # production τ (paper §7.4)
    rep = cat.storage_report()
    print(f"  {int(rep['extvp_tables'])} ExtVP tables "
          f"({rep['extvp_over_vp']:.1f}× VP tuples) "
          f"in {time.perf_counter()-t0:.2f}s")

    # --- build the request mix ------------------------------------------------
    rng = np.random.default_rng(1)
    pool = list(ST_QUERIES.values())
    for qs in basic_queries(sch, seed=2, n_instances=2).values():
        pool.extend(qs)
    for qs in il_queries(sch, seed=3, n_instances=1).values():
        pool.extend(qs)
    requests = [pool[rng.integers(0, len(pool))] for _ in range(args.requests)]

    # --- serve ------------------------------------------------------------------
    lat = []
    empties = 0
    total_rows = 0
    t_start = time.perf_counter()
    for qtext in requests:
        t0 = time.perf_counter()
        q = parse_sparql(qtext, d)
        # statistics short-circuit: provably-empty queries never scan
        from repro.core.algebra import BGP
        if isinstance(q.root, BGP) and compile_bgp(q.root, cat, args.layout).empty:
            empties += 1
            lat.append(time.perf_counter() - t0)
            continue
        res = execute(q, cat, layout=args.layout)
        total_rows += len(res)
        lat.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t_start

    lat_ms = np.asarray(lat) * 1e3
    print(f"\nserved {len(requests)} requests in {wall:.2f}s "
          f"({len(requests)/wall:.1f} qps), layout={args.layout}")
    print(f"  latency ms: p50={np.percentile(lat_ms,50):.1f} "
          f"p90={np.percentile(lat_ms,90):.1f} p99={np.percentile(lat_ms,99):.1f} "
          f"max={lat_ms.max():.1f}")
    print(f"  result rows: {total_rows}, statistics-only empty answers: {empties}")


if __name__ == "__main__":
    main()
