"""End-to-end driver (the paper's kind: a query engine serving requests).

Generates a WatDiv graph, builds the ExtVP store via the ``Dataset``
facade, then serves a batched mixed workload (Basic Testing + IL + ST
templates) through an ``Engine`` — the serving analogue of the paper's §7
evaluation.  Because the workload repeats templates, the engine's plan
cache means requests after the first instantiation of each template skip
parsing and compilation entirely (watch ``plan_hit_rate``).

    PYTHONPATH=src python examples/serve_sparql.py --scale 1.0 --requests 60
    PYTHONPATH=src python examples/serve_sparql.py --backend jit
"""

import argparse
import time

import numpy as np

from repro import Dataset
from repro.rdf.workloads import ST_QUERIES, basic_queries, il_queries


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--layout", default="extvp", choices=["extvp", "vp", "tt"])
    ap.add_argument("--backend", default="eager",
                    help="ExecutionBackend registry key (eager/jit/...) or "
                         "'auto' for per-template adaptive routing")
    args = ap.parse_args()

    print(f"generating WatDiv SF={args.scale} ...")
    t0 = time.perf_counter()
    ds = Dataset.watdiv(scale=args.scale, seed=0,
                        threshold=0.25)   # production τ (paper §7.4)
    rep = ds.storage_report()
    print(f"  {ds.n_triples} triples; {int(rep['extvp_tables'])} ExtVP tables "
          f"({rep['extvp_over_vp']:.1f}× VP tuples) "
          f"in {time.perf_counter()-t0:.2f}s")

    # --- build the request mix ------------------------------------------------
    rng = np.random.default_rng(1)
    pool = list(ST_QUERIES.values())
    for qs in basic_queries(ds.schema, seed=2, n_instances=2).values():
        pool.extend(qs)
    for qs in il_queries(ds.schema, seed=3, n_instances=1).values():
        pool.extend(qs)
    requests = [pool[rng.integers(0, len(pool))] for _ in range(args.requests)]

    # --- serve ------------------------------------------------------------------
    engine = ds.engine(args.backend, layout=args.layout)
    t_start = time.perf_counter()
    engine.query_batch(requests)
    wall = time.perf_counter() - t_start

    m = engine.metrics.summary()
    print(f"\nserved {int(m['served'])} requests in {wall:.2f}s "
          f"({m['served']/wall:.1f} qps), layout={args.layout}, "
          f"backend={engine.backend}")
    print(f"  latency ms: p50={m['p50_ms']:.1f} p90={m['p90_ms']:.1f} "
          f"p99={m['p99_ms']:.1f}")
    print(f"  plan-cache hit rate: {m['plan_hit_rate']:.2f} "
          f"({engine.cache.evictions} evictions)")
    print(f"  micro-batches: {int(m['batches'])} launches for "
          f"{int(m['batched_requests'])} requests "
          f"(occupancy {m['batch_occupancy']:.2f}, "
          f"padding waste {m['padding_waste']:.2f})")
    print(f"  result rows: {int(m['rows'])}, empty answers: "
          f"{int(m['empties'])} (statistics-only: {int(m['short_circuits'])})")
    if m["routed"]:
        print(f"  adaptive routing: {m['routed']} "
              "(engine.runtime_report() has the full decision log)")


if __name__ == "__main__":
    main()
