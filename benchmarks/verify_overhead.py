"""Static-verifier prepare() overhead gate (``verify_plans=True``).

Measures what :mod:`repro.analysis.verifier` adds to the engine's
prepare path over the WatDiv basic suite.  The honest denominator is a
**cold cache-miss prepare**: in a live process the plan cache serves
every repeated template without reaching ``Engine._build`` at all, so
the only prepares that ever happen are first-time ones that pay parse +
plan + backend trace/compile.  Warm in-process rebuild loops (where
jax's compile caches cut a build to ~0.1 ms) measure a state the plan
cache makes unreachable and wildly overstate the verifier's share.

Measurement design: the verifier is strictly additive — ``_build`` runs
it after the backend's prepare, sharing no state with it — so each cold
subprocess times the two terms separately on the same artifacts (one
pass of cold prepares, then one pass of cold verifies) and reports the
ratio.  A/B-ing whole subprocesses instead would difference two ~ms
compile times whose run-to-run variance dwarfs the ~50 µs verifier
term.

Emits ``BENCH_verify_overhead.json``::

    {"scale": ..., "n_queries": ..., "reps": ...,
     "prepare_ms_per_query": ..., "verify_ms_per_query": ...,
     "overhead_pct": ..., "gate_pct": 5.0, "ok": true}

and fails the harness row (derived ``FAIL``) when the overhead exceeds
the 5% gate.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

DEFAULT_OUT = "BENCH_verify_overhead.json"
GATE_PCT = 5.0
REPS = 5
#: the overhead is a ratio of per-query times and is insensitive to
#: graph scale (numerator and denominator both grow with plan size);
#: cap the child's generation cost so the gate stays cheap to run
MAX_SCALE = 0.5


def _child(scale: float) -> None:
    """One cold process: build the store, cold-prepare the basic suite
    with the verifier off, then cold-verify the prepared artifacts.
    Prints both per-query times as the last stdout line."""
    from repro.analysis.verifier import verify_prepared
    from repro.core.stats import build_catalog
    from repro.engine import RuntimeConfig
    from repro.engine.dataset import Dataset
    from repro.rdf.generator import WatDivConfig, generate_watdiv
    from repro.rdf.workloads import basic_queries

    tt, d, sch = generate_watdiv(WatDivConfig(scale_factor=scale, seed=7))
    cat = build_catalog(tt, d)
    ds = Dataset(cat, d, sch)
    queries = [q for insts in basic_queries(sch, n_instances=1).values()
               for q in insts]
    eng = ds.engine("jit", runtime=RuntimeConfig(verify_plans=False))
    t0 = time.perf_counter()
    prepped = [eng.prepare(q) for q in queries]
    t_prepare = time.perf_counter() - t0
    t0 = time.perf_counter()
    for p in prepped:
        verify_prepared(p, cat).raise_if_failed()
    t_verify = time.perf_counter() - t0
    print(json.dumps({"prepare_s": t_prepare / len(queries),
                      "verify_s": t_verify / len(queries),
                      "n_queries": len(queries)}))


def _spawn(scale: float) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(root, "src"), root,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    out = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child",
         "--scale", str(scale)],
        env=env, cwd=root, capture_output=True, text=True, check=True)
    return json.loads(out.stdout.strip().splitlines()[-1])


def run(scale: float = 5.0, csv=None, out_path: str = DEFAULT_OUT) -> dict:
    scale = min(scale, MAX_SCALE)
    results = [_spawn(scale) for _ in range(REPS)]
    ratios = sorted(r["verify_s"] / r["prepare_s"] for r in results)
    prep = sorted(r["prepare_s"] for r in results)
    ver = sorted(r["verify_s"] for r in results)
    overhead = ratios[len(ratios) // 2] * 100.0
    report = {
        "scale": scale, "n_queries": results[0]["n_queries"], "reps": REPS,
        "prepare_ms_per_query": prep[len(prep) // 2] * 1e3,
        "verify_ms_per_query": ver[len(ver) // 2] * 1e3,
        "overhead_pct": overhead, "gate_pct": GATE_PCT,
        "ok": overhead < GATE_PCT,
    }
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    if csv is not None:
        csv.add("verify_overhead", ver[len(ver) // 2],
                f"overhead={overhead:.2f}%"
                + ("" if report["ok"] else " FAIL"))
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=5.0)
    ap.add_argument("--child", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    args = ap.parse_args()
    if args.child:
        _child(min(args.scale, MAX_SCALE))
        return
    report = run(scale=args.scale, out_path=args.out)
    print(json.dumps(report, indent=2))
    if not report["ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
